"""Key format tests (§4.1): order preservation per type, round trips,
multi-column lexicographic semantics, varchar terminator behaviour."""

import struct

import numpy as np
import pytest

pytest.importorskip("hypothesis")  # property tests need the dev extra
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import keyformat as KF


@given(st.integers(-(2**31), 2**31 - 1), st.integers(-(2**31), 2**31 - 1))
def test_int32_order(a, b):
    assert (KF.encode_int32(a) < KF.encode_int32(b)) == (a < b)
    assert KF.decode_int32(KF.encode_int32(a)) == a


@given(st.integers(-(2**63), 2**63 - 1), st.integers(-(2**63), 2**63 - 1))
def test_int64_order(a, b):
    assert (KF.encode_int64(a) < KF.encode_int64(b)) == (a < b)
    assert KF.decode_int64(KF.encode_int64(a)) == a


_floats = st.floats(allow_nan=False, width=32)


@given(_floats, _floats)
def test_float32_order(a, b):
    af = struct.unpack(">f", struct.pack(">f", a))[0]
    bf = struct.unpack(">f", struct.pack(">f", b))[0]
    ka, kb = KF.encode_float32(af), KF.encode_float32(bf)
    if af == bf:  # +0.0 / -0.0 keys may differ; order among equals is free
        return
    assert (ka < kb) == (af < bf)
    assert KF.decode_float32(ka) == af


@given(st.floats(allow_nan=False), st.floats(allow_nan=False))
def test_float64_order(a, b):
    if a == b:
        return
    assert (KF.encode_float64(a) < KF.encode_float64(b)) == (a < b)
    assert KF.decode_float64(KF.encode_float64(a)) == a


@given(st.integers(-(10**9), 10**9), st.integers(-(10**9), 10**9))
def test_decimal_order(a, b):
    ka, kb = KF.encode_decimal(a, 5), KF.encode_decimal(b, 5)
    assert (ka < kb) == (a < b)
    assert KF.decode_decimal(ka, 5) == a


def test_decimal_paper_figure4():
    """Exact byte patterns from Figure 4 (2-byte decimal(2,0))."""
    assert KF.encode_decimal(99, 1) == bytes([0b00000011, 0b01100011])
    assert KF.encode_decimal(1, 1) == bytes([0b00000011, 0b00000001])
    assert KF.encode_decimal(0, 1) == bytes([0b00000011, 0b00000000])
    assert KF.encode_decimal(-1, 1) == bytes([0b00000010, 0b11111110])
    assert KF.encode_decimal(-99, 1) == bytes([0b00000010, 0b10011100])
    assert KF.encode_decimal(None, 1) < KF.encode_decimal(-99, 1)  # null lowest


_varchar = st.text(
    alphabet=st.characters(min_codepoint=1, max_codepoint=127), max_size=20
)


@given(_varchar, _varchar)
def test_varchar_order(a, b):
    ka, kb = KF.encode_varchar(a, 32), KF.encode_varchar(b, 32)
    assert (ka < kb) == (a.encode() < b.encode())


def test_varchar_prefix_case():
    """AB∅ < ABA∅: the distinction bit lands in the terminator (§4.1.C)."""
    ka, kb = KF.encode_varchar("AB", 30), KF.encode_varchar("ABA", 30)
    assert ka < kb
    with pytest.raises(ValueError):
        KF.encode_varchar("A\x00B", 30)


@given(
    st.lists(
        st.tuples(st.integers(-100, 100), _varchar, st.integers(-100, 100)),
        min_size=2,
        max_size=20,
    )
)
@settings(max_examples=50)
def test_multicolumn_lexicographic(rows):
    """Tuple order == encoded byte order (Figure 5 semantics), including
    padded packed-word comparisons."""
    enc = [
        KF.encode_multicolumn(
            [KF.encode_int32(a), KF.encode_varchar(s, 24), KF.encode_int32(b)]
        )
        for (a, s, b) in rows
    ]
    want = sorted(range(len(rows)), key=lambda i: (rows[i][0], rows[i][1].encode(), rows[i][2]))
    got = sorted(range(len(rows)), key=lambda i: enc[i])
    # equal keys may permute freely: compare by tuple values not index
    assert [rows[i] for i in got] == [rows[i] for i in want]
    # packed words preserve order too (zero padding, §4.1)
    ks = KF.keys_to_words(enc)
    order = sorted(range(len(rows)), key=lambda i: tuple(ks.words[i]) + (rows[i],))
    by_words = [rows[i] for i in sorted(range(len(rows)), key=lambda i: tuple(int(w) for w in ks.words[i]))]
    by_bytes = [rows[i] for i in got]
    assert by_words == by_bytes


def test_keys_to_words_roundtrip():
    keys = [b"hello", b"a", b"longer-key-material!"]
    ks = KF.keys_to_words(keys)
    for i, k in enumerate(keys):
        assert KF.words_to_bytes(ks.words[i], int(ks.lengths[i])) == k
