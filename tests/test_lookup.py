"""The ``lookup`` backend op: parity, padding, plan-cache behaviour.

The contract under test: batched point lookups return ``(found, rid)``
with miss lanes normalized to ``NOT_FOUND_RID``, byte-identical across
the jnp oracle, the pallas partial-key probe kernel, and the distributed
owner-shard routing — including duplicate keys, all-ones sentinel-shaped
keys, and query batches straddling plan-cache bucket boundaries — while
a steady query stream at drifting batch sizes replays one compiled
program (the trace counter stays flat).
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.backends import get_backend
from repro.core import plancache
from repro.core.btree import NOT_FOUND_RID, lookup_batch_planned, search_batch
from repro.core.keyformat import KeySet
from repro.core.pipeline import ReconstructionPipeline

BACKENDS = ("jnp", "pallas", "distributed")


def _backend(name):
    return get_backend(name, **({"interpret": True} if name == "pallas" else {}))


def _keyset(rng, n, w=3, mask=0x00FF0F0F):
    words = rng.integers(0, 2**32, size=(n, w), dtype=np.uint32) & np.uint32(mask)
    return KeySet(
        words=words, lengths=np.full(n, w * 4, np.int32),
        rids=np.arange(n, dtype=np.uint32),
    )


def _oracle(tree, queries):
    """search_batch with the op's miss normalization — the reference."""
    found, rid, _ = search_batch(tree, jnp.asarray(queries, jnp.uint32))
    found = np.asarray(found, bool)
    return found, np.where(found, np.asarray(rid, np.uint32), NOT_FOUND_RID)


def _mixed_queries(rng, words):
    """Hits, misses, duplicate-key hits, and all-ones keys in one batch."""
    n = words.shape[0]
    hits = words[rng.integers(0, n, size=40)]
    misses = words[rng.integers(0, n, size=20)] ^ np.uint32(0x1)
    ones = np.full((3, words.shape[1]), 0xFFFFFFFF, np.uint32)
    return np.concatenate([hits, misses, ones], axis=0)


# ---------------------------------------------------------------------------
# parity across backends
# ---------------------------------------------------------------------------


def test_lookup_parity_hit_miss_dup_allones(rng):
    ks = _keyset(rng, 900)
    words = np.asarray(ks.words)
    words[5] = words[6]          # duplicate keys, distinct rids
    words[7] = 0xFFFFFFFF        # a real all-ones key (pad-sentinel shaped)
    ks = KeySet(words=words, lengths=ks.lengths, rids=ks.rids)
    res = ReconstructionPipeline(backend="jnp").run(ks)
    queries = np.concatenate(
        [_mixed_queries(rng, words), words[5][None, :]], axis=0
    )
    want_f, want_r = _oracle(res.tree, queries)
    assert want_f.any() and (~want_f).any()  # the batch exercises both
    for name in BACKENDS:
        got_f, got_r = _backend(name).lookup(res.tree, jnp.asarray(queries))
        np.testing.assert_array_equal(want_f, np.asarray(got_f), err_msg=name)
        np.testing.assert_array_equal(want_r, np.asarray(got_r), err_msg=name)
    # the duplicate-key query resolves to the first equal entry in
    # (key, row) order on every backend
    dup_q = words[5][None, :]
    rids = {n: int(_backend(n).lookup(res.tree, jnp.asarray(dup_q))[1][0])
            for n in BACKENDS}
    assert len(set(rids.values())) == 1, rids


@pytest.mark.parametrize("off", [-1, 0, 1])
def test_lookup_bucket_boundary_batches(rng, off):
    """Query batches straddling a bucket boundary answer identically to
    the unpadded oracle (pad lanes are invisible)."""
    ks = _keyset(rng, 1200)
    res = ReconstructionPipeline(backend="jnp").run(ks)
    q = plancache.BUCKET_MIN + off
    queries = np.asarray(ks.words)[rng.integers(0, ks.n, size=q)]
    queries[::3] ^= np.uint32(0x2)  # sprinkle misses
    want_f, want_r = _oracle(res.tree, queries)
    for name in BACKENDS:
        got_f, got_r = _backend(name).lookup(res.tree, jnp.asarray(queries))
        np.testing.assert_array_equal(want_f, np.asarray(got_f), err_msg=name)
        np.testing.assert_array_equal(want_r, np.asarray(got_r), err_msg=name)


def test_lookup_distributed_routing_parity(rng, monkeypatch):
    """The owner-shard routed path (p > 1) scatters per-shard answers back
    into query order, byte-identical to the unrouted oracle."""
    from repro.backends.distributed import DistributedBackend

    ks = _keyset(rng, 800)
    res = ReconstructionPipeline(backend="jnp").run(ks)
    b = get_backend("distributed")
    monkeypatch.setattr(DistributedBackend, "n_devices", property(lambda self: 4))
    queries = _mixed_queries(rng, np.asarray(ks.words))
    want_f, want_r = _oracle(res.tree, queries)
    got_f, got_r = b.lookup(res.tree, jnp.asarray(queries))
    np.testing.assert_array_equal(want_f, np.asarray(got_f))
    np.testing.assert_array_equal(want_r, np.asarray(got_r))
    routed = b.last_info["lookup_routed"]
    assert len(routed) == 4 and sum(routed) == queries.shape[0]
    assert sum(1 for c in routed if c) >= 2  # the mix actually spread out


# ---------------------------------------------------------------------------
# plan-cache behaviour
# ---------------------------------------------------------------------------


def test_lookup_steady_stream_zero_retrace(rng):
    """Drifting same-bucket batch sizes replay one compiled program."""
    ks = _keyset(rng, 1000)
    res = ReconstructionPipeline(backend="jnp").run(ks)
    b = _backend("jnp")
    b.lookup(res.tree, jnp.asarray(np.asarray(ks.words)[:200]))  # trace
    s0 = plancache.cache_stats()
    for q in (130, 255, 64, 201):
        b.lookup(res.tree, jnp.asarray(np.asarray(ks.words)[:q]))
    s1 = plancache.cache_stats()
    assert s1["traces"] == s0["traces"], (s0, s1)
    assert s1["hits"] >= s0["hits"] + 4


def test_lookup_zero_retrace_across_snapshot_versions(rng):
    """A rebuild of the same-sized index (a new snapshot epoch) replays
    the cached lookup program — the steady read path never recompiles."""
    from repro.core.pipeline import fold_keyset

    ks = _keyset(rng, 1000)
    pipe = ReconstructionPipeline(backend="jnp")
    prev = pipe.run(ks)
    b = _backend("jnp")
    queries = jnp.asarray(np.asarray(ks.words)[:100])
    b.lookup(prev.tree, queries)  # trace
    # balanced churn: delete 30 rows, insert 30 redrawn ones — n unchanged
    keep = np.ones(ks.n, bool)
    keep[rng.choice(ks.n, size=30, replace=False)] = False
    delta = KeySet(
        words=np.asarray(ks.words)[rng.integers(0, ks.n, size=30)],
        lengths=np.full(30, 12, np.int32),
        rids=np.arange(5000, 5030, dtype=np.uint32),
    )
    from repro.core.metadata import meta_from_keys

    meta = meta_from_keys(np.concatenate([ks.words, delta.words]))
    prev = pipe.run(ks, meta=meta)
    b.lookup(prev.tree, queries)  # (re)trace under this meta's geometry
    nxt, folded = pipe.run_incremental(prev, ks, delta, keep_rows=keep, meta=meta)
    assert folded.n == ks.n
    s0 = plancache.cache_stats()
    got_f, got_r = b.lookup(nxt.tree, queries)
    s1 = plancache.cache_stats()
    assert s1["traces"] == s0["traces"], (s0, s1)
    want_f, want_r = _oracle(nxt.tree, np.asarray(queries))
    np.testing.assert_array_equal(want_f, np.asarray(got_f))
    np.testing.assert_array_equal(want_r, np.asarray(got_r))


# ---------------------------------------------------------------------------
# the probe kernel itself
# ---------------------------------------------------------------------------


def test_lookup_kernel_probe_matches_ref(rng):
    from repro.kernels.lookup import probe
    from repro.kernels.lookup.ref import probe_ref

    for m, w in ((37, 2), (512, 3), (700, 3)):
        queries = rng.integers(0, 2**32, size=(m, w), dtype=np.uint32)
        starts = rng.integers(-4, w * 32 + 4, size=(m,)).astype(np.int32)
        for pk in (8, 16):
            # half the lanes get their true window (match), half garbage
            from repro.kernels.build.ref import pk_windows_ref

            entry_pk = pk_windows_ref(queries, starts, pk)
            entry_pk[::2] ^= np.uint32(1)
            want = probe_ref(queries, starts, entry_pk, pk)
            got = np.asarray(
                probe(jnp.asarray(queries), jnp.asarray(starts),
                      jnp.asarray(entry_pk), pk, interpret=True)
            )
            np.testing.assert_array_equal(want, got)
            assert want[1::2].all() and not want[::2].any()


def test_scalar_search_is_batched_row(rng):
    """The bugfix contract: OnlineIndex.search is a thin wrapper over
    search_batch, so the scalar and batched answers cannot diverge."""
    from repro.core.index import OnlineIndex

    ks = _keyset(rng, 400)
    oi = OnlineIndex.build(ks)
    oi.insert(np.asarray([9, 9, 9], np.uint32), 777)
    oi.delete(np.asarray(ks.words[3]))
    queries = np.concatenate(
        [np.asarray(ks.words)[:8], np.asarray([[9, 9, 9]], np.uint32)]
    )
    fb, rb = oi.search_batch(queries)
    for i, q in enumerate(queries):
        f, r = oi.search(q)
        assert (f, r) == (bool(fb[i]), int(rb[i]))
    assert not fb[3]  # the tombstoned row
    assert fb[-1] and rb[-1] == 777  # the delta row


# ---------------------------------------------------------------------------
# hypothesis sweep (parity across backends and bucket boundaries)
# ---------------------------------------------------------------------------


def test_lookup_parity_hypothesis(rng):
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings
    from hypothesis import strategies as st

    @settings(max_examples=10, deadline=None)
    @given(
        seed=st.integers(0, 10**6),
        n=st.integers(280, 600),
        q_off=st.integers(-2, 2),
        dup=st.booleans(),
        ones=st.booleans(),
    )
    def check(seed, n, q_off, dup, ones):
        r = np.random.default_rng(seed)
        words = r.integers(0, 2**32, size=(n, 2), dtype=np.uint32) & np.uint32(
            0x0FFF00FF
        )
        if dup:
            words[1] = words[0]
        if ones:
            words[2] = 0xFFFFFFFF
        ks = KeySet(
            words=words, lengths=np.full(n, 8, np.int32),
            rids=np.arange(n, dtype=np.uint32),
        )
        res = ReconstructionPipeline(backend="jnp").run(ks)
        q = max(1, plancache.BUCKET_MIN + q_off)
        queries = words[r.integers(0, n, size=q)]
        queries[::2] ^= np.uint32(0x4)
        want_f, want_r = _oracle(res.tree, queries)
        for name in BACKENDS:
            got_f, got_r = _backend(name).lookup(res.tree, jnp.asarray(queries))
            np.testing.assert_array_equal(want_f, np.asarray(got_f), err_msg=name)
            np.testing.assert_array_equal(want_r, np.asarray(got_r), err_msg=name)

    check()
