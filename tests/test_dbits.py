"""Theory tests: Lemma 1, Theorem 1, Theorem 2 (paper §3) as properties."""

import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # property tests need the dev extra
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import compress as C
from repro.core import dbits as D


def _keyset(draw_ints, n_words):
    return np.asarray(draw_ints, dtype=np.uint32).reshape(-1, n_words)


@st.composite
def key_arrays(draw, max_n=64, max_w=4):
    w = draw(st.integers(1, max_w))
    n = draw(st.integers(2, max_n))
    # limited variant positions make duplicates + structure likely
    mask = draw(st.integers(1, 2**32 - 1))
    vals = draw(
        st.lists(st.integers(0, 2**32 - 1), min_size=n * w, max_size=n * w)
    )
    arr = _keyset(vals, w) & np.uint32(mask)
    return arr


@given(key_arrays())
@settings(max_examples=60, deadline=None)
def test_theorem1_pairwise_dbits_subset_of_adjacent(arr):
    """D_all == D_adj (Theorem 1): every pairwise distinction bit position
    appears among adjacent-pair positions of the sorted order."""
    arr = np.unique(arr, axis=0)
    if arr.shape[0] < 2:
        return
    jw = jnp.asarray(arr)
    (sw,) = D.sort_words(jw)
    adj = np.asarray(D.adjacent_dbit_positions(sw))
    adj_set = set(int(p) for p in adj if p != D.NO_DBIT)
    n = arr.shape[0]
    ii, jj = np.triu_indices(n, k=1)  # ALL pairs (n <= 64)
    pw = np.asarray(D.dbit_position_pairwise(sw[ii], sw[jj]))
    pw_set = set(int(p) for p in pw if p != D.NO_DBIT)
    assert pw_set <= adj_set  # D_all ⊆ D_adj
    assert adj_set <= pw_set  # D_adj ⊆ D_all (trivially, but checks both)


@given(key_arrays())
@settings(max_examples=60, deadline=None)
def test_theorem2_compressed_sort_equals_full_sort(arr):
    """Sorting by the distinction-bit slice reproduces the full-key order."""
    arr = np.unique(arr, axis=0)
    if arr.shape[0] < 2:
        return
    rng = np.random.default_rng(0)
    perm = rng.permutation(arr.shape[0])
    arr = arr[perm]
    jw = jnp.asarray(arr)
    bm = D.compute_dbitmap(jw)
    plan = C.make_plan(np.asarray(bm), arr.shape[1])
    comp = C.extract_bits(jw, plan)
    (_, p_comp) = D.sort_words(comp, jnp.arange(arr.shape[0], dtype=jnp.uint32))
    full_sorted_by_comp = arr[np.asarray(p_comp)]
    as_tuples = [tuple(r) for r in full_sorted_by_comp]
    assert as_tuples == sorted(as_tuples)


@given(key_arrays(), st.integers(0, 2**32 - 1))
@settings(max_examples=40, deadline=None)
def test_theorem2_extended_positions_also_sort(arr, extra_mask):
    """Extended distinction bit positions (any superset) still sort correctly
    — the basis for lazy deletes (§4.3)."""
    arr = np.unique(arr, axis=0)
    if arr.shape[0] < 2:
        return
    jw = jnp.asarray(arr)
    bm = np.asarray(D.compute_dbitmap(jw))
    bm = bm.copy()
    bm[0] |= np.uint32(extra_mask)  # superset: extra stale/invalid bits
    plan = C.make_plan(bm, arr.shape[1])
    comp = C.extract_bits(jw, plan)
    (_, p) = D.sort_words(comp, jnp.arange(arr.shape[0], dtype=jnp.uint32))
    out = [tuple(r) for r in arr[np.asarray(p)]]
    assert out == sorted(out)


def test_lemma1_min_of_adjacent(rng):
    """D-bit(key_i, key_j) == min_{i<k<=j} D_k (Lemma 1)."""
    arr = np.unique(
        rng.integers(0, 2**32, size=(40, 2), dtype=np.uint32) & np.uint32(0xFF3C0FF0),
        axis=0,
    )
    jw = jnp.asarray(arr)
    (sw,) = D.sort_words(jw)
    adj = np.asarray(D.adjacent_dbit_positions(sw))
    n = sw.shape[0]
    for i in range(n - 1):
        for j in range(i + 1, n):
            got = int(D.dbit_position_pairwise(sw[i][None], sw[j][None])[0])
            want = int(min(adj[i:j]))
            assert got == want, (i, j, got, want)


def test_figure2_example():
    """The worked example of Figure 2: 12-bit keys, positions as in text."""
    rows = [
        "000010100100",  # key0
        "000011101100",  # key1 (D1=5)
        "010000100110",  # key2 (D2=1)
        "010000110110",  # key3 (D3=7)
    ]
    # build keys whose adjacent dbits are D1=5, D2=1, D3=7 as in the text
    arr = np.asarray(
        [[int(r, 2) << 20] for r in rows], dtype=np.uint32
    )  # left-align 12 bits
    jw = jnp.asarray(arr)
    adj = np.asarray(D.adjacent_dbit_positions(jw))
    assert list(adj) == [5, 1, 7]
    # Lemma-1 spot checks from the paper text
    assert int(D.dbit_position_pairwise(jw[1], jw[3])) == 1  # min(D2,D3)=1
    assert int(D.dbit_position_pairwise(jw[0], jw[2])) == 1  # min(D1,D2)=1


def test_bitmap_roundtrip(rng):
    pos = np.unique(rng.integers(0, 96, size=20)).astype(np.int32)
    bm = D.positions_to_bitmap(jnp.asarray(pos), 3)
    back = D.bitmap_to_positions(np.asarray(bm))
    assert list(back) == sorted(pos.tolist())
    assert int(D.bitmap_popcount(bm)) == len(pos)


def test_variant_bitmap_covers_dbitmap(rng):
    arr = rng.integers(0, 2**32, size=(100, 3), dtype=np.uint32) & np.uint32(
        0x0FF0F00F
    )
    jw = jnp.asarray(arr)
    dbm = np.asarray(D.compute_dbitmap(jw))
    var, _ = D.compute_variant_bitmap(jw)
    var = np.asarray(var)
    # distinction bits are variant bits (§3.1)
    assert all((d & v) == d for d, v in zip(dbm, var))
