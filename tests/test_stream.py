"""Async streaming replication tests (transport, protocol, catch-up).

The acceptance contract: a replica driven ONLY through the stream
transport — including one forced checkpoint catch-up — ends byte-identical
to a full ``ReconstructionPipeline.run`` over the folded keyset, on the
jnp and pallas backends.  Around it: transport semantics (positions,
atomic frames, retention), LSN watermark enforcement (out-of-order
rejected, duplicates idempotent, overlaps sliced), wire framing round
trips (including the shed-policy state regression), bounded-lag
backpressure, and the serve-layer standby (pager journal shipping +
engine follow mode).
"""

import numpy as np
import pytest

from repro.core import plancache
from repro.core.keyformat import KeySet
from repro.core.pipeline import ReconstructionPipeline
from repro.replication import (
    BatchFrame,
    ChangeLog,
    CheckpointFrame,
    DirectoryTransport,
    FrameTruncated,
    LsnGapError,
    QueueTransport,
    StreamPrimary,
    StreamReplica,
    decode_frame,
    encode_frame,
)


def _keyset(rng, n, w=3, mask=0x00FF0F0F, rid_base=0) -> KeySet:
    words = rng.integers(0, 2**32, size=(n, w), dtype=np.uint32) & np.uint32(mask)
    return KeySet(
        words=words,
        lengths=np.full(n, w * 4, np.int32),
        rids=np.arange(rid_base, rid_base + n, dtype=np.uint32),
    )


def _random_batch(rng, primary, n_ins=40, n_del=8, rid_base=100_000):
    """One LSN-contiguous batch re-drawing live keys (no new D-bits)."""
    ks = primary.replica.keyset
    log = ChangeLog(ks.n_words, start_lsn=primary.next_lsn)
    if n_ins:
        pick = rng.integers(0, ks.n, size=n_ins)
        log.append_inserts(
            np.asarray(ks.words)[pick],
            rid_base + rng.integers(0, 2**20, size=n_ins).astype(np.uint32),
        )
    if n_del:
        dead = rng.choice(np.asarray(ks.rids), size=min(n_del, ks.n), replace=False)
        log.append_deletes(dead)
    return log


def _assert_replica_state_identical(a, b):
    """Byte-identity of two replicas: keyset, metadata, standing result."""
    np.testing.assert_array_equal(np.asarray(a.keyset.words), np.asarray(b.keyset.words))
    np.testing.assert_array_equal(np.asarray(a.keyset.rids), np.asarray(b.keyset.rids))
    np.testing.assert_array_equal(a.meta.dbitmap, b.meta.dbitmap)
    np.testing.assert_array_equal(a.meta.varbitmap, b.meta.varbitmap)
    np.testing.assert_array_equal(a.meta.refkey, b.meta.refkey)
    np.testing.assert_array_equal(
        np.asarray(a.result.comp_sorted), np.asarray(b.result.comp_sorted)
    )
    np.testing.assert_array_equal(
        np.asarray(a.result.rid_sorted), np.asarray(b.result.rid_sorted)
    )
    assert a.applied_lsn == b.applied_lsn


def _assert_matches_full_run(rep, backend):
    """The stream-driven replica == a full pipeline run over its keyset."""
    full = ReconstructionPipeline(backend=backend).run(rep.keyset, meta=rep.meta)
    np.testing.assert_array_equal(
        np.asarray(rep.result.comp_sorted), np.asarray(full.comp_sorted)
    )
    np.testing.assert_array_equal(
        np.asarray(rep.result.rid_sorted), np.asarray(full.rid_sorted)
    )
    assert len(rep.result.tree.levels) == len(full.tree.levels)
    for la, lb in zip(rep.result.tree.levels, full.tree.levels):
        for k in la:
            np.testing.assert_array_equal(np.asarray(la[k]), np.asarray(lb[k]))


# ---------------------------------------------------------------------------
# transports
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("kind", ["queue", "dir"])
def test_transport_semantics(tmp_path, kind):
    t = QueueTransport() if kind == "queue" else DirectoryTransport(tmp_path / "s")
    assert t.first_pos() == t.end() == 0 and t.read(0) is None
    for i in range(5):
        assert t.publish(f"frame{i}".encode()) == i
    assert t.end() == 5 and t.read(2) == b"frame2" and t.read(5) is None
    assert t.truncate_before(3) == 3
    assert t.first_pos() == 3 and len(t) == 2
    with pytest.raises(FrameTruncated):
        t.read(1)
    # positions never reused after truncation
    assert t.publish(b"six") == 5
    # truncating everything keeps the numbering
    t.truncate_before(6)
    assert t.first_pos() == t.end() == 6
    assert t.publish(b"seven") == 6


def test_directory_transport_ignores_partial_frames(tmp_path):
    t = DirectoryTransport(tmp_path / "s")
    t.publish(b"ok")
    # a torn write (no atomic rename yet) must be invisible to readers
    (tmp_path / "s" / ".tmp_frame_0000000001.bin").write_bytes(b"torn")
    assert t.end() == 1 and t.read(1) is None


# ---------------------------------------------------------------------------
# wire framing + shed-policy state round trip
# ---------------------------------------------------------------------------


def test_frame_roundtrip(rng):
    log = ChangeLog(2, start_lsn=7)
    log.append_inserts(rng.integers(0, 2**32, size=(5, 2), dtype=np.uint32),
                       np.arange(5, dtype=np.uint32))
    log.append_deletes([1, 2])
    f = decode_frame(encode_frame(BatchFrame(log=log, bucket=plancache.bucket(7))))
    assert isinstance(f, BatchFrame)
    assert f.lsn0 == 7 and f.lsn1 == 14 and f.bucket == plancache.bucket(7)
    a, b = log.arrays(), f.log.arrays()
    for k in a:
        np.testing.assert_array_equal(a[k], b[k])

    ck = CheckpointFrame(
        ckpt_dir="/some/dir", step=3, base_lsn=14,
        log_state=ChangeLog(2, start_lsn=14, shed_delete_frac=0.25,
                            deletes_since_shed=9),
    )
    g = decode_frame(encode_frame(ck))
    assert isinstance(g, CheckpointFrame)
    assert (g.ckpt_dir, g.step, g.base_lsn) == ("/some/dir", 3, 14)
    assert g.log_state.shed_delete_frac == 0.25
    assert g.log_state.deletes_since_shed == 9


def test_changelog_npz_preserves_shed_state(tmp_path):
    """Regression: the npz round trip used to drop the shed-policy state."""
    log = ChangeLog(2, shed_delete_frac=0.5, deletes_since_shed=3)
    log.append_inserts(np.asarray([[1, 2], [3, 4]], np.uint32), [0, 1])
    log.append_deletes([0, 1, 7])
    back = ChangeLog.load(log.save(tmp_path / "log.npz"))
    assert back.shed_delete_frac == 0.5
    assert back.deletes_since_shed == 3
    # None stays None (NaN encoding), counter survives a wire hop too
    log2 = ChangeLog.from_wire(ChangeLog(2, deletes_since_shed=4).to_wire())
    assert log2.shed_delete_frac is None and log2.deletes_since_shed == 4


def test_changelog_slice_and_concat(rng):
    log = ChangeLog(2, start_lsn=10)
    log.append_inserts(rng.integers(0, 2**32, size=(6, 2), dtype=np.uint32),
                       np.arange(6, dtype=np.uint32))
    log.append_deletes([0, 1])
    s = log.slice_lsn(12, 17)
    assert s.start_lsn == 12 and s.next_lsn == 17 and len(s) == 5
    assert (s.arrays()["lsns"] == np.arange(12, 17)).all()
    # stitching contiguous slices reproduces the original columns
    whole = ChangeLog.concat([log.slice_lsn(10, 13), log.slice_lsn(13, 18)])
    a, b = log.arrays(), whole.arrays()
    for k in a:
        np.testing.assert_array_equal(a[k], b[k])
    with pytest.raises(ValueError):
        ChangeLog.concat([log.slice_lsn(10, 12), log.slice_lsn(13, 18)])


# ---------------------------------------------------------------------------
# wire integrity: CRC header, typed decode failures, legacy fallback
# ---------------------------------------------------------------------------


def _sample_frame(rng) -> bytes:
    log = ChangeLog(2, start_lsn=3)
    log.append_inserts(rng.integers(0, 2**32, size=(4, 2), dtype=np.uint32),
                       np.arange(4, dtype=np.uint32))
    return encode_frame(BatchFrame(log=log, bucket=plancache.bucket(4)), seq=7)


def test_wire_crc32c_known_vector():
    """The checksum is real CRC32C (Castagnoli): the standard check value."""
    from repro.replication.wire import crc32c

    assert crc32c(b"123456789") == 0xE3069283
    assert crc32c(b"") == 0


def test_wire_header_carries_kind_and_seq(rng):
    from repro.replication import peek_header
    from repro.replication.wire import HEADER_SIZE

    raw = _sample_frame(rng)
    hdr = peek_header(raw)
    assert hdr.version == 1 and hdr.kind == 1 and hdr.seq == 7
    assert hdr.payload_len == len(raw) - HEADER_SIZE
    # legacy (headerless) payloads peek as None instead of exploding
    assert peek_header(raw[HEADER_SIZE:]) is None


def test_decode_rejects_bit_flips_as_frame_corrupt(rng):
    from repro.replication import FrameCorrupt

    raw = _sample_frame(rng)
    for flip_at in (5, len(raw) // 2, len(raw) - 1):
        damaged = bytearray(raw)
        damaged[flip_at] ^= 0x10
        with pytest.raises(FrameCorrupt):
            decode_frame(bytes(damaged))


def test_decode_rejects_truncation_and_padding(rng):
    from repro.replication import FrameCorrupt
    from repro.replication.wire import HEADER_SIZE

    raw = _sample_frame(rng)
    with pytest.raises(FrameCorrupt):
        decode_frame(raw[: HEADER_SIZE - 4])  # shorter than the header
    with pytest.raises(FrameCorrupt):
        decode_frame(raw[:-3])  # payload shorter than the header claims
    with pytest.raises(FrameCorrupt):
        decode_frame(raw + b"\x00\x00")  # padded past the claimed length


def test_decode_rejects_malformed_payloads_as_schema_errors(rng):
    import io

    from repro.replication import FrameSchemaError
    from repro.replication.wire import pack_frame, unpack_frame

    # unknown wire version (checked before the CRC would also fail it)
    bad = bytearray(_sample_frame(rng))
    bad[4] = 99  # the version byte sits right after the 4-byte magic
    with pytest.raises(FrameSchemaError):
        unpack_frame(bytes(bad))
    # unknown frame-kind tag (intact CRC, nonsense kind)
    with pytest.raises(FrameSchemaError):
        decode_frame(pack_frame(99, b"not-checked-yet"))
    # intact frame whose payload is not an npz archive
    with pytest.raises(FrameSchemaError):
        decode_frame(pack_frame(1, b"definitely not a zip"))
    # a valid npz missing the frame_kind discriminator
    buf = io.BytesIO()
    np.savez(buf, unrelated=np.arange(3))
    with pytest.raises(FrameSchemaError):
        decode_frame(pack_frame(1, buf.getvalue()))
    # header kind disagreeing with the payload's own kind string
    _, payload = unpack_frame(_sample_frame(rng))
    with pytest.raises(FrameSchemaError):
        decode_frame(pack_frame(2, payload))  # batch payload, shed tag
    # a batch frame with its log columns stripped
    buf = io.BytesIO()
    np.savez(buf, frame_kind=np.asarray("batch"))
    with pytest.raises(FrameSchemaError):
        decode_frame(pack_frame(1, buf.getvalue()))
    # legacy payload that is not an npz at all
    with pytest.raises(FrameSchemaError):
        decode_frame(b"ZZZZ this is no frame of any version")


def test_decode_legacy_v0_frames_still_works(rng):
    """Pre-header spools (PR-4 raw-npz frames) decode via the fallback."""
    from repro.replication.wire import HEADER_SIZE

    raw = _sample_frame(rng)
    legacy = raw[HEADER_SIZE:]  # exactly what v0 published: the bare npz
    f = decode_frame(legacy)
    assert isinstance(f, BatchFrame) and f.lsn0 == 3 and len(f.log) == 4


def test_primary_stamps_monotonic_wire_seq(rng):
    from repro.replication import peek_header

    t = QueueTransport()
    prim = StreamPrimary(t, _keyset(rng, 200))
    for _ in range(3):
        prim.publish(_random_batch(rng, prim, n_ins=5, n_del=0))
    seqs = [peek_header(t.read(i)).seq for i in range(t.end())]
    assert seqs == list(range(t.end()))  # dense, monotonic, no reuse
    assert prim.stats["wire_seq"] == t.end()


# ---------------------------------------------------------------------------
# the acceptance contract: stream-only replica == full run (jnp + pallas),
# including one forced checkpoint catch-up
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", ["jnp", "pallas"])
def test_stream_replica_byte_identical_with_catchup(tmp_path, backend):
    rng = np.random.default_rng(3)
    base = _keyset(rng, 1500)
    t = QueueTransport()
    prim = StreamPrimary(t, base, ckpt_dir=str(tmp_path / "ckpt"),
                         max_lag_batches=2)
    tail = StreamReplica(t, backend=backend)   # polls every batch
    lagger = StreamReplica(t, backend=backend)  # sleeps, then catches up
    tail.poll()
    for _ in range(9):
        prim.publish(_random_batch(rng, prim))
        tail.poll()
    # backpressure checkpointed + truncated repeatedly while lagger slept;
    # the active tail never needed the catch-up path (one-cycle retention)
    assert prim.stats["ckpt_step"] >= 2
    assert tail.stats["n_catchups"] == 0
    st = lagger.poll()
    assert st["catchup"] and lagger.stats["n_catchups"] == 1
    assert lagger.stats["n_truncation_jumps"] >= 1
    # the lagger then tailed the batches published after its bootstrap base
    assert lagger.stats["n_batches_applied"] > 0
    # the checkpoint chain has delta steps after the first forced one,
    # and the lagger's bootstrap restored through such a chain
    from repro.ckpt.checkpoint import step_manifest
    man = step_manifest(tmp_path / "ckpt", prim.stats["ckpt_step"])
    assert man["delta"] and man["base_step"] == prim.stats["ckpt_step"] - 1
    # never-lagged == caught-up == primary, and all == a full pipeline run
    _assert_replica_state_identical(tail.replica, prim.replica)
    _assert_replica_state_identical(lagger.replica, prim.replica)
    _assert_matches_full_run(tail.replica, backend)
    _assert_matches_full_run(lagger.replica, backend)


def test_bounded_lag_requires_checkpoint_config(rng):
    """max_lag_batches without a tracked index + ckpt_dir is rejected at
    construction — not mid-publish, where it would tear the stream."""
    from repro.replication import BackpressureError

    with pytest.raises(BackpressureError):
        StreamPrimary(QueueTransport(), n_words=2, max_lag_batches=3)
    with pytest.raises(BackpressureError):
        StreamPrimary(QueueTransport(), _keyset(rng, 50), max_lag_batches=3)


def test_stream_duplicate_and_out_of_order(rng):
    base = _keyset(rng, 600)
    t = QueueTransport()
    prim = StreamPrimary(t, base)
    rep = StreamReplica(t)
    rep.poll()
    log = _random_batch(rng, prim, n_ins=20, n_del=4)
    prim.publish(log)
    rep.poll()
    before = np.asarray(rep.replica.result.rid_sorted).copy()

    # duplicate delivery of the same frame is idempotent
    t.publish(t.read(1))
    st = rep.poll()
    assert st["duplicates"] == 1 and st["applied_batches"] == 0
    np.testing.assert_array_equal(before, np.asarray(rep.replica.result.rid_sorted))

    # a batch skipping past the watermark is rejected by the LSN check —
    # but a good batch drained in the same poll is applied first, and the
    # cursor parks on the offending frame (no frames are lost)
    good = _random_batch(rng, prim, n_ins=10, n_del=0)
    prim.publish(good)
    bad = ChangeLog(3, start_lsn=prim.next_lsn + 100)
    bad.append_inserts(np.asarray(base.words)[:1], [1])
    bad_pos = t.publish(encode_frame(BatchFrame(log=bad, bucket=plancache.bucket(1))))
    with pytest.raises(LsnGapError):
        rep.poll()
    assert rep.applied_lsn == good.next_lsn - 1  # good prefix was applied
    assert rep.pos == bad_pos                    # parked on the bad frame
    _assert_replica_state_identical(rep.replica, prim.replica)


def test_stream_overlapping_batch_sliced(rng):
    """Partial overlap (retransmission window) applies only the unseen
    suffix — byte-identical to exact-once delivery."""
    base = _keyset(rng, 500)
    t = QueueTransport()
    prim = StreamPrimary(t, base)
    rep = StreamReplica(t)
    rep.poll()
    l1 = _random_batch(rng, prim, n_ins=12, n_del=0)
    prim.publish(l1)
    rep.poll()
    l2 = ChangeLog(3, start_lsn=prim.next_lsn)
    l2.append_inserts(np.asarray(base.words)[:5],
                      np.arange(7000, 7005, dtype=np.uint32))
    # ships as one frame overlapping 4 already-applied entries
    both = ChangeLog.concat([l1, l2]).slice_lsn(l1.next_lsn - 4, l2.next_lsn)
    t.publish(encode_frame(BatchFrame(log=both, bucket=plancache.bucket(len(both)))))
    prim.replica.apply(l2)
    st = rep.poll()
    assert st["applied_batches"] == 1
    _assert_replica_state_identical(rep.replica, prim.replica)


def test_stream_coalesces_to_bucket(rng):
    """With coalescing on, small publishes buffer and ship as one batch
    whose size tags one plan-cache bucket."""
    base = _keyset(rng, 700)
    t = QueueTransport()
    prim = StreamPrimary(t, base, coalesce_min=64)
    rep = StreamReplica(t)
    rep.poll()
    genesis_frames = t.end()
    for _ in range(3):  # 3 x 16 entries: stays buffered
        prim.publish(_random_batch(rng, prim, n_ins=16, n_del=0))
    assert t.end() == genesis_frames and prim.stats["pending_entries"] == 48
    prim.publish(_random_batch(rng, prim, n_ins=16, n_del=0))  # hits 64
    assert t.end() == genesis_frames + 1
    frame = decode_frame(t.read(genesis_frames))
    assert len(frame.log) == 64 and frame.bucket == plancache.bucket(64)
    st = rep.poll()
    assert st["applied_batches"] == 1  # one rebuild for the coalesced span
    _assert_replica_state_identical(rep.replica, prim.replica)
    # explicit flush ships a short tail
    prim.publish(_random_batch(rng, prim, n_ins=5, n_del=0))
    assert prim.flush() == 5 and prim.flush() == 0
    rep.poll()
    _assert_replica_state_identical(rep.replica, prim.replica)


def test_watermark_noop_fast_path(rng):
    """An empty/cancelling change set advances the watermark without a
    rebuild and stays byte-identical (the pipeline no-op short circuit)."""
    from repro.replication import Replica

    base = _keyset(rng, 400)
    rep = Replica(base)
    standing = rep.result
    log = ChangeLog(3, start_lsn=0)
    log.append_inserts(np.asarray(base.words)[:1], [4242])
    log.append_deletes([4242])  # cancels the insert: net-empty batch
    st = rep.apply(log)
    assert st["noop"] and st["incremental"]
    assert rep.result.tree is standing.tree  # no rebuild happened
    assert rep.result.watermark == log.next_lsn - 1
    assert st["timings"]["build"] == 0.0
    _assert_matches_full_run(rep, "jnp")


# ---------------------------------------------------------------------------
# hypothesis: catch-up from a checkpoint chain == never-lagged replica
# ---------------------------------------------------------------------------


def test_catchup_equals_never_lagged_hypothesis(tmp_path):
    pytest.importorskip("hypothesis")  # property tests need the dev extra
    from hypothesis import given, settings
    from hypothesis import strategies as st

    @settings(max_examples=8, deadline=None)
    @given(seed=st.integers(0, 10**6), n_batches=st.integers(3, 6),
           lag_from=st.integers(1, 2))
    def check(seed, n_batches, lag_from):
        rng = np.random.default_rng(seed)
        ckpt = tmp_path / f"ckpt_{seed}_{n_batches}_{lag_from}"
        t = QueueTransport()
        prim = StreamPrimary(t, _keyset(rng, 300), ckpt_dir=str(ckpt),
                             max_lag_batches=lag_from)
        tail = StreamReplica(t)
        lagger = StreamReplica(t)
        tail.poll()
        for _ in range(n_batches):
            prim.publish(_random_batch(
                rng, prim,
                n_ins=int(rng.integers(0, 30)),
                n_del=int(rng.integers(0, 10)),
            ))
            tail.poll()
        lagger.poll()
        if prim.stats["ckpt_step"] >= 2:
            # retention keeps one checkpoint cycle: a second checkpoint
            # truncated the lagger's tail, forcing the catch-up path
            assert lagger.stats["n_catchups"] >= 1
        _assert_replica_state_identical(tail.replica, prim.replica)
        _assert_replica_state_identical(lagger.replica, prim.replica)

    check()


# ---------------------------------------------------------------------------
# shed adoption as a logged event (control frames)
# ---------------------------------------------------------------------------


def _stale_bit_base() -> KeySet:
    """Rows 0/1 differ only at bit 63; deleting both makes that bit stale."""
    words = np.zeros((6, 2), np.uint32)
    words[0] = (0, 0)
    words[1] = (0, 1)
    for i in range(2, 6):
        words[i] = (i << 8, 0)
    return KeySet(
        words=words, lengths=np.full(6, 8, np.int32),
        rids=np.arange(6, dtype=np.uint32),
    )


def test_shed_frame_keeps_replicas_identical_at_every_watermark():
    """The primary's shed lands in the stream as a control frame; a
    per-batch tailing replica and one draining the whole span in a single
    poll both adopt it at the shed watermark — byte-identical metadata and
    state at head, whatever the poll cadence (closes the ROADMAP item)."""
    from repro.replication import ShedFrame

    t = QueueTransport()
    prim = StreamPrimary(t, _stale_bit_base(), shed_delete_frac=0.1)
    tail = StreamReplica(t)   # polls after every publish
    span = StreamReplica(t)   # drains everything after genesis in one poll
    tail.poll()
    span.poll()

    shed_batch = ChangeLog(2, start_lsn=prim.next_lsn)
    shed_batch.append_deletes([0, 1])  # crosses the 10% threshold
    prim.publish(shed_batch)
    assert prim.stats["n_shed_frames"] == 1
    frame = decode_frame(t.read(t.end() - 1))
    assert isinstance(frame, ShedFrame)
    assert frame.lsn == shed_batch.next_lsn - 1
    # the frame round-trips through the wire encoding
    assert decode_frame(encode_frame(frame)) == frame

    st = tail.poll()
    assert st["shed_adopted"] == 1
    # the per-batch tail adopted at the watermark: stale bit 63 is gone
    # and the metadata equals the primary's exactly
    assert not (tail.replica.meta.dbitmap[1] & np.uint32(1))
    np.testing.assert_array_equal(tail.replica.meta.dbitmap,
                                  prim.replica.meta.dbitmap)

    post = ChangeLog(2, start_lsn=prim.next_lsn)
    post.append_inserts(np.asarray([[7 << 8, 0]], np.uint32), [100])
    prim.publish(post)
    tail.poll()
    st_span = span.poll()  # shed batch + shed frame + post batch, one poll
    assert st_span["shed_adopted"] == 1
    # the shed frame split the span: BOTH spans' apply stats are kept
    # ("applies"), and the post-shed one paid the full resort under the
    # narrow bitmap, exactly like the primary's
    assert len(st_span["applies"]) == 2
    assert st_span["applies"][0]["n_deleted"] == 2
    assert st_span["apply"] is st_span["applies"][-1]
    assert st_span["apply"]["fallback"] == "dbitmap_changed"
    for rep in (tail, span):
        _assert_replica_state_identical(rep.replica, prim.replica)
        _assert_matches_full_run(rep.replica, "jnp")


def test_shed_frame_stale_and_bootstrap_cases(tmp_path):
    """A bootstrapped replica's checkpoint already reflects the shed (the
    primary realigns before snapshotting); a stale duplicate shed frame at
    a watermark the replica is past is skipped, not re-adopted."""
    from repro.replication import ShedFrame

    t = QueueTransport()
    prim = StreamPrimary(t, _stale_bit_base(), shed_delete_frac=0.1,
                         ckpt_dir=str(tmp_path / "ckpt"))
    log = ChangeLog(2, start_lsn=prim.next_lsn)
    log.append_deletes([0, 1])
    prim.publish(log)  # sheds, publishes the control frame
    prim.checkpoint()  # realigned snapshot at the shed watermark
    late = StreamReplica(t, start_pos=t.end() - 1)  # only the ckpt frame
    st = late.poll()
    assert st["catchup"] and st["shed_adopted"] == 0
    _assert_replica_state_identical(late.replica, prim.replica)
    # a stale duplicate of the shed control frame (delivery fault) at a
    # watermark the replica has passed is skipped
    t.publish(encode_frame(ShedFrame(lsn=0)))
    st = late.poll()
    assert st["shed_adopted"] == 0 and late.stats["n_shed_adoptions"] == 0
    _assert_replica_state_identical(late.replica, prim.replica)


# ---------------------------------------------------------------------------
# serve layer: pager journal shipping + engine follow mode
# ---------------------------------------------------------------------------


def test_pager_ships_journal_and_standby_follows():
    from repro.serve.pager import PagedKVManager

    t = QueueTransport()
    pub = StreamPrimary(t, n_words=2)  # fire-and-forget publisher
    pm = PagedKVManager(n_pages=256, page_tokens=16)
    pm.attach_stream(pub)
    for s in range(6):
        pm.pages_for(s, 80)
    pm.rebuild_index()
    pm.free_seq(1)
    pm.pages_for(3, 160)
    pm.rebuild_index()

    standby = StreamReplica(t)
    standby.poll()
    for (s, p), phys in pm._table.items():
        found, rid = standby.search(np.asarray([s, p], np.uint32))
        assert found and rid == phys
    found, _ = standby.search(np.asarray([1, 0], np.uint32))
    assert not found  # freed sequence is gone on the standby too
    # a quiet rebuild (empty journal) ships nothing
    before = t.end()
    pm.rebuild_index()
    assert t.end() == before


def test_engine_follow_restart_replays_stream():
    import jax

    from repro.configs import ARCHS
    from repro.models.lm import LM
    from repro.serve.engine import ServeEngine

    cfg = ARCHS["llama3-8b"].reduced()
    m = LM(cfg, remat=False)
    params = m.init(jax.random.PRNGKey(0))
    t = QueueTransport()
    primary = ServeEngine(m, params, max_seq=64, batch_size=2, page_tokens=16)
    primary.pager.attach_stream(StreamPrimary(t, n_words=2))
    standby = ServeEngine(m, params, max_seq=64, batch_size=2, page_tokens=16)
    standby.follow(StreamReplica(t))

    prompts = np.random.default_rng(0).integers(0, cfg.vocab_size, (2, 16))
    primary.generate(prompts, n_new=4)
    primary.restart()  # drains + ships the journal
    st = standby.restart()  # standby restart replays the stream
    assert st["followed_stream"] and st["lag_frames"] == 0
    assert st["applied_lsn"] == primary.pager._log.start_lsn - 1
    for (s, p), phys in primary.pager._table.items():
        found, rid = standby._follow.search(np.asarray([s, p], np.uint32))
        assert found and rid == phys
