"""Hypothesis property tests for the incremental merge contract.

Property: for ANY base keyset, delta keyset and deletion mask (including
duplicate-heavy keys, empty deltas, delete-everything-but-one), the
``run_incremental`` output — sorted compressed keys, rid permutation and
tree levels — is byte-identical to a full ``run`` over the folded keyset,
on every registered backend.
"""

import numpy as np
import pytest

pytest.importorskip("hypothesis")  # property tests need the dev extra
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.keyformat import KeySet
from repro.core.metadata import meta_from_keys
from repro.core.pipeline import ReconstructionPipeline

BACKENDS = ("jnp", "pallas", "distributed")


@st.composite
def incremental_case(draw):
    """(base keyset, delta keyset or None, keep mask or None, union meta)."""
    w = draw(st.integers(1, 3))
    n = draw(st.integers(2, 120))
    nd = draw(st.integers(0, 40))
    # small masks force heavy duplication; wide masks exercise dense bitmaps
    masks = [draw(st.sampled_from([0x3, 0xFF, 0x0F0F, 0xFFFF_FFFF])) for _ in range(w)]
    rng = np.random.default_rng(draw(st.integers(0, 10**6)))
    words = rng.integers(0, 2**32, size=(n + nd, w), dtype=np.uint32) & np.asarray(
        masks, np.uint32
    )
    meta = meta_from_keys(words)  # union metadata: the incremental path runs
    rids = np.arange(n + nd, dtype=np.uint32)
    rng.shuffle(rids)
    base = KeySet(words=words[:n], lengths=np.full(n, w * 4, np.int32),
                  rids=rids[:n])
    delta = (
        KeySet(words=words[n:], lengths=np.full(nd, w * 4, np.int32),
               rids=rids[n:])
        if nd
        else None
    )
    if draw(st.booleans()):
        keep = rng.random(n) > draw(st.sampled_from([0.1, 0.5, 0.9]))
        if not keep.any() and nd == 0:
            keep[0] = True  # the folded keyset must not be empty
    else:
        keep = None
    return base, delta, keep, meta


@given(incremental_case())
@settings(max_examples=25, deadline=None)
def test_run_incremental_matches_full_run_property(case):
    base, delta, keep, meta = case
    ref = None
    for name in BACKENDS:
        pipe = ReconstructionPipeline(backend=name)
        prev = pipe.run(base, meta=meta)
        inc, folded = pipe.run_incremental(
            prev, base, delta, keep_rows=keep, meta=meta
        )
        assert inc.stats["incremental"] is True
        full = pipe.run(folded, meta=meta)
        for field in ("comp_sorted", "rid_sorted", "row_sorted"):
            np.testing.assert_array_equal(
                np.asarray(getattr(inc, field)),
                np.asarray(getattr(full, field)),
                err_msg=f"{name}:{field} (incremental vs full)",
            )
        for la, lb in zip(inc.tree.levels, full.tree.levels):
            for k in la:
                np.testing.assert_array_equal(
                    np.asarray(la[k]), np.asarray(lb[k]), err_msg=f"{name}:level:{k}"
                )
        for k in inc.tree.leaf:
            np.testing.assert_array_equal(
                np.asarray(inc.tree.leaf[k]), np.asarray(full.tree.leaf[k]),
                err_msg=f"{name}:leaf:{k}",
            )
        # cross-backend byte-identity rides on the same property
        if ref is None:
            ref = inc
        else:
            np.testing.assert_array_equal(
                np.asarray(inc.rid_sorted), np.asarray(ref.rid_sorted),
                err_msg=f"{name} vs jnp rid parity",
            )
            np.testing.assert_array_equal(
                np.asarray(inc.comp_sorted), np.asarray(ref.comp_sorted),
                err_msg=f"{name} vs jnp key parity",
            )
