"""Serve-layer concurrency: follow/restart racing pinned readers.

Covers the stream-standby path of ``serve/engine.py`` under threads: a
reader holding a pinned snapshot while the standby's ``restart()``
replays the primary's stream must keep answering from its *old* epoch
(byte-exact against that epoch's page table), and the next acquire must
see the new one.  Also exercises the pager's concurrent read path
(``read_through_dirty``) with readers racing a mutating writer.
"""

import threading

import numpy as np
import pytest

from repro.replication import QueueTransport, StreamPrimary, StreamReplica


def _engines():
    import jax

    from repro.configs import ARCHS
    from repro.models.lm import LM
    from repro.serve.engine import ServeEngine

    cfg = ARCHS["llama3-8b"].reduced()
    m = LM(cfg, remat=False)
    params = m.init(jax.random.PRNGKey(0))
    t = QueueTransport()
    primary = ServeEngine(m, params, max_seq=64, batch_size=2, page_tokens=16)
    primary.pager.attach_stream(StreamPrimary(t, n_words=2))
    standby = ServeEngine(m, params, max_seq=64, batch_size=2, page_tokens=16)
    standby.follow(StreamReplica(t))
    return cfg, primary, standby


def test_follow_restart_with_pinned_reader():
    cfg, primary, standby = _engines()
    prompts = np.random.default_rng(0).integers(0, cfg.vocab_size, (2, 16))
    primary.generate(prompts, n_new=4)
    primary.restart()
    standby.restart()  # standby now serves epoch e over the shipped table

    rep = standby._follow.replica
    cell = rep.snapshots
    old_epoch = cell.epoch
    old_table = dict(primary.pager._table)
    backend = rep.pipeline.backend

    # the reader pins *before* the next restart and keeps probing its
    # pinned epoch while the restart replays the stream underneath it
    pinned = cell.acquire()
    probe = np.asarray(sorted(old_table), np.uint32)
    want = np.asarray([old_table[tuple(k)] for k in map(tuple, probe)], np.uint32)
    ready = threading.Event()
    done = threading.Event()
    results: dict = {"bad": 0, "iters": 0, "errors": []}

    def reader():
        try:
            ready.set()
            while not done.is_set():
                f, r = pinned.lookup(backend, probe)
                if not (
                    bool(np.asarray(f).all())
                    and np.array_equal(np.asarray(r, np.uint32), want)
                ):
                    results["bad"] += 1
                results["iters"] += 1
        except Exception as e:  # pragma: no cover
            results["errors"].append(repr(e))

    t = threading.Thread(target=reader)
    t.start()
    ready.wait()

    # writer side: the primary frees a sequence (its pages vanish) and a
    # standby restart replays the shipped journal while the reader runs
    primary.pager.free_seq(0)
    primary.restart()
    st = standby.restart()
    assert st["followed_stream"] and cell.epoch == old_epoch + 1
    done.set()
    t.join(timeout=30.0)

    assert results["errors"] == []
    assert results["iters"] > 0 and results["bad"] == 0  # old epoch, byte-exact
    # the pinned epoch-e snapshot still answers the freed key
    gone = np.asarray([k for k in sorted(old_table) if k[0] == 0], np.uint32)
    f, _ = pinned.lookup(backend, gone)
    assert bool(np.asarray(f).all())
    # the *cell* has moved on: a fresh acquire sees the new epoch, where
    # the freed sequence is gone
    with cell.pin() as now:
        assert now.epoch == old_epoch + 1
        f, _ = now.lookup(backend, gone)
        assert not bool(np.asarray(f).any())
    pinned.release()
    assert cell.stats()["retired"] == 0 and cell.stats()["pinned"] == 0
    # lookup_page routes through the standby's replica post-restart
    s1, p1 = next(k for k in primary.pager._table)
    assert standby.lookup_page(s1, p1) == primary.pager._table[(s1, p1)]
    assert standby.lookup_page(0, 0) is None


def test_pager_concurrent_reads_during_writer_churn():
    """read_through_dirty: reader threads keep answering from the current
    epoch while a writer mutates and rebuilds; every answer matches the
    epoch it pinned (verified via the versioned lookup)."""
    from repro.serve.pager import PagedKVManager

    pm = PagedKVManager(
        n_pages=512, page_tokens=16, read_through_dirty=True
    )
    n_seqs, pages = 12, 4
    for s in range(n_seqs):
        pm.pages_for(s, pages * 16)
    pm.rebuild_index()
    probe = np.asarray(
        [(s, p) for s in range(n_seqs) for p in range(pages)], np.uint32
    )
    oracles = {}

    def snap_oracle(epoch):
        found = np.zeros(len(probe), bool)
        rid = np.full(len(probe), 0xFFFFFFFF, np.uint32)
        for i, (s, p) in enumerate(probe):
            phys = pm._table.get((int(s), int(p)))
            if phys is not None:
                found[i], rid[i] = True, phys
        oracles[epoch] = (found, rid)

    snap_oracle(pm._snapshots.epoch)
    pm.lookup_batch(probe)  # warm

    stop = threading.Event()
    bad = [0, 0]
    errors: list = []

    def reader(idx):
        try:
            while not stop.is_set():
                f, r, e = pm.lookup_batch_versioned(probe)
                exp_f, exp_r = oracles[e]
                if not (np.array_equal(f, exp_f) and np.array_equal(r, exp_r)):
                    bad[idx] += 1
        except Exception as exc:  # pragma: no cover
            errors.append(repr(exc))

    ts = [threading.Thread(target=reader, args=(i,)) for i in range(2)]
    for t in ts:
        t.start()
    try:
        for k in range(4):
            victim = k % n_seqs
            pm.free_seq(victim)
            pm.pages_for(victim, pages * 16)
            snap_oracle(pm._snapshots.epoch + 1)
            pm.rebuild_index()
    finally:
        stop.set()
        for t in ts:
            t.join(timeout=30.0)
    assert errors == []
    assert bad == [0, 0]
    assert pm._snapshots.stats()["pinned"] == 0
    assert pm.stats["snapshot"]["n_published"] == 5


def test_engine_admission_knobs_reach_the_pager():
    from repro.serve.pager import PagedKVManager

    # read_through_dirty: in the serving configuration a dirty journal is
    # the writer's problem — reads keep hitting the current epoch, so the
    # lag bound is what protects them from unbounded staleness
    pm = PagedKVManager(
        n_pages=64, page_tokens=16, read_through_dirty=True,
        max_lag_epochs=0, admission="shed", lag_entries_per_epoch=4,
    )
    pm.pages_for(0, 64)
    pm.rebuild_index()
    assert pm.stats["snapshot"]["max_lag_epochs"] == 0
    # pile up journal entries past one epoch's worth: reads shed
    from repro.core.snapshot import AdmissionShed

    for s in range(1, 9):
        pm.pages_for(s, 16)
    assert pm.stats["snapshot"]["lag_epochs"] >= 1
    with pytest.raises(AdmissionShed):
        pm.lookup(0, 0)
    # the rebuild drains the journal and reads are admitted again
    pm.rebuild_index()
    assert pm.lookup(0, 0) is not None
    assert pm.stats["snapshot"]["shed"] == 1
