"""Concurrent snapshot protocol: adversarial thread schedules, exact stats.

PR 5 proved the publish/acquire protocol's invariants single-threaded
(``test_snapshot.py``); this module proves them under real thread
interleavings: publish racing acquire/release, a reader pinned across
several rebuilds, the last release retiring an epoch exactly once, and
the admission-control knob (shed / park / park-timeout).  Every lookup
issued from a reader thread is byte-checked against its *pinned* epoch's
oracle — epoch ``k`` re-mints every rid with a ``k``-coded offset, so a
single lane answered from the wrong epoch flips the comparison.

The schedule sweep runs both as a seeded parametrization (always) and as
a hypothesis property over schedule seeds (when the dev extra is
installed), with the interpreter switch interval cranked down so the
scheduler preempts inside the protocol's critical windows.

The ``soak``-marked tests at the bottom run the full closed-loop load
generator (``repro.serve.loadgen``) — minutes, not seconds — and are
excluded from tier-1 by ``pytest.ini``; CI runs them in a dedicated job
(``-m soak``).
"""

import sys
import threading
import time

import numpy as np
import pytest

from repro.core.keyformat import KeySet
from repro.core.pipeline import ReconstructionPipeline
from repro.core.snapshot import AdmissionShed, SnapshotCell

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # the dev extra is optional; the seeded sweep still runs
    HAVE_HYPOTHESIS = False


def _keyset(rng, n, w=2, rid_base=0):
    words = rng.integers(0, 2**32, size=(n, w), dtype=np.uint32)
    words &= np.uint32(0x00FF0F0F)
    words = np.unique(words, axis=0)  # one rid per distinct key
    m = words.shape[0]
    return KeySet(
        words=words, lengths=np.full(m, w * 4, np.int32),
        rids=np.arange(rid_base, rid_base + m, dtype=np.uint32),
    )


# one small index per distinct epoch, same key population: epoch k's rids
# are the row index + k*1000, so a lookup's rid vector identifies the
# epoch it was answered from (the torn-read oracle).  Built once, reused
# by every schedule test (including the hypothesis sweep, which cannot
# take fixtures).
_EPOCH_POOL: dict = {}


def _epoch_pool(n_epochs: int = 4):
    if _EPOCH_POOL.get("n", 0) >= n_epochs:
        return _EPOCH_POOL
    from repro.backends import get_backend

    rng = np.random.default_rng(7)
    base = _keyset(rng, 300)
    pipe = ReconstructionPipeline(backend="jnp")
    results = []
    for k in range(n_epochs):
        ks = KeySet(
            words=base.words, lengths=base.lengths,
            rids=np.asarray(base.rids) + np.uint32(k * 1000),
        )
        results.append(pipe.run(ks))
    import jax.numpy as jnp

    probe_idx = np.arange(0, base.n, max(1, base.n // 32))[:32]
    _EPOCH_POOL.update(
        n=n_epochs,
        results=results,
        backend=get_backend("jnp"),
        probe=jnp.asarray(np.asarray(base.words)[probe_idx]),
        probe_rids=probe_idx.astype(np.uint32),
    )
    # warm the lookup program so threaded phases replay it
    f, r = _EPOCH_POOL["backend"].lookup(results[0].tree, _EPOCH_POOL["probe"])
    assert bool(np.asarray(f).all())
    return _EPOCH_POOL


def _check_epoch(pool, pin) -> bool:
    """Byte-check a pinned lookup against the pinned epoch's oracle."""
    f, r = pool["backend"].lookup(pin.tree, pool["probe"])
    want = pool["probe_rids"] + np.uint32(pin.epoch * 1000)
    return bool(np.asarray(f).all()) and np.array_equal(
        np.asarray(r, np.uint32), want
    )


# ---------------------------------------------------------------------------
# satellite: double release is detected, not silent corruption
# ---------------------------------------------------------------------------


def test_double_release_raises_even_with_concurrent_pin():
    """The regression: releasing a lease twice used to silently decrement
    some other reader's refcount; now the second release raises and the
    *other* reader's pin (same epoch!) stays intact."""
    pool = _epoch_pool()
    cell = SnapshotCell()
    cell.publish(pool["results"][0])
    pin_a = cell.acquire()
    pin_b = cell.acquire()  # a second reader on the SAME epoch
    pin_a.release()
    with pytest.raises(RuntimeError, match="double release"):
        pin_a.release()
    with pytest.raises(RuntimeError, match="double release"):
        cell.release(pin_a)
    # pin_b was not corrupted by the double release: still pinned, and a
    # publish retires the epoch instead of dropping it
    st = cell.stats()
    assert st["pinned"] == 1 and st["acquires"] == 2 and st["releases"] == 1
    cell.publish(pool["results"][1])
    assert cell.stats()["retired"] == 1
    assert _check_epoch(pool, pin_b)  # epoch-0 answers, byte-exact
    pin_b.release()
    st = cell.stats()
    assert st["retired"] == 0 and st["retired_epochs"] == 1


def test_release_rejects_foreign_and_unpinned_snapshots():
    pool = _epoch_pool()
    cell = SnapshotCell()
    other = SnapshotCell()
    cell.publish(pool["results"][0])
    other.publish(pool["results"][1])
    # a lease minted by another cell
    foreign_pin = other.acquire()
    with pytest.raises(RuntimeError, match="different SnapshotCell"):
        cell.release(foreign_pin)
    foreign_pin.release()
    # a raw snapshot this cell never published
    with pytest.raises(RuntimeError, match="double release or foreign"):
        cell.release(other.current)
    # a raw release of the current snapshot with no outstanding pins
    with pytest.raises(RuntimeError, match="release of unpinned epoch"):
        cell.release(cell.current)
    # legacy raw-snapshot release still works when actually pinned —
    # but only down to zero, never below
    p = cell.acquire()
    cell.release(p.snapshot)
    with pytest.raises(RuntimeError):
        cell.release(p.snapshot)


# ---------------------------------------------------------------------------
# barrier-scheduled interleavings
# ---------------------------------------------------------------------------


def _run_schedule(seed: int, n_readers: int = 4, reader_iters: int = 40):
    """One adversarial schedule: readers loop acquire→verify→release
    while the writer publishes the epoch pool; returns (cell, torn)."""
    pool = _epoch_pool()
    cell = SnapshotCell()
    cell.publish(pool["results"][0])
    rng = np.random.default_rng(seed)
    sleeps = rng.uniform(0.0, 2e-3, size=pool["n"] - 1)
    barrier = threading.Barrier(n_readers + 1)
    torn = [0] * n_readers
    stale = [0] * n_readers
    errors: list = []

    def reader(idx: int):
        try:
            barrier.wait()
            for _ in range(reader_iters):
                before = cell.epoch
                with cell.pin() as pin:
                    if not _check_epoch(pool, pin):
                        torn[idx] += 1
                    if pin.epoch < before:
                        stale[idx] += 1
        except Exception as e:  # pragma: no cover
            errors.append(repr(e))

    def writer():
        try:
            barrier.wait()
            for k in range(1, pool["n"]):
                time.sleep(float(sleeps[k - 1]))
                cell.publish(pool["results"][k])
        except Exception as e:  # pragma: no cover
            errors.append(repr(e))

    old = sys.getswitchinterval()
    sys.setswitchinterval(1e-5)  # preempt inside the critical windows
    try:
        ts = [
            threading.Thread(target=reader, args=(i,)) for i in range(n_readers)
        ] + [threading.Thread(target=writer)]
        for t in ts:
            t.start()
        for t in ts:
            t.join(timeout=60.0)
    finally:
        sys.setswitchinterval(old)
    assert not errors, errors
    st = cell.stats()
    # exact closed-form counters after the schedule
    assert st["acquires"] == st["releases"] == n_readers * reader_iters
    assert st["pinned"] == 0 and st["retired"] == 0
    assert st["n_published"] == pool["n"]
    assert st["retired_epochs"] == pool["n"] - 1  # each freed exactly once
    assert 1 <= st["max_concurrent_pins"] <= n_readers
    assert sum(torn) == 0, f"torn reads under schedule seed {seed}: {torn}"
    assert sum(stale) == 0, f"stale epochs under schedule seed {seed}: {stale}"
    return cell


@pytest.mark.parametrize("seed", [0, 1, 2, 3, 17])
def test_publish_racing_acquire_release_seeded(seed):
    _run_schedule(seed)


if HAVE_HYPOTHESIS:

    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(min_value=0, max_value=2**16))
    def test_publish_racing_acquire_release_hypothesis(seed):
        """Hypothesis sweep over thread-schedule seeds (dev extra only)."""
        _run_schedule(seed, n_readers=3, reader_iters=25)


def test_reader_pinned_across_three_rebuilds():
    """A reader that pinned epoch 0 keeps getting byte-identical epoch-0
    answers while three rebuilds publish 1, 2, 3 underneath it."""
    pool = _epoch_pool()
    cell = SnapshotCell()
    cell.publish(pool["results"][0])
    pin = cell.acquire()
    for k in range(1, 4):
        cell.publish(pool["results"][k])
        assert cell.epoch == k
        assert _check_epoch(pool, pin)  # still epoch-0 rids, byte-exact
    assert cell.stats()["retired"] == 1  # only epoch 0 is pin-held
    assert cell.stats()["retired_epochs"] == 2  # 1 and 2 freed on publish
    pin.release()
    st = cell.stats()
    assert st["retired"] == 0 and st["retired_epochs"] == 3
    # a fresh acquire sees the newest epoch
    with cell.pin() as p2:
        assert p2.epoch == 3 and _check_epoch(pool, p2)


def test_last_release_retires_exactly_once():
    """K readers pin the same epoch; a publish retires it; the releases
    race through a barrier and the epoch is freed exactly once."""
    pool = _epoch_pool()
    K = 6
    cell = SnapshotCell()
    cell.publish(pool["results"][0])
    pins = [cell.acquire() for _ in range(K)]
    cell.publish(pool["results"][1])
    assert cell.stats()["retired"] == 1 and cell.stats()["retired_epochs"] == 0
    barrier = threading.Barrier(K)
    errors: list = []

    def releaser(p):
        try:
            barrier.wait()
            p.release()
        except Exception as e:  # pragma: no cover
            errors.append(repr(e))

    old = sys.getswitchinterval()
    sys.setswitchinterval(1e-5)
    try:
        ts = [threading.Thread(target=releaser, args=(p,)) for p in pins]
        for t in ts:
            t.start()
        for t in ts:
            t.join(timeout=30.0)
    finally:
        sys.setswitchinterval(old)
    assert not errors, errors
    st = cell.stats()
    assert st["pinned"] == 0 and st["retired"] == 0
    assert st["retired_epochs"] == 1  # exactly once, despite the race
    assert st["releases"] == K and st["max_concurrent_pins"] == K


# ---------------------------------------------------------------------------
# admission control: shed, park, park-timeout
# ---------------------------------------------------------------------------


def test_admission_shed_on_lag():
    pool = _epoch_pool()
    cell = SnapshotCell(max_lag_epochs=1)
    cell.publish(pool["results"][0])
    cell.report_lag(1)
    with cell.pin() as p:  # at the bound: still admitted
        assert p.epoch == 0
    cell.report_lag(2)
    with pytest.raises(AdmissionShed):
        cell.acquire()
    assert cell.stats()["shed"] == 1
    cell.report_lag(0)  # writer caught up: reads admitted again
    with cell.pin():
        pass
    assert cell.stats()["shed"] == 1 and cell.stats()["lag_epochs"] == 0


def test_admission_park_until_writer_catches_up():
    pool = _epoch_pool()
    cell = SnapshotCell(max_lag_epochs=0, admission="park")
    cell.publish(pool["results"][0])
    cell.report_lag(3)
    got: list = []

    def parked_reader():
        with cell.pin() as p:
            got.append(p.epoch)

    t = threading.Thread(target=parked_reader)
    t.start()
    time.sleep(0.05)
    assert not got  # still parked
    cell.publish(pool["results"][1])  # publish alone does not clear the lag
    cell.report_lag(0)
    t.join(timeout=10.0)
    assert got == [1]  # woke up on the *new* epoch
    st = cell.stats()
    assert st["parked"] == 1 and st["shed"] == 0 and st["park_wait_s"] > 0


def test_admission_park_timeout_sheds():
    pool = _epoch_pool()
    cell = SnapshotCell(max_lag_epochs=0, admission="park", park_timeout=0.05)
    cell.publish(pool["results"][0])
    cell.report_lag(5)
    t0 = time.perf_counter()
    with pytest.raises(AdmissionShed, match="timed out"):
        cell.acquire()
    assert time.perf_counter() - t0 >= 0.04
    st = cell.stats()
    assert st["parked"] == 1 and st["shed"] == 1


def test_admission_knob_validation():
    with pytest.raises(ValueError):
        SnapshotCell(admission="drop")
    with pytest.raises(ValueError):
        SnapshotCell(max_lag_epochs=-1)


# ---------------------------------------------------------------------------
# the closed-loop load generator (short smoke in tier-1, soaks in CI)
# ---------------------------------------------------------------------------


def test_loadgen_smoke():
    """A short closed-loop run: torn/stale must be zero even at this size."""
    from repro.serve.loadgen import run_load

    rep = run_load(
        backend="jnp", n_keys=1024, n_words=2, batch=64, n_readers=2,
        duration_s=0.8, mutation_batch=32, seed=0,
    )
    assert rep.errors == []
    assert rep.n_requests > 0 and rep.epochs_published >= 2
    assert rep.torn_reads == 0 and rep.stale_epochs == 0
    assert rep.p50_us > 0 and rep.p99_us >= rep.p50_us
    row = rep.to_row()
    assert row["max_concurrent_pins"] >= 1


@pytest.mark.soak
def test_soak_loadgen_jnp_8_readers():
    """The acceptance run: ≥8 readers, live incremental rebuilds, zero
    torn reads, zero stale epochs, zero warm retraces."""
    from repro.serve.loadgen import run_load

    rep = run_load(
        backend="jnp", n_keys=16384, n_words=2, batch=256, n_readers=8,
        duration_s=4.0, mutation_batch=64, seed=0,
    )
    assert rep.errors == []
    assert rep.n_requests >= 8 and rep.epochs_published >= 3
    assert rep.torn_reads == 0 and rep.stale_epochs == 0
    assert rep.warm_traces == 0, "concurrent serving must stay warm"
    st = rep.cell_stats
    assert st["acquires"] == st["releases"] and st["pinned"] == 0
    assert st["max_concurrent_pins"] >= 2


@pytest.mark.soak
def test_soak_loadgen_pallas():
    from repro.serve.loadgen import run_load

    rep = run_load(
        backend="pallas", n_keys=8192, n_words=2, batch=128, n_readers=8,
        duration_s=3.0, mutation_batch=64, seed=1,
    )
    assert rep.errors == []
    assert rep.torn_reads == 0 and rep.stale_epochs == 0
    assert rep.warm_traces == 0


@pytest.mark.soak
def test_soak_loadgen_admission_sheds_under_lag():
    """An impossible feed rate (1 ms per mutation cycle) must trip the
    lag bound and shed reads instead of serving ever-staler answers."""
    from repro.serve.loadgen import run_load

    rep = run_load(
        backend="jnp", n_keys=8192, n_words=2, batch=128, n_readers=4,
        duration_s=3.0, mutation_batch=64, target_mutation_period_s=0.001,
        max_lag_epochs=1, admission="shed", seed=2,
    )
    assert rep.errors == []
    assert rep.torn_reads == 0 and rep.stale_epochs == 0
    assert rep.n_shed > 0 and rep.cell_stats["shed"] == rep.n_shed


@pytest.mark.soak
def test_soak_pager_load():
    """The serving-side twin: page gets racing live pager churn."""
    from repro.serve.loadgen import run_pager_load

    out = run_pager_load(
        n_pages=2048, page_tokens=16, n_seqs=24, pages_per_seq=6,
        n_readers=4, duration_s=3.0, seed=0,
    )
    assert out["errors"] == []
    assert out["n_requests"] > 0 and out["epochs_published"] >= 2
    assert out["torn_reads"] == 0 and out["stale_epochs"] == 0
