"""Per-kernel shape/dtype sweeps against the pure-jnp oracles
(interpret=True executes the Pallas kernel bodies on CPU)."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import compress as C
from repro.core import dbits as D
from repro.kernels.bitonic import ops as bitonic_ops
from repro.kernels.bitonic.ref import block_sort_ref
from repro.kernels.dbit import ops as dbit_ops
from repro.kernels.dbit.ref import adjacent_dbits_ref
from repro.kernels.pext import ops as pext_ops
from repro.kernels.pext.ref import pext_ref


def _keys(rng, n, w, mask=0xFFFFFFFF):
    return rng.integers(0, 2**32, size=(n, w), dtype=np.uint32) & np.uint32(mask)


@pytest.mark.parametrize("n", [64, 1000, 2048, 5000])
@pytest.mark.parametrize("w", [1, 3, 8])
def test_pext_kernel_sweep(rng, n, w):
    arr = _keys(rng, n, w, 0x3FC0FF03)
    jw = jnp.asarray(arr)
    bm = D.compute_dbitmap(jw)
    plan = C.make_plan(np.asarray(bm), w)
    got = pext_ops.pext(jw, plan, tile=256)
    want = pext_ref(jw, plan)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("tile", [128, 512, 1024])
def test_pext_tile_shapes(rng, tile):
    arr = _keys(rng, 777, 2, 0x00FFFF00)
    jw = jnp.asarray(arr)
    bm = D.compute_dbitmap(jw)
    plan = C.make_plan(np.asarray(bm), 2)
    got = pext_ops.pext(jw, plan, tile=tile)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(pext_ref(jw, plan)))


def test_pext_wide_keys(rng):
    """512-byte keys (the paper's ExURL max) = 128 words."""
    arr = _keys(rng, 300, 128, 0x01010101)
    jw = jnp.asarray(arr)
    bm = D.compute_dbitmap(jw)
    plan = C.make_plan(np.asarray(bm), 128)
    got = pext_ops.pext(jw, plan, tile=128)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(pext_ref(jw, plan)))


@pytest.mark.parametrize("n,w,block", [(512, 1, 128), (1024, 2, 256),
                                       (4096, 4, 512), (333, 3, 64)])
def test_bitonic_kernel_sweep(rng, n, w, block):
    arr = _keys(rng, n, w, 0xFFFF00FF)
    rids = np.arange(n, dtype=np.uint32)
    kw, kr = bitonic_ops.block_sort(jnp.asarray(arr), jnp.asarray(rids), block=block)
    kwn, krn = np.asarray(kw), np.asarray(kr)
    for s in range(0, n, block):
        e = min(s + block, n)
        blk = [tuple(r) for r in kwn[s:e]]
        assert blk == sorted(blk)
    assert sorted(map(tuple, kwn)) == sorted(map(tuple, arr))  # permutation
    assert (arr[krn] == kwn).all()  # payload follows keys


def test_bitonic_matches_ref_block_content(rng):
    n, w, block = 1024, 2, 256
    arr = _keys(rng, n, w, 0x0000FFFF)
    rids = np.arange(n, dtype=np.uint32)
    kw, _ = bitonic_ops.block_sort(jnp.asarray(arr), jnp.asarray(rids), block=block)
    rw, _ = block_sort_ref(jnp.asarray(arr), jnp.asarray(rids), block)
    np.testing.assert_array_equal(np.asarray(kw), np.asarray(rw))


def test_bitonic_duplicate_keys(rng):
    """Ties must neither drop nor duplicate payloads."""
    n, block = 512, 128
    arr = np.repeat(_keys(rng, n // 4, 2, 0x000000FF), 4, axis=0)
    rids = np.arange(n, dtype=np.uint32)
    kw, kr = bitonic_ops.block_sort(jnp.asarray(arr), jnp.asarray(rids), block=block)
    assert sorted(np.asarray(kr).tolist()) == rids.tolist()


@pytest.mark.parametrize("n,w", [(100, 1), (1500, 3), (4096, 8)])
def test_dbit_kernel_sweep(rng, n, w):
    arr = _keys(rng, n, w, 0x0FFFFFFF)
    (sw,) = D.sort_words(jnp.asarray(arr))
    got = dbit_ops.adjacent_dbits(sw, tile=256)
    want = adjacent_dbits_ref(sw)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_dbit_kernel_duplicates():
    arr = jnp.asarray(np.asarray([[1, 2], [1, 2], [1, 3]], np.uint32))
    got = np.asarray(dbit_ops.adjacent_dbits(arr, tile=128))
    assert got[0] == D.NO_DBIT  # equal adjacent keys
    assert got[1] == 63  # 2 vs 3 differ in the last bit of word 1


def test_kernel_pipeline_end_to_end(rng):
    """extract (pext kernel) -> block sort (bitonic) -> merge -> dbits
    (dbit kernel) reproduces the pure-jnp reconstruction pipeline."""
    n, w = 2048, 4
    arr = np.unique(_keys(rng, n, w, 0x00FF00FF), axis=0)
    jw = jnp.asarray(arr)
    bm = D.compute_dbitmap(jw)
    plan = C.make_plan(np.asarray(bm), w)
    comp_k = pext_ops.pext(jw, plan, tile=256)
    rids = jnp.arange(arr.shape[0], dtype=jnp.uint32)
    bw, br = bitonic_ops.block_sort(comp_k, rids, block=256)
    # final merge of block runs
    (ms, mr) = D.sort_words(bw, br)
    dp_k = dbit_ops.adjacent_dbits(ms, tile=256)
    # oracle pipeline
    comp_o = C.extract_bits(jw, plan)
    (so, ro) = D.sort_words(comp_o, rids)
    dp_o = adjacent_dbits_ref(so)
    np.testing.assert_array_equal(np.asarray(ms), np.asarray(so))
    np.testing.assert_array_equal(np.asarray(dp_k), np.asarray(dp_o))
