"""Multi-tenant fan-out: stacked ``lookup_many``, arenas, fused engine, SLO.

The acceptance contract: T same-geometry snapshots answer from ONE
compiled program, byte-identical per tenant to the single-snapshot
``lookup`` on every backend; the registry migrates geometry changes
without touching other tenants; a tenant retiring mid-batch sheds only
its own requests; SLO admission sheds under overshoot but never starves
a tenant; pooled loadgen percentiles weight threads by their stream
length.
"""

import threading

import numpy as np
import pytest

from repro.backends import get_backend
from repro.core import plancache
from repro.core.btree import lookup_many_planned, stack_trees, tree_geometry
from repro.core.keyformat import KeySet
from repro.core.pipeline import ReconstructionPipeline
from repro.core.snapshot import IndexSnapshot, SnapshotCell


def _snap(result, epoch=0):
    return IndexSnapshot.from_result(result, epoch=epoch)
from repro.serve import (
    AdmissionShed,
    MultiTenantEngine,
    SLOAdmissionController,
    SLOConfig,
    TenantRegistry,
)
from repro.serve.loadgen import LatencyReservoir, pooled_percentiles


def _keyset(rng, n, w=2, rid_base=0):
    """Exactly ``n`` unique masked keys (duplicates would make rids ambiguous)."""
    pool = rng.integers(0, 2**32, size=(2 * n + 64, w), dtype=np.uint32)
    pool &= np.uint32(0x00FF0F0F)
    uniq = np.unique(pool, axis=0)
    assert uniq.shape[0] >= n
    words = uniq[rng.permutation(uniq.shape[0])[:n]]
    return KeySet(
        words=words,
        lengths=np.full(n, w * 4, np.int32),
        rids=np.arange(rid_base, rid_base + n, dtype=np.uint32),
    )


def _queries(ks, rng, q):
    """Half hits, half guaranteed misses (bit 0x10 is outside the mask)."""
    idx = rng.integers(0, ks.words.shape[0], size=q)
    qs = np.asarray(ks.words)[idx].copy()
    qs[::2] ^= np.uint32(0x10)
    return qs


def _backend(name):
    return get_backend(name, **({"interpret": True} if name == "pallas" else {}))


# ---------------------------------------------------------------------------
# stacked tree + lookup_many core
# ---------------------------------------------------------------------------


def test_stack_trees_geometry_mismatch(rng):
    t_a = ReconstructionPipeline(backend="jnp").run(_keyset(rng, 256)).tree
    t_b = ReconstructionPipeline(backend="jnp").run(_keyset(rng, 300)).tree
    assert tree_geometry(t_a) != tree_geometry(t_b)
    with pytest.raises(ValueError):
        stack_trees([t_a, t_b])


@pytest.mark.parametrize("n", [511, 512, 513])
@pytest.mark.parametrize("backend", ["jnp", "pallas"])
def test_lookup_many_geometry_edges(rng, n, backend):
    """2^k±1 keys: padding boundaries of the stacked tree, two tenants."""
    be = _backend(backend)
    pipe = ReconstructionPipeline(backend=backend)
    kss = [_keyset(rng, n, rid_base=1000 * i) for i in range(2)]
    trees = [pipe.run(ks).tree for ks in kss]
    stacked = stack_trees(trees)
    queries = np.stack([_queries(ks, rng, 48) for ks in kss])
    found, rid = be.lookup_many(stacked, queries)
    for i, tree in enumerate(trees):
        f1, r1 = be.lookup(tree, queries[i])
        np.testing.assert_array_equal(np.asarray(found[i]), np.asarray(f1))
        np.testing.assert_array_equal(np.asarray(rid[i]), np.asarray(r1))


@pytest.mark.parametrize("backend", ["jnp", "pallas", "distributed"])
def test_lookup_many_t1_matches_single_lookup(rng, backend):
    """T=1 degenerates to the single-snapshot path, byte-identical."""
    be = _backend(backend)
    ks = _keyset(rng, 400)
    tree = ReconstructionPipeline(backend=backend).run(ks).tree
    stacked = stack_trees([tree])
    qs = _queries(ks, rng, 64)
    found, rid = be.lookup_many(stacked, qs[None])
    ref = _backend("jnp" if backend == "distributed" else backend)
    f1, r1 = ref.lookup(tree, qs)
    assert found.shape == (1, 64) and rid.shape == (1, 64)
    np.testing.assert_array_equal(np.asarray(found[0]), np.asarray(f1))
    np.testing.assert_array_equal(np.asarray(rid[0]), np.asarray(r1))


def test_lookup_many_partial_arena_and_zero_retrace(rng):
    """Partial tenant rows (n_valid) + warm replay with per-op attribution."""
    be = _backend("jnp")
    kss = [_keyset(rng, 320, rid_base=500 * i) for i in range(3)]
    trees = [ReconstructionPipeline(backend="jnp").run(ks).tree for ks in kss]
    stacked = stack_trees(trees)  # capacity 4: one padded replica row
    queries = np.stack([_queries(ks, rng, 32) for ks in kss])
    n_valid = np.array([32, 7, 0], np.uint32)
    found, rid = be.lookup_many(stacked, queries, n_valid)
    assert found.shape == (3, 32)
    f1, r1 = be.lookup(trees[1], queries[1][:7])
    np.testing.assert_array_equal(np.asarray(found[1][:7]), np.asarray(f1))
    np.testing.assert_array_equal(np.asarray(rid[1][:7]), np.asarray(r1))
    assert not np.asarray(found[1][7:]).any()  # dead lanes answer not-found
    assert not np.asarray(found[2]).any()  # zero-valid tenant row

    s0 = plancache.cache_stats()
    be.lookup_many(stacked, queries, n_valid)
    s1 = plancache.cache_stats()
    assert s1["traces"] == s0["traces"]  # warm replay
    ops = s1["per_op"]
    assert "lookup_many" in ops and ops["lookup_many"]["hits"] >= 1


# ---------------------------------------------------------------------------
# registry: geometry buckets, migration
# ---------------------------------------------------------------------------


def test_registry_migration_on_geometry_change(rng):
    pipe = ReconstructionPipeline(backend="jnp")
    reg = TenantRegistry()
    ks_a, ks_b = _keyset(rng, 256), _keyset(rng, 256, rid_base=5000)
    reg.publish("a", _snap(pipe.run(ks_a)))
    reg.publish("b", _snap(pipe.run(ks_b)))
    arena0 = reg.arena_of("a")
    assert arena0 is reg.arena_of("b") and arena0.capacity == 2

    # 'a' rebuilds at a different size -> different geometry bucket
    ks_a2 = _keyset(rng, 300, rid_base=9000)
    reg.publish("a", _snap(pipe.run(ks_a2), epoch=1))
    st = reg.stats()
    assert st["n_migrations"] == 1 and st["n_arenas"] == 2
    assert reg.arena_of("a") is not reg.arena_of("b")
    assert reg.arena_of("b").tenants == ("b",)

    # both tenants still answer correctly from their new arenas
    be = _backend("jnp")
    for tenant, ks in (("a", ks_a2), ("b", ks_b)):
        arena = reg.arena_of(tenant)
        qs = np.asarray(ks.words[:16])
        nv = np.zeros(arena.capacity, np.uint32)
        nv[arena.slots[tenant]] = 16
        qb = np.full((arena.capacity, 16, 2), 0xFFFFFFFF, np.uint32)
        qb[arena.slots[tenant]] = qs
        found, rid = be.lookup_many(arena.stacked, qb, nv)
        row = arena.slots[tenant]
        assert np.asarray(found[row]).all()
        np.testing.assert_array_equal(
            np.asarray(rid[row]), np.asarray(ks.rids[:16])
        )

    reg.retire("a")
    assert reg.arena_of("a") is None and reg.stats()["n_arenas"] == 1


def test_registry_publish_pins_cell_epoch(rng):
    """Publishing from a SnapshotCell leases the epoch until republish."""
    pipe = ReconstructionPipeline(backend="jnp")
    cell = SnapshotCell()
    pipe.run(_keyset(rng, 256), publish_to=cell)
    reg = TenantRegistry()
    reg.publish("t", cell)
    pipe.run(_keyset(rng, 256, rid_base=700), publish_to=cell)
    # epoch 0 retired by the publish but still pinned by the registry
    assert cell.stats()["retired"] == 1
    reg.publish("t", cell)  # re-pin at epoch 1 releases epoch 0
    assert cell.stats()["retired"] == 0
    assert reg.arena_of("t").epochs["t"] == 1
    reg.retire("t")
    assert cell.stats()["pinned"] == 0


# ---------------------------------------------------------------------------
# engine: fused dispatch, tenant leaving mid-batch
# ---------------------------------------------------------------------------


def _fleet(rng, n_tenants, n=256):
    pipe = ReconstructionPipeline(backend="jnp")
    reg = TenantRegistry()
    kss = {}
    for t in range(n_tenants):
        ks = _keyset(rng, n, rid_base=10_000 * (t + 1))
        kss[t] = ks
        reg.publish(t, _snap(pipe.run(ks)))
    return reg, kss


def test_engine_fuses_cross_tenant_batch(rng):
    reg, kss = _fleet(rng, 3)
    eng = MultiTenantEngine(reg, _backend("jnp"), auto_dispatch=False)
    results = {}

    def ask(t):
        results[t] = eng.submit(t, np.asarray(kss[t].words[:24]))

    threads = [threading.Thread(target=ask, args=(t,)) for t in kss]
    for th in threads:
        th.start()
    while eng.stats()["pending"] < 3:
        pass
    assert eng.flush() == 3
    for th in threads:
        th.join(timeout=10.0)
    st = eng.stats()
    assert st["n_dispatches"] == 1  # ONE lookup_many for all three tenants
    assert st["n_batches"] == 1
    for t, ks in kss.items():
        found, rid, _epoch = results[t]
        assert np.asarray(found).all()
        np.testing.assert_array_equal(np.asarray(rid), np.asarray(ks.rids[:24]))
    eng.shutdown()


def test_tenant_leaving_mid_batch(rng):
    """Retire between enqueue and flush: only the leaver's request sheds."""
    reg, kss = _fleet(rng, 2)
    eng = MultiTenantEngine(reg, _backend("jnp"), auto_dispatch=False)
    out, err = {}, {}

    def ask(t):
        try:
            out[t] = eng.submit(t, np.asarray(kss[t].words[:16]))
        except AdmissionShed as e:
            err[t] = e

    threads = [threading.Thread(target=ask, args=(t,)) for t in (0, 1)]
    for th in threads:
        th.start()
    while eng.stats()["pending"] < 2:
        pass
    reg.retire(1)
    eng.flush()
    for th in threads:
        th.join(timeout=10.0)
    assert 1 in err and "retired" in str(err[1])
    found, rid, _epoch = out[0]  # survivor answered correctly
    assert np.asarray(found).all()
    np.testing.assert_array_equal(np.asarray(rid), np.asarray(kss[0].rids[:16]))
    eng.shutdown()


# ---------------------------------------------------------------------------
# SLO admission
# ---------------------------------------------------------------------------


def test_slo_windowed_aimd():
    ctl = SLOAdmissionController(
        SLOConfig(target_p99_us=1000.0, window=8, fairness_limit=4)
    )
    for _ in range(8):
        ctl.observe("t", 5000.0)  # one overshooting window
    assert ctl.stats()["t"]["shed_frac"] == pytest.approx(0.15)
    for _ in range(16):
        ctl.observe("t", 100.0)  # two clear windows -> multiplicative decay
    assert ctl.stats()["t"]["shed_frac"] == pytest.approx(0.15 * 0.7 * 0.7)
    # the windowed signal forgets the past stall: keep feeding clear
    # windows and the fraction decays toward zero instead of saturating
    for _ in range(20 * 8):
        ctl.observe("t", 100.0)
    assert ctl.stats()["t"]["shed_frac"] < 0.01


def test_slo_sheds_but_never_starves():
    ctl = SLOAdmissionController(
        SLOConfig(target_p99_us=1.0, window=4, fairness_limit=3)
    )
    # drive shed_frac to the 0.9 cap with persistently overshooting windows
    for _ in range(4 * 10):
        ctl.observe("t", 1e6)
    assert ctl.stats()["t"]["shed_frac"] == pytest.approx(0.9)
    verdicts = [ctl.admit("t") for _ in range(200)]
    st = ctl.stats()["t"]
    assert st["n_shed"] > 0  # it does shed
    assert st["forced_admits"] > 0  # the fairness floor fired
    assert sum(verdicts) >= 200 // (3 + 1)  # never starves
    # sheds are spread (accumulator), not bursty: no admit gap > limit
    gap, worst = 0, 0
    for v in verdicts:
        gap = 0 if v else gap + 1
        worst = max(worst, gap)
    assert worst <= 3


def test_pooled_percentiles_weight_by_stream_length():
    """Satellite regression: a slow 8-request thread must not drag the
    pooled p99 of a 10000-request fleet to its own tail."""
    fast = LatencyReservoir(capacity=64, seed=0)
    for _ in range(10_000):
        fast.record(1.0)
    slow = LatencyReservoir(capacity=64, seed=1)
    for _ in range(8):
        slow.record(100.0)
    pooled = pooled_percentiles([fast, slow])
    # unweighted concatenation would put 8/72 = 11% of the mass at 100.0
    # and report p99 = 100; weighted, the slow thread is 8/10008 of the
    # stream and the p99 stays at the fast thread's latency
    assert pooled["p99_us"] == pytest.approx(1.0)
    assert pooled["p50_us"] == pytest.approx(1.0)


# ---------------------------------------------------------------------------
# distributed: tenant axis sharded over the mesh
# ---------------------------------------------------------------------------


def test_distributed_lookup_many_sharded_subprocess():
    """8 tenants over 4 host devices: 2 tenants per shard, byte-identical."""
    import os
    import subprocess
    import sys
    import textwrap
    from pathlib import Path

    src = str(Path(__file__).resolve().parent.parent / "src")
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = src
    code = textwrap.dedent("""
        import numpy as np
        from repro.backends import get_backend
        from repro.core import plancache
        from repro.core.btree import stack_trees
        from repro.core.keyformat import KeySet
        from repro.core.pipeline import ReconstructionPipeline

        def ks_of(seed, n=300, w=2):
            r = np.random.default_rng(seed)
            pool = r.integers(0, 2**32, size=(2 * n + 64, w), dtype=np.uint32)
            pool &= np.uint32(0x00FF0F0F)
            uniq = np.unique(pool, axis=0)
            words = uniq[r.permutation(uniq.shape[0])[:n]]
            rids = np.arange(1000 * seed, 1000 * seed + n, dtype=np.uint32)
            return KeySet(words=words, lengths=np.full(n, w * 4, np.int32), rids=rids)

        pipe = ReconstructionPipeline(backend="jnp")
        kss = [ks_of(s + 1) for s in range(8)]
        trees = [pipe.run(k).tree for k in kss]
        stacked = stack_trees(trees)
        rng = np.random.default_rng(99)
        queries = np.stack([
            np.asarray(k.words)[rng.integers(0, 300, size=32)] for k in kss
        ])
        queries[:, ::2] ^= np.uint32(0x10)  # misses outside the mask

        dist, ref = get_backend("distributed"), get_backend("jnp")
        found, rid = dist.lookup_many(stacked, queries)
        assert dist.last_info["mesh_devices"] == 4, dist.last_info
        assert dist.last_info["tenants_per_shard"] == 2, dist.last_info
        for i, t in enumerate(trees):
            f1, r1 = ref.lookup(t, queries[i])
            np.testing.assert_array_equal(np.asarray(found[i]), np.asarray(f1))
            np.testing.assert_array_equal(np.asarray(rid[i]), np.asarray(r1))
        s0 = plancache.cache_stats()["traces"]
        dist.lookup_many(stacked, queries)
        assert plancache.cache_stats()["traces"] == s0
        print("SHARDED LOOKUP_MANY OK")
    """)
    r = subprocess.run(
        [sys.executable, "-c", code],
        env=env,
        capture_output=True,
        text=True,
        timeout=600,
    )
    assert r.returncode == 0, r.stdout + r.stderr
    assert "SHARDED LOOKUP_MANY OK" in r.stdout
