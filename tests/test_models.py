"""Per-arch smoke tests (reduced configs, deliverable (f)) + layer units."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.models.layers import chunked_softmax_xent, decode_attention, flash_attention
from repro.models.lm import LM
from repro.models.moe import dispatch_indices_cumsum, dispatch_indices_sort, moe_ffn

KEY = jax.random.PRNGKey(0)


def _batch(cfg, B=2, S=32, key=KEY):
    b = {}
    if cfg.embed_input:
        b["tokens"] = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    else:
        b["frames"] = jax.random.normal(key, (B, S, cfg.d_model), jnp.bfloat16)
    b["labels"] = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    if cfg.n_img_tokens:
        b["img_embeds"] = jax.random.normal(
            key, (B, cfg.n_img_tokens, cfg.d_model), jnp.bfloat16
        )
    return b


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_arch_smoke_train_step(arch):
    """One reduced forward/train step on CPU: output shapes + no NaNs."""
    cfg = ARCHS[arch].reduced()
    m = LM(cfg, remat=False)
    params = m.init(KEY)
    loss, metrics = jax.jit(m.loss)(params, _batch(cfg))
    assert np.isfinite(float(loss))
    grads = jax.grad(lambda p: m.loss(p, _batch(cfg))[0])(params)
    gn = sum(float(jnp.sum(jnp.abs(g))) for g in jax.tree_util.tree_leaves(grads))
    assert np.isfinite(gn) and gn > 0


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_arch_smoke_prefill_decode_consistency(arch):
    """Prefill-then-decode logits == teacher-forced forward logits."""
    cfg = ARCHS[arch].reduced()
    m = LM(cfg, remat=False)
    params = m.init(KEY)
    B, S = 2, 32
    batch = _batch(cfg, B, S)
    cache = m.init_cache(B, S + 16)

    # prefill S tokens, then decode the next one
    pb = {k: v for k, v in batch.items() if k != "labels"}
    cache, logits_prefill = jax.jit(m.prefill)(params, pb, cache)
    assert logits_prefill.shape == (B, cfg.vocab_size)
    db = {"pos": jnp.int32(S)}
    if cfg.embed_input:
        db["token"] = jnp.argmax(logits_prefill, -1).astype(jnp.int32)
    else:
        db["frame"] = jnp.zeros((B, cfg.d_model), jnp.bfloat16)
    if cfg.n_img_tokens:
        db["img_embeds"] = batch["img_embeds"]
    cache, logits_decode = jax.jit(m.decode_step)(params, cache, db)
    assert logits_decode.shape == (B, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits_decode)).all()


def test_flash_attention_matches_naive():
    B, H, G, T, dh = 2, 8, 4, 64, 16
    k1, k2, k3 = jax.random.split(KEY, 3)
    q = jax.random.normal(k1, (B, H, T, dh), jnp.float32)
    k = jax.random.normal(k2, (B, G, T, dh), jnp.float32)
    v = jax.random.normal(k3, (B, G, T, dh), jnp.float32)
    out = flash_attention(q, k, v, causal=True, q_chunk=16, kv_chunk=16)
    # naive reference
    r = H // G
    qg = q.reshape(B, G, r, T, dh)
    s = jnp.einsum("bgrqd,bgkd->bgrqk", qg, k) / np.sqrt(dh)
    mask = jnp.tril(jnp.ones((T, T), bool))
    s = jnp.where(mask[None, None, None], s, -jnp.inf)
    ref = jnp.einsum("bgrqk,bgkd->bgrqd", jax.nn.softmax(s, -1), v).reshape(
        B, H, T, dh
    )
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


def test_flash_attention_rectangular_and_noncausal():
    B, H, G, Tq, Tk, dh = 1, 4, 2, 32, 64, 8
    q = jax.random.normal(KEY, (B, H, Tq, dh))
    k = jax.random.normal(jax.random.PRNGKey(1), (B, G, Tk, dh))
    v = jax.random.normal(jax.random.PRNGKey(2), (B, G, Tk, dh))
    out = flash_attention(q, k, v, causal=False, q_chunk=16, kv_chunk=16)
    r = H // G
    qg = q.reshape(B, G, r, Tq, dh)
    s = jnp.einsum("bgrqd,bgkd->bgrqk", qg, k) / np.sqrt(dh)
    ref = jnp.einsum("bgrqk,bgkd->bgrqd", jax.nn.softmax(s, -1), v).reshape(
        B, H, Tq, dh
    )
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


def test_decode_attention_matches_flash_row():
    B, H, G, S, dh = 2, 8, 2, 64, 16
    q = jax.random.normal(KEY, (B, H, 1, dh))
    k = jax.random.normal(jax.random.PRNGKey(1), (B, G, S, dh))
    v = jax.random.normal(jax.random.PRNGKey(2), (B, G, S, dh))
    L = 40
    out = decode_attention(q, k, v, jnp.int32(L), kv_chunk=16)
    r = H // G
    qg = q.reshape(B, G, r, 1, dh)
    s = jnp.einsum("bgrqd,bgkd->bgrqk", qg, k[:, :, :L]) / np.sqrt(dh)
    ref = jnp.einsum(
        "bgrqk,bgkd->bgrqd", jax.nn.softmax(s, -1), v[:, :, :L]
    ).reshape(B, H, 1, dh)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


def test_chunked_xent_matches_dense():
    B, T, d, V = 2, 64, 16, 97  # V deliberately not chunk-aligned
    h = jax.random.normal(KEY, (B, T, d))
    w = jax.random.normal(jax.random.PRNGKey(1), (d, V))
    y = jax.random.randint(jax.random.PRNGKey(2), (B, T), 0, V)
    got = chunked_softmax_xent(h, w, y, chunk=16)
    logits = h @ w
    ref = jnp.mean(
        jax.nn.logsumexp(logits, -1)
        - jnp.take_along_axis(logits, y[..., None], -1)[..., 0]
    )
    np.testing.assert_allclose(float(got), float(ref), rtol=1e-5)


def test_moe_dispatch_sort_equals_cumsum():
    """The compressed-key-sort dispatch and the GShard cumsum dispatch give
    identical expert positions (same arrival order)."""
    rng = np.random.default_rng(0)
    for E, M in [(8, 256), (32, 1000), (128, 4096)]:
        eid = jnp.asarray(rng.integers(0, E, M), jnp.int32)
        pos_sort, _ = dispatch_indices_sort(eid, E)
        pos_cum = dispatch_indices_cumsum(jax.nn.one_hot(eid, E, dtype=jnp.int32))
        np.testing.assert_array_equal(np.asarray(pos_sort), np.asarray(pos_cum))


def test_moe_ffn_modes_agree():
    """einsum vs sort dispatch: identical layer output."""
    E, k, d, f, B, T = 8, 2, 16, 32, 2, 24
    keys = jax.random.split(KEY, 5)
    p = {
        "router": jax.random.normal(keys[0], (d, E)) * 0.1,
        "moe_w1": jax.random.normal(keys[1], (E, d, f)) * 0.1,
        "moe_w3": jax.random.normal(keys[2], (E, d, f)) * 0.1,
        "moe_w2": jax.random.normal(keys[3], (E, f, d)) * 0.1,
    }
    x = jax.random.normal(keys[4], (B, T, d))
    y1, a1 = moe_ffn(p, x, n_experts=E, top_k=k, dispatch_mode="einsum")
    y2, a2 = moe_ffn(p, x, n_experts=E, top_k=k, dispatch_mode="sort")
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-6)
    assert float(a1["dropped_frac"]) == float(a2["dropped_frac"])


def test_moe_capacity_drops_are_bounded():
    E, k, d, f = 4, 1, 8, 16
    p = {
        "router": jnp.zeros((d, E)).at[:, 0].set(10.0),  # all route to expert 0
        "moe_w1": jnp.ones((E, d, f)) * 0.01,
        "moe_w3": jnp.ones((E, d, f)) * 0.01,
        "moe_w2": jnp.ones((E, f, d)) * 0.01,
    }
    x = jnp.ones((1, 64, d))
    y, aux = moe_ffn(p, x, n_experts=E, top_k=k, capacity_factor=1.0)
    # capacity = 64/4 = 16 slots on expert 0 -> 48/64 dropped
    assert 0.70 <= float(aux["dropped_frac"]) <= 0.80


def test_active_vs_total_params_moe():
    cfg = ARCHS["qwen3-moe-235b-a22b"]
    assert cfg.total_params() > 200e9
    assert 15e9 < cfg.active_params() < 30e9  # ~22B active


def test_all_assigned_configs_exact():
    """Spec table values survive in the registry."""
    a = ARCHS
    q = a["qwen3-moe-235b-a22b"]
    assert (q.n_layers, q.d_model, q.n_heads, q.n_kv_heads) == (94, 4096, 64, 4)
    assert (q.n_experts, q.top_k, q.moe_d_ff, q.vocab_size) == (128, 8, 1536, 151936)
    j = a["jamba-v0.1-52b"]
    assert (j.n_layers, j.d_ff, j.n_experts, j.top_k) == (32, 14336, 16, 2)
    mixers = [m for m, _ in j.pattern]
    assert mixers.count("attn") == 1 and mixers.count("mamba") == 7  # 1:7
    x = a["xlstm-1.3b"]
    assert x.d_ff == 0 and x.vocab_size == 50304
    v = a["llama-3.2-vision-90b"]
    assert v.n_layers == 100 and v.d_ff == 28672
    assert [m for m, _ in v.pattern].count("xattn") == 1 and len(v.pattern) == 5
    g = a["granite-34b"]
    assert g.n_kv_heads == 1 and g.n_layers == 88
    mt = a["minitron-4b"]
    assert mt.vocab_size == 256000
    assert a["llama3-8b"].d_ff == 14336
    assert a["internlm2-20b"].d_model == 6144
    assert a["musicgen-large"].embed_input is False
    assert a["llama4-scout-17b-a16e"].shared_expert is True
