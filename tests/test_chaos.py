"""Chaos layer tests: fault injection, the supervisor ladder, soak runs.

Three levels: (1) unit tests pin each injected fault of
``FaultyTransport`` (deterministic per-fault plans) and each rung of the
``ReplicaSupervisor`` degradation ladder (stub replica + fake clock, so
multi-second backoff schedules run in microseconds); (2) integration
tests drive real primaries/replicas through single fault families
(reorder heal, read-corruption retry); (3) the fast soak runs the full
``tools/chaos_soak.py`` harness — every fault family at once plus replica
churn — and requires zero invariant violations on jnp and pallas.
"""

import sys
from pathlib import Path

import numpy as np
import pytest

from repro.core import plancache
from repro.core.keyformat import KeySet
from repro.replication import (
    BatchFrame,
    ChangeLog,
    ChaosPlan,
    FaultyTransport,
    FrameCorrupt,
    FrameTruncated,
    LsnGapError,
    QueueTransport,
    ReplicaSupervisor,
    StreamPrimary,
    StreamReplica,
    SupervisorPolicy,
    encode_frame,
)

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "tools"))
import chaos_soak  # noqa: E402


def _keyset(rng, n, w=3):
    words = rng.integers(0, 2**32, size=(n, w), dtype=np.uint32)
    words &= np.uint32(0x00FF0F0F)
    return KeySet(words=words, lengths=np.full(n, w * 4, np.int32),
                  rids=np.arange(n, dtype=np.uint32))


def _assert_state_identical(a, b):
    np.testing.assert_array_equal(np.asarray(a.keyset.words),
                                  np.asarray(b.keyset.words))
    np.testing.assert_array_equal(np.asarray(a.keyset.rids),
                                  np.asarray(b.keyset.rids))
    np.testing.assert_array_equal(a.meta.dbitmap, b.meta.dbitmap)
    np.testing.assert_array_equal(
        np.asarray(a.result.comp_sorted), np.asarray(b.result.comp_sorted))
    np.testing.assert_array_equal(
        np.asarray(a.result.rid_sorted), np.asarray(b.result.rid_sorted))
    assert a.applied_lsn == b.applied_lsn


# ---------------------------------------------------------------------------
# ChaosPlan
# ---------------------------------------------------------------------------


def test_chaos_plan_sampling_is_deterministic():
    a, b = ChaosPlan.sample(7), ChaosPlan.sample(7)
    assert a == b  # same seed, same plan, field for field
    c = ChaosPlan.sample(8)
    assert a != c
    assert 0 <= a.p_drop_publish <= 0.08 and 0 <= a.p_corrupt <= 0.12
    # intensity scales every probability
    half = ChaosPlan.sample(7, intensity=0.5)
    assert half.p_corrupt == pytest.approx(a.p_corrupt * 0.5)


# ---------------------------------------------------------------------------
# FaultyTransport: one fault family at a time, deterministically
# ---------------------------------------------------------------------------


def test_faulty_drop_never_reaches_inner():
    inner = QueueTransport()
    t = FaultyTransport(inner, ChaosPlan(seed=1, p_drop_publish=1.0))
    t.publish(b"gone")
    assert inner.end() == 0
    assert t.counts == {"drop": 1}
    assert t.ledger[0]["fault"] == "drop"


def test_faulty_duplicate_appends_twice():
    inner = QueueTransport()
    t = FaultyTransport(inner, ChaosPlan(seed=1, p_duplicate=1.0,
                                         reorder_window=1))
    t.publish(b"x")
    assert inner.end() == 2
    assert inner.read(0) == inner.read(1) == b"x"
    assert t.counts["duplicate"] == 1


def test_faulty_reorder_holds_and_permutes():
    inner = QueueTransport()
    t = FaultyTransport(inner, ChaosPlan(seed=3, p_reorder=1.0,
                                         reorder_window=3))
    for i in range(5):
        t.publish(f"f{i}".encode())
    t.flush()
    assert inner.end() == 5  # nothing lost, possibly permuted
    got = [inner.read(i) for i in range(5)]
    assert sorted(got) == sorted(f"f{i}".encode() for i in range(5))
    assert t.counts["hold"] >= 1


def test_faulty_corruption_is_transient():
    inner = QueueTransport()
    t = FaultyTransport(inner, ChaosPlan(seed=2, p_corrupt=1.0,
                                         corrupt_bits=2))
    t.publish(b"pristine-bytes")
    assert t.read(0) != b"pristine-bytes"  # damaged on this read...
    t.enabled = False
    assert t.read(0) == b"pristine-bytes"  # ...but never in storage
    assert t.counts["corrupt"] >= 1


def test_faulty_delay_and_spurious_truncation():
    inner = QueueTransport()
    t = FaultyTransport(inner, ChaosPlan(seed=4, p_delay=1.0))
    t.publish(b"late")
    assert t.read(0) is None  # visible only once the fault clears
    t.enabled = False
    assert t.read(0) == b"late"

    t2 = FaultyTransport(QueueTransport(),
                         ChaosPlan(seed=4, p_spurious_truncated=1.0))
    t2.publish(b"fine")
    with pytest.raises(FrameTruncated):
        t2.read(0)
    t2.enabled = False
    assert t2.read(0) == b"fine"


def test_faulty_scheduled_truncation_cuts_inner():
    inner = QueueTransport()
    t = FaultyTransport(inner, ChaosPlan(seed=1, truncate_at=((3, 1),)))
    for i in range(4):
        t.publish(f"f{i}".encode())
    # at the 3rd publish, everything but the last retained frame was cut
    assert inner.first_pos() == 1
    assert t.counts["scheduled_truncate"] == 1
    with pytest.raises(FrameTruncated):
        t.read(0)


def test_faulty_quiesce_flushes_and_disables():
    inner = QueueTransport()
    t = FaultyTransport(inner, ChaosPlan(seed=3, p_reorder=1.0, p_corrupt=1.0,
                                         reorder_window=4))
    t.publish(b"a")
    t.publish(b"b")
    assert inner.end() < 2  # at least one frame held in the window
    t.quiesce()
    assert inner.end() == 2  # window drained
    assert t.read(0) == inner.read(0)  # no more read-side damage
    assert t.publish(b"c") == 2  # publish-side faults off too
    assert inner.read(2) == b"c"


# ---------------------------------------------------------------------------
# supervisor ladder (stub replica, fake clock/sleep: instant tests)
# ---------------------------------------------------------------------------


class _StubReplica:
    """Scripted poll outcomes: exceptions raise, dicts return."""

    def __init__(self, script, resync_ok=True):
        self.script = list(script)
        self.pos = 0
        self.resync_ok = resync_ok
        self.n_resyncs = 0

    def poll(self, max_frames=None):
        item = self.script.pop(0) if self.script else {"lag_frames": 0}
        if isinstance(item, Exception):
            raise item
        return dict(item)

    def resync(self):
        self.n_resyncs += 1
        return self.resync_ok


class _FakeTime:
    """A tick-per-call clock and a delay-recording sleep."""

    def __init__(self):
        self.now = 0.0
        self.slept = []

    def clock(self):
        self.now += 1.0
        return self.now

    def sleep(self, s):
        self.slept.append(round(s, 6))


def _sup(script, policy=None, resync_ok=True):
    ft = _FakeTime()
    stub = _StubReplica(script, resync_ok=resync_ok)
    return ReplicaSupervisor(stub, policy or SupervisorPolicy(),
                             clock=ft.clock, sleep=ft.sleep), stub, ft


def test_supervisor_rereads_transient_corruption_immediately():
    sup, stub, ft = _sup([FrameCorrupt("flip"), {"lag_frames": 0}])
    out = sup.pump()
    assert out["recovered"] and out["state"] == "healthy"
    assert sup.n_retries == {"corrupt": 1}
    assert ft.slept == []  # the first retry is the free immediate re-read
    assert stub.n_resyncs == 0
    assert sup.time_degraded > 0  # the degraded interval was metered


def test_supervisor_backoff_schedule_and_jitter():
    pol = SupervisorPolicy(base_delay_s=0.05, factor=2.0,
                           retries={"corrupt": 3})
    sup, _, ft = _sup([FrameCorrupt("1"), FrameCorrupt("2"),
                       FrameCorrupt("3"), {"lag_frames": 0}], policy=pol)
    assert sup.pump()["recovered"]
    assert ft.slept == [0.05, 0.1]  # retry 1 free, then exponential
    # the jitter hook scales every delay
    pol_j = SupervisorPolicy(base_delay_s=0.05, factor=2.0,
                             retries={"corrupt": 3}, jitter=lambda: 2.0)
    sup, _, ft = _sup([FrameCorrupt("1"), FrameCorrupt("2"),
                       FrameCorrupt("3"), {"lag_frames": 0}], policy=pol_j)
    sup.pump()
    assert ft.slept == [0.1, 0.2]


def test_supervisor_resync_after_budget_exhaustion():
    # 4 corrupt failures: budget of 3 retries spent, the ladder climbs to
    # resync, and the post-resync poll succeeds
    sup, stub, _ = _sup([FrameCorrupt(str(i)) for i in range(4)]
                        + [{"lag_frames": 0}])
    out = sup.pump()
    assert out["recovered"] and out["resyncs"] == 1
    assert stub.n_resyncs == 1
    assert sup.state == "healthy"


def test_supervisor_waits_for_checkpoint_without_quarantining():
    class _AlwaysGap(_StubReplica):
        def poll(self, max_frames=None):
            raise LsnGapError("dropped frame")

    ft = _FakeTime()
    sup = ReplicaSupervisor(_AlwaysGap([], resync_ok=False),
                            clock=ft.clock, sleep=ft.sleep)
    for _ in range(10):
        out = sup.pump()
        assert out["awaiting_checkpoint"] and out["state"] == "degraded"
    # no checkpoint visible is NOT a quarantine streak: the laggard keeps
    # waiting for the primary's next checkpoint instead of giving up
    assert sup.state == "degraded" and sup.n_quarantines == 0


def test_supervisor_quarantines_persistent_failure_then_resets():
    class _AlwaysCorrupt(_StubReplica):
        def poll(self, max_frames=None):
            raise FrameCorrupt("stuck")

    ft = _FakeTime()
    stub = _AlwaysCorrupt([], resync_ok=True)
    sup = ReplicaSupervisor(stub, SupervisorPolicy(quarantine_after=3),
                            clock=ft.clock, sleep=ft.sleep)
    states = [sup.pump()["state"] for _ in range(3)]
    assert states == ["degraded", "degraded", "quarantined"]
    assert sup.n_quarantines == 1
    polls_before = stub.n_resyncs
    out = sup.pump()  # short-circuits: the wire is not touched
    assert out == {"state": "quarantined", "pumped": False,
                   "recovered": False}
    assert stub.n_resyncs == polls_before
    assert sup.stats()["state"] == "quarantined"
    sup.reset()  # operator re-arm: counters kept, gate cleared
    assert sup.state == "healthy" and sup.n_quarantines == 1
    assert sup.pump()["state"] == "degraded"  # pumping again


# ---------------------------------------------------------------------------
# integration: single fault families against real streams
# ---------------------------------------------------------------------------


def test_reorder_window_heals_swapped_frames(rng):
    base = _keyset(rng, 400)
    t = QueueTransport()
    prim = StreamPrimary(t, base)
    tolerant = StreamReplica(t, reorder_window=4)
    strict = StreamReplica(t)  # default window 0: the PR-4 behavior
    tolerant.poll()
    strict.poll()
    l1 = ChangeLog(3, start_lsn=prim.next_lsn)
    l1.append_inserts(np.asarray(base.words)[:8], np.arange(8, dtype=np.uint32) + 7000)
    l2 = ChangeLog(3, start_lsn=l1.next_lsn)
    l2.append_deletes(np.asarray(base.rids)[:5])
    # the wire delivers them swapped
    t.publish(encode_frame(BatchFrame(log=l2, bucket=plancache.bucket(len(l2))), seq=98))
    t.publish(encode_frame(BatchFrame(log=l1, bucket=plancache.bucket(len(l1))), seq=99))
    prim.replica.apply(l1)
    prim.replica.apply(l2)
    st = tolerant.poll()
    assert st["reorder_heals"] == 1 and st["applied_batches"] == 2
    assert tolerant.stats["held_batches"] == 0
    _assert_state_identical(tolerant.replica, prim.replica)
    with pytest.raises(LsnGapError):
        strict.poll()  # a zero window still rejects the swap, as before


def test_supervisor_heals_read_corruption_end_to_end(rng, tmp_path):
    inner = QueueTransport()
    wire = FaultyTransport(inner, ChaosPlan(seed=5, p_corrupt=0.5,
                                            corrupt_bits=3))
    prim = StreamPrimary(wire, _keyset(rng, 400),
                         ckpt_dir=str(tmp_path / "ckpt"), max_lag_batches=4)
    rep = StreamReplica(wire, reorder_window=4)
    sup = ReplicaSupervisor(rep, sleep=lambda s: None)
    for i in range(5):
        log = ChangeLog(3, start_lsn=prim.next_lsn)
        log.append_inserts(np.asarray(prim.replica.keyset.words)[:6],
                           np.arange(6, dtype=np.uint32) + 9000 + 100 * i)
        prim.publish(log)
        sup.pump()
    wire.quiesce()
    prim.flush()
    prim.checkpoint()
    for _ in range(20):
        out = sup.pump()
        if "error_class" not in out and out.get("lag_frames", 1) == 0:
            break
    assert sup.state == "healthy"
    assert wire.counts.get("corrupt", 0) >= 1  # the wire really was hostile
    assert sup.n_retries.get("corrupt", 0) >= 1  # and the ladder was used
    _assert_state_identical(rep.replica, prim.replica)


# ---------------------------------------------------------------------------
# the soak harness itself (fast mode)
# ---------------------------------------------------------------------------


def test_chaos_soak_fast_queue_and_dir(tmp_path):
    for seed, kind in [(0, "queue"), (1, "queue"), (2, "dir")]:
        rep = chaos_soak.run_soak(seed, kind, "jnp",
                                  str(tmp_path / f"{kind}{seed}"),
                                  steps=8, n_replicas=2)
        assert rep["violations"] == [], rep
        assert rep["steady_traces"] == 0
        assert rep["survivors"] == 2


def test_chaos_soak_fast_pallas(tmp_path):
    rep = chaos_soak.run_soak(0, "queue", "pallas", str(tmp_path),
                              steps=6, n_replicas=2)
    assert rep["violations"] == [], rep


def test_chaos_soak_seed_parsing_and_cli(tmp_path, capsys):
    assert chaos_soak._parse_seeds("0-3") == [0, 1, 2, 3]
    assert chaos_soak._parse_seeds("1,4,7") == [1, 4, 7]
    assert chaos_soak._parse_seeds("0-1,5") == [0, 1, 5]
    rc = chaos_soak.main(["--seeds", "0", "--transports", "queue",
                          "--fast", "--steps", "6"])
    captured = capsys.readouterr()
    assert rc == 0 and "1 runs, 0 failing" in captured.out
