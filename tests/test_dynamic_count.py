"""Dynamic valid-count padding: retrace stability, warm-path zero host
alloc, pad-content independence, per-op bucket floors, LRU auto-sizing.

The tentpole invariant under test: every cached program takes pre-padded
bucket-shaped buffers plus a dynamic ``n_valid`` operand and normalizes
its pad lanes *in-program* — so (a) any ``n`` inside a bucket (including
``n == bucket``) replays one compiled program, (b) a warm same-bucket
call never dispatches an eager ``jnp.concatenate`` / ``jnp.full``, and
(c) whatever garbage sits in the pad lanes cannot change the bytes of
the first ``n`` output rows.
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.backends import get_backend
from repro.core import plancache
from repro.core.keyformat import KeySet
from repro.core.metadata import meta_from_keys
from repro.core.pipeline import ReconstructionPipeline


def _keyset(rng, n, w=3, mask=0x00FF0F0F):
    words = rng.integers(0, 2**32, size=(n, w), dtype=np.uint32) & np.uint32(mask)
    rids = np.arange(n, dtype=np.uint32)
    rng.shuffle(rids)
    return KeySet(words=words, lengths=np.full(n, w * 4, np.int32), rids=rids)


# ---------------------------------------------------------------------------
# retrace property: one program per bucket, any n_valid inside it
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", ["jnp", "pallas"])
def test_sort_zero_retrace_across_n_valid_in_bucket(rng, backend):
    """Every n in a bucket — the bucket boundary itself included — must
    replay the program traced by the first call."""
    plancache.reset_cache()
    be = get_backend(backend)
    cache = plancache.get_cache()

    def one(n):
        keys = jnp.asarray(
            rng.integers(0, 2**32, size=(n, 2), dtype=np.uint32)
        )
        sk, sr = be.sort(keys, jnp.arange(n, dtype=jnp.uint32))
        assert sk.shape[0] == n and sr.shape[0] == n

    one(200)  # traces the bucket-256 program
    traced = cache.stats()["traces"]
    for n in (130, 255, 256, 64, 201, 1):  # 256 == the bucket itself
        one(n)
    assert cache.stats()["traces"] == traced, "same-bucket call retraced"


def test_pipeline_zero_retrace_across_n_valid_in_bucket(rng):
    """Full run() — extract, sort, build, refresh — stays replay-only for
    drifting n inside one bucket."""
    plancache.reset_cache()
    pipe = ReconstructionPipeline(backend="jnp")
    cache = plancache.get_cache()
    meta = None

    ks0 = _keyset(rng, 300)
    meta = meta_from_keys(ks0.words)
    pipe.run(ks0, meta=meta)
    traced = cache.stats()["traces"]
    for n in (257, 400, 512, 511):  # bucket(300) == bucket(512) == 512
        pipe.run(_keyset(rng, n), meta=meta)
    assert cache.stats()["traces"] == traced


# ---------------------------------------------------------------------------
# warm path: zero eager concatenate/full, zero retraces
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("fused", [False, True])
def test_warm_run_no_eager_concat_or_full(rng, monkeypatch, fused):
    """After one cold call, a warm same-bucket run() must execute zero
    eager ``jnp.concatenate`` / ``jnp.full`` dispatches and zero traces.
    (Calls inside traced program bodies don't count — traced bodies do
    not run on replay, which is exactly the point.)"""
    import jax

    plancache.reset_cache()
    pipe = ReconstructionPipeline(backend="jnp", fused=fused)
    ks = _keyset(rng, 700)
    meta = meta_from_keys(ks.words)
    pipe.run(ks, meta=meta)  # cold: traces + commits the pad constants
    traced = plancache.get_cache().stats()["traces"]

    calls = {"concatenate": 0, "full": 0}
    real_concat, real_full = jnp.concatenate, jnp.full

    def counting_concat(*a, **k):
        if not isinstance(jnp.zeros(()), jax.core.Tracer):
            calls["concatenate"] += 1
        return real_concat(*a, **k)

    def counting_full(*a, **k):
        calls["full"] += 1
        return real_full(*a, **k)

    monkeypatch.setattr(jnp, "concatenate", counting_concat)
    monkeypatch.setattr(jnp, "full", counting_full)

    ks2 = _keyset(rng, 690)  # same bucket, different n
    pipe.run(ks2, meta=meta)

    assert calls["concatenate"] == 0, "warm run dispatched eager concatenate"
    assert calls["full"] == 0, "warm run dispatched eager jnp.full"
    assert plancache.get_cache().stats()["traces"] == traced


# ---------------------------------------------------------------------------
# pad-content independence
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", ["jnp", "pallas"])
def test_sort_output_independent_of_pad_contents(rng, backend):
    """Bucket-shaped sort inputs with *different* garbage in the pad lanes
    must produce byte-identical first-n output rows."""
    be = get_backend(backend)
    n, w = 100, 2
    b = plancache.bucket_for("sort", n)
    keys = rng.integers(0, 2**32, size=(n, w), dtype=np.uint32)
    rows = np.arange(n, dtype=np.uint32)

    def padded(fill):
        kp = np.full((b, w), fill, np.uint32)
        rp = np.full((b,), fill & 0x7FFFFFFF, np.uint32)
        kp[:n], rp[:n] = keys, rows
        return jnp.asarray(kp), jnp.asarray(rp)

    outs = []
    for fill in (0, 0xDEADBEEF, 0xFFFFFFFF):
        kp, rp = padded(fill)
        sk, sr = be.sort(kp, rp, n_valid=n)
        outs.append((np.asarray(sk), np.asarray(sr)))
    for got_k, got_r in outs[1:]:
        np.testing.assert_array_equal(outs[0][0], got_k)
        np.testing.assert_array_equal(outs[0][1], got_r)


def test_build_tree_independent_of_pad_contents(rng):
    """pk-windows (and every other build gather) must clip to the dynamic
    count: trees built from padded buffers with different pad garbage are
    byte-identical."""
    be = get_backend("jnp")
    ks = _keyset(rng, 300)
    meta = meta_from_keys(ks.words)
    pipe = ReconstructionPipeline(backend="jnp")
    res = pipe.run(ks, meta=meta)
    n = ks.n
    b = plancache.bucket_for("sort", n)

    comp = np.asarray(res.comp_sorted)
    rowp = np.asarray(res.row_sorted)
    words = np.asarray(ks.words, np.uint32)

    trees = []
    for fill in (0, 0xA5A5A5A5):
        comp_p = np.full((b, comp.shape[1]), fill, np.uint32)
        row_p = np.full((b,), fill & 0x7FFFFFFF, np.uint32)
        words_p = np.full((b, words.shape[1]), fill, np.uint32)
        comp_p[:n], row_p[:n], words_p[:n] = comp, rowp, words
        trees.append(
            be.build(
                jnp.asarray(comp_p), jnp.asarray(row_p), meta,
                jnp.asarray(words_p), jnp.asarray(ks.lengths, jnp.int32),
                pipe.config, rids=jnp.asarray(ks.rids, jnp.uint32), n_valid=n,
            )
        )
    a, c = trees
    np.testing.assert_array_equal(np.asarray(a.sorted_full), np.asarray(c.sorted_full))
    np.testing.assert_array_equal(np.asarray(a.sorted_rids), np.asarray(c.sorted_rids))
    for fname in ("rid", "pk", "dpos", "klen", "valid"):
        np.testing.assert_array_equal(
            np.asarray(a.leaf[fname]), np.asarray(c.leaf[fname]), err_msg=fname
        )
    assert len(a.levels) == len(c.levels)
    for la, lc in zip(a.levels, c.levels):
        for fname in ("child", "hi", "pk", "dpos", "klen"):
            np.testing.assert_array_equal(
                np.asarray(la[fname]), np.asarray(lc[fname]), err_msg=fname
            )


def test_lookup_miss_normalization_independent_of_pad_contents(rng):
    """The cached lookup program normalizes its pad lanes in-program:
    calling it with zero-filled pads instead of the all-ones pads the
    wrapper uses must not change any real lane — found flags, hit rids,
    and miss-lane NOT_FOUND_RID normalization included."""
    from repro.core.btree import NOT_FOUND_RID, lookup_batch_planned

    plancache.reset_cache()
    ks = _keyset(rng, 500)
    meta = meta_from_keys(ks.words)
    res = ReconstructionPipeline(backend="jnp").run(ks, meta=meta)

    q = 100
    queries = np.asarray(ks.words[:q], np.uint32).copy()
    queries[::3] ^= 0x1  # a mix of hits and misses
    queries_j = jnp.asarray(queries)

    f1, r1 = lookup_batch_planned(res.tree, queries_j, backend_name="jnp")
    assert np.all(np.asarray(r1)[~np.asarray(f1)] == NOT_FOUND_RID)

    b = plancache.bucket_for("lookup", q)
    prog = plancache.get_cache().programs[("lookup", "jnp", b, ks.n_words)]
    qp = np.zeros((b, ks.n_words), np.uint32)  # zero pads, not all-ones
    qp[:q] = queries
    f2, r2 = prog(res.tree, jnp.asarray(qp), np.uint32(q))
    np.testing.assert_array_equal(np.asarray(f1), np.asarray(f2[:q]))
    np.testing.assert_array_equal(np.asarray(r1), np.asarray(r2[:q]))


# ---------------------------------------------------------------------------
# per-op bucket floors
# ---------------------------------------------------------------------------


def test_bucket_floor_per_op_override():
    assert plancache.bucket_for("lookup", 10) == plancache.BUCKET_MIN
    try:
        plancache.set_bucket_floor("lookup", 32)
        assert plancache.bucket_for("lookup", 10) == 32
        assert plancache.bucket_for("lookup", 33) == 64
        # other ops keep the default floor
        assert plancache.bucket_for("sort", 10) == plancache.BUCKET_MIN
        assert plancache.get_bucket_floor("lookup") == 32
    finally:
        plancache.set_bucket_floor("lookup", None)
    assert plancache.bucket_for("lookup", 10) == plancache.BUCKET_MIN


def test_bucket_floor_lowered_lookup_still_correct(rng):
    """Lowering the lookup floor changes the program bucket, not answers."""
    plancache.reset_cache()
    ks = _keyset(rng, 300)
    meta = meta_from_keys(ks.words)
    res = ReconstructionPipeline(backend="jnp").run(ks, meta=meta)
    queries = jnp.asarray(ks.words[:20], jnp.uint32)
    be = get_backend("jnp")
    f_ref, r_ref = be.lookup(res.tree, queries)
    try:
        plancache.set_bucket_floor("lookup", 32)
        plancache.reset_cache()
        f_lo, r_lo = be.lookup(res.tree, queries)
    finally:
        plancache.set_bucket_floor("lookup", None)
        plancache.reset_cache()
    np.testing.assert_array_equal(np.asarray(f_ref), np.asarray(f_lo))
    np.testing.assert_array_equal(np.asarray(r_ref), np.asarray(r_lo))

    rejected = plancache.set_bucket_floor
    with pytest.raises(ValueError):
        rejected("lookup", 0)


# ---------------------------------------------------------------------------
# LRU auto-sizing
# ---------------------------------------------------------------------------


def test_plancache_auto_size_grows_on_thrash():
    cache = plancache.PlanCache(
        max_programs=2, auto_size=True, auto_size_window=8, auto_size_cap=16
    )
    # 4 distinct hot programs against a bound of 2: every window closes
    # with evictions and a ~0 hit rate -> the bound must double
    for _ in range(8):
        for k in range(4):
            cache.program(("op", k), lambda: (lambda: None))
    assert cache.resizes >= 1
    assert cache.max_programs > 2
    # stats() keeps its exact shape (the zero-retrace tests diff it)
    assert set(cache.stats()) == {
        "programs", "hits", "misses", "traces", "evictions", "max_programs",
        "per_op",
    }


def test_plancache_auto_size_respects_cap():
    cache = plancache.PlanCache(
        max_programs=2, auto_size=True, auto_size_window=4, auto_size_cap=4
    )
    for _ in range(32):
        for k in range(8):
            cache.program(("op", k), lambda: (lambda: None))
    assert cache.max_programs == 4  # capped


def test_plancache_auto_size_no_growth_without_evictions():
    """A merely *cold* cache (low hit rate, no evictions) must not grow."""
    cache = plancache.PlanCache(
        max_programs=64, auto_size=True, auto_size_window=4
    )
    for k in range(16):
        cache.program(("op", k), lambda: (lambda: None))
    assert cache.resizes == 0
    assert cache.max_programs == 64


@pytest.fixture(scope="module")
def rng():
    return np.random.default_rng(42)
