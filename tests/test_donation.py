"""Buffer-donation contract: donated operands are consumed (zero-copy
chaining), outputs stay byte-identical to the undonated programs for any
pad content, donated/undonated program variants coexist in the plan cache
without retracing, and the donating cascade's live footprint is bounded
by the ladder depth.

Everything here is gated on :func:`repro.core.plancache.donation_supported`
— on platforms where XLA rejects donation the flag is a silent no-op and
these tests skip.
"""

import math

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import plancache
from repro.core.keyformat import KeySet
from repro.core.metadata import meta_from_keys
from repro.core.pipeline import ReconstructionPipeline

pytestmark = pytest.mark.skipif(
    not plancache.donation_supported(),
    reason="platform does not support buffer donation",
)


def _keyset(rng, n, w=3, mask=0x0FFF00FF):
    words = rng.integers(0, 2**32, size=(n, w), dtype=np.uint32) & np.uint32(mask)
    rids = np.arange(n, dtype=np.uint32)
    rng.shuffle(rids)
    return KeySet(words=words, lengths=np.full(n, w * 4, np.int32), rids=rids)


@pytest.fixture(scope="module")
def rng():
    return np.random.default_rng(23)


@pytest.fixture()
def backend():
    from repro.backends import get_backend

    return get_backend("jnp")


def test_donated_sort_consumes_bucket_shaped_input(rng, backend):
    """A bucket-shaped key buffer donated to the sort is deleted after
    dispatch — the program took ownership."""
    b = 1024
    keys = jnp.asarray(rng.integers(0, 2**32, size=(b, 2), dtype=np.uint32))
    out = backend.sort(keys, plancache.iota_u32(b), n_valid=b,
                       keep_padded=True, donate=True)
    out[0].block_until_ready()
    assert keys.is_deleted()


def test_donated_merge_byte_identical_and_constants_survive(rng, backend):
    """Donating both merge runs changes nothing observable: XLA can't
    alias the half-size inputs into the double-size output (they stay
    live until their Python refs drop — the ladder's job), the output is
    byte-identical, and the cached iota constant is untouched."""
    c = 512
    keys = jnp.asarray(rng.integers(0, 2**32, size=(c, 2), dtype=np.uint32))

    def runs():
        ka, ra = backend.sort(keys[: c // 2], plancache.iota_u32(c // 2),
                              n_valid=c // 2, keep_padded=True)
        kb, rb = backend.sort(keys[c // 2 :], plancache.iota_u32(c // 2),
                              n_valid=c // 2, keep_padded=True)
        return ka, ra, kb, rb + jnp.uint32(c // 2)

    ka, ra, kb, rb = runs()
    mk, mr = backend.merge_sorted(
        ka, ra, kb, rb, n_valid_a=c // 2, n_valid_b=c // 2,
        keep_padded=True, donate=True,
    )
    mk.block_until_ready()
    ka2, ra2, kb2, rb2 = runs()
    rk, rr = backend.merge_sorted(
        ka2, ra2, kb2, rb2, n_valid_a=c // 2, n_valid_b=c // 2,
        keep_padded=True, donate=False,
    )
    np.testing.assert_array_equal(np.asarray(mk), np.asarray(rk))
    np.testing.assert_array_equal(np.asarray(mr), np.asarray(rr))
    # cached constants must never be donated: the iota is still usable
    assert not plancache.iota_u32(c // 2).is_deleted()


@pytest.mark.parametrize("fill", [0x00000000, 0xDEADBEEF, 0xFFFFFFFF])
def test_donated_sort_identical_for_any_pad_fill(rng, backend, fill):
    """Donation must not change results, whatever garbage sits in the pad
    lanes: the programs renormalize pads from ``n_valid``."""
    n, b, w = 1000, 1024, 3
    body = rng.integers(0, 2**32, size=(n, w), dtype=np.uint32)
    padded = np.full((b, w), fill, np.uint32)
    padded[:n] = body
    ref_k, ref_r = backend.sort(jnp.asarray(body), plancache.iota_u32(n))
    don_k, don_r = backend.sort(
        jnp.asarray(padded), plancache.iota_u32(b), n_valid=n, donate=True
    )
    np.testing.assert_array_equal(np.asarray(don_k), np.asarray(ref_k))
    np.testing.assert_array_equal(np.asarray(don_r), np.asarray(ref_r))


def test_donated_and_undonated_variants_coexist_warm(rng, backend):
    """The donate flag is part of the program key: both variants compile
    once, then replay with zero retraces."""
    b = 1024
    cache = plancache.get_cache()

    def fresh():
        return jnp.asarray(rng.integers(0, 2**32, size=(b, 2), dtype=np.uint32))

    for donate in (False, True, False, True):
        backend.sort(fresh(), plancache.iota_u32(b), n_valid=b, donate=donate)
    warm0 = cache.stats()["traces"]
    for donate in (False, True):
        backend.sort(fresh(), plancache.iota_u32(b), n_valid=b, donate=donate)
    assert cache.stats()["traces"] == warm0


def test_pipeline_donate_byte_identical(rng):
    """End-to-end: donate=True reproduces the undonated pipeline bit for
    bit on the monolithic, chunked, and full-keys paths."""
    ks = _keyset(rng, 3000)
    meta = meta_from_keys(ks.words)
    ref = ReconstructionPipeline("jnp").run(ks, meta=meta)
    for kw in (
        dict(donate=True),
        dict(donate=True, chunk_threshold=1024, chunk_size=512),
    ):
        res = ReconstructionPipeline("jnp", **kw).run(ks, meta=meta)
        np.testing.assert_array_equal(
            np.asarray(res.comp_sorted), np.asarray(ref.comp_sorted)
        )
        np.testing.assert_array_equal(
            np.asarray(res.rid_sorted), np.asarray(ref.rid_sorted)
        )
        np.testing.assert_array_equal(
            np.asarray(res.tree.sorted_full), np.asarray(ref.tree.sorted_full)
        )
    ref_fk = ReconstructionPipeline("jnp").run(ks, full_keys=True)
    res_fk = ReconstructionPipeline("jnp", donate=True).run(ks, full_keys=True)
    np.testing.assert_array_equal(
        np.asarray(res_fk.comp_sorted), np.asarray(ref_fk.comp_sorted)
    )


def test_cascade_donates_chunk_sorts_and_bounds_live_runs(rng):
    """The donating ladder sorts every chunk in place (bucket-shaped key
    slices alias their sorted outputs and are deleted), does exactly
    ``n_chunks - 1`` merges, and keeps at most O(log n_chunks) runs live
    at once — the ``cascade_peak_live_runs`` stat records the peak."""
    ks = _keyset(rng, 9 * 512 + 37)  # ragged chunk count exercises the tail fold
    meta = meta_from_keys(ks.words)
    pipe = ReconstructionPipeline(
        "jnp", donate=True, chunk_threshold=1024, chunk_size=512
    )
    sort_inputs, merge_calls = [], []
    orig_sort, orig_merge = pipe.backend.sort, pipe.backend.merge_sorted

    def spy_sort(keys, rows, **kw):
        if kw.get("donate"):
            sort_inputs.append(keys)
        return orig_sort(keys, rows, **kw)

    def spy_merge(ka, ra, kb, rb, **kw):
        merge_calls.append(kw)
        return orig_merge(ka, ra, kb, rb, **kw)

    pipe.backend.sort = spy_sort
    pipe.backend.merge_sorted = spy_merge
    try:
        res = pipe.run(ks, meta=meta)
    finally:
        pipe.backend.sort = orig_sort
        pipe.backend.merge_sorted = orig_merge

    n_chunks = res.stats["chunked"]
    assert n_chunks == -(-ks.n // 512)
    # every chunk's key slice was donated and aliased into its sorted
    # output (same bucket shape) — the zero-copy in-place sort
    assert len(sort_inputs) == n_chunks
    for keys in sort_inputs:
        assert keys.is_deleted()
    # a ladder does exactly n-1 merges, all flagged donated
    assert len(merge_calls) == n_chunks - 1
    assert all(kw.get("donate") for kw in merge_calls)
    assert res.stats["cascade_merges"] == n_chunks - 1
    assert res.stats["cascade_peak_live_runs"] <= int(math.log2(n_chunks)) + 2


def test_run_incremental_never_donates_previous_result(rng):
    """The incremental merge's base run is (a view of) the previous
    result; donation must leave it readable after the call."""
    ks = _keyset(rng, 2000)
    meta = meta_from_keys(ks.words)
    pipe = ReconstructionPipeline("jnp", donate=True)
    prev = pipe.run(ks, meta=meta)
    delta = _keyset(rng, 200)
    res, folded = pipe.run_incremental(prev, ks, delta)
    assert not prev.comp_sorted.is_deleted()
    assert not prev.row_sorted.is_deleted()
    sync = ReconstructionPipeline("jnp").run_incremental(prev, ks, delta)[0]
    np.testing.assert_array_equal(
        np.asarray(res.comp_sorted), np.asarray(sync.comp_sorted)
    )
    np.testing.assert_array_equal(
        np.asarray(res.rid_sorted), np.asarray(sync.rid_sorted)
    )
