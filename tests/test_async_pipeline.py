"""Sync-free stage chaining: ``async_dispatch=True`` moves the sync points
(one end-of-run barrier instead of one per stage) and must change nothing
else — results are bit-identical on every backend, on the chunked path,
and through ``run_incremental``; ``stage_timings=True`` restores the
per-stage barriers for one call when the Figure-9 breakdown is wanted.
"""

import numpy as np
import pytest

from repro.core.keyformat import KeySet
from repro.core.metadata import meta_from_keys
from repro.core.pipeline import ReconstructionPipeline


def _keyset(rng, n, w=3, mask=0x0FFF00FF):
    words = rng.integers(0, 2**32, size=(n, w), dtype=np.uint32) & np.uint32(mask)
    rids = np.arange(n, dtype=np.uint32)
    rng.shuffle(rids)
    return KeySet(words=words, lengths=np.full(n, w * 4, np.int32), rids=rids)


@pytest.fixture(scope="module")
def rng():
    return np.random.default_rng(31)


def _assert_results_equal(a, b):
    np.testing.assert_array_equal(np.asarray(a.comp_sorted), np.asarray(b.comp_sorted))
    np.testing.assert_array_equal(np.asarray(a.row_sorted), np.asarray(b.row_sorted))
    np.testing.assert_array_equal(np.asarray(a.rid_sorted), np.asarray(b.rid_sorted))
    np.testing.assert_array_equal(
        np.asarray(a.tree.sorted_full), np.asarray(b.tree.sorted_full)
    )
    assert a.tree.height == b.tree.height
    assert a.watermark == b.watermark


@pytest.mark.parametrize("backend", ["jnp", "pallas", "distributed"])
def test_async_bit_identical_to_sync(rng, backend):
    """Async dispatch only moves the barriers — every backend must return
    the exact result the per-stage-synced pipeline returns."""
    ks = _keyset(rng, 1500)
    meta = meta_from_keys(ks.words)
    res_s = ReconstructionPipeline(backend=backend).run(ks, meta=meta, watermark=7)
    res_a = ReconstructionPipeline(backend=backend, async_dispatch=True).run(
        ks, meta=meta, watermark=7
    )
    _assert_results_equal(res_s, res_a)
    assert res_a.stats["async_dispatch"] is True
    assert res_s.stats["async_dispatch"] is False


def test_async_chunked_bit_identical(rng):
    """The ladder cascade under async dispatch (deep in-flight program
    chains) still matches the synced monolithic run bit for bit."""
    ks = _keyset(rng, 2**12 + 5)
    meta = meta_from_keys(ks.words)
    res_s = ReconstructionPipeline("jnp", chunk_threshold=1 << 30).run(ks, meta=meta)
    res_a = ReconstructionPipeline(
        "jnp", async_dispatch=True, chunk_threshold=2048, chunk_size=1024
    ).run(ks, meta=meta)
    assert res_a.stats["chunked"] == -(-ks.n // 1024)
    _assert_results_equal(res_s, res_a)


def test_async_incremental_bit_identical(rng):
    """run_incremental under async dispatch matches its synced twin."""
    ks = _keyset(rng, 2000)
    meta = meta_from_keys(ks.words)
    sync_pipe = ReconstructionPipeline("jnp")
    async_pipe = ReconstructionPipeline("jnp", async_dispatch=True)
    prev = sync_pipe.run(ks, meta=meta)
    delta = _keyset(rng, 150)
    keep = np.ones(ks.n, bool)
    keep[::11] = False
    res_s, fold_s = sync_pipe.run_incremental(prev, ks, delta, keep_rows=keep)
    res_a, fold_a = async_pipe.run_incremental(prev, ks, delta, keep_rows=keep)
    _assert_results_equal(res_s, res_a)
    np.testing.assert_array_equal(fold_s.words, fold_a.words)
    assert res_a.stats["async_dispatch"] is True


def test_timings_contract(rng):
    """Every run reports a ``sync`` wall: zero under per-stage barriers,
    the end-of-run barrier's wall under async; ``stage_timings`` overrides
    the pipeline policy per call."""
    ks = _keyset(rng, 800)
    meta = meta_from_keys(ks.words)
    pipe = ReconstructionPipeline("jnp", async_dispatch=True)

    res = pipe.run(ks, meta=meta)
    assert res.timings["sync"] >= 0.0
    assert res.timings["total"] > 0.0

    # stage_timings=True restores the barriers for this call only
    res_t = pipe.run(ks, meta=meta, stage_timings=True)
    assert res_t.stats["async_dispatch"] is False
    assert res_t.timings["sync"] == 0.0
    assert all(
        k in res_t.timings
        for k in ("meta", "extract", "sort", "build", "refresh_meta", "sync", "total")
    )

    # ...and stage_timings=False forces async on a sync pipeline
    res_f = ReconstructionPipeline("jnp").run(ks, meta=meta, stage_timings=False)
    assert res_f.stats["async_dispatch"] is True

    prev = pipe.run(ks, meta=meta)
    res_i, _ = pipe.run_incremental(prev, ks, None, watermark=3)
    assert "sync" in res_i.timings  # the no-op short-circuit keeps the key
