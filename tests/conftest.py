"""Shared fixtures.  NOTE: no XLA device-count flags here — smoke tests and
benches must see 1 device; multi-device tests spawn subprocesses."""

import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


def random_masked_words(rng, n, w, mask=None, seed_offset=0):
    """Random keys with limited variant bit positions (realistic tables)."""
    if mask is None:
        mask = rng.integers(0, 2**32, size=w, dtype=np.uint32)
    return rng.integers(0, 2**32, size=(n, w), dtype=np.uint32) & np.asarray(
        mask, np.uint32
    )
