"""Chunked large-N sort path: byte-identity vs the monolithic sort on
every backend, boundary sizes (2^k - 1, 2^k, 2^k + 1), cascade retrace
stability, and the run_many batched grouping under per-op floors.

The chunk sizes here are scaled far below the production defaults
(``chunk_threshold=1<<19``) so the cascade runs in test time; the code
path is identical — only the constants differ.
"""

import numpy as np
import pytest

from repro.core import plancache
from repro.core.keyformat import KeySet
from repro.core.metadata import meta_from_keys
from repro.core.pipeline import ReconstructionPipeline


def _keyset(rng, n, w=3, mask=0x0FFF00FF):
    words = rng.integers(0, 2**32, size=(n, w), dtype=np.uint32) & np.uint32(mask)
    rids = np.arange(n, dtype=np.uint32)
    rng.shuffle(rids)
    return KeySet(words=words, lengths=np.full(n, w * 4, np.int32), rids=rids)


@pytest.fixture(scope="module")
def rng():
    return np.random.default_rng(11)


def _assert_results_equal(a, b):
    np.testing.assert_array_equal(np.asarray(a.comp_sorted), np.asarray(b.comp_sorted))
    np.testing.assert_array_equal(np.asarray(a.row_sorted), np.asarray(b.row_sorted))
    np.testing.assert_array_equal(np.asarray(a.rid_sorted), np.asarray(b.rid_sorted))
    np.testing.assert_array_equal(
        np.asarray(a.tree.sorted_full), np.asarray(b.tree.sorted_full)
    )
    assert a.tree.height == b.tree.height


@pytest.mark.parametrize("backend", ["jnp", "pallas", "distributed"])
@pytest.mark.parametrize("n", [2**12 - 1, 2**12, 2**12 + 1])
def test_chunked_byte_identical_to_monolithic(rng, backend, n):
    """The cascade fold must reproduce the monolithic sort bit-for-bit at
    the awkward boundary sizes (last chunk of 1, exact tiling, one short)
    on all three backends."""
    ks = _keyset(rng, n)
    meta = meta_from_keys(ks.words)
    mono = ReconstructionPipeline(backend=backend, chunk_threshold=1 << 30)
    chunked = ReconstructionPipeline(
        backend=backend, chunk_threshold=2048, chunk_size=1024
    )
    res_m = mono.run(ks, meta=meta)
    res_c = chunked.run(ks, meta=meta)
    assert res_m.stats["chunked"] == 0
    assert res_c.stats["chunked"] == -(-n // 1024)
    _assert_results_equal(res_m, res_c)


def test_chunked_full_keys_baseline(rng):
    """The uncompressed baseline takes the chunked path too."""
    n = 3000
    ks = _keyset(rng, n)
    mono = ReconstructionPipeline(backend="jnp", chunk_threshold=1 << 30)
    chunked = ReconstructionPipeline(
        backend="jnp", chunk_threshold=1024, chunk_size=512
    )
    res_m = mono.run(ks, full_keys=True)
    res_c = chunked.run(ks, full_keys=True)
    assert res_c.stats["chunked"] == -(-n // 512)
    _assert_results_equal(res_m, res_c)


def test_chunked_warm_zero_retrace(rng):
    """A warm chunked rebuild replays entirely from the program cache:
    chunk sorts, cascade merges, build levels, refresh — zero traces."""
    plancache.reset_cache()
    pipe = ReconstructionPipeline(
        backend="jnp", chunk_threshold=2048, chunk_size=1024
    )
    ks = _keyset(rng, 5000)
    meta = meta_from_keys(ks.words)
    pipe.run(ks, meta=meta)
    traced = plancache.get_cache().stats()["traces"]
    pipe.run(_keyset(rng, 5000), meta=meta)  # same n -> same chunking
    pipe.run(_keyset(rng, 4993), meta=meta)  # same buckets, drifted n
    assert plancache.get_cache().stats()["traces"] == traced


def test_chunk_size_must_be_power_of_two():
    with pytest.raises(ValueError, match="power of two"):
        ReconstructionPipeline(chunk_size=1000)


def test_chunked_preserves_tree_queries(rng):
    """End-to-end: lookups against a chunked-path tree answer exactly as
    against the monolithic tree."""
    from repro.backends import get_backend
    import jax.numpy as jnp

    n = 2**12 + 5
    ks = _keyset(rng, n)
    meta = meta_from_keys(ks.words)
    res = ReconstructionPipeline(
        backend="jnp", chunk_threshold=2048, chunk_size=1024
    ).run(ks, meta=meta)
    be = get_backend("jnp")
    queries = jnp.asarray(ks.words[:64], jnp.uint32)
    found, rid = be.lookup(res.tree, queries)
    assert bool(np.all(np.asarray(found)))
    np.testing.assert_array_equal(np.asarray(rid), np.asarray(ks.rids[:64]))


def test_distributed_batched_extract_sort_sharded_subprocess():
    """run_many's batch axis shards across the mesh: the sharded batched
    program must reproduce the per-index jnp results exactly."""
    import subprocess
    import sys
    import textwrap
    from pathlib import Path

    src = str(Path(__file__).resolve().parent.parent / "src")
    import os

    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = src
    code = textwrap.dedent("""
        import numpy as np
        from repro.core.keyformat import KeySet
        from repro.core.pipeline import ReconstructionPipeline
        rng = np.random.default_rng(3)
        def ks_of(seed, n=600, w=3):
            r = np.random.default_rng(seed)
            words = r.integers(0, 2**32, size=(n, w), dtype=np.uint32) & np.uint32(0x00FF0F0F)
            rids = np.arange(n, dtype=np.uint32); r.shuffle(rids)
            return KeySet(words=words, lengths=np.full(n, w * 4, np.int32), rids=rids)
        keysets = [ks_of(s) for s in range(8)]  # 8 % 4 == 0 -> sharded path
        dist = ReconstructionPipeline(backend="distributed")
        ref = ReconstructionPipeline(backend="jnp")
        outs = dist.run_many(keysets)
        refs = [ref.run(k) for k in keysets]
        assert all(o.stats.get("batched") == 8 for o in outs), [o.stats.get("batched") for o in outs]
        for o, r in zip(outs, refs):
            np.testing.assert_array_equal(np.asarray(o.comp_sorted), np.asarray(r.comp_sorted))
            np.testing.assert_array_equal(np.asarray(o.rid_sorted), np.asarray(r.rid_sorted))
        print("SHARDED RUN_MANY OK")
    """)
    r = subprocess.run(
        [sys.executable, "-c", code],
        capture_output=True, text=True, env=env, timeout=600,
    )
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    assert "SHARDED RUN_MANY OK" in r.stdout


def test_chunk_sorts_stay_bucket_shaped_with_tail_n_valid(rng):
    """Every chunk — the ragged tail included — feeds the sort a full
    chunk-bucket-shaped slice plus a dynamic ``n_valid``, so the tail
    replays the same cached program instead of eagerly slicing to its
    ragged length and re-padding (the extra copy the valid-count operand
    exists to avoid)."""
    c = 512
    ks = _keyset(rng, 3 * c + 37)
    meta = meta_from_keys(ks.words)
    pipe = ReconstructionPipeline("jnp", chunk_threshold=1024, chunk_size=c)
    calls = []
    orig = pipe.backend.sort

    def spy(keys, rows, **kw):
        calls.append((int(keys.shape[0]), kw.get("n_valid"), kw.get("keep_padded")))
        return orig(keys, rows, **kw)

    pipe.backend.sort = spy
    try:
        res = pipe.run(ks, meta=meta)
    finally:
        pipe.backend.sort = orig
    assert res.stats["chunked"] == 4
    assert calls == [(c, c, True)] * 3 + [(c, 37, True)]


def test_tune_chunking_measures_and_persists(rng):
    """tune_chunking probes inside a throwaway scoped cache (the serving
    cache's programs and counters stay untouched — the bench's cold walls
    must stay honest), returns a sane plan, and the pipeline adopts and
    surfaces it."""
    pipe = ReconstructionPipeline("jnp")
    before = plancache.get_cache().stats()
    plan = pipe.tune_chunking(candidates=(256, 512), ref_n=1 << 13, iters=2)
    assert plancache.get_cache().stats() == before

    assert plan.backend == "jnp"
    assert plan.chunk_size in (256, 512)
    assert plan.chunk_threshold >= 2 * plan.chunk_size or (
        plan.chunk_threshold == plan.ref_n
    )
    assert set(plan.sort_warm) == {256, 512}
    assert all(v > 0 for v in plan.sort_cold.values())

    assert pipe.chunk_size == plan.chunk_size
    assert pipe.chunk_threshold == plan.chunk_threshold
    assert pipe.chunk_plan is plan

    ks = _keyset(rng, 700)
    res = pipe.run(ks)
    assert res.stats["chunk_tuned"] is True
    assert res.stats["chunk_size"] == plan.chunk_size
    assert res.stats["chunk_threshold"] == plan.chunk_threshold


def test_auto_tune_triggers_lazily(rng):
    """auto_tune_chunks calibrates on the first run that crosses the
    threshold, once; the adopted plan governs the run that triggered it."""
    pipe = ReconstructionPipeline(
        "jnp", auto_tune_chunks=True, chunk_threshold=1024, chunk_size=512
    )
    small = _keyset(rng, 600)
    pipe.run(small)
    assert pipe.chunk_plan is None  # below threshold: no probe

    calls = []
    orig = pipe.tune_chunking

    def spy(**kw):
        calls.append(kw)
        return orig(candidates=(256, 512), ref_n=1 << 13)

    pipe.tune_chunking = spy
    try:
        big = _keyset(rng, 2048)
        res1 = pipe.run(big)
        res2 = pipe.run(big)
    finally:
        pipe.tune_chunking = orig
    assert len(calls) == 1  # calibrated once, then reused
    assert pipe.chunk_plan is not None
    assert res1.stats["chunk_tuned"] and res2.stats["chunk_tuned"]
    ref = ReconstructionPipeline("jnp").run(big)
    np.testing.assert_array_equal(
        np.asarray(res1.comp_sorted), np.asarray(ref.comp_sorted)
    )
