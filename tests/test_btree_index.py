"""B-tree construction/search/online-mutation tests (§4.2-4.3, §5.3)."""

import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # property tests need the dev extra
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.btree import BTreeConfig, search_batch, search_batch_partial
from repro.core.index import OnlineIndex
from repro.core.keyformat import KeySet, encode_int32, encode_varchar, encode_multicolumn, keys_to_words
from repro.core.metadata import meta_from_keys, meta_on_insert
from repro.core.reconstruct import full_key_reconstruct, reconstruct_index


def _make_keyset(rng, n=500, w=3, mask=0x0FFF0FFF):
    arr = np.unique(
        rng.integers(0, 2**32, size=(n, w), dtype=np.uint32) & np.uint32(mask), axis=0
    )
    rng.shuffle(arr)
    return KeySet(
        words=arr,
        lengths=np.full(len(arr), w * 4, np.int32),
        rids=np.arange(len(arr), dtype=np.uint32),
    )


def test_tree_geometry(rng):
    """Node geometry per §5.3: fanouts 14/9, fill 0.9 -> 12/8."""
    cfg = BTreeConfig()
    assert cfg.leaf_cap == 12 and cfg.nonleaf_cap == 8
    ks = _make_keyset(rng, 3000)
    res = reconstruct_index(ks)
    npl = res.tree.nodes_per_level()
    n = ks.n
    assert npl[-1] == -(-n // 12)
    for lvl in range(len(npl) - 1):
        assert npl[lvl] == -(-npl[lvl + 1] // 8)
    assert npl[0] == 1  # root


def test_search_hits_and_misses(rng):
    ks = _make_keyset(rng, 800)
    res = reconstruct_index(ks)
    q = jnp.asarray(ks.words)
    found, rid, pos = search_batch(res.tree, q)
    assert bool(found.all())
    assert (np.asarray(ks.words)[np.asarray(rid)] == np.asarray(ks.words)).all()
    # misses: flip low bits of existing keys to values not present
    missing = np.asarray(ks.words).copy()
    missing[:, -1] ^= np.uint32(0xF0000000)  # outside mask -> absent
    f2, _, _ = search_batch(res.tree, jnp.asarray(missing))
    assert not bool(f2.any())


def test_partial_key_search_equivalence(rng):
    ks = _make_keyset(rng, 1200)
    res = reconstruct_index(ks)
    q = jnp.asarray(ks.words)
    f1, r1, _ = search_batch(res.tree, q)
    f2, r2, nderef = search_batch_partial(res.tree, q)
    assert (np.asarray(f1) == np.asarray(f2)).all()
    assert (np.asarray(r1) == np.asarray(r2)).all()
    # partial keys screen to ~1 deref (vs leaf_cap full compares)
    assert float(np.asarray(nderef).mean()) < 3.0


def test_compressed_equals_full_reconstruction(rng):
    ks = _make_keyset(rng, 700)
    a = reconstruct_index(ks)
    b = full_key_reconstruct(ks)
    assert (np.asarray(a.rid_sorted) == np.asarray(b.rid_sorted)).all()
    assert a.tree.height == b.tree.height
    qa = search_batch(a.tree, jnp.asarray(ks.words))
    qb = search_batch(b.tree, jnp.asarray(ks.words))
    assert (np.asarray(qa[1]) == np.asarray(qb[1])).all()


def test_non_arange_record_ids(rng):
    """Record ids are labels, not row positions (rebuild-after-delete path)."""
    ks0 = _make_keyset(rng, 300)
    rids = rng.permutation(10_000)[: ks0.n].astype(np.uint32)
    ks = KeySet(words=ks0.words, lengths=ks0.lengths, rids=rids)
    res = reconstruct_index(ks)
    found, rid, _ = search_batch(res.tree, jnp.asarray(ks.words))
    assert bool(found.all())
    assert (np.asarray(rid) == rids).all()


def test_insert_delete_search_and_metadata(rng):
    ks = _make_keyset(rng, 400)
    oi = OnlineIndex.build(ks)
    meta0_bits = oi.meta.n_dbits
    new_key = (np.asarray(ks.words[0]) ^ np.uint32([0, 0, 0x40])).astype(np.uint32)
    oi.insert(new_key, rid=99999)
    assert oi.meta.n_dbits >= meta0_bits  # insert may add 1 position
    f, r = oi.search(new_key)
    assert f and r == 99999
    # delete: bitmap unchanged (lazy)
    bits_before = oi.meta.n_dbits
    assert oi.delete(np.asarray(ks.words[5]))
    assert oi.meta.n_dbits == bits_before
    f, _ = oi.search(np.asarray(ks.words[5]))
    assert not f


def test_rebuild_with_stale_bitmap_is_correct(rng):
    """Delete half the keys; D-bitmap keeps stale bits; rebuild with the
    stale bitmap still sorts/searches correctly (Theorem 2) and the rebuild
    sheds stale positions (§4.3)."""
    ks = _make_keyset(rng, 600)
    oi = OnlineIndex.build(ks)
    kill = [np.asarray(ks.words[i]) for i in range(0, 300)]
    for k in kill:
        assert oi.delete(k)
    stale_bits = oi.meta.n_dbits
    oi2 = oi.rebuild()
    assert oi2.meta.n_dbits <= stale_bits  # shed stale positions
    # correctness after rebuild
    for i in range(300, 350):
        f, rid = oi2.search(np.asarray(ks.words[i]))
        assert f and rid == i
    for k in kill[:25]:
        f, _ = oi2.search(k)
        assert not f


@given(st.integers(0, 2**20))
@settings(max_examples=20, deadline=None)
def test_insert_rule_lemma1(seed):
    """meta_on_insert sets exactly max(D(A,K), D(K,B)) (§4.3 insert)."""
    rng = np.random.default_rng(seed)
    arr = np.unique(
        rng.integers(0, 2**32, size=(50, 2), dtype=np.uint32) & np.uint32(0xFFF000FF),
        axis=0,
    )
    if len(arr) < 3:
        return
    meta = meta_from_keys(arr)
    # insert a key between two neighbors
    srt = arr[np.lexsort(arr.T[::-1])]
    a, b = srt[10], srt[11]
    k = a.copy()
    k[-1] ^= np.uint32(0x1)  # differs from a in the last bit
    if tuple(k) == tuple(b) or not (tuple(a) < tuple(k) < tuple(b)):
        return
    m2 = meta_on_insert(meta, a, k, b)
    from repro.core.metadata import _np_dbit

    expected = max(_np_dbit(a, k), _np_dbit(k, b))
    w, bit = expected // 32, 31 - expected % 32
    assert (int(m2.dbitmap[w]) >> bit) & 1 == 1


def test_multicolumn_index_end_to_end(rng):
    names = ["".join(chr(97 + int(c)) for c in rng.integers(0, 26, size=int(rng.integers(3, 10))))
             for _ in range(500)]
    keys = list(dict.fromkeys(
        encode_multicolumn([encode_int32(int(rng.integers(0, 40))), encode_varchar(nm, 15)])
        for nm in names
    ))
    ks = keys_to_words(keys)
    res = reconstruct_index(ks)
    found, _, _ = search_batch(res.tree, jnp.asarray(ks.words))
    assert bool(found.all())
    assert res.stats["compression_ratio"] > 1.5
