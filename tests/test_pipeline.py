"""Backend-parity + pipeline-layer tests.

The determinism contract (repro.backends.base): every backend sorts by the
(key, row) pair, so the sorted compressed keys and rid permutations must be
*byte-identical* across ``jnp``, ``pallas`` (interpret) and ``distributed``
(1- and 4-device CPU meshes in subprocesses) — including on duplicate-heavy
keysets with non-identity rids, where instability or tie mishandling would
show immediately.
"""

import subprocess
import sys
import textwrap
from pathlib import Path

import jax.numpy as jnp
import numpy as np
import pytest

from repro.backends import available_backends, get_backend, register_backend
from repro.backends.base import ExecutionBackend
from repro.core import compress as C
from repro.core import dbits as D
from repro.core.keyformat import KeySet
from repro.core.pipeline import ReconstructionPipeline
from repro.core.sortkeys import word_comparison_counts

SRC = str(Path(__file__).resolve().parents[1] / "src")


def _keyset(rng, n=3000, w=3, mask=0x00FF0F0F, shuffle_rids=True) -> KeySet:
    """Duplicate-heavy keys (small mask) with non-identity rids."""
    words = rng.integers(0, 2**32, size=(n, w), dtype=np.uint32) & np.uint32(mask)
    rids = np.arange(n, dtype=np.uint32)
    if shuffle_rids:
        rng.shuffle(rids)
    return KeySet(words=words, lengths=np.full(n, w * 4, np.int32), rids=rids)


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------


def test_registry_lists_all_three_backends():
    assert {"jnp", "pallas", "distributed"} <= set(available_backends())


def test_registry_unknown_backend_raises():
    with pytest.raises(KeyError):
        get_backend("no-such-backend")


def test_registry_custom_backend_roundtrip():
    from repro.backends.base import _REGISTRY

    try:
        @register_backend("_test_echo")
        class EchoBackend(ExecutionBackend):
            def extract(self, words, plan):
                return jnp.asarray(words, jnp.uint32)

            def sort(self, keys, rows):
                return keys, rows

        be = get_backend("_test_echo")
        assert be.name == "_test_echo"
        assert "_test_echo" in available_backends()
    finally:
        # keep the process-global registry clean: other tests (and the
        # benchmarks) iterate available_backends()
        _REGISTRY.pop("_test_echo", None)


# ---------------------------------------------------------------------------
# backend parity (single-process: jnp vs pallas-interpret vs fused)
# ---------------------------------------------------------------------------


def test_backend_parity_jnp_pallas(rng):
    ks = _keyset(rng)
    ref = ReconstructionPipeline(backend="jnp").run(ks)
    pal = ReconstructionPipeline(
        backend="pallas", backend_opts={"interpret": True}
    ).run(ks)
    np.testing.assert_array_equal(
        np.asarray(ref.comp_sorted), np.asarray(pal.comp_sorted)
    )
    np.testing.assert_array_equal(
        np.asarray(ref.rid_sorted), np.asarray(pal.rid_sorted)
    )
    np.testing.assert_array_equal(
        np.asarray(ref.row_sorted), np.asarray(pal.row_sorted)
    )
    assert pal.stats["backend"] == "pallas"


def test_fused_matches_staged(rng):
    ks = _keyset(rng, n=2000)
    staged = ReconstructionPipeline(backend="jnp", fused=False).run(ks)
    fused = ReconstructionPipeline(backend="jnp", fused=True).run(ks)
    np.testing.assert_array_equal(
        np.asarray(staged.comp_sorted), np.asarray(fused.comp_sorted)
    )
    np.testing.assert_array_equal(
        np.asarray(staged.rid_sorted), np.asarray(fused.rid_sorted)
    )
    assert fused.stats["fused"] and not staged.stats["fused"]


def test_distributed_parity_single_device(rng):
    """p=1 mesh in-process: the distributed wrapper (pad, capacity buckets,
    valid-mask compaction) must be an identity over the jnp order."""
    ks = _keyset(rng, n=1999)  # deliberately not divisible by anything
    ref = ReconstructionPipeline(backend="jnp").run(ks)
    dist = ReconstructionPipeline(backend="distributed").run(ks)
    np.testing.assert_array_equal(
        np.asarray(ref.comp_sorted), np.asarray(dist.comp_sorted)
    )
    np.testing.assert_array_equal(
        np.asarray(ref.rid_sorted), np.asarray(dist.rid_sorted)
    )
    assert dist.stats["overflow"] == 0


# ---------------------------------------------------------------------------
# backend parity (subprocess: 4-device CPU mesh)
# ---------------------------------------------------------------------------


def _run_subprocess(code: str, devices: int):
    import os

    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC
    r = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, env=env, timeout=600,
    )
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    return r.stdout


@pytest.mark.parametrize("devices", [1, 4])
def test_distributed_parity_mesh_subprocess(devices):
    out = _run_subprocess(f"""
        import numpy as np
        from repro.core.keyformat import KeySet
        from repro.core.pipeline import ReconstructionPipeline
        rng = np.random.default_rng(7)
        n, w = 4096, 3
        words = rng.integers(0, 2**32, size=(n, w), dtype=np.uint32) & np.uint32(0x00FF0F0F)
        rids = np.arange(n, dtype=np.uint32); rng.shuffle(rids)
        ks = KeySet(words=words, lengths=np.full(n, w * 4, np.int32), rids=rids)
        ref = ReconstructionPipeline(backend="jnp").run(ks)
        dist = ReconstructionPipeline(
            backend="distributed",
            backend_opts={{"capacity_factor": 2.0}},
        ).run(ks)
        assert dist.stats["mesh_devices"] == {devices}
        np.testing.assert_array_equal(
            np.asarray(ref.comp_sorted), np.asarray(dist.comp_sorted))
        np.testing.assert_array_equal(
            np.asarray(ref.rid_sorted), np.asarray(dist.rid_sorted))
        print("MESH PARITY OK", dist.stats["mesh_devices"])
    """, devices)
    assert "MESH PARITY OK" in out


def test_distsort_overflow_reported_and_retried():
    """Skewed keys + tiny capacity: the kernel must *report* overflow (never
    silently drop) and the backend must retry to an overflow-free run."""
    out = _run_subprocess("""
        import numpy as np, jax.numpy as jnp
        from repro.backends import get_backend
        from repro.core.distsort import sample_sort
        from repro.compat import make_mesh
        rng = np.random.default_rng(0)
        n = 4 * 1024
        # heavy skew: nearly all keys in one bucket
        words = np.zeros((n, 2), dtype=np.uint32)
        words[: n - 8, 1] = 1
        words[n - 8:, 0] = rng.integers(1, 2**31, 8).astype(np.uint32)
        rows = jnp.arange(n, dtype=jnp.uint32)
        mesh = make_mesh((4,), ("data",))
        res = sample_sort(jnp.asarray(words), rows, mesh, "data",
                          capacity_factor=0.5)
        assert int(res.overflow) > 0, "expected reported overflow"
        be = get_backend("distributed", mesh=mesh, capacity_factor=0.5)
        sk, sr = be.sort(jnp.asarray(words), rows)
        assert be.last_info["overflow"] == 0
        assert be.last_info["capacity_retries"] >= 1
        assert sk.shape[0] == n
        # correctness after retry: matches the oracle order
        from repro.core.dbits import sort_words
        ref_k, ref_r = sort_words(jnp.asarray(words), rows)
        np.testing.assert_array_equal(np.asarray(sk), np.asarray(ref_k))
        np.testing.assert_array_equal(np.asarray(sr), np.asarray(ref_r))
        print("OVERFLOW PATH OK", be.last_info)
    """, devices=4)
    assert "OVERFLOW PATH OK" in out


def test_sort_contract_nonascending_rows(rng):
    """The (key, row) contract must hold for any distinct row positions,
    not just ascending ones: ties break on the row *value*."""
    n = 1024
    keys = (rng.integers(0, 4, size=(n, 2), dtype=np.uint32))  # massive ties
    rows = np.arange(n, dtype=np.uint32)
    rng.shuffle(rows)
    want = None
    for name in ("jnp", "pallas", "distributed"):
        sk, sr = get_backend(name).sort(jnp.asarray(keys), jnp.asarray(rows))
        got = np.concatenate([np.asarray(sk), np.asarray(sr)[:, None]], axis=1)
        if want is None:
            # oracle: numpy lexsort over (key words, row)
            order = np.lexsort(
                tuple(np.concatenate([keys, rows[:, None]], axis=1).T[::-1])
            )
            want = np.concatenate([keys[order], rows[order][:, None]], axis=1)
        np.testing.assert_array_equal(got, want, err_msg=name)


def test_distributed_rejects_out_of_range_rows(rng):
    be = get_backend("distributed")
    keys = jnp.asarray(rng.integers(0, 2**32, size=(17, 2), dtype=np.uint32))
    rows = jnp.asarray(np.arange(100, 117, dtype=np.uint32))  # >= n
    with pytest.raises(ValueError, match="row positions"):
        be.sort(keys, rows)


def test_all_duplicate_keys_every_backend(rng):
    """Degenerate keyset (all keys identical, empty D-bitmap): the one-bit
    plan convention must carry through d_offset into the build on every
    backend (regression: empty d_offset crashed build_btree)."""
    from repro.core.btree import search_batch
    from repro.core.keyformat import keys_to_words, encode_int32

    ks = keys_to_words([encode_int32(7)] * 16)
    ref = None
    for name in ("jnp", "pallas", "distributed"):
        res = ReconstructionPipeline(backend=name).run(ks)
        assert res.stats["distinction_bits"] == 0
        found, rid, _ = search_batch(res.tree, jnp.asarray(ks.words[:1]))
        assert bool(found[0])
        if ref is None:
            ref = np.asarray(res.rid_sorted)
        np.testing.assert_array_equal(np.asarray(res.rid_sorted), ref)


# ---------------------------------------------------------------------------
# extraction equivalence + stats regressions
# ---------------------------------------------------------------------------


def test_extract_dynamic_matches_static(rng):
    for w in (1, 3, 5):
        words = rng.integers(0, 2**32, size=(500, w), dtype=np.uint32) & np.uint32(
            0x0F0F00FF
        )
        bm = D.compute_dbitmap(jnp.asarray(words))
        plan = C.make_plan(np.asarray(bm), w)
        static = C.extract_bits(jnp.asarray(words), plan)
        dynamic = C.extract_bits_dynamic(
            jnp.asarray(words), jnp.asarray(np.asarray(bm)), plan.n_words_out
        )
        np.testing.assert_array_equal(np.asarray(static), np.asarray(dynamic))


def test_wcc_full_uses_row_permutation(rng):
    """Regression: wcc_full must be computed over the row-permuted table,
    not rid-indexed (wrong whenever rids are not the identity)."""
    ks = _keyset(rng, n=1500, shuffle_rids=True)
    res = ReconstructionPipeline(backend="jnp").run(ks)
    expect = float(
        word_comparison_counts(jnp.asarray(ks.words)[np.asarray(res.row_sorted)])
    )
    assert res.stats["wcc_full"] == pytest.approx(expect)
    # sanity: the row permutation actually sorts the full keys
    full_sorted = ks.words[np.asarray(res.row_sorted)]
    t = [tuple(r) for r in full_sorted]
    assert t == sorted(t)


# ---------------------------------------------------------------------------
# batched multi-index reconstruction
# ---------------------------------------------------------------------------


def test_run_many_matches_single(rng):
    pipe = ReconstructionPipeline(backend="jnp")
    keysets = [_keyset(rng, n=1000, mask=m) for m in (0x00FF0F0F, 0x0FF000FF, 0x000FFF0F)]
    batched = pipe.run_many(keysets)
    for ks, res in zip(keysets, batched):
        single = pipe.run(ks)
        np.testing.assert_array_equal(
            np.asarray(res.rid_sorted), np.asarray(single.rid_sorted)
        )
        np.testing.assert_array_equal(res.meta.dbitmap, single.meta.dbitmap)
        assert res.stats.get("batched") == 3
        # the batched trees answer searches identically
        from repro.core.btree import search_batch

        q = jnp.asarray(ks.words[:200])
        f1, r1, _ = search_batch(res.tree, q)
        f2, r2, _ = search_batch(single.tree, q)
        np.testing.assert_array_equal(np.asarray(f1), np.asarray(f2))
        np.testing.assert_array_equal(np.asarray(r1), np.asarray(r2))


def test_run_many_mixed_shapes_falls_back(rng):
    pipe = ReconstructionPipeline(backend="jnp")
    keysets = [_keyset(rng, n=600, w=2), _keyset(rng, n=900, w=4)]
    out = pipe.run_many(keysets)
    for ks, res in zip(keysets, out):
        assert res.stats.get("batched") is None
        single = pipe.run(ks)
        np.testing.assert_array_equal(
            np.asarray(res.rid_sorted), np.asarray(single.rid_sorted)
        )


# ---------------------------------------------------------------------------
# online-index neighbor cache
# ---------------------------------------------------------------------------


def test_online_index_neighbor_cache_consistent(rng):
    """The incremental sorted-key cache must agree with a from-scratch
    rebuild of the neighbor view after arbitrary insert/delete sequences."""
    from repro.core.index import OnlineIndex

    base = np.unique(
        rng.integers(0, 2**32, size=(300, 2), dtype=np.uint32) & np.uint32(0x0FFF0FFF),
        axis=0,
    )
    ks = KeySet(
        words=base,
        lengths=np.full(len(base), 8, np.int32),
        rids=np.arange(len(base), dtype=np.uint32),
    )
    oi = OnlineIndex.build(ks)
    inserted = []
    for i in range(60):
        k = rng.integers(0, 2**32, size=2, dtype=np.uint32) | np.uint32(0x10000000)
        oi.insert(k, rid=50_000 + i)
        inserted.append(k)
    for k in inserted[:20]:
        oi.delete(k)
    # cache == freshly recomputed sorted view
    cached = list(oi._sorted_view())
    fresh = [tuple(int(x) for x in r) for r in np.asarray(oi.result.tree.sorted_full)]
    import bisect

    for key_t, _ in oi._delta:
        bisect.insort(fresh, key_t)
    assert cached == fresh
    # and the folded rebuild still resolves the surviving inserts
    oi2 = oi.rebuild()
    for i, k in enumerate(inserted[20:], start=20):
        assert oi2.search(k) == (True, 50_000 + i)
