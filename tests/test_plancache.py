"""Plan cache + shape bucketing: byte-identity under padding, cache
hit/miss/trace accounting, and the bitmap shed policy.

The contract under test: bucketing is *invisible* in the output bytes —
padded sort/merge/build/refresh produce exactly what the unpadded
reference produces, across all backends, including at the awkward sizes
``2**k - 1, 2**k, 2**k + 1`` that straddle bucket boundaries — while the
compiled-program count stays fixed (a second call in the same bucket, at a
*different* size, must perform zero recompilations; the trace counter in
``repro.core.plancache`` increments only while JAX traces, so the
assertion is strong).
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import plancache
from repro.core.dbits import merge_words_keyed, sort_words_keyed
from repro.core.keyformat import KeySet
from repro.core.metadata import meta_from_keys, meta_on_rebuild
from repro.core.pipeline import ReconstructionPipeline, fold_keyset

BACKENDS = ("jnp", "pallas", "distributed")


def _keyset(rng, n, w=3, mask=0x00FF0F0F, rid0=0):
    words = rng.integers(0, 2**32, size=(n, w), dtype=np.uint32) & np.uint32(mask)
    rids = np.arange(rid0, rid0 + n, dtype=np.uint32)
    return KeySet(words=words, lengths=np.full(n, w * 4, np.int32), rids=rids)


def _pipe(backend):
    opts = {"interpret": True} if backend == "pallas" else None
    return ReconstructionPipeline(backend=backend, backend_opts=opts)


def _assert_tree_equal(a, b):
    assert len(a.levels) == len(b.levels)
    np.testing.assert_array_equal(np.asarray(a.sorted_full), np.asarray(b.sorted_full))
    np.testing.assert_array_equal(np.asarray(a.sorted_rids), np.asarray(b.sorted_rids))
    for la, lb in zip(a.levels, b.levels):
        for k in la:
            np.testing.assert_array_equal(np.asarray(la[k]), np.asarray(lb[k]))
    for k in a.leaf:
        np.testing.assert_array_equal(np.asarray(a.leaf[k]), np.asarray(b.leaf[k]))


# ---------------------------------------------------------------------------
# bucket arithmetic
# ---------------------------------------------------------------------------


def test_bucket_is_power_of_two_with_floor():
    assert plancache.bucket(0) == plancache.BUCKET_MIN
    assert plancache.bucket(1) == plancache.BUCKET_MIN
    assert plancache.bucket(plancache.BUCKET_MIN) == plancache.BUCKET_MIN
    assert plancache.bucket(plancache.BUCKET_MIN + 1) == 2 * plancache.BUCKET_MIN
    for k in (9, 12, 16):
        assert plancache.bucket(2**k - 1) == 2**k
        assert plancache.bucket(2**k) == 2**k
        assert plancache.bucket(2**k + 1) == 2 ** (k + 1)


def test_cache_counters_hit_miss():
    cache = plancache.PlanCache()
    calls = []
    prog1 = cache.program(("op", 1), lambda: calls.append(1) or (lambda: 1))
    prog2 = cache.program(("op", 1), lambda: calls.append(2) or (lambda: 2))
    assert prog1 is prog2 and calls == [1]
    assert cache.stats()["misses"] == 1 and cache.stats()["hits"] == 1


def test_cache_lru_bound_and_eviction_counters():
    """The optional ``max_programs`` bound evicts least-recently-used
    programs and counts the victims (long-lived-server hygiene)."""
    cache = plancache.PlanCache(max_programs=2)
    built = []

    def make(tag):
        return lambda: built.append(tag) or (lambda: tag)

    cache.program(("a",), make("a"))
    cache.program(("b",), make("b"))
    cache.program(("a",), make("a2"))  # hit: refreshes a's recency
    cache.program(("c",), make("c"))   # evicts b (the LRU), not a
    st = cache.stats()
    assert st["programs"] == 2 and st["evictions"] == 1
    assert st["max_programs"] == 2
    assert built == ["a", "b", "c"]
    assert cache.program(("a",), make("a3"))() == "a"  # a survived
    assert cache.program(("b",), make("b2"))() == "b2"  # b rebuilds...
    assert built == ["a", "b", "c", "b2"]
    assert cache.stats()["evictions"] == 2  # ...evicting the next LRU (c)
    # unbounded cache never evicts
    unbounded = plancache.PlanCache()
    for i in range(64):
        unbounded.program(("k", i), make(i))
    assert unbounded.stats()["evictions"] == 0
    assert unbounded.stats()["programs"] == 64
    # reset zeroes counters but keeps the configured bound
    cache.reset()
    assert cache.stats() == {
        "programs": 0, "hits": 0, "misses": 0, "traces": 0,
        "evictions": 0, "max_programs": 2, "per_op": {},
    }


def test_cache_per_op_breakdown():
    """stats()["per_op"] splits hit/miss/trace counters by op family
    (key[0]), with tracings attributed to the op whose builder wrapped
    the program — so a bench can see *which* family retraced."""
    cache = plancache.PlanCache()

    def build_sq():
        return cache.jit(lambda x: x * x)

    sq = cache.program(("sq", 256), build_sq)
    cache.program(("sq", 256), build_sq)           # hit
    cache.program(("other", 1), lambda: (lambda: 0))
    sq(jnp.arange(4))                              # first call: one trace
    sq(jnp.arange(4))                              # replay: no trace
    st = cache.stats()["per_op"]
    assert st["sq"] == {"hits": 1, "misses": 1, "traces": 1}
    assert st["other"] == {"hits": 0, "misses": 1, "traces": 0}
    # aggregates stay the sums of the breakdown
    agg = cache.stats()
    assert agg["hits"] == sum(c["hits"] for c in st.values())
    assert agg["misses"] == sum(c["misses"] for c in st.values())
    assert agg["traces"] == sum(c["traces"] for c in st.values())
    # a jit outside any builder lands under "_unkeyed"
    free = cache.jit(lambda x: x + 1)
    free(jnp.arange(4))
    assert cache.stats()["per_op"]["_unkeyed"]["traces"] == 1


def test_cache_lru_bound_stays_correct_under_real_ops(rng):
    """A tightly bounded cache re-traces evicted programs but never
    answers wrong: padded sorts at many buckets stay byte-identical."""
    cache = plancache.PlanCache(max_programs=1)
    from repro.core.dbits import sort_words_keyed

    for n in (255, 300, 600, 257):
        keys = jnp.asarray(
            rng.integers(0, 2**32, size=(n, 2), dtype=np.uint32), jnp.uint32
        )
        rows = jnp.asarray(rng.permutation(n).astype(np.uint32))
        ks_ref, rs_ref = sort_words_keyed(keys, rows)
        ks, rs = plancache.sort_padded(keys, rows, cache=cache)
        np.testing.assert_array_equal(np.asarray(ks_ref), np.asarray(ks))
        np.testing.assert_array_equal(np.asarray(rs_ref), np.asarray(rs))
        assert cache.stats()["programs"] <= 1
    assert cache.stats()["evictions"] >= 1


def test_set_max_programs_global():
    plancache.reset_cache()
    try:
        plancache.set_max_programs(3)
        assert plancache.cache_stats()["max_programs"] == 3
    finally:
        plancache.set_max_programs(None)
        assert plancache.cache_stats()["max_programs"] is None
    # a zero bound is rejected, not silently floored at 1
    with pytest.raises(ValueError):
        plancache.set_max_programs(0)
    with pytest.raises(ValueError):
        plancache.PlanCache(max_programs=0)


def test_trace_counter_counts_traces_not_calls():
    cache = plancache.PlanCache()
    f = cache.jit(lambda x: x + 1)
    f(jnp.zeros((4,)))
    f(jnp.ones((4,)))  # same shape: replay, no trace
    assert cache.stats()["traces"] == 1
    f(jnp.zeros((8,)))  # new shape: one more trace
    assert cache.stats()["traces"] == 2


# ---------------------------------------------------------------------------
# padded ops == unpadded reference (the byte-identity invariant)
# ---------------------------------------------------------------------------


def test_sort_padded_matches_reference(rng):
    for n in (255, 256, 257, 511, 513):
        keys = jnp.asarray(
            rng.integers(0, 2**32, size=(n, 3), dtype=np.uint32) & np.uint32(0xFF0F),
            jnp.uint32,
        )
        rows = jnp.asarray(rng.permutation(n).astype(np.uint32))
        ks_ref, rs_ref = sort_words_keyed(keys, rows)
        ks_pad, rs_pad = plancache.sort_padded(keys, rows, cache=plancache.PlanCache())
        np.testing.assert_array_equal(np.asarray(ks_ref), np.asarray(ks_pad))
        np.testing.assert_array_equal(np.asarray(rs_ref), np.asarray(rs_pad))


def test_sort_padded_all_ones_real_keys_precede_pads(rng):
    # a real all-ones key collides with the pad sentinel; the reserved pad
    # row range must still break the tie in favour of the real row
    n = 300
    keys = jnp.full((n, 2), 0xFFFFFFFF, jnp.uint32)
    rows = jnp.arange(n, dtype=jnp.uint32)
    ks, rs = plancache.sort_padded(keys, rows, cache=plancache.PlanCache())
    np.testing.assert_array_equal(np.asarray(rs), np.arange(n, dtype=np.uint32))


def test_merge_padded_matches_reference(rng):
    for na, nb in ((255, 9), (256, 256), (257, 31), (100, 0), (0, 100)):
        ka = jnp.asarray(
            rng.integers(0, 2**16, size=(na, 2), dtype=np.uint32), jnp.uint32
        )
        kb = jnp.asarray(
            rng.integers(0, 2**16, size=(nb, 2), dtype=np.uint32), jnp.uint32
        )
        ra = jnp.arange(na, dtype=jnp.uint32)
        rb = jnp.arange(na, na + nb, dtype=jnp.uint32)
        ka, ra2 = sort_words_keyed(ka, ra)
        kb, rb2 = sort_words_keyed(kb, rb)
        mk_ref, mr_ref = merge_words_keyed(ka, ra2, kb, rb2)
        mk, mr = plancache.merge_padded(ka, ra2, kb, rb2, cache=plancache.PlanCache())
        np.testing.assert_array_equal(np.asarray(mk_ref), np.asarray(mk))
        np.testing.assert_array_equal(np.asarray(mr_ref), np.asarray(mr))


def test_merge_same_bucket_zero_retrace(rng):
    """The ROADMAP open item: drifting (na, nb) inside one bucket pair must
    not retrace the jnp merge."""
    cache = plancache.PlanCache()

    def merge_at(na, nb):
        ka, ra = sort_words_keyed(
            jnp.asarray(rng.integers(0, 2**16, size=(na, 2), dtype=np.uint32)),
            jnp.arange(na, dtype=jnp.uint32),
        )
        kb, rb = sort_words_keyed(
            jnp.asarray(rng.integers(0, 2**16, size=(nb, 2), dtype=np.uint32)),
            jnp.arange(na, na + nb, dtype=jnp.uint32),
        )
        return plancache.merge_padded(ka, ra, kb, rb, cache=cache)

    merge_at(1000, 100)
    t0 = cache.stats()["traces"]
    assert t0 >= 1
    merge_at(1010, 90)  # same (1024, 128) bucket pair
    merge_at(997, 127)
    assert cache.stats()["traces"] == t0, cache.stats()
    merge_at(2000, 100)  # crosses bucket_a: exactly the new programs trace
    assert cache.stats()["traces"] > t0


# ---------------------------------------------------------------------------
# bucket boundaries: full pipeline across backends (deterministic; the
# hypothesis property sweep lives in test_bucket_boundaries.py)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("off", [-1, 0, 1])
def test_boundary_pipeline_parity_all_backends(rng, off):
    """The whole reconstruction (sorted keys, rid permutation, tree levels,
    refreshed bitmap) is byte-identical across jnp, pallas and distributed
    at bucket-straddling sizes."""
    n = 512 + off
    ks = _keyset(rng, n)
    ref = _pipe("jnp").run(ks)
    for backend in BACKENDS[1:]:
        res = _pipe(backend).run(ks)
        np.testing.assert_array_equal(
            np.asarray(ref.comp_sorted), np.asarray(res.comp_sorted)
        )
        np.testing.assert_array_equal(
            np.asarray(ref.rid_sorted), np.asarray(res.rid_sorted)
        )
        _assert_tree_equal(ref.tree, res.tree)
        np.testing.assert_array_equal(ref.meta.dbitmap, res.meta.dbitmap)


# ---------------------------------------------------------------------------
# pipeline-level cache behaviour (the acceptance assertion)
# ---------------------------------------------------------------------------


def test_second_same_bucket_run_zero_recompiles(rng):
    pipe = _pipe("jnp")
    pipe.run(_keyset(rng, 700))
    s0 = plancache.cache_stats()
    pipe.run(_keyset(rng, 700))
    pipe.run(_keyset(rng, 690))  # drifted size, same bucket
    s1 = plancache.cache_stats()
    assert s1["traces"] == s0["traces"], (s0, s1)
    assert s1["hits"] > s0["hits"]


def test_second_same_bucket_run_incremental_zero_recompiles(rng):
    pipe = _pipe("jnp")
    base = _keyset(rng, 3000)
    delta = _keyset(rng, 150, rid0=3000)
    meta = meta_from_keys(np.concatenate([base.words, delta.words]))
    prev = pipe.run(base, meta=meta)
    res, _ = pipe.run_incremental(prev, base, delta, meta=meta)
    assert res.stats["incremental"] is True
    s0 = plancache.cache_stats()
    res2, _ = pipe.run_incremental(prev, base, delta, meta=meta)
    s1 = plancache.cache_stats()
    assert res2.stats["incremental"] is True
    assert s1["traces"] == s0["traces"], (s0, s1)


def test_incremental_bucketed_matches_full(rng):
    """Byte-identity of the bucketed delta merge against the bucketed full
    run at a boundary-straddling base size."""
    for backend in BACKENDS:
        pipe = _pipe(backend)
        base = _keyset(rng, 1023)
        delta = _keyset(rng, 65, rid0=1023)
        meta = meta_from_keys(np.concatenate([base.words, delta.words]))
        prev = pipe.run(base, meta=meta)
        folded = fold_keyset(base, None, delta)
        full = pipe.run(folded, meta=meta)
        inc, _ = pipe.run_incremental(prev, base, delta, meta=meta)
        assert inc.stats["incremental"] is True, backend
        np.testing.assert_array_equal(
            np.asarray(full.comp_sorted), np.asarray(inc.comp_sorted)
        )
        np.testing.assert_array_equal(
            np.asarray(full.rid_sorted), np.asarray(inc.rid_sorted)
        )
        _assert_tree_equal(full.tree, inc.tree)


def test_run_many_buckets_drifting_sizes(rng):
    """Keysets whose sizes drift within one bucket batch together and each
    member's result equals its own single run."""
    pipe = _pipe("jnp")
    sets = [_keyset(rng, n) for n in (900, 950, 1000)]
    singles = [pipe.run(s) for s in sets]
    manys = pipe.run_many(sets)
    assert manys[0].stats.get("batched") == 3
    for s, m in zip(singles, manys):
        np.testing.assert_array_equal(
            np.asarray(s.comp_sorted), np.asarray(m.comp_sorted)
        )
        np.testing.assert_array_equal(
            np.asarray(s.rid_sorted), np.asarray(m.rid_sorted)
        )


# ---------------------------------------------------------------------------
# vectorized refresh_meta (satellite)
# ---------------------------------------------------------------------------


def _meta_on_rebuild_loop_ref(comp_sorted, old_meta, ref_full_key):
    """The PR-2 per-position Python loop, kept as the test oracle."""
    from dataclasses import replace

    from repro.core.dbits import NO_DBIT, adjacent_dbit_positions

    d_off = old_meta.d_offset()
    dpos = np.asarray(adjacent_dbit_positions(jnp.asarray(comp_sorted, jnp.uint32)))
    valid = dpos != NO_DBIT
    full_pos = d_off[dpos[valid]]
    dbm = np.zeros_like(old_meta.dbitmap)
    for p in np.unique(full_pos):
        dbm[p // 32] |= np.uint32(1) << np.uint32(31 - p % 32)
    return replace(old_meta, dbitmap=dbm, refkey=np.asarray(ref_full_key, np.uint32))


def test_meta_on_rebuild_vectorized_matches_loop(rng):
    for n in (1, 2, 255, 257, 1000):
        ks = _keyset(rng, n)
        meta = meta_from_keys(ks.words)
        res = _pipe("jnp").run(ks, meta=meta)
        comp = np.asarray(res.comp_sorted)
        got = meta_on_rebuild(comp, meta, ks.words[0])
        want = _meta_on_rebuild_loop_ref(comp, meta, ks.words[0])
        np.testing.assert_array_equal(got.dbitmap, want.dbitmap)
        np.testing.assert_array_equal(got.refkey, want.refkey)


# ---------------------------------------------------------------------------
# kernels/build pk-window gather (pallas) vs oracle
# ---------------------------------------------------------------------------


def test_build_kernel_pk_windows_matches_slice_bits(rng):
    from repro.core.btree import _slice_bits
    from repro.kernels.build import pk_windows
    from repro.kernels.build.ref import pk_windows_ref

    for m, w in ((37, 2), (512, 3), (513, 3)):
        words = rng.integers(0, 2**32, size=(m, w), dtype=np.uint32)
        starts = rng.integers(-4, w * 32 + 4, size=(m,)).astype(np.int32)
        for pk in (8, 16):
            want = np.asarray(
                _slice_bits(jnp.asarray(words), jnp.asarray(starts), pk)
            ).astype(np.uint32)
            got = np.asarray(
                pk_windows(jnp.asarray(words), jnp.asarray(starts), pk, interpret=True)
            )
            np.testing.assert_array_equal(want, got)
            np.testing.assert_array_equal(want, pk_windows_ref(words, starts, pk))


# ---------------------------------------------------------------------------
# bitmap shed policy (satellite, ROADMAP open item)
# ---------------------------------------------------------------------------


def _stale_bit_keyset():
    """Keys where one pair's distinction bit vanishes when the pair is
    deleted: rows 0/1 differ only at bit 31 of word 1; everyone else
    differs high in word 0."""
    words = np.zeros((6, 2), np.uint32)
    words[0] = (0, 0)
    words[1] = (0, 1)  # dbit(0, 1) = position 63
    for i in range(2, 6):
        words[i] = (i << 8, 0)
    return KeySet(
        words=words, lengths=np.full(6, 8, np.int32), rids=np.arange(6, dtype=np.uint32)
    )


def test_replica_shed_policy_threshold():
    from repro.replication import ChangeLog
    from repro.replication.replica import Replica

    ks = _stale_bit_keyset()

    # below threshold: bitmap stays pinned (stale bit 63 kept, incremental)
    rep = Replica(ks, shed_delete_frac=0.9)
    log = ChangeLog(2, start_lsn=0)
    log.append_deletes([0])
    stats = rep.apply(log)
    assert stats["shed_bits"] is False
    assert stats["incremental"] is True
    assert rep.meta.dbitmap[1] & np.uint32(1)  # bit 63 still pinned

    # above threshold: the refreshed bitmap is adopted and the stale bit
    # (only distinguishing the deleted pair) is gone
    rep2 = Replica(ks, shed_delete_frac=0.1)
    log2 = ChangeLog(2, start_lsn=0)
    log2.append_deletes([0, 1])
    stats2 = rep2.apply(log2)
    assert stats2["shed_bits"] is True
    assert stats2["deletes_since_shed"] == 0
    assert not (rep2.meta.dbitmap[1] & np.uint32(1))  # bit 63 shed

    # the post-shed batch pays one full rebuild (narrower projection), then
    # the replica answers byte-identically
    log3 = ChangeLog(2, start_lsn=log2.next_lsn)
    ins = np.asarray([[7 << 8, 0]], np.uint32)
    log3.append_inserts(ins, [100])
    stats3 = rep2.apply(log3)
    assert stats3["incremental"] is False
    assert stats3["fallback"] == "dbitmap_changed"
    found, rid = rep2.search(ins[0])
    assert found and rid == 100


def test_pager_shed_policy():
    from repro.serve.pager import PagedKVManager

    pg = PagedKVManager(n_pages=512, page_tokens=16, shed_delete_frac=0.25)
    for s in range(8):
        pg.pages_for(s, 16 * 8)  # 8 pages per seq
    pg.rebuild_index()
    assert pg._last_rebuild["shed_bits"] is False
    for s in range(4):
        pg.free_seq(s)
    pg.rebuild_index()  # 32 frees > 25% of 32 live keys -> shed
    assert pg._last_rebuild["shed_bits"] is True
    # lookups stay correct across the shed
    assert pg.lookup(5, 3) == pg._table[(5, 3)]
    assert pg.lookup(0, 0) is None
