"""Docs enforcement: public-surface docstrings + markdown health.

A pydocstyle-lite AST check: every public module / class / function /
method on the repo's public surface (the pipeline, the replication
layer, the plan cache, the backend op contract) must carry a docstring —
args/returns/determinism-contract notes live there, and an undocumented
public entry point is a review failure, not a style nit.  Plus the
``tools/check_docs.py`` link/drift checker, so the tier-1 suite (and CI)
fails on a broken intra-repo link or an undocumented new subsystem.
"""

import ast
import subprocess
import sys
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parent.parent
SRC = REPO / "src" / "repro"

#: the enforced public surface (satellite scope: grow it as modules join)
SURFACE = [
    SRC / "core" / "pipeline.py",
    SRC / "core" / "plancache.py",
    SRC / "core" / "snapshot.py",
    SRC / "backends" / "base.py",
    SRC / "replication" / "log.py",
    SRC / "replication" / "replica.py",
    SRC / "replication" / "stream.py",
    SRC / "replication" / "transport.py",
    SRC / "replication" / "wire.py",
    SRC / "replication" / "chaos.py",
    SRC / "replication" / "supervisor.py",
    SRC / "ckpt" / "checkpoint.py",
    SRC / "serve" / "loadgen.py",
    SRC / "serve" / "pager.py",
    SRC / "serve" / "tenants.py",
]


def _public_defs(path: Path):
    """Yield (qualname, node) for the module + public defs/classes."""
    tree = ast.parse(path.read_text())
    yield f"{path.name} (module)", tree
    for node in tree.body:
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            if not node.name.startswith("_"):
                yield node.name, node
        elif isinstance(node, ast.ClassDef) and not node.name.startswith("_"):
            yield node.name, node
            for sub in node.body:
                # __init__ (underscored) documents via the class docstring
                if isinstance(sub, (ast.FunctionDef, ast.AsyncFunctionDef)):
                    if not sub.name.startswith("_"):
                        yield f"{node.name}.{sub.name}", sub


@pytest.mark.parametrize("path", SURFACE, ids=lambda p: str(p.relative_to(SRC)))
def test_public_surface_has_docstrings(path):
    missing = [
        name
        for name, node in _public_defs(path)
        if ast.get_docstring(node) is None
    ]
    assert not missing, (
        f"{path.relative_to(REPO)}: public surface without docstrings: "
        f"{', '.join(missing)}"
    )


def test_docs_links_and_module_list():
    """tools/check_docs.py must pass (broken links / module drift fail)."""
    proc = subprocess.run(
        [sys.executable, str(REPO / "tools" / "check_docs.py")],
        capture_output=True,
        text=True,
    )
    assert proc.returncode == 0, proc.stdout + proc.stderr


def test_docs_exist_and_linked_from_readme():
    """The four docs exist and the README links every one of them."""
    readme = (REPO / "README.md").read_text()
    for doc in ("architecture.md", "replication.md", "adding-a-backend.md",
                "benchmarks.md"):
        assert (REPO / "docs" / doc).exists(), f"docs/{doc} missing"
        assert f"docs/{doc}" in readme, f"README does not link docs/{doc}"
