"""Versioned snapshot read path: publish/acquire protocol, torn reads.

The acceptance contract: queries issued concurrently with
``StreamReplica.poll`` / ``OnlineIndex.rebuild`` are answered from the
pinned snapshot — every answer matches exactly one published epoch,
never a mixture of two reconstructions — and the snapshot epoch
round-trips through the checkpoint layer.
"""

import numpy as np
import pytest

from repro.core import plancache
from repro.core.keyformat import KeySet
from repro.core.pipeline import ReconstructionPipeline
from repro.core.snapshot import SnapshotCell
from repro.replication import ChangeLog, QueueTransport, StreamPrimary, StreamReplica
from repro.replication.replica import Replica


def _keyset(rng, n, w=3, mask=0x00FF0F0F, rid_base=0):
    words = rng.integers(0, 2**32, size=(n, w), dtype=np.uint32) & np.uint32(mask)
    return KeySet(
        words=words, lengths=np.full(n, w * 4, np.int32),
        rids=np.arange(rid_base, rid_base + n, dtype=np.uint32),
    )


# ---------------------------------------------------------------------------
# the cell protocol
# ---------------------------------------------------------------------------


def test_snapshot_cell_publish_pin_retire(rng):
    ks = _keyset(rng, 300)
    pipe = ReconstructionPipeline(backend="jnp")
    cell = SnapshotCell()
    with pytest.raises(RuntimeError):
        cell.acquire()  # nothing published yet
    res0 = pipe.run(ks, publish_to=cell)
    assert cell.epoch == 0 and cell.current.tree is res0.tree

    pinned = cell.acquire()
    res1 = pipe.run(ks, publish_to=cell)  # double buffer: next epoch
    assert cell.epoch == 1 and cell.current.tree is res1.tree
    # the pinned epoch-0 snapshot survives the swap, untouched
    assert pinned.epoch == 0 and pinned.tree is res0.tree
    assert cell.stats()["retired"] == 1
    cell.release(pinned)
    assert cell.stats()["retired"] == 0  # dropped once unpinned

    # an unpinned previous snapshot is dropped immediately on publish
    pipe.run(ks, publish_to=cell)
    assert cell.epoch == 2 and cell.stats()["retired"] == 0

    with pytest.raises(RuntimeError):
        cell.release(pinned)  # double release is a bug, not a no-op
    with pytest.raises(ValueError):
        cell.publish(res1, epoch=1)  # epochs must increase

    # frozen metadata: mutating the producer's result cannot reach a snapshot
    snap = cell.current
    res1.meta.dbitmap[:] = 0
    assert snap.meta.dbitmap.any() or snap.meta.dbitmap.shape == (0,)


def test_snapshot_cell_resume_epoch(rng):
    ks = _keyset(rng, 280)
    cell = SnapshotCell(start_epoch=41)
    ReconstructionPipeline(backend="jnp").run(ks, publish_to=cell)
    assert cell.epoch == 42  # resumed numbering, not restarted at 0


# ---------------------------------------------------------------------------
# readers pinned across a rebuild (OnlineIndex + Replica)
# ---------------------------------------------------------------------------


def test_online_index_reader_pinned_across_rebuild(rng):
    from repro.core.index import OnlineIndex

    ks = _keyset(rng, 400)
    oi = OnlineIndex.build(ks)
    victim = np.asarray(ks.words[7])
    pinned = oi.snapshots.acquire()
    oi.delete(victim)
    oi2 = oi.rebuild()
    assert oi2.snapshots is oi.snapshots and oi2.snapshots.epoch == 1
    # the new epoch answers post-delete; the pinned epoch still finds it
    f_new, _ = oi2.search(victim)
    assert not f_new
    f_old, _ = pinned.lookup(oi2._backend_obj(), victim[None, :])
    assert bool(f_old[0])
    oi.snapshots.release(pinned)
    # the pre-rebuild *instance* stays bound to its own epoch: its
    # overlay (which recorded the delete) composes with the pre-rebuild
    # tree, never with the successor's — rid reuse in the successor
    # cannot make the old instance's tombstones mask a live key
    assert oi._snapshot.epoch == 0 and oi2._snapshot.epoch == 1
    f, _ = oi.search(victim)
    assert not f  # old instance: base hit masked by its own tombstone
    f, r = oi.search(np.asarray(ks.words[8]))
    assert f and r == 8  # and untouched keys still answer from epoch 0


def test_replica_epochs_align_with_watermarks(rng):
    ks = _keyset(rng, 350)
    rep = Replica(ks)
    assert rep.snapshots.epoch == 0
    assert rep.snapshots.current.watermark is None
    lsn = 0
    for i in range(3):
        log = ChangeLog(3, start_lsn=lsn)
        log.append_inserts(np.asarray(ks.words)[i : i + 2],
                           np.arange(9000 + 2 * i, 9002 + 2 * i, dtype=np.uint32))
        lsn = log.next_lsn
        rep.apply(log)
        assert rep.snapshots.epoch == i + 1
        assert rep.snapshots.current.watermark == lsn - 1
    # a net-empty (noop) batch still publishes: epochs track watermarks
    log = ChangeLog(3, start_lsn=lsn)
    log.append_inserts(np.asarray(ks.words)[:1], [4242])
    log.append_deletes([4242])
    st = rep.apply(log)
    assert st["noop"] and rep.snapshots.epoch == 4
    assert rep.snapshots.current.watermark == log.next_lsn - 1


# ---------------------------------------------------------------------------
# the torn-read acceptance test
# ---------------------------------------------------------------------------


class _ProbingTransport(QueueTransport):
    """A transport whose reads fire a probe — queries *inside* poll()."""

    def __init__(self):
        super().__init__()
        self.probe = None

    def read(self, pos):
        if self.probe is not None:
            self.probe("transport-read")
        return super().read(pos)


def test_no_torn_reads_during_poll(rng):
    """Queries interleaved with ``StreamReplica.poll`` — fired between
    frame reads and at the instants just before and after each snapshot
    swap — must each match exactly ONE published epoch's answers."""
    base = _keyset(rng, 500)
    t = _ProbingTransport()
    prim = StreamPrimary(t, base)
    rep = StreamReplica(t)
    rep.poll()  # bring-up (no probing yet)

    # the probe keys: X is deleted by the batch, Y inserted by it — the
    # two epochs answer (found_x, found_y) as (True, False) / (False, True)
    x = np.asarray(base.words[11])
    y = (np.asarray(base.words[12]) ^ np.uint32(0x30000)).astype(np.uint32)
    log = ChangeLog(3, start_lsn=prim.next_lsn)
    log.append_deletes([11])
    log.append_inserts(y[None, :], [7777])
    answers = []

    def probe(where):
        if rep.replica is None:
            return
        fx, _ = rep.replica.search(x)
        fy, rid_y = rep.replica.search(y)
        answers.append((where, fx, fy, rid_y))

    # also probe at the swap itself: just before publish the rebuild is
    # complete but unpublished — reads must still see the old epoch
    cell = rep.replica.snapshots
    orig_publish = cell.publish

    def probed_publish(result, epoch=None):
        probe("pre-swap")
        snap = orig_publish(result, epoch=epoch)
        probe("post-swap")
        return snap

    cell.publish = probed_publish
    t.probe = probe
    prim.publish(log)
    rep.poll()
    t.probe = None
    cell.publish = orig_publish

    assert len(answers) >= 3
    pre = (True, False)
    post = (False, True)
    for where, fx, fy, rid_y in answers:
        assert (fx, fy) in (pre, post), (where, fx, fy)
        if (fx, fy) == post:
            assert rid_y == 7777
    # both epochs were actually observed (pre-swap probes the old one,
    # post-swap the new one)
    observed = {(fx, fy) for _, fx, fy, _ in answers}
    assert observed == {pre, post}, answers
    # and a fresh query now sees the post-watermark answer
    assert rep.replica.search(x) == (False, int(0xFFFFFFFF))


def test_steady_query_stream_zero_retrace_across_polls(rng):
    """The acceptance criterion: a same-bucket query stream interleaved
    with balanced-churn polls records zero new traces once warm."""
    base = _keyset(rng, 600)
    t = QueueTransport()
    prim = StreamPrimary(t, base)
    rep = StreamReplica(t)
    rep.poll()
    queries = np.asarray(base.words)[:: 3]

    def churn():
        # redraw 10 live keys: n stays constant, tree geometry stable
        log = ChangeLog(3, start_lsn=prim.next_lsn)
        dead = np.asarray(prim.replica.keyset.rids)[:10]
        log.append_deletes(dead)
        log.append_inserts(
            np.asarray(prim.replica.keyset.words)[:10],
            np.asarray(dead) + np.uint32(50000),
        )
        prim.publish(log)
        rep.poll()

    churn()
    rep.search_batch(queries)  # warm the lookup program (the delegate)
    churn()
    s0 = plancache.cache_stats()
    for q in (len(queries), len(queries) - 7, len(queries) - 40):
        f, r = rep.search_batch(queries[:q])
        assert f.shape == (q,) and r.dtype == np.uint32
    churn()
    rep.search_batch(queries)
    s1 = plancache.cache_stats()
    assert s1["traces"] == s0["traces"], (s0, s1)


# ---------------------------------------------------------------------------
# checkpoint round trip of the snapshot epoch
# ---------------------------------------------------------------------------


def test_snapshot_epoch_roundtrips_through_checkpoint(tmp_path, rng):
    base = _keyset(rng, 400)
    t = QueueTransport()
    prim = StreamPrimary(t, base, ckpt_dir=str(tmp_path / "ckpt"))
    rep = StreamReplica(t)
    for i in range(3):
        log = ChangeLog(3, start_lsn=prim.next_lsn)
        log.append_inserts(np.asarray(base.words)[i : i + 4],
                           np.arange(8000 + 4 * i, 8004 + 4 * i, dtype=np.uint32))
        prim.publish(log)
    man = prim.checkpoint()
    assert man["meta"]["snapshot_epoch"] == prim.replica.snapshots.epoch

    from repro.ckpt.checkpoint import restore_checkpoint

    _, stats = restore_checkpoint(tmp_path / "ckpt", man["step"], {})
    assert stats["snapshot_epoch"] == man["meta"]["snapshot_epoch"]

    # a bootstrapped replica resumes the primary's epoch numbering
    st = rep.poll()
    assert st["catchup"] is False or True  # poll drains; bootstrap only on gap
    lag = StreamReplica(t, start_pos=t.end() - 1)  # sees only the ckpt frame
    st = lag.poll()
    assert st["catchup"]
    assert lag.replica.snapshots.epoch == man["meta"]["snapshot_epoch"]
    # and subsequent batches keep incrementing from there
    log = ChangeLog(3, start_lsn=prim.next_lsn)
    log.append_deletes([0])
    prim.publish(log)
    lag.poll()
    assert lag.replica.snapshots.epoch == man["meta"]["snapshot_epoch"] + 1
