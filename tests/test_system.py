"""End-to-end system behaviour tests: reconstruction pipeline, checkpoint
recovery with index rebuild, serving engine + paged index, data pipeline,
training loop convergence, distributed paths (subprocess, 8 fake devices)."""

import json
import subprocess
import sys
import textwrap
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt.checkpoint import latest_step, restore_checkpoint, save_checkpoint
from repro.configs.paper_index import ZipfConfig
from repro.core.reconstruct import full_key_reconstruct, reconstruct_index
from repro.data.pipeline import TokenPipeline, dedup_tokens, shuffle_order
from repro.data.synthetic import lm_tokens, zipf_keys

SRC = str(Path(__file__).resolve().parents[1] / "src")


# ---------------------------------------------------------------------------
# reconstruction pipeline (paper §5 end-to-end)
# ---------------------------------------------------------------------------


def test_reconstruction_beats_full_sort_in_work():
    """On a Zipf dataset the compressed pipeline sorts strictly fewer key
    words — the paper's mechanism — and both pipelines agree exactly."""
    ks = zipf_keys(ZipfConfig(1.5, 40, 0, n_keys=4000), seed=1)
    comp = reconstruct_index(ks)
    full = full_key_reconstruct(ks)
    assert (np.asarray(comp.rid_sorted) == np.asarray(full.rid_sorted)).all()
    assert comp.stats["comp_sort_key_words"] < comp.stats["full_sort_key_words"]
    assert comp.stats["compression_ratio"] > 1.5
    assert comp.stats["sort_key_ratio"] >= 1.5


def test_reconstruction_with_persisted_metadata_roundtrip(tmp_path):
    """DS-metadata persists; reconstruction from persisted metadata (without
    recomputing it) matches the fresh build — the recovery path."""
    ks = zipf_keys(ZipfConfig(2.5, 48, 0, n_keys=2000), seed=3)
    first = reconstruct_index(ks)
    np.savez(tmp_path / "dsmeta.npz", **first.meta.to_npz_dict())
    from repro.core.metadata import DSMeta

    meta = DSMeta.from_npz_dict(dict(np.load(tmp_path / "dsmeta.npz")))
    second = reconstruct_index(ks, meta=meta)
    assert (np.asarray(first.rid_sorted) == np.asarray(second.rid_sorted)).all()


def test_kernel_backed_reconstruction_matches_jnp():
    ks = zipf_keys(ZipfConfig(1.5, 40, 2, n_keys=1500), seed=5)
    a = reconstruct_index(ks, use_kernel=False)
    b = reconstruct_index(ks, use_kernel=True)
    assert (np.asarray(a.rid_sorted) == np.asarray(b.rid_sorted)).all()
    assert (np.asarray(a.comp_sorted) == np.asarray(b.comp_sorted)).all()


# ---------------------------------------------------------------------------
# checkpoint + manifest index reconstruction (fault tolerance)
# ---------------------------------------------------------------------------


def test_checkpoint_roundtrip_and_index(tmp_path):
    tree = {
        "a": {"w": np.arange(12.0).reshape(3, 4), "b": np.zeros(7)},
        "blocks": {"0": {"wq": np.ones((4, 4), np.float32)}},
    }
    save_checkpoint(tmp_path, 5, tree)
    assert latest_step(tmp_path) == 5
    like = jax.tree_util.tree_map(lambda x: np.zeros_like(x), tree)
    got, stats = restore_checkpoint(tmp_path, 5, like)
    for a, b in zip(jax.tree_util.tree_leaves(tree), jax.tree_util.tree_leaves(got)):
        np.testing.assert_array_equal(a, b)
    assert stats["n_leaves"] == 3
    assert stats["compression_ratio"] >= 1.0


def test_checkpoint_partial_write_ignored(tmp_path):
    tree = {"w": np.ones(3)}
    save_checkpoint(tmp_path, 1, tree)
    # a torn checkpoint: directory without DONE marker
    torn = tmp_path / "step_00000002"
    torn.mkdir()
    (torn / "junk.npy").write_bytes(b"xx")
    assert latest_step(tmp_path) == 1  # crash-restart picks the committed one
    with pytest.raises(FileNotFoundError):
        restore_checkpoint(tmp_path, 2, tree)


def test_checkpoint_manifest_lookup_every_leaf(tmp_path):
    """Every leaf resolves through the reconstructed B-tree index."""
    rng = np.random.default_rng(0)
    tree = {f"layer{i:03d}": {"w": rng.normal(size=(3,)), "b": rng.normal(size=(2,))}
            for i in range(100)}
    save_checkpoint(tmp_path, 7, tree)
    from repro.ckpt.checkpoint import CheckpointIndex

    idx = CheckpointIndex(Path(tmp_path) / "step_00000007")
    assert len(set(idx.lookup(n) for n in idx.names)) == len(idx.names)
    with pytest.raises(KeyError):
        idx.lookup("not/a/leaf")


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------


def test_shuffle_order_is_permutation_and_deterministic():
    a = shuffle_order(1000, seed=4)
    b = shuffle_order(1000, seed=4)
    c = shuffle_order(1000, seed=5)
    assert sorted(a.tolist()) == list(range(1000))
    assert (a == b).all()
    assert (a != c).any()


def test_dedup_tokens():
    docs = np.asarray([[1, 2, 3], [4, 5, 6], [1, 2, 3], [7, 8, 9], [4, 5, 6]])
    keep = dedup_tokens(docs)
    assert len(keep) == 3
    kept = {tuple(docs[i]) for i in keep}
    assert kept == {(1, 2, 3), (4, 5, 6), (7, 8, 9)}


def test_pipeline_resume_determinism():
    docs = lm_tokens(256, 65, vocab=1000, seed=0)
    p1 = TokenPipeline(docs, global_batch=8, seq_len=64, seed=1)
    p2 = TokenPipeline(docs, global_batch=8, seq_len=64, seed=1)
    # straggler/restart safety: batch_at(step) is pure
    for step in (0, 7, 31, 33):
        b1, b2 = p1.batch_at(step), p2.batch_at(step)
        np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    # an epoch covers every doc exactly once
    seen = np.concatenate(
        [p1.batch_at(s)["tokens"][:, :1] for s in range(p1.per_epoch)]
    )
    assert len(seen) == 256


# ---------------------------------------------------------------------------
# serving engine + paged index
# ---------------------------------------------------------------------------


def test_serve_engine_generate_and_restart():
    from repro.configs import ARCHS
    from repro.models.lm import LM
    from repro.serve.engine import ServeEngine

    cfg = ARCHS["llama3-8b"].reduced()
    m = LM(cfg, remat=False)
    params = m.init(jax.random.PRNGKey(0))
    eng = ServeEngine(m, params, max_seq=64, batch_size=2, page_tokens=16)
    prompts = np.random.default_rng(0).integers(0, cfg.vocab_size, (2, 16))
    out = eng.generate(prompts, n_new=8)
    assert out.shape == (2, 8)
    st = eng.restart()  # index rebuild from page table
    assert st["index_height"] >= 1
    assert eng.pager.lookup(0, 0) is not None
    assert eng.pager.lookup(7, 0) is None


def test_greedy_decode_matches_teacher_forcing():
    """Decode path == train path: generate 4 tokens greedily, then verify
    each is the argmax of a fresh prefill over its full prefix."""
    from repro.configs import ARCHS
    from repro.models.lm import LM
    from repro.serve.engine import ServeEngine

    cfg = ARCHS["llama3-8b"].reduced()
    m = LM(cfg, remat=False)
    params = m.init(jax.random.PRNGKey(1))
    B, T, n_new = 2, 16, 4
    prompts = np.random.default_rng(1).integers(0, cfg.vocab_size, (B, T))
    eng = ServeEngine(m, params, max_seq=T + n_new, batch_size=B)
    out = eng.generate(prompts, n_new=n_new)

    full = np.concatenate([prompts, out], axis=1)
    for i in range(n_new):
        pb = {"tokens": jnp.asarray(full[:, : T + i], jnp.int32)}
        c = m.init_cache(B, T + n_new)
        _, logits = jax.jit(m.prefill)(params, pb, c)
        want = np.asarray(jnp.argmax(logits, -1))
        np.testing.assert_array_equal(want, out[:, i])


# ---------------------------------------------------------------------------
# training loop (integration, tiny)
# ---------------------------------------------------------------------------


def test_train_loop_runs_and_resumes(tmp_path):
    from repro.launch.train import main as train_main

    train_main([
        "--arch", "repro-100m", "--reduced", "--steps", "30",
        "--batch", "4", "--seq", "32", "--ckpt-dir", str(tmp_path),
        "--ckpt-every", "15", "--log-every", "10",
    ])
    assert latest_step(tmp_path) == 30
    # resume: runs steps 30..40 from the checkpoint without error
    train_main([
        "--arch", "repro-100m", "--reduced", "--steps", "40",
        "--batch", "4", "--seq", "32", "--ckpt-dir", str(tmp_path),
        "--ckpt-every", "10", "--log-every", "10",
    ])
    assert latest_step(tmp_path) == 40


def test_grad_accumulation_equivalence():
    """accum=2 over a batch == accum=1 on the same batch (same update)."""
    from repro.configs import ARCHS
    from repro.models.lm import LM
    from repro.train.optim import OptConfig, adamw_init
    from repro.train.trainstep import make_train_step

    cfg = ARCHS["llama3-8b"].reduced()
    m = LM(cfg, remat=False)
    params = m.init(jax.random.PRNGKey(0))
    batch = {
        "tokens": jax.random.randint(jax.random.PRNGKey(1), (4, 32), 0, cfg.vocab_size),
        "labels": jax.random.randint(jax.random.PRNGKey(2), (4, 32), 0, cfg.vocab_size),
    }
    s1 = make_train_step(m, OptConfig(), accum=1)
    s2 = make_train_step(m, OptConfig(), accum=2)
    p1, _, m1 = jax.jit(s1)(params, adamw_init(params), batch)
    p2, _, m2 = jax.jit(s2)(params, adamw_init(params), batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]), rtol=1e-3)
    for a, b in zip(jax.tree_util.tree_leaves(p1), jax.tree_util.tree_leaves(p2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5)


# ---------------------------------------------------------------------------
# distributed paths (subprocess: needs >1 device)
# ---------------------------------------------------------------------------


def _run_subprocess(code: str, devices: int = 8):
    import os

    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC
    r = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, env=env, timeout=600,
    )
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    return r.stdout


def test_distributed_sample_sort_subprocess():
    out = _run_subprocess("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.core.distsort import sample_sort
        from repro.compat import make_mesh
        mesh = make_mesh((8,), ("data",))
        rng = np.random.default_rng(0)
        n, W = 8 * 512, 2
        words = rng.integers(0, 2**32, size=(n, W), dtype=np.uint32)
        res = sample_sort(jnp.asarray(words),
                          jnp.arange(n, dtype=jnp.uint32), mesh, "data")
        k = np.asarray(res.keys)[np.asarray(res.valid)]
        assert k.shape[0] == n
        t = [tuple(r) for r in k]
        assert t == sorted(t)
        assert int(res.overflow) == 0
        print("DIST SORT OK")
    """)
    assert "DIST SORT OK" in out


def test_distributed_reconstruction_subprocess():
    """Full pipeline with the distributed sort: extract -> sample_sort
    agrees with the single-device sort."""
    out = _run_subprocess("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.configs.paper_index import ZipfConfig
        from repro.core import compress as C, dbits as D
        from repro.core.distsort import sample_sort
        from repro.data.synthetic import zipf_keys
        ks = zipf_keys(ZipfConfig(1.5, 40, 0, n_keys=4096), seed=2)
        n = (ks.n // 8) * 8
        words = jnp.asarray(ks.words[:n]); rids = jnp.arange(n, dtype=jnp.uint32)
        bm = D.compute_dbitmap(words)
        plan = C.make_plan(np.asarray(bm), ks.n_words)
        comp = C.extract_bits(words, plan)
        from repro.compat import make_mesh
        mesh = make_mesh((8,), ("data",))
        # Zipf keys are heavily skewed -> raise bucket capacity (overflow is
        # detected, never silent)
        res = sample_sort(comp, rids, mesh, "data", capacity_factor=4.0)
        assert int(res.overflow) == 0, int(res.overflow)
        got = np.asarray(res.rids)[np.asarray(res.valid)]
        (sw, want) = D.sort_words(comp, rids)
        np.testing.assert_array_equal(
            np.asarray(comp)[got], np.asarray(comp)[np.asarray(want)])
        print("DIST RECON OK")
    """)
    assert "DIST RECON OK" in out


def test_gradient_compression_subprocess():
    out = _run_subprocess("""
        import numpy as np, jax, jax.numpy as jnp
        from functools import partial
        from repro.train.compression import compressed_allreduce_grads, ef_init
        from jax.sharding import PartitionSpec as P
        from repro.compat import make_mesh
        mesh = make_mesh((8,), ("pod",))
        g = {"w": jnp.arange(8*32, dtype=jnp.float32).reshape(8, 32) / 100.0}
        ef = ef_init(g)
        from repro.compat import shard_map
        fn = shard_map(
            partial(compressed_allreduce_grads, axis_name="pod"),
            mesh=mesh, in_specs=(P("pod"), P("pod")),
            out_specs=(P(), P("pod")))
        out, new_ef = fn(g, ef)
        want = np.mean(np.asarray(g["w"]).reshape(8, 1, 32), axis=0)
        got = np.asarray(out["w"])
        err = np.abs(got - want).max()
        assert err < max(np.abs(want).max(), 1e-3) / 50, err
        print("COMPRESSED ALLREDUCE OK", err)
    """)
    assert "COMPRESSED ALLREDUCE OK" in out


def test_moe_sort_dispatch_under_mesh_subprocess():
    """The compressed-key-sort MoE dispatch compiles and runs sharded."""
    out = _run_subprocess("""
        import numpy as np, jax, jax.numpy as jnp
        from dataclasses import replace
        from repro.configs import ARCHS
        from repro.models.lm import LM
        from repro.distributed.ctx import use_mesh
        cfg = replace(ARCHS["qwen3-moe-235b-a22b"].reduced(),
                      dispatch_mode="sort")
        m = LM(cfg, remat=False)
        params = m.init(jax.random.PRNGKey(0))
        from repro.compat import make_mesh
        mesh = make_mesh((2, 4), ("data", "model"))
        batch = {"tokens": jnp.zeros((4, 32), jnp.int32),
                 "labels": jnp.zeros((4, 32), jnp.int32)}
        with use_mesh(mesh):
            loss, _ = jax.jit(m.loss)(params, batch)
        assert np.isfinite(float(loss))
        print("MOE SORT DISPATCH OK", float(loss))
    """)
    assert "MOE SORT DISPATCH OK" in out


def test_elastic_restore_subprocess():
    """Checkpoint saved unsharded restores onto a 2x4 mesh with the rule
    engine's shardings (elastic resharding path)."""
    out = _run_subprocess("""
        import numpy as np, jax, jax.numpy as jnp, tempfile
        from repro.ckpt.checkpoint import save_checkpoint, restore_checkpoint
        from repro.launch.shardings import params_shardings
        from repro.configs import ARCHS
        from repro.models.lm import LM
        cfg = ARCHS["llama3-8b"].reduced()
        m = LM(cfg, remat=False)
        params = m.init(jax.random.PRNGKey(0))
        d = tempfile.mkdtemp()
        save_checkpoint(d, 1, params)
        from repro.compat import make_mesh
        mesh = make_mesh((2, 4), ("data", "model"))
        sh = params_shardings(mesh, jax.eval_shape(lambda: params))
        like = jax.tree_util.tree_map(np.zeros_like, params)
        got, stats = restore_checkpoint(d, 1, like, shardings=sh)
        leaf = got["blocks"]["0"]["wq"]
        assert len(leaf.sharding.device_set) > 1
        for a, b in zip(jax.tree_util.tree_leaves(params),
                        jax.tree_util.tree_leaves(got)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        print("ELASTIC RESTORE OK")
    """)
    assert "ELASTIC RESTORE OK" in out


# ---------------------------------------------------------------------------
# dry-run artifacts (deliverable (e)) — validated from the committed runs
# ---------------------------------------------------------------------------


def test_dryrun_artifacts_complete():
    """Every (arch x shape x mesh) cell is present and ok/skip per the
    assignment's applicability rules (no errors), and fits per device."""
    root = Path(__file__).resolve().parents[1] / "experiments" / "dryrun"
    if not root.exists():
        pytest.skip("dry-run artifacts not generated yet")
    from repro.configs import ARCHS, SHAPES, shape_applies

    for mesh in ("pod1", "pod2"):
        mdir = root / mesh
        if not mdir.exists():
            pytest.skip(f"{mesh} artifacts not generated yet")
        for a, cfg in ARCHS.items():
            for s, shape in SHAPES.items():
                f = mdir / f"{a}__{s}.json"
                assert f.exists(), f"missing dry-run cell {mesh}/{a}/{s}"
                d = json.loads(f.read_text())
                ok, _ = shape_applies(cfg, shape)
                want = "ok" if ok else "skipped"
                assert d["status"] == want, (mesh, a, s, d.get("error", ""))
                if ok:
                    peak = d["memory_analysis"].get("peak_memory_in_bytes", 0)
                    assert peak < 16 * 2**30, (mesh, a, s, peak)
