"""Replication layer + incremental reconstruction tests (no dev extras).

The merge contract (repro.backends.base): ``merge_sorted`` over two
ascending (key, row) runs must be byte-identical to ``sort`` over their
concatenation, on every backend.  ``run_incremental`` layers the same
guarantee end to end: its sorted compressed keys, rid permutation and tree
levels must match a full ``run`` over the folded keyset — including on
duplicate-heavy keysets, deletes-only / empty-delta edge cases, and with
the full-path fallback when the D-bitmap grew.
"""

import numpy as np
import pytest

import jax.numpy as jnp

from repro.backends import get_backend
from repro.core.dbits import merge_words_keyed, sort_words_keyed
from repro.core.keyformat import KeySet
from repro.core.metadata import meta_from_keys
from repro.core.pipeline import ReconstructionPipeline, fold_keyset
from repro.replication import ChangeLog, Replica

BACKENDS = ("jnp", "pallas", "distributed")


def _keyset(rng, n, w=3, mask=0x00FF0F0F, rid_base=0) -> KeySet:
    words = rng.integers(0, 2**32, size=(n, w), dtype=np.uint32) & np.uint32(mask)
    return KeySet(
        words=words,
        lengths=np.full(n, w * 4, np.int32),
        rids=np.arange(rid_base, rid_base + n, dtype=np.uint32),
    )


def _sorted_run(rng, n, w, mask, rows):
    keys = rng.integers(0, 2**32, size=(n, w), dtype=np.uint32) & np.uint32(mask)
    if n == 0:
        return jnp.asarray(keys), jnp.asarray(rows, jnp.uint32)
    return sort_words_keyed(jnp.asarray(keys), jnp.asarray(rows, jnp.uint32))


def _assert_result_identical(a, b):
    np.testing.assert_array_equal(np.asarray(a.comp_sorted), np.asarray(b.comp_sorted))
    np.testing.assert_array_equal(np.asarray(a.rid_sorted), np.asarray(b.rid_sorted))
    np.testing.assert_array_equal(np.asarray(a.row_sorted), np.asarray(b.row_sorted))
    np.testing.assert_array_equal(a.meta.dbitmap, b.meta.dbitmap)
    assert len(a.tree.levels) == len(b.tree.levels)
    for la, lb in zip(a.tree.levels, b.tree.levels):
        for k in la:
            np.testing.assert_array_equal(np.asarray(la[k]), np.asarray(lb[k]))
    for k in a.tree.leaf:
        np.testing.assert_array_equal(
            np.asarray(a.tree.leaf[k]), np.asarray(b.tree.leaf[k])
        )


# ---------------------------------------------------------------------------
# merge_sorted backend contract
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("na,nb", [(3000, 150), (500, 500), (100, 0), (0, 100)])
def test_merge_sorted_matches_sort_every_backend(rng, na, nb):
    """Duplicate-heavy runs with interleaved row ids: the merge must equal
    the full keyed sort of the concatenation, byte for byte."""
    w, mask = 3, 0x000F0F0F  # heavy duplicates
    rows = np.arange(na + nb, dtype=np.uint32)
    rng.shuffle(rows)
    ka, ra = _sorted_run(rng, na, w, mask, rows[:na])
    kb, rb = _sorted_run(rng, nb, w, mask, rows[na:])
    all_k = jnp.concatenate([ka, kb], axis=0)
    all_r = jnp.concatenate([ra, rb])
    ref_k, ref_r = sort_words_keyed(all_k, all_r)
    for name in BACKENDS:
        mk, mr = get_backend(name).merge_sorted(ka, ra, kb, rb)
        np.testing.assert_array_equal(np.asarray(mk), np.asarray(ref_k), err_msg=name)
        np.testing.assert_array_equal(np.asarray(mr), np.asarray(ref_r), err_msg=name)


def test_merge_kernel_matches_numpy_ref(rng):
    from repro.kernels.merge import merge_ranks
    from repro.kernels.merge.ref import merge_ranks_ref

    w, mask = 2, 0x3F
    ks, rs = _sorted_run(rng, 200, w, mask, np.arange(200, dtype=np.uint32))
    kq = rng.integers(0, 2**32, size=(77, w), dtype=np.uint32) & np.uint32(mask)
    rq = np.arange(200, 277, dtype=np.uint32)
    got = np.asarray(merge_ranks(jnp.asarray(kq), jnp.asarray(rq), ks, rs))
    want = merge_ranks_ref(kq, rq, np.asarray(ks), np.asarray(rs))
    np.testing.assert_array_equal(got, want)


def test_merge_words_keyed_is_permutation(rng):
    """The rank scatter must be collision-free for distinct rows."""
    ka, ra = _sorted_run(rng, 512, 2, 0x7, np.arange(512, dtype=np.uint32))
    kb, rb = _sorted_run(rng, 256, 2, 0x7, np.arange(512, 768, dtype=np.uint32))
    mk, mr = merge_words_keyed(ka, ra, kb, rb)
    assert sorted(np.asarray(mr).tolist()) == list(range(768))


# ---------------------------------------------------------------------------
# run_incremental == full run on the folded keyset
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", BACKENDS)
def test_run_incremental_byte_identical(rng, backend):
    n, nd, w = 4000, 200, 3
    all_words = rng.integers(0, 2**32, size=(n + nd, w), dtype=np.uint32) & np.uint32(
        0x00FF0F0F
    )
    meta = meta_from_keys(all_words)  # union metadata: no bit growth later
    base = KeySet(
        words=all_words[:n], lengths=np.full(n, 12, np.int32),
        rids=np.arange(n, dtype=np.uint32),
    )
    delta = KeySet(
        words=all_words[n:], lengths=np.full(nd, 12, np.int32),
        rids=np.arange(50_000, 50_000 + nd, dtype=np.uint32),
    )
    keep = rng.random(n) > 0.05
    pipe = ReconstructionPipeline(backend=backend)
    prev = pipe.run(base, meta=meta)
    inc, folded = pipe.run_incremental(prev, base, delta, keep_rows=keep, meta=meta)
    assert inc.stats["incremental"] is True
    assert inc.stats["n_delta"] == nd
    assert inc.stats["n_deleted"] == int(n - keep.sum())
    full = pipe.run(folded, meta=meta)
    _assert_result_identical(inc, full)


def test_run_incremental_empty_delta_and_deletes_only(rng):
    n = 1500
    base = _keyset(rng, n)
    meta = meta_from_keys(base.words)
    pipe = ReconstructionPipeline()
    prev = pipe.run(base, meta=meta)

    # empty delta, no deletes: the merged run IS the previous run
    inc, folded = pipe.run_incremental(prev, base, None, meta=meta)
    assert inc.stats["incremental"] is True
    _assert_result_identical(inc, pipe.run(folded, meta=meta))
    np.testing.assert_array_equal(
        np.asarray(inc.comp_sorted), np.asarray(prev.comp_sorted)
    )

    # deletes only: filtered base run, renumbered rows
    keep = rng.random(n) > 0.2
    inc2, folded2 = pipe.run_incremental(prev, base, None, keep_rows=keep, meta=meta)
    assert folded2.n == int(keep.sum())
    _assert_result_identical(inc2, pipe.run(folded2, meta=meta))


def test_run_incremental_all_duplicate_keys(rng):
    """Degenerate keyset (empty D-bitmap, one-bit plan convention)."""
    n, nd = 64, 16
    words = np.full((n, 2), 7, np.uint32)
    base = KeySet(words=words, lengths=np.full(n, 8, np.int32),
                  rids=np.arange(n, dtype=np.uint32))
    meta = meta_from_keys(words)
    delta = KeySet(words=np.full((nd, 2), 7, np.uint32),
                   lengths=np.full(nd, 8, np.int32),
                   rids=np.arange(1000, 1000 + nd, dtype=np.uint32))
    pipe = ReconstructionPipeline()
    prev = pipe.run(base, meta=meta)
    inc, folded = pipe.run_incremental(prev, base, delta, meta=meta)
    assert inc.stats["incremental"] is True
    _assert_result_identical(inc, pipe.run(folded, meta=meta))


def test_run_incremental_falls_back_when_bitmap_grew(rng):
    from dataclasses import replace

    from repro.core.metadata import _set_bit

    base = _keyset(rng, 1000, w=2, mask=0xFF)
    meta = meta_from_keys(base.words)
    pipe = ReconstructionPipeline()
    prev = pipe.run(base, meta=meta)
    grown = replace(meta, dbitmap=_set_bit(meta.dbitmap, 2))
    delta = KeySet(
        words=base.words[:3] | np.uint32(1 << 29),
        lengths=np.full(3, 8, np.int32),
        rids=np.arange(9000, 9003, dtype=np.uint32),
    )
    inc, folded = pipe.run_incremental(prev, base, delta, meta=grown)
    assert inc.stats["incremental"] is False
    assert inc.stats["incremental_fallback"] == "dbitmap_changed"
    _assert_result_identical(inc, pipe.run(folded, meta=grown))


# ---------------------------------------------------------------------------
# ChangeLog semantics + serialization
# ---------------------------------------------------------------------------


def test_changelog_fold_replay_semantics():
    log = ChangeLog(n_words=2)
    base_rids = np.asarray([0, 1, 2, 3], np.uint32)
    k = lambda v: np.asarray([[v, v]], np.uint32)
    log.append_inserts(k(10), [10])          # plain insert, survives
    log.append_inserts(k(11), [11])          # insert then delete -> dead
    log.append_deletes([11])
    log.append_deletes([2])                  # base delete
    log.append_deletes([3])                  # base delete then reinsert:
    log.append_inserts(k(33), [3])           #   base row dead, insert lives
    keep, iw, il, ir = log.fold(base_rids)
    assert keep.tolist() == [True, True, False, False]
    assert ir.tolist() == [10, 3]
    assert iw[:, 0].tolist() == [10, 33]
    assert il.tolist() == [8, 8]
    assert len(log) == 6 and log.next_lsn == 6


def test_changelog_npz_roundtrip(tmp_path):
    rng = np.random.default_rng(0)
    log = ChangeLog(n_words=3, start_lsn=17)
    log.append_inserts(
        rng.integers(0, 2**32, size=(9, 3), dtype=np.uint32),
        np.arange(9, dtype=np.uint32),
        lengths=np.full(9, 10, np.int32),
    )
    log.append_deletes([4, 5])
    path = log.save(tmp_path / "log.npz")
    back = ChangeLog.load(path)
    assert back.n_words == 3 and back.start_lsn == 17
    assert back.next_lsn == log.next_lsn
    a, b = log.arrays(), back.arrays()
    for key in a:
        np.testing.assert_array_equal(a[key], b[key])


def test_changelog_empty_fold():
    log = ChangeLog(n_words=2)
    keep, iw, il, ir = log.fold(np.asarray([5, 6], np.uint32))
    assert keep.tolist() == [True, True] and iw.shape == (0, 2)


# ---------------------------------------------------------------------------
# Replica
# ---------------------------------------------------------------------------


def test_replica_matches_scratch_rebuild(rng):
    base = _keyset(rng, 3000, mask=0x0FFF00FF)
    rep = Replica(base)
    log = ChangeLog(3)
    ins = rng.integers(0, 2**32, size=(120, 3), dtype=np.uint32) & np.uint32(0x0FFF00FF)
    log.append_inserts(ins, np.arange(90_000, 90_120, dtype=np.uint32))
    log.append_deletes(np.arange(40, 80, dtype=np.uint32))
    st = rep.apply(log)
    assert st["n_delta"] == 120 and st["n_deleted"] == 40
    assert rep.applied_lsn == log.next_lsn - 1
    # the replica's index answers identically to a from-scratch rebuild of
    # the folded table under the replica's metadata
    scratch = ReconstructionPipeline().run(rep.keyset, meta=rep.meta)
    np.testing.assert_array_equal(
        np.asarray(rep.result.rid_sorted), np.asarray(scratch.rid_sorted)
    )
    found, rid = rep.search(ins[7])
    assert found and rid in range(90_000, 90_120)
    # deleted rid no longer reachable via its key unless duplicated
    assert rep.keyset.n == 3000 - 40 + 120


def test_replica_consecutive_batches_stay_incremental(rng):
    base = _keyset(rng, 4096, mask=0x00FF00FF)
    rep = Replica(base)
    lsn = 0
    n_inc = 0
    for b in range(3):
        log = ChangeLog(3, start_lsn=lsn)
        pick = rng.integers(0, rep.keyset.n, size=64)
        log.append_inserts(
            np.asarray(rep.keyset.words)[pick],
            np.arange(10_000 + 100 * b, 10_064 + 100 * b, dtype=np.uint32),
        )
        lsn = log.next_lsn
        st = rep.apply(log)
        n_inc += int(st["incremental"])
    # re-drawn existing keys add no distinction bits -> every batch merges
    assert n_inc == 3


# ---------------------------------------------------------------------------
# OnlineIndex incremental rebuild
# ---------------------------------------------------------------------------


def test_online_index_rebuild_incremental_and_correct(rng):
    from repro.core.index import OnlineIndex

    base = np.unique(
        rng.integers(0, 2**32, size=(400, 2), dtype=np.uint32) & np.uint32(0x0FFF0FFF),
        axis=0,
    )
    ks = KeySet(words=base, lengths=np.full(len(base), 8, np.int32),
                rids=np.arange(len(base), dtype=np.uint32))
    oi = OnlineIndex.build(ks)
    # duplicate existing keys: the insert rule sets no new bits
    dup = [base[i] for i in (3, 50, 99)]
    for j, k in enumerate(dup):
        oi.insert(k, rid=70_000 + j)
    oi.delete(base[10])
    oi2 = oi.rebuild()
    assert oi2.result.stats["incremental"] is True
    # the carried bitmap is pinned to the extraction bitmap, so a quiet
    # follow-up rebuild (even after the delete shed bits) merges again
    oi2b = oi2.rebuild()
    assert oi2b.result.stats["incremental"] is True
    assert oi2.keyset.n == len(base) + len(dup) - 1
    for j, k in enumerate(dup):
        found, rid = oi2.search(k)
        assert found
    found, _ = oi2.search(base[10])
    assert not found
    # a rebuild after a bit-growing insert falls back but stays correct
    newkey = base[0] | np.uint32(0x80000000)
    oi2.insert(newkey, rid=80_000)
    oi3 = oi2.rebuild()
    assert oi3.search(newkey) == (True, 80_000)


# ---------------------------------------------------------------------------
# checkpoint delta steps
# ---------------------------------------------------------------------------


def _tree():
    rng = np.random.default_rng(1)
    return {
        "wte": rng.normal(size=(16, 8)).astype(np.float32),
        "block": {"w1": rng.normal(size=(8, 8)).astype(np.float32),
                  "w2": rng.normal(size=(8,)).astype(np.float32)},
    }


def test_delta_checkpoint_roundtrip_and_chain(tmp_path):
    import jax

    from repro.ckpt.checkpoint import (
        restore_checkpoint,
        save_checkpoint,
        save_checkpoint_delta,
    )

    t1 = _tree()
    save_checkpoint(tmp_path, 1, t1)
    t2 = {"wte": t1["wte"] + 1, "block": dict(t1["block"])}
    save_checkpoint_delta(tmp_path, 2, t2, base_step=1)
    like = jax.tree_util.tree_map(np.zeros_like, t2)
    got, stats = restore_checkpoint(tmp_path, 2, like)
    for a, b in zip(jax.tree_util.tree_leaves(t2), jax.tree_util.tree_leaves(got)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # only changed keys move: the restore replays the log incrementally
    assert stats["incremental"] is True
    # chain: delta-on-delta with another changed leaf
    t3 = {"wte": t2["wte"], "block": {"w1": t2["block"]["w1"] * 2,
                                      "w2": t2["block"]["w2"]}}
    save_checkpoint_delta(tmp_path, 3, t3, base_step=2)
    got3, stats3 = restore_checkpoint(tmp_path, 3, like)
    for a, b in zip(jax.tree_util.tree_leaves(t3), jax.tree_util.tree_leaves(got3)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert stats3["meta"]["base_step"] == 2


def test_restore_checkpoint_backend_plumbed(tmp_path):
    import jax

    from repro.ckpt.checkpoint import restore_checkpoint, save_checkpoint

    t1 = _tree()
    save_checkpoint(tmp_path, 5, t1)
    like = jax.tree_util.tree_map(np.zeros_like, t1)
    _, stats = restore_checkpoint(tmp_path, 5, like, backend="pallas")
    assert stats["index_backend"] == "pallas"
    _, stats = restore_checkpoint(tmp_path, 5, like)
    assert stats["index_backend"] == "jnp"


# ---------------------------------------------------------------------------
# serving pager log replay
# ---------------------------------------------------------------------------


def test_pager_restart_replays_log(rng):
    from repro.serve.pager import PagedKVManager

    pm = PagedKVManager(n_pages=128, page_tokens=16)
    for s in range(6):
        pm.pages_for(s, 80)
    pm.rebuild_index()
    assert pm.stats["last_rebuild"]["incremental"] is False  # first build
    pm.free_seq(1)
    pm.pages_for(3, 160)  # extend an existing seq: no new key bits
    res = pm.rebuild_index()
    info = pm.stats["last_rebuild"]
    assert info["log_entries_replayed"] > 0
    # lookups agree with the table either way
    for (s, p), phys in list(pm._table.items()):
        assert pm.lookup(s, p) == phys
    assert pm.lookup(1, 0) is None
    # quiet restart folds an empty log through the merge path
    pm.rebuild_index()
    assert pm.stats["last_rebuild"]["incremental"] is True
    assert pm.stats["last_rebuild"]["log_entries_replayed"] == 0


def test_pager_realloc_of_mapped_slot_stays_consistent():
    """Re-alloc of an already-mapped (seq, page) must retire the old
    physical page in the log, or replay diverges from the table."""
    from repro.serve.pager import PagedKVManager

    pm = PagedKVManager(n_pages=32, page_tokens=8)
    for s in range(4):
        pm.alloc(s, 0)
    pm.rebuild_index()
    old = pm._table[(3, 0)]
    new = pm.alloc(3, 0)  # overwrite the mapping
    assert new != old
    res = pm.rebuild_index()
    assert res.comp_sorted.shape[0] == len(pm._table) == 4
    assert pm.lookup(3, 0) == new
    assert old in pm._free  # the retired page is allocatable again


# ---------------------------------------------------------------------------
# batched run_many on pallas (satellite)
# ---------------------------------------------------------------------------


def test_run_many_batched_on_pallas(rng):
    pipe = ReconstructionPipeline(backend="pallas")
    ref = ReconstructionPipeline(backend="jnp")
    keysets = [
        _keyset(rng, 900, mask=m) for m in (0x00FF0F0F, 0x0FF000FF, 0x000FFF0F)
    ]
    out = pipe.run_many(keysets)
    for ks, res in zip(keysets, out):
        assert res.stats.get("batched") == 3
        single = ref.run(ks)
        np.testing.assert_array_equal(
            np.asarray(res.rid_sorted), np.asarray(single.rid_sorted)
        )
        np.testing.assert_array_equal(
            np.asarray(res.comp_sorted), np.asarray(single.comp_sorted)
        )
