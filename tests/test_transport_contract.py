"""The transport contract, enforced uniformly across every realization.

Every :class:`~repro.replication.transport.Transport` must honor the same
observable semantics — dense never-reused positions, ``None`` past the
end, :class:`FrameTruncated` below ``first_pos``, retention that keeps
numbering — because the stream protocol's correctness proofs quantify
over *any* conforming transport.  This suite runs the contract against
``QueueTransport``, ``DirectoryTransport``, and a zero-fault
``FaultyTransport`` (the chaos wrapper must be a transparent conformer
when its plan injects nothing — otherwise soak results under faults say
nothing about the protocol).
"""

import pytest

from repro.replication import (
    DirectoryTransport,
    FaultyTransport,
    FrameTruncated,
    QueueTransport,
)

KINDS = ["queue", "dir", "faulty-zero"]


def make_transport(kind: str, tmp_path):
    """A fresh transport of the requested kind rooted under ``tmp_path``."""
    if kind == "queue":
        return QueueTransport()
    if kind == "dir":
        return DirectoryTransport(tmp_path / "spool")
    if kind == "faulty-zero":
        # a zero-fault plan: the wrapper must be a transparent pass-through
        return FaultyTransport(QueueTransport())
    raise ValueError(kind)


@pytest.fixture(params=KINDS)
def transport(request, tmp_path):
    return make_transport(request.param, tmp_path)


def test_empty_transport(transport):
    assert transport.first_pos() == transport.end() == 0
    assert transport.read(0) is None
    assert len(transport) == 0


def test_publish_assigns_dense_positions(transport):
    for i in range(6):
        assert transport.publish(f"f{i}".encode()) == i
    assert transport.end() == 6
    for i in range(6):
        assert transport.read(i) == f"f{i}".encode()
    assert transport.read(6) is None  # past the end: wait, not an error


def test_frames_are_copied_not_aliased(transport):
    buf = bytearray(b"mutable")
    transport.publish(bytes(buf))
    buf[0] = ord("X")
    assert transport.read(0) == b"mutable"


def test_truncation_semantics(transport):
    for i in range(5):
        transport.publish(f"f{i}".encode())
    assert transport.truncate_before(3) == 3
    assert transport.first_pos() == 3 and transport.end() == 5
    assert len(transport) == 2
    with pytest.raises(FrameTruncated):
        transport.read(0)
    with pytest.raises(FrameTruncated):
        transport.read(2)
    assert transport.read(3) == b"f3"
    # truncating at or below first_pos is a no-op, not an error
    assert transport.truncate_before(3) == 0
    assert transport.truncate_before(0) == 0
    assert transport.first_pos() == 3


def test_positions_never_reused(transport):
    for i in range(4):
        transport.publish(f"f{i}".encode())
    transport.truncate_before(4)  # empty the retained window entirely
    assert transport.first_pos() == transport.end() == 4
    assert transport.publish(b"next") == 4  # numbering continues
    transport.truncate_before(5)
    assert transport.publish(b"again") == 5


def test_interleaved_publish_truncate_read(transport):
    pos = []
    for i in range(3):
        pos.append(transport.publish(f"a{i}".encode()))
    transport.truncate_before(2)
    pos.append(transport.publish(b"b"))
    assert pos == [0, 1, 2, 3]
    assert transport.read(2) == b"a2" and transport.read(3) == b"b"
    with pytest.raises(FrameTruncated):
        transport.read(1)


@pytest.mark.parametrize("kind", ["dir"])
def test_restart_recovers_position_state(tmp_path, kind):
    """A re-opened durable transport resumes numbering and retention."""
    t = make_transport(kind, tmp_path)
    for i in range(4):
        t.publish(f"f{i}".encode())
    t.truncate_before(2)
    # a brand-new instance over the same spool sees the same stream
    t2 = make_transport(kind, tmp_path)
    assert t2.first_pos() == 2 and t2.end() == 4
    assert t2.read(3) == b"f3"
    with pytest.raises(FrameTruncated):
        t2.read(1)
    assert t2.publish(b"f4") == 4


@pytest.mark.parametrize("kind", ["dir"])
def test_restart_after_full_truncation(tmp_path, kind):
    """END marker semantics: an emptied spool still resumes numbering."""
    t = make_transport(kind, tmp_path)
    for i in range(3):
        t.publish(f"f{i}".encode())
    t.truncate_before(3)
    t2 = make_transport(kind, tmp_path)
    assert t2.first_pos() == t2.end() == 3
    assert t2.publish(b"f3") == 3


def test_torn_frame_invisible(tmp_path):
    """A crashed mid-write publisher leaves no readable partial frame."""
    t = DirectoryTransport(tmp_path / "spool")
    t.publish(b"ok")
    (tmp_path / "spool" / ".tmp_frame_0000000001.bin").write_bytes(b"torn")
    assert t.end() == 1
    assert t.read(1) is None


def test_noop_truncation_skips_end_marker(tmp_path):
    """A truncation that drops nothing must not churn the spool: the END
    marker is written only when frames were actually removed."""
    t = DirectoryTransport(tmp_path / "spool")
    t.publish(b"a")
    t.publish(b"b")
    assert t.truncate_before(0) == 0
    assert not (tmp_path / "spool" / "END").exists()
    assert t.truncate_before(1) == 1
    assert (tmp_path / "spool" / "END").exists()


def test_zero_fault_wrapper_records_nothing(tmp_path):
    """Transparency is checkable: the pass-through plan injects zero
    faults, so the ledger stays empty across a full publish/read cycle."""
    t = make_transport("faulty-zero", tmp_path)
    for i in range(8):
        t.publish(f"f{i}".encode())
    for i in range(8):
        assert t.read(i) == f"f{i}".encode()
    t.truncate_before(4)
    assert t.ledger == [] and t.counts == {}
