"""Compression + sort layer tests, incl. the paper's ratios machinery."""

import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # property tests need the dev extra
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import compress as C
from repro.core import dbits as D
from repro.core.sortkeys import compressed_key_sort, full_key_sort, word_comparison_counts


@st.composite
def masked_keys(draw):
    w = draw(st.integers(1, 5))
    n = draw(st.integers(2, 80))
    masks = [draw(st.integers(0, 2**32 - 1)) for _ in range(w)]
    rng = np.random.default_rng(draw(st.integers(0, 10**6)))
    arr = rng.integers(0, 2**32, size=(n, w), dtype=np.uint32) & np.asarray(
        masks, np.uint32
    )
    return arr


@given(masked_keys())
@settings(max_examples=40, deadline=None)
def test_static_vs_dynamic_extraction(arr):
    jw = jnp.asarray(arr)
    bm = D.compute_dbitmap(jw)
    plan = C.make_plan(np.asarray(bm), arr.shape[1])
    a = C.extract_bits(jw, plan)
    b = C.extract_bits_dynamic(jw, bm, plan.n_words_out)
    assert (np.asarray(a) == np.asarray(b)).all()


@given(masked_keys())
@settings(max_examples=40, deadline=None)
def test_compressed_sort_matches_full_sort(arr):
    arr = np.unique(arr, axis=0)
    if len(arr) < 2:
        return
    rng = np.random.default_rng(1)
    arr = arr[rng.permutation(len(arr))]
    jw = jnp.asarray(arr)
    rids = jnp.arange(len(arr), dtype=jnp.uint32)
    full = full_key_sort(jw, rids)
    bm = D.compute_dbitmap(jw)
    plan = C.make_plan(np.asarray(bm), arr.shape[1])
    comp = compressed_key_sort(jw, rids, plan)
    assert (np.asarray(full.rids) == np.asarray(comp.rids)).all()


def test_extraction_plan_bit_order():
    """Compressed keys preserve significance order: bit positions ascending
    source map to ascending output positions."""
    bm = np.asarray([0x80000001, 0x00000000, 0xC0000000], np.uint32)
    plan = C.make_plan(bm, 3)
    assert plan.positions == (0, 31, 64, 65)
    assert plan.n_words_out == 1
    # key with bits: pos0=1, pos31=0, pos64=1, pos65=0 -> compressed 1010...
    key = jnp.asarray([[0x80000000, 0, 0x80000000]], jnp.uint32)
    out = C.extract_bits(key, plan)
    assert int(out[0, 0]) == 0b1010 << 28


def test_word_comparison_ratio_mechanism():
    """Compaction shrinks wcc even at equal key width (paper §6.3 effect 2):
    spread distinction bits -> multiple words touched; compressed -> one."""
    rng = np.random.default_rng(2)
    n = 4096
    # word 0 and 2 invariant (constant prefix columns, the paper's Zipf-m
    # effect); the distinguishing entropy lives in words 1 and 3
    arr = np.zeros((n, 4), np.uint32)
    arr[:, 0] = 0x61616161
    arr[:, 2] = 0x62626262
    arr[:, 1] = rng.integers(0, 1 << 12, n).astype(np.uint32)
    arr[:, 3] = rng.integers(0, 1 << 12, n).astype(np.uint32)
    arr = np.unique(arr, axis=0)
    rng.shuffle(arr)
    jw = jnp.asarray(arr)
    (sf,) = D.sort_words(jw)
    bm = D.compute_dbitmap(jw)
    plan = C.make_plan(np.asarray(bm), 4)
    comp = C.extract_bits(jw, plan)
    (sc,) = D.sort_words(comp)
    wcc_full = float(word_comparison_counts(sf))
    wcc_comp = float(word_comparison_counts(sc))
    assert comp.shape[1] == 1
    assert wcc_comp == 1.0
    assert wcc_full > 1.5  # several words examined pre-compression
