"""Hypothesis property tests: shape bucketing is invisible in the output.

For every backend, at sizes straddling bucket boundaries
(``n = 2^k - 1, 2^k, 2^k + 1``), the bucketed (padded) sort / merge /
build outputs must be byte-identical to the unpadded references — and the
plan cache must register hits, not retraces, for repeat calls inside a
bucket.  (Deterministic versions of the key cases also run without
hypothesis in test_plancache.py; this module is the randomized sweep.)
"""

import numpy as np
import pytest

pytest.importorskip("hypothesis")  # property tests need the dev extra
import jax.numpy as jnp
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import plancache
from repro.core.dbits import merge_words_keyed, sort_words_keyed
from repro.core.keyformat import KeySet
from repro.core.pipeline import ReconstructionPipeline

BACKENDS = ("jnp", "pallas", "distributed")


def _pipe(backend):
    opts = {"interpret": True} if backend == "pallas" else None
    return ReconstructionPipeline(backend=backend, backend_opts=opts)


def _keyset(rng, n, w=3, mask=0x00FF0F0F):
    words = rng.integers(0, 2**32, size=(n, w), dtype=np.uint32) & np.uint32(mask)
    rids = np.arange(n, dtype=np.uint32)
    rng.shuffle(rids)
    return KeySet(words=words, lengths=np.full(n, w * 4, np.int32), rids=rids)


@settings(max_examples=8, deadline=None)
@given(
    k=st.integers(min_value=8, max_value=10),
    off=st.sampled_from([-1, 0, 1]),
    w=st.integers(min_value=1, max_value=3),
    seed=st.integers(min_value=0, max_value=2**16),
)
def test_padded_sort_byte_identical(k, off, w, seed):
    rng = np.random.default_rng(seed)
    n = 2**k + off
    keys = jnp.asarray(
        rng.integers(0, 2**32, size=(n, w), dtype=np.uint32) & np.uint32(0x0FF00FFF)
    )
    rows = jnp.asarray(rng.permutation(n).astype(np.uint32))
    ks_ref, rs_ref = sort_words_keyed(keys, rows)
    cache = plancache.PlanCache()
    ks_pad, rs_pad = plancache.sort_padded(keys, rows, cache=cache)
    np.testing.assert_array_equal(np.asarray(ks_ref), np.asarray(ks_pad))
    np.testing.assert_array_equal(np.asarray(rs_ref), np.asarray(rs_pad))
    # repeat call in the same bucket: hit, no trace
    t0 = cache.stats()["traces"]
    plancache.sort_padded(keys[: n - 1], rows[: n - 1], cache=cache)
    if plancache.bucket(n - 1) == plancache.bucket(n):
        assert cache.stats()["traces"] == t0
        assert cache.stats()["hits"] >= 1


@settings(max_examples=8, deadline=None)
@given(
    ka=st.integers(min_value=7, max_value=9),
    offa=st.sampled_from([-1, 0, 1]),
    nb=st.integers(min_value=0, max_value=70),
    seed=st.integers(min_value=0, max_value=2**16),
)
def test_padded_merge_byte_identical(ka, offa, nb, seed):
    rng = np.random.default_rng(seed)
    na = 2**ka + offa
    keys = rng.integers(0, 2**16, size=(na + nb, 2), dtype=np.uint32)
    rows = np.arange(na + nb, dtype=np.uint32)
    a_k, a_r = sort_words_keyed(jnp.asarray(keys[:na]), jnp.asarray(rows[:na]))
    b_k, b_r = sort_words_keyed(jnp.asarray(keys[na:]), jnp.asarray(rows[na:]))
    mk_ref, mr_ref = merge_words_keyed(a_k, a_r, b_k, b_r)
    mk, mr = plancache.merge_padded(a_k, a_r, b_k, b_r, cache=plancache.PlanCache())
    np.testing.assert_array_equal(np.asarray(mk_ref), np.asarray(mk))
    np.testing.assert_array_equal(np.asarray(mr_ref), np.asarray(mr))


@settings(max_examples=4, deadline=None)
@given(
    off=st.sampled_from([-1, 0, 1]),
    seed=st.integers(min_value=0, max_value=2**16),
)
def test_padded_build_parity_across_backends(off, seed):
    """Sorted keys, rid permutation, every tree level array and the
    refreshed bitmap agree across all three backends at boundary sizes."""
    rng = np.random.default_rng(seed)
    ks = _keyset(rng, 256 + off)
    ref = _pipe("jnp").run(ks)
    for backend in BACKENDS[1:]:
        res = _pipe(backend).run(ks)
        np.testing.assert_array_equal(
            np.asarray(ref.comp_sorted), np.asarray(res.comp_sorted)
        )
        np.testing.assert_array_equal(
            np.asarray(ref.rid_sorted), np.asarray(res.rid_sorted)
        )
        assert len(ref.tree.levels) == len(res.tree.levels)
        for la, lb in zip(ref.tree.levels, res.tree.levels):
            for key in la:
                np.testing.assert_array_equal(np.asarray(la[key]), np.asarray(lb[key]))
        for key in ref.tree.leaf:
            np.testing.assert_array_equal(
                np.asarray(ref.tree.leaf[key]), np.asarray(res.tree.leaf[key])
            )
        np.testing.assert_array_equal(ref.meta.dbitmap, res.meta.dbitmap)
