#!/usr/bin/env python
"""Chaos soak harness: replication under a hostile wire, byte-for-byte.

Drives a :class:`StreamPrimary` and a churning fleet of supervised
:class:`StreamReplica` consumers over a fault-injecting transport
(:class:`repro.replication.chaos.FaultyTransport`) with a seeded
:class:`ChaosPlan` — drops, duplicates, reorders, bit flips, delayed
visibility, spurious truncation signals, and scheduled mid-stream
retention cuts — plus mid-span replica kill/restart churn, and then
asserts the three invariants that make the fault layer trustworthy:

1. **byte identity** — after a fault-free drain every surviving replica's
   keyset, metadata, and standing reconstruction equal the primary's
   never-lagged tracked replica exactly;
2. **no quarantine leak** — bounded transient faults must be absorbed by
   the degradation ladder (retry -> resync -> checkpoint), never end in a
   quarantined supervisor;
3. **steady-state plan stability** — once the wire is quiet, warm
   constant-shape batches replay cached programs: the plan cache traces
   **zero** new programs during the measured steady rounds.

Every run is reproducible from ``(seed, transport, backend)``; the
injection ledger is part of the report, so a failure names exactly which
faults the schedule dealt.

Usage::

    PYTHONPATH=src python tools/chaos_soak.py --seeds 0-7 \
        --transports queue,dir --fast        # the CI smoke matrix
    PYTHONPATH=src python tools/chaos_soak.py --seeds 0-31 --soak  # full

Exits non-zero if any run violates an invariant.
"""

from __future__ import annotations

import argparse
import json
import sys
import tempfile
import time
from pathlib import Path

try:
    import repro  # noqa: F401
except ImportError:  # direct execution without PYTHONPATH=src
    sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import numpy as np

from repro.core import plancache
from repro.core.keyformat import KeySet
from repro.replication import (
    ChangeLog,
    ChaosPlan,
    DirectoryTransport,
    FaultyTransport,
    QueueTransport,
    ReplicaSupervisor,
    StreamPrimary,
    StreamReplica,
    SupervisorPolicy,
)

#: constant batch churn: equal insert/delete volume keeps the keyset size
#: (and therefore every plan-cache bucket) fixed across the whole soak
N_INS = N_DEL = 24
BASE_KEYS = 600


def _keyset(rng: np.random.Generator, n: int, w: int = 3) -> KeySet:
    words = rng.integers(0, 2**32, size=(n, w), dtype=np.uint32)
    words &= np.uint32(0x00FF0F0F)
    return KeySet(
        words=words,
        lengths=np.full(n, w * 4, np.int32),
        rids=np.arange(n, dtype=np.uint32),
    )


def _batch(rng: np.random.Generator, prim: StreamPrimary) -> ChangeLog:
    """One constant-shape batch: re-draw live keys, retire as many rids.

    Re-drawing live key words adds no new distinction bits (the §4.3
    insert rule lands on already-set positions), so every warm apply stays
    on the incremental path and replays cached programs.
    """
    ks = prim.replica.keyset
    log = ChangeLog(ks.n_words, start_lsn=prim.next_lsn)
    pick = rng.integers(0, ks.n, size=N_INS)
    log.append_inserts(
        np.asarray(ks.words)[pick],
        100_000 + rng.integers(0, 2**20, size=N_INS).astype(np.uint32),
    )
    dead = rng.choice(np.asarray(ks.rids), size=N_DEL, replace=False)
    log.append_deletes(dead)
    return log


def _identical(rep, ref) -> list[str]:
    """Byte-identity violations between a replica and the reference."""
    bad = []
    pairs = [
        ("keyset.words", rep.keyset.words, ref.keyset.words),
        ("keyset.rids", rep.keyset.rids, ref.keyset.rids),
        ("meta.dbitmap", rep.meta.dbitmap, ref.meta.dbitmap),
        ("meta.varbitmap", rep.meta.varbitmap, ref.meta.varbitmap),
        ("comp_sorted", rep.result.comp_sorted, ref.result.comp_sorted),
        ("rid_sorted", rep.result.rid_sorted, ref.result.rid_sorted),
    ]
    for name, a, b in pairs:
        if not np.array_equal(np.asarray(a), np.asarray(b)):
            bad.append(name)
    if rep.applied_lsn != ref.applied_lsn:
        bad.append(f"applied_lsn {rep.applied_lsn} != {ref.applied_lsn}")
    return bad


def _mk_supervisor(
    transport, backend: str, start_pos: int = 0
) -> ReplicaSupervisor:
    rep = StreamReplica(
        transport, backend=backend, start_pos=start_pos, reorder_window=4
    )
    # no real sleeping: the ladder's backoff schedule is exercised, the
    # wall clock is not (the whole soak must run in CI smoke time)
    return ReplicaSupervisor(
        rep, SupervisorPolicy(), clock=time.monotonic, sleep=lambda s: None
    )


def run_soak(
    seed: int,
    transport_kind: str,
    backend: str,
    workdir: str,
    steps: int = 24,
    n_replicas: int = 3,
    intensity: float = 1.0,
    steady_rounds: int = 3,
) -> dict:
    """One seeded chaos run; returns a report with a ``violations`` list."""
    rng = np.random.default_rng(seed)
    root = Path(workdir)
    if transport_kind == "queue":
        inner = QueueTransport()
    elif transport_kind == "dir":
        inner = DirectoryTransport(root / "spool")
    else:
        raise ValueError(f"unknown transport kind {transport_kind!r}")
    plan = ChaosPlan.sample(seed, n_publishes_hint=steps + 4,
                            intensity=intensity)
    wire = FaultyTransport(inner, plan)

    prim = StreamPrimary(
        wire, _keyset(rng, BASE_KEYS), backend=backend,
        ckpt_dir=str(root / "ckpt"), max_lag_batches=3,
    )
    sups = [_mk_supervisor(wire, backend) for _ in range(n_replicas)]
    kill_at, restart_at = max(2, steps // 3), max(3, steps // 2)
    n_killed = 0

    # ---- chaos phase: publish, churn replicas, pump at skewed cadences
    for step in range(1, steps + 1):
        prim.publish(_batch(rng, prim))
        if step == kill_at and len(sups) > 1:
            sups.pop()  # a replica dies mid-span, state lost
            n_killed += 1
        if step == restart_at:
            # a fresh replica joins mid-stream: its cursor starts at 0,
            # long since truncated — the catch-up ladder brings it up
            sups.append(_mk_supervisor(wire, backend))
        for i, sup in enumerate(sups):
            if step % (i + 1) == 0:  # skewed cadence: replica i lags i+1 steps
                sup.pump()

    # ---- drain phase: faults off, one fault-free checkpoint at head
    wire.quiesce()
    prim.flush()
    prim.checkpoint()
    violations: list[str] = []
    for i, sup in enumerate(sups):
        for _ in range(40):
            out = sup.pump()
            if out.get("state") == "quarantined":
                break
            if "error_class" not in out and out.get("lag_frames", 1) == 0:
                break
        else:
            violations.append(f"replica {i} never converged: {out}")
        if sup.state == "quarantined":
            violations.append(f"replica {i} quarantine leak: {sup.stats()}")
        elif sup.replica.replica is None:
            violations.append(f"replica {i} never built an index")
        else:
            bad = _identical(sup.replica.replica, prim.replica)
            if bad:
                violations.append(f"replica {i} diverged: {bad}")

    # ---- steady phase: warm the constant shapes, then demand 0 traces
    for _ in range(2):
        prim.publish(_batch(rng, prim))
        for sup in sups:
            sup.pump()
    t0 = plancache.cache_stats()["traces"]
    for _ in range(steady_rounds):
        prim.publish(_batch(rng, prim))
        for sup in sups:
            out = sup.pump()
            if "error_class" in out:
                violations.append(f"steady-state pump faulted: {out}")
    steady_traces = plancache.cache_stats()["traces"] - t0
    if steady_traces != 0:
        violations.append(f"steady_state_traces={steady_traces}, want 0")
    for i, sup in enumerate(sups):
        bad = _identical(sup.replica.replica, prim.replica)
        if bad:
            violations.append(f"replica {i} diverged post-steady: {bad}")

    return {
        "seed": seed,
        "transport": transport_kind,
        "backend": backend,
        "steps": steps,
        "plan": {
            k: getattr(plan, k)
            for k in ("p_drop_publish", "p_duplicate", "p_reorder",
                      "p_corrupt", "p_delay", "p_spurious_truncated",
                      "truncate_at")
        },
        "faults_injected": dict(wire.counts),
        "n_killed": n_killed,
        "survivors": len(sups),
        "steady_traces": int(steady_traces),
        "supervisors": [sup.stats() for sup in sups],
        "violations": violations,
    }


def _parse_seeds(spec: str) -> list[int]:
    """``"0-7"`` or ``"1,3,9"`` (or a mix) -> a list of seeds."""
    seeds: list[int] = []
    for part in spec.split(","):
        if "-" in part.strip().lstrip("-"):
            lo, hi = part.split("-", 1)
            seeds.extend(range(int(lo), int(hi) + 1))
        else:
            seeds.append(int(part))
    return seeds


def main(argv: "list[str] | None" = None) -> int:
    """CLI entry point; returns the process exit code."""
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--seeds", default="0-3", help="range (0-7) or list (1,3)")
    ap.add_argument("--transports", default="queue,dir")
    ap.add_argument("--backends", default="jnp")
    ap.add_argument("--steps", type=int, default=None,
                    help="chaos steps per run (default 12 fast / 40 soak)")
    ap.add_argument("--intensity", type=float, default=1.0,
                    help="scale all sampled fault probabilities")
    ap.add_argument("--fast", action="store_true",
                    help="CI smoke sizing (fewer steps, 2 replicas)")
    ap.add_argument("--soak", action="store_true",
                    help="full sweep sizing (long runs, 3 replicas)")
    ap.add_argument("--json", action="store_true",
                    help="dump the full per-run reports as JSON")
    args = ap.parse_args(argv)

    steps = args.steps or (40 if args.soak else 12 if args.fast else 24)
    n_replicas = 2 if args.fast else 3
    failures = 0
    reports = []
    for backend in args.backends.split(","):
        for kind in args.transports.split(","):
            for seed in _parse_seeds(args.seeds):
                with tempfile.TemporaryDirectory() as tmp:
                    rep = run_soak(
                        seed, kind.strip(), backend.strip(), tmp,
                        steps=steps, n_replicas=n_replicas,
                        intensity=args.intensity,
                    )
                reports.append(rep)
                ok = not rep["violations"]
                failures += 0 if ok else 1
                faults = sum(rep["faults_injected"].values())
                print(
                    f"[{'ok' if ok else 'FAIL'}] seed={seed} "
                    f"transport={rep['transport']} backend={rep['backend']} "
                    f"faults={faults} survivors={rep['survivors']} "
                    f"steady_traces={rep['steady_traces']}"
                    + ("" if ok else f" violations={rep['violations']}")
                )
    if args.json:
        print(json.dumps(reports, indent=2, default=str))
    print(f"{len(reports)} runs, {failures} failing")
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
