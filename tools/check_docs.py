#!/usr/bin/env python
"""Docs health check: intra-repo links resolve, docs track the modules.

Two failure classes, both cheap to check and expensive to let rot:

1. **Broken intra-repo markdown links** — every ``[text](target)`` in the
   repo's markdown whose target is a relative path must point at an
   existing file (anchors are stripped; external schemes and bare anchors
   are ignored).
2. **Docs drifting from the module list** — every package directory under
   ``src/repro`` (and the top-level ``compat`` module) must be mentioned
   in ``docs/architecture.md``; a new subsystem without an architecture
   note fails CI until it is documented.

Run from anywhere: ``python tools/check_docs.py``.  Exit code 0 = healthy.
Also invoked by ``tests/test_docs.py`` so the tier-1 suite carries it.
"""

from __future__ import annotations

import re
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
MD_LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
SKIP_DIRS = {".git", ".github", "node_modules", "__pycache__", ".tmp"}


def markdown_files() -> list[Path]:
    """Every tracked-looking markdown file in the repo."""
    out = []
    for p in REPO.rglob("*.md"):
        if not any(part in SKIP_DIRS for part in p.parts):
            out.append(p)
    return sorted(out)


def check_links() -> list[str]:
    """Broken relative links as ``file: target`` error strings."""
    errors = []
    for md in markdown_files():
        for target in MD_LINK.findall(md.read_text()):
            if re.match(r"^[a-z][a-z0-9+.-]*:", target):  # http:, mailto:, …
                continue
            if target.startswith("#"):  # intra-document anchor
                continue
            path = target.split("#", 1)[0]
            if not path:
                continue
            resolved = (md.parent / path).resolve()
            if not resolved.exists():
                errors.append(f"{md.relative_to(REPO)}: broken link -> {target}")
    return errors


def check_module_drift() -> list[str]:
    """src/repro packages missing from docs/architecture.md."""
    arch = REPO / "docs" / "architecture.md"
    if not arch.exists():
        return ["docs/architecture.md is missing"]
    text = arch.read_text()
    errors = []
    pkg_root = REPO / "src" / "repro"
    modules = sorted(
        p.name for p in pkg_root.iterdir()
        if p.is_dir() and (p / "__init__.py").exists()
    ) + ["compat"]
    for mod in modules:
        if not re.search(rf"\b{re.escape(mod)}\b", text):
            errors.append(
                f"docs/architecture.md: module 'repro.{mod}' is not mentioned"
            )
    return errors


def main() -> int:
    """Run both checks; print findings; nonzero exit on any."""
    errors = check_links() + check_module_drift()
    for e in errors:
        print(f"FAIL {e}")
    n_md = len(markdown_files())
    if errors:
        print(f"docs check: {len(errors)} problem(s) across {n_md} markdown files")
        return 1
    print(f"docs check ok: {n_md} markdown files, links + module list clean")
    return 0


if __name__ == "__main__":
    sys.exit(main())
