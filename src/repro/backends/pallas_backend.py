"""Pallas-kernel backend: PEXT plane extraction + bitonic VMEM block sort.

Extraction runs through ``repro.kernels.pext`` (the static shift/mask
schedule over word planes); the sort runs the paper's row-column structure
on one device: ``repro.kernels.bitonic`` sorts VMEM-sized blocks, then one
``lax.sort`` merges the block runs (Appendix A step 3.2's multiway merge).

``interpret`` is auto-selected from the platform: on TPU the kernels are
compiled by Mosaic; elsewhere the kernel *bodies* execute under the Pallas
interpreter so the same code path is validated on CPU CI.

The merge carries the row id as an extra least-significant key word: the
bitonic network is not stable, so ties must be re-broken on the row id to
meet the backend determinism contract (byte-identical output vs the jnp
oracle).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.compress import ExtractionPlan
from repro.core.dbits import sort_words_keyed
from repro.kernels.bitonic import ops as bitonic_ops
from repro.kernels.bitonic.kernel import DEFAULT_BLOCK
from repro.kernels.merge import ops as merge_ops
from repro.kernels.merge.kernel import DEFAULT_TILE as MERGE_TILE
from repro.kernels.pext import ops as pext_ops
from repro.kernels.pext.kernel import DEFAULT_TILE

from .base import ExecutionBackend, register_backend

__all__ = ["PallasBackend"]


@register_backend("pallas")
class PallasBackend(ExecutionBackend):
    """kernels/pext extraction + kernels/bitonic block sort."""

    supports_batched = True

    def __init__(
        self,
        interpret: bool | None = None,
        tile: int = DEFAULT_TILE,
        block: int = DEFAULT_BLOCK,
        merge_tile: int = MERGE_TILE,
    ) -> None:
        super().__init__()
        if interpret is None:
            interpret = jax.default_backend() != "tpu"
        self.interpret = bool(interpret)
        self.tile = int(tile)
        self.block = int(block)
        self.merge_tile = int(merge_tile)
        self.last_info = {"interpret": self.interpret}

    def extract(self, words: jnp.ndarray, plan: ExtractionPlan) -> jnp.ndarray:
        return pext_ops.pext(
            jnp.asarray(words, jnp.uint32),
            plan,
            tile=self.tile,
            interpret=self.interpret,
        )

    def sort(self, keys, rows):
        keys = jnp.asarray(keys, jnp.uint32)
        rows = jnp.asarray(rows, jnp.uint32)
        bk, brow = bitonic_ops.block_sort(
            keys, rows, block=self.block, interpret=self.interpret
        )
        # merge of block-sorted runs; the keyed sort restores the (key, row)
        # order the unstable bitonic network does not guarantee
        return sort_words_keyed(bk, brow)

    def merge_sorted(self, keys_a, rows_a, keys_b, rows_b):
        """kernels/merge tiled merge-path ranks + permutation scatter."""
        return merge_ops.merge_sorted(
            keys_a, rows_a, keys_b, rows_b,
            tile=self.merge_tile, interpret=self.interpret,
        )

    def batched_extract_sort(self, words, bitmaps, rows, plans):
        """Batched fast path: per-index pext extraction (each plan is a
        static kernel schedule), then ONE vmapped program over the stacked
        batch for the sort — the bitonic block-sort kernel vmaps by growing
        its grid, and the run merge rides along inside the same trace."""
        del bitmaps  # pext wants the static plans, not runtime bitmaps
        comp = jnp.stack(
            [
                pext_ops.pext(words[i], p, tile=self.tile, interpret=self.interpret)
                for i, p in enumerate(plans)
            ]
        )

        def one(c, r):
            bk, brow = bitonic_ops.block_sort(
                c, r, block=self.block, interpret=self.interpret
            )
            return sort_words_keyed(bk, brow)

        return jax.vmap(one)(comp, jnp.asarray(rows, jnp.uint32))
