"""Pallas-kernel backend: PEXT plane extraction + bitonic VMEM block sort.

Extraction runs through ``repro.kernels.pext`` (the static shift/mask
schedule over word planes); the sort runs the paper's row-column structure
on one device: ``repro.kernels.bitonic`` sorts VMEM-sized blocks, then one
``lax.sort`` merges the block runs (Appendix A step 3.2's multiway merge).

``interpret`` is auto-selected from the platform: on TPU the kernels are
compiled by Mosaic; elsewhere the kernel *bodies* execute under the Pallas
interpreter so the same code path is validated on CPU CI.

The merge carries the row id as an extra least-significant key word: the
bitonic network is not stable, so ties must be re-broken on the row id to
meet the backend determinism contract (byte-identical output vs the jnp
oracle).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.compress import ExtractionPlan
from repro.core.dbits import sort_words_keyed
from repro.core.plancache import get_cache, merge_padded, sort_padded
from repro.kernels.bitonic import ops as bitonic_ops
from repro.kernels.bitonic.kernel import DEFAULT_BLOCK
from repro.kernels.build import ops as build_ops
from repro.kernels.build.kernel import DEFAULT_TILE as BUILD_TILE
from repro.kernels.lookup import ops as lookup_ops
from repro.kernels.lookup.kernel import DEFAULT_TILE as LOOKUP_TILE
from repro.kernels.merge import ops as merge_ops
from repro.kernels.merge.kernel import DEFAULT_TILE as MERGE_TILE
from repro.kernels.pext import ops as pext_ops
from repro.kernels.pext.kernel import DEFAULT_TILE

from .base import ExecutionBackend, register_backend

__all__ = ["PallasBackend"]


@register_backend("pallas")
class PallasBackend(ExecutionBackend):
    """kernels/pext extraction + kernels/bitonic block sort."""

    supports_batched = True

    def __init__(
        self,
        interpret: bool | None = None,
        tile: int = DEFAULT_TILE,
        block: int = DEFAULT_BLOCK,
        merge_tile: int = MERGE_TILE,
        build_tile: int = BUILD_TILE,
        lookup_tile: int = LOOKUP_TILE,
    ) -> None:
        super().__init__()
        if interpret is None:
            interpret = jax.default_backend() != "tpu"
        self.interpret = bool(interpret)
        self.tile = int(tile)
        self.block = int(block)
        self.merge_tile = int(merge_tile)
        self.build_tile = int(build_tile)
        self.lookup_tile = int(lookup_tile)
        self.last_info = {"interpret": self.interpret}

    def extract(self, words: jnp.ndarray, plan: ExtractionPlan) -> jnp.ndarray:
        return pext_ops.pext(
            jnp.asarray(words, jnp.uint32),
            plan,
            tile=self.tile,
            interpret=self.interpret,
        )

    def sort(self, keys, rows, *, n_valid=None, keep_padded=False, donate=False):
        block, interpret = self.block, self.interpret

        def impl(kp, rp):
            bk, brow = bitonic_ops.block_sort(kp, rp, block=block, interpret=interpret)
            # merge of block-sorted runs; the keyed sort restores the
            # (key, row) order the unstable bitonic network does not
            # guarantee
            return sort_words_keyed(bk, brow)

        return sort_padded(
            jnp.asarray(keys, jnp.uint32), jnp.asarray(rows, jnp.uint32),
            backend=self.name, impl=impl, extra_key=(block, interpret),
            n_valid=n_valid, keep_padded=keep_padded, donate=donate,
        )

    def merge_sorted(self, keys_a, rows_a, keys_b, rows_b, *,
                     n_valid_a=None, n_valid_b=None, keep_padded=False,
                     donate=False):
        """kernels/merge tiled merge-path rank of the smaller run +
        complement scatter, shape-bucketed (one compiled program per
        (bucket_a, bucket_b)); donation rides on the outer jit."""
        tile, interpret = self.merge_tile, self.interpret

        def impl(ka, ra, kb, rb):
            return merge_ops.merge_sorted(ka, ra, kb, rb, tile=tile, interpret=interpret)

        return merge_padded(
            jnp.asarray(keys_a, jnp.uint32), jnp.asarray(rows_a, jnp.uint32),
            jnp.asarray(keys_b, jnp.uint32), jnp.asarray(rows_b, jnp.uint32),
            backend=self.name, impl=impl, extra_key=(tile, interpret),
            n_valid_a=n_valid_a, n_valid_b=n_valid_b,
            keep_padded=keep_padded, donate=donate,
        )

    def build(self, comp_sorted, row_sorted, meta, words, lengths, config,
              rids=None, n_valid=None, donate=False):
        """Cached build programs with the kernels/build tiled pk-window
        gather substituted for the jnp ``_slice_bits`` (bit-identical)."""
        from repro.core.btree import build_btree

        return build_btree(
            comp_sorted, row_sorted, meta, words, lengths, config, rids=rids,
            backend_name=self.name,
            slice_fn=build_ops.slice_fn(tile=self.build_tile, interpret=self.interpret),
            program_key_extra=(self.build_tile, self.interpret),
            n_valid=n_valid, donate=donate,
        )

    def lookup(self, tree, queries):
        """Plan-cached descent with the kernels/lookup partial-key probe
        at the leaf: candidates are screened by the tiled window kernel
        and confirmed with the full-key compare — byte-identical to the
        jnp oracle's unscreened compare by construction."""
        from repro.core.btree import lookup_batch_planned

        return lookup_batch_planned(
            tree,
            jnp.asarray(queries, jnp.uint32),
            backend_name=self.name,
            leaf_match_fn=lookup_ops.leaf_match_fn(
                tile=self.lookup_tile, interpret=self.interpret
            ),
            program_key_extra=(self.lookup_tile, self.interpret),
        )

    def lookup_many(self, stacked, queries, n_valid=None):
        """Fused multi-tenant lookup with the tenant-major probe kernel:
        the vmapped descent routes every tenant's queries, then ONE
        ``pallas_call`` over a (T, pairs/tile) grid screens all (tenant,
        query, entry) pairs before the full-key confirm — byte-identical
        per tenant to the single-snapshot pallas :meth:`lookup`."""
        from repro.core.btree import lookup_many_planned

        return lookup_many_planned(
            stacked,
            jnp.asarray(queries, jnp.uint32),
            n_valid,
            backend_name=self.name,
            leaf_match_many_fn=lookup_ops.leaf_match_many_fn(
                tile=self.lookup_tile, interpret=self.interpret
            ),
            program_key_extra=(self.lookup_tile, self.interpret),
        )

    def batched_extract_sort(self, words, bitmaps, rows, plans):
        """Batched fast path: per-index pext extraction (each plan is a
        static kernel schedule), then ONE vmapped program over the stacked
        batch for the sort — the bitonic block-sort kernel vmaps by growing
        its grid, and the run merge rides along inside the same trace.  The
        vmapped sort program is memoized in the plan cache per stacked
        shape, so repeated replication batches replay it."""
        del bitmaps  # pext wants the static plans, not runtime bitmaps
        comp = jnp.stack(
            [
                pext_ops.pext(words[i], p, tile=self.tile, interpret=self.interpret)
                for i, p in enumerate(plans)
            ]
        )
        cache = get_cache()
        k, n, wc = (int(s) for s in comp.shape)
        block, interpret = self.block, self.interpret

        def builder():
            def one(c, r):
                bk, brow = bitonic_ops.block_sort(c, r, block=block, interpret=interpret)
                return sort_words_keyed(bk, brow)

            return cache.jit(jax.vmap(one))

        prog = cache.program(
            ("run_many", self.name, k, n, wc, block, interpret), builder
        )
        return prog(comp, jnp.asarray(rows, jnp.uint32))
