# Pluggable execution backends for the reconstruction pipeline.
#
# A backend implements the extract and sort stages (the data-parallel hot
# path); registering one here makes it addressable by name from every
# pipeline call site — core, serving, checkpointing, benchmarks.  See
# base.py for the interface + determinism contract and README.md for how
# to add a backend.

from .base import (
    ExecutionBackend,
    available_backends,
    get_backend,
    register_backend,
)
from . import jnp_backend  # noqa: F401  (self-registers "jnp")
from . import pallas_backend  # noqa: F401  (self-registers "pallas")
from . import distributed  # noqa: F401  (self-registers "distributed")

__all__ = [
    "ExecutionBackend",
    "available_backends",
    "get_backend",
    "register_backend",
]
