"""Mesh-distributed backend: the row-column sample sort over one mesh axis.

Wraps ``repro.core.distsort.make_sample_sort`` so a mesh run produces the
same (keys_sorted, rows_sorted) pair — and therefore the same
``ReconstructionResult`` shape — as the single-device backends.

**ICI volume** is the reason this backend exists inside the pipeline rather
than as a bolted-on flag: the pipeline's extract stage runs *before* the
sort stage, i.e. before the sample sort's bucketed ``all_to_all``, so the
bytes crossing the interconnect are the compressed sort keys.  The exchange
volume shrinks by exactly the paper's sort-key ratio — compression does not
merely shorten the comparator, it shrinks the step the paper maps to shared
memory (distsort docstring, "perfect partition -> regular-sampling
splitters + bucketed all_to_all").

Static-shape adaptation (see distsort): buckets carry a capacity factor and
the kernel *reports* overflow instead of dropping silently.  This backend
retries with doubled capacity until the sort is overflow-free and records
the attempts in ``last_info`` — callers see exactly the MoE-dispatch
compromise, never a wrong answer.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.compat import make_mesh, shard_map
from repro.core.compress import ExtractionPlan, extract_bits
from repro.core.dbits import rank_in_sorted_keyed
from repro.core.distsort import make_sample_sort
from repro.core.plancache import (
    bucket_for,
    get_cache,
    iota_u32,
    merge_padded,
    pad_run,
    pad_tail,
)

from .base import ExecutionBackend, register_backend

__all__ = ["DistributedBackend"]

_SENTINEL = np.uint32(0xFFFFFFFF)


@register_backend("distributed")
class DistributedBackend(ExecutionBackend):
    """shard_map sample sort over ``axis_name`` of ``mesh``."""

    supports_batched = True

    def __init__(
        self,
        mesh=None,
        axis_name: str = "data",
        capacity_factor: float = 1.5,
        max_capacity_retries: int = 4,
    ) -> None:
        super().__init__()
        if mesh is None:
            mesh = make_mesh((len(jax.devices()),), (axis_name,))
        self.mesh = mesh
        self.axis_name = axis_name
        self.capacity_factor = float(capacity_factor)
        self.max_capacity_retries = int(max_capacity_retries)
        self._fns: dict = {}  # (n_per_shard, n_words, capacity) -> sort fn
        self.last_info = {"mesh_devices": int(mesh.shape[axis_name])}

    @property
    def n_devices(self) -> int:
        return int(self.mesh.shape[self.axis_name])

    def extract(self, words: jnp.ndarray, plan: ExtractionPlan) -> jnp.ndarray:
        # Extraction is embarrassingly row-parallel; under the mesh it runs
        # shard-local ahead of the exchange (this ordering is what shrinks
        # the all_to_all byte volume by the sort-key ratio).
        return extract_bits(jnp.asarray(words, jnp.uint32), plan)

    def _sort_fn(self, n_per_shard: int, n_words: int, capacity: float):
        key = (n_per_shard, n_words, capacity)
        if key not in self._fns:
            self._fns[key] = make_sample_sort(
                self.mesh, self.axis_name, n_per_shard, n_words, capacity
            )
        return self._fns[key]

    def sort(self, keys, rows, *, n_valid=None, keep_padded=False, donate=False):
        # ``donate`` is accepted for signature parity but ignored: the
        # sample sort compacts its shard-padded result host-side, so there
        # is no single compiled program whose output could alias the input
        # buffer.  Outputs are identical either way (the flag is a memory
        # hint, never a semantic one).
        del donate
        keys = jnp.asarray(keys, jnp.uint32)
        rows = jnp.asarray(rows, jnp.uint32)
        b, w = (int(s) for s in keys.shape)
        n = b if n_valid is None else int(n_valid)
        p = self.n_devices

        if n_valid is not None:
            # inputs are bucket-shaped with arbitrary pad lanes; normalize
            # from the dynamic count (scalar broadcasts — no materialized
            # per-call fill): pad keys to the sentinel, pad rows to their
            # lane index (>= n, so compaction strips them)
            lane = iota_u32(b)
            valid = lane < jnp.uint32(n)
            keys = jnp.where(valid[:, None], keys, jnp.uint32(_SENTINEL))
            rows = jnp.where(valid, rows, lane)

        # shard padding occupies row ids n..; reject out-of-range rows
        # rather than silently confusing them with padding
        if n and int(jnp.max(rows[:n])) >= n:
            raise ValueError(
                "distributed backend requires row positions in [0, n); "
                f"got max row {int(jnp.max(rows[:n]))} for n={n}"
            )

        # pad to a shard multiple; sentinel keys sort last, pad row ids are
        # n.. so the (key, row) tie-break keeps real all-ones keys ahead.
        # Concat-free: sentinel tail via the cached-constant pad, row tail
        # via one dynamic_update_slice of the real rows into a cached iota
        # (its untouched tail lanes are exactly the pad ids cur_n..total-1)
        cur = int(keys.shape[0])
        total = cur + ((-cur) % p)
        if total != cur:
            keys = pad_tail(keys, total, _SENTINEL)
            import jax.lax as lax

            rows = lax.dynamic_update_slice(iota_u32(total), rows, (0,))

        res = self.sample_sort_raw(keys, rows)

        # compact the shard-padded result to the dense global order
        valid = np.asarray(res.valid)
        k = np.asarray(res.keys)[valid]
        r = np.asarray(res.rids)[valid]
        real = r < n
        k, r = k[real], r[real]
        ks = jnp.asarray(k, jnp.uint32)
        rs = jnp.asarray(r, jnp.uint32)
        if keep_padded:
            return pad_run(ks, rs, b if n_valid is not None else bucket_for("sort", n))
        return ks, rs

    def merge_sorted(self, keys_a, rows_a, keys_b, rows_b, *,
                     n_valid_a=None, n_valid_b=None, keep_padded=False,
                     donate=False):
        """Owner-shard routing + shard-local merges.

        The base run A is globally sorted, i.e. already range-partitioned
        into ``p`` contiguous shard chunks.  Only the (small) delta B moves:
        each element is routed to the chunk that owns its merge position
        (one rank binary search against A), then every chunk merges locally
        with its routed slice — so the bytes crossing the interconnect are
        the delta, never the base.  This is the same economics as the
        extract-before-all_to_all ordering of the sort stage: incremental
        maintenance keeps the bulk data shard-resident.

        ``n_valid_a``/``n_valid_b`` mark bucket-shaped runs (the valid
        prefix merges; pads are dropped before routing); ``keep_padded``
        re-pads the merged run to ``ba + bb`` rows.  ``donate`` is ignored
        — the routing path is host-side, so there is no program whose
        output could alias the inputs (see :meth:`sort`).
        """
        del donate
        keys_a = jnp.asarray(keys_a, jnp.uint32)
        rows_a = jnp.asarray(rows_a, jnp.uint32)
        keys_b = jnp.asarray(keys_b, jnp.uint32)
        rows_b = jnp.asarray(rows_b, jnp.uint32)
        ba, bb = int(keys_a.shape[0]), int(keys_b.shape[0])
        if n_valid_a is not None:
            keys_a, rows_a = keys_a[: int(n_valid_a)], rows_a[: int(n_valid_a)]
        if n_valid_b is not None:
            keys_b, rows_b = keys_b[: int(n_valid_b)], rows_b[: int(n_valid_b)]
        na, nb = int(keys_a.shape[0]), int(keys_b.shape[0])

        def _shape_out(ks, rs):
            if not keep_padded:
                return ks, rs
            return pad_run(ks, rs, ba + bb)

        p = self.n_devices
        if na == 0 or nb == 0 or p == 1:
            mk, mr = merge_padded(keys_a, rows_a, keys_b, rows_b,
                                  backend=self.name)
            self.last_info = {"mesh_devices": p, "delta_routed": [nb]}
            return _shape_out(mk, mr)
        chunk = -(-na // p)
        # rank of each delta element in the base run decides the owner chunk:
        # rank r lands between A[r-1] and A[r], i.e. inside chunk r // chunk
        rank_b = np.asarray(
            rank_in_sorted_keyed(keys_a, rows_a, keys_b, rows_b)
        )
        owner = np.minimum(rank_b // chunk, p - 1)
        parts_k, parts_r, routed = [], [], []
        for i in range(p):
            s, e = i * chunk, min((i + 1) * chunk, na)
            sel = np.nonzero(owner == i)[0]
            routed.append(int(sel.size))
            # chunk sizes drift with (na, routed delta); bucketing the local
            # merge keeps every chunk on a cached compiled program
            mk, mr = merge_padded(
                keys_a[s:e], rows_a[s:e],
                jnp.take(keys_b, sel, axis=0), jnp.take(rows_b, sel, axis=0),
                backend=self.name,
            )
            parts_k.append(mk)
            parts_r.append(mr)
        self.last_info = {"mesh_devices": p, "delta_routed": routed}
        return _shape_out(
            jnp.concatenate(parts_k, axis=0), jnp.concatenate(parts_r, axis=0)
        )

    def lookup(self, tree, queries):
        """Owner-shard routed point lookups.

        The sorted key space is range-partitioned into ``p`` contiguous
        chunks (the same partition the sample sort produced); each query
        is routed to the chunk that owns its key range — one vectorized
        compare against the ``p - 1`` chunk boundary keys — and every
        owner answers its group through the shared plan-cached lookup
        program.  Group sizes drift with the query mix, so the bucketed
        program is what keeps a steady routed stream replay-only; answers
        are scattered back into query order, byte-identical to the
        unrouted oracle because each query's answer is independent of its
        group.  ``last_info["lookup_routed"]`` records the per-shard
        query counts.
        """
        from repro.core.btree import NOT_FOUND_RID, lookup_batch_planned
        from repro.core.dbits import lex_compare_le

        queries = jnp.asarray(queries, jnp.uint32)
        q = int(queries.shape[0])
        p = self.n_devices
        n = int(tree.n_keys)
        if p == 1 or q == 0 or n < p:
            out = lookup_batch_planned(tree, queries, backend_name=self.name)
            self.last_info = {"mesh_devices": p, "lookup_routed": [q]}
            return out
        chunk = -(-n // p)
        # boundary b is the first key of chunk b+1; a query belongs to the
        # last chunk whose boundary is <= it (compare over all boundaries
        # at once — log-free, p is the mesh size)
        bounds = tree.sorted_full[
            jnp.minimum(jnp.arange(1, p, dtype=jnp.int32) * chunk, n - 1)
        ]
        owner = np.asarray(
            jnp.sum(
                lex_compare_le(bounds[None, :, :], queries[:, None, :]).astype(
                    jnp.int32
                ),
                axis=1,
            )
        )
        found = np.zeros((q,), bool)
        rid = np.full((q,), NOT_FOUND_RID, np.uint32)
        routed = []
        for i in range(p):
            sel = np.nonzero(owner == i)[0]
            routed.append(int(sel.size))
            if not sel.size:
                continue
            f, r = lookup_batch_planned(
                tree, jnp.take(queries, sel, axis=0), backend_name=self.name
            )
            found[sel] = np.asarray(f)
            rid[sel] = np.asarray(r)
        self.last_info = {"mesh_devices": p, "lookup_routed": routed}
        return jnp.asarray(found), jnp.asarray(rid, jnp.uint32)

    def lookup_many(self, stacked, queries, n_valid=None):
        """Fused multi-tenant lookup with the tenant axis over the mesh.

        The stacked arena is the natural distribution unit: every tenant's
        descent is independent, so the whole BTree pytree shards on its
        leading tenant axis (``T / p`` tenants per device) and the fused
        body runs shard-locally under ``shard_map`` — batch parallelism
        with zero interconnect bytes, the read-path twin of
        :meth:`batched_extract_sort`.  The shard_mapped program is
        memoized per ``(T, query bucket, geometry, p)``; falls back to the
        single-device fused path when the arena does not tile the mesh
        axis.  ``last_info["tenants_per_shard"]`` records the placement.
        """
        from repro.core.btree import (
            _leaf_match_many_full,
            _lookup_many_body,
            lookup_many_planned,
            tree_geometry,
        )

        queries = jnp.asarray(queries, jnp.uint32)
        t_q, q, w = (int(s) for s in queries.shape)
        t_cap = int(stacked.sorted_full.shape[0])
        p = self.n_devices
        if p == 1 or t_cap % p:
            self.last_info = {"mesh_devices": p, "tenants_per_shard": t_cap}
            return lookup_many_planned(
                stacked, queries, n_valid, backend_name=self.name
            )
        if t_q > t_cap:
            raise ValueError(f"{t_q} tenant blocks > arena capacity {t_cap}")

        from jax.sharding import PartitionSpec as P

        cache = get_cache()
        b = bucket_for("lookup_many", q)
        if n_valid is None:
            nv = np.full((t_q,), q, np.uint32)
        else:
            nv = np.asarray(n_valid, np.uint32).reshape(-1)
            if nv.shape[0] != t_q:
                raise ValueError(
                    f"n_valid has {nv.shape[0]} rows, expected {t_q}"
                )
        nv_full = np.zeros((t_cap,), np.uint32)
        nv_full[:t_q] = np.minimum(nv, q)

        def builder():
            body = _lookup_many_body(_leaf_match_many_full)
            spec = P(self.axis_name)  # shard the leading (tenant) axis
            fn = shard_map(
                body,
                mesh=self.mesh,
                in_specs=(spec, spec, spec),
                out_specs=(spec, spec),
            )
            return cache.jit(fn)

        prog = cache.program(
            ("lookup_many", self.name, t_cap, b, w, tree_geometry(stacked), p),
            builder,
        )
        qp = pad_tail(queries, b, 0xFFFFFFFF, axis=1)
        qp = pad_tail(qp, t_cap, 0xFFFFFFFF, axis=0)
        found, rid = prog(stacked, qp, jnp.asarray(nv_full))
        self.last_info = {"mesh_devices": p, "tenants_per_shard": t_cap // p}
        return found[:t_q, :q], rid[:t_q, :q]

    def batched_extract_sort(self, words, bitmaps, rows, plans):
        """Shards ``run_many``'s *batch* axis across the mesh.

        Each device extracts + sorts its ``k / p`` keysets entirely
        shard-locally — batch parallelism instead of the sample sort's key
        parallelism, so no bytes cross the interconnect at all.  The
        shard_mapped program is memoized in the shared plan cache per
        ``(k, n, W, Wc, p)``, so replication batches replay it.  Falls
        back to the single-device vmap when the batch does not tile the
        mesh axis.
        """
        k = int(words.shape[0])
        p = self.n_devices
        if p == 1 or k % p:
            return super().batched_extract_sort(words, bitmaps, rows, plans)

        from jax.sharding import PartitionSpec as P

        cache = get_cache()
        _, n, w = (int(s) for s in words.shape)
        n_words_out = plans[0].n_words_out  # equal across the batch

        def builder():
            from repro.core.compress import extract_bits_dynamic
            from repro.core.dbits import sort_words_keyed

            def one(wds, bm, r):
                comp = extract_bits_dynamic(wds, bm, n_words_out)
                return sort_words_keyed(comp, r)

            local = jax.vmap(one, in_axes=(0, 0, 0))
            spec3 = P(self.axis_name, None, None)
            spec2 = P(self.axis_name, None)
            fn = shard_map(
                local,
                mesh=self.mesh,
                in_specs=(spec3, spec2, spec2),
                out_specs=(spec3, spec2),
            )
            return cache.jit(fn)

        prog = cache.program(
            ("run_many", self.name, k, n, w, n_words_out, p), builder
        )
        self.last_info = {"mesh_devices": p, "batch_per_shard": k // p}
        return prog(
            jnp.asarray(words, jnp.uint32),
            jnp.asarray(bitmaps, jnp.uint32),
            jnp.asarray(rows, jnp.uint32),
        )

    def sample_sort_raw(self, keys, rows):
        """Device-side sample sort with overflow retry: the shard-padded
        ``DistSortResult`` (keys/rids/valid stay device arrays; no host
        compaction).  For callers that time or post-process on device —
        the scaling benchmarks use this so host traffic is not measured.
        ``n`` must already be a multiple of the mesh axis size (``sort``
        handles padding)."""
        keys = jnp.asarray(keys, jnp.uint32)
        rows = jnp.asarray(rows, jnp.uint32)
        n, w = keys.shape
        p = self.n_devices
        if n % p:
            raise ValueError(f"n={n} must divide over {p} devices")
        capacity = self.capacity_factor
        attempts = 0
        while True:
            attempts += 1
            fn = self._sort_fn(n // p, w, capacity)
            res = fn(keys, rows)
            overflow = int(res.overflow)
            if overflow == 0:
                break
            if attempts > self.max_capacity_retries:
                raise RuntimeError(
                    f"distributed sort still overflowing after "
                    f"{attempts} attempts (capacity {capacity}, "
                    f"overflow {overflow})"
                )
            capacity *= 2.0
        self.last_info = {
            "mesh_devices": p,
            "capacity_factor": capacity,
            "capacity_retries": attempts - 1,
            "overflow": overflow,
        }
        return res
