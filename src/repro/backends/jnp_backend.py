"""The pure-jnp oracle backend: ``extract_bits`` + multiword ``lax.sort``.

This is the reference semantics every other backend is tested against.  The
fused path jits extract+sort as one program so XLA fuses the bit-gather into
the sort's operand production and the compressed array is never written back
to HBM between the stages.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core.compress import ExtractionPlan, extract_bits
from repro.core.dbits import merge_words_keyed, sort_words_keyed

from .base import ExecutionBackend, register_backend

__all__ = ["JnpBackend"]


@partial(jax.jit, static_argnames=("plan",))
def _fused_extract_sort(words: jnp.ndarray, rows: jnp.ndarray, plan: ExtractionPlan):
    comp = extract_bits(words, plan)
    return sort_words_keyed(comp, rows)


# merge-path merge: two rank passes (vectorized binary search) + permutation
# scatter; one program so XLA fuses the compares with the scatter operands
_merged = jax.jit(merge_words_keyed)


@register_backend("jnp")
class JnpBackend(ExecutionBackend):
    """Vectorized jnp ops on the default device — the oracle path."""

    supports_fused = True
    supports_batched = True

    def extract(self, words: jnp.ndarray, plan: ExtractionPlan) -> jnp.ndarray:
        return extract_bits(words, plan)

    def sort(self, keys, rows):
        return sort_words_keyed(
            jnp.asarray(keys, jnp.uint32), jnp.asarray(rows, jnp.uint32)
        )

    def fused_extract_sort(self, words, plan, rows):
        return _fused_extract_sort(
            jnp.asarray(words, jnp.uint32), jnp.asarray(rows, jnp.uint32), plan
        )

    def merge_sorted(self, keys_a, rows_a, keys_b, rows_b):
        # shapes are static at trace time, so the empty-run short-circuits
        # inside merge_words_keyed specialize correctly under jit
        return _merged(
            jnp.asarray(keys_a, jnp.uint32), jnp.asarray(rows_a, jnp.uint32),
            jnp.asarray(keys_b, jnp.uint32), jnp.asarray(rows_b, jnp.uint32),
        )
