"""The pure-jnp oracle backend: ``extract_bits`` + multiword ``lax.sort``.

This is the reference semantics every other backend is tested against.  The
fused path jits extract+sort as one program so XLA fuses the bit-gather into
the sort's operand production and the compressed array is never written back
to HBM between the stages.

Every shape-polymorphic op (sort, merge, fused) runs through the shared
plan cache (``repro.core.plancache``): inputs pad to power-of-two buckets
with sentinel rows that sort strictly last, and the compiled program is
memoized per bucket — a churny serving load whose ``n`` / ``(na, nb)``
drift within a bucket replays one program instead of retracing per shape
(the ROADMAP's jnp-merge retrace item).  The ``lookup`` op is inherited
unchanged: the base class's plan-cached full-key descent *is* the jnp
oracle the other backends' probes are tested against.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.core.compress import ExtractionPlan
from repro.core.plancache import fused_extract_sort_padded, merge_padded, sort_padded

from .base import ExecutionBackend, register_backend

__all__ = ["JnpBackend"]


@register_backend("jnp")
class JnpBackend(ExecutionBackend):
    """Vectorized jnp ops on the default device — the oracle path."""

    supports_fused = True
    supports_batched = True

    def extract(self, words: jnp.ndarray, plan: ExtractionPlan) -> jnp.ndarray:
        from repro.core.compress import extract_bits

        return extract_bits(words, plan)

    def sort(self, keys, rows, *, n_valid=None, keep_padded=False, donate=False):
        return sort_padded(
            jnp.asarray(keys, jnp.uint32), jnp.asarray(rows, jnp.uint32),
            backend=self.name, n_valid=n_valid, keep_padded=keep_padded,
            donate=donate,
        )

    def fused_extract_sort(self, words, plan, rows, *, n_valid=None,
                           keep_padded=False, donate=False):
        return fused_extract_sort_padded(
            jnp.asarray(words, jnp.uint32), plan, jnp.asarray(rows, jnp.uint32),
            backend=self.name, n_valid=n_valid, keep_padded=keep_padded,
            donate=donate,
        )

    def merge_sorted(self, keys_a, rows_a, keys_b, rows_b, *,
                     n_valid_a=None, n_valid_b=None, keep_padded=False,
                     donate=False):
        # merge-path merge: one rank pass (vectorized binary search of the
        # smaller run) + complement scatter, one cached program per
        # (bucket_a, bucket_b); ``donate`` consumes both input runs
        return merge_padded(
            jnp.asarray(keys_a, jnp.uint32), jnp.asarray(rows_a, jnp.uint32),
            jnp.asarray(keys_b, jnp.uint32), jnp.asarray(rows_b, jnp.uint32),
            backend=self.name, n_valid_a=n_valid_a, n_valid_b=n_valid_b,
            keep_padded=keep_padded, donate=donate,
        )
