"""Execution-backend interface + registry for the reconstruction pipeline.

A backend supplies the two data-parallel stages of the paper's pipeline —
compressed-key **extract** (§5.1) and parallel **sort** (§5.2) — behind one
interface, so ``repro.core.pipeline`` can run the identical scan → extract →
sort → build → refresh flow on the pure-jnp oracle path, the Pallas kernels,
or a mesh-distributed sample sort without any call-site branching (the
encoder/executor split HOPE and Upscaledb argue for).

Determinism contract: ``sort`` orders rows by the lexicographic pair
``(key, row)`` — ties between equal keys break on the ascending row id.
Every backend honours it, which is what makes the sorted compressed keys and
rid permutations *byte-identical* across backends (and what the parity tests
assert).  All three built-in backends realize it the same way: the row id is
carried as an extra least-significant sort-key word (the paper's sort key is
literally the (compressed key, rid) pair).  Rows are the pipeline's row
*positions* — distinct values in ``[0, n)``; the distributed backend
validates this because its shard padding occupies ids ``>= n``.
"""

from __future__ import annotations

import abc
from typing import TYPE_CHECKING, Callable, Type

import jax.numpy as jnp

if TYPE_CHECKING:  # real import stays lazy: repro.core.__init__ imports the
    # pipeline, which imports this package — a module-level import here
    # would close that cycle before the registry names exist
    from repro.core.compress import ExtractionPlan

__all__ = [
    "ExecutionBackend",
    "register_backend",
    "get_backend",
    "available_backends",
]

_REGISTRY: dict[str, Type["ExecutionBackend"]] = {}


def register_backend(name: str) -> Callable[[type], type]:
    """Class decorator: register an ExecutionBackend under ``name``."""

    def deco(cls: type) -> type:
        cls.name = name
        _REGISTRY[name] = cls
        return cls

    return deco


def get_backend(name: str, **opts) -> "ExecutionBackend":
    """Instantiate a registered backend (options are backend-specific)."""
    if name not in _REGISTRY:
        raise KeyError(
            f"unknown backend {name!r}; registered: {sorted(_REGISTRY)}"
        )
    return _REGISTRY[name](**opts)


def available_backends() -> list[str]:
    return sorted(_REGISTRY)


class ExecutionBackend(abc.ABC):
    """One execution substrate for the pipeline's extract and sort stages.

    ``last_info`` holds backend-specific facts about the most recent sort
    (e.g. distsort overflow retries); the pipeline folds it into
    ``ReconstructionResult.stats``.
    """

    name: str = "?"
    #: backend can run extract+sort as one fused program (the compressed
    #: array is never materialized between the stages)
    supports_fused: bool = False
    #: backend's extract+sort can be vmapped over a stacked batch of
    #: same-shape keysets (single-device jnp semantics; the pipeline's
    #: run_many uses this for the batched fast path)
    supports_batched: bool = False

    def __init__(self) -> None:
        self.last_info: dict = {}

    # ------------------------------------------------------------ extract
    @abc.abstractmethod
    def extract(self, words: jnp.ndarray, plan: "ExtractionPlan") -> jnp.ndarray:
        """(n, W) uint32 full keys -> (n, Wc) uint32 compressed keys."""

    def extract_dynamic(
        self, words: jnp.ndarray, bitmap: jnp.ndarray, n_words_out: int
    ) -> jnp.ndarray:
        """Runtime-bitmap extraction (no per-bitmap retrace); jnp fallback."""
        from repro.core.compress import extract_bits_dynamic

        return extract_bits_dynamic(words, bitmap, n_words_out)

    # --------------------------------------------------------------- sort
    @abc.abstractmethod
    def sort(
        self, keys: jnp.ndarray, rows: jnp.ndarray
    ) -> tuple[jnp.ndarray, jnp.ndarray]:
        """Sort (n, W) keys with (n,) distinct row positions in [0, n).

        Returns (keys_sorted, rows_sorted) in ascending (key, row) order —
        see the determinism contract in the module docstring.
        """

    # -------------------------------------------------------- fused path
    def fused_extract_sort(
        self, words: jnp.ndarray, plan: ExtractionPlan, rows: jnp.ndarray
    ) -> tuple[jnp.ndarray, jnp.ndarray]:
        """extract+sort as one program; only if ``supports_fused``."""
        raise NotImplementedError(f"backend {self.name} has no fused path")
