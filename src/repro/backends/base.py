"""Execution-backend interface + registry for the reconstruction pipeline.

A backend supplies the two data-parallel stages of the paper's pipeline —
compressed-key **extract** (§5.1) and parallel **sort** (§5.2) — behind one
interface, so ``repro.core.pipeline`` can run the identical scan → extract →
sort → build → refresh flow on the pure-jnp oracle path, the Pallas kernels,
or a mesh-distributed sample sort without any call-site branching (the
encoder/executor split HOPE and Upscaledb argue for).

Determinism contract: ``sort`` orders rows by the lexicographic pair
``(key, row)`` — ties between equal keys break on the ascending row id.
Every backend honours it, which is what makes the sorted compressed keys and
rid permutations *byte-identical* across backends (and what the parity tests
assert).  All three built-in backends realize it the same way: the row id is
carried as an extra least-significant sort-key word (the paper's sort key is
literally the (compressed key, rid) pair).  Rows are the pipeline's row
*positions* — distinct values in ``[0, n)``; the distributed backend
validates this because its shard padding occupies ids ``>= n``.

The incremental reconstruction path adds a third data-parallel op,
``merge_sorted``: given two runs that are each ascending in (key, row) —
the surviving base run and the freshly sorted delta — produce the merged
run.  The contract extends naturally: the output must be byte-identical to
``sort`` over the concatenated pairs (rows must be distinct *across* the two
runs, so the (key, row) order is total).  Backends realize it differently —
merge-path ranks on jnp, the tiled rank kernel on pallas, owner-shard
routing + local merges on the distributed mesh — but the output bytes are
the same everywhere.

Compiled-plan execution promotes the remaining serial stages to backend
ops: ``build`` (§5.3 bulk build — per-level entry programs cached in the
shared plan cache, with a backend-substitutable partial-key gather) and
``refresh_meta`` (§4.3 — a cached device program for the adjacent D-bit
positions plus one host scatter-OR).  Shape-polymorphic ops (sort, merge,
fused extract+sort, the batched path) run through
``repro.core.plancache``: inputs pad to power-of-two buckets and the
compiled program is memoized per ``(op, backend, bucket, n_words,
static config)``, so drifting sizes under a churny serving load replay
cached programs instead of retracing.

The read path adds a fourth data-parallel family, ``lookup``: batched
point lookups against a built tree, plan-cached per query-batch bucket.
The contract is byte-identity again — ``(found, rid)`` with miss lanes
normalized to ``repro.core.btree.NOT_FOUND_RID`` must be bit-for-bit
equal across backends (jnp full-key descent, the pallas partial-key
probe kernel, distributed owner-shard routing), which is what lets a
reader switch substrates — or snapshot epochs built on different
substrates — without ever seeing a divergent answer.
"""

from __future__ import annotations

import abc
from typing import TYPE_CHECKING, Callable, Type

import jax.numpy as jnp

if TYPE_CHECKING:  # real import stays lazy: repro.core.__init__ imports the
    # pipeline, which imports this package — a module-level import here
    # would close that cycle before the registry names exist
    from repro.core.compress import ExtractionPlan

__all__ = [
    "ExecutionBackend",
    "register_backend",
    "get_backend",
    "available_backends",
]

_REGISTRY: dict[str, Type["ExecutionBackend"]] = {}


def register_backend(name: str) -> Callable[[type], type]:
    """Class decorator: register an ExecutionBackend under ``name``."""

    def deco(cls: type) -> type:
        cls.name = name
        _REGISTRY[name] = cls
        return cls

    return deco


def get_backend(name: str, **opts) -> "ExecutionBackend":
    """Instantiate a registered backend (options are backend-specific)."""
    if name not in _REGISTRY:
        raise KeyError(
            f"unknown backend {name!r}; registered: {sorted(_REGISTRY)}"
        )
    return _REGISTRY[name](**opts)


def available_backends() -> list[str]:
    """Sorted names of every registered execution backend."""
    return sorted(_REGISTRY)


class ExecutionBackend(abc.ABC):
    """One execution substrate for the pipeline's extract and sort stages.

    ``last_info`` holds backend-specific facts about the most recent sort
    (e.g. distsort overflow retries); the pipeline folds it into
    ``ReconstructionResult.stats``.
    """

    name: str = "?"
    #: backend can run extract+sort as one fused program (the compressed
    #: array is never materialized between the stages)
    supports_fused: bool = False
    #: backend's extract+sort can be vmapped over a stacked batch of
    #: same-shape keysets (single-device jnp semantics; the pipeline's
    #: run_many uses this for the batched fast path)
    supports_batched: bool = False

    def __init__(self) -> None:
        self.last_info: dict = {}

    # ------------------------------------------------------------ extract
    @abc.abstractmethod
    def extract(self, words: jnp.ndarray, plan: "ExtractionPlan") -> jnp.ndarray:
        """(n, W) uint32 full keys -> (n, Wc) uint32 compressed keys."""

    def extract_dynamic(
        self, words: jnp.ndarray, bitmap: jnp.ndarray, n_words_out: int
    ) -> jnp.ndarray:
        """Runtime-bitmap extraction (no per-bitmap retrace); jnp fallback."""
        from repro.core.compress import extract_bits_dynamic

        return extract_bits_dynamic(words, bitmap, n_words_out)

    # --------------------------------------------------------------- sort
    @abc.abstractmethod
    def sort(
        self,
        keys: jnp.ndarray,
        rows: jnp.ndarray,
        *,
        n_valid: int | None = None,
        keep_padded: bool = False,
        donate: bool = False,
    ) -> tuple[jnp.ndarray, jnp.ndarray]:
        """Sort (n, W) keys with (n,) distinct row positions in [0, n).

        Returns (keys_sorted, rows_sorted) in ascending (key, row) order —
        see the determinism contract in the module docstring.

        ``n_valid`` marks the inputs as already bucket-shaped with
        ``n_valid`` real rows (pad lanes may be arbitrary; the cached
        program normalizes them from the dynamic count).  ``keep_padded``
        returns the bucket-shaped outputs (pads sorted to the tail) so
        the pipeline can chain into the build programs without slicing
        and re-padding.

        ``donate=True`` is the caller's assertion that nothing else reads
        the *keys* buffer again — the compiled program consumes it
        (``donate_argnums``) and XLA reuses its storage.  The rows operand
        is never donated (it is often the shared cached iota).  Backends
        without compiled-program donation (the distributed host-routing
        path) may ignore the flag; outputs are identical either way.
        """

    # -------------------------------------------------------- fused path
    def fused_extract_sort(
        self,
        words: jnp.ndarray,
        plan: ExtractionPlan,
        rows: jnp.ndarray,
        *,
        n_valid: int | None = None,
        keep_padded: bool = False,
        donate: bool = False,
    ) -> tuple[jnp.ndarray, jnp.ndarray]:
        """extract+sort as one program; only if ``supports_fused``.

        ``n_valid`` / ``keep_padded`` / ``donate`` behave as in
        :meth:`sort` (``donate`` consumes the words operand).
        """
        raise NotImplementedError(f"backend {self.name} has no fused path")

    # -------------------------------------------------------------- merge
    def merge_sorted(
        self,
        keys_a: jnp.ndarray,
        rows_a: jnp.ndarray,
        keys_b: jnp.ndarray,
        rows_b: jnp.ndarray,
        *,
        n_valid_a: int | None = None,
        n_valid_b: int | None = None,
        keep_padded: bool = False,
        donate: bool = False,
    ) -> tuple[jnp.ndarray, jnp.ndarray]:
        """Merge two ascending (key, row) runs into one.

        Must be byte-identical to ``sort`` over the concatenated inputs;
        rows must be distinct across both runs (see the module docstring).
        The default is the jnp merge-path reference, shape-bucketed so
        drifting ``(na, nb)`` pairs inside a bucket replay one compiled
        program; backends override with their native realization.

        ``n_valid_a``/``n_valid_b`` mark the runs as bucket-shaped with
        that many valid rows; ``keep_padded`` returns the full
        ``(ba + bb,)`` outputs with pads at the tail (cascade chaining).
        ``donate=True`` consumes all four run operands — a merge's inputs
        are dead after it, so the cascade's peak live footprint stays
        O(log) runs.  Backends that merge host-side may ignore ``donate``.
        """
        from repro.core.plancache import merge_padded

        return merge_padded(
            jnp.asarray(keys_a, jnp.uint32), jnp.asarray(rows_a, jnp.uint32),
            jnp.asarray(keys_b, jnp.uint32), jnp.asarray(rows_b, jnp.uint32),
            backend=self.name, n_valid_a=n_valid_a, n_valid_b=n_valid_b,
            keep_padded=keep_padded, donate=donate,
        )

    # -------------------------------------------------------------- build
    def build(
        self,
        comp_sorted: jnp.ndarray,
        row_sorted: jnp.ndarray,
        meta,
        words: jnp.ndarray,
        lengths: jnp.ndarray | None,
        config,
        rids: jnp.ndarray | None = None,
        n_valid: int | None = None,
        donate: bool = False,
    ):
        """Stage 3 (§5.3): bottom-up bulk build of the partial-key B+tree.

        The default runs the cached jnp build programs; backends may
        substitute their own entry-gather realization (the Pallas backend
        passes its ``kernels/build`` pk-window kernel) — output trees must
        be byte-identical across backends.  ``n_valid`` marks
        ``comp_sorted``/``row_sorted`` as bucket-shaped with ``n_valid``
        real rows (the pipeline chains the sort stage's padded outputs in
        without re-padding).  ``donate=True`` lets the build programs
        consume their scratch operands (the sort permutation and the
        per-level hi-index buffer) — only safe when the caller no longer
        reads the padded row buffer afterwards.
        """
        from repro.core.btree import build_btree

        return build_btree(
            comp_sorted, row_sorted, meta, words, lengths, config,
            rids=rids, backend_name=self.name, n_valid=n_valid,
            donate=donate,
        )

    # ------------------------------------------------------------- lookup
    def lookup(
        self, tree, queries: jnp.ndarray
    ) -> tuple[jnp.ndarray, jnp.ndarray]:
        """Batched point lookup: (q, W) queries -> ((q,) found, (q,) rid).

        Miss lanes carry ``repro.core.btree.NOT_FOUND_RID``; outputs must
        be byte-identical across backends (the read-path analogue of the
        sort contract).  The default is the plan-cached full-key descent —
        one compiled program per query-batch bucket, so a steady query
        stream at drifting batch sizes replays without retracing.
        Backends substitute their own leaf probe (the pallas partial-key
        kernel) or routing (the distributed owner shards).
        """
        from repro.core.btree import lookup_batch_planned

        return lookup_batch_planned(
            tree, jnp.asarray(queries, jnp.uint32), backend_name=self.name
        )

    # ------------------------------------------------- lookup (multi-tenant)
    def lookup_many(
        self, stacked, queries: jnp.ndarray, n_valid=None
    ) -> tuple[jnp.ndarray, jnp.ndarray]:
        """Fused point lookup over T stacked same-geometry trees.

        ``stacked`` is a ``repro.core.btree.stack_trees`` arena;
        ``queries`` is (T_q, q, W) with ``T_q`` at most the arena
        capacity, tenant ``t``'s block answered against member tree
        ``t``; ``n_valid`` (optional (T_q,)) gives per-tenant live lane
        counts.  Returns ``((T_q, q) found, (T_q, q) rid)`` — each
        tenant's slice byte-identical to :meth:`lookup` on that tenant's
        tree alone, which is the single-snapshot contract lifted over
        the tenant axis.  The default is the jnp oracle: ``vmap`` of the
        plan-cached descent over the tenant axis, one compiled program
        per ``(T, query bucket, tree geometry)``.  Backends substitute
        their own realization (the pallas probe kernel's tenant-major
        grid; distributed sharding of the tenant axis over the mesh).
        """
        from repro.core.btree import lookup_many_planned

        return lookup_many_planned(
            stacked, jnp.asarray(queries, jnp.uint32), n_valid,
            backend_name=self.name,
        )

    # ------------------------------------------------------- refresh meta
    def refresh_meta(self, comp_sorted: jnp.ndarray, meta, ref_key,
                     n_valid: int | None = None, donate: bool = False):
        """Stage 4 (§4.3): recompute DS-metadata at the opportune time.

        The adjacent D-bit positions run as a cached, shape-bucketed
        device program; the scatter-OR into the bitmap words is one
        vectorized host op (``meta_on_rebuild``).  ``n_valid`` marks
        ``comp_sorted`` as bucket-shaped with ``n_valid`` real rows.
        Only the (n-1,) device dpos vector crosses to the host — the
        sorted keys themselves stay on device.  ``donate=True`` consumes
        ``comp_sorted`` — refresh is the pipeline's last consumer of the
        padded sorted run, so its buffer is reclaimed in place; only pass
        it when nothing else reads that buffer again.
        """
        import numpy as np

        from repro.core.metadata import meta_on_rebuild
        from repro.core.plancache import adjacent_dpos_padded

        dpos = adjacent_dpos_padded(
            jnp.asarray(comp_sorted, jnp.uint32), backend=self.name,
            n_valid=n_valid, donate=donate,
        )
        # comp_sorted is unused by meta_on_rebuild when dpos_comp is given;
        # pass an empty view rather than forcing a device->host transfer of
        # the (possibly bucket-padded) sorted run
        comp_unused = np.zeros((0, int(comp_sorted.shape[1])), np.uint32)
        return meta_on_rebuild(
            comp_unused, meta, np.asarray(ref_key), dpos_comp=dpos
        )

    # ----------------------------------------------------- batched (many)
    def batched_extract_sort(
        self,
        words: jnp.ndarray,
        bitmaps: jnp.ndarray,
        rows: jnp.ndarray,
        plans: list["ExtractionPlan"],
    ) -> tuple[jnp.ndarray, jnp.ndarray]:
        """Extract+sort a stacked batch of same-shape keysets.

        ``words``: (k, n, W); ``bitmaps``: (k, W) per-index D-bitmaps (all
        with the same output width); ``rows``: (k, n); ``plans``: the static
        per-index extraction plans (for backends whose extractor wants a
        trace-time schedule).  Returns (comp_sorted (k, n, Wc), row_sorted
        (k, n)).  Only called when ``supports_batched``; the default is the
        vmapped dynamic-bitmap extract + keyed sort (single-device jnp
        semantics), compiled once per ``(k, n, W, Wc)`` via the plan cache
        (the pipeline pads the stacked ``n`` up to a bucket boundary, so
        replication batches at drifting sizes replay the same program).
        """
        import jax

        from repro.core.plancache import get_cache

        cache = get_cache()
        k, n, w = (int(s) for s in words.shape)
        n_words_out = plans[0].n_words_out  # equal across the batch

        def builder():
            from repro.core.compress import extract_bits_dynamic
            from repro.core.dbits import sort_words_keyed

            def one(wds, bm, r):
                comp = extract_bits_dynamic(wds, bm, n_words_out)
                return sort_words_keyed(comp, r)

            return cache.jit(jax.vmap(one, in_axes=(0, 0, 0)))

        prog = cache.program(
            ("run_many", self.name, k, n, w, n_words_out), builder
        )
        return prog(words, bitmaps, rows)
