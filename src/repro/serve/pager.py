"""Paged KV-cache manager whose page index is a reconstructable B-tree.

Pages of ``page_tokens`` KV slots are allocated from a free list; the page
table maps ``(seq_id, page_no) -> physical page``.  Exactly like the
paper's main-memory indexes, the *search index* over the page table is
never persisted: on engine restart (or replica bring-up) it is rebuilt from
the table rows with the compressed key sort — `(seq_id << bits) || page_no`
keys compress to their few distinction bits, and the bulk build produces
the lookup tree.  ``rebuild_index`` *is* ``repro.core.reconstruct`` on this
table.
"""

from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.core.btree import search_batch
from repro.core.keyformat import KeySet
from repro.core.pipeline import ReconstructionPipeline
from repro.core.reconstruct import ReconstructionResult

__all__ = ["PagedKVManager"]


def _pack_key(seq_id: int, page_no: int) -> np.ndarray:
    """(seq_id, page_no) -> (2,) uint32 key words (word 0 most significant)."""
    return np.asarray([seq_id, page_no], dtype=np.uint32)


@dataclass
class PagedKVManager:
    n_pages: int
    page_tokens: int
    backend: str = "jnp"  # execution backend for index reconstruction
    _free: list = field(default_factory=list)
    _table: dict = field(default_factory=dict)  # (seq, page_no) -> phys page
    _index: ReconstructionResult | None = None
    _index_dirty: bool = True

    def __post_init__(self):
        self._free = list(range(self.n_pages - 1, -1, -1))

    # ------------------------------------------------------------- mutation
    def alloc(self, seq_id: int, page_no: int) -> int:
        if not self._free:
            raise MemoryError("KV pager out of pages")
        phys = self._free.pop()
        self._table[(seq_id, page_no)] = phys
        self._index_dirty = True
        return phys

    def free_seq(self, seq_id: int) -> int:
        gone = [k for k in self._table if k[0] == seq_id]
        for k in gone:
            self._free.append(self._table.pop(k))
        self._index_dirty = True
        return len(gone)

    def pages_for(self, seq_id: int, n_tokens: int) -> list[int]:
        """Ensure pages covering n_tokens exist; returns physical page list."""
        need = -(-n_tokens // self.page_tokens)
        out = []
        for p in range(need):
            if (seq_id, p) not in self._table:
                self.alloc(seq_id, p)
            out.append(self._table[(seq_id, p)])
        return out

    # ---------------------------------------------------------------- index
    def rebuild_index(self, backend: str | None = None) -> ReconstructionResult:
        """Reconstruct the page-table B-tree (the paper's recovery path)."""
        if not self._table:
            raise ValueError("empty page table")
        items = sorted(self._table.items())
        words = np.stack([_pack_key(s, p) for (s, p), _ in items])
        rids = np.asarray([phys for _, phys in items], np.uint32)
        ks = KeySet(words=words, lengths=np.full(len(items), 8, np.int32), rids=rids)
        pipe = ReconstructionPipeline(backend=backend or self.backend)
        self._index = pipe.run(ks)
        self._index_dirty = False
        return self._index

    def lookup(self, seq_id: int, page_no: int) -> int | None:
        """Index-backed point lookup (tree search, not the dict)."""
        if self._index is None or self._index_dirty:
            self.rebuild_index()
        import jax.numpy as jnp

        q = jnp.asarray(_pack_key(seq_id, page_no))[None, :]
        found, rid, _ = search_batch(self._index.tree, q)
        return int(rid[0]) if bool(found[0]) else None

    @property
    def stats(self) -> dict:
        return {
            "pages_used": self.n_pages - len(self._free),
            "pages_free": len(self._free),
            "index_keys": len(self._table),
            "compression_ratio": (
                self._index.stats.get("compression_ratio") if self._index else None
            ),
        }
