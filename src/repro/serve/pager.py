"""Paged KV-cache manager whose page index is a reconstructable B-tree.

Pages of ``page_tokens`` KV slots are allocated from a free list; the page
table maps ``(seq_id, page_no) -> physical page``.  Exactly like the
paper's main-memory indexes, the *search index* over the page table is
never persisted: on engine restart (or replica bring-up) it is rebuilt from
the table rows with the compressed key sort — `(seq_id << bits) || page_no`
keys compress to their few distinction bits, and the bulk build produces
the lookup tree.

Every mutation is also journaled into a ``repro.replication.ChangeLog``
(alloc = INSERT of the packed key with the physical page as rid, free =
DELETE by physical page), and DS-metadata is kept current with the §4.3
insert rule.  A restart therefore *replays the pager's log*: the log folds
onto the keyset of the previous build and
``ReconstructionPipeline.run_incremental`` merges just the churn into the
standing sorted run — paying the full resort only when an alloc introduced
a new distinction bit.  ``rebuild_index`` *is* the paper's recovery path on
this table, now with its incremental fast path.

Gets are versioned: every rebuild publishes an epoch-stamped snapshot
into a ``repro.core.snapshot.SnapshotCell`` and ``lookup``/``lookup_batch``
pin the current epoch around the backend's plan-cached ``lookup`` op —
page gets racing a restart rebuild answer from the pre-rebuild index.

Concurrency contract: **single-writer, multi-reader**.  Mutations
(``alloc``/``free_seq``/``pages_for``) and ``rebuild_index`` belong to
one writer thread; ``lookup``/``lookup_batch`` may run from any number
of reader threads concurrently with both, because they only touch the
snapshot cell (thread-safe) and the backend's plan cache (thread-safe).
Readers default to *rebuild-on-read* when the index is dirty — the
single-threaded convenience — which is serialized under an internal
rebuild mutex; a concurrent serving deployment sets
``read_through_dirty=True`` so readers keep answering from the current
epoch while the writer folds the journal, and (optionally) bounds
staleness with the ``max_lag_epochs`` admission-control knob (journal
backlog is converted to epochs at ``lag_entries_per_epoch`` entries per
rebuild; over the bound, reads shed or park — see
``repro.core.snapshot``).
"""

from __future__ import annotations

import bisect
import threading
from dataclasses import dataclass, field

import numpy as np

from repro.core.btree import NOT_FOUND_RID  # noqa: F401  (re-export for callers)
from repro.core.keyformat import KeySet
from repro.core.metadata import DSMeta, meta_on_insert, shed_or_pin
from repro.core.pipeline import ReconstructionPipeline
from repro.core.reconstruct import ReconstructionResult
from repro.core.snapshot import SnapshotCell
from repro.replication import ChangeLog

__all__ = ["PagedKVManager"]


def _pack_key(seq_id: int, page_no: int) -> np.ndarray:
    """(seq_id, page_no) -> (2,) uint32 key words (word 0 most significant)."""
    return np.asarray([seq_id, page_no], dtype=np.uint32)


@dataclass
class PagedKVManager:
    """Paged KV allocator whose page index rebuilds via compressed key sort.

    Tracks ``(seq_id, page_no) -> physical page`` with a journaled free
    list; ``rebuild_index`` is the paper's recovery path over this table
    and every rebuild publishes a versioned snapshot that ``lookup`` /
    ``lookup_batch`` pin (see the module docstring for the lifecycle and
    the single-writer/multi-reader concurrency contract).
    """

    n_pages: int
    page_tokens: int
    backend: str = "jnp"  # execution backend for index reconstruction
    #: shed delete-stale distinction bits when frees since the last shed
    #: exceed this fraction of the live index (None = always pin, PR-2
    #: behavior; see Replica for the policy rationale)
    shed_delete_frac: float | None = None
    #: serving mode: readers answer from the current published epoch even
    #: while the journal is dirty, instead of triggering a rebuild from
    #: the read path (required when lookups run on reader threads
    #: concurrent with a writer — see the module concurrency contract)
    read_through_dirty: bool = False
    #: admission control: bound on rebuild lag (in epochs) before reads
    #: shed or park; None disables (see repro.core.snapshot.SnapshotCell)
    max_lag_epochs: int | None = None
    admission: str = "shed"
    park_timeout: float | None = None
    #: journal entries that count as one epoch of lag when converting the
    #: pending-log backlog into the cell's lag metric
    lag_entries_per_epoch: int = 64
    _deletes_since_shed: int = 0
    _free: list = field(default_factory=list)
    _table: dict = field(default_factory=dict)  # (seq, page_no) -> phys page
    _index: ReconstructionResult | None = None
    _index_dirty: bool = True
    # replication journal + incremental-rebuild state
    _log: ChangeLog = field(default_factory=lambda: ChangeLog(2), repr=False)
    _stream: object | None = field(default=None, repr=False)
    _base_keyset: KeySet | None = field(default=None, repr=False)
    _meta: DSMeta | None = field(default=None, repr=False)
    _sorted_keys: list | None = field(default=None, repr=False)
    _last_rebuild: dict = field(default_factory=dict, repr=False)
    # versioned read path: rebuilds publish epochs here, gets pin them
    _snapshots: SnapshotCell = field(default_factory=SnapshotCell, repr=False)
    _lookup_backend: object | None = field(default=None, repr=False)
    # serializes rebuild_index (rebuild-on-read racing an explicit rebuild)
    _rebuild_lock: threading.Lock = field(
        default_factory=threading.Lock, repr=False
    )

    def __post_init__(self):
        self._free = list(range(self.n_pages - 1, -1, -1))
        self._snapshots = SnapshotCell(
            max_lag_epochs=self.max_lag_epochs,
            admission=self.admission,
            park_timeout=self.park_timeout,
        )

    # ------------------------------------------------------------- mutation
    def alloc(self, seq_id: int, page_no: int) -> int:
        """Map ``(seq_id, page_no)`` to a fresh physical page (journaled).

        A re-alloc of a mapped slot retires the old physical page first;
        genuinely new keys advance DS-metadata with the §4.3 insert rule.
        Returns the physical page id.
        """
        if not self._free:
            raise MemoryError("KV pager out of pages")
        phys = self._free.pop()
        key_t = (int(seq_id), int(page_no))
        if key_t in self._table:
            # re-alloc of a mapped slot: retire the old physical page so the
            # log replay (delete old rid, insert new) matches the table
            old = self._table[key_t]
            self._free.append(old)
            self._log.append_deletes([old])
        elif self._meta is not None:
            # §4.3 insert rule against the current sorted key population
            # (only genuinely new keys extend it)
            keys = self._sorted_view()
            i = bisect.bisect_left(keys, key_t)
            a = np.asarray(keys[i - 1], np.uint32) if i > 0 else None
            b = np.asarray(keys[i], np.uint32) if i < len(keys) else None
            self._meta = meta_on_insert(self._meta, a, _pack_key(*key_t), b)
            keys.insert(i, key_t)
        self._table[key_t] = phys
        self._log.append_inserts(_pack_key(*key_t)[None, :], [phys])
        self._index_dirty = True
        self._report_lag()
        return phys

    def free_seq(self, seq_id: int) -> int:
        """Free every page of ``seq_id`` (lazy deletes: metadata untouched).

        Returns the number of pages released back to the free list.
        """
        gone = [k for k in self._table if k[0] == seq_id]
        freed = []
        for k in gone:
            phys = self._table.pop(k)
            self._free.append(phys)
            freed.append(phys)
            if self._sorted_keys is not None:
                j = bisect.bisect_left(self._sorted_keys, k)
                if j < len(self._sorted_keys) and self._sorted_keys[j] == k:
                    self._sorted_keys.pop(j)
        if freed:
            # DS-metadata untouched: the lazy delete rule (Theorem 2)
            self._log.append_deletes(freed)
            self._deletes_since_shed += len(freed)
        self._index_dirty = True
        self._report_lag()
        return len(gone)

    def _report_lag(self) -> None:
        """Writer-side: convert journal backlog into the cell's lag metric."""
        if self.max_lag_epochs is not None:
            self._snapshots.report_lag(len(self._log) // self.lag_entries_per_epoch)

    def pages_for(self, seq_id: int, n_tokens: int) -> list[int]:
        """Ensure pages covering n_tokens exist; returns physical page list."""
        need = -(-n_tokens // self.page_tokens)
        out = []
        for p in range(need):
            if (seq_id, p) not in self._table:
                self.alloc(seq_id, p)
            out.append(self._table[(seq_id, p)])
        return out

    def _sorted_view(self) -> list:
        if self._sorted_keys is None:
            self._sorted_keys = sorted(self._table)
        return self._sorted_keys

    # ---------------------------------------------------------- streaming
    def attach_stream(self, primary) -> None:
        """Ship this pager's journal over a replication stream.

        ``primary`` is a fire-and-forget ``repro.replication.StreamPrimary``
        (``keyset=None, n_words=2``) over any transport.  From then on,
        every ``rebuild_index`` also publishes the log batch it drains —
        a standby engine following the stream (``ServeEngine.follow``)
        keeps a warm copy of the page index and its restart replays the
        stream instead of a local journal.  Attach before the first
        mutation so the stream carries the table from LSN 0.
        """
        if primary.n_words != 2:
            raise ValueError("page-table stream must carry 2-word keys")
        if primary.next_lsn != self._log.start_lsn:
            raise ValueError(
                f"stream at LSN {primary.next_lsn} cannot carry a journal "
                f"starting at {self._log.start_lsn}"
            )
        self._stream = primary

    # ---------------------------------------------------------------- index
    def rebuild_index(self, backend: str | None = None) -> ReconstructionResult:
        """Reconstruct the page-table B-tree (the paper's recovery path).

        After the first build, the rebuild replays the mutation log: it
        folds onto the previous build's keyset and goes through the
        pipeline's incremental delta-merge path (byte-identical full-path
        fallback when the D-bitmap grew).  Serialized under an internal
        mutex so rebuild-on-read racing an explicit rebuild folds the
        journal exactly once.
        """
        with self._rebuild_lock:
            return self._rebuild_index_locked(backend)

    def _rebuild_index_locked(self, backend: str | None) -> ReconstructionResult:
        """Body of :meth:`rebuild_index`; caller holds ``_rebuild_lock``."""
        if not self._table:
            raise ValueError("empty page table")
        pipe = ReconstructionPipeline(backend=backend or self.backend)
        if self._index is None or self._base_keyset is None:
            items = sorted(self._table.items())
            words = np.stack([_pack_key(s, p) for (s, p), _ in items])
            rids = np.asarray([phys for _, phys in items], np.uint32)
            ks = KeySet(
                words=words, lengths=np.full(len(items), 8, np.int32), rids=rids
            )
            res = pipe.run(ks, publish_to=self._snapshots)
            folded = ks
        else:
            keep_rows, delta = self._log.fold_keyset(self._base_keyset)
            res, folded = pipe.run_incremental(
                self._index, self._base_keyset, delta,
                keep_rows=keep_rows, meta=self._meta,
                publish_to=self._snapshots,
            )
        self._index, self._base_keyset = res, folded
        # pin the working bitmap to the extraction bitmap so the next
        # restart can merge instead of resort — unless enough frees
        # accumulated to shed the delete-stale widened bits (shed_or_pin)
        self._meta, shed, self._deletes_since_shed = shed_or_pin(
            res.meta, res.extract_bitmap, self._deletes_since_shed,
            self.shed_delete_frac, folded.n,
        )
        self._last_rebuild = {
            "incremental": bool(res.stats.get("incremental", False)),
            "fallback": res.stats.get("incremental_fallback"),
            "log_entries_replayed": len(self._log),
            "shed_bits": shed,
        }
        if self._stream is not None and len(self._log):
            # ship the drained journal batch before resetting it: a standby
            # following the stream replays exactly what this rebuild folded
            self._stream.publish(self._log)
        self._log = ChangeLog(2, start_lsn=self._log.next_lsn)
        self._index_dirty = False
        self._report_lag()
        return res

    def _backend_obj(self):
        """The lookup backend instance (lazy; matches ``self.backend``)."""
        if self._lookup_backend is None:
            from repro.backends import get_backend

            self._lookup_backend = get_backend(self.backend)
        return self._lookup_backend

    def lookup_batch_versioned(
        self, pairs: np.ndarray
    ) -> tuple[np.ndarray, np.ndarray, int]:
        """Batched page gets with the answering epoch: ``(found, rid, epoch)``.

        Routes through the snapshot protocol: the current epoch is pinned
        for the whole probe, so gets racing a ``rebuild_index`` (a restart
        folding the journal) answer from the pre-rebuild index — never a
        torn one.  The probe is the backend's plan-cached ``lookup`` op.
        With ``read_through_dirty`` a dirty journal does *not* trigger a
        rebuild from the read path (only the very first build does);
        callers use the returned epoch to know which published state
        answered.  May raise ``repro.core.snapshot.AdmissionShed`` when
        admission control is on and rebuild lag exceeds the bound.
        """
        import jax.numpy as jnp

        if self._index is None or (self._index_dirty and not self.read_through_dirty):
            self.rebuild_index()
        q = jnp.asarray(np.asarray(pairs, np.uint32).reshape(-1, 2))
        with self._snapshots.pin() as snap:
            found, rid = self._backend_obj().lookup(snap.tree, q)
            epoch = snap.epoch
        return np.asarray(found, bool), np.asarray(rid, np.uint32), epoch

    def lookup_batch(self, pairs: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
        """Batched page gets: (q, 2) (seq_id, page_no) rows -> (found, rid).

        A thin wrapper over :meth:`lookup_batch_versioned` that drops the
        epoch stamp.
        """
        found, rid, _ = self.lookup_batch_versioned(pairs)
        return found, rid

    def lookup(self, seq_id: int, page_no: int) -> int | None:
        """Index-backed point lookup (tree search, not the dict).

        A thin wrapper over :meth:`lookup_batch` — one implementation for
        scalar and batched gets.
        """
        found, rid = self.lookup_batch(
            _pack_key(seq_id, page_no)[None, :]
        )
        return int(rid[0]) if bool(found[0]) else None

    @property
    def stats(self) -> dict:
        """Pager health: page occupancy, index state, journal backlog,
        last-rebuild breakdown, and the snapshot cell's exact counters."""
        return {
            "pages_used": self.n_pages - len(self._free),
            "pages_free": len(self._free),
            "index_keys": len(self._table),
            "compression_ratio": (
                self._index.stats.get("compression_ratio") if self._index else None
            ),
            "last_rebuild": dict(self._last_rebuild),
            "log_entries_pending": len(self._log),
            "snapshot_epoch": self._snapshots.epoch,
            "snapshot": self._snapshots.stats(),
        }
