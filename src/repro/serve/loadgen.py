"""Closed-loop concurrent serving load generator (reads racing rebuilds).

The snapshot protocol promises that lookups stay servable — torn-free
and epoch-exact — while the index is being rebuilt underneath them.
This module is the harness that *measures* that promise instead of
assuming it: N reader threads issue batched lookups through a shared
:class:`repro.core.snapshot.SnapshotCell` in a closed loop (each thread
fires its next request the moment the previous one completes — the
classic closed-loop load model, so offered load tracks service capacity
instead of overrunning it), while one writer thread drives
``ReconstructionPipeline.run_incremental(publish_to=cell)`` at a
configurable mutation rate.  Every response is verified, not just
timed:

* **torn-read check** — the ``(found, rid)`` batch is byte-compared
  against the *pinned epoch's* oracle (the host-side truth registered
  for that epoch before it was published).  Churned keys re-enter each
  epoch with rids that encode the epoch number, so a single stale or
  mixed lane flips the comparison.
* **stale-epoch check** — the epoch a request pinned must be at least
  the cell epoch observed just before its ``acquire``: a reader can
  race a publish forward, never backward.

Per-request wall latencies land in fixed-size :class:`LatencyReservoir`
samplers (one per thread — no shared-state contention on the hot path)
and the report aggregates p50/p90/p99, throughput, admission-control
counters (sheds / parks under the ``max_lag_epochs`` bound, see
``repro.core.snapshot``), exact cell counters, and the plan-cache trace
delta — warm concurrent serving must stay at **zero retraces**.

The same closed loop also runs against the serving page table:
:func:`run_pager_load` hammers ``PagedKVManager.lookup_batch`` (the op
behind ``ServeEngine.lookup_page``) from N threads while a writer
allocs/frees pages and folds the journal through ``rebuild_index``.

``benchmarks/bench_serve.py`` sweeps the (readers × mutation-rate)
grid on the jnp and pallas backends and gates p99-under-load in CI;
``tests/test_concurrent_snapshot.py`` runs the short and soak forms.
"""

from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field

import numpy as np

from repro.core import plancache
from repro.core.keyformat import KeySet
from repro.core.pipeline import ReconstructionPipeline
from repro.core.snapshot import AdmissionShed, SnapshotCell

__all__ = [
    "LatencyReservoir",
    "ReaderReport",
    "LoadReport",
    "pooled_percentiles",
    "run_load",
    "run_pager_load",
    "run_multitenant_load",
]


class LatencyReservoir:
    """Fixed-size uniform sample of a latency stream (Vitter's algorithm R).

    A closed-loop run at serving rates produces far more requests than a
    benchmark should hold in memory; the reservoir keeps a seeded,
    uniformly drawn ``capacity``-sized subset with O(1) per record, so
    percentiles over the sample converge on the stream's.  Single-owner:
    each reader thread records into its own reservoir and the report
    merges the samples afterwards.
    """

    def __init__(self, capacity: int = 4096, seed: int = 0) -> None:
        self.capacity = int(capacity)
        self._buf = np.zeros(self.capacity, np.float64)
        self.n_seen = 0
        self._rng = np.random.default_rng(seed)

    def record(self, value: float) -> None:
        """Offer one observation (reservoir-samples past capacity)."""
        i = self.n_seen
        self.n_seen += 1
        if i < self.capacity:
            self._buf[i] = value
            return
        j = int(self._rng.integers(0, i + 1))
        if j < self.capacity:
            self._buf[j] = value

    def samples(self) -> np.ndarray:
        """The retained sample (a copy, at most ``capacity`` long)."""
        return self._buf[: min(self.n_seen, self.capacity)].copy()


def _percentiles(samples: np.ndarray, ps=(50, 90, 99)) -> dict[str, float]:
    """p50/p90/p99 (µs) of a pooled sample array (zeros when empty)."""
    if samples.size == 0:
        return {f"p{p}_us": 0.0 for p in ps}
    return {f"p{p}_us": float(np.percentile(samples, p)) for p in ps}


def pooled_percentiles(reservoirs, ps=(50, 90, 99)) -> dict[str, float]:
    """Stream-weighted percentiles across per-thread reservoirs.

    Each reservoir is a uniform sample of *its own thread's* stream, so
    one retained sample stands for ``n_seen / len(samples)`` stream
    observations.  Concatenating the raw samples unweighted overweights
    slow threads — a thread that completed 8 requests contributes the
    same sample mass as one that completed 10000, dragging the pooled
    p99 toward the slow thread's tail.  Weighted nearest-rank instead:
    sort the pooled values, each carrying its per-thread weight, and
    read each percentile off the cumulative weight — equivalent to
    percentiles over the union of the original streams.
    """
    vals, wts = [], []
    for res in reservoirs:
        s = res.samples()
        if s.size == 0:
            continue
        vals.append(s)
        wts.append(np.full(s.size, res.n_seen / s.size, np.float64))
    if not vals:
        return {f"p{p}_us": 0.0 for p in ps}
    v = np.concatenate(vals)
    w = np.concatenate(wts)
    order = np.argsort(v, kind="stable")
    v, w = v[order], w[order]
    cw = np.cumsum(w)
    out = {}
    for p in ps:
        idx = int(np.searchsorted(cw, p / 100.0 * cw[-1], side="left"))
        out[f"p{p}_us"] = float(v[min(idx, v.size - 1)])
    return out


@dataclass
class ReaderReport:
    """One reader thread's closed-loop tally (verified, not just timed)."""

    n_requests: int = 0
    n_shed: int = 0
    torn_reads: int = 0
    stale_epochs: int = 0
    min_epoch: int | None = None
    max_epoch: int | None = None
    errors: list = field(default_factory=list)
    reservoir: LatencyReservoir = field(default_factory=LatencyReservoir)

    def saw_epoch(self, epoch: int) -> None:
        """Track the epoch span this reader actually served from."""
        if self.min_epoch is None or epoch < self.min_epoch:
            self.min_epoch = epoch
        if self.max_epoch is None or epoch > self.max_epoch:
            self.max_epoch = epoch


@dataclass
class LoadReport:
    """Aggregated result of one closed-loop run (see :func:`run_load`)."""

    n_readers: int
    duration_s: float
    batch: int
    n_requests: int
    n_shed: int
    torn_reads: int
    stale_epochs: int
    epochs_published: int
    warm_traces: int
    lookups_per_s: float
    p50_us: float
    p90_us: float
    p99_us: float
    unloaded_p50_us: float
    cell_stats: dict
    readers: list[ReaderReport]
    errors: list

    def to_row(self) -> dict:
        """Flat JSON-ready dict (benchmark row / CI gate input)."""
        return {
            "n_readers": self.n_readers,
            "duration_s": self.duration_s,
            "batch": self.batch,
            "n_requests": self.n_requests,
            "n_shed": self.n_shed,
            "torn_reads": self.torn_reads,
            "stale_epochs": self.stale_epochs,
            "epochs_published": self.epochs_published,
            "warm_traces": self.warm_traces,
            "lookups_per_s": self.lookups_per_s,
            "p50_us": self.p50_us,
            "p90_us": self.p90_us,
            "p99_us": self.p99_us,
            "unloaded_p50_us": self.unloaded_p50_us,
            "max_concurrent_pins": self.cell_stats["max_concurrent_pins"],
            "sheds": self.cell_stats["shed"],
            "parked": self.cell_stats["parked"],
            "retired_epochs": self.cell_stats["retired_epochs"],
        }


def _probe_keyset(rng, n_keys: int, n_words: int) -> KeySet:
    """A masked-random keyset (realistic few-distinction-bit tables)."""
    words = rng.integers(0, 2**32, size=(n_keys, n_words), dtype=np.uint32)
    words &= np.uint32(0x00FF0F0F)
    # dedupe: churn bookkeeping needs one rid per distinct key
    words = np.unique(words, axis=0)
    n = words.shape[0]
    return KeySet(
        words=words,
        lengths=np.full(n, n_words * 4, np.int32),
        rids=np.arange(n, dtype=np.uint32),
    )


def _expected_answers(
    truth: dict, probe_keys: np.ndarray
) -> tuple[np.ndarray, np.ndarray]:
    """(found, rid) oracle for the probe batch under the host truth dict."""
    q = probe_keys.shape[0]
    found = np.zeros(q, bool)
    rid = np.full(q, 0xFFFFFFFF, np.uint32)
    for i in range(q):
        r = truth.get(tuple(int(w) for w in probe_keys[i]))
        if r is not None:
            found[i] = True
            rid[i] = r
    return found, rid


def run_load(
    *,
    backend: str = "jnp",
    n_keys: int = 16384,
    n_words: int = 2,
    batch: int = 256,
    n_readers: int = 8,
    duration_s: float = 2.0,
    mutation_batch: int = 64,
    mutation_period_s: float = 0.0,
    target_mutation_period_s: float | None = None,
    max_lag_epochs: int | None = None,
    admission: str = "shed",
    park_timeout: float | None = 0.05,
    seed: int = 0,
    reservoir_capacity: int = 4096,
    warmup_cycles: int = 1,
) -> LoadReport:
    """Closed-loop readers vs. a live incremental-rebuild writer.

    Builds an ``n_keys`` index on ``backend``, publishes it into a
    shared :class:`SnapshotCell`, then runs ``n_readers`` threads each
    looping *acquire → batched lookup → verify → release* for
    ``duration_s`` while the writer thread redraws ``mutation_batch``
    keys per cycle (rids re-minted to encode the epoch) and folds them
    through ``run_incremental(publish_to=cell)`` every
    ``mutation_period_s`` seconds (0 = flat out).  Key population and
    tree geometry stay constant, so after ``warmup_cycles`` the whole
    run must replay cached programs — the report carries the exact
    plan-cache trace delta.

    ``max_lag_epochs``/``admission``/``park_timeout`` configure the
    cell's admission control; ``target_mutation_period_s`` (default:
    ``mutation_period_s``) is the feed rate the writer *owes* — its lag
    report is how many owed cycles its rebuilds have fallen behind, so
    a writer that cannot keep up trips the bound and sheds readers.

    Every response is byte-checked against its pinned epoch's oracle
    (torn reads) and its pinned epoch is checked against the epoch
    observed before acquire (stale epochs); both counts must be zero on
    a healthy protocol and the report carries them per reader.
    """
    import jax
    import jax.numpy as jnp

    from repro.backends import get_backend

    rng = np.random.default_rng(seed)
    ks = _probe_keyset(rng, n_keys, n_words)
    n = ks.n
    pipe = ReconstructionPipeline(backend=backend)
    backend_obj = get_backend(backend)
    cell = SnapshotCell(
        max_lag_epochs=max_lag_epochs,
        admission=admission,
        park_timeout=park_timeout,
    )

    # host truth: key tuple -> rid, mirrored by every publish's oracle
    words_h = np.asarray(ks.words)
    truth = {
        tuple(int(w) for w in words_h[i]): int(ks.rids[i]) for i in range(n)
    }

    # probe batch: stable keys, churn-eligible keys, and guaranteed misses.
    # Indices < churn_lo are never churned, so those probe lanes stay
    # constant-rid hits; lanes in the churn window change rid per epoch;
    # the xor'd lanes miss in every epoch.
    churn_lo = max(1, min(batch, n - mutation_batch))
    probe_idx = np.concatenate(
        [
            np.arange(0, batch // 2, dtype=np.int64) % churn_lo,
            churn_lo + np.arange(batch - batch // 2, dtype=np.int64)
            % max(1, n - churn_lo),
        ]
    )
    probe_keys = words_h[probe_idx].copy()
    # ~20% misses: bit 4 is outside the key mask, so the xor'd keys can
    # never collide with a real (current or churned) key
    probe_keys[::5] ^= np.uint32(0x10)

    oracles: dict[int, tuple[np.ndarray, np.ndarray]] = {}

    def register_oracle(epoch: int) -> None:
        oracles[epoch] = _expected_answers(truth, probe_keys)

    register_oracle(cell.epoch + 1)
    cur = pipe.run(ks, publish_to=cell)
    base = ks

    q_dev = jnp.asarray(probe_keys)

    def one_lookup(tree):
        f, r = backend_obj.lookup(tree, q_dev)
        jax.block_until_ready((f, r))
        return np.asarray(f, bool), np.asarray(r, np.uint32)

    # ------------------------------------------------------------ writer
    stop = threading.Event()
    writer_errors: list = []
    epoch_rid_base = 1 << 17  # churn rids: epoch * base + slot (encodes epoch)
    wrng = np.random.default_rng(seed + 1)
    target_period = (
        mutation_period_s
        if target_mutation_period_s is None
        else target_mutation_period_s
    )
    # host mirror of the folded keyset's row order: tags[i] = original key
    # id of base row i (the fold keeps surviving rows, then appends the
    # delta — so victim rows move to the tail each cycle)
    tags = np.arange(n, dtype=np.int64)

    def writer_cycle():
        nonlocal cur, base, tags
        # redraw `mutation_batch` keys from the churn window: delete + re-
        # insert the same key under a fresh epoch-coded rid.  n and the key
        # population stay constant => stable geometry, warm programs.
        next_epoch = cell.epoch + 1
        victims = churn_lo + wrng.choice(
            n - churn_lo, size=min(mutation_batch, n - churn_lo), replace=False
        )
        keep = ~np.isin(tags, victims)
        delta_words = words_h[victims]
        new_rids = (
            np.uint32(next_epoch * epoch_rid_base)
            + np.arange(len(victims), dtype=np.uint32)
        )
        delta = KeySet(
            words=delta_words,
            lengths=np.full(len(victims), n_words * 4, np.int32),
            rids=new_rids,
        )
        for i_k, key in enumerate(delta_words):
            truth[tuple(int(w) for w in key)] = int(new_rids[i_k])
        register_oracle(next_epoch)
        tags = np.concatenate([tags[keep], victims])
        cur, base = pipe.run_incremental(
            cur, base, delta, keep_rows=keep, meta=cur.meta, publish_to=cell
        )

    def writer_loop():
        t_start = time.perf_counter()
        cycles = 0
        try:
            while not stop.is_set():
                writer_cycle()
                cycles += 1
                # owed-minus-done backlog: the lag report admission reads
                if target_period and target_period > 0:
                    owed = (time.perf_counter() - t_start) / target_period
                    cell.report_lag(int(max(0.0, owed - cycles)))
                else:
                    cell.report_lag(0)
                if mutation_period_s > 0:
                    stop.wait(mutation_period_s)
        except Exception as e:  # pragma: no cover - surfaced in the report
            writer_errors.append(repr(e))
            stop.set()

    # ------------------------------------------------------------ readers
    def reader_loop(report: ReaderReport):
        try:
            while not stop.is_set():
                t0 = time.perf_counter()
                epoch_before = cell.epoch
                try:
                    pin = cell.acquire()
                except AdmissionShed:
                    report.n_shed += 1
                    stop.wait(0.001)  # shed backoff: don't spin the lock
                    continue
                try:
                    f, r = one_lookup(pin.tree)
                finally:
                    pin.release()
                report.reservoir.record((time.perf_counter() - t0) * 1e6)
                report.n_requests += 1
                report.saw_epoch(pin.snapshot.epoch)
                if pin.snapshot.epoch < epoch_before:
                    report.stale_epochs += 1
                exp_f, exp_r = oracles[pin.snapshot.epoch]
                if not (np.array_equal(f, exp_f) and np.array_equal(r, exp_r)):
                    report.torn_reads += 1
        except Exception as e:  # pragma: no cover - surfaced in the report
            report.errors.append(repr(e))

    # ------------------------------------------------- warmup + baseline
    one_lookup(cell.current.tree)
    for _ in range(max(warmup_cycles, 1)):
        writer_cycle()
    one_lookup(cell.current.tree)
    # unloaded closed-loop baseline: one thread, no writer — the
    # denominator of the machine-neutral tail-latency ratio
    unloaded = []
    for _ in range(16):
        t0 = time.perf_counter()
        one_lookup(cell.current.tree)
        unloaded.append((time.perf_counter() - t0) * 1e6)
    unloaded_p50 = float(np.percentile(np.asarray(unloaded), 50))

    s0 = plancache.cache_stats()
    reports = [
        ReaderReport(reservoir=LatencyReservoir(reservoir_capacity, seed + 10 + i))
        for i in range(n_readers)
    ]
    threads = [
        threading.Thread(target=reader_loop, args=(rep,), daemon=True)
        for rep in reports
    ]
    wt = threading.Thread(target=writer_loop, daemon=True)
    t_run0 = time.perf_counter()
    for t in threads:
        t.start()
    wt.start()
    time.sleep(duration_s)
    stop.set()
    for t in threads:
        t.join(timeout=30.0)
    wt.join(timeout=30.0)
    wall = time.perf_counter() - t_run0
    warm_traces = plancache.cache_stats()["traces"] - s0["traces"]

    pcts = pooled_percentiles([rep.reservoir for rep in reports])
    n_requests = sum(rep.n_requests for rep in reports)
    errors = writer_errors + [e for rep in reports for e in rep.errors]
    return LoadReport(
        n_readers=n_readers,
        duration_s=wall,
        batch=len(probe_keys),
        n_requests=n_requests,
        n_shed=sum(rep.n_shed for rep in reports),
        torn_reads=sum(rep.torn_reads for rep in reports),
        stale_epochs=sum(rep.stale_epochs for rep in reports),
        epochs_published=cell.stats()["n_published"],
        warm_traces=warm_traces,
        lookups_per_s=n_requests * len(probe_keys) / max(wall, 1e-9),
        unloaded_p50_us=unloaded_p50,
        cell_stats=cell.stats(),
        readers=reports,
        errors=errors,
        **pcts,
    )


def run_pager_load(
    *,
    n_pages: int = 4096,
    page_tokens: int = 16,
    n_seqs: int = 32,
    pages_per_seq: int = 8,
    n_readers: int = 4,
    duration_s: float = 1.0,
    rebuild_period_s: float = 0.0,
    max_lag_epochs: int | None = None,
    admission: str = "shed",
    seed: int = 0,
) -> dict:
    """Closed-loop page gets racing live pager mutation + rebuilds.

    The serving-side twin of :func:`run_load`: readers hammer
    ``PagedKVManager.lookup_batch`` (the index probe behind
    ``ServeEngine.lookup_page``) over a fixed probe set of
    ``(seq_id, page_no)`` pairs while the writer thread frees and
    re-allocates one sequence per cycle and folds the journal through
    ``rebuild_index`` — each rebuild publishes the next epoch into the
    pager's cell.  Responses are checked against the per-epoch oracle
    of the page table (registered before each publish), so a torn or
    stale probe is a counted failure, not a flake.  Returns a flat
    stats dict (requests, torn/stale counts, sheds, epochs, p50/p99).
    """
    from repro.serve.pager import PagedKVManager

    pm = PagedKVManager(
        n_pages=n_pages,
        page_tokens=page_tokens,
        read_through_dirty=True,
        max_lag_epochs=max_lag_epochs,
        admission=admission,
    )
    for s in range(n_seqs):
        pm.pages_for(s, pages_per_seq * page_tokens)
    pm.rebuild_index()

    probe = np.asarray(
        [(s, p) for s in range(n_seqs) for p in range(pages_per_seq)][:256],
        np.uint32,
    )
    oracles: dict[int, tuple[np.ndarray, np.ndarray]] = {}

    def register_oracle(epoch: int) -> None:
        found = np.zeros(len(probe), bool)
        rid = np.full(len(probe), 0xFFFFFFFF, np.uint32)
        for i, (s, p) in enumerate(probe):
            phys = pm._table.get((int(s), int(p)))
            if phys is not None:
                found[i] = True
                rid[i] = phys
        oracles[epoch] = (found, rid)

    register_oracle(pm._snapshots.epoch)
    pm.lookup_batch(probe)  # warm the probe program

    stop = threading.Event()
    errors: list = []
    counts = {"requests": 0, "torn": 0, "stale": 0, "shed": 0}
    lock = threading.Lock()
    reservoirs = [LatencyReservoir(2048, seed + i) for i in range(n_readers)]

    def reader(idx: int):
        res = reservoirs[idx]
        try:
            while not stop.is_set():
                t0 = time.perf_counter()
                epoch_before = pm._snapshots.epoch
                try:
                    found, rid, epoch = pm.lookup_batch_versioned(probe)
                except AdmissionShed:
                    with lock:
                        counts["shed"] += 1
                    stop.wait(0.001)  # shed backoff: don't spin the lock
                    continue
                res.record((time.perf_counter() - t0) * 1e6)
                exp_f, exp_r = oracles[epoch]
                torn = not (
                    np.array_equal(found, exp_f) and np.array_equal(rid, exp_r)
                )
                with lock:
                    counts["requests"] += 1
                    if torn:
                        counts["torn"] += 1
                    if epoch < epoch_before:
                        counts["stale"] += 1
        except Exception as e:  # pragma: no cover
            errors.append(repr(e))

    wrng = np.random.default_rng(seed + 99)

    def writer():
        try:
            while not stop.is_set():
                victim = int(wrng.integers(0, n_seqs))
                pm.free_seq(victim)
                pm.pages_for(victim, pages_per_seq * page_tokens)
                register_oracle(pm._snapshots.epoch + 1)
                pm.rebuild_index()
                if rebuild_period_s > 0:
                    stop.wait(rebuild_period_s)
        except Exception as e:  # pragma: no cover
            errors.append(repr(e))
            stop.set()

    threads = [
        threading.Thread(target=reader, args=(i,), daemon=True)
        for i in range(n_readers)
    ]
    wt = threading.Thread(target=writer, daemon=True)
    for t in threads:
        t.start()
    wt.start()
    time.sleep(duration_s)
    stop.set()
    for t in threads:
        t.join(timeout=30.0)
    wt.join(timeout=30.0)

    pcts = pooled_percentiles(reservoirs)
    return {
        "n_readers": n_readers,
        "n_requests": counts["requests"],
        "torn_reads": counts["torn"],
        "stale_epochs": counts["stale"],
        "n_shed": counts["shed"],
        "epochs_published": pm._snapshots.stats()["n_published"],
        "snapshot": pm._snapshots.stats(),
        "errors": errors,
        **pcts,
    }


def _probe_keyset_exact(rng, n_keys: int, n_words: int) -> KeySet:
    """A masked-random keyset with *exactly* ``n_keys`` distinct keys.

    The multi-tenant arena buckets tenants by tree geometry, which is a
    function of the key count — every tenant in one arena must hold the
    same ``n``.  Draw an oversized masked pool, dedupe, and slice.
    """
    pool = rng.integers(0, 2**32, size=(2 * n_keys + 64, n_words), dtype=np.uint32)
    pool &= np.uint32(0x00FF0F0F)
    pool = np.unique(pool, axis=0)
    if pool.shape[0] < n_keys:  # pragma: no cover - masked space is ~2^32
        raise ValueError(f"masked pool too small: {pool.shape[0]} < {n_keys}")
    words = pool[rng.permutation(pool.shape[0])[:n_keys]]
    return KeySet(
        words=words,
        lengths=np.full(n_keys, n_words * 4, np.int32),
        rids=np.arange(n_keys, dtype=np.uint32),
    )


def run_multitenant_load(
    *,
    backend: str = "jnp",
    n_tenants: int = 4,
    n_keys: int = 2048,
    n_words: int = 2,
    batch: int = 128,
    n_readers: int = 4,
    duration_s: float = 1.5,
    mutation_batch: int = 48,
    mutation_period_s: float = 0.0,
    target_p99_us: float | None = None,
    slo_window: int = 64,
    fairness_limit: int = 16,
    max_delay_s: float = 0.002,
    max_batch_queries: int = 4096,
    seed: int = 0,
    warmup_cycles: int = 1,
) -> dict:
    """Closed-loop multi-tenant readers vs. per-tenant churn writers.

    The fleet form of :func:`run_load`: ``n_tenants`` same-geometry
    indexes (exactly ``n_keys`` each) publish into per-tenant
    :class:`SnapshotCell`\\ s and join one
    :class:`~repro.serve.tenants.TenantRegistry` arena; ``n_readers``
    threads round-robin over the tenants submitting probe batches
    through a :class:`~repro.serve.tenants.MultiTenantEngine`, whose
    dispatcher fuses the cross-tenant queues into single
    ``lookup_many`` dispatches.  One writer thread churns the tenants
    round-robin — per-tenant delete+reinsert with epoch-coded rids, key
    population and geometry constant — so warm traffic must replay
    cached programs (the report carries the exact trace delta).

    Every response is verified against its ``(tenant, epoch)`` oracle
    registered before that epoch published (torn check), and its epoch
    must not precede the arena epoch observed before submit (stale
    check).  ``target_p99_us`` turns on the
    :class:`~repro.serve.tenants.SLOAdmissionController`: sheds and
    forced admits land in the report, and ``served_per_tenant`` lets the
    caller assert no tenant starved.
    """
    import jax.numpy as jnp

    from repro.backends import get_backend
    from repro.core.snapshot import SnapshotCell
    from repro.serve.tenants import (
        MultiTenantEngine,
        SLOAdmissionController,
        SLOConfig,
        TenantRegistry,
    )

    backend_obj = get_backend(
        backend, **({"interpret": True} if backend == "pallas" else {})
    )
    registry = TenantRegistry()
    slo = (
        None
        if target_p99_us is None
        else SLOAdmissionController(
            SLOConfig(
                target_p99_us=float(target_p99_us),
                window=slo_window,
                fairness_limit=fairness_limit,
            )
        )
    )

    # ------------------------------------------------ per-tenant state
    tenants = list(range(n_tenants))
    cells, pipes, states, probes = {}, {}, {}, {}
    oracles: dict[tuple, tuple[np.ndarray, np.ndarray]] = {}
    epoch_rid_base = 1 << 17

    for t in tenants:
        rng = np.random.default_rng(seed + 1000 * (t + 1))
        ks = _probe_keyset_exact(rng, n_keys, n_words)
        words_h = np.asarray(ks.words)
        truth = {
            tuple(int(w) for w in words_h[i]): int(ks.rids[i])
            for i in range(n_keys)
        }
        churn_lo = max(1, min(batch, n_keys - mutation_batch))
        probe_idx = np.concatenate(
            [
                np.arange(0, batch // 2, dtype=np.int64) % churn_lo,
                churn_lo
                + np.arange(batch - batch // 2, dtype=np.int64)
                % max(1, n_keys - churn_lo),
            ]
        )
        probe_keys = words_h[probe_idx].copy()
        probe_keys[::5] ^= np.uint32(0x10)  # guaranteed misses (outside mask)
        probes[t] = probe_keys

        cell = SnapshotCell()
        pipe = ReconstructionPipeline(backend=backend)
        oracles[(t, cell.epoch + 1)] = _expected_answers(truth, probe_keys)
        cur = pipe.run(ks, publish_to=cell)
        cells[t], pipes[t] = cell, pipe
        states[t] = {
            "cur": cur,
            "base": ks,
            "tags": np.arange(n_keys, dtype=np.int64),
            "truth": truth,
            "words": words_h,
            "churn_lo": churn_lo,
            "wrng": np.random.default_rng(seed + 2000 * (t + 1)),
        }
        registry.publish(t, cell)

    engine = MultiTenantEngine(
        registry,
        backend_obj,
        max_batch_queries=max_batch_queries,
        max_delay_s=max_delay_s,
        slo=slo,
    )

    # ------------------------------------------------------------ writer
    stop = threading.Event()
    writer_errors: list = []

    def writer_cycle(t: int) -> None:
        st = states[t]
        cell = cells[t]
        next_epoch = cell.epoch + 1
        churn_lo, words_h, truth = st["churn_lo"], st["words"], st["truth"]
        wrng = st["wrng"]
        victims = churn_lo + wrng.choice(
            n_keys - churn_lo,
            size=min(mutation_batch, n_keys - churn_lo),
            replace=False,
        )
        keep = ~np.isin(st["tags"], victims)
        delta_words = words_h[victims]
        new_rids = (
            np.uint32(next_epoch * epoch_rid_base)
            + np.arange(len(victims), dtype=np.uint32)
        )
        delta = KeySet(
            words=delta_words,
            lengths=np.full(len(victims), n_words * 4, np.int32),
            rids=new_rids,
        )
        for i_k, key in enumerate(delta_words):
            truth[tuple(int(w) for w in key)] = int(new_rids[i_k])
        oracles[(t, next_epoch)] = _expected_answers(truth, probes[t])
        st["tags"] = np.concatenate([st["tags"][keep], victims])
        st["cur"], st["base"] = pipes[t].run_incremental(
            st["cur"], st["base"], delta, keep_rows=keep,
            meta=st["cur"].meta, publish_to=cell,
        )
        registry.publish(t, cell)

    def writer_loop():
        i = 0
        try:
            while not stop.is_set():
                writer_cycle(tenants[i % n_tenants])
                i += 1
                if mutation_period_s > 0:
                    stop.wait(mutation_period_s)
        except Exception as e:  # pragma: no cover - surfaced in the report
            writer_errors.append(repr(e))
            stop.set()

    # ----------------------------------------------------------- readers
    counts = {"requests": 0, "torn": 0, "stale": 0, "shed": 0}
    count_lock = threading.Lock()
    reservoirs = [LatencyReservoir(4096, seed + 10 + i) for i in range(n_readers)]
    reader_errors: list = []

    def reader_loop(idx: int):
        res = reservoirs[idx]
        i = idx  # stagger tenant phase across readers
        try:
            while not stop.is_set():
                t = tenants[i % n_tenants]
                i += 1
                arena = registry.arena_of(t)
                epoch_before = arena.epochs[t] if arena is not None else -1
                t0 = time.perf_counter()
                try:
                    found, rid, epoch = engine.submit(t, probes[t])
                except AdmissionShed:
                    with count_lock:
                        counts["shed"] += 1
                    stop.wait(0.0005)  # shed backoff
                    continue
                res.record((time.perf_counter() - t0) * 1e6)
                exp_f, exp_r = oracles[(t, epoch)]
                torn = not (
                    np.array_equal(found, exp_f) and np.array_equal(rid, exp_r)
                )
                with count_lock:
                    counts["requests"] += 1
                    if torn:
                        counts["torn"] += 1
                    if epoch < epoch_before:
                        counts["stale"] += 1
        except Exception as e:  # pragma: no cover - surfaced in the report
            reader_errors.append(repr(e))

    # ------------------------------------------------ warmup + baseline
    for _ in range(max(warmup_cycles, 1)):
        for t in tenants:
            writer_cycle(t)
    # warm the fused program (arena-capacity x probe-bucket shape) and
    # measure the unloaded fused round trip (micro-batch delay included —
    # the same path the loaded readers pay)
    for t in tenants:
        engine.submit(t, probes[t])
    # warm every query bucket the dispatcher can coalesce into: under
    # backlog one tenant's queued requests fuse into blocks up to the
    # bounded take (max_batch_queries plus one request of overshoot),
    # and a mid-run retrace would stall every tenant in the batch
    arena0 = registry.arena_of(tenants[0])
    qcap = max_batch_queries + batch
    qb = plancache.bucket_for("lookup_many", batch)
    while True:
        blk = np.full((1, qb, n_words), 0xFFFFFFFF, np.uint32)
        backend_obj.lookup_many(arena0.stacked, blk, np.zeros(1, np.uint32))
        if qb >= qcap:
            break
        qb *= 2
    unloaded = []
    for _ in range(8):
        t0 = time.perf_counter()
        engine.submit(tenants[0], probes[tenants[0]])
        unloaded.append((time.perf_counter() - t0) * 1e6)
    unloaded_p50 = float(np.percentile(np.asarray(unloaded), 50))

    s0 = plancache.cache_stats()
    threads = [
        threading.Thread(target=reader_loop, args=(i,), daemon=True)
        for i in range(n_readers)
    ]
    wt = threading.Thread(target=writer_loop, daemon=True)
    t_run0 = time.perf_counter()
    for th in threads:
        th.start()
    wt.start()
    time.sleep(duration_s)
    stop.set()
    for th in threads:
        th.join(timeout=30.0)
    wt.join(timeout=30.0)
    engine.shutdown()
    wall = time.perf_counter() - t_run0
    warm_traces = plancache.cache_stats()["traces"] - s0["traces"]

    pcts = pooled_percentiles(reservoirs)
    eng_stats = engine.stats()
    return {
        "backend": backend,
        "n_tenants": n_tenants,
        "n_readers": n_readers,
        "duration_s": wall,
        "batch": batch,
        "n_requests": counts["requests"],
        "n_shed": counts["shed"],
        "torn_reads": counts["torn"],
        "stale_epochs": counts["stale"],
        "epochs_published": sum(
            cells[t].stats()["n_published"] for t in tenants
        ),
        "warm_traces": warm_traces,
        "lookups_per_s": counts["requests"] * batch / max(wall, 1e-9),
        "unloaded_p50_us": unloaded_p50,
        "served_per_tenant": eng_stats["served_per_tenant"],
        "n_batches": eng_stats["n_batches"],
        "n_dispatches": eng_stats["n_dispatches"],
        "registry": registry.stats(),
        "slo": None if slo is None else slo.stats(),
        "errors": writer_errors + reader_errors,
        **pcts,
    }
