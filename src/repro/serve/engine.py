"""Batched serving engine: prefill + greedy/temperature decode loop.

Drives the model's ``prefill``/``decode_step`` with a contiguous KV cache
(the paged manager tracks logical->physical pages for admission control and
restart-time index rebuild).  Jit-compiled per (batch, max_seq) signature.
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models.lm import LM

from .pager import PagedKVManager

__all__ = ["ServeEngine"]


@dataclass
class ServeEngine:
    model: LM
    params: dict
    max_seq: int
    batch_size: int
    page_tokens: int = 128
    #: concurrent-serving knobs, forwarded to the pager: serve page gets
    #: from the current published epoch while the journal is dirty
    #: (required when lookups run on reader threads), and optionally bound
    #: rebuild lag with admission control (see PagedKVManager)
    read_through_dirty: bool = False
    max_lag_epochs: int | None = None
    admission: str = "shed"

    def __post_init__(self):
        cfg = self.model.cfg
        self.pager = PagedKVManager(
            n_pages=self.batch_size * (-(-self.max_seq // self.page_tokens)) * 2,
            page_tokens=self.page_tokens,
            read_through_dirty=self.read_through_dirty,
            max_lag_epochs=self.max_lag_epochs,
            admission=self.admission,
        )
        self._prefill = jax.jit(self.model.prefill)
        self._decode = jax.jit(self.model.decode_step)
        self._cache = None
        self._pos = 0
        self._follow = None

    def admit(self, tokens: np.ndarray, extras: dict | None = None) -> jnp.ndarray:
        """Prefill a (B, T) batch of prompts; returns last-token logits."""
        B, T = tokens.shape
        assert B == self.batch_size and T <= self.max_seq
        for b in range(B):
            self.pager.pages_for(seq_id=b, n_tokens=T)
        cache = self.model.init_cache(B, self.max_seq)
        batch = {"tokens": jnp.asarray(tokens, jnp.int32)}
        if extras:
            batch.update(extras)
        self._cache, logits = self._prefill(self.params, batch, cache)
        self._pos = T
        return logits

    def step(self, tokens: np.ndarray, extras: dict | None = None) -> jnp.ndarray:
        """One decode step for the whole batch; returns (B, V) logits."""
        for b in range(self.batch_size):
            self.pager.pages_for(seq_id=b, n_tokens=self._pos + 1)
        batch = {
            "token": jnp.asarray(tokens, jnp.int32),
            "pos": jnp.int32(self._pos),
        }
        if extras:
            batch.update(extras)
        self._cache, logits = self._decode(self.params, self._cache, batch)
        self._pos += 1
        return logits

    def generate(self, prompts: np.ndarray, n_new: int, temperature: float = 0.0,
                 seed: int = 0, extras: dict | None = None) -> np.ndarray:
        """Greedy (or sampled) continuation of (B, T) prompts by n_new tokens."""
        logits = self.admit(prompts, extras)
        key = jax.random.PRNGKey(seed)
        out = []
        tok = self._pick(logits, temperature, key)
        for i in range(n_new):
            out.append(np.asarray(tok))
            if self._pos >= self.max_seq:
                break
            key, sub = jax.random.split(key)
            logits = self.step(tok, extras)
            tok = self._pick(logits, temperature, sub)
        return np.stack(out, axis=1)

    @staticmethod
    def _pick(logits, temperature, key):
        if temperature <= 0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return jax.random.categorical(key, logits / temperature, axis=-1).astype(
            jnp.int32
        )

    # ------------------------------------------------------------ page gets
    def lookup_page(self, seq_id: int, page_no: int) -> int | None:
        """Resolve a logical page through the index read path.

        On a primary this is the pager's snapshot-pinned ``lookup`` (the
        plan-cached backend op against the current epoch); on a following
        standby (``follow``) it reads through the stream replica's pinned
        snapshot — either way a get racing a rebuild answers from the
        pre-rebuild epoch, never a torn index.
        """
        if self._follow is not None:
            found, rid = self._follow.search(
                np.asarray([seq_id, page_no], np.uint32)
            )
            return int(rid) if found else None
        return self.pager.lookup(seq_id, page_no)

    # ------------------------------------------------------- fault recovery
    def follow(self, stream_replica) -> None:
        """Run this engine as a streaming standby of another engine's pager.

        ``stream_replica`` is a ``repro.replication.StreamReplica`` over
        the transport a primary pager publishes to (see
        ``PagedKVManager.attach_stream``).  From then on ``restart``
        replays the *stream* instead of the local journal: the standby's
        page index is reconstructed from the primary's shipped change-log
        batches, so a failover starts from a warm, current index without
        ever receiving an index image.
        """
        self._follow = stream_replica

    def restart(self, backend: str | None = None) -> dict:
        """Simulated engine restart: decode state dropped, page index
        reconstructed from the page table (paper §5 applied to serving).
        ``backend`` picks the reconstruction substrate for this restart
        (defaults to the pager's configured backend).  After the first
        restart the pager replays its mutation log through the incremental
        delta-merge path — ``incremental``/``log_entries_replayed`` in the
        returned stats say which path ran and how much churn it folded.
        A following standby (``follow``) instead drains its stream replica
        and reports the stream watermark/lag alongside the rebuild stats;
        the stream replica's backend is fixed at construction, so passing
        ``backend`` to a following restart is an error, not a silent no-op.
        """
        if self._follow is not None:
            if backend is not None:
                raise ValueError(
                    "a following standby rebuilds on its StreamReplica's "
                    "backend; construct the replica with backend=... instead"
                )
            poll = self._follow.poll()
            rep = self._follow.replica
            if rep is None:
                raise RuntimeError("standby stream has delivered no state yet")
            res = rep.result
            # a shed frame can split the poll into several apply spans —
            # account for all of them, not just the last
            applies = poll.get("applies") or (
                [poll["apply"]] if poll.get("apply") else []
            )
            return {
                "index_height": res.tree.height,
                "compression_ratio": res.stats["compression_ratio"],
                "backend": res.stats["backend"],
                "followed_stream": True,
                "applied_lsn": poll["applied_lsn"],
                "lag_frames": poll["lag_frames"],
                "catchup": poll["catchup"],
                "incremental": bool(applies)
                and all(st.get("incremental", False) for st in applies),
                "log_entries_replayed": sum(
                    st.get("n_delta", 0) + st.get("n_deleted", 0)
                    for st in applies
                ),
                "snapshot_epoch": rep.snapshots.epoch,
            }
        res = self.pager.rebuild_index(backend=backend)
        tm = res.timings
        stage_keys = ("meta", "extract", "sort", "build", "refresh_meta",
                      "filter", "merge")
        return {
            "index_height": res.tree.height,
            "compression_ratio": res.stats["compression_ratio"],
            # the restart pays every stage, metadata refresh included —
            # tm["total"] is only the paper's extract+sort+build breakdown
            "rebuild_s": tm["meta"] + tm["total"] + tm["refresh_meta"],
            "backend": res.stats["backend"],
            "stage_s": {k: tm[k] for k in stage_keys if k in tm},
            "snapshot_epoch": self.pager.stats["snapshot_epoch"],
            **self.pager.stats["last_rebuild"],
        }
