"""Multi-tenant serving: geometry-bucketed arenas + fused cross-tenant reads.

The backend layer's ``lookup_many`` answers T same-geometry snapshots in
one compiled program; this module is the serving machinery that keeps a
*fleet* of live tenants shaped for it:

* :class:`TenantRegistry` bin-packs live :class:`IndexSnapshot`\\ s into
  **arenas** — one immutable stacked tree per distinct
  ``tree_geometry`` — as tenants publish and retire.  Every publish pins
  its snapshot's epoch (the ``SnapshotCell`` lease protocol), restacks
  only the affected arena(s), and atomically swaps the tenant→arena
  view, so readers are never blocked and never see a half-migrated
  arena: a rebuild that *changes* a tenant's geometry moves it to a
  different bucket without touching any other arena.
* :class:`MultiTenantEngine` coalesces per-tenant request queues into
  fused cross-tenant batches: requests accumulate until a size or time
  bound trips, then one ``backend.lookup_many`` per touched arena
  answers every tenant's block in a single dispatch — N Python
  dispatches become one, which is where the fan-out throughput comes
  from (``benchmarks/bench_multitenant.py`` gates the ratio).
* :class:`SLOAdmissionController` replaces the fixed ``max_lag_epochs``
  bound with latency-target admission: a per-tenant reservoir meters
  each tenant's p99 and an AIMD loop adjusts a per-tenant shed fraction
  to hold the configured tail target — backing off admission when the
  tail overshoots, relaxing when it clears, and never fully starving a
  tenant (a fairness bound forces an admit after ``fairness_limit``
  consecutive sheds; the forced-admit counter is asserted in tests).

Torn/stale safety is inherited, not re-proven: an arena is built from
epoch-pinned snapshots and is itself immutable, so a fused batch answers
every tenant from exactly one ``(snapshot, epoch)`` pair — the same
invariant the single-tenant ``SnapshotCell`` protocol gives one reader,
lifted over the tenant axis.  ``repro.serve.loadgen.run_multitenant_load``
is the closed-loop harness that verifies it under churn.
"""

from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field

import numpy as np

from repro.core.btree import stack_trees, tree_geometry
from repro.core.snapshot import AdmissionShed, IndexSnapshot, SnapshotPin

__all__ = [
    "Arena",
    "TenantRegistry",
    "MultiTenantEngine",
    "SLOConfig",
    "SLOAdmissionController",
]


@dataclass(frozen=True)
class Arena:
    """One geometry bucket: T pinned snapshots stacked into one tree.

    Immutable — the registry replaces arenas wholesale, so an in-flight
    fused batch keeps answering from the arena object it grabbed (its
    stacked arrays are independent copies and its ``epochs`` map is
    frozen with it) even while the registry migrates tenants underneath.
    ``slots[tenant]`` is the tenant's row in the stacked tree;
    ``capacity`` is the stack's (power-of-two, no-shrink) tenant axis,
    so joins within capacity replay the same compiled program.
    """

    geometry: tuple
    tenants: tuple
    slots: dict
    stacked: object
    epochs: dict
    capacity: int


class TenantRegistry:
    """Live tenant snapshots bin-packed into geometry-bucketed arenas.

    Writers (tenant publish/retire) serialize on one mutation lock and
    only restack the arena(s) the tenant belongs to; the tenant→arena
    ``view()`` is an immutable dict swapped atomically after each
    mutation, so the engine's read path is lock-free.  Each tenant's
    snapshot is held alive by a :class:`SnapshotPin` lease until the
    tenant republishes or retires — an arena can therefore never
    reference freed epochs (the zero-torn guarantee's first half; the
    second is arena immutability).
    """

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._members: dict[tuple, list] = {}  # geometry -> ordered tenants
        self._tenant_geom: dict = {}
        self._pins: dict = {}  # tenant -> SnapshotPin | None
        self._snaps: dict = {}  # tenant -> IndexSnapshot
        self._arenas: dict[tuple, Arena] = {}
        self._view: dict = {}  # tenant -> Arena, replaced atomically
        self.n_publishes = 0
        self.n_retires = 0
        self.n_migrations = 0
        self.n_restacks = 0

    # ------------------------------------------------------------ mutation
    def publish(self, tenant, source) -> Arena:
        """Join or refresh ``tenant`` with a snapshot; returns its arena.

        ``source`` is a ``SnapshotCell`` (its current epoch is pinned —
        the normal serving wiring, so the cell cannot free the epoch an
        arena still answers from) or a bare :class:`IndexSnapshot` (no
        lease, for static fleets).  A republish at the same geometry
        restacks one arena in place (slot preserved); a geometry change
        migrates the tenant to its new bucket and restacks both arenas —
        readers of every other arena are untouched and never wait.
        """
        if hasattr(source, "acquire"):
            pin: SnapshotPin | None = source.acquire()
            snap = pin.snapshot
        else:
            pin, snap = None, source
        if not isinstance(snap, IndexSnapshot):
            raise TypeError(f"expected SnapshotCell or IndexSnapshot, got {snap!r}")
        geom = tree_geometry(snap.tree)
        with self._lock:
            old_pin = self._pins.get(tenant)
            old_geom = self._tenant_geom.get(tenant)
            self._pins[tenant] = pin
            self._snaps[tenant] = snap
            self._tenant_geom[tenant] = geom
            if old_geom is not None and old_geom != geom:
                self.n_migrations += 1
                self._members[old_geom].remove(tenant)
                self._rebuild_arena_locked(old_geom)
            if tenant not in self._members.setdefault(geom, []):
                self._members[geom].append(tenant)
            arena = self._rebuild_arena_locked(geom)
            self._swap_view_locked()
            self.n_publishes += 1
        if old_pin is not None:
            old_pin.release()
        return arena

    def retire(self, tenant) -> None:
        """Remove ``tenant``; its arena restacks without it.

        The tenant's epoch pin is released after the view swap, so a
        fused batch already in flight on the old arena object still
        answers from intact (copied) arrays; new batches no longer see
        the tenant and the engine sheds its queued requests.
        """
        with self._lock:
            if tenant not in self._tenant_geom:
                raise KeyError(f"unknown tenant {tenant!r}")
            geom = self._tenant_geom.pop(tenant)
            pin = self._pins.pop(tenant)
            self._snaps.pop(tenant)
            self._members[geom].remove(tenant)
            self._rebuild_arena_locked(geom)
            self._swap_view_locked()
            self.n_retires += 1
        if pin is not None:
            pin.release()

    def _rebuild_arena_locked(self, geom: tuple) -> Arena | None:
        """Restack one geometry bucket from its members' pinned trees."""
        members = self._members.get(geom, [])
        if not members:
            self._members.pop(geom, None)
            self._arenas.pop(geom, None)
            return None
        prev = self._arenas.get(geom)
        needed = 1 << max(0, (len(members) - 1).bit_length())
        # no-shrink hysteresis: keep the old capacity so churn at the
        # boundary does not flip the compiled program's tenant axis
        capacity = max(needed, prev.capacity if prev is not None else 1)
        trees = [self._snaps[t].tree for t in members]
        arena = Arena(
            geometry=geom,
            tenants=tuple(members),
            slots={t: i for i, t in enumerate(members)},
            stacked=stack_trees(trees, capacity=capacity),
            epochs={t: int(self._snaps[t].epoch) for t in members},
            capacity=capacity,
        )
        self._arenas[geom] = arena
        self.n_restacks += 1
        return arena

    def _swap_view_locked(self) -> None:
        self._view = {
            t: self._arenas[g] for t, g in self._tenant_geom.items()
        }

    # ---------------------------------------------------------------- reads
    def view(self) -> dict:
        """The current tenant→arena map (immutable; atomic swap on mutate)."""
        return self._view

    def arena_of(self, tenant) -> Arena | None:
        """The arena currently serving ``tenant`` (``None`` if absent)."""
        return self._view.get(tenant)

    def stats(self) -> dict:
        """Registry counters + per-arena occupancy (taken under the lock)."""
        with self._lock:
            return {
                "n_tenants": len(self._tenant_geom),
                "n_arenas": len(self._arenas),
                "n_publishes": self.n_publishes,
                "n_retires": self.n_retires,
                "n_migrations": self.n_migrations,
                "n_restacks": self.n_restacks,
                "arenas": [
                    {"tenants": len(a.tenants), "capacity": a.capacity}
                    for a in self._arenas.values()
                ],
            }


@dataclass
class SLOConfig:
    """Knobs for :class:`SLOAdmissionController` (see class docstring)."""

    target_p99_us: float
    window: int = 64
    step: float = 0.15
    relax: float = 0.7
    max_shed_frac: float = 0.9
    fairness_limit: int = 16
    reservoir_capacity: int = 1024


@dataclass
class _TenantSLO:
    reservoir: object
    window_buf: list = field(default_factory=list)
    shed_frac: float = 0.0
    acc: float = 0.0
    consec_sheds: int = 0
    n_obs: int = 0
    n_admitted: int = 0
    n_shed: int = 0
    forced_admits: int = 0
    p99_us: float = 0.0


class SLOAdmissionController:
    """Latency-target admission: shed just enough to hold a p99 target.

    The successor of the fixed ``max_lag_epochs`` bound: instead of
    counting rebuild backlog, it meters each tenant's end-to-end request
    latency in a loadgen-style reservoir and closes an AIMD loop on the
    tail — every ``window`` observations the tenant's p99 is compared
    against ``target_p99_us``; overshoot bumps the tenant's shed
    fraction additively, a clear margin (< 0.8x target) decays it
    multiplicatively.  :meth:`admit` spreads sheds evenly with an
    accumulator (no random number per request) and **never starves**: after
    ``fairness_limit`` consecutive sheds a request is force-admitted and
    counted, which is the fairness invariant the tests assert.
    """

    def __init__(self, config: SLOConfig) -> None:
        self.config = config
        self._lock = threading.Lock()
        self._tenants: dict = {}

    def _state(self, tenant) -> _TenantSLO:
        st = self._tenants.get(tenant)
        if st is None:
            from .loadgen import LatencyReservoir

            st = self._tenants[tenant] = _TenantSLO(
                reservoir=LatencyReservoir(
                    self.config.reservoir_capacity, seed=len(self._tenants)
                )
            )
        return st

    def admit(self, tenant) -> bool:
        """Admission verdict for one request (False = shed it)."""
        with self._lock:
            st = self._state(tenant)
            st.acc += st.shed_frac
            if st.acc >= 1.0:
                if st.consec_sheds >= self.config.fairness_limit:
                    # fairness floor: the accumulator owes a shed, but the
                    # tenant has eaten too many in a row — admit anyway
                    st.acc -= 1.0
                    st.forced_admits += 1
                else:
                    st.acc -= 1.0
                    st.consec_sheds += 1
                    st.n_shed += 1
                    return False
            st.consec_sheds = 0
            st.n_admitted += 1
            return True

    def observe(self, tenant, latency_us: float) -> None:
        """Feed one completed request's latency into the tenant's loop.

        The control signal is the p99 of the *last window* of
        observations, not of the whole history — a reservoir over all
        history never forgets a past stall, so a controller fed by it
        saturates its shed fraction permanently; the windowed tail lets
        the loop back off during an overload burst and re-admit the
        moment the tail clears.  The cumulative reservoir rides along
        for reporting.
        """
        with self._lock:
            st = self._state(tenant)
            st.reservoir.record(float(latency_us))
            st.window_buf.append(float(latency_us))
            st.n_obs += 1
            if len(st.window_buf) < self.config.window:
                return
            st.p99_us = float(np.percentile(np.asarray(st.window_buf), 99))
            st.window_buf.clear()
            if st.p99_us > self.config.target_p99_us:
                st.shed_frac = min(
                    self.config.max_shed_frac, st.shed_frac + self.config.step
                )
            elif st.p99_us < 0.8 * self.config.target_p99_us:
                st.shed_frac = max(0.0, st.shed_frac * self.config.relax)

    def stats(self) -> dict:
        """Per-tenant admission state (shed fraction, counts, last p99)."""
        with self._lock:
            return {
                t: {
                    "shed_frac": st.shed_frac,
                    "n_admitted": st.n_admitted,
                    "n_shed": st.n_shed,
                    "forced_admits": st.forced_admits,
                    "p99_us": st.p99_us,
                }
                for t, st in self._tenants.items()
            }


@dataclass
class _Request:
    tenant: object
    queries: np.ndarray
    event: threading.Event = field(default_factory=threading.Event)
    t_enqueue: float = 0.0
    found: np.ndarray | None = None
    rid: np.ndarray | None = None
    epoch: int | None = None
    error: Exception | None = None


class MultiTenantEngine:
    """Per-tenant request queues coalesced into fused cross-tenant batches.

    :meth:`submit` is the blocking read call: it runs SLO admission,
    enqueues the request, and waits for the dispatcher to fuse it into a
    cross-tenant batch — one ``backend.lookup_many`` per touched arena
    answers every queued tenant's block in a single dispatch, then each
    request completes with its tenant's ``(found, rid, epoch)`` slice.
    Micro-batching is time/size-bounded: a batch flushes when its queued
    query count reaches ``max_batch_queries`` or its oldest request has
    waited ``max_delay_s``.  ``auto_dispatch=False`` disables the
    dispatcher thread — tests drive :meth:`flush` explicitly for
    deterministic fusion.

    A tenant retired between submit and flush completes with
    :class:`AdmissionShed` (its queue drains; same-batch tenants are
    unaffected) — the "tenant leaving mid-batch" contract.
    """

    def __init__(
        self,
        registry: TenantRegistry,
        backend,
        *,
        max_batch_queries: int = 1024,
        max_delay_s: float = 0.002,
        slo: SLOAdmissionController | None = None,
        auto_dispatch: bool = True,
    ) -> None:
        self.registry = registry
        self.backend = backend
        self.max_batch_queries = int(max_batch_queries)
        self.max_delay_s = float(max_delay_s)
        self.slo = slo
        self._cond = threading.Condition()
        self._pending: list[_Request] = []
        self._pending_queries = 0
        self._stop = False
        self.n_batches = 0
        self.n_dispatches = 0  # lookup_many calls (one per touched arena)
        self.n_requests = 0
        self.n_slo_shed = 0
        self.served_per_tenant: dict = {}
        self._thread: threading.Thread | None = None
        if auto_dispatch:
            self._thread = threading.Thread(
                target=self._dispatch_loop, daemon=True
            )
            self._thread.start()

    # ---------------------------------------------------------------- reads
    def submit(self, tenant, queries) -> tuple[np.ndarray, np.ndarray, int]:
        """One tenant's batched lookup through the fused path (blocking).

        Returns ``(found, rid, epoch)`` where ``epoch`` is the snapshot
        epoch the answer was computed against (per-epoch oracles verify
        it).  Raises :class:`AdmissionShed` when SLO admission sheds the
        request or the tenant is retired before its batch flushes.
        """
        if self.slo is not None and not self.slo.admit(tenant):
            with self._cond:
                self.n_slo_shed += 1
            raise AdmissionShed(f"SLO admission shed tenant {tenant!r}")
        req = _Request(
            tenant=tenant,
            queries=np.asarray(queries, np.uint32),
            t_enqueue=time.perf_counter(),
        )
        with self._cond:
            if self._stop:
                raise RuntimeError("engine is shut down")
            self._pending.append(req)
            self._pending_queries += int(req.queries.shape[0])
            self._cond.notify_all()
        # explicit-flush mode blocks here until another thread calls flush()
        return self._wait(req)

    def _wait(self, req: _Request) -> tuple[np.ndarray, np.ndarray, int]:
        req.event.wait()
        if req.error is not None:
            raise req.error
        if self.slo is not None:
            self.slo.observe(
                req.tenant, (time.perf_counter() - req.t_enqueue) * 1e6
            )
        return req.found, req.rid, req.epoch

    # ----------------------------------------------------------- dispatcher
    def _dispatch_loop(self) -> None:
        while True:
            with self._cond:
                while not self._pending and not self._stop:
                    self._cond.wait()
                if self._stop and not self._pending:
                    return
                deadline = self._pending[0].t_enqueue + self.max_delay_s
                while (
                    self._pending_queries < self.max_batch_queries
                    and not self._stop
                ):
                    remaining = deadline - time.perf_counter()
                    if remaining <= 0:
                        break
                    self._cond.wait(timeout=remaining)
                # take a bounded chunk: max_batch_queries caps the fused
                # dispatch shape (one request may overshoot), so a backlog
                # drains in warm-bucket-sized pieces instead of coalescing
                # into an arbitrarily large — and untraced — query block.
                # Leftover stays pending; the next loop iteration sees the
                # aged oldest request and flushes again without delay.
                batch: list[_Request] = []
                taken = 0
                while self._pending and taken < self.max_batch_queries:
                    req = self._pending.pop(0)
                    batch.append(req)
                    taken += int(req.queries.shape[0])
                self._pending_queries -= taken
            if batch:
                self._flush_batch(batch)

    def flush(self) -> int:
        """Fuse and answer everything queued right now (explicit mode).

        Returns the number of requests completed.  The deterministic
        twin of the dispatcher thread: tests enqueue from several
        tenants, then flush once and assert a single fused dispatch.
        """
        with self._cond:
            batch = self._pending
            self._pending = []
            self._pending_queries = 0
        if batch:
            self._flush_batch(batch)
        return len(batch)

    def _flush_batch(self, batch: list[_Request]) -> None:
        view = self.registry.view()  # one atomic read for the whole batch
        by_arena: dict[int, tuple[Arena, list[_Request]]] = {}
        for req in batch:
            arena = view.get(req.tenant)
            if arena is None:
                req.error = AdmissionShed(
                    f"tenant {req.tenant!r} retired before its batch flushed"
                )
                req.event.set()
                continue
            by_arena.setdefault(id(arena), (arena, []))[1].append(req)
        for arena, reqs in by_arena.values():
            try:
                self._flush_arena(arena, reqs)
            except Exception as e:  # surfaced on every waiting request
                for req in reqs:
                    req.error = e
                    req.event.set()
        with self._cond:
            self.n_batches += 1
            self.n_requests += len(batch)

    def _flush_arena(self, arena: Arena, reqs: list[_Request]) -> None:
        """One fused ``lookup_many`` answering every request on ``arena``.

        Requests from the same tenant concatenate into that tenant's
        query block (offsets remembered for the scatter-back); tenants
        of the arena with nothing queued ride along as zero-valid rows,
        so the dispatch shape depends only on the arena capacity and the
        query bucket — warm batches replay one program.
        """
        per_slot: dict[int, list[_Request]] = {}
        for req in reqs:
            per_slot.setdefault(arena.slots[req.tenant], []).append(req)
        t_rows = max(per_slot) + 1
        counts = {
            s: sum(int(r.queries.shape[0]) for r in rs)
            for s, rs in per_slot.items()
        }
        qmax = max(max(counts.values()), 1)
        w = int(arena.stacked.sorted_full.shape[-1])
        qblock = np.full((t_rows, qmax, w), 0xFFFFFFFF, np.uint32)
        n_valid = np.zeros((t_rows,), np.uint32)
        for s, rs in per_slot.items():
            off = 0
            for r in rs:
                k = int(r.queries.shape[0])
                qblock[s, off : off + k] = r.queries
                off += k
            n_valid[s] = off
        found, rid = self.backend.lookup_many(arena.stacked, qblock, n_valid)
        found = np.asarray(found, bool)
        rid = np.asarray(rid, np.uint32)
        with self._cond:
            self.n_dispatches += 1
        for s, rs in per_slot.items():
            off = 0
            for r in rs:
                k = int(r.queries.shape[0])
                r.found = found[s, off : off + k].copy()
                r.rid = rid[s, off : off + k].copy()
                r.epoch = arena.epochs[r.tenant]
                off += k
                with self._cond:
                    self.served_per_tenant[r.tenant] = (
                        self.served_per_tenant.get(r.tenant, 0) + 1
                    )
                r.event.set()

    # ------------------------------------------------------------ lifecycle
    def shutdown(self) -> None:
        """Stop the dispatcher after draining everything already queued."""
        with self._cond:
            self._stop = True
            self._cond.notify_all()
        if self._thread is not None:
            self._thread.join(timeout=30.0)
            self._thread = None

    def stats(self) -> dict:
        """Engine counters: fused batches, dispatches, per-tenant served."""
        with self._cond:
            return {
                "n_batches": self.n_batches,
                "n_dispatches": self.n_dispatches,
                "n_requests": self.n_requests,
                "n_slo_shed": self.n_slo_shed,
                "pending": len(self._pending),
                "served_per_tenant": dict(self.served_per_tenant),
            }
