from . import engine, loadgen, pager  # noqa: F401
