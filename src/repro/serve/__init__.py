from . import engine, pager  # noqa: F401
