from repro.core.snapshot import (  # noqa: F401
    AdmissionShed,
    IndexSnapshot,
    SnapshotCell,
    SnapshotPin,
)

from . import engine, loadgen, pager, tenants  # noqa: F401
from .tenants import (  # noqa: F401
    Arena,
    MultiTenantEngine,
    SLOAdmissionController,
    SLOConfig,
    TenantRegistry,
)

__all__ = [
    "AdmissionShed",
    "Arena",
    "IndexSnapshot",
    "MultiTenantEngine",
    "SLOAdmissionController",
    "SLOConfig",
    "SnapshotCell",
    "SnapshotPin",
    "TenantRegistry",
    "engine",
    "loadgen",
    "pager",
    "tenants",
]
