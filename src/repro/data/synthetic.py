"""Synthetic datasets: the paper's generators + LM token streams.

``zipf_keys`` implements Zipf(s, n, m) of §6.3 *exactly*: 10M (scaled) keys
of n bytes; within each 8-byte word the first m bytes are one arbitrary
fixed ASCII value and the remaining 8-m bytes are lower-case ASCII drawn
from Zipf(s, 26).  Because the generator is fully specified, Table 4's
sort-key ratios are reproducible and validated in the benchmarks.

Table-2 stand-ins: the real INDBTAB/Human/Wikititle/ExURL/WikiURL/Part
datasets are not redistributable; generators here match their published
*shape* statistics (key length distribution, structure) so compression
behaviour is comparable, not identical — stated in EXPERIMENTS.md.
"""

from __future__ import annotations

import numpy as np

from repro.configs.paper_index import IndexDatasetConfig, ZipfConfig
from repro.core.keyformat import KeySet, keys_to_words

__all__ = ["zipf_keys", "dataset_keys", "lm_tokens"]


def _zipf_choice(rng: np.random.Generator, s: float, k: int, size) -> np.ndarray:
    """Draw from Zipf(s) truncated to {0..k-1} (paper's Zipf(s, 26))."""
    ranks = np.arange(1, k + 1, dtype=np.float64)
    p = ranks ** (-s)
    p /= p.sum()
    return rng.choice(k, size=size, p=p)


def zipf_keys(cfg: ZipfConfig, seed: int = 0, unique: bool = True) -> KeySet:
    """Zipf(s, n, m) keys of §6.3, packed."""
    rng = np.random.default_rng(seed)
    n_words8 = cfg.n_bytes // 8
    assert cfg.n_bytes % 8 == 0, "paper generator uses whole 8-byte words"
    fixed = ord("a")  # "an arbitrary fixed character"
    buf = np.empty((cfg.n_keys, cfg.n_bytes), dtype=np.uint8)
    for w in range(n_words8):
        lo = w * 8
        buf[:, lo : lo + cfg.m] = fixed
        z = _zipf_choice(rng, cfg.s, 26, (cfg.n_keys, 8 - cfg.m))
        buf[:, lo + cfg.m : lo + 8] = ord("a") + z
    if unique:
        # append a 4-byte sequence tail word-aligned? No — the paper keys may
        # collide; dedupe instead (sorting/compression assume distinct keys).
        buf = np.unique(buf, axis=0)
    keys = [bytes(r) for r in buf]
    return keys_to_words(keys)


def _url_like(rng, n, avg_len, max_len):
    """Hierarchical URLs: deep shared prefixes, distinction bits near the
    tail (matches the real ExURL/WikiURL dbit spread, paper Table 2)."""
    n_dom = max(n // 400, 8)
    doms = [f"www.site{int(i):04d}.org" for i in range(n_dom)]
    segs = ["wiki", "pages", "article", "item", "data", "ref", "cat", "id"]
    out = set()
    while len(out) < n:
        d = doms[int(rng.integers(0, n_dom))]
        depth = int(rng.integers(1, 4))
        path = "/".join(
            f"{segs[int(rng.integers(0, len(segs)))]}{int(rng.integers(0, 50))}"
            for _ in range(depth)
        )
        leaf = "".join(chr(97 + c) for c in rng.integers(0, 26, rng.integers(3, 9)))
        out.add(f"http://{d}/{path}/{leaf}{int(rng.integers(0, 10**4))}"
                .encode()[:max_len])
    return list(out)


def _genome_reads(rng, n, read_len):
    """EST-like reads: deep-coverage loci with point errors, so adjacent
    sorted reads share long prefixes and distinction bits spread across the
    whole read (the Human dataset's broad dbit profile, paper Table 2)."""
    genome = rng.integers(0, 4, size=max(n * 2, 100_000))
    acgt = np.frombuffer(b"ACGT", np.uint8)
    loci = rng.integers(0, len(genome) - read_len, size=max(n // 12, 4))
    out = set()
    while len(out) < n:
        off = int(loci[int(rng.integers(0, len(loci)))])
        read = genome[off : off + read_len].copy()
        # ~3 sequencing errors per read, uniform over positions
        for _ in range(int(rng.poisson(3))):
            read[int(rng.integers(0, read_len))] = int(rng.integers(0, 4))
        out.add(bytes(acgt[read]))
    return list(out)


def _title_like(rng, n, max_len):
    words = ["".join(chr(97 + c) for c in rng.integers(0, 26, rng.integers(3, 9)))
             for _ in range(2000)]
    out = []
    for _ in range(n):
        k = int(rng.integers(1, 4))
        t = "_".join(words[int(i)] for i in rng.integers(0, len(words), k))
        out.append(t.title().encode()[:max_len])
    return out


def _fixed_record(rng, n, width):
    """INDBTAB/Part-like: fixed-width multi-column business keys — a few
    low-cardinality columns + a sequence column (most bits invariant)."""
    out = np.zeros((n, width), dtype=np.uint8)
    out[:, :] = ord("0")
    doc = rng.integers(0, 10000, n)
    item = rng.integers(0, 100, n)
    seq = np.arange(n)
    for i in range(n):
        s = f"{2024:04d}{int(doc[i]):08d}{int(item[i]):04d}{int(seq[i]):010d}"
        b = s.encode()[:width]
        out[i, : len(b)] = np.frombuffer(b, np.uint8)
    return [bytes(r) for r in out]


def dataset_keys(cfg: IndexDatasetConfig, seed: int = 0) -> KeySet:
    rng = np.random.default_rng(seed)
    if cfg.kind == "fixed":
        keys = _fixed_record(rng, cfg.n_keys, cfg.key_bytes)
    elif cfg.kind == "url":
        keys = _url_like(rng, cfg.n_keys, cfg.key_bytes, cfg.key_bytes * 2)
    elif cfg.kind == "title":
        keys = _title_like(rng, cfg.n_keys, cfg.key_bytes * 3)
    elif cfg.kind == "genome":
        keys = _genome_reads(rng, cfg.n_keys, cfg.key_bytes)
    elif cfg.kind == "zipf":
        n8 = ((cfg.key_bytes + 7) // 8) * 8
        return zipf_keys(
            ZipfConfig(cfg.zipf_s, n8, cfg.zipf_m, cfg.n_keys), seed=seed
        )
    else:
        raise ValueError(cfg.kind)
    keys = sorted(set(keys))
    rng.shuffle(keys)
    return keys_to_words(keys)


def lm_tokens(n_docs: int, doc_len: int, vocab: int, seed: int = 0) -> np.ndarray:
    """Zipf-distributed synthetic token stream, (n_docs, doc_len) int32."""
    rng = np.random.default_rng(seed)
    return _zipf_choice(rng, 1.1, vocab, (n_docs, doc_len)).astype(np.int32)
