"""Deterministic sharded data pipeline.

Epoch shuffling and dedup both run through the compressed key sort
(DESIGN.md §4.4):

  * shuffle: sort documents by ``(fnv1a(seed || doc_id) || doc_id)`` — a
    keyed permutation that any worker can reproduce locally, so a restarted
    or straggling worker re-derives exactly its shard without coordination
    (straggler/restart safety comes from determinism, not state);
  * dedup: equal compressed keys => equal keys when the D-bitmap covers the
    dataset (Theorem 2 corollary) — adjacent-equality scan post-sort.

Batches are yielded as (step, batch) with a monotone step id; resuming from
checkpoint step N skips exactly N batches by arithmetic, not by replay.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.core.compress import make_plan
from repro.core.dbits import compute_dbitmap
from repro.core.sortkeys import compressed_key_sort

__all__ = ["shuffle_order", "dedup_tokens", "TokenPipeline"]


def _fnv1a_vec(x: np.ndarray, seed: int) -> np.ndarray:
    h = np.full(x.shape, (0xCBF29CE484222325 ^ seed) & 0xFFFFFFFF, np.uint64)
    v = x.astype(np.uint64)
    for shift in (0, 8, 16, 24):
        h = (h ^ ((v >> shift) & 0xFF)) * np.uint64(0x01000193)
        h &= np.uint64(0xFFFFFFFF)
    return h.astype(np.uint32)


def shuffle_order(n_docs: int, seed: int) -> np.ndarray:
    """Keyed shuffle permutation via compressed key sort."""
    import jax.numpy as jnp

    doc = np.arange(n_docs, dtype=np.uint32)
    words = np.stack([_fnv1a_vec(doc, seed), doc], axis=1)  # (n, 2) uint32
    bm = compute_dbitmap(jnp.asarray(words))
    plan = make_plan(np.asarray(bm), 2)
    res = compressed_key_sort(jnp.asarray(words), jnp.asarray(doc), plan)
    return np.asarray(res.rids)


def dedup_tokens(docs: np.ndarray) -> np.ndarray:
    """Drop exact-duplicate rows of (n, L) int32 token docs via sorted
    compressed keys (adjacent-equal scan)."""
    import jax.numpy as jnp

    words = np.ascontiguousarray(docs.astype(np.uint32))
    bm = compute_dbitmap(jnp.asarray(words))
    plan = make_plan(np.asarray(bm), words.shape[1])
    res = compressed_key_sort(
        jnp.asarray(words), jnp.arange(len(words), dtype=jnp.uint32), plan
    )
    keys = np.asarray(res.keys)
    rids = np.asarray(res.rids)
    keep = np.ones(len(keys), bool)
    keep[1:] = (keys[1:] != keys[:-1]).any(axis=1)
    return np.sort(rids[keep])


@dataclass
class TokenPipeline:
    """Sharded, resumable LM batch source over a document array."""

    docs: np.ndarray  # (n_docs, doc_len) int32
    global_batch: int
    seq_len: int
    seed: int = 0

    def __post_init__(self):
        assert self.docs.shape[1] >= self.seq_len + 1
        self.n_docs = self.docs.shape[0]
        self.per_epoch = self.n_docs // self.global_batch
        self._order_cache: dict[int, np.ndarray] = {}

    def _epoch_order(self, epoch: int) -> np.ndarray:
        if epoch not in self._order_cache:
            self._order_cache[epoch] = shuffle_order(self.n_docs, self.seed + epoch)
        return self._order_cache[epoch]

    def batch_at(self, step: int) -> dict:
        """Deterministic random access — the resume/straggler-safety hook."""
        epoch, off = divmod(step, self.per_epoch)
        order = self._epoch_order(epoch)
        rows = order[off * self.global_batch : (off + 1) * self.global_batch]
        toks = self.docs[rows]
        return {
            "tokens": toks[:, : self.seq_len].astype(np.int32),
            "labels": toks[:, 1 : self.seq_len + 1].astype(np.int32),
        }

    def __iter__(self):
        step = 0
        while True:
            yield step, self.batch_at(step)
            step += 1
