from . import pipeline, synthetic  # noqa: F401
