"""Mesh context: lets model code state sharding intent without importing
mesh machinery everywhere.

``use_mesh(mesh, data_axes, model_axis)`` installs the mesh; ``constrain``
then applies ``with_sharding_constraint`` with logical axis names resolved
to the installed mesh ("data" -> the (possibly composite) batch axes,
"model" -> the tensor-parallel axis).  Outside a mesh context every helper
is a no-op, so the same model code runs single-device (smoke tests) and on
the 512-chip dry-run mesh unchanged.
"""

from __future__ import annotations

import contextlib
import threading

import jax
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.compat import set_mesh

_state = threading.local()


def current() -> tuple[Mesh, tuple, str] | None:
    return getattr(_state, "ctx", None)


@contextlib.contextmanager
def use_mesh(mesh: Mesh, data_axes=("data",), model_axis: str = "model"):
    prev = getattr(_state, "ctx", None)
    _state.ctx = (mesh, tuple(data_axes), model_axis)
    try:
        with set_mesh(mesh):
            yield mesh
    finally:
        _state.ctx = prev


def _resolve(axis):
    ctx = current()
    if ctx is None:
        return None
    _, data_axes, model_axis = ctx
    if axis == "data":
        return data_axes if len(data_axes) > 1 else data_axes[0]
    if axis == "model":
        return model_axis
    return axis  # literal mesh axis name or None


def spec(*logical_axes) -> P:
    return P(*[_resolve(a) for a in logical_axes])


def constrain(x, *logical_axes):
    """with_sharding_constraint using logical axis names; no-op without mesh."""
    ctx = current()
    if ctx is None:
        return x
    mesh, _, _ = ctx
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec(*logical_axes)))


def named_sharding(*logical_axes) -> NamedSharding | None:
    ctx = current()
    if ctx is None:
        return None
    mesh, _, _ = ctx
    return NamedSharding(mesh, spec(*logical_axes))


def axis_size(logical: str) -> int:
    """Mesh extent of a logical axis (1 outside a mesh context)."""
    ctx = current()
    if ctx is None:
        return 1
    mesh, _, _ = ctx
    resolved = _resolve(logical)
    if resolved is None:
        return 1
    if isinstance(resolved, (tuple, list)):
        n = 1
        for a in resolved:
            n *= mesh.shape[a]
        return n
    return mesh.shape[resolved]
