"""Sharding-rule engine: param path -> PartitionSpec.

Policy (DESIGN.md §6):
  * tensor-parallel dims (attention heads, FFN hidden, vocab, experts,
    SSM inner dim) -> "model" axis;
  * one remaining large dim -> "data" axis (FSDP / ZeRO-style; the
    optimizer state inherits the same specs, giving ZeRO-1 for free);
  * the "pod" axis (multi-pod mesh) carries ONLY the batch — parameter
    all-gathers stay on intra-pod ICI, and just the gradient all-reduce
    crosses pods (the slow axis);
  * stacked-layer leading dims (from the scan-over-layers transform) are
    never sharded.

Rules are keyed on parameter *leaf names* — the model zoo uses a fixed
naming convention (wq/wk/wv/wo, w1/w2/w3, embed, lm_head, router, A_log,
in_proj/out_proj, ...), so the engine needs no per-arch tables.
"""

from __future__ import annotations

import jax
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

__all__ = ["param_spec", "param_specs", "param_shardings", "batch_spec"]

# leaf name -> spec for the *unstacked* param; None entries = replicated dim.
# Convention: weights are (in_dim, out_dim); "model" goes on the TP dim,
# "data" on the other large dim (FSDP).
_RULES: list[tuple[tuple[str, ...], tuple]] = [
    # embedding: FEATURE-sharded (gather over a vocab-sharded table forces
    # SPMD full rematerialization; feature sharding keeps the gather local).
    (("embed",), (None, "model")),
    (("lm_head",), ("data", "model")),  # (d, V): vocab-sharded -> chunked loss

    # attention projections
    (("wq", "wk", "wv"), ("data", "model")),  # (d, heads*hd)
    (("wo",), ("model", "data")),  # (heads*hd, d)
    # dense FFN
    (("w1", "w3"), ("data", "model")),  # (d, ff)
    (("w2",), ("model", "data")),  # (ff, d)
    # MoE: expert dim on model (EP), then FSDP on d
    (("moe_w1", "moe_w3"), ("model", "data", None)),  # (E, d, ff)
    (("moe_w2",), ("model", "data", None)),  # (E, ff, d)
    (("router",), (None, "model")),  # (d, E)
    # Mamba
    (("in_proj",), ("data", "model")),  # (d, 2*di)
    (("out_proj",), ("model", "data")),  # (di, d)
    (("x_proj",), ("model", None)),  # (di, dt_rank + 2N)
    (("dt_proj",), (None, "model")),  # (dt_rank, di)
    (("conv_w",), ("model", None)),  # (di, k)
    (("A_log",), ("model", None)),  # (di, N)
    (("D", "dt_bias", "conv_b"), ("model",)),  # (di,)
    # xLSTM
    (("w_up",), ("data", "model")),  # (d, 2*di)
    (("w_down",), ("model", "data")),  # (di, d)
    (("wq_l", "wk_l", "wv_l"), ("model", None)),  # (di, di) inner
    (("wi", "wf", "wog"), ("model", None)),  # (di, H)
    (("r_i", "r_f", "r_z", "r_o"), (None, "model", None)),  # (H, dh, dh)
    (("sw_i", "sw_f", "sw_z", "sw_o"), ("data", "model")),  # (d, d)
    # norms, gates, biases: replicated
    (("ln", "q_norm", "k_norm", "final_norm", "gate", "bias", "b_i", "b_f"), None),
]


def _rule_for(name: str):
    for names, spec in _RULES:
        if name in names:
            return spec
    return None  # default: replicate


def param_spec(path: tuple, leaf: jax.ShapeDtypeStruct | None = None) -> P:
    """Spec for one param addressed by its key path (pytree path tuple)."""
    name = None
    stacked = False
    for k in path:
        ks = k.key if hasattr(k, "key") else str(k)
        if ks == "blocks":
            stacked = True  # scan-stacked: leading layer dim, never sharded
        name = ks
    rule = _rule_for(name)
    if rule is None:
        return P()
    dims = list(rule)
    if stacked:
        dims = [None] + dims
    if leaf is not None:
        # guard: never shard a dim the rule names if the leaf is lower-rank
        dims = dims[: len(leaf.shape)] if len(dims) > len(leaf.shape) else dims
        while len(dims) < len(leaf.shape):
            dims.append(None)
    return P(*dims)


def param_specs(params_tree) -> dict:
    """Pytree of PartitionSpec matching a params (or ShapeDtypeStruct) tree."""
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: param_spec(path, leaf), params_tree
    )


def param_shardings(params_tree, mesh: Mesh) -> dict:
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), param_specs(params_tree),
        is_leaf=lambda x: isinstance(x, P),
    )


def batch_spec(mesh: Mesh) -> P:
    """Batch dim over every data-parallel axis present ('pod' included)."""
    axes = [a for a in ("pod", "data") if a in mesh.axis_names]
    return P(tuple(axes)) if len(axes) > 1 else P(axes[0])
