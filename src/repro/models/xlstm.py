"""xLSTM blocks (arXiv:2405.04517): mLSTM (matrix memory, parallelizable)
and sLSTM (scalar memory, strictly sequential recurrence).

Both use stabilized exponential gating (the m-state max-trick).  mLSTM here
runs as a time scan carrying (C, n, m) — correct for train/prefill/decode
alike; the chunkwise-parallel production form is a §Perf candidate.  sLSTM
has data-dependent recurrence (h feeds the gates) and cannot be
parallelized over time (the paper says as much), so a scan is the honest
implementation; its block-diagonal recurrent weights keep the per-step cost
at (H, dh, dh).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .layers import silu


def mlstm_mix(p: dict, x: jnp.ndarray, state: dict | None = None,
              n_heads: int = 4) -> tuple[jnp.ndarray, dict]:
    """mLSTM block.  x: (B, T, d).

    p: w_up (d, 2di), wq_l/wk_l/wv_l (di, di), wi/wf (di, H), w_down (di, d).
    state: {C: (B,H,dh,dh), n: (B,H,dh), m: (B,H)}.
    """
    B, T, d = x.shape
    di = p["wq_l"].shape[0]
    H = n_heads
    dh = di // H

    xz = jnp.einsum("btd,de->bte", x, p["w_up"])
    xi, z = jnp.split(xz, 2, axis=-1)  # (B, T, di)

    def heads(w):
        return jnp.einsum("bte,ef->btf", xi, w).reshape(B, T, H, dh)

    q, k, v = heads(p["wq_l"]), heads(p["wk_l"]), heads(p["wv_l"])
    k = k / jnp.sqrt(jnp.float32(dh)).astype(k.dtype)
    ig = jnp.einsum("bte,eh->bth", xi, p["wi"]).astype(jnp.float32)  # log-space
    fg = jnp.einsum("bte,eh->bth", xi, p["wf"]).astype(jnp.float32)
    fg = jax.nn.log_sigmoid(fg)

    if state is None:
        C0 = jnp.zeros((B, H, dh, dh), jnp.float32)
        n0 = jnp.zeros((B, H, dh), jnp.float32)
        m0 = jnp.full((B, H), -jnp.inf, jnp.float32)
    else:
        C0, n0, m0 = state["C"], state["n"], state["m"]

    def step(carry, t):
        C, n, m = carry
        qt = jax.lax.dynamic_slice_in_dim(q, t, 1, 1)[:, 0].astype(jnp.float32)
        kt = jax.lax.dynamic_slice_in_dim(k, t, 1, 1)[:, 0].astype(jnp.float32)
        vt = jax.lax.dynamic_slice_in_dim(v, t, 1, 1)[:, 0].astype(jnp.float32)
        it = jax.lax.dynamic_slice_in_dim(ig, t, 1, 1)[:, 0]
        ft = jax.lax.dynamic_slice_in_dim(fg, t, 1, 1)[:, 0]
        m_new = jnp.maximum(ft + m, it)
        fs = jnp.exp(ft + jnp.where(jnp.isfinite(m), m, -jnp.inf) - m_new)
        is_ = jnp.exp(it - m_new)
        C = fs[..., None, None] * C + is_[..., None, None] * (
            kt[..., :, None] * vt[..., None, :]
        )
        n = fs[..., None] * n + is_[..., None] * kt
        num = jnp.einsum("bhde,bhd->bhe", C, qt)
        den = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", n, qt)), 1.0)
        h = num / den[..., None]
        return (C, n, m_new), h

    (C, n, m), hs = jax.lax.scan(step, (C0, n0, m0), jnp.arange(T))
    h = jnp.moveaxis(hs, 0, 1).reshape(B, T, di).astype(x.dtype)  # (B,T,H,dh)->
    out = jnp.einsum("bte,ed->btd", h * silu(z), p["w_down"])
    return out, {"C": C, "n": n, "m": m}


def slstm_mix(p: dict, x: jnp.ndarray, state: dict | None = None,
              n_heads: int = 4) -> tuple[jnp.ndarray, dict]:
    """sLSTM block.  x: (B, T, d) with d == hidden width (post-LN residual).

    p: sw_i/sw_f/sw_z/sw_o (d, d), r_i/r_f/r_z/r_o (H, dh, dh),
       b_i/b_f (d,).  state: {h, c, n, m} each (B, H, dh).
    """
    B, T, d = x.shape
    H = n_heads
    dh = d // H

    wx_i = jnp.einsum("btd,de->bte", x, p["sw_i"]).astype(jnp.float32) + p["b_i"]
    wx_f = jnp.einsum("btd,de->bte", x, p["sw_f"]).astype(jnp.float32) + p["b_f"]
    wx_z = jnp.einsum("btd,de->bte", x, p["sw_z"]).astype(jnp.float32)
    wx_o = jnp.einsum("btd,de->bte", x, p["sw_o"]).astype(jnp.float32)

    if state is None:
        h0 = jnp.zeros((B, H, dh), jnp.float32)
        c0 = jnp.zeros((B, H, dh), jnp.float32)
        n0 = jnp.ones((B, H, dh), jnp.float32)
        m0 = jnp.zeros((B, H, dh), jnp.float32)
    else:
        h0, c0, n0, m0 = state["h"], state["c"], state["n"], state["m"]

    def rec(h, r):  # block-diagonal recurrent matmul
        return jnp.einsum("bhd,hde->bhe", h, r)

    def step(carry, t):
        h, c, n, m = carry
        g = lambda wx: jax.lax.dynamic_slice_in_dim(wx, t, 1, 1)[:, 0].reshape(B, H, dh)
        it = g(wx_i) + rec(h, p["r_i"])
        ft = g(wx_f) + rec(h, p["r_f"])
        zt = jnp.tanh(g(wx_z) + rec(h, p["r_z"]))
        ot = jax.nn.sigmoid(g(wx_o) + rec(h, p["r_o"]))
        lf = jax.nn.log_sigmoid(ft)  # forget in log space (sigmoid variant)
        m_new = jnp.maximum(lf + m, it)
        i_ = jnp.exp(it - m_new)
        f_ = jnp.exp(lf + m - m_new)
        c = f_ * c + i_ * zt
        n = f_ * n + i_
        h = ot * c / jnp.maximum(n, 1e-6)
        return (h, c, n, m_new), h

    (h, c, n, m), hs = jax.lax.scan(step, (h0, c0, n0, m0), jnp.arange(T))
    out = jnp.moveaxis(hs, 0, 1).reshape(B, T, d).astype(x.dtype)
    return out, {"h": h, "c": c, "n": n, "m": m}
