"""The LM model zoo: one builder covering all ten assigned architectures.

A model is a stack of *superblocks* — the config's ``pattern`` of
(mixer, ffn) sublayers — scanned with stacked parameters, so compile time
is O(|pattern|) regardless of depth (94-layer qwen3 compiles one
superblock).  Three modes share the same forward code:

  train    — causal forward over (B, S), chunked-vocab loss, no cache;
  prefill  — causal forward that also fills the KV/state caches;
  decode   — single-token step against the caches (B, 1).

Caches are stacked pytrees (leading superblock dim) consumed/produced as
scan xs/ys.  All parameter leaf names follow the sharding-rule convention
in ``repro.distributed.sharding``.
"""

from __future__ import annotations

import functools
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig, ShapeConfig
from repro.distributed.ctx import constrain

from .layers import (
    apply_rope,
    chunked_softmax_xent,
    decode_attention,
    flash_attention,
    rms_norm,
    silu,
)
from .moe import moe_ffn
from .ssm import mamba_mix
from .xlstm import mlstm_mix, slstm_mix

# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def _lin(key, fan_in, shape, dtype=jnp.float32):
    return (jax.random.normal(key, shape, dtype) * (fan_in ** -0.5)).astype(dtype)


def _init_sublayer(cfg: ArchConfig, mixer: str, ffn: str, key) -> dict:
    d, hd, H, G = cfg.d_model, cfg.hd, cfg.n_heads, cfg.n_kv_heads
    ks = iter(jax.random.split(key, 32))
    p: dict = {"ln": jnp.ones((d,), jnp.float32)}
    if mixer in ("attn", "xattn"):
        p.update(
            wq=_lin(next(ks), d, (d, H * hd)),
            wk=_lin(next(ks), d, (d, G * hd)),
            wv=_lin(next(ks), d, (d, G * hd)),
            wo=_lin(next(ks), H * hd, (H * hd, d)),
        )
        if cfg.qk_norm:
            p.update(q_norm=jnp.ones((hd,)), k_norm=jnp.ones((hd,)))
        if mixer == "xattn":
            p.update(gate=jnp.zeros(()), ln_kv=jnp.ones((d,)))
    elif mixer == "mamba":
        di, N, r_ = cfg.ssm_expand * d, cfg.ssm_state, cfg.dt_rank
        p.update(
            in_proj=_lin(next(ks), d, (d, 2 * di)),
            conv_w=_lin(next(ks), cfg.ssm_conv, (di, cfg.ssm_conv)),
            conv_b=jnp.zeros((di,)),
            x_proj=_lin(next(ks), di, (di, r_ + 2 * N)),
            dt_proj=_lin(next(ks), r_, (r_, di)),
            dt_bias=jnp.log(
                jnp.exp(
                    jax.random.uniform(next(ks), (di,), minval=1e-3, maxval=0.1)
                ) - 1.0
            ),
            A_log=jnp.log(
                jnp.tile(jnp.arange(1, N + 1, dtype=jnp.float32)[None, :], (di, 1))
            ),
            D=jnp.ones((di,)),
            out_proj=_lin(next(ks), di, (di, d)),
        )
    elif mixer == "mlstm":
        di = cfg.xlstm_expand * d
        p.update(
            w_up=_lin(next(ks), d, (d, 2 * di)),
            wq_l=_lin(next(ks), di, (di, di)),
            wk_l=_lin(next(ks), di, (di, di)),
            wv_l=_lin(next(ks), di, (di, di)),
            wi=_lin(next(ks), di, (di, cfg.xlstm_heads)),
            wf=_lin(next(ks), di, (di, cfg.xlstm_heads)),
            w_down=_lin(next(ks), di, (di, d)),
        )
    elif mixer == "slstm":
        Hx = cfg.xlstm_heads
        dh = d // Hx
        p.update(
            sw_i=_lin(next(ks), d, (d, d)),
            sw_f=_lin(next(ks), d, (d, d)),
            sw_z=_lin(next(ks), d, (d, d)),
            sw_o=_lin(next(ks), d, (d, d)),
            r_i=_lin(next(ks), dh, (Hx, dh, dh)),
            r_f=_lin(next(ks), dh, (Hx, dh, dh)),
            r_z=_lin(next(ks), dh, (Hx, dh, dh)),
            r_o=_lin(next(ks), dh, (Hx, dh, dh)),
            b_i=jnp.zeros((d,)),
            b_f=jnp.ones((d,)),  # forget-gate bias init > 0
        )
    else:
        raise ValueError(mixer)

    if ffn == "dense":
        p.update(
            ln2=jnp.ones((d,)),
            w1=_lin(next(ks), d, (d, cfg.d_ff)),
            w3=_lin(next(ks), d, (d, cfg.d_ff)),
            w2=_lin(next(ks), cfg.d_ff, (cfg.d_ff, d)),
        )
    elif ffn == "moe":
        E, f = cfg.n_experts, cfg.moe_d_ff
        p.update(
            ln2=jnp.ones((d,)),
            router=_lin(next(ks), d, (d, E)),
            moe_w1=_lin(next(ks), d, (E, d, f)),
            moe_w3=_lin(next(ks), d, (E, d, f)),
            moe_w2=_lin(next(ks), f, (E, f, d)),
        )
        if cfg.shared_expert:
            p.update(
                w1=_lin(next(ks), d, (d, cfg.d_ff)),
                w3=_lin(next(ks), d, (d, cfg.d_ff)),
                w2=_lin(next(ks), cfg.d_ff, (cfg.d_ff, d)),
            )
    elif ffn != "none":
        raise ValueError(ffn)
    return p


# ---------------------------------------------------------------------------
# the model
# ---------------------------------------------------------------------------


@dataclass
class LM:
    cfg: ArchConfig
    compute_dtype: jnp.dtype = jnp.bfloat16
    remat: bool = True

    # ----------------------------------------------------------------- init
    def init(self, key) -> dict:
        cfg = self.cfg
        k_embed, k_blocks, k_head = jax.random.split(key, 3)
        params: dict = {}
        params["embed"] = _lin(k_embed, cfg.d_model, (cfg.vocab_size, cfg.d_model))
        sb_keys = jax.random.split(k_blocks, cfg.n_superblocks)

        def one_sb(k):
            kk = jax.random.split(k, len(cfg.pattern))
            return {
                str(i): _init_sublayer(cfg, mixer, ffn, kk[i])
                for i, (mixer, ffn) in enumerate(cfg.pattern)
            }

        params["blocks"] = jax.vmap(one_sb)(sb_keys)
        params["final_norm"] = jnp.ones((cfg.d_model,), jnp.float32)
        if not cfg.tie_embeddings:
            params["lm_head"] = _lin(k_head, cfg.d_model, (cfg.d_model, cfg.vocab_size))
        return params

    def param_struct(self) -> dict:
        return jax.eval_shape(self.init, jax.random.PRNGKey(0))

    # ---------------------------------------------------------------- pieces
    def _lm_head(self, params):
        if self.cfg.tie_embeddings:
            return params["embed"].T
        return params["lm_head"]

    def _embed(self, params, batch) -> jnp.ndarray:
        if self.cfg.embed_input:
            # cast-then-gather: the bf16 table halves gather traffic and the
            # cast fuses; the table is feature-sharded so the gather is local
            return params["embed"].astype(self.compute_dtype)[batch["tokens"]]
        return batch["frames"].astype(self.compute_dtype)  # audio stub frontend

    def _attn(self, p, h, mode, pos, kv_cache):
        cfg = self.cfg
        B, T, d = h.shape
        H, G, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
        x = rms_norm(h, p["ln"], cfg.norm_eps)
        q = (x @ p["wq"]).reshape(B, T, H, hd).transpose(0, 2, 1, 3)
        k = (x @ p["wk"]).reshape(B, T, G, hd).transpose(0, 2, 1, 3)
        v = (x @ p["wv"]).reshape(B, T, G, hd).transpose(0, 2, 1, 3)
        if cfg.qk_norm:
            q = rms_norm(q, p["q_norm"], cfg.norm_eps)
            k = rms_norm(k, p["k_norm"], cfg.norm_eps)
        if cfg.rope_theta > 0:
            positions = pos + jnp.arange(T)
            q = apply_rope(q, positions, cfg.rope_theta)
            k = apply_rope(k, positions, cfg.rope_theta)
        # §Perf: materialize KV at full head count for the flash compute so
        # the head dim shards evenly over the model axis (the cache itself
        # stays G-wide; see EXPERIMENTS.md §Perf)
        rep = (
            (lambda x: jnp.repeat(x, H // G, axis=1))
            if (cfg.attn_repeat_kv and G < H)
            else (lambda x: x)
        )
        new_cache = None
        if mode == "train":
            o = flash_attention(
                q, rep(k), rep(v), causal=True,
                q_chunk=cfg.q_chunk, kv_chunk=cfg.kv_chunk,
            )
        elif mode == "prefill":
            ck = jax.lax.dynamic_update_slice_in_dim(kv_cache["k"], k, 0, axis=2)
            cv = jax.lax.dynamic_update_slice_in_dim(kv_cache["v"], v, 0, axis=2)
            new_cache = {"k": ck, "v": cv}
            o = flash_attention(
                q, rep(k), rep(v), causal=True,
                q_chunk=cfg.q_chunk, kv_chunk=cfg.kv_chunk,
            )
        else:  # decode
            ck = jax.lax.dynamic_update_slice(
                kv_cache["k"], k, (0, 0, pos, 0)
            )
            cv = jax.lax.dynamic_update_slice(
                kv_cache["v"], v, (0, 0, pos, 0)
            )
            new_cache = {"k": ck, "v": cv}
            o = decode_attention(q, ck, cv, pos + 1, kv_chunk=cfg.kv_chunk)
        o = o.transpose(0, 2, 1, 3).reshape(B, T, H * hd)
        return h + (o @ p["wo"]).astype(h.dtype), new_cache

    def _xattn(self, p, h, mode, img_embeds, cache):
        cfg = self.cfg
        B, T, d = h.shape
        H, G, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
        x = rms_norm(h, p["ln"], cfg.norm_eps)
        q = (x @ p["wq"]).reshape(B, T, H, hd).transpose(0, 2, 1, 3)
        if mode == "decode" and cache is not None:
            k, v = cache["k_img"], cache["v_img"]
            new_cache = cache
        else:
            y = rms_norm(img_embeds.astype(h.dtype), p["ln_kv"], cfg.norm_eps)
            n_img = y.shape[1]
            k = (y @ p["wk"]).reshape(B, n_img, G, hd).transpose(0, 2, 1, 3)
            v = (y @ p["wv"]).reshape(B, n_img, G, hd).transpose(0, 2, 1, 3)
            new_cache = {"k_img": k, "v_img": v} if mode != "train" else None
        o = flash_attention(
            q, k, v, causal=False, q_chunk=cfg.q_chunk, kv_chunk=cfg.kv_chunk
        )
        o = o.transpose(0, 2, 1, 3).reshape(B, T, H * hd)
        return h + jnp.tanh(p["gate"]).astype(h.dtype) * (o @ p["wo"]).astype(h.dtype), new_cache

    def _dense_ffn(self, p, h):
        x = rms_norm(h, p["ln2"], self.cfg.norm_eps)
        y = (silu(x @ p["w1"]) * (x @ p["w3"])) @ p["w2"]
        return h + y.astype(h.dtype)

    def _moe_ffn(self, p, h):
        cfg = self.cfg
        x = rms_norm(h, p["ln2"], cfg.norm_eps)
        y, aux = moe_ffn(
            p,
            x,
            n_experts=cfg.n_experts,
            top_k=cfg.top_k,
            capacity_factor=cfg.capacity_factor,
            dispatch_mode=cfg.dispatch_mode,
            shared_expert=cfg.shared_expert,
        )
        return h + y.astype(h.dtype), aux

    # --------------------------------------------------------------- forward
    def _forward(self, params, h, *, mode, pos, cache, img_embeds):
        cfg = self.cfg
        cast = lambda t: jax.tree_util.tree_map(
            lambda x: x.astype(self.compute_dtype)
            if x.dtype == jnp.float32 and x.ndim >= 2
            else x,
            t,
        )

        def superblock(h, xs):
            p_sb, cache_sb = xs
            p_sb = cast(p_sb)
            new_cache = {}
            aux = {"lb_loss": jnp.float32(0), "z_loss": jnp.float32(0),
                   "dropped_frac": jnp.float32(0)}
            for i, (mixer, ffn) in enumerate(cfg.pattern):
                pm = p_sb[str(i)]
                csl = cache_sb.get(str(i)) if cache_sb else None
                if mixer == "attn":
                    h, nc = self._attn(pm, h, mode, pos, csl)
                elif mixer == "xattn":
                    h, nc = self._xattn(pm, h, mode, img_embeds, csl)
                elif mixer == "mamba":
                    x = rms_norm(h, pm["ln"], cfg.norm_eps)
                    y, st = mamba_mix(pm, x, csl if mode == "decode" else None,
                                      chunk=cfg.ssm_chunk)
                    h = h + y.astype(h.dtype)
                    nc = st if mode != "train" else None
                elif mixer == "mlstm":
                    x = rms_norm(h, pm["ln"], cfg.norm_eps)
                    y, st = mlstm_mix(pm, x, csl if mode == "decode" else None,
                                      n_heads=cfg.xlstm_heads)
                    h = h + y.astype(h.dtype)
                    nc = st if mode != "train" else None
                elif mixer == "slstm":
                    x = rms_norm(h, pm["ln"], cfg.norm_eps)
                    y, st = slstm_mix(pm, x, csl if mode == "decode" else None,
                                      n_heads=cfg.xlstm_heads)
                    h = h + y.astype(h.dtype)
                    nc = st if mode != "train" else None
                if nc is not None:
                    new_cache[str(i)] = nc
                if ffn == "dense":
                    h = self._dense_ffn(pm, h)
                elif ffn == "moe":
                    h, a = self._moe_ffn(pm, h)
                    aux = {k: aux[k] + a[k] for k in aux}
            # NOTE: no blanket constraint on h here — batch sharding
            # propagates from the inputs, and pinning (B,T,d) replicated-d
            # trips an XLA SPMD dynamic-slice bug against the
            # feature-sharded embedding gather (see EXPERIMENTS.md §Perf i1)
            return h, (new_cache, aux)

        fn = superblock
        if self.remat and mode == "train":
            fn = jax.checkpoint(
                superblock,
                policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable,
            )
        if cache is None:
            cache = {}
        h, (new_caches, auxs) = jax.lax.scan(fn, h, (params["blocks"], cache))
        aux = jax.tree_util.tree_map(lambda a: jnp.sum(a) / cfg.n_superblocks, auxs)
        return h, new_caches, aux

    # ------------------------------------------------------------------ API
    def loss(self, params, batch) -> tuple[jnp.ndarray, dict]:
        cfg = self.cfg
        h = self._embed(params, batch)
        img = batch.get("img_embeds")
        h, _, aux = self._forward(
            params, h, mode="train", pos=jnp.int32(0), cache=None, img_embeds=img
        )
        h = rms_norm(h, params["final_norm"], cfg.norm_eps)
        head = self._lm_head(params).astype(self.compute_dtype)
        mask = batch.get("mask")
        xent = chunked_softmax_xent(
            h, head, batch["labels"], mask=mask, chunk=cfg.loss_chunk
        )
        loss = xent
        if cfg.n_experts:
            loss = loss + 0.01 * aux["lb_loss"] + 1e-3 * aux["z_loss"]
        metrics = {"xent": xent, **aux}
        return loss, metrics

    def prefill(self, params, batch, cache) -> tuple[dict, jnp.ndarray]:
        cfg = self.cfg
        h = self._embed(params, batch)
        img = batch.get("img_embeds")
        h, new_cache, _ = self._forward(
            params, h, mode="prefill", pos=jnp.int32(0), cache=cache, img_embeds=img
        )
        h = rms_norm(h, params["final_norm"], cfg.norm_eps)
        logits = jnp.einsum(
            "bd,dv->bv", h[:, -1], self._lm_head(params).astype(h.dtype),
            preferred_element_type=jnp.float32,
        )
        return new_cache, logits

    def decode_step(self, params, cache, batch) -> tuple[dict, jnp.ndarray]:
        """batch: {token: (B,) | frame: (B, d), pos: ()} -> (cache, logits)."""
        cfg = self.cfg
        pos = batch["pos"]
        if cfg.embed_input:
            h = params["embed"][batch["token"]][:, None].astype(self.compute_dtype)
        else:
            h = batch["frame"][:, None].astype(self.compute_dtype)
        img = batch.get("img_embeds")
        h, new_cache, _ = self._forward(
            params, h, mode="decode", pos=pos, cache=cache, img_embeds=img
        )
        h = rms_norm(h, params["final_norm"], cfg.norm_eps)
        logits = jnp.einsum(
            "bd,dv->bv", h[:, 0], self._lm_head(params).astype(h.dtype),
            preferred_element_type=jnp.float32,
        )
        return new_cache, logits

    # ---------------------------------------------------------------- caches
    def init_cache(self, batch_size: int, max_seq: int) -> dict:
        """Zero caches, stacked over superblocks (scan xs layout)."""
        cfg = self.cfg
        B, S = batch_size, max_seq
        G, hd, d = cfg.n_kv_heads, cfg.hd, cfg.d_model
        nsb = cfg.n_superblocks
        out: dict = {}
        for i, (mixer, _ffn) in enumerate(cfg.pattern):
            if mixer == "attn":
                out[str(i)] = {
                    "k": jnp.zeros((nsb, B, G, S, hd), self.compute_dtype),
                    "v": jnp.zeros((nsb, B, G, S, hd), self.compute_dtype),
                }
            elif mixer == "xattn":
                n_img = cfg.n_img_tokens
                out[str(i)] = {
                    "k_img": jnp.zeros((nsb, B, G, n_img, hd), self.compute_dtype),
                    "v_img": jnp.zeros((nsb, B, G, n_img, hd), self.compute_dtype),
                }
            elif mixer == "mamba":
                di, N, cw = cfg.ssm_expand * d, cfg.ssm_state, cfg.ssm_conv
                out[str(i)] = {
                    "h": jnp.zeros((nsb, B, di, N), jnp.float32),
                    "conv": jnp.zeros((nsb, B, cw - 1, di), self.compute_dtype),
                }
            elif mixer == "mlstm":
                di, Hx = cfg.xlstm_expand * d, cfg.xlstm_heads
                dh = di // Hx
                out[str(i)] = {
                    "C": jnp.zeros((nsb, B, Hx, dh, dh), jnp.float32),
                    "n": jnp.zeros((nsb, B, Hx, dh), jnp.float32),
                    "m": jnp.full((nsb, B, Hx), -jnp.inf, jnp.float32),
                }
            elif mixer == "slstm":
                Hx = cfg.xlstm_heads
                dh = d // Hx
                out[str(i)] = {
                    "h": jnp.zeros((nsb, B, Hx, dh), jnp.float32),
                    "c": jnp.zeros((nsb, B, Hx, dh), jnp.float32),
                    "n": jnp.ones((nsb, B, Hx, dh), jnp.float32),
                    "m": jnp.zeros((nsb, B, Hx, dh), jnp.float32),
                }
        return out

    def cache_struct(self, batch_size: int, max_seq: int) -> dict:
        return jax.eval_shape(lambda: self.init_cache(batch_size, max_seq))


# ---------------------------------------------------------------------------
# dry-run input specs
# ---------------------------------------------------------------------------


def input_specs(cfg: ArchConfig, shape: ShapeConfig) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of a shape cell.

    Weak-type-correct, shardable, no device allocation (the modality
    frontends of [audio]/[vlm] archs are stubs: precomputed frame/patch
    embeddings appear here as inputs).
    """
    B, S = shape.global_batch, shape.seq_len
    sds = jax.ShapeDtypeStruct
    bf16, i32 = jnp.bfloat16, jnp.int32
    d = cfg.d_model
    if shape.kind == "train":
        batch: dict = {}
        if cfg.embed_input:
            batch["tokens"] = sds((B, S), i32)
        else:
            batch["frames"] = sds((B, S, d), bf16)
        batch["labels"] = sds((B, S), i32)
        if cfg.n_img_tokens:
            batch["img_embeds"] = sds((B, cfg.n_img_tokens, d), bf16)
        return batch
    if shape.kind == "prefill":
        batch = {}
        if cfg.embed_input:
            batch["tokens"] = sds((B, S), i32)
        else:
            batch["frames"] = sds((B, S, d), bf16)
        if cfg.n_img_tokens:
            batch["img_embeds"] = sds((B, cfg.n_img_tokens, d), bf16)
        return batch
    # decode
    batch = {"pos": sds((), i32)}
    if cfg.embed_input:
        batch["token"] = sds((B,), i32)
    else:
        batch["frame"] = sds((B, d), bf16)
    if cfg.n_img_tokens:
        batch["img_embeds"] = sds((B, cfg.n_img_tokens, d), bf16)
    return batch
