from . import layers, lm, moe, ssm, xlstm  # noqa: F401
