"""Selective SSM (Mamba) block — chunked associative scan.

Training/prefill materializes per-chunk (B, c, di, N) discretized states and
carries the (B, di, N) hidden state across chunks with a first-order
associative scan, bounding peak memory at one chunk.  Decode is the O(1)
single-step recurrence with a rolling conv cache.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .layers import silu


def _causal_conv(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray,
                 cache: jnp.ndarray | None = None):
    """Depthwise causal conv.  x: (B, T, di); w: (di, k); b: (di,).

    cache: (B, k-1, di) trailing context from the previous segment (decode /
    chunked prefill); returns (y, new_cache).
    """
    B, T, di = x.shape
    k = w.shape[1]
    if cache is None:
        cache = jnp.zeros((B, k - 1, di), x.dtype)
    xx = jnp.concatenate([cache, x], axis=1)  # (B, T+k-1, di)
    y = jnp.zeros_like(x)
    for i in range(k):
        y = y + xx[:, i : i + T, :] * w[None, None, :, i]
    new_cache = xx[:, T:, :] if k > 1 else cache
    return y + b[None, None, :], new_cache


def mamba_mix(p: dict, x: jnp.ndarray, state: dict | None = None,
              chunk: int = 256) -> tuple[jnp.ndarray, dict]:
    """x: (B, T, d) -> (B, T, d).  state carries {h, conv} for decode.

    p: in_proj (d, 2di), conv_w (di, k), conv_b (di,), x_proj (di, r+2N),
       dt_proj (r, di), dt_bias (di,), A_log (di, N), D (di,),
       out_proj (di, d).
    """
    B, T, d = x.shape
    di = p["A_log"].shape[0]
    N = p["A_log"].shape[1]
    r = p["dt_proj"].shape[0]

    xz = jnp.einsum("btd,de->bte", x, p["in_proj"])
    x1, z = jnp.split(xz, 2, axis=-1)  # (B, T, di)

    conv_cache = None if state is None else state["conv"]
    x1, new_conv = _causal_conv(x1, p["conv_w"], p["conv_b"], conv_cache)
    x1 = silu(x1)

    xdbc = jnp.einsum("bte,ef->btf", x1, p["x_proj"])
    dt_r, B_, C_ = jnp.split(xdbc, [r, r + N], axis=-1)
    dt = jax.nn.softplus(
        jnp.einsum("btr,re->bte", dt_r, p["dt_proj"]) + p["dt_bias"]
    ).astype(jnp.float32)  # (B, T, di)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))  # (di, N)

    h0 = (
        jnp.zeros((B, di, N), jnp.float32)
        if state is None or "h" not in state
        else state["h"]
    )

    if T == 1:  # decode fast path
        dA = jnp.exp(dt[:, 0, :, None] * A[None])  # (B, di, N)
        dBx = (
            dt[:, 0, :, None]
            * B_[:, 0, None, :].astype(jnp.float32)
            * x1[:, 0, :, None].astype(jnp.float32)
        )
        h = dA * h0 + dBx
        y = jnp.einsum("bdn,bn->bd", h, C_[:, 0].astype(jnp.float32))[:, None]
        h_last = h
    else:
        chunk = min(chunk, T)
        assert T % chunk == 0
        nc = T // chunk

        def step(h_in, idx):
            sl = lambda a: jax.lax.dynamic_slice_in_dim(a, idx * chunk, chunk, 1)
            dt_c, B_c, C_c, x_c = sl(dt), sl(B_), sl(C_), sl(x1)
            dA = jnp.exp(dt_c[..., None] * A[None, None])  # (B, c, di, N)
            dBx = (
                dt_c[..., None]
                * B_c[:, :, None, :].astype(jnp.float32)
                * x_c[..., None].astype(jnp.float32)
            )

            def comb(a, b):
                return (a[0] * b[0], b[0] * a[1] + b[1])

            cumA, cumB = jax.lax.associative_scan(comb, (dA, dBx), axis=1)
            h_all = cumA * h_in[:, None] + cumB  # (B, c, di, N)
            y_c = jnp.einsum("bcdn,bcn->bcd", h_all, C_c.astype(jnp.float32))
            return h_all[:, -1], y_c

        h_last, ys = jax.lax.scan(step, h0, jnp.arange(nc))
        y = jnp.moveaxis(ys, 0, 1).reshape(B, T, di)

    y = y + x1.astype(jnp.float32) * p["D"][None, None]
    out = (y.astype(x.dtype) * silu(z)) @ p["out_proj"]
    return out, {"h": h_last, "conv": new_conv}
