"""Mixture-of-Experts layer with compressed-key-sort dispatch.

Token -> expert dispatch is a *sort problem*: entries keyed by
``(expert_id, arrival order)`` must be grouped by expert with a stable
order.  This is where the paper's technique is a first-class feature of the
framework (DESIGN.md §4.1): the dispatch sort key packs
``expert_id || flat position`` into ``ceil(log2 E) + ceil(log2 N·k)`` bits —
Theorem 2 applied to a key domain known at trace time.  The full 64-bit key
would need two uint32 sort words; the compressed key fits **one**, halving
every comparator stage of the dispatch sort (the paper's sort-key ratio,
at trace time instead of from a measured D-bitmap).

Two dispatch modes:
  * ``sort``   — compressed-key sort of (expert, position) entries, then
    capacity-bucket scatter.  Runs under jit; on a sharded token axis XLA
    lowers the sort to a distributed merge exchange.
  * ``einsum`` — GShard-style cumsum-over-one-hot positions (no sort).
    Default for the giant dry-run cells.
Both produce identical (E, C, d) dispatch buffers (tested).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.ctx import constrain

from .layers import silu


def _bits_for(n: int) -> int:
    return max(1, int(np.ceil(np.log2(max(n, 2)))))


def dispatch_indices_sort(expert_id: jnp.ndarray, n_experts: int):
    """Stable grouping by expert via the compressed key sort.

    expert_id: (M,) int32 (M = N * top_k flat entries).  Returns
    (position_in_expert (M,), sort permutation (M,)) where positions count
    0.. within each expert in arrival order.

    The sort key is the trace-time-compressed ``expert_id || arrival``:
    provably order-equivalent to the 64-bit wide key (Theorem 2 — every
    distinction bit of the domain lies in the low ``be + bm`` bits).
    """
    m = expert_id.shape[0]
    be, bm = _bits_for(n_experts), _bits_for(m)
    if be + bm <= 32:
        key = (expert_id.astype(jnp.uint32) << np.uint32(bm)) | jnp.arange(
            m, dtype=jnp.uint32
        )
        sorted_key = jax.lax.sort(key)  # single-word comparator
        perm = (sorted_key & jnp.uint32((1 << bm) - 1)).astype(jnp.int32)
        eid_sorted = (sorted_key >> np.uint32(bm)).astype(jnp.int32)
    else:  # fall back to two-word lexicographic sort
        eid_s, perm = jax.lax.sort(
            (expert_id.astype(jnp.uint32), jnp.arange(m, dtype=jnp.uint32)), num_keys=1
        )
        eid_sorted, perm = eid_s.astype(jnp.int32), perm.astype(jnp.int32)
    start = jnp.searchsorted(eid_sorted, jnp.arange(n_experts))
    pos_sorted = jnp.arange(m, dtype=jnp.int32) - start[eid_sorted]
    pos = jnp.zeros((m,), jnp.int32).at[perm].set(pos_sorted)
    return pos, perm


def dispatch_indices_cumsum(expert_onehot: jnp.ndarray):
    """GShard-style positions: cumulative sum of the one-hot matrix.

    expert_onehot: (M, E) {0,1}.  Returns position_in_expert (M,).
    """
    pos = (jnp.cumsum(expert_onehot, axis=0) - 1) * expert_onehot
    return jnp.sum(pos, axis=1).astype(jnp.int32)


def moe_ffn(
    p: dict,
    x: jnp.ndarray,
    *,
    n_experts: int,
    top_k: int,
    capacity_factor: float = 1.25,
    dispatch_mode: str = "einsum",
    shared_expert: bool = False,
) -> tuple[jnp.ndarray, dict]:
    """x: (B, T, d) -> (B, T, d), plus aux metrics/losses.

    Experts are sharded over the "model" axis (EP); the (E, C, d) dispatch
    buffer is constrained accordingly.
    """
    B, T, d = x.shape
    n = B * T
    xf = x.reshape(n, d)

    logits = jnp.einsum(
        "nd,de->ne", xf, p["router"], preferred_element_type=jnp.float32
    )
    probs = jax.nn.softmax(logits, axis=-1)
    gate, eidx = jax.lax.top_k(probs, top_k)  # (n, k)
    if top_k > 1:
        gate = gate / jnp.sum(gate, axis=-1, keepdims=True)

    # flatten k-major so first choices win capacity contention
    e_flat = eidx.T.reshape(-1).astype(jnp.int32)  # (k*n,)
    g_flat = gate.T.reshape(-1)
    t_flat = jnp.tile(jnp.arange(n, dtype=jnp.int32), (top_k,))
    m = n * top_k
    cap = max(8, int(np.ceil(n * top_k / n_experts * capacity_factor)))

    if dispatch_mode == "sort":
        pos, _ = dispatch_indices_sort(e_flat, n_experts)
    else:
        onehot = jax.nn.one_hot(e_flat, n_experts, dtype=jnp.int32)
        pos = dispatch_indices_cumsum(onehot)

    keep = pos < cap
    slot = jnp.where(keep, pos, cap)  # cap = out-of-bounds -> dropped

    buf = jnp.zeros((n_experts, cap, d), x.dtype)
    buf = buf.at[e_flat, slot].add(xf[t_flat], mode="drop")
    # NOTE: do NOT pin (E, C, d) shardings here — with scatter-built
    # dispatch, forcing E over "model" makes GSPMD materialize a replicated
    # buffer and all-reduce it per layer (measured 7.5x total collective
    # blow-up, EXPERIMENTS.md §Perf qwen3 i1); XLA's propagated sharding
    # (tokens stay data-sharded) is strictly better.

    h1 = jnp.einsum("ecd,edf->ecf", buf, p["moe_w1"])
    h3 = jnp.einsum("ecd,edf->ecf", buf, p["moe_w3"])
    h = silu(h1) * h3
    y = jnp.einsum("ecf,efd->ecd", h, p["moe_w2"])

    # combine: gather each kept entry's expert output, weight by its gate
    out_e = y[e_flat, slot]  # (m, d); dropped entries read slot `cap`... guard:
    out_e = jnp.where(keep[:, None], out_e, 0)
    contrib = out_e * g_flat[:, None].astype(out_e.dtype)
    out = jnp.zeros((n, d), x.dtype).at[t_flat].add(contrib.astype(x.dtype))

    if shared_expert:
        hs = silu(jnp.einsum("nd,df->nf", xf, p["w1"])) * jnp.einsum(
            "nd,df->nf", xf, p["w3"]
        )
        out = out + jnp.einsum("nf,fd->nd", hs, p["w2"])

    # aux: load-balance (Switch) + router z-loss
    me = jnp.mean(jax.nn.one_hot(eidx[:, 0], n_experts, dtype=jnp.float32), axis=0)
    ce = jnp.mean(probs, axis=0)
    aux = {
        "lb_loss": n_experts * jnp.sum(me * ce),
        "z_loss": jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2),
        "dropped_frac": 1.0 - jnp.mean(keep.astype(jnp.float32)),
    }
    return out.reshape(B, T, d), aux
