"""Shared model layers (pure JAX, no framework deps).

Memory discipline for long sequences (DESIGN.md §6): attention is a
flash-style *pair scan* — an ordered scan over (q-chunk, kv-chunk) block
pairs with running max/sum softmax state, emitting only causal pairs so HLO
FLOPs match causal-optimal cost (no masked half-square waste); the loss is
a chunked-vocab cross entropy so (B, T, V) logits never materialize.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.compat import optimization_barrier

# ---------------------------------------------------------------------------
# norms / activations / rope
# ---------------------------------------------------------------------------


def rms_norm(x: jnp.ndarray, w: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    scale = jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    out = ((xf * scale) * w.astype(jnp.float32)).astype(x.dtype)
    # pin the bf16 cast here: without the barrier XLA hoists the fp32->bf16
    # convert past the TP collectives and moves activations over ICI in
    # fp32 — 2x the wire bytes (EXPERIMENTS.md §Perf i3)
    return optimization_barrier(out)


def silu(x):
    return x * jax.nn.sigmoid(x)


def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (B, H, T, dh); positions: (T,) or (B, T)."""
    dh = x.shape[-1]
    inv = rope_freqs(dh, theta)
    ang = positions[..., None].astype(jnp.float32) * inv  # (..., T, dh/2)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    if positions.ndim == 1:
        cos, sin = cos[None, None], sin[None, None]
    else:  # (B, T, dh/2) -> (B, 1, T, dh/2)
        cos, sin = cos[:, None], sin[:, None]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------


def _einsum_f32(sub: str, a, b):
    """bf16 x bf16 -> f32 einsum.

    TPU: native MXU mixed-precision via preferred_element_type.  CPU: the
    XLA-CPU DotThunk cannot *execute* BF16xBF16=F32 for these shapes, so
    cast inputs (the converts fold; CPU is the validation substrate only).
    """
    if jax.default_backend() == "cpu":
        return jnp.einsum(sub, a.astype(jnp.float32), b.astype(jnp.float32))
    return jnp.einsum(sub, a, b, preferred_element_type=jnp.float32)


def _block_attn_update(q_i, k_j, v_j, m, l, acc, mask=None, scale=1.0):
    """One online-softmax block update.

    q_i: (B, G, r, qc, dh); k_j/v_j: (B, G, kc, dh);
    m,l: (B, G, r, qc); acc: (B, G, r, qc, dh) fp32.
    """
    s = jnp.einsum(
        "bgrqd,bgkd->bgrqk", q_i, k_j, preferred_element_type=jnp.float32
    ) * scale
    if mask is not None:
        s = jnp.where(mask, s, -jnp.inf)
    m_new = jnp.maximum(m, jnp.max(s, axis=-1))
    # guard fully-masked rows (m_new == -inf)
    safe_m = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
    p = jnp.exp(s - safe_m[..., None])
    if mask is not None:
        p = jnp.where(mask, p, 0.0)
    corr = jnp.exp(jnp.where(jnp.isfinite(m), m - safe_m, -jnp.inf))
    corr = jnp.where(jnp.isfinite(corr), corr, 0.0)
    l_new = l * corr + jnp.sum(p, axis=-1)
    acc_new = acc * corr[..., None] + jnp.einsum(
        "bgrqk,bgkd->bgrqd", p.astype(v_j.dtype), v_j,
        preferred_element_type=jnp.float32,
    )
    return m_new, l_new, acc_new


def flash_attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    causal: bool = True,
    q_chunk: int = 512,
    kv_chunk: int = 1024,
) -> jnp.ndarray:
    """Blockwise GQA attention.  q: (B, Hq, Tq, dh); k,v: (B, G, Tk, dh).

    Per-q-chunk *segments*: an unrolled loop over q chunks, each carrying
    only a chunk-local (B, G, r, qc, dh) online-softmax state through an
    inner scan over exactly the causally-visible kv chunks (static count
    per segment).  Compared to a single scan with full-length state this
    keeps emitted FLOPs causal-optimal AND keeps the fp32 accumulator
    chunk-sized — the scan transpose (backward) then accumulates
    chunk-local too, which removes the full-(B,H,T,dh) fp32 collectives
    GSPMD otherwise emits around the loop state (EXPERIMENTS.md §Perf i2).
    """
    from repro.distributed.ctx import constrain

    B, Hq, Tq, dh = q.shape
    G, Tk = k.shape[1], k.shape[2]
    r = Hq // G
    q_chunk = min(q_chunk, Tq)
    kv_chunk = min(kv_chunk, Tk)
    if Tq % q_chunk:  # ragged (small tests): single q block
        q_chunk = Tq
    if Tk % kv_chunk:
        kv_chunk = Tk
    nq, nk = Tq // q_chunk, Tk // kv_chunk
    qg = q.reshape(B, G, r, Tq, dh)
    # keep a head dim model-sharded through the scan: without the pin,
    # GSPMD replicates the attention math across the model axis.  Shard G
    # when it divides the axis (KV stays sharded too); otherwise shard the
    # per-group repeat dim r and let the small KV replicate — pinning a
    # non-dividing G measurably backfires (EXPERIMENTS.md §Perf qwen3 i2).
    from repro.distributed.ctx import axis_size

    ms = axis_size("model")
    if ms > 1 and G % ms == 0:
        qg = constrain(qg, "data", "model", None, None, None)
        k = constrain(k, "data", "model", None, None)
        v = constrain(v, "data", "model", None, None)
    elif ms > 1 and r % ms == 0:
        # G doesn't divide (e.g. qwen3 kv=4 on model=16): shard the repeat
        # dim; small KV replicates — pinning uneven G measurably backfires
        qg = constrain(qg, "data", None, "model", None, None)
    elif ms > 1 and ms % G == 0:
        # uneven-but-contained G (kv=8 on model=16): measured -54% executed
        # FLOPs / -46% collectives on llama3-8b train_4k (§Perf i1)
        qg = constrain(qg, "data", "model", None, None, None)
        k = constrain(k, "data", "model", None, None)
        v = constrain(v, "data", "model", None, None)
    scale = 1.0 / np.sqrt(dh)

    # causal offset: queries are the *last* Tq positions of the Tk context
    off = Tk - Tq
    k_pos = jnp.arange(kv_chunk)

    outs = []
    for i in range(nq):
        q_i = qg[:, :, :, i * q_chunk : (i + 1) * q_chunk]
        if causal:
            last_q = off + (i + 1) * q_chunk - 1
            n_vis = min(last_q // kv_chunk + 1, nk)  # static per segment
        else:
            n_vis = nk
        m0 = jnp.full((B, G, r, q_chunk), -jnp.inf, jnp.float32)
        l0 = jnp.zeros((B, G, r, q_chunk), jnp.float32)
        acc0 = jnp.zeros((B, G, r, q_chunk, dh), jnp.float32)
        gq = off + i * q_chunk + jnp.arange(q_chunk)

        def step(carry, j, q_i=q_i, gq=gq):
            m, l, acc = carry
            k_j = jax.lax.dynamic_slice_in_dim(k, j * kv_chunk, kv_chunk, axis=2)
            v_j = jax.lax.dynamic_slice_in_dim(v, j * kv_chunk, kv_chunk, axis=2)
            if causal:
                gk = j * kv_chunk + k_pos
                mask = (gq[:, None] >= gk[None, :])[None, None, None]
            else:
                mask = None
            m, l, acc = _block_attn_update(q_i, k_j, v_j, m, l, acc, mask, scale)
            return (m, l, acc), None

        (m, l, acc), _ = jax.lax.scan(
            step, (m0, l0, acc0), jnp.arange(n_vis)
        )
        outs.append(acc / jnp.maximum(l[..., None], 1e-30))

    out = jnp.concatenate(outs, axis=3)
    return out.reshape(B, Hq, Tq, dh).astype(q.dtype)


def decode_attention(
    q: jnp.ndarray,
    k_cache: jnp.ndarray,
    v_cache: jnp.ndarray,
    length: jnp.ndarray,
    kv_chunk: int = 2048,
) -> jnp.ndarray:
    """Single-token attention against a KV cache — flash-decoding style.

    q: (B, Hq, 1, dh); caches: (B, G, S, dh); length: () or (B,) valid kv
    count.  The sequence axis is split into segments computed *in
    parallel* (each segment's online-softmax partials are tiny), then
    combined with a max/logsumexp merge.  With the cache sharded over the
    model axis on S, every segment's math is device-local and only the
    (B, G, r, dh)-sized partials cross ICI — the KV cache itself never
    moves (§Perf decode iteration).
    """
    from repro.distributed.ctx import constrain

    B, Hq, _, dh = q.shape
    G, S = k_cache.shape[1], k_cache.shape[2]
    r = Hq // G
    kv_chunk = min(kv_chunk, S)
    if S % kv_chunk:  # ragged tail (small tests): pad; masked out below
        pad = kv_chunk - S % kv_chunk
        k_cache = jnp.concatenate(
            [k_cache, jnp.zeros((B, G, pad, dh), k_cache.dtype)], axis=2
        )
        v_cache = jnp.concatenate(
            [v_cache, jnp.zeros((B, G, pad, dh), v_cache.dtype)], axis=2
        )
        S += pad
    ns, sc = S // kv_chunk, kv_chunk
    qg = q.reshape(B, G, r, dh)
    k5 = constrain(k_cache.reshape(B, G, ns, sc, dh),
                   "data", None, "model", None, None)
    v5 = constrain(v_cache.reshape(B, G, ns, sc, dh),
                   "data", None, "model", None, None)
    scale = 1.0 / np.sqrt(dh)
    length = jnp.asarray(length)
    lb = length if length.ndim else length[None].repeat(B, 0)  # (B,)

    s = _einsum_f32("bgrd,bgscd->bgrsc", qg, k5) * scale
    pos = (jnp.arange(ns) * sc)[:, None] + jnp.arange(sc)[None, :]  # (ns, sc)
    mask = (pos[None] < lb[:, None, None])[:, None, None]  # (B,1,1,ns,sc)
    s = jnp.where(mask, s, -jnp.inf)
    m_s = jnp.max(s, axis=-1)  # (B,G,r,ns)
    safe = jnp.where(jnp.isfinite(m_s), m_s, 0.0)
    p = jnp.where(mask, jnp.exp(s - safe[..., None]), 0.0)
    l_s = jnp.sum(p, axis=-1)  # (B,G,r,ns)
    acc_s = _einsum_f32("bgrsc,bgscd->bgrsd", p.astype(v5.dtype), v5)
    # merge segments (the only cross-segment — hence cross-device — math)
    m = jnp.max(m_s, axis=-1, keepdims=True)  # (B,G,r,1)
    w = jnp.where(jnp.isfinite(m_s), jnp.exp(m_s - jnp.where(
        jnp.isfinite(m), m, 0.0)), 0.0)  # (B,G,r,ns)
    l = jnp.sum(w * l_s, axis=-1)  # (B,G,r)
    out = jnp.sum(w[..., None] * acc_s, axis=3) / jnp.maximum(l[..., None], 1e-30)
    return out.reshape(B, Hq, 1, dh).astype(q.dtype)


# ---------------------------------------------------------------------------
# losses
# ---------------------------------------------------------------------------


def chunked_softmax_xent(
    h: jnp.ndarray,
    lm_head: jnp.ndarray,
    labels: jnp.ndarray,
    mask: jnp.ndarray | None = None,
    chunk: int = 512,
    z_loss: float = 0.0,
) -> jnp.ndarray:
    """Cross entropy without materializing (B, T, V) logits.

    h: (B, T, d); lm_head: (d, V); labels: (B, T) int32.  Scans T in chunks
    computing per-chunk logits in fp32.
    """
    B, T, d = h.shape
    chunk = min(chunk, T)
    assert T % chunk == 0
    if mask is None:
        mask = jnp.ones((B, T), jnp.float32)

    def step(carry, idx):
        tot, cnt = carry
        h_c = jax.lax.dynamic_slice_in_dim(h, idx * chunk, chunk, axis=1)
        y_c = jax.lax.dynamic_slice_in_dim(labels, idx * chunk, chunk, axis=1)
        m_c = jax.lax.dynamic_slice_in_dim(mask, idx * chunk, chunk, axis=1)
        logits = jnp.einsum(
            "btd,dv->btv", h_c, lm_head, preferred_element_type=jnp.float32
        )
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, y_c[..., None], axis=-1)[..., 0]
        nll = (lse - gold) * m_c
        if z_loss:
            nll = nll + z_loss * (lse * lse) * m_c
        return (tot + jnp.sum(nll), cnt + jnp.sum(m_c)), None

    (tot, cnt), _ = jax.lax.scan(
        step, (jnp.float32(0), jnp.float32(0)), jnp.arange(T // chunk)
    )
    return tot / jnp.maximum(cnt, 1.0)
