"""Roofline analysis over the dry-run artifacts (deliverable (g)).

Per (arch x shape x mesh) cell, three terms in *seconds per step*:

  compute    = HLO_dot_FLOPs_per_device / 197e12      (bf16 peak, v5e)
  memory     = analytic HBM bytes per device / 819e9  (model below)
  collective = HLO collective bytes per device / 50e9 (1 ICI link, conservative)

HLO_dot_FLOPs and collective bytes come from ``hloanalysis`` (post-SPMD
shapes are per-partition; while-loop trip counts multiplied through), so
the compute term reflects FLOPs *actually executed* per device — sharding
inefficiencies (e.g. replicated attention math) show up here, which is the
point.  The CPU backend's ``cost_analysis()`` counts loop bodies once and
is reported only as a raw cross-check.

Memory term model (documented per EXPERIMENTS.md §Roofline):
  train:   accum * (3*Wb + act) + 20*N/chips
           Wb  = 2*N_total/chips      (bf16 weights read fwd+bwd+grad write)
           act = tokens_mb/chips * L * d * 18B   (fwd write, bwd read, remat)
  prefill: 2*Wb + act + kv_write
  decode:  Wb (all weights stream per token — the MoE decode wall)
           + kv_read (+state for SSM archs)

MODEL_FLOPS = 6*N_active*D (train) or 2*N_active*D (inference); the ratio
MODEL_FLOPS / (HLO_FLOPs * chips) is the "useful fraction" — remat,
sharding replication and dispatch overheads push it below 1.

Roofline fraction (the §Perf score) =
  [MODEL_FLOPS / (chips*197e12)] / max(compute, memory, collective)
i.e. the MFU bound this program shape admits on the target fabric.
"""

from __future__ import annotations

import argparse
import json
from pathlib import Path

from repro.configs import ARCHS, SHAPES, get_arch, get_shape

PEAK_FLOPS = 197e12  # bf16 / chip
HBM_BW = 819e9  # B/s / chip
ICI_BW = 50e9  # B/s / link (single-link conservative)

OUT_ROOT = Path(__file__).resolve().parents[3] / "experiments"


def _attn_layers(cfg) -> int:
    per = sum(1 for m, _ in cfg.pattern if m in ("attn", "xattn"))
    return per * cfg.n_superblocks


def workload_model(cfg, shape, chips: int) -> dict:
    """Analytic per-device HBM bytes + useful FLOPs."""
    N_tot, N_act = cfg.total_params(), cfg.active_params()
    B, S = shape.global_batch, shape.seq_len
    L, d = cfg.n_layers, cfg.d_model
    La = _attn_layers(cfg)
    kv_row = 2 * cfg.n_kv_heads * cfg.hd * 2  # K+V bytes per token per layer

    if shape.kind == "train":
        D = B * S
        model_flops = 6.0 * N_act * D
        tokens_mb = D // shape.accum
        Wb = 2.0 * N_tot / chips
        act = tokens_mb / chips * L * d * 18.0
        hbm = shape.accum * (3 * Wb + act) + 20.0 * N_tot / chips
    elif shape.kind == "prefill":
        D = B * S
        model_flops = 2.0 * N_act * D
        Wb = 2.0 * N_tot / chips
        act = D / chips * L * d * 6.0
        kv_write = D / chips * La * kv_row
        hbm = 2 * Wb + act + kv_write
    else:  # decode
        D = B
        model_flops = 2.0 * N_act * D
        Wb = 2.0 * N_tot / chips
        kv_read = B * S * La * kv_row / chips
        state = 0.0
        for m, _ in cfg.pattern:
            if m == "mamba":
                state += cfg.ssm_expand * d * cfg.ssm_state * 4 * 2
            elif m == "mlstm":
                di = cfg.xlstm_expand * d
                state += (di // cfg.xlstm_heads) * di * 4 * 2
            elif m == "slstm":
                state += 4 * d * 4 * 2
        state *= cfg.n_superblocks * B / chips
        hbm = Wb + kv_read + state
    return {"model_flops": model_flops, "hbm_bytes_dev": hbm, "tokens": D}


def analyze_cell(rec: dict) -> dict | None:
    if rec.get("status") != "ok":
        return None
    cfg = get_arch(rec["arch"])
    shape = get_shape(rec["shape"])
    chips = rec["n_devices"]
    wm = workload_model(cfg, shape, chips)
    hs = rec.get("hlo_summary", {})
    dot_flops_dev = hs.get("dot_flops", 0.0)
    coll_dev = sum(hs.get("collective_bytes", {}).values())

    t_compute = dot_flops_dev / PEAK_FLOPS
    t_memory = wm["hbm_bytes_dev"] / HBM_BW
    t_coll = coll_dev / ICI_BW
    bound = max(t_compute, t_memory, t_coll, 1e-12)
    dom = {t_compute: "compute", t_memory: "memory", t_coll: "collective"}[bound]
    t_useful = wm["model_flops"] / (chips * PEAK_FLOPS)
    useful_frac = (
        wm["model_flops"] / (dot_flops_dev * chips) if dot_flops_dev else 0.0
    )
    return {
        "arch": rec["arch"],
        "shape": rec["shape"],
        "mesh": rec["mesh"],
        "chips": chips,
        "t_compute_s": t_compute,
        "t_memory_s": t_memory,
        "t_collective_s": t_coll,
        "dominant": dom,
        "model_flops": wm["model_flops"],
        "hlo_flops_x_chips": dot_flops_dev * chips,
        "useful_flop_frac": useful_frac,
        "roofline_frac": t_useful / bound,
        "collective_bytes_dev": coll_dev,
        "hbm_bytes_dev": wm["hbm_bytes_dev"],
    }


_FIX_HINTS = {
    ("compute", True): "shard the attention pair-scan over the model axis "
    "(replicated head math inflates executed FLOPs)",
    ("compute", False): "already matmul-bound; raise arithmetic intensity "
    "(larger microbatch) or accept — near roofline",
    ("memory", True): "decode streams all weights per token: quantize "
    "weights (int8) or batch wider to amortize",
    ("memory", False): "cut activation traffic: fewer remat rewrites, fuse "
    "norms, bf16 master-weight reads",
    ("collective", True): "overlap EP all-to-all with expert GEMMs; "
    "compress dispatch payloads",
    ("collective", False): "overlap FSDP all-gathers with layer compute; "
    "reduce-scatter gradients",
}


def hint(row: dict, cfg) -> str:
    if row["dominant"] == "compute":
        return _FIX_HINTS[("compute", row["useful_flop_frac"] < 0.5)]
    if row["dominant"] == "memory":
        return _FIX_HINTS[("memory", row["shape"].startswith(("decode", "long")))]
    return _FIX_HINTS[("collective", bool(cfg.n_experts))]


def render_table(rows: list[dict]) -> str:
    hdr = ("| arch | shape | dom | compute s | memory s | collective s | "
           "MODEL_FLOPS | useful frac | roofline frac | next move |")
    sep = "|" + "---|" * 10
    out = [hdr, sep]
    for r in sorted(rows, key=lambda x: (x["shape"], x["arch"])):
        cfg = get_arch(r["arch"])
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['dominant'][:4]} "
            f"| {r['t_compute_s']:.3e} | {r['t_memory_s']:.3e} "
            f"| {r['t_collective_s']:.3e} | {r['model_flops']:.2e} "
            f"| {r['useful_flop_frac']:.2f} | {r['roofline_frac']:.3f} "
            f"| {hint(r, cfg)} |"
        )
    return "\n".join(out)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="pod1", choices=["pod1", "pod2", "both"])
    ap.add_argument("--json-out", default=None)
    args = ap.parse_args()
    meshes = ["pod1", "pod2"] if args.mesh == "both" else [args.mesh]
    all_rows = []
    for mesh in meshes:
        rows, opt_rows = [], []
        for f in sorted((OUT_ROOT / "dryrun" / mesh).glob("*.json")):
            rec = json.loads(f.read_text())
            row = analyze_cell(rec)
            if not row:
                continue
            # arch__shape.json = baseline; arch__shape__<tag>.json = variant
            if f.stem.count("__") > 1:
                row["variant"] = f.stem.split("__", 2)[2]
                opt_rows.append(row)
            else:
                rows.append(row)
        print(f"\n## Roofline — {mesh} ({rows[0]['chips'] if rows else '?'} chips)\n")
        print(render_table(rows))
        (OUT_ROOT / f"roofline_{mesh}.md").write_text(render_table(rows) + "\n")
        opt = [r for r in opt_rows if r["variant"] == "opt"]
        if opt:
            print(f"\n## Roofline — {mesh}, OPTIMIZED cells (§Perf)\n")
            print(render_table(opt))
            (OUT_ROOT / f"roofline_{mesh}_opt.md").write_text(
                render_table(opt) + "\n")
        all_rows += rows
    if args.json_out:
        Path(args.json_out).write_text(json.dumps(all_rows, indent=1))
    # quick pick of hillclimb candidates
    pod1 = [r for r in all_rows if r["mesh"] == "pod1"]
    if pod1:
        worst = min(pod1, key=lambda r: r["roofline_frac"])
        coll = max(pod1, key=lambda r: r["t_collective_s"] / max(r["t_compute_s"], 1e-12))
        print(f"\nworst roofline fraction: {worst['arch']}/{worst['shape']} "
              f"({worst['roofline_frac']:.3f})")
        print(f"most collective-bound:   {coll['arch']}/{coll['shape']} "
              f"(coll/compute = {coll['t_collective_s']/max(coll['t_compute_s'],1e-12):.2f})")


if __name__ == "__main__":
    main()
