"""Concrete NamedShardings for dry-run/train/serve step signatures.

Centralizes divisibility-guarded placement of params, optimizer state,
batches, and caches onto the production mesh (rules in
``repro.distributed.sharding``; guards here because e.g. long_500k has
global_batch=1, which no axis may shard)."""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.distributed.sharding import param_spec

__all__ = [
    "guard_spec",
    "params_shardings",
    "batch_shardings",
    "cache_shardings",
    "replicated",
]


def _axis_size(mesh: Mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, (tuple, list)):
        n = 1
        for a in axis:
            n *= mesh.shape[a]
        return n
    return mesh.shape[axis]


def guard_spec(mesh: Mesh, spec: P, shape: tuple) -> P:
    """Drop sharded dims that don't divide evenly (GSPMD tolerates uneven,
    but even placement keeps the roofline accounting clean and shard_map
    compatible)."""
    dims = list(spec) + [None] * (len(shape) - len(spec))
    out = []
    for d, s in zip(dims, shape):
        if d is not None and s % _axis_size(mesh, d) != 0:
            d = None
        out.append(d)
    return P(*out)


def _data_axes(mesh: Mesh):
    axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    return axes if len(axes) > 1 else axes[0]


def params_shardings(mesh: Mesh, params_tree, serve_tp_only: bool = False):
    """Rule-engine specs, divisibility-guarded, as NamedShardings.

    serve_tp_only: drop the FSDP ("data"/"pod") dims — for serving, params
    must be resident per TP group, or every decode step all-gathers the
    full weight set over ICI (§Perf decode iteration)."""

    def one(path, leaf):
        spec = param_spec(path, leaf)
        if serve_tp_only:
            spec = P(*[None if d in ("data", "pod") else d for d in spec])
        return NamedSharding(mesh, guard_spec(mesh, spec, leaf.shape))

    return jax.tree_util.tree_map_with_path(one, params_tree)


def opt_shardings(mesh: Mesh, opt_tree, params_shardings_tree):
    """m/v mirror the param shardings; step is replicated."""
    rep = NamedSharding(mesh, P())

    def build(tree):
        return jax.tree_util.tree_map(lambda s: s, params_shardings_tree)

    return {
        "m": build(opt_tree["m"]),
        "v": build(opt_tree["v"]),
        "step": rep,
    }


def batch_shardings(mesh: Mesh, batch_tree):
    dp = _data_axes(mesh)

    def one(leaf):
        if leaf.ndim == 0:
            spec = P()
        else:
            spec = P(*([dp] + [None] * (leaf.ndim - 1)))
        return NamedSharding(mesh, guard_spec(mesh, spec, leaf.shape))

    return jax.tree_util.tree_map(one, batch_tree)


def cache_shardings(mesh: Mesh, cfg: ArchConfig, cache_tree):
    """Cache layout: (nsb, B, ...) — batch over data axes, the widest inner
    feature dim over model."""
    dp = _data_axes(mesh)

    def one(path, leaf):
        name = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
        nd = leaf.ndim
        if name in ("k", "v"):  # (nsb, B, G, S, hd): S over model —
            # flash-decoding segments stay device-local (§Perf decode)
            spec = P(None, dp, None, "model", None)
        elif name in ("k_img", "v_img"):  # (nsb, B, G, n_img, hd)
            spec = P(None, dp, None, None, "model")
        elif name == "h" and nd == 4:  # mamba (nsb, B, di, N)
            spec = P(None, dp, "model", None)
        elif name == "conv":  # (nsb, B, cw-1, di)
            spec = P(None, dp, None, "model")
        elif name == "C":  # mlstm (nsb, B, H, dh, dh)
            spec = P(None, dp, None, "model", None)
        elif nd == 4:  # mlstm/slstm vectors (nsb, B, H, dh)
            spec = P(None, dp, None, "model")
        elif nd == 3:  # (nsb, B, H)
            spec = P(None, dp, None)
        else:
            spec = P()
        return NamedSharding(mesh, guard_spec(mesh, spec, leaf.shape))

    return jax.tree_util.tree_map_with_path(one, cache_tree)


def replicated(mesh: Mesh):
    return NamedSharding(mesh, P())
