"""Serving driver: batched generation with the paged-KV engine.

  PYTHONPATH=src python -m repro.launch.serve --arch llama3-8b --reduced
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.models.lm import LM
from repro.serve.engine import ServeEngine

from .train import resolve_arch


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="repro-100m")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=32)
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = resolve_arch(args.arch, args.reduced)
    model = LM(cfg)
    params = model.init(jax.random.PRNGKey(args.seed))
    engine = ServeEngine(model, params, max_seq=args.max_seq, batch_size=args.batch)

    rng = np.random.default_rng(args.seed)
    extras = {}
    if cfg.n_img_tokens:
        extras["img_embeds"] = rng.normal(
            size=(args.batch, cfg.n_img_tokens, cfg.d_model)
        ).astype(np.float32)
    prompts = rng.integers(0, cfg.vocab_size, (args.batch, args.prompt_len))

    t0 = time.time()
    out = engine.generate(prompts, args.new_tokens, extras=extras or None)
    dt = time.time() - t0
    print(f"generated {out.shape} tokens in {dt:.2f}s "
          f"({out.size/dt:,.0f} tok/s)")
    print("first sequences:", out[:2, :12].tolist())
    print("pager:", engine.pager.stats)
    print("restart (index rebuild):", engine.restart())


if __name__ == "__main__":
    main()
