import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"  # noqa: E402

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this driver builds the real step function (train_step /
prefill / decode_step), lowers it against ShapeDtypeStruct inputs with the
production shardings (no allocation), compiles it for the 256-chip
single-pod mesh and the 512-chip multi-pod mesh, and records:

  * memory_analysis()        — proves the program fits per device,
  * cost_analysis()          — HLO FLOPs / bytes for §Roofline,
  * collective byte volumes  — parsed from the post-SPMD HLO text,

into ``experiments/dryrun/<mesh>/<arch>__<shape>.json`` (incremental: cells
already on disk are skipped unless --force).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch llama3-8b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--mesh pod1|pod2]
"""

import argparse
import json
import re
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, SHAPES, get_arch, get_shape, shape_applies
from repro.models.lm import LM, input_specs
from repro.train.optim import OptConfig, adamw_init
from repro.train.trainstep import make_train_step

from .mesh import make_production_mesh
from .shardings import (
    batch_shardings,
    cache_shardings,
    opt_shardings,
    params_shardings,
    replicated,
)

OUT_ROOT = Path(__file__).resolve().parents[3] / "experiments" / "dryrun"

_COLLECTIVES = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    """Bytes of one HLO shape literal like 'bf16[16,1024]'. 0 if unknown."""
    m = _SHAPE_RE.match(shape_str)
    if not m:
        return 0
    dt, dims = m.groups()
    b = _DTYPE_BYTES.get(dt)
    if b is None:
        return 0
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * b


def collective_bytes(hlo_text: str) -> dict:
    """Sum result-shape bytes of every collective op in post-SPMD HLO.

    Methodology note (EXPERIMENTS.md §Roofline): we count the *result*
    operand size per op; ring-algorithm on-wire factors ((n-1)/n for
    all-gather/reduce-scatter, 2(n-1)/n for all-reduce) are applied in the
    roofline stage, not here.
    """
    out = {k: 0 for k in _COLLECTIVES}
    counts = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        s = line.lstrip()
        # e.g.:  %ag = bf16[8,512]{1,0} all-gather(...)  /  tuple results
        m = re.search(r"=\s+(.+?)\s+(" + "|".join(_COLLECTIVES) + r")[\(\-]", s)
        if not m:
            continue
        shapes_str, op = m.groups()
        if "start" in s.split(op)[1][:8]:
            pass  # async start counted; done-ops produce no new bytes
        total = 0
        for sh in _SHAPE_RE.finditer(shapes_str):
            total += _shape_bytes(sh.group(0))
        # skip the matching *-done ops (tuple forwarding, zero new bytes)
        if f"{op}-done" in s:
            continue
        out[op] += total
        counts[op] += 1
    return {"bytes": out, "counts": counts}


def _apply_overrides(cfg, overrides: dict | None):
    if not overrides:
        return cfg
    import dataclasses

    typed = {}
    for k, v in overrides.items():
        cur = getattr(cfg, k)
        if isinstance(cur, bool):
            typed[k] = v in ("1", "true", "True", True)
        elif isinstance(cur, int):
            typed[k] = int(v)
        elif isinstance(cur, float):
            typed[k] = float(v)
        else:
            typed[k] = v
    return dataclasses.replace(cfg, **typed)


def build_cell(arch_name: str, shape_name: str, mesh, serve_dtype=jnp.bfloat16,
               overrides: dict | None = None, serve_tp_only: bool = False):
    """Return (fn, args_sds) for one cell."""
    cfg = _apply_overrides(get_arch(arch_name), overrides)
    shape = get_shape(shape_name)
    model = LM(cfg)
    params_sds = model.param_struct()
    p_sh = params_shardings(
        mesh, params_sds,
        serve_tp_only=serve_tp_only and shape.kind != "train",
    )
    batch_sds = input_specs(cfg, shape)
    b_sh = batch_shardings(mesh, batch_sds)

    if shape.kind == "train":
        opt_sds = jax.eval_shape(adamw_init, params_sds)
        o_sh = opt_shardings(mesh, opt_sds, p_sh)
        step = make_train_step(
            model, OptConfig(), accum=shape.accum, param_shardings=p_sh
        )
        fn = jax.jit(
            step,
            in_shardings=(p_sh, o_sh, b_sh),
            out_shardings=(p_sh, o_sh, replicated(mesh)),
            donate_argnums=(0, 1),
        )
        return fn, (params_sds, opt_sds, batch_sds)

    # serving cells run bf16 weights
    sp_sds = jax.tree_util.tree_map(
        lambda s: jax.ShapeDtypeStruct(
            s.shape, serve_dtype if (s.dtype == jnp.float32 and len(s.shape) >= 2) else s.dtype
        ),
        params_sds,
    )
    cache_sds = model.cache_struct(shape.global_batch, shape.seq_len)
    c_sh = cache_shardings(mesh, cfg, cache_sds)

    if shape.kind == "prefill":
        fn = jax.jit(
            model.prefill,
            in_shardings=(p_sh, b_sh, c_sh),
            out_shardings=(c_sh, replicated(mesh)),
            donate_argnums=(2,),
        )
        return fn, (sp_sds, batch_sds, cache_sds)

    fn = jax.jit(
        model.decode_step,
        in_shardings=(p_sh, c_sh, b_sh),
        out_shardings=(c_sh, replicated(mesh)),
        donate_argnums=(1,),
    )
    return fn, (sp_sds, cache_sds, batch_sds)


def run_cell(arch_name: str, shape_name: str, mesh_name: str, force: bool = False,
             keep_hlo: bool = False, overrides: dict | None = None,
             suffix: str = "", mesh_ctx: bool = False,
             serve_tp_only: bool = False) -> dict:
    out_dir = OUT_ROOT / mesh_name
    out_dir.mkdir(parents=True, exist_ok=True)
    out_file = out_dir / f"{arch_name}__{shape_name}{suffix}.json"
    if out_file.exists() and not force:
        return json.loads(out_file.read_text())

    cfg = _apply_overrides(get_arch(arch_name), overrides)
    shape = get_shape(shape_name)
    ok, why = shape_applies(cfg, shape)
    if not ok:
        rec = {"arch": arch_name, "shape": shape_name, "mesh": mesh_name,
               "status": "skipped", "reason": why}
        out_file.write_text(json.dumps(rec, indent=1))
        return rec

    mesh = make_production_mesh(multi_pod=(mesh_name == "pod2"))
    t0 = time.time()
    rec = {"arch": arch_name, "shape": shape_name, "mesh": mesh_name,
           "n_devices": mesh.size, "overrides": overrides or {},
           "mesh_ctx": mesh_ctx}
    try:
        fn, args = build_cell(arch_name, shape_name, mesh, overrides=overrides,
                              serve_tp_only=serve_tp_only)
        import contextlib

        from repro.distributed.ctx import use_mesh

        ctx = (
            use_mesh(mesh, data_axes=tuple(
                a for a in ("pod", "data") if a in mesh.axis_names))
            if mesh_ctx
            else jax.set_mesh(mesh)
        )
        with ctx:
            lowered = fn.lower(*args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        hlo = compiled.as_text()
        coll = collective_bytes(hlo)
        from .hloanalysis import analyze_hlo

        hlo_summary = analyze_hlo(hlo).as_dict()
        rec.update(
            status="ok",
            t_lower_s=round(t_lower, 2),
            t_compile_s=round(t_compile, 2),
            flops=float(cost.get("flops", -1)) if cost else -1,
            bytes_accessed=float(cost.get("bytes accessed", -1)) if cost else -1,
            cost_analysis={k: float(v) for k, v in (cost or {}).items()
                           if isinstance(v, (int, float)) and not k.startswith("utilization")},
            memory_analysis=_mem_dict(mem),
            collectives=coll,
            hlo_summary=hlo_summary,
            hlo_lines=hlo.count("\n"),
            params_total=cfg.total_params(),
            params_active=cfg.active_params(),
            tokens_per_step=shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1),
            kind=shape.kind,
            # scan structure: XLA CPU cost_analysis counts while-loop bodies
            # ONCE; the roofline stage needs these static trip counts plus an
            # analytic workload model (launch/roofline.py) to reconstruct
            # whole-step numbers.
            scan_trips={
                "accum": shape.accum if shape.kind == "train" else 1,
                "n_superblocks": cfg.n_superblocks,
                "pattern": list(map(list, cfg.pattern)),
            },
        )
        if keep_hlo:
            (out_dir / f"{arch_name}__{shape_name}{suffix}.hlo.txt").write_text(hlo)
    except Exception as e:  # record failures — they are bugs to fix
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-4000:])
    out_file.write_text(json.dumps(rec, indent=1))
    return rec


def _mem_dict(mem) -> dict:
    if mem is None:
        return {}
    out = {}
    for k in ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "generated_code_size_in_bytes",
              "alias_size_in_bytes", "host_argument_size_in_bytes",
              "host_output_size_in_bytes", "host_temp_size_in_bytes",
              "peak_memory_in_bytes", "serialized_size_in_bytes"):
        v = getattr(mem, k, None)
        if v is not None:
            out[k] = int(v)
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="pod1", choices=["pod1", "pod2", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--keep-hlo", action="store_true")
    ap.add_argument("--override", action="append", default=[],
                    help="ArchConfig override key=value (repeatable); "
                    "used by §Perf hillclimb iterations")
    ap.add_argument("--out-suffix", default="",
                    help="artifact filename suffix (keeps baselines intact)")
    ap.add_argument("--mesh-ctx", action="store_true",
                    help="activate in-model sharding constraints (ctx.use_mesh)")
    ap.add_argument("--serve-tp-only", action="store_true",
                    help="serving cells: params TP-sharded only (no FSDP dim)")
    args = ap.parse_args()
    overrides = dict(kv.split("=", 1) for kv in args.override)

    archs = [args.arch] if args.arch else sorted(ARCHS)
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = ["pod1", "pod2"] if args.mesh == "both" else [args.mesh]
    if not (args.all or args.arch or args.shape):
        ap.error("pass --all or --arch/--shape")

    n_ok = n_skip = n_err = 0
    for mesh_name in meshes:
        for a in archs:
            for s in shapes:
                t0 = time.time()
                rec = run_cell(a, s, mesh_name, force=args.force,
                               keep_hlo=args.keep_hlo, overrides=overrides,
                               suffix=args.out_suffix, mesh_ctx=args.mesh_ctx,
                               serve_tp_only=args.serve_tp_only)
                dt = time.time() - t0
                st = rec["status"]
                n_ok += st == "ok"
                n_skip += st == "skipped"
                n_err += st == "error"
                extra = ""
                if st == "ok":
                    mem = rec.get("memory_analysis", {})
                    extra = (f"flops={rec['flops']:.3e} "
                             f"args={mem.get('argument_size_in_bytes', 0)/2**30:.2f}GiB "
                             f"temp={mem.get('temp_size_in_bytes', 0)/2**30:.2f}GiB")
                elif st == "error":
                    extra = rec.get("error", "")[:200]
                print(f"[{mesh_name}] {a:28s} {s:12s} {st:8s} {dt:7.1f}s {extra}",
                      flush=True)
    print(f"done: ok={n_ok} skipped={n_skip} errors={n_err}")
    if n_err:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
