"""End-to-end training driver (CPU-runnable; mesh-ready).

Trains a real model with the full substrate: synthetic Zipf token pipeline
(compressed-key-sort shuffle), microbatched AdamW train step, periodic
atomic checkpoints, and crash-restart via the reconstructed manifest index.

  PYTHONPATH=src python -m repro.launch.train --arch repro-100m --steps 300
  PYTHONPATH=src python -m repro.launch.train --arch llama3-8b --reduced ...
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.ckpt.checkpoint import latest_step, restore_checkpoint, save_checkpoint
from repro.configs import ARCHS
from repro.configs.base import ArchConfig
from repro.data.pipeline import TokenPipeline
from repro.data.synthetic import lm_tokens
from repro.models.lm import LM
from repro.train.optim import OptConfig, adamw_init
from repro.train.trainstep import make_train_step

# ~100M-param e2e example model (deliverable (b)): dense llama-style.
REPRO_100M = ArchConfig(
    name="repro-100m",
    family="dense",
    n_layers=10,
    d_model=640,
    n_heads=10,
    n_kv_heads=5,
    d_ff=2560,
    vocab_size=32768,
    pattern=((("attn", "dense")),),
    rope_theta=10000.0,
    q_chunk=128,
    kv_chunk=128,
    loss_chunk=128,
)


def resolve_arch(name: str, reduced: bool) -> ArchConfig:
    if name == "repro-100m":
        cfg = REPRO_100M
    else:
        cfg = ARCHS[name]
    return cfg.reduced() if reduced else cfg


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="repro-100m")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = resolve_arch(args.arch, args.reduced)
    model = LM(cfg)
    print(f"arch={cfg.name} params~{cfg.total_params()/1e6:.1f}M "
          f"active~{cfg.active_params()/1e6:.1f}M")

    docs = lm_tokens(
        n_docs=max(args.batch * 64, 512), doc_len=args.seq + 1,
        vocab=cfg.vocab_size, seed=args.seed,
    )
    pipe = TokenPipeline(docs, args.batch, args.seq, seed=args.seed)

    opt_cfg = OptConfig(peak_lr=args.lr, warmup_steps=20, decay_steps=args.steps)
    step_fn = jax.jit(
        make_train_step(model, opt_cfg, accum=args.accum), donate_argnums=(0, 1)
    )

    params = model.init(jax.random.PRNGKey(args.seed))
    opt = adamw_init(params)
    start = 0
    prev = latest_step(args.ckpt_dir)
    if prev is not None:
        (params, opt), stats = restore_checkpoint(
            args.ckpt_dir, prev, (params, opt)
        )
        start = stats["meta"]["step"]
        print(f"restored step {start} (manifest index rebuilt in "
              f"{stats['index_rebuild_s']*1e3:.1f} ms, "
              f"compression {stats['compression_ratio']:.2f}:1)")

    t0 = time.time()
    tokens_done = 0
    for step in range(start, args.steps):
        batch = jax.tree_util.tree_map(jnp.asarray, pipe.batch_at(step))
        params, opt, metrics = step_fn(params, opt, batch)
        tokens_done += args.batch * args.seq
        if (step + 1) % args.log_every == 0:
            m = {k: float(v) for k, v in metrics.items()}
            tps = tokens_done / (time.time() - t0)
            print(f"step {step+1:5d} loss={m['loss']:.4f} "
                  f"xent={m.get('xent', m['loss']):.4f} "
                  f"gnorm={m['grad_norm']:.3f} lr={m['lr']:.2e} tok/s={tps:,.0f}",
                  flush=True)
        if (step + 1) % args.ckpt_every == 0 or step + 1 == args.steps:
            path = save_checkpoint(
                args.ckpt_dir, step + 1, (params, opt),
                extra_meta={"step": step + 1, "arch": cfg.name},
            )
            print(f"checkpointed -> {path}")
    print(f"done: {args.steps - start} steps, "
          f"{tokens_done/1e6:.2f}M tokens in {time.time()-t0:.1f}s")
    return params, opt


if __name__ == "__main__":
    main()
