"""Production mesh builders.

Defined as FUNCTIONS (not module-level constants) so importing this module
never touches jax device state — the dry-run driver must set
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` *before* any jax
initialization, and smoke tests/benches must keep seeing 1 device.
"""

from __future__ import annotations

import jax
from jax.sharding import Mesh

from repro.compat import make_mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """Single pod: (16, 16) ("data", "model") = 256 chips.
    Multi-pod:  (2, 16, 16) ("pod", "data", "model") = 512 chips."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_host_mesh(n: int | None = None, axis: str = "data") -> Mesh:
    """Small mesh over whatever host devices exist (tests/benches)."""
    n = n or len(jax.devices())
    return make_mesh((n,), (axis,))


def data_axes(mesh: Mesh) -> tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)
