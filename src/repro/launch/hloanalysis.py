"""Post-SPMD HLO analyzer: whole-step FLOPs and collective bytes.

XLA's ``compiled.cost_analysis()`` on the CPU backend counts while-loop
bodies ONCE, so a scanned 94-layer model with 8 accumulation microbatches
under-reports by ~750x.  This analyzer reconstructs whole-step numbers from
the HLO text itself:

  1. parse every computation into (op kind, result shape, operands);
  2. recover each while loop's trip count from its condition computation
     (the scan induction comparison against a constant);
  3. walk the call graph from the entry computation, multiplying through
     nested while bodies (accum-scan x layer-scan x attention pair-scan);
  4. accumulate dot FLOPs (2*M*N*K from operand shapes) and collective
     result bytes per op kind, each scaled by its computation's multiplier.

Per-device numbers (post-SPMD shapes are per-partition).  dot covers the
model's matmul work; elementwise FLOPs are excluded (consistent with
MFU-style accounting).
"""

from __future__ import annotations

import re
from collections import defaultdict
from dataclasses import dataclass, field

__all__ = ["analyze_hlo", "HLOSummary"]

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
}

_COLLECTIVES = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
# header: "%name (params...) -> result {"; params may nest parens (tuples)
_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s+\(")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(\(?[^=]*?\)?)\s+([\w\-]+)\("
)
_OPERAND_RE = re.compile(r"%([\w\.\-]+)")


def _parse_shape(s: str):
    """'bf16[8,128]' -> ('bf16', (8,128)); tuples -> list of those."""
    out = []
    for m in _SHAPE_RE.finditer(s):
        dt, dims = m.groups()
        shape = tuple(int(d) for d in dims.split(",") if d)
        out.append((dt, shape))
    return out


def _numel(shape) -> int:
    n = 1
    for d in shape:
        n *= d
    return n


def _shape_bytes(parsed) -> int:
    return sum(_DTYPE_BYTES.get(dt, 4) * _numel(sh) for dt, sh in parsed)


@dataclass
class _Op:
    name: str
    kind: str
    shapes: list  # parsed result shapes
    operands: list
    line: str


@dataclass
class HLOSummary:
    dot_flops: float = 0.0
    collective_bytes: dict = field(default_factory=lambda: defaultdict(float))
    collective_counts: dict = field(default_factory=lambda: defaultdict(int))
    while_trips: dict = field(default_factory=dict)
    raw_dot_flops: float = 0.0  # bodies counted once (cross-check)

    def as_dict(self) -> dict:
        return {
            "dot_flops": self.dot_flops,
            "raw_dot_flops": self.raw_dot_flops,
            "collective_bytes": dict(self.collective_bytes),
            "collective_counts": dict(self.collective_counts),
            "while_trips": self.while_trips,
        }


_COMMENT_RE = re.compile(r"/\*.*?\*/")


def _parse_computations(text: str) -> tuple[dict, str | None]:
    comps: dict[str, list[_Op]] = {}
    entry = None
    cur = None
    for line in text.splitlines():
        line = _COMMENT_RE.sub("", line)  # /*index=5*/ comments contain '='
        if not line.strip():
            continue
        if not line.startswith((" ", "\t")) and ("->" in line) and ("{" in line):
            m = _COMP_HDR.match(line.strip())
            if m:
                cur = m.group(1)
                comps[cur] = []
                if line.strip().startswith("ENTRY"):
                    entry = cur
            continue
        if cur is None:
            continue
        s = line.strip()
        if s == "}":
            continue
        m = _OP_RE.match(s)
        if not m:
            continue
        name, shape_str, kind = m.groups()
        # operands: within the first (...) after the op kind
        after = s.split(kind + "(", 1)
        operands = _OPERAND_RE.findall(after[1]) if len(after) > 1 else []
        comps[cur].append(
            _Op(name=name, kind=kind, shapes=_parse_shape(shape_str),
                operands=operands, line=s)
        )
    return comps, entry


def _trip_count(cond_ops: list[_Op]) -> int:
    """Scan conditions compare the induction var against a constant."""
    best = 1
    consts: dict[str, int] = {}
    for op in cond_ops:
        if op.kind == "constant":
            m = re.search(r"constant\((-?\d+)\)", op.line)
            if m:
                consts[op.name] = int(m.group(1))
    for op in cond_ops:
        if op.kind == "compare":
            for o in op.operands:
                if o in consts and consts[o] > best:
                    best = consts[o]
    return best


def _attrs_comp_refs(line: str) -> dict:
    """body=%x, condition=%y, to_apply=%z, calls=%w references."""
    out = {}
    for key in ("body", "condition", "to_apply", "branch_computations", "calls"):
        m = re.search(key + r"=\{?%?([\w\.\-]+(?:,\s*%?[\w\.\-]+)*)\}?", line)
        if m:
            out[key] = [x.strip().lstrip("%") for x in m.group(1).split(",")]
    return out


def _dot_flops(op: _Op, symtab: dict) -> float:
    """2 * numel(result) * K, K = product of lhs contracting dims."""
    if not op.shapes:
        return 0.0
    result_elems = sum(_numel(sh) for _, sh in op.shapes)
    k = 1
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.line)
    if m and op.operands:
        lhs = symtab.get(op.operands[0])
        if lhs:
            dims = [int(d) for d in m.group(1).split(",") if d]
            for d in dims:
                if d < len(lhs[0][1]):
                    k *= lhs[0][1][d]
    return 2.0 * result_elems * k


def analyze_hlo(text: str) -> HLOSummary:
    comps, entry = _parse_computations(text)
    if entry is None:
        entry = next(iter(comps))
    # symbol tables per computation: op name -> parsed shapes
    symtabs = {
        c: {op.name: op.shapes for op in ops} for c, ops in comps.items()
    }

    out = HLOSummary()

    # multipliers via worklist from entry
    mult: dict[str, float] = defaultdict(float)
    mult[entry] = 1.0
    order = [entry]
    seen = {entry}
    # (build call graph in topological-ish order via BFS; HLO call graphs
    # are acyclic)
    i = 0
    while i < len(order):
        c = order[i]
        i += 1
        m = mult[c]
        for op in comps.get(c, []):
            refs = _attrs_comp_refs(op.line)
            if op.kind == "while":
                body = refs.get("body", [None])[0]
                cond = refs.get("condition", [None])[0]
                # XLA stamps the static trip count into backend_config
                mt = re.search(r'known_trip_count\\?":\{\\?"n\\?":\\?"(\d+)', op.line)
                if mt:
                    trips = int(mt.group(1))
                else:
                    trips = _trip_count(comps.get(cond, [])) if cond else 1
                if body:
                    out.while_trips[body] = trips
                    mult[body] += m * trips
                    if body not in seen:
                        seen.add(body)
                        order.append(body)
                if cond:
                    mult[cond] += m * (trips + 1)
                    if cond not in seen:
                        seen.add(cond)
                        order.append(cond)
            else:
                for key in ("to_apply", "calls", "branch_computations"):
                    for callee in refs.get(key, []):
                        if callee in comps:
                            mult[callee] += m
                            if callee not in seen:
                                seen.add(callee)
                                order.append(callee)

    # NOTE: BFS accumulation above is approximate for diamond call graphs;
    # HLO from jax scan nests cleanly (each body called from one while), so
    # multipliers are exact for our programs.
    for c, ops in comps.items():
        m = mult.get(c, 0.0)
        st = symtabs[c]
        for op in ops:
            if op.kind == "dot":
                f = _dot_flops(op, st)
                out.raw_dot_flops += f
                out.dot_flops += m * f
            elif op.kind in _COLLECTIVES or any(
                op.kind == k + "-start" for k in _COLLECTIVES
            ):
                kind = op.kind.replace("-start", "")
                b = _shape_bytes(op.shapes)
                out.collective_bytes[kind] += m * b
                out.collective_counts[kind] += 1
    return out
