"""Record-level change log for replication and delta reconstruction.

The paper's replication story (§1, §6) ships the *table* and the tiny
DS-metadata — never an index image — and the replica reconstructs.  This
module adds the missing piece for *incremental* bring-up: a record-level
**change log** a primary can stream to replicas (or a checkpoint can store
next to a base step), so a consumer folds a small delta instead of paying a
full O(n log n) resort.

Entries are columnar, LSN-stamped, and **device-friendly**: appends take
(m, W) key-word arrays + rid vectors and are kept as array chunks — there is
no per-record Python object anywhere, so a million-entry log is five arrays,
and ``fold`` is pure vectorized masking.

Fold semantics (replay in LSN order, vectorized):

* a base row is dropped iff any DELETE entry names its rid;
* an INSERT survives iff no DELETE with the same rid has a larger LSN
  (so delete-then-reinsert of a rid works, and rid reuse after free — the
  KV-pager's pattern — replays correctly);
* surviving INSERTs keep log order — they become the delta keyset appended
  after the surviving base rows, exactly the row numbering
  ``ReconstructionPipeline.run_incremental`` expects.

Live rows must have unique rids (the usual record-id contract); two live
INSERTs of the same rid both survive the fold and both land in the index.
"""

from __future__ import annotations

import io
import os
from pathlib import Path

import numpy as np

__all__ = ["OP_INSERT", "OP_DELETE", "ChangeLog"]

OP_INSERT = np.uint8(1)
OP_DELETE = np.uint8(2)


class ChangeLog:
    """Columnar LSN-stamped insert/delete log over (n_words)-word keys.

    Besides the five entry columns the log can carry the **shed-policy
    state** of its owner (the ``shed_delete_frac`` configuration and the
    owner's ``deletes_since_shed`` counter, both set at construction): a
    consumer that snapshots its apply state by serializing a log — the
    stream checkpoint frames do exactly this — must resume the bitmap shed
    policy where it left off, or a caught-up replica's future shed
    decisions diverge from a never-lagged one's.  Both fields are *pure
    carried state* (appends do not touch them; the owner tracks its own
    volume) and round-trip through ``to_npz_dict``/``from_npz_dict`` — and
    therefore through ``save``/``load`` and the wire framing.

    Parameters
    ----------
    n_words:            key width in uint32 words; every appended key must
                        reshape to ``(m, n_words)``.
    start_lsn:          LSN of the first entry this log will hold (logs are
                        contiguous: entry *i* has LSN ``start_lsn + i``).
    shed_delete_frac:   the owner's shed threshold (carried, not enforced
                        here — ``repro.core.metadata.shed_or_pin`` applies
                        it); ``None`` = never shed.
    deletes_since_shed: resume value for the delete-volume counter.
    """

    def __init__(
        self,
        n_words: int,
        start_lsn: int = 0,
        shed_delete_frac: float | None = None,
        deletes_since_shed: int = 0,
    ) -> None:
        self.n_words = int(n_words)
        self.start_lsn = int(start_lsn)
        self._next_lsn = int(start_lsn)
        self.shed_delete_frac = (
            None if shed_delete_frac is None else float(shed_delete_frac)
        )
        self.deletes_since_shed = int(deletes_since_shed)
        # parallel column chunks; concatenated lazily by arrays()
        self._ops: list[np.ndarray] = []
        self._lsns: list[np.ndarray] = []
        self._words: list[np.ndarray] = []
        self._rids: list[np.ndarray] = []
        self._lengths: list[np.ndarray] = []
        self._cache: dict | None = None

    # ------------------------------------------------------------- append
    def append_inserts(
        self,
        words: np.ndarray,
        rids: np.ndarray,
        lengths: np.ndarray | None = None,
    ) -> tuple[int, int]:
        """Append m INSERT entries; returns their [lsn0, lsn1) range."""
        words = np.asarray(words, np.uint32).reshape(-1, self.n_words)
        m = words.shape[0]
        rids = np.asarray(rids, np.uint32).reshape(m)
        if lengths is None:
            lengths = np.full(m, self.n_words * 4, np.int32)
        return self._append(OP_INSERT, words, rids, np.asarray(lengths, np.int32))

    def append_deletes(self, rids: np.ndarray) -> tuple[int, int]:
        """Append DELETE entries (by rid; keys are not needed to fold).

        Returns the entries' ``[lsn0, lsn1)`` range.
        """
        rids = np.asarray(rids, np.uint32).reshape(-1)
        m = rids.shape[0]
        return self._append(
            OP_DELETE,
            np.zeros((m, self.n_words), np.uint32),
            rids,
            np.zeros(m, np.int32),
        )

    def _append(self, op, words, rids, lengths) -> tuple[int, int]:
        m = words.shape[0]
        if m == 0:
            return self._next_lsn, self._next_lsn
        lsn0 = self._next_lsn
        self._ops.append(np.full(m, op, np.uint8))
        self._lsns.append(np.arange(lsn0, lsn0 + m, dtype=np.uint64))
        self._words.append(words)
        self._rids.append(rids)
        self._lengths.append(lengths)
        self._next_lsn = lsn0 + m
        self._cache = None
        return lsn0, self._next_lsn

    # ------------------------------------------------------------- access
    def __len__(self) -> int:
        return self._next_lsn - self.start_lsn

    @property
    def next_lsn(self) -> int:
        """LSN the next appended entry will receive (= end of this log)."""
        return self._next_lsn

    def arrays(self) -> dict[str, np.ndarray]:
        """The whole log as five columns (concatenated once, then cached)."""
        if self._cache is None:
            if self._ops:
                self._cache = {
                    "ops": np.concatenate(self._ops),
                    "lsns": np.concatenate(self._lsns),
                    "words": np.concatenate(self._words, axis=0),
                    "rids": np.concatenate(self._rids),
                    "lengths": np.concatenate(self._lengths),
                }
            else:
                self._cache = {
                    "ops": np.zeros(0, np.uint8),
                    "lsns": np.zeros(0, np.uint64),
                    "words": np.zeros((0, self.n_words), np.uint32),
                    "rids": np.zeros(0, np.uint32),
                    "lengths": np.zeros(0, np.int32),
                }
        return self._cache

    # --------------------------------------------------------------- fold
    def fold(
        self, base_rids: np.ndarray
    ) -> tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
        """Replay the log against base rows, fully vectorized.

        Returns ``(keep, ins_words, ins_lengths, ins_rids)``: a bool mask
        over base row positions plus the surviving inserts in log order —
        the exact inputs of ``fold_keyset`` / ``run_incremental``.
        """
        a = self.arrays()
        ops, lsns = a["ops"], a["lsns"]
        dmask = ops == OP_DELETE
        del_rids, del_lsns = a["rids"][dmask], lsns[dmask]
        base_rids = np.asarray(base_rids, np.uint32)

        if del_rids.size == 0:
            keep = np.ones(base_rids.shape[0], bool)
            imask = ops == OP_INSERT
            return keep, a["words"][imask], a["lengths"][imask], a["rids"][imask]

        uniq, inv = np.unique(del_rids, return_inverse=True)
        max_del_lsn = np.zeros(uniq.shape[0], np.uint64)
        np.maximum.at(max_del_lsn, inv, del_lsns)

        keep = ~np.isin(base_rids, uniq)

        imask = ops == OP_INSERT
        ins_rids, ins_lsns = a["rids"][imask], lsns[imask]
        pos = np.searchsorted(uniq, ins_rids)
        posc = np.minimum(pos, uniq.shape[0] - 1)
        hit = (pos < uniq.shape[0]) & (uniq[posc] == ins_rids)
        dead = hit & (max_del_lsn[posc] > ins_lsns)
        live = ~dead
        return (
            keep,
            a["words"][imask][live],
            a["lengths"][imask][live],
            a["rids"][imask][live],
        )

    def fold_keyset(self, base) -> tuple[np.ndarray | None, "object | None"]:
        """``fold`` packaged for the pipeline: (keep_rows, delta keyset).

        ``keep_rows`` is None when nothing was deleted and ``delta`` is None
        when no insert survived — exactly the argument conventions of
        ``ReconstructionPipeline.run_incremental``.  Every incremental call
        site (OnlineIndex, Replica, pager, checkpoint restore) goes through
        this one helper.
        """
        from repro.core.keyformat import KeySet

        keep, ins_words, ins_lengths, ins_rids = self.fold(np.asarray(base.rids))
        delta = (
            KeySet(words=ins_words, lengths=ins_lengths, rids=ins_rids)
            if ins_words.shape[0]
            else None
        )
        return (None if bool(keep.all()) else keep), delta

    # ------------------------------------------------- slicing / stitching
    def slice_lsn(self, lsn0: int, lsn1: int) -> "ChangeLog":
        """The sub-log of entries with LSN in ``[lsn0, lsn1)``.

        The stream layer's replay primitive: a replica that already applied
        part of a shipped batch (its watermark sits inside the batch's LSN
        range) slices off the prefix it has seen and applies the rest —
        which is what makes duplicate/overlapping delivery idempotent.
        Entries keep their original LSNs; the slice's ``start_lsn`` is the
        clamped ``lsn0``.  Shed state is *not* carried (a slice is a wire
        batch, not an owner snapshot).
        """
        lsn0 = max(int(lsn0), self.start_lsn)
        lsn1 = min(int(lsn1), self._next_lsn)
        out = ChangeLog(self.n_words, start_lsn=lsn0)
        if lsn1 <= lsn0:
            out._next_lsn = max(lsn0, lsn1)
            return out
        a = self.arrays()
        m = (a["lsns"] >= np.uint64(lsn0)) & (a["lsns"] < np.uint64(lsn1))
        out._ops = [a["ops"][m]]
        out._lsns = [a["lsns"][m]]
        out._words = [a["words"][m]]
        out._rids = [a["rids"][m]]
        out._lengths = [a["lengths"][m]]
        out._next_lsn = lsn1
        return out

    @staticmethod
    def concat(logs: "list[ChangeLog]") -> "ChangeLog":
        """Stitch LSN-contiguous logs into one (replay order preserved).

        The watermark-triggered rebuild primitive: a replica that drained
        several pending stream batches folds them through **one**
        ``run_incremental`` instead of paying one rebuild per batch.  Each
        ``logs[i+1].start_lsn`` must equal ``logs[i].next_lsn``; key widths
        must agree.  Shed state is *not* carried (wire batches, not owner
        snapshots).
        """
        if not logs:
            raise ValueError("concat of no logs")
        out = ChangeLog(logs[0].n_words, start_lsn=logs[0].start_lsn)
        expect = logs[0].start_lsn
        for log in logs:
            if log.n_words != out.n_words:
                raise ValueError(
                    f"key width mismatch: {log.n_words} != {out.n_words}"
                )
            if log.start_lsn != expect:
                raise ValueError(
                    f"non-contiguous logs: expected lsn {expect}, "
                    f"got {log.start_lsn}"
                )
            a = log.arrays()
            if a["ops"].size:
                out._ops.append(a["ops"])
                out._lsns.append(a["lsns"])
                out._words.append(a["words"])
                out._rids.append(a["rids"])
                out._lengths.append(a["lengths"])
            expect = log.next_lsn
        out._next_lsn = expect
        return out

    # ------------------------------------------------------ serialization
    def to_npz_dict(self) -> dict[str, np.ndarray]:
        """The log as a flat dict of ``log_``-prefixed arrays.

        Embeddable into a larger npz (the delta-checkpoint and stream-frame
        formats do) — includes the shed-policy state, which must survive
        the round trip (``shed_delete_frac`` is encoded as NaN when unset).
        """
        a = self.arrays()
        frac = np.nan if self.shed_delete_frac is None else self.shed_delete_frac
        return {
            "log_ops": a["ops"],
            "log_lsns": a["lsns"],
            "log_words": a["words"],
            "log_rids": a["rids"],
            "log_lengths": a["lengths"],
            "log_n_words": np.asarray(self.n_words, np.int32),
            "log_start_lsn": np.asarray(self.start_lsn, np.int64),
            "log_shed_frac": np.asarray(frac, np.float64),
            "log_deletes_since_shed": np.asarray(
                self.deletes_since_shed, np.int64
            ),
        }

    @staticmethod
    def from_npz_dict(d: dict[str, np.ndarray]) -> "ChangeLog":
        """Inverse of ``to_npz_dict`` (tolerates pre-shed-state archives).

        A dict missing required ``log_*`` columns raises the typed
        :class:`repro.replication.wire.FrameSchemaError` (not a raw
        ``KeyError``) so stream consumers can classify the failure.
        """
        from .wire import FrameSchemaError

        try:
            frac = float(d.get("log_shed_frac", np.nan))
            log = ChangeLog(
                int(d["log_n_words"]),
                start_lsn=int(d["log_start_lsn"]),
                shed_delete_frac=None if np.isnan(frac) else frac,
                deletes_since_shed=int(d.get("log_deletes_since_shed", 0)),
            )
            ops = np.asarray(d["log_ops"], np.uint8)
            if ops.size:
                log._ops = [ops]
                log._lsns = [np.asarray(d["log_lsns"], np.uint64)]
                log._words = [np.asarray(d["log_words"], np.uint32)]
                log._rids = [np.asarray(d["log_rids"], np.uint32)]
                log._lengths = [np.asarray(d["log_lengths"], np.int32)]
                log._next_lsn = int(d["log_lsns"][-1]) + 1
        except (KeyError, ValueError, TypeError) as e:
            raise FrameSchemaError(f"malformed change-log archive: {e!r}") from e
        return log

    def save(self, path: str | os.PathLike) -> Path:
        """Persist as an npz file; inverse of ``load``."""
        path = Path(path)
        np.savez(path, **self.to_npz_dict())
        return path

    @staticmethod
    def load(path: str | os.PathLike) -> "ChangeLog":
        """Load a log persisted by ``save``."""
        with np.load(path) as z:
            return ChangeLog.from_npz_dict(dict(z))

    # ------------------------------------------------------- wire framing
    def to_wire(self) -> bytes:
        """Serialize for a stream transport (the npz archive as bytes).

        The stream layer wraps this payload in a typed frame
        (``repro.replication.stream.encode_frame``); the bytes themselves
        are a standard npz, so any npz reader can inspect a captured frame.
        """
        buf = io.BytesIO()
        np.savez(buf, **self.to_npz_dict())
        return buf.getvalue()

    @staticmethod
    def from_wire(payload: bytes) -> "ChangeLog":
        """Inverse of ``to_wire``.

        A payload that is not an npz archive (torn copy, foreign bytes)
        raises the typed :class:`repro.replication.wire.FrameSchemaError`
        instead of a raw zipfile exception.
        """
        from .wire import FrameSchemaError

        try:
            with np.load(io.BytesIO(payload)) as z:
                d = dict(z)
        except Exception as e:  # zipfile.BadZipFile, OSError, ValueError
            raise FrameSchemaError(
                f"wire payload is not an npz archive: {e}"
            ) from e
        return ChangeLog.from_npz_dict(d)
