"""Record-level change log for replication and delta reconstruction.

The paper's replication story (§1, §6) ships the *table* and the tiny
DS-metadata — never an index image — and the replica reconstructs.  This
module adds the missing piece for *incremental* bring-up: a record-level
**change log** a primary can stream to replicas (or a checkpoint can store
next to a base step), so a consumer folds a small delta instead of paying a
full O(n log n) resort.

Entries are columnar, LSN-stamped, and **device-friendly**: appends take
(m, W) key-word arrays + rid vectors and are kept as array chunks — there is
no per-record Python object anywhere, so a million-entry log is five arrays,
and ``fold`` is pure vectorized masking.

Fold semantics (replay in LSN order, vectorized):

* a base row is dropped iff any DELETE entry names its rid;
* an INSERT survives iff no DELETE with the same rid has a larger LSN
  (so delete-then-reinsert of a rid works, and rid reuse after free — the
  KV-pager's pattern — replays correctly);
* surviving INSERTs keep log order — they become the delta keyset appended
  after the surviving base rows, exactly the row numbering
  ``ReconstructionPipeline.run_incremental`` expects.

Live rows must have unique rids (the usual record-id contract); two live
INSERTs of the same rid both survive the fold and both land in the index.
"""

from __future__ import annotations

import os
from pathlib import Path

import numpy as np

__all__ = ["OP_INSERT", "OP_DELETE", "ChangeLog"]

OP_INSERT = np.uint8(1)
OP_DELETE = np.uint8(2)


class ChangeLog:
    """Columnar LSN-stamped insert/delete log over (n_words)-word keys."""

    def __init__(self, n_words: int, start_lsn: int = 0) -> None:
        self.n_words = int(n_words)
        self.start_lsn = int(start_lsn)
        self._next_lsn = int(start_lsn)
        # parallel column chunks; concatenated lazily by arrays()
        self._ops: list[np.ndarray] = []
        self._lsns: list[np.ndarray] = []
        self._words: list[np.ndarray] = []
        self._rids: list[np.ndarray] = []
        self._lengths: list[np.ndarray] = []
        self._cache: dict | None = None

    # ------------------------------------------------------------- append
    def append_inserts(
        self,
        words: np.ndarray,
        rids: np.ndarray,
        lengths: np.ndarray | None = None,
    ) -> tuple[int, int]:
        """Append m INSERT entries; returns their [lsn0, lsn1) range."""
        words = np.asarray(words, np.uint32).reshape(-1, self.n_words)
        m = words.shape[0]
        rids = np.asarray(rids, np.uint32).reshape(m)
        if lengths is None:
            lengths = np.full(m, self.n_words * 4, np.int32)
        return self._append(OP_INSERT, words, rids, np.asarray(lengths, np.int32))

    def append_deletes(self, rids: np.ndarray) -> tuple[int, int]:
        """Append DELETE entries (by rid; keys are not needed to fold)."""
        rids = np.asarray(rids, np.uint32).reshape(-1)
        m = rids.shape[0]
        return self._append(
            OP_DELETE,
            np.zeros((m, self.n_words), np.uint32),
            rids,
            np.zeros(m, np.int32),
        )

    def _append(self, op, words, rids, lengths) -> tuple[int, int]:
        m = words.shape[0]
        if m == 0:
            return self._next_lsn, self._next_lsn
        lsn0 = self._next_lsn
        self._ops.append(np.full(m, op, np.uint8))
        self._lsns.append(np.arange(lsn0, lsn0 + m, dtype=np.uint64))
        self._words.append(words)
        self._rids.append(rids)
        self._lengths.append(lengths)
        self._next_lsn = lsn0 + m
        self._cache = None
        return lsn0, self._next_lsn

    # ------------------------------------------------------------- access
    def __len__(self) -> int:
        return self._next_lsn - self.start_lsn

    @property
    def next_lsn(self) -> int:
        return self._next_lsn

    def arrays(self) -> dict[str, np.ndarray]:
        """The whole log as five columns (concatenated once, then cached)."""
        if self._cache is None:
            if self._ops:
                self._cache = {
                    "ops": np.concatenate(self._ops),
                    "lsns": np.concatenate(self._lsns),
                    "words": np.concatenate(self._words, axis=0),
                    "rids": np.concatenate(self._rids),
                    "lengths": np.concatenate(self._lengths),
                }
            else:
                self._cache = {
                    "ops": np.zeros(0, np.uint8),
                    "lsns": np.zeros(0, np.uint64),
                    "words": np.zeros((0, self.n_words), np.uint32),
                    "rids": np.zeros(0, np.uint32),
                    "lengths": np.zeros(0, np.int32),
                }
        return self._cache

    # --------------------------------------------------------------- fold
    def fold(
        self, base_rids: np.ndarray
    ) -> tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
        """Replay the log against base rows, fully vectorized.

        Returns ``(keep, ins_words, ins_lengths, ins_rids)``: a bool mask
        over base row positions plus the surviving inserts in log order —
        the exact inputs of ``fold_keyset`` / ``run_incremental``.
        """
        a = self.arrays()
        ops, lsns = a["ops"], a["lsns"]
        dmask = ops == OP_DELETE
        del_rids, del_lsns = a["rids"][dmask], lsns[dmask]
        base_rids = np.asarray(base_rids, np.uint32)

        if del_rids.size == 0:
            keep = np.ones(base_rids.shape[0], bool)
            imask = ops == OP_INSERT
            return keep, a["words"][imask], a["lengths"][imask], a["rids"][imask]

        uniq, inv = np.unique(del_rids, return_inverse=True)
        max_del_lsn = np.zeros(uniq.shape[0], np.uint64)
        np.maximum.at(max_del_lsn, inv, del_lsns)

        keep = ~np.isin(base_rids, uniq)

        imask = ops == OP_INSERT
        ins_rids, ins_lsns = a["rids"][imask], lsns[imask]
        pos = np.searchsorted(uniq, ins_rids)
        posc = np.minimum(pos, uniq.shape[0] - 1)
        hit = (pos < uniq.shape[0]) & (uniq[posc] == ins_rids)
        dead = hit & (max_del_lsn[posc] > ins_lsns)
        live = ~dead
        return (
            keep,
            a["words"][imask][live],
            a["lengths"][imask][live],
            a["rids"][imask][live],
        )

    def fold_keyset(self, base) -> tuple[np.ndarray | None, "object | None"]:
        """``fold`` packaged for the pipeline: (keep_rows, delta keyset).

        ``keep_rows`` is None when nothing was deleted and ``delta`` is None
        when no insert survived — exactly the argument conventions of
        ``ReconstructionPipeline.run_incremental``.  Every incremental call
        site (OnlineIndex, Replica, pager, checkpoint restore) goes through
        this one helper.
        """
        from repro.core.keyformat import KeySet

        keep, ins_words, ins_lengths, ins_rids = self.fold(np.asarray(base.rids))
        delta = (
            KeySet(words=ins_words, lengths=ins_lengths, rids=ins_rids)
            if ins_words.shape[0]
            else None
        )
        return (None if bool(keep.all()) else keep), delta

    # ------------------------------------------------------ serialization
    def to_npz_dict(self) -> dict[str, np.ndarray]:
        a = self.arrays()
        return {
            "log_ops": a["ops"],
            "log_lsns": a["lsns"],
            "log_words": a["words"],
            "log_rids": a["rids"],
            "log_lengths": a["lengths"],
            "log_n_words": np.asarray(self.n_words, np.int32),
            "log_start_lsn": np.asarray(self.start_lsn, np.int64),
        }

    @staticmethod
    def from_npz_dict(d: dict[str, np.ndarray]) -> "ChangeLog":
        log = ChangeLog(int(d["log_n_words"]), start_lsn=int(d["log_start_lsn"]))
        ops = np.asarray(d["log_ops"], np.uint8)
        if ops.size:
            log._ops = [ops]
            log._lsns = [np.asarray(d["log_lsns"], np.uint64)]
            log._words = [np.asarray(d["log_words"], np.uint32)]
            log._rids = [np.asarray(d["log_rids"], np.uint32)]
            log._lengths = [np.asarray(d["log_lengths"], np.int32)]
            log._next_lsn = int(d["log_lsns"][-1]) + 1
        return log

    def save(self, path: str | os.PathLike) -> Path:
        path = Path(path)
        np.savez(path, **self.to_npz_dict())
        return path

    @staticmethod
    def load(path: str | os.PathLike) -> "ChangeLog":
        with np.load(path) as z:
            return ChangeLog.from_npz_dict(dict(z))
