"""Wire integrity for stream frames: CRC32C-framed headers, typed errors.

The PR-4 framing shipped raw npz archives: any bit flip, truncation, or
foreign payload surfaced as whatever ``zipfile``/``numpy`` happened to
raise (or worse, decoded to garbage).  This module is the integrity layer
underneath ``repro.replication.stream.encode_frame``:

* every frame gets a fixed 28-byte header — magic, format version, frame
  kind tag, a publisher-assigned **monotonic sequence number**, payload
  length, and a **CRC32C** checksum covering header fields + payload;
* :func:`unpack_frame` verifies all of it and raises **typed** errors a
  supervisor can act on: :class:`FrameCorrupt` for damage (bad checksum,
  truncated or padded buffer — *re-read, then catch up*) and
  :class:`FrameSchemaError` for malformed-but-intact payloads (unknown
  version or kind, not-an-npz, missing fields — *never heals, skip to a
  checkpoint*);
* payloads whose first bytes are not the magic are **legacy v0 frames**
  (pre-header spools): :func:`is_framed` lets the decoder fall back to the
  raw-npz path so old spools still decode.

CRC32C (Castagnoli) is computed with a table-driven pure-Python loop —
no new dependency, and frame payloads are small (KBs of change-log
columns); the checksum choice matches what storage/wire protocols
(iSCSI, ext4, gRPC) use, so captured frames verify with standard tools.
"""

from __future__ import annotations

import struct
from dataclasses import dataclass

__all__ = [
    "WireError",
    "FrameCorrupt",
    "FrameSchemaError",
    "FrameHeader",
    "MAGIC",
    "WIRE_VERSION",
    "HEADER_SIZE",
    "crc32c",
    "is_framed",
    "pack_frame",
    "unpack_frame",
]


class WireError(RuntimeError):
    """Base class for frame integrity failures."""


class FrameCorrupt(WireError):
    """The frame bytes are damaged (checksum mismatch, truncated or
    over-long buffer) — a re-read may heal it; a persistent corruption
    means the position is lost and the consumer must catch up from a
    checkpoint."""


class FrameSchemaError(WireError):
    """The frame bytes are intact but not a decodable frame (unknown
    version or kind tag, payload is not an npz archive, required fields
    missing) — re-reading never helps; skip to a checkpoint."""


#: leading bytes of every framed payload ("Repro Key-sort Frame v1")
MAGIC = b"RKF1"

#: current header format version
WIRE_VERSION = 1

#: ``<`` magic(4s) version(B) kind(B) reserved(H) seq(Q) payload_len(Q) crc(I)
_HEADER = struct.Struct("<4sBBHQQI")

#: size in bytes of the fixed frame header
HEADER_SIZE = _HEADER.size


def _make_table() -> list[int]:
    # Castagnoli polynomial, reflected form (same table as iSCSI/ext4)
    poly = 0x82F63B78
    table = []
    for i in range(256):
        c = i
        for _ in range(8):
            c = (c >> 1) ^ poly if c & 1 else c >> 1
        table.append(c)
    return table


_TABLE = _make_table()


def crc32c(data: bytes, crc: int = 0) -> int:
    """CRC32C (Castagnoli) of ``data``; chainable via the ``crc`` seed."""
    crc ^= 0xFFFFFFFF
    table = _TABLE
    for b in memoryview(data):
        crc = table[(crc ^ b) & 0xFF] ^ (crc >> 8)
    return crc ^ 0xFFFFFFFF


@dataclass(frozen=True)
class FrameHeader:
    """The decoded fixed header of a framed payload.

    ``kind`` is the numeric frame-kind tag (the stream layer maps it to
    the frame dataclasses); ``seq`` is the publisher's monotonic frame
    counter — independent of transport positions, so a reader can detect
    wire-level reordering/duplication even after retention renumbered
    nothing (positions are never reused, but a chaos wire can still
    deliver them out of order).
    """

    version: int
    kind: int
    seq: int
    payload_len: int
    crc: int


def _body_crc(version: int, kind: int, seq: int, payload: bytes) -> int:
    # the checksum covers the load-bearing header fields + payload, so a
    # bit flip anywhere past the magic is caught by one comparison
    head = struct.pack("<BBHQQ", version, kind, 0, seq, len(payload))
    return crc32c(payload, crc=crc32c(head))


def is_framed(buf: bytes) -> bool:
    """Whether ``buf`` starts with the v1 frame magic (else: legacy v0)."""
    return bytes(buf[:4]) == MAGIC


def pack_frame(kind: int, payload: bytes, seq: int = 0) -> bytes:
    """Wrap ``payload`` in a v1 integrity header; inverse of ``unpack_frame``."""
    if not 0 <= int(kind) <= 0xFF:
        raise ValueError(f"frame kind tag out of range: {kind}")
    crc = _body_crc(WIRE_VERSION, kind, seq, payload)
    return (
        _HEADER.pack(MAGIC, WIRE_VERSION, kind, 0, seq, len(payload), crc)
        + payload
    )


def unpack_frame(buf: bytes) -> tuple[FrameHeader, bytes]:
    """Verify and split a framed payload into ``(header, payload)``.

    Raises :class:`FrameCorrupt` on damage (short buffer, length
    mismatch, checksum mismatch) and :class:`FrameSchemaError` on an
    unknown magic or format version.
    """
    buf = bytes(buf)
    if len(buf) < HEADER_SIZE:
        raise FrameCorrupt(
            f"frame shorter than its header ({len(buf)} < {HEADER_SIZE} bytes)"
        )
    magic, version, kind, _res, seq, plen, crc = _HEADER.unpack_from(buf)
    if magic != MAGIC:
        raise FrameSchemaError(f"bad frame magic {magic!r}")
    if version != WIRE_VERSION:
        raise FrameSchemaError(f"unknown wire format version {version}")
    payload = buf[HEADER_SIZE:]
    if len(payload) != plen:
        raise FrameCorrupt(
            f"frame payload length {len(payload)} != header's {plen} "
            "(truncated or padded)"
        )
    if _body_crc(version, kind, seq, payload) != crc:
        raise FrameCorrupt("frame checksum mismatch (CRC32C)")
    return FrameHeader(version, kind, seq, plen, crc), payload
