"""Fault injection for stream transports: seeded chaos plans + a wrapper.

Fast recovery only matters if it is *correct under failure*: the byte
identity contract (`docs/replication.md` §Determinism) has to survive an
adversarial wire, not just the perfectly ordered lossless transports the
tests construct.  :class:`FaultyTransport` wraps any
:class:`~repro.replication.transport.Transport` and injects the classic
delivery faults, each driven by one seeded RNG so a failing schedule is
replayable bit-for-bit from its seed:

================  =======================================================
fault             injection point
================  =======================================================
drop              ``publish``: the frame silently never reaches the wire
duplicate         ``publish``: the frame is appended twice
reorder           ``publish``: frames buffered in a small window and
                  flushed in a permuted order (positions are assigned in
                  the permuted order — LSNs arrive out of order)
corrupt           ``read``: 1+ random bit flips in a *copy* of the frame
                  (re-reads may heal — transient wire damage)
delay             ``read``: the frame pretends not to be published yet
spurious truncate ``read``: a fake ``FrameTruncated`` (poller takes the
                  catch-up jump for nothing)
mid-stream cut    scheduled real ``truncate_before`` at the N-th publish
                  (retention fires at the worst moment)
================  =======================================================

Every injection lands in the **ledger** (`FaultyTransport.ledger` /
`.counts`), so a soak run can report exactly which faults a surviving
replica absorbed.  With an all-zero plan the wrapper is a transparent
pass-through — the transport-contract suite runs it against the same
assertions as the real transports.

``quiesce()`` ends the chaos phase: faults off, the reorder window
flushed — the fault-free drain a soak harness uses to assert every
surviving replica converges byte-identical to the reference.
"""

from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from .transport import FrameTruncated, Transport

__all__ = ["ChaosPlan", "FaultyTransport"]


@dataclass(frozen=True)
class ChaosPlan:
    """One seeded fault schedule: per-op probabilities + scheduled cuts.

    All probabilities default to 0 (transparent pass-through).
    ``truncate_at`` schedules real mid-stream retention: at the i-th
    ``publish`` call (1-based), ``truncate_before(end - keep_last)``
    fires on the inner transport — whatever protocol frames that cuts.
    """

    seed: int = 0
    p_drop_publish: float = 0.0
    p_duplicate: float = 0.0
    p_reorder: float = 0.0
    reorder_window: int = 4
    p_corrupt: float = 0.0
    corrupt_bits: int = 1
    p_delay: float = 0.0
    p_spurious_truncated: float = 0.0
    truncate_at: tuple[tuple[int, int], ...] = field(default_factory=tuple)

    @staticmethod
    def sample(seed: int, n_publishes_hint: int = 40,
               intensity: float = 1.0) -> "ChaosPlan":
        """Draw a random-but-reproducible plan for a soak run.

        Probabilities are drawn from ranges scaled by ``intensity`` and
        kept low enough that a bounded-retry supervisor converges once
        checkpoints flow; about half the sampled plans also schedule one
        mid-stream truncation somewhere past the warm-up publishes.
        """
        r = np.random.default_rng(np.uint64(seed) * np.uint64(0x9E3779B9) + 1)
        s = float(intensity)
        truncate: tuple[tuple[int, int], ...] = ()
        if n_publishes_hint >= 8 and r.random() < 0.5:
            at = int(r.integers(4, max(5, n_publishes_hint - 2)))
            truncate = ((at, int(r.integers(1, 4))),)
        return ChaosPlan(
            seed=int(seed),
            p_drop_publish=float(r.uniform(0, 0.08)) * s,
            p_duplicate=float(r.uniform(0, 0.15)) * s,
            p_reorder=float(r.uniform(0, 0.25)) * s,
            reorder_window=int(r.integers(2, 5)),
            p_corrupt=float(r.uniform(0, 0.12)) * s,
            corrupt_bits=int(r.integers(1, 4)),
            p_delay=float(r.uniform(0, 0.20)) * s,
            p_spurious_truncated=float(r.uniform(0, 0.05)) * s,
            truncate_at=truncate,
        )


class FaultyTransport(Transport):
    """A fault-injecting wrapper around any transport.

    Publish-side faults (drop, duplicate, reorder, scheduled truncation)
    mutate what lands on the inner transport; read-side faults (corrupt,
    delay, spurious truncation) are **transient** — they damage what this
    call returns, never what is stored, so a re-read can heal them
    (exactly the failure mode the supervisor's re-read-once path is for).

    Position contract under chaos: ``publish`` returns the position the
    frame *would* get were the window flushed in order — exact whenever
    no frames are held, best-effort while the reorder window is holding
    frames (the publisher's only positional use is aiming retention,
    which tolerates slack; subscribers order by LSN, not position).

    ``enabled`` gates all injection; :meth:`quiesce` disables faults and
    flushes the reorder window for a fault-free drain.
    """

    def __init__(self, inner: Transport, plan: ChaosPlan | None = None) -> None:
        self.inner = inner
        self.plan = plan if plan is not None else ChaosPlan()
        self.enabled = True
        self.ledger: list[dict] = []
        self.counts: dict[str, int] = {}
        self._rng = np.random.default_rng(np.uint64(self.plan.seed))
        self._window: list[bytes] = []
        self._n_publishes = 0

    # ------------------------------------------------------------- ledger
    def _record(self, fault: str, **detail) -> None:
        self.counts[fault] = self.counts.get(fault, 0) + 1
        self.ledger.append({"fault": fault, "op": self._n_publishes, **detail})

    # ------------------------------------------------------------ publish
    def publish(self, frame: bytes) -> int:
        """Append one frame, subject to the plan's publish-side faults."""
        if not self.enabled:
            return self.inner.publish(frame)
        self._n_publishes += 1
        for at, keep_last in self.plan.truncate_at:
            if at == self._n_publishes:
                self.flush()  # held frames land before the cut, not after
                cut = max(self.inner.end() - int(keep_last), 0)
                dropped = self.inner.truncate_before(cut)
                self._record("scheduled_truncate", pos=cut, dropped=dropped)
        r = self._rng
        predicted = self.inner.end() + len(self._window)
        if r.random() < self.plan.p_drop_publish:
            self._record("drop", predicted_pos=predicted)
            return predicted
        self._window.append(bytes(frame))
        if r.random() < self.plan.p_duplicate:
            self._window.append(bytes(frame))
            self._record("duplicate", predicted_pos=predicted)
        if (
            len(self._window) < self.plan.reorder_window
            and r.random() < self.plan.p_reorder
        ):
            self._record("hold", predicted_pos=predicted,
                         window=len(self._window))
            return predicted
        self._flush_window()
        return predicted

    def _flush_window(self) -> None:
        if not self._window:
            return
        order = list(range(len(self._window)))
        if len(order) > 1:
            order = [int(i) for i in self._rng.permutation(len(order))]
            if order != sorted(order):
                self._record("reorder", n=len(order), order=tuple(order))
        for i in order:
            self.inner.publish(self._window[i])
        self._window.clear()

    def flush(self) -> None:
        """Release held frames to the inner transport (possibly permuted)."""
        self._flush_window()

    def quiesce(self) -> None:
        """End the chaos phase: disable all faults, flush the window."""
        self.enabled = False
        self._flush_window()

    # --------------------------------------------------------------- read
    def read(self, pos: int) -> bytes | None:
        """The frame at ``pos``, subject to the plan's read-side faults."""
        if not self.enabled:
            return self.inner.read(pos)
        r = self._rng
        if r.random() < self.plan.p_spurious_truncated:
            self._record("spurious_truncated", pos=pos)
            raise FrameTruncated(f"frame {pos} truncated (injected)")
        raw = self.inner.read(pos)  # a real FrameTruncated passes through
        if raw is None:
            return None
        if r.random() < self.plan.p_delay:
            self._record("delay", pos=pos)
            return None
        if r.random() < self.plan.p_corrupt:
            damaged = bytearray(raw)
            for _ in range(max(1, self.plan.corrupt_bits)):
                i = int(r.integers(len(damaged)))
                damaged[i] ^= 1 << int(r.integers(8))
            self._record("corrupt", pos=pos, n_bits=self.plan.corrupt_bits)
            return bytes(damaged)
        return raw

    # -------------------------------------------------------- passthrough
    def first_pos(self) -> int:
        """Oldest retained position (inner transport's)."""
        return self.inner.first_pos()

    def end(self) -> int:
        """One past the newest *visible* position (held frames excluded)."""
        return self.inner.end()

    def truncate_before(self, pos: int) -> int:
        """Retention passes through to the inner transport."""
        return self.inner.truncate_before(pos)
