"""Async streaming replication: primary → N replicas over a transport.

The paper's headline scenario (§1, §6) run end to end: the wire carries
the **table and its change log — never an index image** — and every
consumer keeps its index current by *reconstructing*, incrementally, with
the compressed key sort.  This module turns the in-process ``Replica``
into a real primary/replica topology over a pluggable
:mod:`~repro.replication.transport`:

* :class:`StreamPrimary` appends LSN-ordered ``ChangeLog`` batches to the
  transport (optionally **coalescing** small batches up to a plan-cache
  bucket boundary so every replica's delta sort replays one compiled
  program), keeps its own index current through the same ``Replica``
  apply path, periodically snapshots its state through the checkpoint
  layer (``save_checkpoint`` / ``save_checkpoint_delta`` chains), and
  publishes the checkpoint *manifest* as a stream frame so laggards can
  find their catch-up base.
* :class:`StreamReplica` tails the transport by position: contiguous
  batches are stitched (``ChangeLog.concat``) and folded through **one**
  watermark-triggered ``run_incremental`` per poll; duplicate or
  overlapping delivery is idempotent (LSN watermark check +
  ``slice_lsn``); a gap with no checkpoint frame is a protocol error; a
  replica that fell behind a retention truncation **bootstraps from the
  checkpoint chain** and then resumes tailing.

Backpressure is bounded-lag: with ``max_lag_batches`` set, the primary
checkpoints and truncates the transport once that many batches pile up
after the last checkpoint frame, which caps both transport growth and the
worst-case catch-up replay any replica can face.

Determinism: a replica driven only through the stream — including one
that bootstrapped from a checkpoint — holds the same byte-identity
contract as ``Replica`` itself: its standing result always equals a full
``ReconstructionPipeline.run`` over its folded keyset under its working
metadata, on every backend.  Shed adoption is a **logged event**: when
the primary's tracked index sheds its D-bitmap, a :class:`ShedFrame`
lands in the stream at that watermark and every consumer adopts the shed
exactly there (``Replica.adopt_shed``) — so tailing, lagging, and
checkpoint-bootstrapped replicas are byte-identical to the primary at
*every* watermark, whatever their poll cadence (see docs/replication.md
§Determinism).

Reads are versioned: every inner ``Replica`` publishes each rebuild into
a ``repro.core.snapshot.SnapshotCell`` and serves lookups from the
pinned epoch, so queries interleaved with ``poll`` answer from the
pre-watermark snapshot — never a torn mixture of two reconstructions.
"""

from __future__ import annotations

import io
from dataclasses import dataclass

import numpy as np

from repro.core.keyformat import KeySet
from repro.core.metadata import DSMeta

from .log import ChangeLog
from .replica import Replica
from .transport import FrameTruncated, Transport
from .wire import (
    FrameCorrupt,
    FrameHeader,
    FrameSchemaError,
    is_framed,
    pack_frame,
    unpack_frame,
)

__all__ = [
    "BatchFrame",
    "CheckpointFrame",
    "ShedFrame",
    "encode_frame",
    "decode_frame",
    "peek_header",
    "StreamPrimary",
    "StreamReplica",
    "StreamError",
    "LsnGapError",
    "BackpressureError",
    "FrameCorrupt",
    "FrameSchemaError",
]


class StreamError(RuntimeError):
    """Base class for stream protocol violations."""


class LsnGapError(StreamError):
    """A batch frame skipped past the expected LSN with no checkpoint to
    bridge the gap — out-of-order or lost delivery, rejected."""


class BackpressureError(StreamError):
    """Bounded-lag backpressure misconfigured: ``max_lag_batches`` needs a
    tracked index and a checkpoint directory to shed lag into — rejected
    at construction, before any frame could be torn mid-publish."""


# ---------------------------------------------------------------------------
# frames
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class BatchFrame:
    """One shipped change-log batch: entries ``[lsn0, lsn1)`` in LSN order.

    ``bucket`` tags the plan-cache bucket the batch size falls in — a
    coalescing primary aims successive batches at one bucket so the
    replica-side delta extract/sort replays a cached program.
    """

    log: ChangeLog
    bucket: int

    @property
    def lsn0(self) -> int:
        """First LSN in the batch."""
        return self.log.start_lsn

    @property
    def lsn1(self) -> int:
        """One past the last LSN in the batch."""
        return self.log.next_lsn


@dataclass(frozen=True)
class ShedFrame:
    """A control frame: the primary's index shed its D-bitmap at ``lsn``.

    Shed adoption used to be a local, volume-triggered decision — which
    meant a replica folding several batches through one rebuild checked
    the threshold once for the span and could shed at a different
    watermark than the primary (docs/replication.md §Determinism, the old
    caveat).  Logging the adoption as a stream frame makes it part of the
    replay: consumers treat the frame as a span boundary (pending batches
    through ``lsn`` fold first) and then adopt the refreshed bitmap via
    ``Replica.adopt_shed`` — so a tailing replica and a caught-up one are
    identical at *every* watermark, whatever their poll cadence.
    """

    lsn: int


@dataclass(frozen=True)
class CheckpointFrame:
    """A checkpoint manifest: where a catch-up base lives on disk.

    ``base_lsn`` is the first LSN **not** covered by the checkpointed
    state — the state is current through ``base_lsn - 1`` and a
    bootstrapped replica resumes tailing *at* ``base_lsn``.
    ``log_state`` is the primary's empty log tail starting at
    ``base_lsn``, carrying the shed-policy bookkeeping
    (``shed_delete_frac`` / ``deletes_since_shed``) a bootstrapped
    replica must resume with.
    """

    ckpt_dir: str
    step: int
    base_lsn: int
    log_state: ChangeLog


#: numeric frame-kind tags for the wire header (0 is reserved)
_KIND_CODES = {"batch": 1, "shed": 2, "checkpoint": 3}
_KIND_NAMES = {v: k for k, v in _KIND_CODES.items()}


def encode_frame(
    frame: "BatchFrame | CheckpointFrame | ShedFrame", seq: int = 0
) -> bytes:
    """Serialize a frame for a transport: integrity header + npz payload.

    The payload is a self-describing npz archive (the frame kind, the
    frame-specific fields, and — for batch/checkpoint frames — the
    ``log_``-prefixed change-log columns), wrapped in the fixed
    :mod:`~repro.replication.wire` header: magic, format version, frame
    kind tag, the publisher's monotonic sequence number ``seq``, payload
    length, and a CRC32C covering both.  A bit flip anywhere on the wire
    surfaces as a typed :class:`~repro.replication.wire.FrameCorrupt`
    instead of a garbage decode.
    """
    buf = io.BytesIO()
    if isinstance(frame, BatchFrame):
        np.savez(
            buf,
            frame_kind=np.asarray("batch"),
            frame_bucket=np.asarray(frame.bucket, np.int64),
            **frame.log.to_npz_dict(),
        )
    elif isinstance(frame, ShedFrame):
        np.savez(
            buf,
            frame_kind=np.asarray("shed"),
            frame_lsn=np.asarray(frame.lsn, np.int64),
        )
    elif isinstance(frame, CheckpointFrame):
        np.savez(
            buf,
            frame_kind=np.asarray("checkpoint"),
            frame_ckpt_dir=np.asarray(frame.ckpt_dir),
            frame_step=np.asarray(frame.step, np.int64),
            frame_base_lsn=np.asarray(frame.base_lsn, np.int64),
            **frame.log_state.to_npz_dict(),
        )
    else:
        raise TypeError(f"not a stream frame: {type(frame).__name__}")
    kind = type(frame).__name__.replace("Frame", "").lower()
    return pack_frame(_KIND_CODES[kind], buf.getvalue(), seq=int(seq))


def peek_header(payload: bytes) -> FrameHeader | None:
    """The verified wire header of a framed payload; ``None`` for legacy
    v0 frames (raw npz, no header).  Raises the same typed errors as
    :func:`decode_frame` on a damaged header."""
    return unpack_frame(payload)[0] if is_framed(payload) else None


def _load_npz(body: bytes) -> dict:
    """Decode an npz payload defensively (typed error, never garbage)."""
    try:
        with np.load(io.BytesIO(body)) as z:
            return dict(z)
    except Exception as e:  # zipfile.BadZipFile, OSError, ValueError, ...
        raise FrameSchemaError(
            f"frame payload is not an npz archive: {e}"
        ) from e


def decode_frame(payload: bytes) -> "BatchFrame | CheckpointFrame | ShedFrame":
    """Inverse of :func:`encode_frame`, with verification.

    Framed (v1) payloads have their length and CRC32C checked and the
    header's kind tag cross-checked against the npz body; payloads
    without the frame magic decode through the **legacy v0 fallback**
    (raw npz — pre-header spools keep working).  All failure modes raise
    typed errors: :class:`~repro.replication.wire.FrameCorrupt` for
    damaged bytes, :class:`~repro.replication.wire.FrameSchemaError` for
    intact-but-malformed payloads (unknown kind, missing fields,
    not-an-npz) — never a raw ``KeyError`` or zipfile exception.
    """
    if is_framed(payload):
        hdr, body = unpack_frame(payload)
        expect_kind = _KIND_NAMES.get(hdr.kind)
        if expect_kind is None:
            raise FrameSchemaError(f"unknown frame kind tag {hdr.kind}")
    else:
        hdr, body, expect_kind = None, payload, None  # legacy v0 frame
    d = _load_npz(body)
    if "frame_kind" not in d:
        raise FrameSchemaError("frame payload has no 'frame_kind' field")
    kind = str(d["frame_kind"])
    if expect_kind is not None and kind != expect_kind:
        raise FrameSchemaError(
            f"header kind {expect_kind!r} != payload kind {kind!r}"
        )
    try:
        if kind == "batch":
            return BatchFrame(
                log=ChangeLog.from_npz_dict(d), bucket=int(d["frame_bucket"])
            )
        if kind == "shed":
            return ShedFrame(lsn=int(d["frame_lsn"]))
        if kind == "checkpoint":
            return CheckpointFrame(
                ckpt_dir=str(d["frame_ckpt_dir"]),
                step=int(d["frame_step"]),
                base_lsn=int(d["frame_base_lsn"]),
                log_state=ChangeLog.from_npz_dict(d),
            )
    except (KeyError, ValueError, TypeError) as e:
        raise FrameSchemaError(f"malformed {kind!r} frame: {e!r}") from e
    raise FrameSchemaError(f"unknown frame kind {kind!r}")


# ---------------------------------------------------------------------------
# checkpointed state <-> pytree (rides the repro.ckpt manifest machinery)
# ---------------------------------------------------------------------------


def _state_tree(rep: Replica) -> dict:
    """A replica's base state as a pytree the checkpoint layer can diff."""
    ks, meta = rep.keyset, rep.meta
    return {
        "keyset": {
            "words": np.asarray(ks.words, np.uint32),
            "lengths": np.asarray(ks.lengths, np.int32),
            "rids": np.asarray(ks.rids, np.uint32),
        },
        "meta": {
            "dbitmap": np.asarray(meta.dbitmap, np.uint32),
            "varbitmap": np.asarray(meta.varbitmap, np.uint32),
            "refkey": np.asarray(meta.refkey, np.uint32),
            "n_words": np.asarray(meta.n_words, np.int32),
        },
    }


def _state_like() -> dict:
    """Structure-only template for ``restore_checkpoint`` (shapes are
    taken from the stored arrays, only the leaf names must match)."""
    z32 = np.zeros(0, np.uint32)
    return {
        "keyset": {"words": z32, "lengths": z32, "rids": z32},
        "meta": {"dbitmap": z32, "varbitmap": z32, "refkey": z32,
                 "n_words": z32},
    }


# ---------------------------------------------------------------------------
# primary
# ---------------------------------------------------------------------------


class StreamPrimary:
    """The publishing side: appends batches, checkpoints, bounds lag.

    Parameters
    ----------
    transport:        where frames go (any :class:`Transport`).
    keyset:           base table at stream origin.  When given, the primary
                      keeps its **own** index current (it applies every
                      batch it ships through the same ``Replica`` path a
                      consumer runs — the primary *is* the never-lagged
                      replica) and publishes the base rows as a genesis
                      batch so replicas can bring up from LSN 0.  ``None``
                      makes a fire-and-forget publisher (e.g. the serve
                      pager shipping its journal): no tracked index, no
                      checkpoints — ``n_words`` is then required.
    n_words:          key width; inferred from ``keyset`` when present.
    backend:          execution backend for the tracked index.
    shed_delete_frac: bitmap shed policy of the tracked index (carried to
                      replicas in checkpoint frames).
    ckpt_dir:         directory for state checkpoints (full step first,
                      ``save_checkpoint_delta`` chain after).
    max_lag_batches:  bounded-lag backpressure — after this many batch
                      frames pile up past the last checkpoint frame, the
                      primary checkpoints and truncates the transport,
                      capping retention and worst-case catch-up replay.
    coalesce_min:     buffer published logs until this many entries are
                      pending, then ship them as one batch whose size tags
                      a plan-cache bucket; ``None`` ships every publish
                      immediately.  ``flush()`` forces the buffer out.
    """

    def __init__(
        self,
        transport: Transport,
        keyset: KeySet | None = None,
        *,
        n_words: int | None = None,
        backend: str = "jnp",
        backend_opts: dict | None = None,
        shed_delete_frac: float | None = None,
        ckpt_dir: "str | None" = None,
        max_lag_batches: int | None = None,
        coalesce_min: int | None = None,
    ) -> None:
        if keyset is None and n_words is None:
            raise ValueError("need a base keyset or an explicit n_words")
        if max_lag_batches is not None and (keyset is None or ckpt_dir is None):
            raise BackpressureError(
                "max_lag_batches needs a tracked index (keyset) and a "
                "ckpt_dir to shed lag into"
            )
        self.transport = transport
        self.backend = backend
        self.backend_opts = backend_opts
        self.shed_delete_frac = shed_delete_frac
        self.ckpt_dir = ckpt_dir
        self.max_lag_batches = max_lag_batches
        self.coalesce_min = coalesce_min
        self.n_words = int(keyset.n_words if keyset is not None else n_words)
        self._pending: list[ChangeLog] = []
        self._next_lsn = 0
        self._wire_seq = 0
        self._ckpt_step = 0
        self._prev_ckpt_pos: int | None = None
        self._batches_since_ckpt = 0
        self._in_checkpoint = False
        self.n_batches_published = 0
        self.n_shed_frames = 0
        self.replica: Replica | None = None
        if keyset is not None:
            genesis = ChangeLog(self.n_words, start_lsn=0)
            genesis.append_inserts(
                np.asarray(keyset.words, np.uint32),
                np.asarray(keyset.rids, np.uint32),
                lengths=np.asarray(keyset.lengths, np.int32),
            )
            self._next_lsn = genesis.next_lsn
            self.replica = Replica(
                keyset,
                backend=backend,
                backend_opts=backend_opts,
                shed_delete_frac=shed_delete_frac,
                applied_lsn=genesis.next_lsn - 1,
            )
            self._ship(genesis)

    # -------------------------------------------------------------- write
    def _publish_frame(self, frame) -> int:
        """Encode with the next monotonic wire sequence number and publish."""
        pos = self.transport.publish(encode_frame(frame, seq=self._wire_seq))
        self._wire_seq += 1
        return pos

    @property
    def next_lsn(self) -> int:
        """LSN the next published log must start at (contiguity check)."""
        return self._next_lsn

    def publish(self, log: ChangeLog) -> None:
        """Enqueue one LSN-contiguous log for shipment.

        With coalescing off the log ships immediately; with
        ``coalesce_min`` set it is buffered until enough entries are
        pending (``flush()`` forces shipment).  Raises ``StreamError`` on
        an LSN discontinuity — the primary is the stream's single writer
        and its sequence must be gap-free.
        """
        if log.n_words != self.n_words:
            raise ValueError(
                f"log key width {log.n_words} != stream width {self.n_words}"
            )
        if log.start_lsn != self._next_lsn:
            raise StreamError(
                f"publish out of order: log starts at {log.start_lsn}, "
                f"stream is at {self._next_lsn}"
            )
        self._next_lsn = log.next_lsn
        self._pending.append(log)
        pending_entries = sum(len(p) for p in self._pending)
        if self.coalesce_min is None or pending_entries >= self.coalesce_min:
            self.flush()

    def flush(self) -> int:
        """Ship the coalesced pending buffer as one batch frame.

        Returns the number of entries shipped (0 when nothing pending).
        """
        if not self._pending:
            return 0
        merged = (
            self._pending[0]
            if len(self._pending) == 1
            else ChangeLog.concat(self._pending)
        )
        self._pending = []
        self._ship(merged)
        return len(merged)

    def _ship(self, log: ChangeLog) -> None:
        """Apply to the tracked index, publish the frame, apply backpressure."""
        from repro.core import plancache

        shed = False
        if self.replica is not None and log.next_lsn - 1 > self.replica.applied_lsn:
            # skip only spans the tracked index already covers (the genesis
            # batch, which the Replica constructor consumed) — compare
            # watermarks, not "is this LSN 0"
            shed = bool(self.replica.apply(log).get("shed_bits"))
        self._publish_frame(BatchFrame(log=log, bucket=plancache.bucket(len(log))))
        if shed:
            # shed adoption is a logged event: the control frame pins the
            # watermark the bitmap shed at, so every consumer adopts it at
            # exactly that point regardless of its poll cadence
            self._publish_frame(ShedFrame(lsn=log.next_lsn - 1))
            self.n_shed_frames += 1
        self.n_batches_published += 1
        self._batches_since_ckpt += 1
        if (
            self.max_lag_batches is not None
            and self._batches_since_ckpt > self.max_lag_batches
            # a checkpoint's own flush must not re-enter checkpointing:
            # the snapshot about to be taken covers this batch anyway
            and not self._in_checkpoint
        ):
            # the constructor guarantees a tracked index + ckpt_dir here
            self.checkpoint(truncate=True)

    # --------------------------------------------------------- checkpoint
    def checkpoint(self, truncate: bool = False) -> dict:
        """Snapshot the tracked state through the checkpoint layer and
        publish its manifest as a stream frame.

        The first call writes a full ``save_checkpoint`` step; every later
        call writes a ``save_checkpoint_delta`` step chained onto the
        previous one (restore folds the chain).  ``truncate=True`` applies
        the bounded-lag retention policy: frames before the *previous*
        checkpoint frame are dropped, so the transport always retains one
        full checkpoint cycle — a replica within one cycle of the head
        still tails batches, anything older must bootstrap from the
        (≤ one cycle old) checkpoint it finds at the stream's start.
        Returns the published ``repro.ckpt.step_manifest``.
        """
        if self.replica is None or self.ckpt_dir is None:
            raise StreamError("checkpointing needs a tracked index + ckpt_dir")
        self._in_checkpoint = True
        try:
            return self._checkpoint(truncate)
        finally:
            self._in_checkpoint = False

    def _checkpoint(self, truncate: bool) -> dict:
        """The checkpoint body (re-entrancy guarded by ``checkpoint``)."""
        from repro.ckpt.checkpoint import (
            save_checkpoint,
            save_checkpoint_delta,
            step_manifest,
        )

        self.flush()
        rep = self.replica
        if not np.array_equal(
            np.asarray(rep.meta.dbitmap, np.uint32),
            np.asarray(rep.result.extract_bitmap, np.uint32),
        ):
            # a shed just adopted a narrower bitmap: realign the standing
            # run to it (one full resort) so the snapshot is
            # self-consistent — state and extraction agree at the watermark
            rep.apply(ChangeLog(self.n_words, start_lsn=rep.applied_lsn + 1))
        step = self._ckpt_step + 1
        state = _state_tree(rep)
        extra = {
            "applied_lsn": rep.applied_lsn,
            "stream_state": True,
            # the snapshot epoch rides the checkpoint: a bootstrapped
            # replica resumes the primary's epoch numbering (round-trip
            # asserted in tests/test_snapshot.py)
            "snapshot_epoch": rep.snapshots.epoch,
        }
        if self._ckpt_step == 0:
            save_checkpoint(self.ckpt_dir, step, state, extra_meta=extra)
        else:
            save_checkpoint_delta(
                self.ckpt_dir, step, state, base_step=self._ckpt_step,
                extra_meta=extra,
            )
        self._ckpt_step = step
        manifest = step_manifest(self.ckpt_dir, step)
        base_lsn = rep.applied_lsn + 1
        frame = CheckpointFrame(
            ckpt_dir=str(self.ckpt_dir),
            step=step,
            base_lsn=base_lsn,
            log_state=ChangeLog(
                self.n_words,
                start_lsn=base_lsn,
                shed_delete_frac=rep.shed_delete_frac,
                deletes_since_shed=rep.deletes_since_shed,
            ),
        )
        pos = self._publish_frame(frame)
        self._batches_since_ckpt = 0
        if truncate and self._prev_ckpt_pos is not None:
            self.transport.truncate_before(self._prev_ckpt_pos)
        self._prev_ckpt_pos = pos
        return manifest

    @property
    def stats(self) -> dict:
        """Publisher-side counters (shipment, retention, checkpoints)."""
        return {
            "next_lsn": self._next_lsn,
            "wire_seq": self._wire_seq,
            "n_batches_published": self.n_batches_published,
            "n_shed_frames": self.n_shed_frames,
            "batches_since_ckpt": self._batches_since_ckpt,
            "ckpt_step": self._ckpt_step,
            "pending_entries": sum(len(p) for p in self._pending),
            "transport_retained": len(self.transport),
        }


# ---------------------------------------------------------------------------
# replica
# ---------------------------------------------------------------------------


class StreamReplica:
    """The consuming side: tail the transport, stay byte-identical.

    Holds a cursor into the transport and an inner :class:`Replica` (built
    lazily: from the genesis batch, or from a checkpoint frame during
    catch-up).  ``poll()`` drains available frames and folds all pending
    batches through one watermark-triggered incremental rebuild.

    The LSN watermark check makes delivery faults safe: duplicate batches
    are skipped, overlapping batches are sliced to the unseen suffix, and
    a forward gap raises :class:`LsnGapError` unless a checkpoint frame
    bridges it (the retention/catch-up path).

    ``shed_delete_frac`` configures a *local* volume-based shed policy
    and defaults to ``None`` — the recommended mode, where shed adoption
    is driven entirely by the stream's logged :class:`ShedFrame` control
    frames (a shed frame splits the drained span at its watermark and
    the inner replica adopts the refreshed bitmap there).

    ``reorder_window`` (default 0 = strict) makes the poller tolerant of
    a reordering wire: a batch arriving *ahead* of the expected LSN is
    held back (up to that many frames) instead of raising
    :class:`LsnGapError` immediately, and is spliced in once the missing
    frames arrive — so a chaos transport that swaps frames within a small
    window heals in-protocol, without a checkpoint bootstrap.  Only when
    the holdback fills without connecting does the gap surface.
    """

    def __init__(
        self,
        transport: Transport,
        backend: str = "jnp",
        backend_opts: dict | None = None,
        shed_delete_frac: float | None = None,
        start_pos: int = 0,
        reorder_window: int = 0,
    ) -> None:
        self.transport = transport
        self.backend = backend
        self.backend_opts = backend_opts
        self.shed_delete_frac = shed_delete_frac
        self.pos = int(start_pos)
        self.reorder_window = int(reorder_window)
        self.replica: Replica | None = None
        self._genesis: ChangeLog | None = None
        # holdback buffer for out-of-order batches: start_lsn -> ChangeLog
        self._held: dict[int, ChangeLog] = {}
        self.n_polls = 0
        self.n_batches_applied = 0
        self.n_duplicates = 0
        self.n_rebuilds = 0
        self.n_catchups = 0
        self.n_truncation_jumps = 0
        self.n_shed_adoptions = 0
        self.n_frames_rejected = 0
        self.n_reorder_heals = 0
        self.n_resyncs = 0

    # ------------------------------------------------------------- state
    @property
    def applied_lsn(self) -> int:
        """LSN watermark the standing index is current through (-1 = none)."""
        if self.replica is not None:
            return self.replica.applied_lsn
        if self._genesis is not None:
            return self._genesis.next_lsn - 1
        return -1

    def lag_frames(self) -> int:
        """How many published frames this replica has not read yet."""
        return max(0, self.transport.end() - self.pos)

    def search(self, query_words) -> tuple[bool, int]:
        """Point lookup through the standing index: ``(found, rid)``."""
        if self.replica is None:
            raise StreamError("replica has no index yet (nothing consumed)")
        return self.replica.search(query_words)

    def search_batch(self, query_words):
        """Batched point lookup through the inner replica's pinned
        snapshot: (q, W) keys -> ((q,) found, (q,) rid) — the read
        scale-out form of :meth:`search` (see ``Replica.search_batch``)."""
        if self.replica is None:
            raise StreamError("replica has no index yet (nothing consumed)")
        return self.replica.search_batch(query_words)

    # -------------------------------------------------------------- poll
    def poll(self, max_frames: int | None = None) -> dict:
        """Drain available frames; one incremental rebuild for the span.

        Reads frames from the cursor until the transport runs dry (or
        ``max_frames``): batch frames accumulate into a pending list after
        the LSN watermark check; a checkpoint frame triggers bootstrap
        when the replica is behind its ``base_lsn`` (or has no state yet)
        and is skipped otherwise; a shed control frame splits the span at
        its watermark (flush, adopt, continue).  Each span's batches are
        stitched and folded through ONE ``Replica.apply`` — the
        applied-batch watermark, not the frame count, triggers the
        rebuild.  Returns poll stats (frames seen, batches applied,
        duplicates, catch-ups, shed adoptions, the new watermark;
        ``applies`` lists every span's apply stats, ``apply`` keeps the
        last one).
        """
        seen = 0
        pending: list[ChangeLog] = []
        fail: Exception | None = None
        out = {
            "frames": 0, "applied_batches": 0, "duplicates": 0,
            "catchup": False, "truncated_jump": False, "apply": None,
            "applies": [], "shed_adopted": 0, "frames_rejected": 0,
            "reorder_heals": 0,
        }

        def _flush_pending():
            # a shed frame can split one poll into several spans; "apply"
            # keeps the last span's stats (compat), "applies" all of them
            if pending:
                out["applied_batches"] += len(pending)
                st = self._apply_pending(pending)
                if st is not None:
                    out["applies"].append(st)
                out["apply"] = st
                pending.clear()

        while max_frames is None or seen < max_frames:
            try:
                raw = self.transport.read(self.pos)
            except FrameTruncated:
                # retention passed us by: jump to the oldest retained
                # frame — the protocol guarantees a checkpoint frame leads
                # the retained suffix after a truncation
                self.pos = self.transport.first_pos()
                self.n_truncation_jumps += 1
                out["truncated_jump"] = True
                continue
            if raw is None:
                break
            try:
                frame = decode_frame(raw)
            except (FrameCorrupt, FrameSchemaError) as err:
                # a damaged/undecodable frame: apply the drained good
                # prefix, leave the cursor ON the frame (a re-read may
                # heal transient wire corruption), surface the typed error
                self.n_frames_rejected += 1
                out["frames_rejected"] += 1
                err.pos = self.pos
                fail = err
                break
            seen += 1
            out["frames"] += 1
            if isinstance(frame, ShedFrame):
                # a shed is a span boundary: the state at frame.lsn must
                # adopt the refreshed bitmap *before* later batches fold,
                # or the post-shed full resort lands at the wrong watermark
                _flush_pending()
                if self.replica is not None and self.applied_lsn == frame.lsn:
                    if self.replica.adopt_shed():
                        self.n_shed_adoptions += 1
                        out["shed_adopted"] += 1
                # a frame at a watermark we are already past is stale (the
                # checkpoint state we bootstrapped from was realigned) —
                # skip; one ahead of us cannot happen on a contiguous read
                self.pos += 1
                continue
            if isinstance(frame, CheckpointFrame):
                eff = pending[-1].next_lsn - 1 if pending else self.applied_lsn
                no_state = (
                    self.replica is None
                    and self._genesis is None
                    and not pending
                )
                if no_state or eff + 1 < frame.base_lsn:
                    pending.clear()  # superseded by the checkpoint state
                    self._bootstrap(frame)
                    out["catchup"] = True
                self._drain_held(pending, out)
                self.pos += 1
                continue
            log = frame.log
            expected = self._expected_lsn(pending)
            if expected is None:
                # no state at all: only the stream origin (LSN 0) may start
                # us — anything later means our base was truncated away and
                # a checkpoint frame should have led the retained suffix
                if log.start_lsn != 0:
                    if self._hold(log):
                        self.pos += 1
                        continue
                    fail = LsnGapError(
                        f"no base state and the stream starts at LSN "
                        f"{log.start_lsn}, not 0 — checkpoint frame missing"
                    )
                    break
                pending.append(log)
                self._drain_held(pending, out)
            elif len(log) == 0 and log.start_lsn == expected:
                pass  # heartbeat: empty batch at the watermark, nothing to do
            elif log.next_lsn <= expected:
                self.n_duplicates += 1
                out["duplicates"] += 1
            elif log.start_lsn > expected:
                # ahead of the watermark: an out-of-order wire (or a real
                # gap).  With a reorder window, hold the batch back and
                # keep draining — the missing frames may be right behind
                # it; only a full holdback surfaces as a gap.
                if self._hold(log):
                    self.pos += 1
                    continue
                fail = LsnGapError(
                    f"batch [{log.start_lsn}, {log.next_lsn}) skips past "
                    f"expected LSN {expected} with no checkpoint to bridge"
                )
                break  # apply what we drained first; pos stays on the frame
            else:
                if log.start_lsn < expected:
                    log = log.slice_lsn(expected, log.next_lsn)
                pending.append(log)
                self._drain_held(pending, out)
            self.pos += 1
        _flush_pending()
        self.n_polls += 1
        out["applied_lsn"] = self.applied_lsn
        out["lag_frames"] = self.lag_frames()
        if fail is not None:
            # raised only after the drained good prefix was applied and
            # with the cursor parked on the offending frame — the replica's
            # state is current through every contiguous batch it saw
            raise fail
        return out

    def _hold(self, log: ChangeLog) -> bool:
        """Park an ahead-of-watermark batch in the reorder holdback.

        Returns ``False`` when the window is disabled or full (the caller
        surfaces the gap).  A batch already held at the same start LSN is
        absorbed as a duplicate.
        """
        if self.reorder_window <= 0:
            return False
        if log.start_lsn in self._held:
            self.n_duplicates += 1
            return True
        if len(self._held) >= self.reorder_window:
            return False
        self._held[log.start_lsn] = log
        return True

    def _drain_held(self, pending: list[ChangeLog], out: dict) -> None:
        """Splice held batches that now connect to the watermark."""
        while self._held:
            expected = self._expected_lsn(pending)
            if expected is None:
                return
            lsn0 = min(self._held)
            log = self._held[lsn0]
            if log.start_lsn > expected:
                return
            del self._held[lsn0]
            if log.next_lsn <= expected:
                self.n_duplicates += 1
                out["duplicates"] += 1
                continue
            if log.start_lsn < expected:
                log = log.slice_lsn(expected, log.next_lsn)
            pending.append(log)
            self.n_reorder_heals += 1
            out["reorder_heals"] += 1

    def _expected_lsn(self, pending: list[ChangeLog]) -> int | None:
        """Next LSN the stream must hand us (None before the origin)."""
        if pending:
            return pending[-1].next_lsn
        if self.replica is not None:
            return self.replica.applied_lsn + 1
        if self._genesis is not None:
            return self._genesis.next_lsn
        return None

    def _apply_pending(self, pending: list[ChangeLog]) -> dict | None:
        """Fold drained batches: genesis bring-up or one incremental apply."""
        if self.replica is not None:
            st = (
                self.replica.apply(pending[0])
                if len(pending) == 1
                else self.replica.apply_many(pending)
            )
            self.n_batches_applied += len(pending)
            self.n_rebuilds += 1
            return st
        # no index yet: accumulate the genesis prefix until a row survives
        logs = ([self._genesis] if self._genesis is not None else []) + pending
        genesis = logs[0] if len(logs) == 1 else ChangeLog.concat(logs)
        keep, words, lengths, rids = genesis.fold(np.zeros(0, np.uint32))
        del keep
        if words.shape[0] == 0:
            self._genesis = genesis
            return None
        self.replica = Replica(
            KeySet(words=words, lengths=lengths, rids=rids),
            backend=self.backend,
            backend_opts=self.backend_opts,
            shed_delete_frac=self.shed_delete_frac,
            applied_lsn=genesis.next_lsn - 1,
        )
        self._genesis = None
        self.n_batches_applied += len(pending)
        self.n_rebuilds += 1
        return {"bring_up": True, "n_keys": words.shape[0]}

    # ----------------------------------------------------------- catch-up
    def _bootstrap(self, frame: CheckpointFrame) -> None:
        """Restore the checkpoint chain; resume tailing at its watermark.

        The restored state is the primary's keyset + *working* metadata at
        ``base_lsn`` plus the shed-volume counter carried in the frame's
        ``log_state`` — constructing the replica from them reproduces,
        byte for byte, the state a never-lagged replica holds at that
        watermark.  The shed *policy* is the replica's own configuration
        (by default ``None``): shed decisions arrive as logged control
        frames, so a bootstrapped consumer and a tailing one adopt them
        at the same watermarks instead of re-deriving them locally.  The
        checkpointed snapshot epoch is resumed, so the bootstrapped
        replica's epoch history continues the primary's numbering.
        """
        from repro.ckpt.checkpoint import restore_checkpoint

        state, _stats = restore_checkpoint(
            frame.ckpt_dir, frame.step, _state_like()
        )
        keyset = KeySet(
            words=np.asarray(state["keyset"]["words"], np.uint32),
            lengths=np.asarray(state["keyset"]["lengths"], np.int32),
            rids=np.asarray(state["keyset"]["rids"], np.uint32),
        )
        meta = DSMeta(
            dbitmap=np.asarray(state["meta"]["dbitmap"], np.uint32),
            varbitmap=np.asarray(state["meta"]["varbitmap"], np.uint32),
            refkey=np.asarray(state["meta"]["refkey"], np.uint32),
            n_words=int(state["meta"]["n_words"]),
        )
        ls = frame.log_state
        self.replica = Replica(
            keyset,
            meta=meta,
            backend=self.backend,
            backend_opts=self.backend_opts,
            shed_delete_frac=self.shed_delete_frac,
            applied_lsn=frame.base_lsn - 1,
            deletes_since_shed=ls.deletes_since_shed,
            snapshot_epoch=int(_stats["meta"].get("snapshot_epoch", 0)),
        )
        self._genesis = None
        self.n_catchups += 1

    def resync(self) -> bool:
        """Advance the cursor to the next visible checkpoint frame.

        The degradation-ladder escape hatch: when polling is stuck on a
        position that keeps failing (persistent corruption, or a gap the
        reorder window could not bridge because the frame was dropped
        outright), the LSNs parked between the cursor and the next
        checkpoint frame are unrecoverable from the wire — but the
        checkpoint state covers them.  Scan forward from the cursor,
        skipping undecodable frames, and park ON the first checkpoint
        frame found; the next ``poll`` then either bootstraps from it
        (watermark behind its ``base_lsn``) or skips it as stale and
        resumes tailing, both byte-identical paths.  Any held-back
        reordered batches are discarded (the checkpoint supersedes or
        re-covers them).  Returns ``False`` when no checkpoint frame is
        visible yet — the caller should back off and retry after the
        primary's next checkpoint lands.
        """
        pos = max(self.pos, self.transport.first_pos())
        while pos < self.transport.end():
            try:
                raw = self.transport.read(pos)
            except FrameTruncated:
                pos = max(pos + 1, self.transport.first_pos())
                continue
            if raw is None:
                pos += 1  # delayed visibility: scan past, it may firm up
                continue
            try:
                frame = decode_frame(raw)
            except (FrameCorrupt, FrameSchemaError):
                pos += 1
                continue
            if isinstance(frame, CheckpointFrame):
                self.pos = pos
                self._held.clear()
                self.n_resyncs += 1
                return True
            pos += 1
        return False

    @property
    def stats(self) -> dict:
        """Consumer-side counters (applies, duplicates, catch-ups, lag,
        fault-path health: rejected frames, reorder heals, resyncs)."""
        return {
            "applied_lsn": self.applied_lsn,
            "pos": self.pos,
            "lag_frames": self.lag_frames(),
            "n_polls": self.n_polls,
            "n_batches_applied": self.n_batches_applied,
            "n_rebuilds": self.n_rebuilds,
            "n_duplicates": self.n_duplicates,
            "n_catchups": self.n_catchups,
            "n_truncation_jumps": self.n_truncation_jumps,
            "n_shed_adoptions": self.n_shed_adoptions,
            "n_frames_rejected": self.n_frames_rejected,
            "n_reorder_heals": self.n_reorder_heals,
            "n_resyncs": self.n_resyncs,
            "held_batches": len(self._held),
        }
