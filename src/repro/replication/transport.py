"""Pluggable stream transports: ordered frame logs a primary appends to.

A transport is the wire of the async replication layer
(`repro.replication.stream`): an **append-only ordered sequence of opaque
byte frames** with explicit positions.  Publishers append; subscribers poll
by position — there is no push, no connection state, and no subscriber
registration, so a replica can detach for hours and resume from its last
position (or discover it has been truncated past and must catch up from a
checkpoint frame).

Two realizations ship:

* :class:`QueueTransport` — an in-memory list.  The unit-test and
  single-process transport; also the reference semantics the protocol
  tests run against.
* :class:`DirectoryTransport` — one file per frame in a spool directory,
  committed with the same atomic-rename protocol the checkpoint layer
  uses.  A reader never sees a partial frame; separate processes (or a
  shared filesystem) can tail the same stream.

Retention: ``truncate_before(pos)`` drops frames below ``pos`` — the
primary's bounded-lag backpressure calls it after publishing a checkpoint
frame, which is what forces laggards onto the catch-up path.  Positions
are **never reused**: after truncation ``first_pos`` advances but ``end``
keeps counting, so a subscriber's cursor comparison stays meaningful.
"""

from __future__ import annotations

import abc
import os
from pathlib import Path

__all__ = ["Transport", "QueueTransport", "DirectoryTransport", "FrameTruncated"]


class Transport(abc.ABC):
    """Append-only ordered frame log with explicit positions.

    Positions are dense integers assigned at publish time, starting at 0.
    ``read`` returns ``None`` past the end (nothing published yet) and
    raises :class:`FrameTruncated` below ``first_pos`` (retention dropped
    the frame) — the two conditions a poller must distinguish: the first
    means *wait*, the second means *catch up from a checkpoint*.
    """

    @abc.abstractmethod
    def publish(self, frame: bytes) -> int:
        """Append one frame; returns the position it was assigned."""

    @abc.abstractmethod
    def read(self, pos: int) -> bytes | None:
        """The frame at ``pos``; ``None`` if not yet published.

        Raises :class:`FrameTruncated` if ``pos`` fell below
        ``first_pos`` (dropped by retention).
        """

    @abc.abstractmethod
    def first_pos(self) -> int:
        """Position of the oldest retained frame (== ``end`` when empty)."""

    @abc.abstractmethod
    def end(self) -> int:
        """One past the newest published position (0 when never written)."""

    @abc.abstractmethod
    def truncate_before(self, pos: int) -> int:
        """Drop retained frames with position < ``pos``; returns #dropped."""

    def __len__(self) -> int:
        return self.end() - self.first_pos()


class FrameTruncated(LookupError):
    """Requested position was dropped by retention — catch up required."""


class QueueTransport(Transport):
    """In-memory transport: a list plus a base offset.

    Single-process only (tests, benchmarks, in-process standbys).  Frames
    are kept as-is; truncation pops from the front and advances the base
    so positions stay stable.
    """

    def __init__(self) -> None:
        self._frames: list[bytes] = []
        self._base = 0

    def publish(self, frame: bytes) -> int:
        """Append one frame; returns its position."""
        self._frames.append(bytes(frame))
        return self._base + len(self._frames) - 1

    def read(self, pos: int) -> bytes | None:
        """The frame at ``pos``, ``None`` past the end."""
        if pos < self._base:
            raise FrameTruncated(f"frame {pos} truncated (first={self._base})")
        i = pos - self._base
        return self._frames[i] if i < len(self._frames) else None

    def first_pos(self) -> int:
        """Oldest retained position."""
        return self._base

    def end(self) -> int:
        """One past the newest position."""
        return self._base + len(self._frames)

    def truncate_before(self, pos: int) -> int:
        """Drop frames below ``pos``; returns how many were dropped."""
        drop = max(0, min(pos, self.end()) - self._base)
        del self._frames[:drop]
        self._base += drop
        return drop


class DirectoryTransport(Transport):
    """One file per frame in a spool directory (atomic-rename commit).

    Frame ``i`` lives at ``<dir>/frame_<i:010d>.bin``; a publisher writes
    to a dot-prefixed temp name and renames, so concurrent readers never
    observe a partial frame (the same commit protocol as the checkpoint
    layer).  ``end`` is recovered by scanning, which also makes the
    transport restartable: a new publisher process resumes numbering from
    what is on disk.
    """

    def __init__(self, root: str | os.PathLike) -> None:
        self.root = Path(root)
        self.root.mkdir(parents=True, exist_ok=True)
        # single-writer end counter: publish() is O(1) after the first call
        self._next: int | None = None
        # reader-side cursors: positions are dense, so end/first advance by
        # forward existence probes (amortized O(1) per call) instead of a
        # full directory scan — stays correct under a concurrent writer
        # (end grows) and concurrent truncation (first grows)
        self._end_cache: int | None = None
        self._first_cache: int | None = None

    def _path(self, pos: int) -> Path:
        return self.root / f"frame_{pos:010d}.bin"

    def _positions(self) -> list[int]:
        return sorted(
            int(p.name[6:-4])
            for p in self.root.iterdir()
            if p.name.startswith("frame_") and p.name.endswith(".bin")
        )

    def publish(self, frame: bytes) -> int:
        """Append one frame (write temp file, fsync, atomic rename).

        After the rename the *directory* is fsynced too (best effort):
        the file's data being durable is not enough — the rename itself
        lives in the directory, and without the directory fsync a crash
        can forget a frame a reader already observed as committed.
        """
        pos = self.end() if self._next is None else self._next
        tmp = self.root / f".tmp_frame_{pos:010d}.bin"
        with open(tmp, "wb") as f:
            f.write(frame)
            f.flush()
            os.fsync(f.fileno())
        tmp.rename(self._path(pos))
        self._fsync_dir()
        self._next = pos + 1
        return pos

    def _fsync_dir(self) -> None:
        # best effort: directories can't be fsynced on every platform
        # (and O_RDONLY-on-dir is refused on some); durability of the
        # rename is a hardening, not a protocol requirement
        try:
            fd = os.open(self.root, os.O_RDONLY)
        except OSError:
            return
        try:
            os.fsync(fd)
        except OSError:
            pass
        finally:
            os.close(fd)

    def read(self, pos: int) -> bytes | None:
        """The frame at ``pos``, ``None`` if not yet published.

        Reads the file first and classifies a miss afterwards, so a
        concurrent truncation between the two steps still surfaces as
        ``FrameTruncated`` (catch-up), never as a raw filesystem error.
        """
        try:
            return self._path(pos).read_bytes()
        except FileNotFoundError:
            if pos < self.first_pos():
                raise FrameTruncated(f"frame {pos} truncated") from None
            return None

    def first_pos(self) -> int:
        """Oldest retained position (== ``end`` when the spool is empty)."""
        end = self.end()
        if self._first_cache is None:
            ps = self._positions()
            self._first_cache = ps[0] if ps else end
        while (
            self._first_cache < end
            and not self._path(self._first_cache).exists()
        ):
            self._first_cache += 1  # truncation passed the cursor
        return min(self._first_cache, end)

    def end(self) -> int:
        """One past the newest published position."""
        if self._end_cache is None:
            ps = self._positions()
            self._end_cache = ps[-1] + 1 if ps else self._read_end_marker()
        while self._path(self._end_cache).exists():
            self._end_cache += 1  # a concurrent writer appended
        return self._end_cache

    def _read_end_marker(self) -> int:
        # retention may empty the spool; END records where numbering resumes
        marker = self.root / "END"
        return int(marker.read_text()) if marker.exists() else 0

    def truncate_before(self, pos: int) -> int:
        """Unlink frames below ``pos``; returns how many were dropped."""
        dropped = 0
        end = self.end()
        for i in self._positions():
            if i < pos:
                self._path(i).unlink()
                dropped += 1
        if dropped:
            # END records where numbering resumes if retention emptied the
            # spool; a no-op truncation leaves the marker alone (nothing
            # moved, and rewriting it would churn the spool for no reason)
            (self.root / "END").write_text(str(end))
            self._fsync_dir()
        return dropped
