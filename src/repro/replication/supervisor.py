"""Retry/backoff supervision for a stream replica: the degradation ladder.

``StreamReplica.poll`` is deliberately *mechanism, not policy*: on a
damaged or undecodable frame it applies the drained good prefix, parks the
cursor on the offending frame, and raises a typed error.  This module is
the policy half — :class:`ReplicaSupervisor` wraps ``poll`` in a bounded
retry loop that walks the **degradation ladder**:

1. **re-read** — a :class:`~repro.replication.wire.FrameCorrupt` is
   transient wire damage by definition (the stored frame may be fine), so
   the first retry is immediate: just read the position again.
2. **backoff + retry** — repeated failures back off exponentially
   (``base_delay_s`` · ``factor``^k, capped at ``max_delay_s``, scaled by
   the ``jitter`` hook), with an independent retry budget per failure
   class (corrupt / schema / gap).
3. **resync** — once a class's budget is spent the wire at this position
   is presumed unrecoverable; ``StreamReplica.resync()`` scans forward to
   the next visible checkpoint frame, whose state covers the lost LSNs,
   and the next poll bootstraps from it.
4. **degraded** — no checkpoint visible yet: report ``degraded`` and
   return (the caller keeps pumping; the primary's next checkpoint is the
   cure).  Time spent degraded is metered into ``time_degraded``.
5. **quarantined** — the checkpoint path itself keeps failing at the same
   position (``quarantine_after`` consecutive stuck pumps): stop touching
   the wire and surface ``state="quarantined"`` in :meth:`stats` instead
   of crashing.  ``reset()`` re-arms after operator intervention.

The clock and sleep are injectable, so tests drive the whole ladder —
including multi-second backoff schedules — in microseconds.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable

from .stream import FrameCorrupt, FrameSchemaError, LsnGapError, StreamReplica

__all__ = ["SupervisorPolicy", "ReplicaSupervisor"]


def _default_retries() -> dict:
    # schema errors never heal by re-reading (the payload is intact but
    # malformed) — they get the smallest budget; corruption is transient
    # by construction; a gap may close when delayed frames firm up
    return {"corrupt": 3, "schema": 1, "gap": 3}


@dataclass(frozen=True)
class SupervisorPolicy:
    """Tunables for the degradation ladder.

    ``retries`` is the per-failure-class budget *within one pump*;
    ``quarantine_after`` counts consecutive pumps that ended unrecovered
    at the same stream position even though the checkpoint path was
    available; ``jitter`` multiplies each backoff delay (default: no
    jitter — pass e.g. ``lambda: 0.5 + rng.random()`` to decorrelate a
    fleet of replicas hammering a recovering transport).
    """

    base_delay_s: float = 0.05
    max_delay_s: float = 2.0
    factor: float = 2.0
    retries: dict = field(default_factory=_default_retries)
    quarantine_after: int = 3
    max_resyncs_per_pump: int = 4
    jitter: Callable[[], float] | None = None


class ReplicaSupervisor:
    """Drives a :class:`StreamReplica` through faults without crashing.

    Parameters
    ----------
    replica: the stream consumer to supervise (anything with ``poll`` /
             ``resync`` / ``pos`` / ``stats`` quacks well enough — tests
             use stubs).
    policy:  the ladder tunables (:class:`SupervisorPolicy`).
    clock:   monotonic time source (injectable for tests).
    sleep:   how to wait out a backoff delay (injectable for tests).

    Health states: ``healthy`` → ``degraded`` (a pump needed the ladder)
    → ``quarantined`` (the ladder kept failing; pumping is suspended
    until :meth:`reset`).  Counters for every rung live in
    :meth:`stats`.
    """

    def __init__(
        self,
        replica: StreamReplica,
        policy: SupervisorPolicy | None = None,
        clock: Callable[[], float] = time.monotonic,
        sleep: Callable[[float], None] = time.sleep,
    ) -> None:
        self.replica = replica
        self.policy = policy if policy is not None else SupervisorPolicy()
        self.clock = clock
        self.sleep = sleep
        self.state = "healthy"
        self.n_pumps = 0
        self.n_faulty_pumps = 0
        self.n_retries: dict[str, int] = {}
        self.n_backoffs = 0
        self.n_resyncs = 0
        self.n_quarantines = 0
        self.time_degraded = 0.0
        self._degraded_since: float | None = None
        self._fail_streak = 0
        self._last_fail_pos: int | None = None

    # ------------------------------------------------------------- ladder
    @staticmethod
    def _classify(err: Exception) -> str:
        """Map a poll failure to its retry-budget class."""
        if isinstance(err, FrameCorrupt):
            return "corrupt"
        if isinstance(err, FrameSchemaError):
            return "schema"
        if isinstance(err, LsnGapError):
            return "gap"
        return "gap"  # unknown stream errors get the gap treatment

    def _delay(self, attempt: int) -> float:
        """Backoff before retry ``attempt`` (1-based); first is free."""
        if attempt <= 1:
            return 0.0  # the immediate re-read rung
        p = self.policy
        d = min(p.max_delay_s, p.base_delay_s * p.factor ** (attempt - 2))
        return d * (p.jitter() if p.jitter is not None else 1.0)

    def _enter_degraded(self) -> None:
        if self.state == "healthy":
            self.state = "degraded"
            self._degraded_since = self.clock()

    def _leave_degraded(self) -> None:
        if self.state == "degraded":
            if self._degraded_since is not None:
                self.time_degraded += self.clock() - self._degraded_since
                self._degraded_since = None
            self.state = "healthy"

    # --------------------------------------------------------------- pump
    def pump(self, max_frames: int | None = None) -> dict:
        """One supervised poll: drain what the wire allows, never raise.

        Returns the poll stats on success (plus ``state``/``recovered``);
        on an unrecovered fault, a dict describing where the ladder
        stopped (``error_class``, ``pos``, ``awaiting_checkpoint``).  A
        quarantined supervisor short-circuits without touching the wire.
        """
        self.n_pumps += 1
        if self.state == "quarantined":
            return {"state": "quarantined", "pumped": False,
                    "recovered": False}
        attempts: dict[str, int] = {}
        resyncs = 0
        faulted = False
        checkpoint_seen = False
        while True:
            try:
                out = self.replica.poll(max_frames=max_frames)
            except (FrameCorrupt, FrameSchemaError, LsnGapError) as err:
                faulted = True
                cls = self._classify(err)
                self.n_retries[cls] = self.n_retries.get(cls, 0) + 1
                self._enter_degraded()
                attempts[cls] = attempts.get(cls, 0) + 1
                budget = int(self.policy.retries.get(cls, 0))
                if attempts[cls] <= budget:
                    d = self._delay(attempts[cls])
                    if d > 0:
                        self.n_backoffs += 1
                        self.sleep(d)
                    continue
                # budget spent: climb to the checkpoint rung
                if (
                    resyncs < self.policy.max_resyncs_per_pump
                    and self.replica.resync()
                ):
                    resyncs += 1
                    checkpoint_seen = True
                    self.n_resyncs += 1
                    attempts = {}  # fresh position, fresh budgets
                    continue
                return self._unrecovered(err, cls, checkpoint_seen)
            # poll came back clean
            if faulted:
                self.n_faulty_pumps += 1
            self._leave_degraded()
            self._fail_streak = 0
            self._last_fail_pos = None
            out["state"] = self.state
            out["recovered"] = faulted
            out["resyncs"] = resyncs
            return out

    def _unrecovered(
        self, err: Exception, cls: str, checkpoint_seen: bool
    ) -> dict:
        """Close out a pump the ladder could not clear."""
        self.n_faulty_pumps += 1
        pos = int(getattr(self.replica, "pos", -1))
        if checkpoint_seen:
            # the cure was available and did not take: count the streak
            if self._last_fail_pos == pos:
                self._fail_streak += 1
            else:
                self._fail_streak = 1
            self._last_fail_pos = pos
            if self._fail_streak >= self.policy.quarantine_after:
                self._leave_degraded()
                self.state = "quarantined"
                self.n_quarantines += 1
        # no checkpoint visible: stay degraded and wait for the primary's
        # next checkpoint — deliberately NOT a streak (nothing to retry
        # against), so a laggard cannot quarantine itself while healthy
        # frames are simply still in flight
        return {
            "state": self.state,
            "recovered": False,
            "error_class": cls,
            "error": repr(err),
            "pos": pos,
            "awaiting_checkpoint": not checkpoint_seen,
        }

    # -------------------------------------------------------------- admin
    def reset(self) -> None:
        """Operator re-arm: leave quarantine/degraded, clear the streak.

        Counters are preserved (they are the incident record); only the
        gate state is cleared, so the next :meth:`pump` touches the wire
        again.
        """
        self._leave_degraded()
        self.state = "healthy"
        self._degraded_since = None
        self._fail_streak = 0
        self._last_fail_pos = None

    def stats(self) -> dict:
        """The full health picture: ladder counters + the replica's own
        consumer counters (watermark, rejected frames, resyncs, lag)."""
        out = {
            "state": self.state,
            "n_pumps": self.n_pumps,
            "n_faulty_pumps": self.n_faulty_pumps,
            "n_retries": dict(self.n_retries),
            "n_backoffs": self.n_backoffs,
            "n_resyncs": self.n_resyncs,
            "n_quarantines": self.n_quarantines,
            "time_degraded": self.time_degraded,
            "fail_streak": self._fail_streak,
        }
        rep_stats = getattr(self.replica, "stats", None)
        if isinstance(rep_stats, dict):
            out["replica"] = dict(rep_stats)
        return out
