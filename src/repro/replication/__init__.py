"""Replication layer: change logs, replicas, and the async stream.

``ChangeLog`` is the record-level insert/delete log (LSN-stamped columnar
arrays, npz-serializable — the checkpoint layer stores one next to a base
step for delta checkpoints); ``Replica`` consumes log batches and keeps its
index current through ``ReconstructionPipeline.run_incremental``.

The async stream (``repro.replication.stream``) ships log batches from a
``StreamPrimary`` to N ``StreamReplica`` consumers over a pluggable
``transport`` (in-memory queue or spool directory), with LSN-watermark
idempotency, bounded-lag backpressure, and checkpoint-chain catch-up.
See docs/replication.md for the protocol.
"""

from .log import OP_DELETE, OP_INSERT, ChangeLog  # noqa: F401
from .replica import Replica  # noqa: F401
from .stream import (  # noqa: F401
    BackpressureError,
    BatchFrame,
    CheckpointFrame,
    LsnGapError,
    ShedFrame,
    StreamError,
    StreamPrimary,
    StreamReplica,
    decode_frame,
    encode_frame,
)
from .transport import (  # noqa: F401
    DirectoryTransport,
    FrameTruncated,
    QueueTransport,
    Transport,
)

__all__ = [
    "ChangeLog",
    "Replica",
    "OP_INSERT",
    "OP_DELETE",
    "Transport",
    "QueueTransport",
    "DirectoryTransport",
    "FrameTruncated",
    "StreamPrimary",
    "StreamReplica",
    "BatchFrame",
    "CheckpointFrame",
    "ShedFrame",
    "encode_frame",
    "decode_frame",
    "StreamError",
    "LsnGapError",
    "BackpressureError",
]
