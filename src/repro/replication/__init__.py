"""Replication layer: change logs, replicas, and the async stream.

``ChangeLog`` is the record-level insert/delete log (LSN-stamped columnar
arrays, npz-serializable — the checkpoint layer stores one next to a base
step for delta checkpoints); ``Replica`` consumes log batches and keeps its
index current through ``ReconstructionPipeline.run_incremental``.

The async stream (``repro.replication.stream``) ships log batches from a
``StreamPrimary`` to N ``StreamReplica`` consumers over a pluggable
``transport`` (in-memory queue or spool directory), with LSN-watermark
idempotency, bounded-lag backpressure, and checkpoint-chain catch-up.

The fault layer hardens the stream against an adversarial wire: every
frame carries a CRC32C integrity header (``repro.replication.wire``),
``FaultyTransport`` injects seeded delivery faults for testing
(``repro.replication.chaos``), and ``ReplicaSupervisor`` walks the
retry/backoff/resync/quarantine degradation ladder around ``poll``
(``repro.replication.supervisor``).  See docs/replication.md for the
protocol and the fault model.
"""

from .chaos import ChaosPlan, FaultyTransport  # noqa: F401
from .log import OP_DELETE, OP_INSERT, ChangeLog  # noqa: F401
from .replica import Replica  # noqa: F401
from .stream import (  # noqa: F401
    BackpressureError,
    BatchFrame,
    CheckpointFrame,
    LsnGapError,
    ShedFrame,
    StreamError,
    StreamPrimary,
    StreamReplica,
    decode_frame,
    encode_frame,
    peek_header,
)
from .supervisor import ReplicaSupervisor, SupervisorPolicy  # noqa: F401
from .transport import (  # noqa: F401
    DirectoryTransport,
    FrameTruncated,
    QueueTransport,
    Transport,
)
from .wire import (  # noqa: F401
    FrameCorrupt,
    FrameHeader,
    FrameSchemaError,
    WireError,
)

__all__ = [
    "ChangeLog",
    "Replica",
    "OP_INSERT",
    "OP_DELETE",
    "Transport",
    "QueueTransport",
    "DirectoryTransport",
    "FrameTruncated",
    "StreamPrimary",
    "StreamReplica",
    "BatchFrame",
    "CheckpointFrame",
    "ShedFrame",
    "encode_frame",
    "decode_frame",
    "peek_header",
    "StreamError",
    "LsnGapError",
    "BackpressureError",
    "WireError",
    "FrameCorrupt",
    "FrameSchemaError",
    "FrameHeader",
    "ChaosPlan",
    "FaultyTransport",
    "ReplicaSupervisor",
    "SupervisorPolicy",
]
