"""Replication/delta layer: change logs + incremental replica bring-up.

``ChangeLog`` is the record-level insert/delete log (LSN-stamped columnar
arrays, npz-serializable — the checkpoint layer stores one next to a base
step for delta checkpoints); ``Replica`` consumes log batches and keeps its
index current through ``ReconstructionPipeline.run_incremental``.
"""

from .log import OP_DELETE, OP_INSERT, ChangeLog  # noqa: F401
from .replica import Replica  # noqa: F401

__all__ = ["ChangeLog", "Replica", "OP_INSERT", "OP_DELETE"]
