"""A replica that consumes change-log batches and rebuilds incrementally.

The paper's replication premise: the wire carries the table (here: the base
keyset once, then ``ChangeLog`` batches) and the DS-metadata — never an
index image.  ``Replica`` keeps the reconstructed index current by folding
each log batch through ``ReconstructionPipeline.run_incremental``: delete
entries become a keep-mask over the base rows, surviving inserts become the
delta keyset, and only the delta is extracted and sorted before the backend
``merge_sorted`` splices it into the standing run.  When a batch's keys add
new distinction bits the pipeline transparently falls back to the full
rebuild (same result, full cost) — the replica's answer is byte-identical
either way.

DS-metadata upkeep is the §4.3 insert rule, vectorized: every inserted key
finds its neighbors (A, B) in the standing sorted order with one batched
rank search, and D(A,K) / D(K,B) are OR-scattered into the D-bitmap in one
shot.  Setting both is exactly the paper's "set max(D(A,K), D(K,B))"
because the min equals D(A,B), which Lemma 1 guarantees is already set.
Delta-internal adjacency is covered by the delta's own D-bitmap.
"""

from __future__ import annotations

from dataclasses import replace

import jax.numpy as jnp
import numpy as np

from repro.core.btree import BTreeConfig
from repro.core.dbits import (
    NO_DBIT,
    compute_dbitmap,
    dbit_position_pairwise,
    positions_to_bitmap,
    rank_in_sorted_keyed,
)
from repro.core.keyformat import KeySet  # noqa: F401  (public API type)
from repro.core.metadata import DSMeta, shed_or_pin
from repro.core.pipeline import ReconstructionPipeline, ReconstructionResult
from repro.core.snapshot import SnapshotCell

from .log import ChangeLog

__all__ = ["Replica"]


class Replica:
    """One replicated index: base bring-up + incremental log consumption.

    Parameters
    ----------
    keyset:             the base table rows (bring-up reconstructs from it).
    meta:               DS-metadata to extract under; ``None`` derives it
                        from the keys.  A catch-up bootstrap passes the
                        checkpointed *working* metadata here, which is what
                        makes the bootstrapped state byte-identical to a
                        never-lagged replica's (see ``stream.StreamReplica``).
    backend:            execution backend name for all rebuilds.
    config:             B-tree geometry.
    backend_opts:       forwarded to the backend constructor.
    shed_delete_frac:   bitmap shed threshold (``None`` = always pin).
    applied_lsn:        LSN watermark this base state is current through
                        (``-1`` = nothing applied; a bootstrap resumes at
                        the checkpoint's watermark).
    deletes_since_shed: resume value for the shed-policy volume counter.
    snapshot_epoch:     epoch the bring-up snapshot is published at (a
                        checkpoint bootstrap resumes the primary's
                        numbering; the default starts at 0).
    """

    def __init__(
        self,
        keyset: KeySet,
        meta: DSMeta | None = None,
        backend: str = "jnp",
        config: BTreeConfig = BTreeConfig(),
        backend_opts: dict | None = None,
        shed_delete_frac: float | None = None,
        applied_lsn: int = -1,
        deletes_since_shed: int = 0,
        snapshot_epoch: int = 0,
    ) -> None:
        self.pipeline = ReconstructionPipeline(
            backend=backend, config=config, backend_opts=backend_opts
        )
        self.keyset = keyset
        # the versioned read path: every rebuild publishes the next epoch
        # here and every search pins the current one (double buffering)
        self.snapshots = SnapshotCell(start_epoch=int(snapshot_epoch) - 1)
        self.result: ReconstructionResult = self.pipeline.run(
            keyset, meta=meta, watermark=applied_lsn if applied_lsn >= 0 else None,
            publish_to=self.snapshots,
        )
        # the working metadata mirrors the *extraction* bitmap (plus insert
        # bits as batches arrive): keeping it pinned to what comp_sorted was
        # extracted under is what lets consecutive batches stay incremental
        self._meta = replace(
            self.result.meta,
            dbitmap=np.array(self.result.extract_bitmap, np.uint32, copy=True),
        )
        # bitmap shed policy: pinning keeps rebuilds incremental but lets
        # delete-stale distinction bits accumulate (wider compressed keys).
        # When the delete volume since the bits were last re-derived crosses
        # ``shed_delete_frac`` of the index size, adopt the refreshed
        # (shed) bitmap instead — the next batch pays one full resort under
        # the narrower projection, then pinning resumes.  ``None`` never
        # sheds (the PR-2 behavior).
        self.shed_delete_frac = shed_delete_frac
        self._deletes_since_shed = int(deletes_since_shed)
        self.applied_lsn = int(applied_lsn)
        self.n_applied_batches = 0

    @property
    def tree(self):
        """The standing partial-key B+tree (current reconstruction)."""
        return self.result.tree

    @property
    def meta(self) -> DSMeta:
        """The working DS-metadata (pinned/shed per the bitmap policy)."""
        return self._meta

    @property
    def deletes_since_shed(self) -> int:
        """Delete volume since the D-bitmap was last re-derived (shed
        policy bookkeeping; snapshotted into checkpoint frames)."""
        return self._deletes_since_shed

    @property
    def stats(self) -> dict:
        """Health snapshot of the standing index: watermark, size, shed
        bookkeeping, snapshot epoch — the inner-replica half of the
        counters a stream consumer (or its supervisor) surfaces."""
        return {
            "applied_lsn": self.applied_lsn,
            "n_applied_batches": self.n_applied_batches,
            "n_keys": self.keyset.n,
            "watermark": self.result.watermark,
            "deletes_since_shed": self._deletes_since_shed,
            "shed_delete_frac": self.shed_delete_frac,
            "snapshot_epoch": self.snapshots.epoch,
        }

    # ------------------------------------------------------------- lookup
    def search_batch(
        self, query_words: np.ndarray
    ) -> tuple[np.ndarray, np.ndarray]:
        """Batched point lookup: (q, W) keys -> ((q,) found, (q,) rid).

        Pins the current snapshot epoch and probes it with the backend's
        plan-cached ``lookup`` op — a query stream interleaved with
        ``apply`` keeps answering from the pre-rebuild epoch until the new
        one is published, never a torn mixture.  Miss lanes carry
        ``repro.core.btree.NOT_FOUND_RID``.
        """
        q = jnp.asarray(
            np.asarray(query_words, np.uint32).reshape(-1, self.keyset.n_words)
        )
        with self.snapshots.pin() as snap:
            found, rid = self.pipeline.backend.lookup(snap.tree, q)
        return np.asarray(found, bool), np.asarray(rid, np.uint32)

    def search(self, query_words: np.ndarray) -> tuple[bool, int]:
        """Point lookup through the pinned snapshot: ``(found, rid)``.

        A thin wrapper over :meth:`search_batch` (one implementation for
        scalar and batched lookups).
        """
        found, rid = self.search_batch(
            np.asarray(query_words, np.uint32)[None, :]
        )
        return bool(found[0]), int(rid[0])

    # -------------------------------------------------------------- apply
    def apply_many(self, logs: "list[ChangeLog]") -> dict:
        """Fold several LSN-contiguous batches through ONE rebuild.

        The watermark-triggered form of ``apply``: a consumer that drained
        multiple pending stream batches stitches them (``ChangeLog.concat``
        checks contiguity) and pays one fold + one incremental
        reconstruction for the whole span, instead of one rebuild per
        batch.  Returns the same stats dict as ``apply``.
        """
        return self.apply(ChangeLog.concat(logs))

    def apply(self, log: ChangeLog) -> dict:
        """Fold one log batch into the standing index.

        Deletes become a keep-mask over the base rows, surviving inserts
        the delta keyset; DS-metadata advances by the vectorized §4.3
        insert rule *before* the rebuild so the extraction plan covers the
        batch.  The rebuild runs ``ReconstructionPipeline.run_incremental``
        — byte-identical to a full ``run`` over the folded keyset (empty
        batches short-circuit through the pipeline's no-op fast path and
        only advance the watermark).  Returns apply stats: which path ran
        (``incremental`` / ``fallback`` / ``noop``), churn counts, shed
        policy state, the new ``applied_lsn``, and per-stage timings.
        """
        if log.n_words != self.keyset.n_words:
            raise ValueError(
                f"log key width {log.n_words} != index width {self.keyset.n_words}"
            )
        keep_rows, delta = log.fold_keyset(self.keyset)
        n_delta = 0 if delta is None else delta.n
        n_deleted = 0 if keep_rows is None else int(self.keyset.n - keep_rows.sum())
        meta = self._insert_rule(delta.words) if n_delta else self._meta

        res, folded = self.pipeline.run_incremental(
            self.result, self.keyset, delta, keep_rows=keep_rows, meta=meta,
            watermark=log.next_lsn - 1, publish_to=self.snapshots,
        )
        self.keyset, self.result = folded, res
        self._meta, shed, self._deletes_since_shed = shed_or_pin(
            res.meta, res.extract_bitmap,
            self._deletes_since_shed + n_deleted,
            self.shed_delete_frac, folded.n,
        )
        self.applied_lsn = log.next_lsn - 1
        self.n_applied_batches += 1
        return {
            "incremental": bool(res.stats.get("incremental")),
            "fallback": res.stats.get("incremental_fallback"),
            "noop": bool(res.stats.get("noop", False)),
            "n_delta": n_delta,
            "n_deleted": n_deleted,
            "n_keys": folded.n,
            "shed_bits": shed,
            "deletes_since_shed": self._deletes_since_shed,
            "applied_lsn": self.applied_lsn,
            "timings": dict(res.timings),
        }

    # ------------------------------------------------------- shed adoption
    def adopt_shed(self) -> bool:
        """Adopt the refreshed (shed) D-bitmap of the last rebuild *now*.

        The stream-driven form of the shed policy: instead of evaluating
        ``shed_delete_frac`` locally (whose per-rebuild cadence diverges
        between replicas that poll at different rates), a consumer adopts
        sheds exactly where the primary logged them — the shed control
        frame in the stream names the watermark, and this call flips the
        working metadata from the pinned extraction bitmap to the
        refreshed one, so the next rebuild pays the one full resort under
        the narrower projection just as the primary's did.  Returns
        whether the bitmap actually changed (idempotent on a replica that
        already shed locally).
        """
        refreshed = self.result.meta
        changed = not np.array_equal(
            np.asarray(self._meta.dbitmap, np.uint32),
            np.asarray(refreshed.dbitmap, np.uint32),
        )
        self._meta = refreshed
        self._deletes_since_shed = 0
        return changed

    # ---------------------------------------------------- metadata upkeep
    def _insert_rule(self, ins_words: np.ndarray) -> DSMeta:
        """§4.3 insert rule for a whole batch, no host loop."""
        meta = self._meta
        sf = self.result.tree.sorted_full  # standing sorted full keys
        n = int(sf.shape[0])
        k = jnp.asarray(ins_words, jnp.uint32)
        m = int(k.shape[0])
        zeros_s = jnp.zeros((n,), jnp.uint32)
        zeros_q = jnp.zeros((m,), jnp.uint32)
        # strict-key rank: row tie-break never fires with equal row ids
        rank = rank_in_sorted_keyed(sf, zeros_s, k, zeros_q)
        has_a = rank > 0
        has_b = rank < n
        a = sf[jnp.clip(rank - 1, 0, n - 1)]
        b = sf[jnp.clip(rank, 0, n - 1)]
        d_ak = jnp.where(has_a, dbit_position_pairwise(a, k), NO_DBIT)
        d_kb = jnp.where(has_b, dbit_position_pairwise(k, b), NO_DBIT)
        nw = meta.n_words
        bm = positions_to_bitmap(jnp.concatenate([d_ak, d_kb]), nw)
        # delta-internal adjacency (keys that end up next to each other)
        bm = bm | compute_dbitmap(k)
        dbitmap = np.asarray(bm, np.uint32) | meta.dbitmap
        var = meta.varbitmap | np.bitwise_or.reduce(
            np.asarray(ins_words, np.uint32) ^ meta.refkey[None, :], axis=0
        )
        return replace(meta, dbitmap=dbitmap, varbitmap=var)
