from . import ops, ref  # noqa: F401
from .ops import adjacent_dbits  # noqa: F401
