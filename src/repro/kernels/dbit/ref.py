"""Pure-jnp oracle: `repro.core.dbits.adjacent_dbit_positions`."""

from __future__ import annotations

import jax.numpy as jnp

from repro.core.dbits import adjacent_dbit_positions


def adjacent_dbits_ref(sorted_words: jnp.ndarray) -> jnp.ndarray:
    """(n, W) sorted keys -> (n-1,) int32 adjacent distinction bit positions."""
    return adjacent_dbit_positions(sorted_words)
