"""Pallas TPU kernel: adjacent-key distinction bit positions (paper §5.3).

After the compressed key sort, the bulk build needs D_i = D-bit(key_{i-1},
key_i) for every adjacent pair — an O(n) scan that the paper folds into
reconstruction (Remark 1).  Kernel: XOR the key planes against the
1-shifted planes, locate the first differing word with an unrolled
running-mask pass over the (few) word planes, and take ``clz`` of that word
— all lane-parallel over a VMEM tile of keys.

Inputs arrive as two plane blocks (current and previous rows) so each grid
step is self-contained; ops.py builds the shifted copy once.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.dbits import NO_DBIT

DEFAULT_TILE = 1024


def _dbit_kernel(n_words: int, a_ref, b_ref, o_ref):
    """a_ref, b_ref: (W, T) planes (prev and current rows); o_ref: (1, T) int32."""
    a = a_ref[...]
    b = b_ref[...]
    t = a.shape[1]
    pos = jnp.full((t,), NO_DBIT, jnp.int32)
    found = jnp.zeros((t,), jnp.bool_)
    for w in range(n_words):
        x = a[w] ^ b[w]
        nz = x != 0
        take = nz & (~found)
        clz = jax.lax.clz(x.astype(jnp.uint32)).astype(jnp.int32)
        pos = jnp.where(take, jnp.int32(w * 32) + clz, pos)
        found = found | nz
    o_ref[...] = pos[None, :]


@partial(jax.jit, static_argnames=("tile", "interpret"))
def dbit_planes(
    prev_planes: jnp.ndarray,
    cur_planes: jnp.ndarray,
    tile: int = DEFAULT_TILE,
    interpret: bool = True,
) -> jnp.ndarray:
    """(W, n) x2 -> (n,) int32 distinction bit positions (NO_DBIT if equal)."""
    w, n = prev_planes.shape
    assert n % tile == 0
    grid = (n // tile,)
    out = pl.pallas_call(
        partial(_dbit_kernel, w),
        grid=grid,
        in_specs=[
            pl.BlockSpec((w, tile), lambda i: (0, i)),
            pl.BlockSpec((w, tile), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((1, tile), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, n), jnp.int32),
        interpret=interpret,
    )(prev_planes, cur_planes)
    return out[0]
