"""Jit'd public wrapper for the adjacent-dbit kernel."""

from __future__ import annotations

import jax.numpy as jnp

from repro.core.plancache import pad_tail

from .kernel import DEFAULT_TILE, dbit_planes


def adjacent_dbits(
    sorted_words: jnp.ndarray, tile: int = DEFAULT_TILE, interpret: bool = True
) -> jnp.ndarray:
    """(n, W) sorted keys -> (n-1,) adjacent distinction bit positions.

    The tile pad rides ``plancache.pad_tail`` (cached zero constants, no
    per-call concatenate); pad columns are equal in both operands, so
    their positions are garbage that the ``[:m]`` slice strips.
    """
    n, w = sorted_words.shape
    planes = jnp.asarray(sorted_words, jnp.uint32).T  # (W, n)
    m = n - 1
    total = m + ((-m) % tile)
    prev = pad_tail(planes[:, : n - 1], total, 0, axis=1)
    cur = pad_tail(planes[:, 1:], total, 0, axis=1)
    out = dbit_planes(prev, cur, tile=tile, interpret=interpret)
    return out[:m]
