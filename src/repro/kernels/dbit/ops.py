"""Jit'd public wrapper for the adjacent-dbit kernel."""

from __future__ import annotations

import jax.numpy as jnp

from .kernel import DEFAULT_TILE, dbit_planes


def adjacent_dbits(
    sorted_words: jnp.ndarray, tile: int = DEFAULT_TILE, interpret: bool = True
) -> jnp.ndarray:
    """(n, W) sorted keys -> (n-1,) adjacent distinction bit positions."""
    n, w = sorted_words.shape
    planes = jnp.asarray(sorted_words, jnp.uint32).T  # (W, n)
    prev = planes[:, : n - 1]
    cur = planes[:, 1:]
    m = n - 1
    pad = (-m) % tile
    if pad:
        z = jnp.zeros((w, pad), jnp.uint32)
        prev = jnp.concatenate([prev, z], axis=1)
        cur = jnp.concatenate([cur, z], axis=1)
    out = dbit_planes(prev, cur, tile=tile, interpret=interpret)
    return out[:m]
