from . import ops, ref  # noqa: F401
from .ops import block_sort  # noqa: F401
