"""Pure-jnp oracle for the bitonic block sort: per-block ``lax.sort``."""

from __future__ import annotations

import jax.numpy as jnp

from repro.core.dbits import sort_words


def block_sort_ref(
    words: jnp.ndarray, rids: jnp.ndarray, block: int
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """(n, W) keys + (n,) rids -> each block of `block` rows sorted."""
    n, w = words.shape
    assert n % block == 0
    outs_w, outs_r = [], []
    for s in range(0, n, block):
        sw, sr = sort_words(words[s : s + block], rids[s : s + block])
        outs_w.append(sw)
        outs_r.append(sr)
    return jnp.concatenate(outs_w, axis=0), jnp.concatenate(outs_r, axis=0)
