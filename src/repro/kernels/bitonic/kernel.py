"""Pallas TPU kernel: in-VMEM bitonic block sort of multiword keys.

This is the VMEM analogue of the paper's ``basic_sort`` (Appendix A, step
3.1): the row-column sort keeps each base block inside the per-core L3
slice and quicksorts it.  Quicksort's data-dependent branches are hostile
to a vector unit, so the TPU-native block sort is a **bitonic network**:
O(log^2 T) compare-exchange substages, each a static permutation + select —
branch-free, fully lane-parallel, and entirely VMEM-resident.

Keys are (W, T) uint32 word planes plus a (1, T) payload plane (record id).
The comparator is the multiword lexicographic order (word 0 most
significant) — the same comparator the paper's sort uses, so compressing
keys shrinks ``W`` and with it the cost of *every* substage.

The partner exchange ``idx ^ j`` is a static permutation per substage; we
express it with `jnp.take` along the lane axis (interpret-validated; on
real TPU hardware Mosaic lowers power-of-two strided gathers to cheap
in-register shuffles for j >= 128-lane strides and VMEM swizzles below).
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

DEFAULT_BLOCK = 512


def _lex_cmp_planes(a, b, n_key_words: int):
    """a, b: (W, T) planes -> ((T,) a<b, (T,) a==b) lexicographic over key words."""
    less = jnp.zeros(a.shape[1], jnp.bool_)
    eq = jnp.ones(a.shape[1], jnp.bool_)
    for w in range(n_key_words):
        less = less | (eq & (a[w] < b[w]))
        eq = eq & (a[w] == b[w])
    return less, eq


def _bitonic_kernel(n_key_words: int, block: int, x_ref, o_ref):
    """x_ref/o_ref: (W+1, block) planes — key words + rid payload plane.

    Per substage, every lane decides *keep mine vs take partner's* from a
    lane-local comparison.  With ``want_le = (is_lo == ascending)``:
        keep = want_le ? (x <= p) : (x > p)   [ > as !(<=) with eq split ]
    Ties keep both lanes' own entries (no payload duplication).
    """
    x = x_ref[...]
    # iota must be materialized in-kernel (captured constants are rejected)
    idx = jax.lax.broadcasted_iota(jnp.int32, (block,), 0)
    log_t = int(np.log2(block))
    for stage in range(1, log_t + 1):
        k = 1 << stage
        for sub in range(stage - 1, -1, -1):
            j = 1 << sub
            partner = idx ^ j
            px = jnp.take(x, partner, axis=1)
            ascending = (idx & k) == 0
            is_lo = (idx & j) == 0
            lt, eq = _lex_cmp_planes(x, px, n_key_words)
            le = lt | eq
            want_le = is_lo == ascending
            keep = jnp.where(want_le, le, ~lt)
            x = jnp.where(keep[None, :], x, px)
    o_ref[...] = x


@partial(jax.jit, static_argnames=("n_key_words", "block", "interpret"))
def bitonic_block_sort_planes(
    planes: jnp.ndarray,
    n_key_words: int,
    block: int = DEFAULT_BLOCK,
    interpret: bool = True,
) -> jnp.ndarray:
    """Sort each block of ``block`` lanes independently.

    planes: (W+1, n) uint32 — key word planes then one rid plane; ``n`` a
    multiple of ``block``.  Returns same shape, each block sorted by the
    first ``n_key_words`` planes (stably w.r.t. nothing — ties broken by
    nothing; pad rid plane participates only as payload).
    """
    wp, n = planes.shape
    assert n % block == 0 and (block & (block - 1)) == 0, (n, block)
    grid = (n // block,)
    return pl.pallas_call(
        partial(_bitonic_kernel, n_key_words, block),
        grid=grid,
        in_specs=[pl.BlockSpec((wp, block), lambda i: (0, i))],
        out_specs=pl.BlockSpec((wp, block), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((wp, n), jnp.uint32),
        interpret=interpret,
    )(planes)
