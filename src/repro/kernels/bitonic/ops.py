"""Jit'd public wrapper for the bitonic block-sort kernel."""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core.plancache import pad_tail

from .kernel import DEFAULT_BLOCK, bitonic_block_sort_planes

# Pad sentinel sorts last (all-ones key); mirrors distsort's convention.
_SENTINEL = np.uint32(0xFFFFFFFF)


def block_sort(
    words: jnp.ndarray,
    rids: jnp.ndarray,
    block: int = DEFAULT_BLOCK,
    interpret: bool = True,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Sort each ``block`` of rows of (n, W) keys + (n,) rid payload.

    Rows are padded with all-ones sentinel keys up to a block multiple via
    ``plancache.pad_tail`` (a cached fill constant + one
    ``dynamic_update_slice`` — no per-call concatenate; the pad sorts to
    the tail of the final block and is stripped).  Returns the
    block-sorted (n, W) keys and (n,) rids — the paper's Appendix step 3.1;
    feed the runs to a merge (``lax.sort`` or the distsort exchange).
    """
    n, w = words.shape
    total = n + ((-n) % block)
    planes = jnp.concatenate(
        [jnp.asarray(words, jnp.uint32).T, jnp.asarray(rids, jnp.uint32)[None, :]], axis=0
    )
    planes = pad_tail(planes, total, _SENTINEL, axis=1)
    out = bitonic_block_sort_planes(planes, n_key_words=w, block=block, interpret=interpret)
    return out[:w, :n].T, out[w, :n]
