"""Jit'd public wrapper for the pext kernel (row-major in/out, padding)."""

from __future__ import annotations

import jax.numpy as jnp

from repro.core.compress import ExtractionPlan
from repro.core.plancache import pad_tail

from .kernel import DEFAULT_TILE, pext_planes


def pext(
    words: jnp.ndarray,
    plan: ExtractionPlan,
    tile: int = DEFAULT_TILE,
    interpret: bool = True,
) -> jnp.ndarray:
    """(n, W) uint32 keys -> (n, Wc) uint32 compressed keys.

    Pads the key axis to a tile multiple (``plancache.pad_tail``: cached
    zero constant + one ``dynamic_update_slice`` — this wrapper is called
    eagerly on the pallas extract path, so the pad must not allocate per
    call), runs the plane kernel, strips the padding.  A planes-native
    pipeline should call ``pext_planes`` directly and skip both
    transposes.
    """
    n, w = words.shape
    total = n + ((-n) % tile)
    planes = pad_tail(jnp.asarray(words, jnp.uint32).T, total, 0, axis=1)
    out = pext_planes(planes, plan, tile=tile, interpret=interpret)
    return out[:, :n].T
