"""Jit'd public wrapper for the pext kernel (row-major in/out, padding)."""

from __future__ import annotations

import jax.numpy as jnp

from repro.core.compress import ExtractionPlan

from .kernel import DEFAULT_TILE, pext_planes


def pext(
    words: jnp.ndarray,
    plan: ExtractionPlan,
    tile: int = DEFAULT_TILE,
    interpret: bool = True,
) -> jnp.ndarray:
    """(n, W) uint32 keys -> (n, Wc) uint32 compressed keys.

    Pads the key axis to a tile multiple, runs the plane kernel, strips the
    padding.  A planes-native pipeline should call ``pext_planes`` directly
    and skip both transposes.
    """
    n, w = words.shape
    pad = (-n) % tile
    planes = jnp.asarray(words, jnp.uint32).T
    if pad:
        planes = jnp.concatenate(
            [planes, jnp.zeros((w, pad), jnp.uint32)], axis=1
        )
    out = pext_planes(planes, plan, tile=tile, interpret=interpret)
    return out[:, :n].T
