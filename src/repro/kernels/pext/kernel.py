"""Pallas TPU kernel: compressed-key bit extraction (the paper's PEXT step).

CPU version: one BMI ``PEXT`` per 8-byte mask word + shift/OR concatenation
(paper §5.1, Figure 8).  TPU adaptation: there is no bit-extract unit, but
the D-bitmap is metadata fixed at reconstruction time, so we compile it into
a static shift/mask schedule over **word planes**:

  layout:  keys as (W, n) uint32 planes — the key axis is the 128-lane axis,
           so every scheduled bit op is amortized over a full 8x128 vector
           register tile;
  per output bit b:  out[dw] |= ((in[sw] >> ss) & 1) << ds      (all lanes)

The schedule costs ~3 VPU ops per extracted bit per 1024-lane tile — the
MXU/VPU-idiomatic equivalent of PEXT's 1 cycle per 64-bit word, and it keeps
the whole tile in VMEM for the downstream sort.

Grid: 1-D over tiles of the key axis.  BlockSpec pins each (W, T) input
tile and (Wc, T) output tile in VMEM; W, Wc are sublane-sized (<= 128 words
for 512-byte keys), T defaults to 1024 lanes.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.compress import ExtractionPlan

DEFAULT_TILE = 1024


def _pext_kernel(plan: ExtractionPlan, x_ref, o_ref):
    """x_ref: (W, T) uint32 planes; o_ref: (Wc, T) uint32 planes."""
    x = x_ref[...]
    t = x.shape[1]
    out = [jnp.zeros((t,), jnp.uint32) for _ in range(plan.n_words_out)]
    for b in range(plan.n_bits):
        sw, ss = plan.src_word[b], plan.src_shift[b]
        dw, ds = plan.dst(b)
        bit = (x[sw, :] >> jnp.uint32(ss)) & jnp.uint32(1)
        out[dw] = out[dw] | (bit << jnp.uint32(ds))
    o_ref[...] = jnp.stack(out, axis=0)


@partial(jax.jit, static_argnames=("plan", "tile", "interpret"))
def pext_planes(
    planes: jnp.ndarray,
    plan: ExtractionPlan,
    tile: int = DEFAULT_TILE,
    interpret: bool = True,
) -> jnp.ndarray:
    """(W, n) uint32 word planes -> (Wc, n) compressed word planes.

    ``n`` must be a multiple of ``tile`` (ops.py pads).  interpret=True runs
    the kernel body on CPU for validation; on TPU pass interpret=False.
    """
    w, n = planes.shape
    assert n % tile == 0, (n, tile)
    grid = (n // tile,)
    return pl.pallas_call(
        partial(_pext_kernel, plan),
        grid=grid,
        in_specs=[pl.BlockSpec((w, tile), lambda i: (0, i))],
        out_specs=pl.BlockSpec((plan.n_words_out, tile), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((plan.n_words_out, n), jnp.uint32),
        interpret=interpret,
    )(planes)
