from . import ops, ref  # noqa: F401
from .ops import pext, pext_planes  # noqa: F401
