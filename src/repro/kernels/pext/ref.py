"""Pure-jnp oracle for the pext kernel: `repro.core.compress.extract_bits`
on the row-major layout, transposed to planes."""

from __future__ import annotations

import jax.numpy as jnp

from repro.core.compress import ExtractionPlan, extract_bits


def pext_ref(words: jnp.ndarray, plan: ExtractionPlan) -> jnp.ndarray:
    """(n, W) uint32 -> (n, Wc) uint32 compressed keys."""
    return extract_bits(words, plan)


def pext_planes_ref(planes: jnp.ndarray, plan: ExtractionPlan) -> jnp.ndarray:
    """(W, n) -> (Wc, n), plane layout."""
    return extract_bits(planes.T, plan).T
