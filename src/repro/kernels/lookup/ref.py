"""Pure-numpy oracle for the partial-key probe: scalar window + compare."""

from __future__ import annotations

import numpy as np


def probe_ref(
    queries: np.ndarray, starts: np.ndarray, entry_pk: np.ndarray, pk: int
) -> np.ndarray:
    """(m, W) query keys + (m,) starts + (m,) stored partial keys -> (m,)
    bool candidate mask, matching the kernel's straddle semantics (clipped
    start, zero word past the key end, top ``pk`` bits kept)."""
    q = np.asarray(queries, np.uint32)
    m, n_words = q.shape
    out = np.zeros((m,), bool)
    for i in range(m):
        start = min(max(int(starts[i]), 0), n_words * 32 - 1)
        wi, sh = start // 32, start % 32
        w0 = int(q[i, wi])
        w1 = int(q[i, wi + 1]) if wi + 1 < n_words else 0
        window = ((w0 << sh) | (w1 >> (32 - sh) if sh else 0)) & 0xFFFFFFFF
        out[i] = np.uint32(window >> (32 - pk)) == np.uint32(entry_pk[i])
    return out
