"""Pallas TPU kernel: tiled partial-key probe for batched point lookups.

The paper's point lookup (§4.3, after Bohannon et al.) screens leaf
entries by their stored partial keys before paying a full-key dereference:
a true match requires the *query's* ``pk``-bit window at the entry's
distinction bit position to equal the entry's stored partial key.  This
kernel is that screen, vectorized over (query, entry) pairs:

* pairs stream through VMEM in ``tile``-lane blocks — the query's key as
  word planes (one (W, tile) block per grid step), the entry's window
  start position and stored partial key as (1, tile) planes alongside;
* the window extraction is the ``kernels/build`` straddle (branch-free
  per-plane compare+select word pick, double shift, top-``pk`` keep) —
  bit-identical to ``repro.core.btree._slice_bits`` by construction;
* the compare is one lane-wise uint32 equality, so the kernel emits the
  candidate mask directly and the caller derefs only screened lanes.

A full-key match always window-matches (the window is sliced from the
matching key itself), so masking a full-equality compare with this
screen is byte-identical to the unscreened compare — which is how the
pallas backend's ``lookup`` op stays bit-for-bit equal to the jnp oracle
while still exercising the partial-key economics.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_TILE = 512


def _probe_kernel(n_words: int, pk: int, w_ref, s_ref, p_ref, o_ref):
    """w_ref: (W, tile) query word planes; s_ref: (1, tile) int32 window
    start bits; p_ref: (1, tile) uint32 stored partial keys; o_ref:
    (1, tile) uint32 candidate mask (1 = window match).
    """
    start = jnp.clip(s_ref[0, :], 0, n_words * 32 - 1)
    wi = start // 32
    sh = (start % 32).astype(jnp.uint32)
    w0 = jnp.zeros(start.shape, jnp.uint32)
    w1 = jnp.zeros(start.shape, jnp.uint32)
    for w in range(n_words):
        plane = w_ref[w, :]
        w0 = jnp.where(wi == w, plane, w0)
        # wi + 1 == W selects nothing, leaving the zero fill — identical
        # to the oracle's where(wi + 1 < W, ..., 0)
        w1 = jnp.where(wi + 1 == w, plane, w1)
    hi = w0 << sh
    lo = jnp.where(sh == 0, jnp.uint32(0), w1 >> (jnp.uint32(32) - sh))
    window = (hi | lo) >> jnp.uint32(32 - pk)
    o_ref[0, :] = (window == p_ref[0, :]).astype(jnp.uint32)


@partial(jax.jit, static_argnames=("pk", "tile", "interpret"))
def probe_planes(
    word_planes: jnp.ndarray,
    starts: jnp.ndarray,
    entry_pk: jnp.ndarray,
    pk: int,
    tile: int = DEFAULT_TILE,
    interpret: bool = True,
) -> jnp.ndarray:
    """(W, n) query word planes + (n,) starts + (n,) stored partial keys
    -> (n,) uint32 candidate mask.  ``n`` must be a multiple of ``tile``."""
    w, n = word_planes.shape
    assert n % tile == 0, (word_planes.shape, tile)
    grid = (n // tile,)
    out = pl.pallas_call(
        partial(_probe_kernel, w, int(pk)),
        grid=grid,
        in_specs=[
            pl.BlockSpec((w, tile), lambda i: (0, i)),
            pl.BlockSpec((1, tile), lambda i: (0, i)),
            pl.BlockSpec((1, tile), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((1, tile), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, n), jnp.uint32),
        interpret=interpret,
    )(
        word_planes,
        starts[None, :].astype(jnp.int32),
        entry_pk[None, :].astype(jnp.uint32),
    )
    return out[0]


def _probe_many_kernel(n_words: int, pk: int, w_ref, s_ref, p_ref, o_ref):
    """Tenant-major twin of ``_probe_kernel``.

    Refs carry a leading singleton tenant block — w_ref: (1, W, tile)
    query word planes, s_ref/p_ref/o_ref: (1, 1, tile) — and the math is
    identical lane-for-lane, so the fused multi-tenant probe stays
    bit-identical to the single-tenant kernel on each tenant's slice.
    """
    start = jnp.clip(s_ref[0, 0, :], 0, n_words * 32 - 1)
    wi = start // 32
    sh = (start % 32).astype(jnp.uint32)
    w0 = jnp.zeros(start.shape, jnp.uint32)
    w1 = jnp.zeros(start.shape, jnp.uint32)
    for w in range(n_words):
        plane = w_ref[0, w, :]
        w0 = jnp.where(wi == w, plane, w0)
        w1 = jnp.where(wi + 1 == w, plane, w1)
    hi = w0 << sh
    lo = jnp.where(sh == 0, jnp.uint32(0), w1 >> (jnp.uint32(32) - sh))
    window = (hi | lo) >> jnp.uint32(32 - pk)
    o_ref[0, 0, :] = (window == p_ref[0, 0, :]).astype(jnp.uint32)


@partial(jax.jit, static_argnames=("pk", "tile", "interpret"))
def probe_planes_many(
    word_planes: jnp.ndarray,
    starts: jnp.ndarray,
    entry_pk: jnp.ndarray,
    pk: int,
    tile: int = DEFAULT_TILE,
    interpret: bool = True,
) -> jnp.ndarray:
    """(T, W, n) stacked query word planes + (T, n) starts/partial keys
    -> (T, n) uint32 candidate mask.

    The grid gains a tenant-major axis — ``(T, n // tile)`` — so one
    ``pallas_call`` screens every tenant's (query, entry) pairs; each
    grid step streams one tenant's ``tile``-lane block through VMEM,
    which is the kernel-level realization of "one program, N tenants".
    ``n`` must be a multiple of ``tile``.
    """
    t, w, n = word_planes.shape
    assert n % tile == 0, (word_planes.shape, tile)
    grid = (t, n // tile)
    out = pl.pallas_call(
        partial(_probe_many_kernel, w, int(pk)),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, w, tile), lambda t, i: (t, 0, i)),
            pl.BlockSpec((1, 1, tile), lambda t, i: (t, 0, i)),
            pl.BlockSpec((1, 1, tile), lambda t, i: (t, 0, i)),
        ],
        out_specs=pl.BlockSpec((1, 1, tile), lambda t, i: (t, 0, i)),
        out_shape=jax.ShapeDtypeStruct((t, 1, n), jnp.uint32),
        interpret=interpret,
    )(
        word_planes,
        starts[:, None, :].astype(jnp.int32),
        entry_pk[:, None, :].astype(jnp.uint32),
    )
    return out[:, 0, :]
