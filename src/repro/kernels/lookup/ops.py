"""Jit-friendly public wrappers for the partial-key probe kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.plancache import pad_tail

from .kernel import DEFAULT_TILE, probe_planes, probe_planes_many


def probe(
    queries: jnp.ndarray,
    starts: jnp.ndarray,
    entry_pk: jnp.ndarray,
    pk: int,
    tile: int = DEFAULT_TILE,
    interpret: bool = True,
) -> jnp.ndarray:
    """(m, W) query keys + (m,) window starts + (m,) stored partial keys
    -> (m,) bool candidate mask (query window == stored partial key).

    Pads the pair axis to a tile multiple via ``plancache.pad_tail`` (pad
    starts/pks are 0 — garbage lanes, stripped before return; cached zero
    constants, no per-call concatenate), transposes to word planes, and
    runs the tiled kernel.  Traces inside the cached lookup program,
    exactly like ``kernels/build``'s ``slice_fn`` does inside the build
    programs.
    """
    m, w = queries.shape
    total = m + ((-m) % tile)
    planes = pad_tail(jnp.asarray(queries, jnp.uint32).T, total, 0, axis=1)
    starts = pad_tail(jnp.asarray(starts, jnp.int32), total, 0)
    entry_pk = pad_tail(jnp.asarray(entry_pk, jnp.uint32), total, 0)
    out = probe_planes(planes, starts, entry_pk, int(pk), tile=tile, interpret=interpret)
    return out[:m].astype(bool)


def leaf_match_fn(tile: int = DEFAULT_TILE, interpret: bool = True):
    """A ``lookup_batch_planned(leaf_match_fn=...)``-shaped closure.

    Screens every (query, leaf entry) pair with the probe kernel, then
    confirms candidates with the full-key compare — byte-identical to the
    unscreened compare (a full match always window-matches), which is the
    pallas ``lookup`` op's realization of the backend contract.
    """

    def fn(tree, node, keys, queries):
        q, lc = node.shape[0], tree.config.leaf_cap
        dpos = tree.leaf["dpos"][node]  # (q, lc)
        entry_pk = tree.leaf["pk"][node]  # (q, lc)
        flat_q = jnp.repeat(queries, lc, axis=0)  # (q*lc, W) pair queries
        cand = probe(
            flat_q,
            (dpos + 1).reshape(-1),
            entry_pk.reshape(-1),
            tree.config.pk_bits,
            tile=tile,
            interpret=interpret,
        ).reshape(q, lc)
        return cand & jnp.all(keys == queries[:, None, :], axis=-1)

    return fn


def probe_many(
    queries: jnp.ndarray,
    starts: jnp.ndarray,
    entry_pk: jnp.ndarray,
    pk: int,
    tile: int = DEFAULT_TILE,
    interpret: bool = True,
) -> jnp.ndarray:
    """(T, m, W) stacked pair queries + (T, m) starts/partial keys
    -> (T, m) bool candidate mask — the tenant-major twin of :func:`probe`.

    Pads the pair axis to a tile multiple against cached constants (pad
    lanes are garbage, stripped before return), transposes each tenant's
    block to word planes, and runs the tenant-major grid kernel — one
    ``pallas_call`` for the whole arena.
    """
    t, m, w = queries.shape
    total = m + ((-m) % tile)
    planes = pad_tail(
        jnp.swapaxes(jnp.asarray(queries, jnp.uint32), 1, 2), total, 0, axis=2
    )
    starts = pad_tail(jnp.asarray(starts, jnp.int32), total, 0, axis=1)
    entry_pk = pad_tail(jnp.asarray(entry_pk, jnp.uint32), total, 0, axis=1)
    out = probe_planes_many(
        planes, starts, entry_pk, int(pk), tile=tile, interpret=interpret
    )
    return out[:, :m].astype(bool)


def leaf_match_many_fn(tile: int = DEFAULT_TILE, interpret: bool = True):
    """A ``lookup_many_planned(leaf_match_many_fn=...)``-shaped closure.

    The stacked twin of :func:`leaf_match_fn`: per-tenant gathers of the
    leaf entries' window starts and stored partial keys, one tenant-major
    probe kernel over every (tenant, query, entry) pair, then the
    full-key confirm — byte-identical per tenant to the single-snapshot
    pallas lookup (a full match always window-matches).
    """

    def fn(tree, node, keys, queries):
        t, q = node.shape
        lc = tree.config.leaf_cap
        gather = jax.vmap(lambda arr, n: arr[n])
        dpos = gather(tree.leaf["dpos"], node)  # (T, q, lc)
        entry_pk = gather(tree.leaf["pk"], node)  # (T, q, lc)
        flat_q = jnp.repeat(queries, lc, axis=1)  # (T, q*lc, W)
        cand = probe_many(
            flat_q,
            (dpos + 1).reshape(t, -1),
            entry_pk.reshape(t, -1),
            tree.config.pk_bits,
            tile=tile,
            interpret=interpret,
        ).reshape(t, q, lc)
        return cand & jnp.all(keys == queries[:, :, None, :], axis=-1)

    return fn
