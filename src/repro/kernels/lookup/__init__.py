from . import ops, ref  # noqa: F401
from .ops import leaf_match_fn, probe  # noqa: F401
