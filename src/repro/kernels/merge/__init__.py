from . import ops, ref  # noqa: F401
from .ops import merge_ranks, merge_sorted  # noqa: F401
