"""Jit'd public wrappers for the merge-path rank kernel (padding, scatter)."""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core.plancache import pad_tail

from .kernel import DEFAULT_TILE, merge_rank_planes

# Pad queries with the all-ones sentinel: their ranks are garbage but they
# are stripped before the scatter (mirrors the bitonic/distsort convention).
_SENTINEL = np.uint32(0xFFFFFFFF)


def merge_ranks(
    keys_q: jnp.ndarray,
    rows_q: jnp.ndarray,
    keys_s: jnp.ndarray,
    rows_s: jnp.ndarray,
    tile: int = DEFAULT_TILE,
    interpret: bool = True,
) -> jnp.ndarray:
    """#{i : (key_s, row_s)_i < (key_q, row_q)} per query, via the kernel.

    ``(keys_s, rows_s)`` ascending in (key, row); queries unrestricted.
    Returns (n_q,) int32.  The tile pad rides ``plancache.pad_tail``
    (cached sentinel constant, no per-call concatenate).
    """
    n_q, w = keys_q.shape
    n_s = int(keys_s.shape[0])
    if n_q == 0 or n_s == 0:
        return jnp.zeros((n_q,), jnp.int32)
    q_planes = jnp.concatenate(
        [jnp.asarray(keys_q, jnp.uint32).T, jnp.asarray(rows_q, jnp.uint32)[None, :]],
        axis=0,
    )
    q_planes = pad_tail(q_planes, n_q + ((-n_q) % tile), _SENTINEL, axis=1)
    s_planes = jnp.concatenate(
        [jnp.asarray(keys_s, jnp.uint32).T, jnp.asarray(rows_s, jnp.uint32)[None, :]],
        axis=0,
    )
    ranks = merge_rank_planes(q_planes, s_planes, tile=tile, interpret=interpret)
    return ranks[:n_q]


def merge_sorted(
    keys_a: jnp.ndarray,
    rows_a: jnp.ndarray,
    keys_b: jnp.ndarray,
    rows_b: jnp.ndarray,
    tile: int = DEFAULT_TILE,
    interpret: bool = True,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Kernel-ranked merge of two ascending (key, row) runs.

    The single rank pass (the smaller run ranked in the larger — see
    ``merge_from_ranks``) runs through the Pallas kernel; the
    complement-scatter assembly is shared with the jnp reference, so the
    output is byte-identical to ``repro.core.dbits.merge_words_keyed``.
    Halving the rank passes halves the kernel work per merge, which is
    what makes the chunked cascade's merge levels cheap on this backend.
    """
    from repro.core.dbits import merge_from_ranks

    def kernel_ranks(keys_s, rows_s, keys_q, rows_q):
        return merge_ranks(
            keys_q, rows_q, keys_s, rows_s, tile=tile, interpret=interpret
        )

    return merge_from_ranks(keys_a, rows_a, keys_b, rows_b, rank_fn=kernel_ranks)
