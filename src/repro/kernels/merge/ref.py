"""Pure-numpy oracle for the merge-path ranks: scalar binary search."""

from __future__ import annotations

import numpy as np


def _pair_less(ka, ra, kb, rb) -> bool:
    ta, tb = tuple(int(x) for x in ka), tuple(int(x) for x in kb)
    return (ta, int(ra)) < (tb, int(rb))


def merge_ranks_ref(
    keys_q: np.ndarray, rows_q: np.ndarray, keys_s: np.ndarray, rows_s: np.ndarray
) -> np.ndarray:
    """Per-query rank in the sorted run, one scalar binary search each."""
    n_s = len(keys_s)
    out = np.zeros(len(keys_q), np.int32)
    for i in range(len(keys_q)):
        lo, hi = 0, n_s
        while lo < hi:
            mid = (lo + hi) // 2
            if _pair_less(keys_s[mid], rows_s[mid], keys_q[i], rows_q[i]):
                lo = mid + 1
            else:
                hi = mid
        out[i] = lo
    return out
