"""Pallas TPU kernel: tiled merge-path ranks for sorted multiword runs.

The incremental reconstruction path (delta merge) needs the output position
of every element of two sorted runs in their merge.  Merge-path reduces the
merge to per-element *ranks*: the position of element ``q`` of one run is its
own index plus the number of elements of the other run that precede it under
the (key, row) determinism contract.  The rank computation is the whole
cost, and it is what this kernel tiles:

* the query run streams through VMEM in ``tile``-lane blocks (one grid step
  per tile);
* the searched run is resident as word planes (keys + row id as the final,
  least-significant plane), so each of the ``log2(n_s)`` binary-search steps
  is one lane-gather + one multiword compare over the whole tile — the
  branch-free vector analogue of the scalar binary search;
* rows are carried as an extra key word, exactly as in the bitonic kernel,
  so ties between equal keys resolve on the ascending row id and the merge
  is byte-identical to the full sort.

The searched run must fit in VMEM (one (W+1, n_s) uint32 block, ~1 MB at
64k×3-word keys); callers with larger runs fall back to the jnp merge.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_TILE = 512


def _lex_less_planes(a, b, n_words: int):
    """(W, T) planes: lexicographic a < b over the first ``n_words`` planes."""
    less = jnp.zeros(a.shape[1], jnp.bool_)
    eq = jnp.ones(a.shape[1], jnp.bool_)
    for w in range(n_words):
        less = less | (eq & (a[w] < b[w]))
        eq = eq & (a[w] == b[w])
    return less


def _rank_kernel(n_planes: int, n_s: int, q_ref, s_ref, o_ref):
    """q_ref: (W+1, tile) query planes; s_ref: (W+1, n_s) sorted planes;
    o_ref: (1, tile) int32 ranks.

    Per lane, a [lo, hi) binary search over the searched run; every substage
    is a static-count whole-tile step (no data-dependent trips), so the
    kernel is one straight-line program of log2(n_s) gather+compare rounds.
    """
    q = q_ref[...]
    s = s_ref[...]
    t = q.shape[1]
    lo = jnp.zeros((t,), jnp.int32)
    hi = jnp.full((t,), n_s, jnp.int32)
    for _ in range(max(1, n_s.bit_length())):
        mid = (lo + hi) // 2
        midc = jnp.minimum(mid, n_s - 1)
        sm = jnp.take(s, midc, axis=1)  # (W+1, tile) lane gather
        # strict (key, row) less: the row plane is the last key word and row
        # ids are distinct, so no equality case survives
        lt = _lex_less_planes(sm, q, n_planes) & (mid < n_s)
        lo = jnp.where(lt, mid + 1, lo)
        hi = jnp.where(lt, hi, mid)
    o_ref[...] = lo[None, :]


@partial(jax.jit, static_argnames=("tile", "interpret"))
def merge_rank_planes(
    q_planes: jnp.ndarray,
    s_planes: jnp.ndarray,
    tile: int = DEFAULT_TILE,
    interpret: bool = True,
) -> jnp.ndarray:
    """Ranks of (W+1, n_q) query planes in (W+1, n_s) sorted planes.

    ``n_q`` must be a multiple of ``tile``; returns (n_q,) int32.  The last
    plane of each operand is the row id (the tie-break key word).
    """
    wp, n_q = q_planes.shape
    wp_s, n_s = s_planes.shape
    assert wp == wp_s and n_q % tile == 0, (q_planes.shape, s_planes.shape, tile)
    grid = (n_q // tile,)
    out = pl.pallas_call(
        partial(_rank_kernel, wp, int(n_s)),
        grid=grid,
        in_specs=[
            pl.BlockSpec((wp, tile), lambda i: (0, i)),
            pl.BlockSpec((wp_s, n_s), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, tile), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, n_q), jnp.int32),
        interpret=interpret,
    )(q_planes, s_planes)
    return out[0]
