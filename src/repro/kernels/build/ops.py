"""Jit-friendly public wrapper for the pk-window gather kernel."""

from __future__ import annotations

import jax.numpy as jnp

from repro.core.plancache import pad_tail

from .kernel import DEFAULT_TILE, pk_window_planes


def pk_windows(
    words: jnp.ndarray,
    starts: jnp.ndarray,
    pk: int,
    tile: int = DEFAULT_TILE,
    interpret: bool = True,
) -> jnp.ndarray:
    """(m, W) uint32 keys + (m,) start bit positions -> (m,) uint32 windows.

    Pads the entry axis to a tile multiple via ``plancache.pad_tail``
    (pad starts are 0 — harmless garbage lanes, stripped before return;
    cached zero constants, no per-call concatenate), transposes to word
    planes, and runs the tiled kernel.  Drop-in for
    ``repro.core.btree._slice_bits`` when the window axis is 1-D: the
    build programs call it through ``slice_fn`` so it traces inside the
    cached build program.
    """
    m, w = words.shape
    total = m + ((-m) % tile)
    planes = pad_tail(jnp.asarray(words, jnp.uint32).T, total, 0, axis=1)
    starts = pad_tail(jnp.asarray(starts, jnp.int32), total, 0)
    out = pk_window_planes(planes, starts, int(pk), tile=tile, interpret=interpret)
    return out[:m]


def slice_fn(tile: int = DEFAULT_TILE, interpret: bool = True):
    """A ``build_btree(slice_fn=...)``-shaped closure over kernel options."""

    def fn(words, starts, pk):
        return pk_windows(words, starts, pk, tile=tile, interpret=interpret)

    return fn
