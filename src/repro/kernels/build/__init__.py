from . import ops, ref  # noqa: F401
from .ops import pk_windows, slice_fn  # noqa: F401
