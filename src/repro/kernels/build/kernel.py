"""Pallas TPU kernel: tiled partial-key window gather for the tree build.

Stage 3 of the pipeline (bulk build, §5.3) spends its per-entry time on one
primitive: slice ``pk`` bits out of each entry's full key starting at a
per-entry bit position (the bit after the entry's distinction bit — paper
option C.b, the partial key is read out of the record's own key).  The jnp
realization (`repro.core.btree._slice_bits`) is a pair of
``take_along_axis`` word gathers; this kernel is the tiled, planes-native
variant:

* entries stream through VMEM in ``tile``-lane blocks, full keys as word
  planes (one (W, tile) block per grid step) with the start positions as a
  (1, tile) int32 plane alongside;
* the per-lane word selection is branch-free: each of the ``W`` planes is
  selected into the (word, word+1) straddle pair with a lane-wide compare
  + select (W is 2–4 words; a compare/select pair per plane beats a lane
  gather on the VPU);
* the double-shift concatenation and the final ``32 - pk`` right shift are
  plain lane-wise uint32 ops.

Bit-for-bit identical to ``_slice_bits`` by construction (same clip, same
straddle semantics, same shift widths) — the build programs swap it in via
``build_btree(slice_fn=...)`` and the backend parity tests hold across the
substitution.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_TILE = 512


def _pk_window_kernel(n_words: int, pk: int, w_ref, s_ref, o_ref):
    """w_ref: (W, tile) key word planes; s_ref: (1, tile) int32 start bit
    positions; o_ref: (1, tile) uint32 pk-bit windows.

    Mirrors ``repro.core.btree._slice_bits``: clip the start into the key,
    read the straddling word pair (the second word is zero past the key
    end), shift-concatenate, keep the top ``pk`` bits.
    """
    start = jnp.clip(s_ref[0, :], 0, n_words * 32 - 1)
    wi = start // 32
    sh = (start % 32).astype(jnp.uint32)
    w0 = jnp.zeros(start.shape, jnp.uint32)
    w1 = jnp.zeros(start.shape, jnp.uint32)
    for w in range(n_words):
        plane = w_ref[w, :]
        w0 = jnp.where(wi == w, plane, w0)
        # wi + 1 == W selects nothing, leaving the zero fill — identical to
        # the oracle's where(wi + 1 < W, ..., 0)
        w1 = jnp.where(wi + 1 == w, plane, w1)
    hi = w0 << sh
    lo = jnp.where(sh == 0, jnp.uint32(0), w1 >> (jnp.uint32(32) - sh))
    o_ref[0, :] = (hi | lo) >> jnp.uint32(32 - pk)


@partial(jax.jit, static_argnames=("pk", "tile", "interpret"))
def pk_window_planes(
    word_planes: jnp.ndarray,
    starts: jnp.ndarray,
    pk: int,
    tile: int = DEFAULT_TILE,
    interpret: bool = True,
) -> jnp.ndarray:
    """(W, n) uint32 key word planes + (n,) int32 starts -> (n,) uint32
    pk-bit windows.  ``n`` must be a multiple of ``tile``."""
    w, n = word_planes.shape
    assert n % tile == 0, (word_planes.shape, tile)
    grid = (n // tile,)
    out = pl.pallas_call(
        partial(_pk_window_kernel, w, int(pk)),
        grid=grid,
        in_specs=[
            pl.BlockSpec((w, tile), lambda i: (0, i)),
            pl.BlockSpec((1, tile), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((1, tile), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, n), jnp.uint32),
        interpret=interpret,
    )(word_planes, starts[None, :].astype(jnp.int32))
    return out[0]
