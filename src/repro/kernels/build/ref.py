"""Pure-numpy oracle for the pk-window gather: scalar bit slicing."""

from __future__ import annotations

import numpy as np


def pk_windows_ref(words: np.ndarray, starts: np.ndarray, pk: int) -> np.ndarray:
    """(m, W) uint32 keys + (m,) start positions -> (m,) uint32 windows.

    One scalar straddle per entry, matching ``_slice_bits`` semantics: the
    start is clipped into the key, the word past the key end reads as 0,
    and the top ``pk`` bits of the 32-bit window are kept.
    """
    w = np.asarray(words, np.uint32)
    m, n_words = w.shape
    out = np.zeros((m,), np.uint32)
    for i in range(m):
        start = min(max(int(starts[i]), 0), n_words * 32 - 1)
        wi, sh = start // 32, start % 32
        w0 = int(w[i, wi])
        w1 = int(w[i, wi + 1]) if wi + 1 < n_words else 0
        window = ((w0 << sh) | (w1 >> (32 - sh) if sh else 0)) & 0xFFFFFFFF
        out[i] = np.uint32(window >> (32 - pk))
    return out
