"""Compatibility shims over jax API drift.

The repo targets current jax (``jax.shard_map``, ``jax.make_mesh(...,
axis_types=...)``, ``jax.sharding.AxisType``); the pinned container image
ships an older release where ``shard_map`` still lives under
``jax.experimental`` and meshes have no axis types.  Every module that
builds a mesh or shard_maps goes through this file so the whole tree moves
between versions with one edit.
"""

from __future__ import annotations

import jax

__all__ = ["shard_map", "make_mesh", "set_mesh", "optimization_barrier"]


# jax < 0.5 has no differentiation (nor transpose) rule for
# optimization_barrier; this custom_vjp barriers the primal AND the
# cotangent, so the scheduling pin holds in both the forward and backward
# streams (hoisting the bf16 cast out of either direction doubles ICI
# bytes).  custom_vjp because the bwd is plain code — a barriered tangent
# under custom_jvp would need the transpose rule old jax also lacks.
# Forward-mode jvp is not supported through this shim (nothing here uses
# it).  No import-time jax execution: defining a custom_vjp touches no
# device state.
@jax.custom_vjp
def optimization_barrier(x):
    return jax.lax.optimization_barrier(x)


def _optimization_barrier_fwd(x):
    return jax.lax.optimization_barrier(x), None


def _optimization_barrier_bwd(_, g):
    return (jax.lax.optimization_barrier(g),)


optimization_barrier.defvjp(_optimization_barrier_fwd, _optimization_barrier_bwd)


if hasattr(jax, "shard_map"):
    shard_map = jax.shard_map
else:  # jax < 0.5: experimental namespace
    from jax.experimental.shard_map import shard_map  # type: ignore[no-redef]


def set_mesh(mesh: jax.sharding.Mesh):
    """Context manager installing ``mesh`` as the ambient mesh.

    ``jax.set_mesh`` where it exists; on older jax the ``Mesh`` object is
    itself the context manager that installs the physical mesh.
    """
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    return mesh


def make_mesh(axis_shapes, axis_names) -> jax.sharding.Mesh:
    """``jax.make_mesh`` with Auto axis types where supported."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        try:
            return jax.make_mesh(
                tuple(axis_shapes),
                tuple(axis_names),
                axis_types=(axis_type.Auto,) * len(tuple(axis_names)),
            )
        except TypeError:
            pass
    return jax.make_mesh(tuple(axis_shapes), tuple(axis_names))
