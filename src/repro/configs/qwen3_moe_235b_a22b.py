"""qwen3-moe-235b-a22b — 94L d_model=4096 64H (GQA kv=4) per-expert d_ff=1536,
vocab=151936, MoE 128 experts top-8.  [hf:Qwen/Qwen3-30B-A3B family; hf]

head_dim=128 (HF config value; q/k-norm enabled as in Qwen3)."""

from .base import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-moe-235b-a22b",
    family="moe",
    n_layers=94,
    d_model=4096,
    n_heads=64,
    n_kv_heads=4,
    head_dim=128,
    d_ff=0,  # no dense FFN: every layer is MoE
    moe_d_ff=1536,
    n_experts=128,
    top_k=8,
    vocab_size=151936,
    pattern=((("attn", "moe")),),
    qk_norm=True,
    rope_theta=1000000.0,
    source="hf:Qwen/Qwen3-235B-A22B",
)
