"""xlstm-1.3b — 48L d_model=2048 4H d_ff=0 vocab=50304; sLSTM + mLSTM blocks
at 7:1 (the paper's xLSTM[7:1] 1.3B configuration).  [arXiv:2405.04517;
unverified]

No separate FFN (d_ff=0): mLSTM blocks carry a 2x up-projection internally,
sLSTM blocks operate at model width."""

from .base import ArchConfig

_PATTERN = tuple(("mlstm" if i != 7 else "slstm", "none") for i in range(8))

CONFIG = ArchConfig(
    name="xlstm-1.3b",
    family="ssm",
    n_layers=48,
    d_model=2048,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    pattern=_PATTERN,
    xlstm_heads=4,
    xlstm_expand=2,
    tie_embeddings=True,
    source="arXiv:2405.04517",
)
