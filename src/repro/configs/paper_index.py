"""The paper's own workload configs: the six datasets of Table 2 (synthetic
stand-ins matching published statistics) and the Zipf(s, n, m) sensitivity
generator of §6.3 (fully specified in the paper, so Table 4's sort-key
ratios are *reproducible exactly*)."""

from dataclasses import dataclass


@dataclass(frozen=True)
class IndexDatasetConfig:
    name: str
    n_keys: int  # scaled down from the paper for CPU benching
    key_bytes: int  # fixed width (or max width) per paper Table 2
    kind: str  # "fixed" | "zipf" | "url" | "title"
    zipf_s: float = 1.5
    zipf_m: int = 0  # leading constant bytes per 8-byte word (paper §6.3)


# Paper Table 2 stand-ins (n scaled ~1/64 for CPU wall-clock; the *ratios*
# —compression, sort-key, word-comparison— are size-independent).
DATASETS = {
    "INDBTAB": IndexDatasetConfig("INDBTAB", 256_000, 35, "fixed"),
    "Human": IndexDatasetConfig("Human", 570_000, 101, "genome"),
    "Wikititle": IndexDatasetConfig("Wikititle", 218_000, 24, "title"),
    "ExURL": IndexDatasetConfig("ExURL", 120_000, 59, "url"),
    "WikiURL": IndexDatasetConfig("WikiURL", 200_000, 50, "url"),
    "Part": IndexDatasetConfig("Part", 31_000, 34, "fixed"),
}


@dataclass(frozen=True)
class ZipfConfig:
    """Zipf(s, n, m) of §6.3: keys of n bytes; in each 8-byte word the first
    m bytes are a constant, the rest lower-case ASCII ~ Zipf(s, 26)."""

    s: float
    n_bytes: int
    m: int
    n_keys: int = 100_000  # paper uses 10M; ratios are size-independent


# Table 4 rows (datasets 1-20)
ZIPF_TABLE4 = [
    *(ZipfConfig(2.5, n, 0) for n in (48, 56, 64, 72, 80, 88, 96, 104, 112)),
    *(ZipfConfig(1.5, 40, m) for m in range(5)),
    *(ZipfConfig(1.5, 64, m) for m in range(6)),
]
