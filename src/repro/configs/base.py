"""Architecture + shape configuration system.

Every assigned architecture is an ``ArchConfig`` whose ``pattern`` is the
per-superblock sublayer cycle: a tuple of (mixer, ffn) kind pairs, cycled
``n_layers / len(pattern)`` times.  The layer stack is scanned over
superblocks, so compile time is O(pattern), not O(depth).

mixer kinds: attn | mamba | mlstm | slstm | xattn
ffn kinds:   dense | moe | none
"""

from __future__ import annotations

from dataclasses import dataclass, field, replace

__all__ = ["ArchConfig", "ShapeConfig", "SHAPES", "shape_applies"]


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str  # dense | moe | hybrid | ssm | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // n_heads
    pattern: tuple = ((("attn", "dense")),)
    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0
    shared_expert: bool = False
    capacity_factor: float = 1.25
    dispatch_mode: str = "einsum"  # or "sort" (compressed-key-sort dispatch)
    # --- SSM (Mamba) ---
    ssm_expand: int = 2
    ssm_state: int = 16
    ssm_conv: int = 4
    ssm_dt_rank: int = 0  # 0 -> d_model // 16
    # --- xLSTM ---
    xlstm_heads: int = 4
    xlstm_expand: int = 2
    # --- VLM ---
    n_img_tokens: int = 0
    # --- frontend stub ---
    embed_input: bool = True  # False: input_specs provides frame embeddings
    # --- misc ---
    rope_theta: float = 500000.0
    norm_eps: float = 1e-5
    qk_norm: bool = False
    tie_embeddings: bool = False
    # attention chunking (activation-memory control)
    q_chunk: int = 512
    kv_chunk: int = 1024
    loss_chunk: int = 512
    ssm_chunk: int = 256
    # §Perf knob: repeat KV to the full head count before attention so the
    # head dim shards cleanly over "model" (GQA group dim G < mesh axis
    # otherwise replicates the pair-scan math; see EXPERIMENTS.md §Perf)
    attn_repeat_kv: bool = False
    source: str = ""  # provenance note

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def n_superblocks(self) -> int:
        assert self.n_layers % len(self.pattern) == 0, (
            self.name, self.n_layers, len(self.pattern))
        return self.n_layers // len(self.pattern)

    @property
    def dt_rank(self) -> int:
        return self.ssm_dt_rank or max(1, self.d_model // 16)

    def active_params(self) -> int:
        """Approximate active parameter count (MoE: routed top_k only)."""
        return _param_count(self, active_only=True)

    def total_params(self) -> int:
        return _param_count(self, active_only=False)

    def reduced(self) -> "ArchConfig":
        """Tiny same-family config for CPU smoke tests."""
        return replace(
            self,
            n_layers=len(self.pattern),
            d_model=64,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_kv_heads > 1 else 1,
            head_dim=16,
            d_ff=128 if self.d_ff else 0,
            vocab_size=512,
            n_experts=min(self.n_experts, 8) if self.n_experts else 0,
            moe_d_ff=64 if self.n_experts else 0,
            n_img_tokens=16 if self.n_img_tokens else 0,
            ssm_dt_rank=8,
            q_chunk=16,
            kv_chunk=16,
            loss_chunk=16,
            ssm_chunk=8,
            xlstm_heads=min(self.xlstm_heads, 4),
        )


def _param_count(c: ArchConfig, active_only: bool) -> int:
    d, hd = c.d_model, c.hd
    total = c.vocab_size * d * (1 if c.tie_embeddings else 2) if c.embed_input else c.vocab_size * d
    per_pattern = 0
    for mixer, ffn in c.pattern:
        if mixer in ("attn", "xattn"):
            per_pattern += d * hd * (c.n_heads + 2 * c.n_kv_heads) + c.n_heads * hd * d
        elif mixer == "mamba":
            di = c.ssm_expand * d
            per_pattern += d * 2 * di + di * (c.dt_rank + 2 * c.ssm_state)
            per_pattern += c.dt_rank * di + di * c.ssm_conv + di * d + 2 * di
        elif mixer == "mlstm":
            di = c.xlstm_expand * d
            per_pattern += d * 2 * di + 3 * di * di + 2 * di * c.xlstm_heads + di * d
        elif mixer == "slstm":
            dh = d // c.xlstm_heads
            per_pattern += 4 * d * d + 4 * c.xlstm_heads * dh * dh
        if ffn == "dense":
            per_pattern += 3 * d * c.d_ff
        elif ffn == "moe":
            e = c.top_k if active_only else c.n_experts
            per_pattern += 3 * d * c.moe_d_ff * e + d * c.n_experts
            if c.shared_expert:
                per_pattern += 3 * d * c.d_ff
    return total + per_pattern * c.n_superblocks


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    kind: str  # train | prefill | decode
    seq_len: int
    global_batch: int
    accum: int = 1  # gradient-accumulation microbatches (train only)


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", "train", 4096, 256, accum=8),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeConfig("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeConfig("long_500k", "decode", 524288, 1),
}


def shape_applies(arch: ArchConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """Assignment rule: long_500k needs sub-quadratic sequence mixing —
    runs for SSM/hybrid, skipped (with note) for pure full-attention archs."""
    if shape.name == "long_500k" and arch.family not in ("ssm", "hybrid"):
        return False, (
            "skipped: pure full-attention arch; 500k decode requires "
            "sub-quadratic mixing (DESIGN.md §Arch-applicability)"
        )
    return True, ""
