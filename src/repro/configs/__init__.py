"""Config registry: --arch <id> resolution."""

from .base import SHAPES, ArchConfig, ShapeConfig, shape_applies
from .granite_34b import CONFIG as granite_34b
from .internlm2_20b import CONFIG as internlm2_20b
from .jamba_v0_1_52b import CONFIG as jamba_v0_1_52b
from .llama3_8b import CONFIG as llama3_8b
from .llama4_scout_17b_a16e import CONFIG as llama4_scout
from .llama_3_2_vision_90b import CONFIG as llama_3_2_vision_90b
from .minitron_4b import CONFIG as minitron_4b
from .musicgen_large import CONFIG as musicgen_large
from .qwen3_moe_235b_a22b import CONFIG as qwen3_moe
from .xlstm_1_3b import CONFIG as xlstm_1_3b

ARCHS: dict[str, ArchConfig] = {
    c.name: c
    for c in [
        qwen3_moe,
        llama4_scout,
        jamba_v0_1_52b,
        musicgen_large,
        xlstm_1_3b,
        llama_3_2_vision_90b,
        granite_34b,
        minitron_4b,
        llama3_8b,
        internlm2_20b,
    ]
}


def get_arch(name: str) -> ArchConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; have {sorted(ARCHS)}")
    return ARCHS[name]


def get_shape(name: str) -> ShapeConfig:
    if name not in SHAPES:
        raise KeyError(f"unknown shape {name!r}; have {sorted(SHAPES)}")
    return SHAPES[name]


__all__ = [
    "ARCHS",
    "SHAPES",
    "ArchConfig",
    "ShapeConfig",
    "get_arch",
    "get_shape",
    "shape_applies",
]
