"""musicgen-large — 48L d_model=2048 32H (kv=32, full MHA) d_ff=8192,
vocab=2048 (EnCodec codebook).  Decoder-only over EnCodec tokens.
[arXiv:2306.05284; hf]

The EnCodec frontend is a STUB: input_specs() provides precomputed frame
embeddings (B, T, d_model); the backbone predicts codebook tokens."""

from .base import ArchConfig

CONFIG = ArchConfig(
    name="musicgen-large",
    family="audio",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8192,
    vocab_size=2048,
    pattern=((("attn", "dense")),),
    embed_input=False,  # frame embeddings arrive precomputed
    rope_theta=10000.0,
    source="arXiv:2306.05284",
)
