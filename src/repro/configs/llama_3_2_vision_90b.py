"""llama-3.2-vision-90b — 100L d_model=8192 64H (GQA kv=8) d_ff=28672,
vocab=128256; cross-attention image layers every 5th layer.
[hf:meta-llama/Llama-3.2-11B-Vision family; unverified]

The vision tower is a STUB: input_specs() provides precomputed patch
embeddings (B, n_img_tokens, d_model); cross-attn layers are gated
(tanh-gate, zero-init) as in the release."""

from .base import ArchConfig

_PATTERN = tuple(
    ("xattn" if i == 4 else "attn", "dense") for i in range(5)
)

CONFIG = ArchConfig(
    name="llama-3.2-vision-90b",
    family="vlm",
    n_layers=100,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=28672,
    vocab_size=128256,
    pattern=_PATTERN,
    n_img_tokens=1024,
    rope_theta=500000.0,
    source="hf:meta-llama/Llama-3.2-90B-Vision",
)
