"""jamba-v0.1-52b — 32L d_model=4096 32H (GQA kv=8) d_ff=14336, vocab=65536,
MoE 16 experts top-2; Mamba:attention 7:1 interleave. [arXiv:2403.19887; hf]

Superblock of 8 layers: attention at index 4 (mid-block, as in the release),
MoE replaces the MLP every other layer (offset 1)."""

from .base import ArchConfig

_PATTERN = tuple(
    ("attn" if i == 4 else "mamba", "moe" if i % 2 == 1 else "dense")
    for i in range(8)
)

CONFIG = ArchConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    moe_d_ff=14336,
    n_experts=16,
    top_k=2,
    vocab_size=65536,
    pattern=_PATTERN,
    ssm_expand=2,
    ssm_state=16,
    ssm_conv=4,
    rope_theta=0.0,  # Jamba uses no positional encoding in attn layers
    source="arXiv:2403.19887",
)
