"""Train step builder: microbatched grad accumulation + AdamW + shardings.

The returned step is a pure function
    (params, opt, batch) -> (params, opt, metrics)
suitable for ``jax.jit`` with donated params/opt.  Gradient accumulation
reshapes the global batch to (accum, B/accum, ...) and scans, so peak
activation memory is one microbatch regardless of the global batch spec
(train_4k is 1M tokens — accum=8 keeps the MoE dispatch buffers and
attention state bounded; DESIGN.md §6).
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ShapeConfig
from repro.models.lm import LM

from .optim import OptConfig, adamw_init, adamw_update

__all__ = ["make_train_step", "init_train_state"]


def init_train_state(model: LM, key):
    params = model.init(key)
    opt = adamw_init(params)
    return params, opt


def make_train_step(model: LM, opt_cfg: OptConfig, accum: int = 1,
                    param_shardings=None):
    def loss_fn(params, mb):
        loss, metrics = model.loss(params, mb)
        return loss, metrics

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def pin(tree):
        """Pin gradient(-accumulator) sharding to the params' sharding —
        without this GSPMD replicates the fp32 accumulator (32 GB/device for
        an 8B model) and lowers the DP reduction as a full all-reduce
        instead of a reduce-scatter."""
        if param_shardings is None:
            return tree
        return jax.tree_util.tree_map(
            lambda x, s: jax.lax.with_sharding_constraint(x, s),
            tree, param_shardings,
        )

    def train_step(params, opt, batch):
        if accum == 1:
            (loss, metrics), grads = grad_fn(params, batch)
            grads = pin(grads)
        else:
            # reshape every batch leaf (B, ...) -> (accum, B/accum, ...)
            mb = jax.tree_util.tree_map(
                lambda x: x.reshape((accum, x.shape[0] // accum) + x.shape[1:]),
                batch,
            )
            zeros = pin(jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params
            ))

            def micro(carry, mb_i):
                gacc, lacc = carry
                (l, m), g = grad_fn(params, mb_i)
                gacc = pin(jax.tree_util.tree_map(
                    lambda a, b: a + b.astype(jnp.float32), gacc, g
                ))
                return (gacc, lacc + l), m

            (grads, loss_sum), ms = jax.lax.scan(micro, (zeros, jnp.float32(0)), mb)
            grads = jax.tree_util.tree_map(lambda g: g / accum, grads)
            loss = loss_sum / accum
            metrics = jax.tree_util.tree_map(lambda x: jnp.mean(x), ms)

        params, opt, opt_metrics = adamw_update(opt_cfg, params, grads, opt)
        metrics = {**metrics, **opt_metrics, "loss": loss}
        return params, opt, metrics

    return train_step
