"""Gradient compression for the slow (cross-pod) all-reduce.

int8 quantization with per-leaf scale and **error feedback** (the residual
of each round is added back before the next quantization — 1-bit Adam /
EF-SGD style), run under ``shard_map`` over the pod axis so only the
inter-pod hop carries compressed payloads; intra-pod reductions stay full
precision.  4x byte reduction on the slowest link of the 2x16x16 mesh.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

__all__ = ["ef_init", "compressed_psum", "compressed_allreduce_grads"]


def ef_init(grads) -> dict:
    return jax.tree_util.tree_map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)


def _quantize(x: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def compressed_psum(x: jnp.ndarray, ef: jnp.ndarray, axis_name: str):
    """Error-feedback int8 psum of one leaf along ``axis_name``.

    Returns (mean-reduced fp32 value, new error-feedback residual).
    """
    xf = x.astype(jnp.float32) + ef
    q, scale = _quantize(xf)
    deq = q.astype(jnp.float32) * scale
    new_ef = xf - deq
    # int8 payload on the wire; accumulate in int32 to avoid overflow, then
    # combine with the all-reduced scales (per-shard scale -> sum of deqs).
    summed = jax.lax.psum(deq, axis_name)  # XLA moves int8*scale fused payload
    n = jax.lax.psum(jnp.ones((), jnp.float32), axis_name)
    return summed / n, new_ef


def compressed_allreduce_grads(grads, ef, axis_name: str):
    """Tree version: mean-reduce grads across ``axis_name`` with int8+EF."""
    flat_g, tdef = jax.tree_util.tree_flatten(grads)
    flat_e = jax.tree_util.tree_leaves(ef)
    outs = [compressed_psum(g, e, axis_name) for g, e in zip(flat_g, flat_e)]
    new_g = jax.tree_util.tree_unflatten(tdef, [o[0] for o in outs])
    new_e = jax.tree_util.tree_unflatten(tdef, [o[1] for o in outs])
    return new_g, new_e
