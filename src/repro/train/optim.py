"""AdamW + LR schedules, from scratch (no optax in this environment).

Optimizer state mirrors the parameter pytree, so it inherits the same
FSDP/TP shardings — ZeRO-1 falls out of the sharding rules with no extra
machinery (DESIGN.md §6).
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

__all__ = ["OptConfig", "adamw_init", "adamw_update", "lr_at", "global_norm"]


@dataclass(frozen=True)
class OptConfig:
    peak_lr: float = 3e-4
    warmup_steps: int = 100
    decay_steps: int = 10_000
    min_lr_ratio: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


def lr_at(cfg: OptConfig, step: jnp.ndarray) -> jnp.ndarray:
    step = step.astype(jnp.float32)
    warm = cfg.peak_lr * jnp.minimum(step / max(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip(
        (step - cfg.warmup_steps) / max(cfg.decay_steps - cfg.warmup_steps, 1), 0, 1
    )
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * t))
    return jnp.where(step < cfg.warmup_steps, warm, cfg.peak_lr * cos)


def adamw_init(params) -> dict:
    zeros = lambda p: jax.tree_util.tree_map(
        lambda x: jnp.zeros(x.shape, jnp.float32), p
    )
    return {"m": zeros(params), "v": zeros(params), "step": jnp.zeros((), jnp.int32)}


def global_norm(tree) -> jnp.ndarray:
    s = sum(
        jnp.sum(jnp.square(x.astype(jnp.float32)))
        for x in jax.tree_util.tree_leaves(tree)
    )
    return jnp.sqrt(s)


def adamw_update(cfg: OptConfig, params, grads, opt):
    step = opt["step"] + 1
    lr = lr_at(cfg, step)
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    bc1 = 1 - cfg.b1 ** step.astype(jnp.float32)
    bc2 = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        mh = m / bc1
        vh = v / bc2
        step_ = mh / (jnp.sqrt(vh) + cfg.eps)
        # decoupled weight decay on matrices only (ndim >= 2)
        wd = cfg.weight_decay if p.ndim >= 2 else 0.0
        p_new = p.astype(jnp.float32) - lr * (step_ + wd * p.astype(jnp.float32))
        return p_new.astype(p.dtype), m, v

    flat_p, tdef = jax.tree_util.tree_flatten(params)
    flat_g = jax.tree_util.tree_leaves(grads)
    flat_m = jax.tree_util.tree_leaves(opt["m"])
    flat_v = jax.tree_util.tree_leaves(opt["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree_util.tree_unflatten(tdef, [o[0] for o in out])
    new_m = jax.tree_util.tree_unflatten(tdef, [o[1] for o in out])
    new_v = jax.tree_util.tree_unflatten(tdef, [o[2] for o in out])
    metrics = {"lr": lr, "grad_norm": gnorm}
    return new_p, {"m": new_m, "v": new_v, "step": step}, metrics
