from . import compression, optim, trainstep  # noqa: F401
