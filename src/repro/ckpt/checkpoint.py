"""Checkpointing with reconstructable manifest index (fault tolerance).

Layout of a checkpoint directory:

  step_<N>/                          — a FULL (base) step
    manifest.npz       — the TABLE: rows of (key, file, shape, dtype)
                         where key = fnv1a(param path) || shard coords
    dsmeta.npz         — DS-metadata of the manifest keys (D-bitmap etc.)
    <leaf files>.npy   — one array per param leaf (full array; elastic
                         restore re-places onto any mesh)
    DONE               — commit marker (atomic-rename protocol)

  step_<M>/                          — a DELTA step (base step + log)
    delta_log.npz      — a ``repro.replication.ChangeLog`` (LSN-stamped
                         insert/delete entries over manifest keys) plus the
                         delta file names and the base step number
    dsmeta.npz         — base DS-metadata advanced by the §4.3 insert rule
    <changed leaves>.npy — only leaves that changed vs the base
    DONE

Exactly as in the paper's main-memory DBMS setting, the *search index* over
the manifest is never serialized — only the DS-metadata is — and restore
begins by RECONSTRUCTING the key index with the compressed key sort
(``repro.core.reconstruct``).  For thousand-node restores the manifest has
one row per (leaf x shard) — millions of rows — and index rebuild cost is
exactly the paper's Table 1 problem.  Delta steps push the same premise one
step further: restore replays the log onto the base manifest and rebuilds
through ``ReconstructionPipeline.run_incremental`` — unchanged D-bitmap ⇒
only the changed rows are sorted and merged into the base run.  Unchanged
leaf payloads are read from the base step's directory (manifest file
entries are step-relative paths), so a delta step stores only what moved.

Fault-tolerance properties:
  * atomic commit (DONE marker written last; partial checkpoints ignored);
  * ``latest_step`` scans for the newest committed step -> crash-restart;
  * elastic resharding: arrays are saved unsharded and re-placed with
    ``jax.device_put`` under the *restoring* mesh's shardings, so a
    checkpoint from mesh A restores onto mesh B (different axis sizes);
  * delta chains: a delta step's base may itself be a delta step — restore
    folds the chain recursively.
"""

from __future__ import annotations

import json
import os
import shutil
from pathlib import Path

import jax
import numpy as np

from repro.core.keyformat import KeySet
from repro.core.metadata import DSMeta
from repro.core.pipeline import ReconstructionPipeline
from repro.core.reconstruct import ReconstructionResult

__all__ = [
    "save_checkpoint",
    "save_checkpoint_delta",
    "restore_checkpoint",
    "latest_step",
    "step_manifest",
    "CheckpointIndex",
]


def _fnv1a(s: str) -> int:
    h = 0xCBF29CE484222325
    for c in s.encode():
        h = ((h ^ c) * 0x100000001B3) & 0xFFFFFFFFFFFFFFFF
    return h


def _flatten(tree) -> list[tuple[str, np.ndarray]]:
    leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in leaves:
        name = "/".join(
            str(p.key) if hasattr(p, "key") else str(p) for p in path
        )
        out.append((name, np.asarray(leaf)))
    return out


def _manifest_key(name: str, shard: int = 0) -> np.ndarray:
    """96-bit manifest key: 64-bit path hash || 32-bit shard coord."""
    h = _fnv1a(name)
    return np.asarray([h >> 32, h & 0xFFFFFFFF, shard], dtype=np.uint32)


def save_checkpoint(ckpt_dir: str | os.PathLike, step: int, tree,
                    extra_meta: dict | None = None) -> Path:
    """Write a full (base) checkpoint step and commit it atomically.

    Persists every pytree leaf as its own file, the manifest table
    (hashed-path keys → files), and ONLY the DS-metadata of the manifest
    keys — the search index is reconstructed on restore, never stored.
    ``extra_meta`` lands in the step's ``meta.json``.  Returns the
    committed step directory.
    """
    root = Path(ckpt_dir)
    final = root / f"step_{step:08d}"
    tmp = root / f".tmp_step_{step:08d}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)

    rows_keys, rows_files, rows_names = [], [], []
    for i, (name, arr) in enumerate(_flatten(tree)):
        fn = f"leaf_{i:06d}.npy"
        np.save(tmp / fn, arr)
        rows_keys.append(_manifest_key(name))
        rows_files.append(fn)
        rows_names.append(name)

    keys = np.stack(rows_keys)  # (n, 3) uint32
    np.savez(
        tmp / "manifest.npz",
        keys=keys,
        files=np.asarray(rows_files),
        names=np.asarray(rows_names),
    )
    # persist ONLY the DS-metadata of the manifest keys — the index itself
    # is reconstructed on restore (the paper's premise)
    from repro.core.metadata import meta_from_keys

    meta = meta_from_keys(keys)
    np.savez(tmp / "dsmeta.npz", **meta.to_npz_dict())
    (tmp / "meta.json").write_text(json.dumps({"step": step, **(extra_meta or {})}))
    (tmp / "DONE").write_text("ok")
    if final.exists():
        shutil.rmtree(final)
    tmp.rename(final)  # atomic commit
    return final


def _manifest_view(root: Path, step: int):
    """The folded manifest of a step, host-side — no index reconstruction.

    Returns ``(live_keys (n, 3), live_rids (n,), files_slots, names_slots)``
    with file paths relative to the step's own directory.  Rids are *slot*
    indices into the (append-only) files/names lists; delta chains fold
    recursively through their logs.  This is the cheap manifest read the
    save path uses; restores go through ``CheckpointIndex``, which also
    rebuilds the search index.
    """
    step_dir = root / f"step_{step:08d}"
    if (step_dir / "manifest.npz").exists():
        m = np.load(step_dir / "manifest.npz")
        files = [str(x) for x in m["files"]]
        names = [str(x) for x in m["names"]]
        keys = m["keys"].astype(np.uint32)
        return keys, np.arange(len(files), dtype=np.uint32), files, names
    from repro.core.pipeline import fold_keyset
    from repro.replication import ChangeLog

    with np.load(step_dir / "delta_log.npz") as z:
        d = dict(z)
    base_step = int(d["base_step"])
    bkeys, brids, bfiles, bnames = _manifest_view(root, base_step)
    log = ChangeLog.from_npz_dict(d)
    keep, ins_words, ins_lengths, ins_rids = log.fold(brids)
    # fold through the pipeline's shared keyset fold — the same vectorized
    # mask+append every incremental call site uses — instead of a private
    # concatenate of the manifest columns
    base_ks = KeySet(
        words=bkeys,
        lengths=np.full(bkeys.shape[0], bkeys.shape[1] * 4, np.int32),
        rids=brids,
    )
    delta_ks = (
        KeySet(
            words=np.asarray(ins_words, np.uint32),
            lengths=np.asarray(ins_lengths, np.int32),
            rids=np.asarray(ins_rids, np.uint32),
        )
        if len(ins_rids)
        else None
    )
    folded = fold_keyset(base_ks, keep_rows=keep, delta=delta_ks)
    keys = np.asarray(folded.words, np.uint32)
    rids = np.asarray(folded.rids, np.uint32)
    rel = f"../step_{base_step:08d}/"
    files = [rel + f for f in bfiles] + [str(x) for x in d["files"]]
    names = list(bnames) + [str(x) for x in d["names"]]
    return keys, rids, files, names


def save_checkpoint_delta(ckpt_dir: str | os.PathLike, step: int, tree,
                          base_step: int, extra_meta: dict | None = None) -> Path:
    """Delta checkpoint: the change log vs ``base_step`` plus changed leaves.

    Only leaves whose payload differs from the base are written; unchanged
    leaves stay referenced in the base step's directory.  Manifest changes
    are recorded as an LSN-stamped ``ChangeLog``: a changed leaf is a
    DELETE of its base manifest row + an INSERT of the same key with a new
    slot; new/removed leaves are plain INSERTs/DELETEs.  The step's
    DS-metadata is the base metadata advanced by the §4.3 insert rule, so a
    restore that sees no new distinction bits replays the log through the
    *incremental* reconstruction path.
    """
    import bisect

    from repro.core.metadata import meta_on_insert
    from repro.replication import ChangeLog

    root = Path(ckpt_dir)
    base_dir = root / f"step_{base_step:08d}"
    if not (base_dir / "DONE").exists():
        raise FileNotFoundError(f"no committed base checkpoint at {base_dir}")
    # host-side manifest read — the save path never rebuilds the index
    base_keys, base_rids, base_files, base_names = _manifest_view(root, base_step)
    base_meta = DSMeta.from_npz_dict(dict(np.load(base_dir / "dsmeta.npz")))

    final = root / f"step_{step:08d}"
    tmp = root / f".tmp_step_{step:08d}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)

    live = {base_names[int(r)]: int(r) for r in base_rids}
    n_slots = len(base_files)
    log = ChangeLog(n_words=3)
    delta_files: list[str] = []
    delta_names: list[str] = []
    inserted_keys: list[np.ndarray] = []
    seen: set[str] = set()
    for name, arr in _flatten(tree):
        seen.add(name)
        if name in live:
            old = np.load(base_dir / base_files[live[name]])
            if (old.shape == arr.shape and old.dtype == arr.dtype
                    and np.array_equal(old, arr)):
                continue  # unchanged: stays a base reference
            log.append_deletes([live[name]])
        fn = f"leaf_{len(delta_files):06d}.npy"
        np.save(tmp / fn, arr)
        key = _manifest_key(name)
        log.append_inserts(key[None, :], [n_slots + len(delta_files)])
        delta_files.append(fn)
        delta_names.append(name)
        inserted_keys.append(key)
    for name, rid in live.items():
        if name not in seen:
            log.append_deletes([rid])

    # DS-metadata: base + insert rule per inserted manifest key (host-side
    # scalar work, as everywhere in the metadata layer)
    skeys = sorted(tuple(int(x) for x in row) for row in base_keys)
    meta = base_meta
    for key in inserted_keys:
        kt = tuple(int(x) for x in key)
        i = bisect.bisect_left(skeys, kt)
        a = np.asarray(skeys[i - 1], np.uint32) if i > 0 else None
        b = np.asarray(skeys[i], np.uint32) if i < len(skeys) else None
        meta = meta_on_insert(meta, a, key, b)
        bisect.insort(skeys, kt)

    np.savez(
        tmp / "delta_log.npz",
        **log.to_npz_dict(),
        files=np.asarray(delta_files),
        names=np.asarray(delta_names),
        base_step=np.asarray(base_step, np.int64),
    )
    np.savez(tmp / "dsmeta.npz", **meta.to_npz_dict())
    (tmp / "meta.json").write_text(
        json.dumps({"step": step, "base_step": base_step, **(extra_meta or {})})
    )
    (tmp / "DONE").write_text("ok")
    if final.exists():
        shutil.rmtree(final)
    tmp.rename(final)  # atomic commit
    return final


def latest_step(ckpt_dir: str | os.PathLike) -> int | None:
    """Newest *committed* step number in ``ckpt_dir`` (None when empty).

    Only steps whose DONE marker exists count — a crash mid-save leaves a
    ``.tmp_step_*`` directory that is never considered.
    """
    root = Path(ckpt_dir)
    if not root.exists():
        return None
    steps = [
        int(p.name.split("_")[1])
        for p in root.iterdir()
        if p.name.startswith("step_") and (p / "DONE").exists()
    ]
    return max(steps) if steps else None


def step_manifest(ckpt_dir: str | os.PathLike, step: int) -> dict:
    """Describe a committed step for publication on a replication stream.

    Returns ``{"ckpt_dir", "step", "base_step", "delta", "meta"}`` — what a
    catch-up consumer needs to locate (and fold, if it is a delta chain)
    the checkpoint: the directory, the step number, the base step a delta
    step folds onto (``None`` for a full step), and the step's
    ``meta.json`` contents.  Raises ``FileNotFoundError`` for uncommitted
    steps, so a manifest can never point at a torn checkpoint.
    """
    root = Path(ckpt_dir)
    step_dir = root / f"step_{step:08d}"
    if not (step_dir / "DONE").exists():
        raise FileNotFoundError(f"no committed checkpoint at {step_dir}")
    meta = json.loads((step_dir / "meta.json").read_text())
    delta = (step_dir / "delta_log.npz").exists()
    base = None
    if delta:
        with np.load(step_dir / "delta_log.npz") as z:
            base = int(z["base_step"])
    return {
        "ckpt_dir": str(root),
        "step": int(step),
        "base_step": base,
        "delta": delta,
        "meta": meta,
    }


class CheckpointIndex:
    """The reconstructed manifest index: hashed-path point lookups.

    For a delta step the base manifest is folded through the persisted
    change log and the index is rebuilt *incrementally* (base run merged
    with the changed rows) whenever the persisted D-bitmap still matches
    the base extraction — ``result.stats["incremental"]`` records which
    path ran.  ``files``/``names`` are slot lists: record ids index into
    them, and entries of a delta step refer into the base step's directory
    by relative path.

    The reconstruction is frozen into an epoch-stamped
    ``repro.core.snapshot.IndexSnapshot`` (the epoch round-trips through
    the step's ``meta.json`` — a stream-checkpointing primary stores its
    cell's epoch there and a restore resumes it) and lookups probe the
    snapshot with the backend's plan-cached ``lookup`` op.
    """

    def __init__(self, step_dir: Path, backend: str = "jnp"):
        from repro.core.snapshot import IndexSnapshot

        self.dir = Path(step_dir)
        self.backend = backend
        meta = DSMeta.from_npz_dict(dict(np.load(self.dir / "dsmeta.npz")))
        step_meta = json.loads((self.dir / "meta.json").read_text())
        self.snapshot_epoch = int(step_meta.get("snapshot_epoch", 0))
        if (self.dir / "delta_log.npz").exists():
            self._init_delta(meta)
        else:
            m = np.load(self.dir / "manifest.npz")
            self.keys = m["keys"].astype(np.uint32)
            self.files = [str(x) for x in m["files"]]
            self.names = [str(x) for x in m["names"]]
            ks = KeySet(
                words=self.keys,
                lengths=np.full(len(self.files), 12, np.int32),
                rids=np.arange(len(self.files), dtype=np.uint32),
            )
            # THE paper pipeline: extract by persisted D-bitmap -> sort -> build
            self._pipe = ReconstructionPipeline(backend=backend)
            self.result: ReconstructionResult = self._pipe.run(ks, meta=meta)
            self._keyset = ks
        self.snapshot = IndexSnapshot.from_result(
            self.result, epoch=self.snapshot_epoch
        )

    def _init_delta(self, meta: DSMeta) -> None:
        """Replay-on-restore: fold the base manifest through the log and
        rebuild via the incremental pipeline path (full-path fallback when
        the persisted bitmap grew past the base extraction)."""
        from repro.replication import ChangeLog

        with np.load(self.dir / "delta_log.npz") as z:
            d = dict(z)
        base_step = int(d["base_step"])
        base = CheckpointIndex(
            self.dir.parent / f"step_{base_step:08d}", backend=self.backend
        )
        log = ChangeLog.from_npz_dict(d)
        keep_rows, delta = log.fold_keyset(base._keyset)
        self._pipe = ReconstructionPipeline(backend=self.backend)
        self.result, self._keyset = self._pipe.run_incremental(
            base.result, base._keyset, delta, keep_rows=keep_rows, meta=meta
        )
        rel = f"../step_{base_step:08d}/"
        self.files = [rel + f for f in base.files] + [str(x) for x in d["files"]]
        self.names = list(base.names) + [str(x) for x in d["names"]]
        self.keys = np.asarray(self._keyset.words, np.uint32)

    def lookup(self, name: str) -> str:
        """Point lookup: param path → leaf file (tree search, not a scan).

        Probes the frozen snapshot through the backend's plan-cached
        ``lookup`` op, so a restore's million-lookup loop replays one
        compiled program per query-batch bucket.  Raises ``KeyError`` when
        the path is not in the manifest.
        """
        import jax.numpy as jnp

        q = jnp.asarray(_manifest_key(name))[None, :]
        found, rid = self.snapshot.lookup(self._pipe.backend, q)
        if not bool(found[0]):
            raise KeyError(name)
        return self.files[int(rid[0])]


def restore_checkpoint(ckpt_dir: str | os.PathLike, step: int, like_tree,
                       shardings=None, backend: str = "jnp") -> tuple[dict, dict]:
    """Restore a pytree; elastic re-placement under ``shardings`` if given.

    Every leaf is fetched through the reconstructed manifest index (point
    lookup by hashed path) — the restore path exercises the paper's index,
    not a linear scan.  ``backend`` selects the execution substrate the
    manifest index is reconstructed on (any registered backend name).
    Delta steps replay their change log onto the base step transparently.
    """
    step_dir = Path(ckpt_dir) / f"step_{step:08d}"
    if not (step_dir / "DONE").exists():
        raise FileNotFoundError(f"no committed checkpoint at {step_dir}")
    idx = CheckpointIndex(step_dir, backend=backend)

    flat, tdef = jax.tree_util.tree_flatten_with_path(like_tree)
    out = []
    for path, leaf in flat:
        name = "/".join(str(p.key) if hasattr(p, "key") else str(p) for p in path)
        arr = np.load(step_dir / idx.lookup(name))
        out.append(arr)
    tree = jax.tree_util.tree_unflatten(tdef, out)
    if shardings is not None:
        tree = jax.tree_util.tree_map(
            lambda x, s: jax.device_put(x, s), tree, shardings
        )
    stats = {
        "n_leaves": len(out),
        "index_height": idx.result.tree.height,
        "compression_ratio": idx.result.stats["compression_ratio"],
        "index_rebuild_s": idx.result.timings["total"],
        "index_backend": idx.result.stats["backend"],
        "incremental": bool(idx.result.stats.get("incremental", False)),
        "snapshot_epoch": idx.snapshot.epoch,
        "meta": json.loads((step_dir / "meta.json").read_text()),
    }
    return tree, stats
