"""Checkpointing with reconstructable manifest index (fault tolerance).

Layout of a checkpoint directory:

  step_<N>/
    manifest.npz       — the TABLE: rows of (key, file, shape, dtype)
                         where key = fnv1a(param path) || shard coords
    dsmeta.npz         — DS-metadata of the manifest keys (D-bitmap etc.)
    <leaf files>.npy   — one array per param leaf (full array; elastic
                         restore re-places onto any mesh)
    DONE               — commit marker (atomic-rename protocol)

Exactly as in the paper's main-memory DBMS setting, the *search index* over
the manifest is never serialized — only the DS-metadata is — and restore
begins by RECONSTRUCTING the key index with the compressed key sort
(``repro.core.reconstruct``).  For thousand-node restores the manifest has
one row per (leaf x shard) — millions of rows — and index rebuild cost is
exactly the paper's Table 1 problem.

Fault-tolerance properties:
  * atomic commit (DONE marker written last; partial checkpoints ignored);
  * ``latest_step`` scans for the newest committed step -> crash-restart;
  * elastic resharding: arrays are saved unsharded and re-placed with
    ``jax.device_put`` under the *restoring* mesh's shardings, so a
    checkpoint from mesh A restores onto mesh B (different axis sizes).
"""

from __future__ import annotations

import json
import os
import shutil
from pathlib import Path

import jax
import numpy as np

from repro.core.keyformat import KeySet
from repro.core.metadata import DSMeta
from repro.core.pipeline import ReconstructionPipeline
from repro.core.reconstruct import ReconstructionResult

__all__ = ["save_checkpoint", "restore_checkpoint", "latest_step", "CheckpointIndex"]


def _fnv1a(s: str) -> int:
    h = 0xCBF29CE484222325
    for c in s.encode():
        h = ((h ^ c) * 0x100000001B3) & 0xFFFFFFFFFFFFFFFF
    return h


def _flatten(tree) -> list[tuple[str, np.ndarray]]:
    leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in leaves:
        name = "/".join(
            str(p.key) if hasattr(p, "key") else str(p) for p in path
        )
        out.append((name, np.asarray(leaf)))
    return out


def _manifest_key(name: str, shard: int = 0) -> np.ndarray:
    """96-bit manifest key: 64-bit path hash || 32-bit shard coord."""
    h = _fnv1a(name)
    return np.asarray([h >> 32, h & 0xFFFFFFFF, shard], dtype=np.uint32)


def save_checkpoint(ckpt_dir: str | os.PathLike, step: int, tree,
                    extra_meta: dict | None = None) -> Path:
    root = Path(ckpt_dir)
    final = root / f"step_{step:08d}"
    tmp = root / f".tmp_step_{step:08d}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)

    rows_keys, rows_files, rows_names = [], [], []
    for i, (name, arr) in enumerate(_flatten(tree)):
        fn = f"leaf_{i:06d}.npy"
        np.save(tmp / fn, arr)
        rows_keys.append(_manifest_key(name))
        rows_files.append(fn)
        rows_names.append(name)

    keys = np.stack(rows_keys)  # (n, 3) uint32
    np.savez(
        tmp / "manifest.npz",
        keys=keys,
        files=np.asarray(rows_files),
        names=np.asarray(rows_names),
    )
    # persist ONLY the DS-metadata of the manifest keys — the index itself
    # is reconstructed on restore (the paper's premise)
    from repro.core.metadata import meta_from_keys

    meta = meta_from_keys(keys)
    np.savez(tmp / "dsmeta.npz", **meta.to_npz_dict())
    (tmp / "meta.json").write_text(json.dumps({"step": step, **(extra_meta or {})}))
    (tmp / "DONE").write_text("ok")
    if final.exists():
        shutil.rmtree(final)
    tmp.rename(final)  # atomic commit
    return final


def latest_step(ckpt_dir: str | os.PathLike) -> int | None:
    root = Path(ckpt_dir)
    if not root.exists():
        return None
    steps = [
        int(p.name.split("_")[1])
        for p in root.iterdir()
        if p.name.startswith("step_") and (p / "DONE").exists()
    ]
    return max(steps) if steps else None


class CheckpointIndex:
    """The reconstructed manifest index: hashed-path point lookups."""

    def __init__(self, step_dir: Path, backend: str = "jnp"):
        self.dir = step_dir
        m = np.load(step_dir / "manifest.npz")
        self.keys = m["keys"].astype(np.uint32)
        self.files = [str(x) for x in m["files"]]
        self.names = [str(x) for x in m["names"]]
        meta = DSMeta.from_npz_dict(dict(np.load(step_dir / "dsmeta.npz")))
        ks = KeySet(
            words=self.keys,
            lengths=np.full(len(self.files), 12, np.int32),
            rids=np.arange(len(self.files), dtype=np.uint32),
        )
        # THE paper pipeline: extract by persisted D-bitmap -> sort -> build
        pipe = ReconstructionPipeline(backend=backend)
        self.result: ReconstructionResult = pipe.run(ks, meta=meta)

    def lookup(self, name: str) -> str:
        from repro.core.btree import search_batch
        import jax.numpy as jnp

        q = jnp.asarray(_manifest_key(name))[None, :]
        found, rid, _ = search_batch(self.result.tree, q)
        if not bool(found[0]):
            raise KeyError(name)
        return self.files[int(rid[0])]


def restore_checkpoint(ckpt_dir: str | os.PathLike, step: int, like_tree,
                       shardings=None) -> tuple[dict, dict]:
    """Restore a pytree; elastic re-placement under ``shardings`` if given.

    Every leaf is fetched through the reconstructed manifest index (point
    lookup by hashed path) — the restore path exercises the paper's index,
    not a linear scan.
    """
    step_dir = Path(ckpt_dir) / f"step_{step:08d}"
    if not (step_dir / "DONE").exists():
        raise FileNotFoundError(f"no committed checkpoint at {step_dir}")
    idx = CheckpointIndex(step_dir)

    flat, tdef = jax.tree_util.tree_flatten_with_path(like_tree)
    out = []
    for path, leaf in flat:
        name = "/".join(str(p.key) if hasattr(p, "key") else str(p) for p in path)
        arr = np.load(step_dir / idx.lookup(name))
        out.append(arr)
    tree = jax.tree_util.tree_unflatten(tdef, out)
    if shardings is not None:
        tree = jax.tree_util.tree_map(
            lambda x, s: jax.device_put(x, s), tree, shardings
        )
    stats = {
        "n_leaves": len(out),
        "index_height": idx.result.tree.height,
        "compression_ratio": idx.result.stats["compression_ratio"],
        "index_rebuild_s": idx.result.timings["total"],
        "meta": json.loads((step_dir / "meta.json").read_text()),
    }
    return tree, stats
