"""Order-preserving index key formats (paper §4.1).

Every supported column type is encoded into a binary index key such that a
plain lexicographic *byte* comparison of encoded keys is equivalent to the
type's native ordering.  Multi-column keys are the concatenation of the
per-column encodings.  Encoding runs host-side in the data pipeline (numpy),
after which keys are packed into ``(n, W)`` big-endian ``uint32`` word arrays
— the representation every other layer (compression, sort, B-tree) operates
on.  Bit position ``p`` (paper convention: position 0 = most significant bit)
lives in word ``p // 32`` at shift ``31 - (p % 32)``.
"""

from __future__ import annotations

import struct
from dataclasses import dataclass
from typing import Iterable, Sequence

import numpy as np

__all__ = [
    "encode_int32",
    "encode_int64",
    "encode_float32",
    "encode_float64",
    "encode_decimal",
    "encode_fixed_string",
    "encode_varchar",
    "encode_multicolumn",
    "decode_int32",
    "decode_int64",
    "decode_float32",
    "decode_float64",
    "decode_decimal",
    "KeySet",
    "keys_to_words",
    "words_to_bytes",
]


# ---------------------------------------------------------------------------
# scalar encoders — each returns `bytes` whose lexicographic order matches the
# native order of the value (see Leis et al. [20] for int/float mappings).
# ---------------------------------------------------------------------------

def encode_int32(x: int) -> bytes:
    """Two's-complement int32 -> order-preserving bytes (flip sign bit)."""
    u = (int(x) & 0xFFFFFFFF) ^ 0x80000000
    return struct.pack(">I", u)


def decode_int32(b: bytes) -> int:
    u = struct.unpack(">I", b[:4])[0] ^ 0x80000000
    return u - 0x100000000 if u >= 0x80000000 else u


def encode_int64(x: int) -> bytes:
    u = (int(x) & 0xFFFFFFFFFFFFFFFF) ^ 0x8000000000000000
    return struct.pack(">Q", u)


def decode_int64(b: bytes) -> int:
    u = struct.unpack(">Q", b[:8])[0] ^ 0x8000000000000000
    return u - 0x10000000000000000 if u >= 0x8000000000000000 else u


def _float_bits_to_key(u: int, width_bits: int) -> int:
    sign = 1 << (width_bits - 1)
    # Negative floats: flip every bit (reverses their order and places them
    # below positives).  Non-negative: set the sign bit.
    if u & sign:
        return u ^ ((1 << width_bits) - 1)
    return u | sign


def _key_to_float_bits(k: int, width_bits: int) -> int:
    sign = 1 << (width_bits - 1)
    if k & sign:
        return k ^ sign
    return k ^ ((1 << width_bits) - 1)


def encode_float32(x: float) -> bytes:
    (u,) = struct.unpack(">I", struct.pack(">f", x))
    return struct.pack(">I", _float_bits_to_key(u, 32))


def decode_float32(b: bytes) -> float:
    (k,) = struct.unpack(">I", b[:4])
    return struct.unpack(">f", struct.pack(">I", _key_to_float_bits(k, 32)))[0]


def encode_float64(x: float) -> bytes:
    (u,) = struct.unpack(">Q", struct.pack(">d", x))
    return struct.pack(">Q", _float_bits_to_key(u, 64))


def decode_float64(b: bytes) -> float:
    (k,) = struct.unpack(">Q", b[:8])
    return struct.unpack(">d", struct.pack(">Q", _key_to_float_bits(k, 64)))[0]


def encode_decimal(unscaled: int | None, n_bytes: int) -> bytes:
    """decimal(m, n) per paper Fig. 4.

    ``unscaled`` is the integer value with the decimal point removed (the
    point's location lives in column metadata).  Layout: 1-byte header whose
    last bit (bit 0) is the sign (1 = negative) and second-to-last bit
    (bit 1) is the not-null flag (0 = null), followed by ``n_bytes`` of the
    magnitude, big-endian.  Mapping: negative -> toggle sign bit and all
    magnitude bits; otherwise toggle sign bit only.
    """
    if unscaled is None:
        # Nulls: header 0 sorts below every non-null entry.
        return bytes([0x00]) + b"\x00" * n_bytes
    neg = unscaled < 0
    mag = -unscaled if neg else unscaled
    if mag >= 1 << (8 * n_bytes):
        raise ValueError(f"decimal magnitude {mag} overflows {n_bytes} bytes")
    header = 0b00000010 | (1 if neg else 0)
    body = mag.to_bytes(n_bytes, "big")
    # toggle sign bit; if negative also toggle every magnitude bit
    header ^= 0b00000001
    if neg:
        body = bytes(b ^ 0xFF for b in body)
    return bytes([header]) + body


def decode_decimal(b: bytes, n_bytes: int) -> int | None:
    header, body = b[0], b[1 : 1 + n_bytes]
    if header == 0x00:
        return None
    sign_toggled = header ^ 0b00000001
    neg = bool(sign_toggled & 0b00000001)
    if neg:
        body = bytes(x ^ 0xFF for x in body)
    mag = int.from_bytes(body, "big")
    return -mag if neg else mag


def encode_fixed_string(s: bytes | str, length: int) -> bytes:
    b = s.encode("utf-8") if isinstance(s, str) else bytes(s)
    if len(b) > length:
        raise ValueError(f"fixed string longer than {length}")
    return b.ljust(length, b"\x00")


def encode_varchar(s: bytes | str, max_length: int) -> bytes:
    """varchar(n): the string itself plus one null terminator (paper §4.1.C).

    Null characters inside the string are rejected (the paper's assumption);
    the terminator makes shorter-prefix strings sort below their extensions
    and places the distinction bit inside the terminator byte.
    """
    b = s.encode("utf-8") if isinstance(s, str) else bytes(s)
    if b"\x00" in b:
        raise ValueError("varchar value must not contain null characters")
    if len(b) > max_length:
        raise ValueError(f"varchar longer than {max_length}")
    return b + b"\x00"


def encode_multicolumn(cols: Sequence[bytes]) -> bytes:
    """Index key over multiple columns = concatenation of column encodings."""
    return b"".join(cols)


# ---------------------------------------------------------------------------
# packing keys into uint32 word arrays
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class KeySet:
    """A packed set of index keys.

    words:   (n, W) uint32, big-endian word order (word 0 holds bit
             positions 0..31, bit 0 = MSB of word 0).
    lengths: (n,) int32 — original key length in bytes (shorter keys are
             zero-padded for comparison, per paper §4.1: padding does not
             affect order).
    rids:    (n,) uint32 record ids.
    """

    words: np.ndarray
    lengths: np.ndarray
    rids: np.ndarray

    @property
    def n(self) -> int:
        return int(self.words.shape[0])

    @property
    def n_words(self) -> int:
        return int(self.words.shape[1])

    @property
    def n_bits(self) -> int:
        return self.n_words * 32


def keys_to_words(
    keys: Iterable[bytes],
    rids: Sequence[int] | None = None,
    n_words: int | None = None,
) -> KeySet:
    """Pack variable-length byte keys into a (n, W) uint32 array.

    Keys shorter than the longest are padded with zero bytes (paper §4.1:
    "If one index key is shorter, it is padded with 0's in the binary
    comparison").
    """
    key_list = [bytes(k) for k in keys]
    n = len(key_list)
    if n == 0:
        raise ValueError("empty key set")
    max_len = max(len(k) for k in key_list)
    if n_words is None:
        n_words = max(1, (max_len + 3) // 4)
    elif n_words * 4 < max_len:
        raise ValueError(f"n_words={n_words} too small for {max_len}-byte keys")
    buf = np.zeros((n, n_words * 4), dtype=np.uint8)
    lengths = np.zeros((n,), dtype=np.int32)
    for i, k in enumerate(key_list):
        buf[i, : len(k)] = np.frombuffer(k, dtype=np.uint8)
        lengths[i] = len(k)
    words = buf.reshape(n, n_words, 4)
    # big-endian within each word: byte 0 is the most significant
    words = (
        words[..., 0].astype(np.uint32) << 24
        | words[..., 1].astype(np.uint32) << 16
        | words[..., 2].astype(np.uint32) << 8
        | words[..., 3].astype(np.uint32)
    )
    if rids is None:
        rid_arr = np.arange(n, dtype=np.uint32)
    else:
        rid_arr = np.asarray(rids, dtype=np.uint32)
    return KeySet(words=words, lengths=lengths, rids=rid_arr)


def words_to_bytes(words: np.ndarray, length: int | None = None) -> bytes:
    """Inverse of the packing for a single key row (testing/debug helper)."""
    w = np.asarray(words, dtype=np.uint32)
    out = bytearray()
    for word in w:
        out += int(word).to_bytes(4, "big")
    return bytes(out[:length]) if length is not None else bytes(out)
