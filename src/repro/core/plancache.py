"""Shape-bucketed compiled-program cache for the reconstruction hot path.

The pipeline's data-parallel stages are shape-polymorphic in Python but
shape-*monomorphic* once compiled: every distinct ``(n, n_words)`` the
serving layer throws at a stage retraces and recompiles the program (the
ROADMAP's "jnp merge retraces per (na, nb)" open item is one instance; an
un-jitted build stage dispatching dozens of eager ops per level is the
worse one).  Under a churny workload the sizes drift every call and the
hot path never stops compiling.

This module fixes the program count, not the programs: inputs are padded
up to **bucket boundaries** (powers of two with a per-op floor), compiled
programs are memoized in a :class:`PlanCache` keyed by
``(op, backend, bucket(s), n_words, static config)``, and the dynamic
part of the shape travels as data — a ``n_valid`` scalar operand.  A
serving load whose sizes drift within a bucket replays one compiled
program forever; crossing a bucket boundary costs exactly one new compile.

Padding is an **in-program** concept: every cached program takes
bucket-shaped buffers plus the dynamic valid count, and the first thing
the traced body does is normalize the pad lanes with masked
``jnp.where`` writes over the static bucket shape.  The host side
therefore never materializes sentinel rows per call — the pad fill is a
**cached device constant** (built once per ``(shape, fill, dtype)``, on
the cold path only) that inputs are copied into with one
``lax.dynamic_update_slice``.  Warm same-bucket calls are shape-stable
replays with zero host allocation and zero eager ``jnp.concatenate`` /
``jnp.full`` dispatches — the property the warm-path regression test
asserts by monkeypatching those two functions.

Normalization discipline (what keeps byte-identity):

* **sort / merge / fused extract+sort** — pad lanes are rewritten to the
  all-ones sentinel key and row ids from a reserved range (``>= 2**31``,
  above any real row position, which the backend contract bounds by
  ``n < 2**31``).  Under the (key, row) determinism contract the pads
  therefore compare strictly after every real pair — equal-key ties break
  on the row id — so the first ``n`` output rows are bit-for-bit the
  unpadded result and the pads are sliced off before anything downstream
  sees them.  Because the normalization happens *inside* the program, the
  incoming pad lanes may carry arbitrary garbage.
* **build / refresh / lookup** — pads are inert garbage lanes: every
  consumer clips its gathers to the valid count (carried as a dynamic
  scalar operand) and the padded tail is sliced off host-side.

Counters: ``hits``/``misses`` count cache lookups; ``traces`` counts
actual program *tracings* (the Python body of a cached program runs only
while JAX traces it, so the counter increments exactly once per compile).
``assert cache.stats()["traces"]`` unchanged across a call is the strong
form of "zero recompilations" the regression tests use.

Long-lived servers can bound the cache: ``PlanCache(max_programs=N)``
evicts the least-recently-used program past the bound (``evictions``
counts them; an evicted program that is needed again simply rebuilds and
re-traces).  ``auto_size=True`` additionally grows the bound when a
recent window of lookups shows a low hit rate *while* evictions occur —
the thrash signature of a bound set below the working set — doubling
``max_programs`` up to ``auto_size_cap``.  The default is unbounded.
"""

from __future__ import annotations

import math
import threading
import time
import warnings
from contextlib import contextmanager
from dataclasses import dataclass, field
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "BUCKET_MIN",
    "ROW_PAD_A",
    "ROW_PAD_B",
    "bucket",
    "bucket_for",
    "set_bucket_floor",
    "get_bucket_floor",
    "PlanCache",
    "get_cache",
    "reset_cache",
    "set_max_programs",
    "cache_stats",
    "scoped_cache",
    "donation_supported",
    "const_full",
    "iota_u32",
    "pad_tail",
    "pad_rows_2d",
    "pad_rows_1d",
    "pad_run",
    "sort_padded",
    "merge_padded",
    "fused_extract_sort_padded",
    "adjacent_dpos_padded",
    "ChunkPlan",
    "tune_chunking",
]

#: default bucket floor — tiny inputs share one program instead of one per
#: size; per-op overrides via :func:`set_bucket_floor`
BUCKET_MIN = 256

#: sentinel key word for pad rows (sorts last; ties break on the row id)
SENTINEL = np.uint32(0xFFFFFFFF)

#: pad row-id bases: above any real row position (the backend contract has
#: rows in [0, n) with n < 2**31) and distinct between the two merge runs
ROW_PAD_A = np.uint32(0x80000000)
ROW_PAD_B = np.uint32(0xC0000000)


def bucket(n: int, minimum: int = BUCKET_MIN) -> int:
    """Smallest power of two >= max(n, minimum)."""
    n = max(int(n), int(minimum))
    return 1 << (n - 1).bit_length()


#: per-op bucket floors (op -> floor); ops not listed use ``BUCKET_MIN``.
#: The knob exists because one floor does not fit every op: a lookup
#: query batch of 32 paying a 256-lane descent is pure wasted work, while
#: the sort floor below 256 would shatter the program cache for no win.
_FLOORS: dict[str, int] = {}


def set_bucket_floor(op: str, floor: int | None) -> None:
    """Override the bucket floor for one op family (``None`` restores the
    ``BUCKET_MIN`` default).  Lowering a floor after programs were traced
    at the old floor costs one re-trace per newly reachable bucket —
    change floors at startup, not mid-stream."""
    if floor is None:
        _FLOORS.pop(op, None)
        return
    if int(floor) < 1:
        raise ValueError(f"bucket floor must be >= 1, got {floor}")
    _FLOORS[op] = int(floor)


def get_bucket_floor(op: str) -> int:
    """The effective bucket floor for ``op``."""
    return _FLOORS.get(op, BUCKET_MIN)


def bucket_for(op: str, n: int) -> int:
    """Bucket of ``n`` under ``op``'s floor (see :func:`set_bucket_floor`)."""
    return bucket(n, get_bucket_floor(op))


@dataclass
class PlanCache:
    """Memoized compiled programs + hit/miss/trace/eviction counters.

    ``max_programs`` (optional) bounds the cache: past the bound the
    least-recently-used program is evicted (``programs`` is kept in
    recency order — a hit re-inserts its key at the end).

    ``auto_size=True`` turns on hit-rate-driven growth of the bound:
    whenever a window of ``auto_size_window`` lookups closes with a hit
    rate below ``auto_size_hit_rate`` *and* at least one eviction inside
    the window (i.e. the cache is thrashing, not merely cold), the bound
    doubles, capped at ``auto_size_cap``.  ``resizes`` counts the growth
    events (not part of :meth:`stats` — the zero-retrace assertions diff
    that dict exactly).

    The cache is thread-safe: lookups, inserts, LRU maintenance and
    every counter run under one re-entrant mutex, so N serving threads
    replaying warm programs concurrently with a rebuilding writer see
    exact ``hits``/``misses``/``traces`` counts (the concurrent
    zero-retrace assertions depend on that) and a racing cold miss
    builds each program exactly once — both racers get the *same*
    jitted callable, and JAX's own dispatch locking makes its first
    trace single-shot.  The compile itself (the first call of the
    returned program) happens outside the mutex.
    """

    programs: dict = field(default_factory=dict)
    hits: int = 0
    misses: int = 0
    traces: int = 0
    evictions: int = 0
    #: per-op-family counters keyed by the op name (``key[0]`` of every
    #: program key): op -> {"hits", "misses", "traces"}.  Surfaced through
    #: :meth:`stats` so benches and soaks can see *which* family retraced
    #: (e.g. the tenant-axis ``lookup_many`` bucketing) instead of only an
    #: aggregate trace delta.
    per_op: dict = field(default_factory=dict)
    _building_op: str | None = field(default=None, repr=False)
    max_programs: int | None = None
    auto_size: bool = False
    auto_size_cap: int = 4096
    auto_size_window: int = 64
    auto_size_hit_rate: float = 0.5
    resizes: int = 0
    _win_lookups: int = 0
    _win_hits: int = 0
    _win_evictions: int = 0
    _lock: threading.RLock = field(default_factory=threading.RLock, repr=False)

    def __post_init__(self) -> None:
        if self.max_programs is not None and int(self.max_programs) < 1:
            raise ValueError(
                f"max_programs must be >= 1 or None, got {self.max_programs}"
            )

    def program(self, key: tuple, builder: Callable[[], Callable]) -> Callable:
        """The compiled program for ``key``, building it on first use.

        Atomic under the cache mutex: concurrent lookups of the same
        cold key build it once and share the callable (``builder`` is
        cheap — it wraps, it does not compile)."""
        with self._lock:
            self._win_lookups += 1
            op_stats = self._per_op(key)
            prog = self.programs.get(key)
            if prog is not None:
                self.hits += 1
                self._win_hits += 1
                op_stats["hits"] += 1
                if self.max_programs is not None:
                    # refresh recency: dicts iterate in insertion order, so
                    # re-inserting makes the oldest entry the LRU victim
                    del self.programs[key]
                    self.programs[key] = prog
                self._maybe_grow()
                return prog
            self.misses += 1
            op_stats["misses"] += 1
            # builders wrap synchronously under the lock, so any cache.jit
            # they call attributes its future tracings to this op family
            prev_op, self._building_op = self._building_op, self._op_of(key)
            try:
                prog = builder()
            finally:
                self._building_op = prev_op
            self.programs[key] = prog
            if self.max_programs is not None:
                while len(self.programs) > int(self.max_programs):
                    victim = next(iter(self.programs))
                    del self.programs[victim]
                    self.evictions += 1
                    self._win_evictions += 1
            self._maybe_grow()
            return prog

    def _maybe_grow(self) -> None:
        """Close an auto-size window and grow the bound on thrash."""
        if not self.auto_size or self.max_programs is None:
            return
        if self._win_lookups < int(self.auto_size_window):
            return
        hit_rate = self._win_hits / max(self._win_lookups, 1)
        if self._win_evictions > 0 and hit_rate < float(self.auto_size_hit_rate):
            grown = min(int(self.max_programs) * 2, int(self.auto_size_cap))
            if grown > int(self.max_programs):
                self.max_programs = grown
                self.resizes += 1
        self._win_lookups = self._win_hits = self._win_evictions = 0

    @staticmethod
    def _op_of(key: tuple) -> str:
        """The op-family name of a program key (``key[0]`` by convention)."""
        return str(key[0]) if isinstance(key, tuple) and key else str(key)

    def _per_op(self, key_or_op) -> dict:
        """The per-op counter dict for a key/op (created on first touch);
        caller holds the lock."""
        op = key_or_op if isinstance(key_or_op, str) else self._op_of(key_or_op)
        entry = self.per_op.get(op)
        if entry is None:
            entry = self.per_op[op] = {"hits": 0, "misses": 0, "traces": 0}
        return entry

    def jit(self, fn: Callable, **jit_kwargs) -> Callable:
        """``jax.jit`` with trace counting: the wrapper body executes only
        while JAX traces, so ``traces`` counts compilations, not calls.
        When called from inside a :meth:`program` builder the tracings are
        also attributed to that program's op family in :attr:`per_op`
        (``"_unkeyed"`` otherwise)."""
        op = self._building_op or "_unkeyed"

        def traced(*args, **kwargs):
            with self._lock:  # exact trace counts under concurrent tracing
                self.traces += 1
                self._per_op(op)["traces"] += 1
            return fn(*args, **kwargs)

        jitted = jax.jit(traced, **jit_kwargs)
        if not jit_kwargs.get("donate_argnums"):
            return jitted

        # Donation is an aliasing *offer*: operands whose shape matches an
        # output are reused in place (and deleted); the rest — e.g. a
        # ladder merge's half-size input runs, whose output is strictly
        # larger — can't alias, stay live, and XLA warns about them at
        # lowering.  That warning is expected for the cascade's programs,
        # so silence it for donated programs only.
        def quiet(*args, **kwargs):
            with warnings.catch_warnings():
                warnings.filterwarnings(
                    "ignore", message="Some donated buffers were not usable"
                )
                return jitted(*args, **kwargs)

        return quiet

    def stats(self) -> dict[str, Any]:
        """Counter snapshot: ``programs`` (cached), ``hits``/``misses``
        (cache lookups), ``traces`` (actual JAX tracings — the number that
        must stay flat across a warm same-bucket call), ``evictions``
        (LRU victims), the configured ``max_programs`` bound, and
        ``per_op`` — the same hit/miss/trace counters broken down by op
        family (``key[0]`` of the program keys; ``cache.jit`` calls made
        outside a program builder land under ``"_unkeyed"``)."""
        with self._lock:
            return {
                "programs": len(self.programs),
                "hits": self.hits,
                "misses": self.misses,
                "traces": self.traces,
                "evictions": self.evictions,
                "max_programs": self.max_programs,
                "per_op": {op: dict(c) for op, c in self.per_op.items()},
            }

    def reset(self) -> None:
        """Drop every cached program and zero the counters (tests); the
        ``max_programs`` bound and auto-size configuration survive."""
        with self._lock:
            self.programs.clear()
            self.hits = self.misses = self.traces = self.evictions = 0
            self.resizes = 0
            self.per_op.clear()
            self._win_lookups = self._win_hits = self._win_evictions = 0


_GLOBAL = PlanCache()


def get_cache() -> PlanCache:
    """The process-global cache every backend shares by default."""
    return _GLOBAL


def reset_cache() -> None:
    """Reset the process-global cache (see :meth:`PlanCache.reset`) and
    drop the cached pad-fill device constants."""
    _GLOBAL.reset()
    _CONSTS.clear()


def set_max_programs(max_programs: int | None) -> None:
    """Bound (or unbound, with ``None``) the process-global cache.

    ``max_programs`` must be >= 1 (the hot program itself must stay
    cached) or ``None``.  Takes effect on the next
    :meth:`PlanCache.program` insert; already cached programs are
    evicted lazily as new ones land.
    """
    if max_programs is not None and int(max_programs) < 1:
        raise ValueError(
            f"max_programs must be >= 1 or None, got {max_programs}"
        )
    _GLOBAL.max_programs = (
        None if max_programs is None else int(max_programs)
    )


def cache_stats() -> dict[str, Any]:
    """Counter snapshot of the process-global cache (see
    :meth:`PlanCache.stats`); the zero-retrace assertions diff this."""
    return _GLOBAL.stats()


@contextmanager
def scoped_cache(cache: PlanCache | None = None):
    """Temporarily swap the process-global cache for ``cache`` (default: a
    fresh one).  Calibration passes like :func:`tune_chunking` run inside
    this scope so their probe programs neither pollute the serving cache
    nor pre-compile the programs a cold-path benchmark is about to time.
    The cached pad constants (``_CONSTS``) stay shared — they are
    immutable device values, not compiled programs.  The swap is a
    process-global rebind: run calibration before starting serving
    threads, not concurrently with them."""
    global _GLOBAL
    prev, _GLOBAL = _GLOBAL, (cache if cache is not None else PlanCache())
    try:
        yield _GLOBAL
    finally:
        _GLOBAL = prev


_DONATION_SUPPORTED: bool | None = None


def donation_supported() -> bool:
    """Whether this backend actually consumes ``donate_argnums`` buffers.

    Probed once per process: a tiny jitted add with a donated operand
    either deletes its input (donation honoured — CPU and TPU do) or
    leaves it alive with a "donation not implemented" warning (some
    platforms).  The padded-op wrappers fold the *effective* flag into
    their cache keys, so on a non-donating platform ``donate=True`` maps
    to the ordinary program instead of caching a useless variant.
    """
    global _DONATION_SUPPORTED
    if _DONATION_SUPPORTED is None:
        try:
            x = jnp.zeros((8,), jnp.uint32)
            with warnings.catch_warnings():
                warnings.simplefilter("ignore")
                jax.jit(lambda v: v + 1, donate_argnums=(0,))(x).block_until_ready()
            _DONATION_SUPPORTED = bool(x.is_deleted())
        except Exception:
            _DONATION_SUPPORTED = False
    return _DONATION_SUPPORTED


# ---------------------------------------------------------------------------
# padding helpers — cached fill constants + one dynamic_update_slice; no
# per-call jnp.concatenate / jnp.full on the warm path
# ---------------------------------------------------------------------------

#: (shape, dtype name, fill) -> committed device constant.  Bounded by the
#: set of distinct bucket shapes in flight — the same cardinality as the
#: program cache itself.  Cleared by :func:`reset_cache`.
_CONSTS: dict[tuple, jnp.ndarray] = {}


def const_full(shape: tuple, fill, dtype) -> jnp.ndarray:
    """A cached device constant of ``shape`` filled with ``fill``.

    Built with ``jnp.full`` exactly once per ``(shape, dtype, fill)`` —
    the cold path; warm callers get the committed array back.  Callers
    must treat it as immutable (every consumer copies out of it via
    ``dynamic_update_slice``, which is out-of-place).

    Values produced while JAX is *tracing* (the pad helpers also run
    inside traced program bodies, e.g. the kernel ops' tile pads) are
    tracers and must never enter the cache — they would leak out of
    their trace.  Tracer results are returned uncached; the constant
    commits the first time the helper runs eagerly.
    """
    dtype = jnp.dtype(dtype)
    key = (tuple(shape), dtype.name, int(fill))
    out = _CONSTS.get(key)
    if out is None:
        out = jnp.full(tuple(shape), fill, dtype)
        if not isinstance(out, jax.core.Tracer):
            _CONSTS[key] = out
    return out


def iota_u32(n: int) -> jnp.ndarray:
    """Cached ``arange(n)`` uint32 — the row-position operand of a freshly
    scanned table, shared across calls (lane i of a bucket-shaped buffer
    holds row i, which is exactly the iota's lane i).  Tracer results are
    never cached (see :func:`const_full`)."""
    key = ((int(n),), "uint32", -1)  # fill -1 never collides with const_full
    out = _CONSTS.get(key)
    if out is None:
        out = jnp.arange(int(n), dtype=jnp.uint32)
        if not isinstance(out, jax.core.Tracer):
            _CONSTS[key] = out
    return out


def pad_tail(x: jnp.ndarray, total: int, fill, axis: int = 0) -> jnp.ndarray:
    """Grow ``x`` to ``total`` along ``axis`` against a cached fill constant.

    Identity when ``x`` is already ``total`` long (the warm zero-copy
    case); otherwise one ``lax.dynamic_update_slice`` into the cached
    constant — no ``jnp.concatenate``, no per-call ``jnp.full``.  The
    pad content is ``fill``; cached programs that take a dynamic valid
    count normalize their pads in-program and do not depend on it.
    """
    x = jnp.asarray(x)
    n = int(x.shape[axis])
    total = int(total)
    if n == total:
        return x
    if n > total:
        raise ValueError(f"cannot pad {n} rows down to {total}")
    shape = list(x.shape)
    shape[axis] = total
    base = const_full(tuple(shape), fill, x.dtype)
    if n == 0:
        return base
    return jax.lax.dynamic_update_slice(base, x, (0,) * x.ndim)


def pad_rows_2d(x: jnp.ndarray, rows: int, fill) -> jnp.ndarray:
    """Pad the leading axis of (n, W) to ``rows`` with ``fill``."""
    return pad_tail(x, rows, fill, axis=0)


def pad_rows_1d(x: jnp.ndarray, rows: int, fill) -> jnp.ndarray:
    """Pad a (n,) vector to ``rows`` with ``fill`` (1-D twin of
    :func:`pad_rows_2d`)."""
    return pad_tail(x, rows, fill, axis=0)


def pad_run(
    keys: jnp.ndarray, rows: jnp.ndarray, b: int, row_base: np.uint32 = ROW_PAD_A
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Pad a (key, row) run to ``b`` rows with sentinel pairs that sort last.

    Pad lane ``i`` gets the all-ones key and row id ``row_base + i`` —
    the same values the in-program normalization writes, so eagerly
    padded runs and dynamically counted ones are interchangeable.
    """
    n = int(keys.shape[0])
    keys = jnp.asarray(keys, jnp.uint32)
    rows = jnp.asarray(rows, jnp.uint32)
    if n >= b:
        return keys, rows
    keys_p = pad_tail(keys, b, SENTINEL)
    pad_ids = jnp.uint32(row_base) + iota_u32(b)
    rows_p = jax.lax.dynamic_update_slice(pad_ids, rows, (0,))
    return keys_p, rows_p


def _mask_run(keys, rows, n_valid, row_base):
    """In-program pad normalization: lanes >= n_valid become (all-ones
    key, reserved row id) pairs that sort strictly last.  Runs inside the
    traced body, so the incoming pad lanes may be arbitrary garbage."""
    lane = jnp.arange(keys.shape[0], dtype=jnp.uint32)
    valid = lane < n_valid
    keys = jnp.where(valid[:, None], keys, jnp.uint32(SENTINEL))
    rows = jnp.where(valid, rows, jnp.uint32(row_base) + lane)
    return keys, rows


# ---------------------------------------------------------------------------
# bucketed stage wrappers — every program takes bucket-shaped buffers plus
# a dynamic n_valid operand (a np.uint32 scalar: fixed dtype, no retrace)
# ---------------------------------------------------------------------------

def sort_padded(
    keys: jnp.ndarray,
    rows: jnp.ndarray,
    *,
    backend: str = "jnp",
    impl: Callable | None = None,
    extra_key: tuple = (),
    cache: PlanCache | None = None,
    n_valid: int | None = None,
    keep_padded: bool = False,
    donate: bool = False,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Bucketed keyed sort: one compiled program per (backend, bucket, W).

    ``impl(keys_pad, rows_pad) -> (keys_sorted, rows_sorted)`` is the
    backend's sort body (default: the jnp keyed sort); it runs inside one
    jitted, cached program over the padded shapes, after the in-program
    pad normalization.  ``n_valid`` (optional) marks the inputs as
    already bucket-shaped with ``n_valid`` real rows — the zero-copy warm
    path; without it the inputs are padded here (one
    ``dynamic_update_slice`` against a cached constant).  ``keep_padded``
    returns the full bucket-shaped outputs (pads sorted to the tail) for
    callers that chain into another bucket-shaped stage.

    ``donate=True`` donates the *keys* operand to the compiled program
    (``donate_argnums``): XLA reuses its buffer for the output and the
    caller's array is consumed (``.is_deleted()``).  The rows operand is
    never donated — it is frequently the shared cached iota constant.
    Only donate buffers no other consumer will touch again.  The
    effective flag is part of the cache key, so donated and non-donated
    variants coexist; on platforms without donation support it degrades
    to the ordinary program (see :func:`donation_supported`).
    """
    cache = cache or _GLOBAL
    w = int(keys.shape[1])
    if n_valid is None:
        n = int(keys.shape[0])
        b = bucket_for("sort", n)
        keys = pad_tail(jnp.asarray(keys, jnp.uint32), b, SENTINEL)
        rows = pad_tail(jnp.asarray(rows, jnp.uint32), b, 0)
    else:
        n = int(n_valid)
        b = int(keys.shape[0])
    if impl is None:
        from .dbits import sort_words_keyed

        impl = sort_words_keyed

    don = bool(donate) and donation_supported()
    jit_kwargs = {"donate_argnums": (0,)} if don else {}

    def builder():
        def prog(kp, rp, nv):
            kp, rp = _mask_run(kp, rp, nv, ROW_PAD_A)
            return impl(kp, rp)

        return cache.jit(prog, **jit_kwargs)

    prog = cache.program(("sort", backend, b, w, don) + extra_key, builder)
    ks, rs = prog(keys, rows, np.uint32(n))
    if keep_padded:
        return ks, rs
    return ks[:n], rs[:n]


def merge_padded(
    keys_a: jnp.ndarray,
    rows_a: jnp.ndarray,
    keys_b: jnp.ndarray,
    rows_b: jnp.ndarray,
    *,
    backend: str = "jnp",
    impl: Callable | None = None,
    extra_key: tuple = (),
    cache: PlanCache | None = None,
    n_valid_a: int | None = None,
    n_valid_b: int | None = None,
    keep_padded: bool = False,
    donate: bool = False,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Bucketed two-run merge: one program per (backend, bucket_a, bucket_b, W).

    Fixes the per-``(na, nb)`` retrace of the jnp merge (ROADMAP): any
    (na, nb) inside the same bucket pair replays the cached program.  Pad
    lanes are normalized *inside* the program (sentinel key, reserved row
    range, distinct between the runs), so the first ``na + nb`` merged
    rows are byte-identical to the unpadded merge regardless of what the
    incoming pad lanes carried.

    ``keep_padded`` returns the full ``(ba + bb,)``-shaped outputs (pads
    sorted strictly to the tail) for cascade callers that chain the run
    into another padded merge with ``n_valid``.  ``donate=True`` offers
    all four run operands to XLA for in-place reuse — the merge is their
    last reader.  Whether a buffer is actually consumed is up to the
    aliaser (an operand strictly smaller than every output, like an
    equal-halves merge input, can't alias and stays live until its
    Python reference drops).  Never pass arrays you (or a cached
    constant) still need; the effective flag is part of the cache key
    (see :func:`sort_padded`).
    """
    cache = cache or _GLOBAL
    w = int(keys_a.shape[1])
    if n_valid_a is None:
        na = int(keys_a.shape[0])
        ba = bucket_for("merge", na)
        keys_a = pad_tail(jnp.asarray(keys_a, jnp.uint32), ba, SENTINEL)
        rows_a = pad_tail(jnp.asarray(rows_a, jnp.uint32), ba, 0)
    else:
        na, ba = int(n_valid_a), int(keys_a.shape[0])
    if n_valid_b is None:
        nb = int(keys_b.shape[0])
        bb = bucket_for("merge", nb)
        keys_b = pad_tail(jnp.asarray(keys_b, jnp.uint32), bb, SENTINEL)
        rows_b = pad_tail(jnp.asarray(rows_b, jnp.uint32), bb, 0)
    else:
        nb, bb = int(n_valid_b), int(keys_b.shape[0])
    if impl is None:
        from .dbits import merge_words_keyed

        impl = merge_words_keyed

    don = bool(donate) and donation_supported()
    jit_kwargs = {"donate_argnums": (0, 1, 2, 3)} if don else {}

    def builder():
        def prog(ka, ra, kb, rb, nva, nvb):
            ka, ra = _mask_run(ka, ra, nva, ROW_PAD_A)
            kb, rb = _mask_run(kb, rb, nvb, ROW_PAD_B)
            return impl(ka, ra, kb, rb)

        return cache.jit(prog, **jit_kwargs)

    prog = cache.program(("merge", backend, ba, bb, w, don) + extra_key, builder)
    km, rm = prog(keys_a, rows_a, keys_b, rows_b, np.uint32(na), np.uint32(nb))
    if keep_padded:
        return km, rm
    return km[: na + nb], rm[: na + nb]


def fused_extract_sort_padded(
    words: jnp.ndarray,
    plan,
    rows: jnp.ndarray,
    *,
    backend: str = "jnp",
    cache: PlanCache | None = None,
    n_valid: int | None = None,
    keep_padded: bool = False,
    donate: bool = False,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Bucketed fused extract+sort (one program per bucket *and* plan).

    All-ones pad keys extract to the all-ones compressed pattern — the
    maximum any real key can compress to, since the slack bits of the last
    compressed word are zero for every key — and the reserved row range
    breaks the tie, so pads still sort strictly last.  The pads are
    normalized in-program from the dynamic valid count.

    ``donate=True`` donates the *words* operand (the rows operand is
    often the shared cached iota and is never donated).  Only safe when
    nothing downstream reads the full-key buffer again — the pipeline's
    full path keeps it alive for the build stage and must not donate.
    """
    cache = cache or _GLOBAL
    w = int(words.shape[1])
    if n_valid is None:
        n = int(words.shape[0])
        b = bucket_for("sort", n)
        words = pad_tail(jnp.asarray(words, jnp.uint32), b, SENTINEL)
        rows = pad_tail(jnp.asarray(rows, jnp.uint32), b, 0)
    else:
        n = int(n_valid)
        b = int(words.shape[0])

    don = bool(donate) and donation_supported()
    jit_kwargs = {"donate_argnums": (0,)} if don else {}

    def builder():
        from .compress import extract_bits
        from .dbits import sort_words_keyed

        def prog(wp, rp, nv):
            wp, rp = _mask_run(wp, rp, nv, ROW_PAD_A)
            return sort_words_keyed(extract_bits(wp, plan), rp)

        return cache.jit(prog, **jit_kwargs)

    prog = cache.program(("fused", backend, b, w, plan, don), builder)
    ks, rs = prog(words, rows, np.uint32(n))
    if keep_padded:
        return ks, rs
    return ks[:n], rs[:n]


def adjacent_dpos_padded(
    comp_sorted: jnp.ndarray,
    *,
    backend: str = "jnp",
    cache: PlanCache | None = None,
    n_valid: int | None = None,
    donate: bool = False,
) -> np.ndarray:
    """Adjacent distinction-bit positions of a sorted run, bucketed.

    The refresh stage's device half: one cached program per (backend,
    bucket, Wc) computes all bucket-1 adjacent D-bit positions over
    in-program-normalized lanes (pads become all-ones rows, whose
    adjacencies land past the ``n - 1`` slice); the host half (the
    scatter-OR into the 32-bit bitmap words) lives in
    ``repro.core.metadata.meta_on_rebuild``.  Returns (n-1,) int32 with
    ``NO_DBIT`` at equal-key adjacencies.

    ``donate=True`` donates the sorted-run operand — refresh is the last
    consumer of the padded sorted keys in the full pipeline, so its
    scratch is reclaimed in place.  Only pass buffers nothing else reads
    afterwards.
    """
    cache = cache or _GLOBAL
    wc = int(comp_sorted.shape[1])
    if n_valid is None:
        n = int(comp_sorted.shape[0])
        if n < 2:
            return np.zeros((0,), np.int32)
        b = bucket_for("refresh", n)
        comp_sorted = pad_tail(jnp.asarray(comp_sorted, jnp.uint32), b, SENTINEL)
    else:
        n = int(n_valid)
        if n < 2:
            return np.zeros((0,), np.int32)
        b = int(comp_sorted.shape[0])

    don = bool(donate) and donation_supported()
    jit_kwargs = {"donate_argnums": (0,)} if don else {}

    def builder():
        from .dbits import adjacent_dbit_positions

        def prog(cp, nv):
            lane = jnp.arange(cp.shape[0], dtype=jnp.uint32)
            cp = jnp.where((lane < nv)[:, None], cp, jnp.uint32(SENTINEL))
            return adjacent_dbit_positions(cp)

        return cache.jit(prog, **jit_kwargs)

    prog = cache.program(("refresh_dpos", backend, b, wc, don), builder)
    return np.asarray(prog(comp_sorted, np.uint32(n))[: n - 1], np.int32)


# ---------------------------------------------------------------------------
# measured chunk auto-tuning — closes the ROADMAP "chunk-size auto-tuning"
# item: chunk_threshold / chunk_size picked from measured per-bucket sort
# and merge program costs instead of static constructor knobs
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ChunkPlan:
    """A measured chunking policy for one backend.

    ``chunk_size`` minimizes the modeled *warm* cascade wall at ``ref_n``
    keys; ``chunk_threshold`` is the smallest power-of-two key count at
    which the chunked path's cold cost (compiles + cascade) undercuts the
    extrapolated monolithic sort's compile, i.e. the point where paying
    the cascade's extra warm work buys back more compile time than it
    costs.  The raw per-candidate samples ride along for transparency
    (seconds; ``*_cold`` includes the compile, ``*_warm`` is a replay).
    """

    backend: str
    chunk_size: int
    chunk_threshold: int
    ref_n: int
    n_words: int
    sort_cold: dict[int, float]
    sort_warm: dict[int, float]
    merge_cold: dict[int, float]
    merge_warm: dict[int, float]


def _cascade_warm_model(n: int, c: int, sort_w: float, merge_w: float) -> float:
    """Modeled warm cascade wall: per-chunk sorts + per-level merges.

    The merge sample is one equal-halves merge at output bucket ``2c``;
    higher levels scale linearly in merged rows times the rank search's
    log(bucket) growth.
    """
    n_chunks = -(-n // c)
    cost = n_chunks * sort_w
    per_row = merge_w / (2 * c)
    base_steps = max(math.log2(c), 1.0)
    runs, size = n_chunks, c
    while runs > 1:
        merged_rows = (runs // 2) * 2 * size
        cost += per_row * merged_rows * (max(math.log2(size), 1.0) / base_steps)
        runs = -(-runs // 2)
        size *= 2
    return cost


def _median_wall(fn, iters: int) -> float:
    walls = []
    for _ in range(max(int(iters), 1)):
        t0 = time.perf_counter()
        out = fn()
        jax.tree_util.tree_map(
            lambda x: x.block_until_ready()
            if hasattr(x, "block_until_ready") else x,
            out,
        )
        walls.append(time.perf_counter() - t0)
    walls.sort()
    return walls[len(walls) // 2]


def tune_chunking(
    backend,
    *,
    candidates: tuple[int, ...] = (1 << 16, 1 << 17, 1 << 18),
    n_words: int = 2,
    ref_n: int = 1 << 20,
    iters: int = 1,
    seed: int = 0,
) -> ChunkPlan:
    """Calibrate ``chunk_size`` / ``chunk_threshold`` for one backend.

    For every candidate chunk bucket ``c`` this times the backend's sort
    program at bucket ``c`` (cold = compile + run, then warm replays) and
    one equal-halves merge at output bucket ``2c``, all inside a
    :func:`scoped_cache` so the probe programs never enter — or
    pre-compile — the serving cache.  ``backend`` is duck-typed: anything
    with the ``sort`` / ``merge_sorted`` backend-op signatures works, so
    this module needs no import of ``repro.backends``.

    * ``chunk_size`` — the candidate minimizing the modeled warm cascade
      wall at ``ref_n`` keys (chunk sorts + log-depth merge levels; see
      ``_cascade_warm_model``).
    * ``chunk_threshold`` — chunking exists to bound *compile* cost and
      peak memory, not to beat the monolithic program's warm wall (a
      cascade always does ~log extra passes).  The threshold is the
      smallest power of two ``N >= 2 * chunk_size`` where the
      extrapolated monolithic cold cost (compile fitted as a power law
      over the two largest candidates + n·log n warm scaling) exceeds
      the chunked path's cold cost; if the model never crosses below
      ``ref_n`` the threshold falls back to ``ref_n``.
    """
    rng = np.random.default_rng(seed)
    cands = sorted(int(c) for c in candidates)
    if len(cands) < 2:
        raise ValueError("need at least two chunk-size candidates")
    for c in cands:
        if c & (c - 1):
            raise ValueError(f"chunk-size candidates must be powers of two: {c}")

    sort_cold: dict[int, float] = {}
    sort_warm: dict[int, float] = {}
    merge_cold: dict[int, float] = {}
    merge_warm: dict[int, float] = {}

    with scoped_cache():
        for c in cands:
            keys = jnp.asarray(
                rng.integers(0, 2**32, size=(c, n_words), dtype=np.uint32)
            )
            rows = iota_u32(c)
            sort_cold[c] = _median_wall(
                lambda: backend.sort(keys, rows, n_valid=c, keep_padded=True), 1
            )
            sort_warm[c] = _median_wall(
                lambda: backend.sort(keys, rows, n_valid=c, keep_padded=True),
                iters,
            )
            # equal-halves merge at output bucket 2c: two independently
            # sorted c-runs with disjoint row ranges (the cascade invariant)
            h = c // 2
            ka, ra = backend.sort(keys[:h], iota_u32(h), n_valid=h,
                                  keep_padded=True)
            kb, rb = backend.sort(keys[h:], iota_u32(h), n_valid=h,
                                  keep_padded=True)
            rb = rb + jnp.uint32(h)
            merge_cold[c] = _median_wall(
                lambda: backend.merge_sorted(
                    ka, ra, kb, rb, n_valid_a=h, n_valid_b=h, keep_padded=True
                ),
                1,
            )
            merge_warm[c] = _median_wall(
                lambda: backend.merge_sorted(
                    ka, ra, kb, rb, n_valid_a=h, n_valid_b=h, keep_padded=True
                ),
                iters,
            )

    chunk_size = min(
        cands,
        key=lambda c: _cascade_warm_model(
            ref_n, c, sort_warm[c], merge_warm[c]
        ),
    )

    # -- threshold: where the monolithic compile stops being worth paying --
    c1, c2 = cands[-2], cands[-1]
    comp1 = max(sort_cold[c1] - sort_warm[c1], 1e-6)
    comp2 = max(sort_cold[c2] - sort_warm[c2], 1e-6)
    # compile-cost growth exponent, clamped to a sane superlinear band
    alpha = math.log(comp2 / comp1) / math.log(c2 / c1)
    alpha = min(max(alpha, 1.0), 3.0)
    c_ref = chunk_size
    sort_compile = max(sort_cold[c_ref] - sort_warm[c_ref], 1e-6)
    merge_compile = max(merge_cold[c_ref] - merge_warm[c_ref], 1e-6)
    warm_rate = sort_warm[c2] / (c2 * max(math.log2(c2), 1.0))

    def mono_cold(n: int) -> float:
        return comp2 * (n / c2) ** alpha + warm_rate * n * math.log2(n)

    def chunked_cold(n: int) -> float:
        levels = max(math.ceil(math.log2(-(-n // c_ref))), 1)
        compiles = sort_compile + sum(
            merge_compile * (2**lvl) ** (alpha - 1.0) for lvl in range(levels)
        )
        return compiles + _cascade_warm_model(
            n, c_ref, sort_warm[c_ref], merge_warm[c_ref]
        )

    threshold = ref_n
    n = 2 * chunk_size
    while n < ref_n:
        if chunked_cold(n) < mono_cold(n):
            threshold = n
            break
        n *= 2

    return ChunkPlan(
        backend=getattr(backend, "name", "?"),
        chunk_size=chunk_size,
        chunk_threshold=threshold,
        ref_n=int(ref_n),
        n_words=int(n_words),
        sort_cold=sort_cold,
        sort_warm=sort_warm,
        merge_cold=merge_cold,
        merge_warm=merge_warm,
    )
