"""Shape-bucketed compiled-program cache for the reconstruction hot path.

The pipeline's data-parallel stages are shape-polymorphic in Python but
shape-*monomorphic* once compiled: every distinct ``(n, n_words)`` the
serving layer throws at a stage retraces and recompiles the program (the
ROADMAP's "jnp merge retraces per (na, nb)" open item is one instance; an
un-jitted build stage dispatching dozens of eager ops per level is the
worse one).  Under a churny workload the sizes drift every call and the
hot path never stops compiling.

This module fixes the program count, not the programs: inputs are padded
up to **bucket boundaries** (powers of two with a floor), compiled
programs are memoized in a :class:`PlanCache` keyed by
``(op, backend, bucket(s), n_words, static config)``, and the dynamic
part of the shape travels as data — either a valid-count scalar operand or
sentinel padding rows that sort strictly after every real row.  A serving
load whose sizes drift within a bucket replays one compiled program
forever; crossing a bucket boundary costs exactly one new compile.

Padding discipline (what keeps byte-identity):

* **sort / merge / fused extract+sort** — pad rows carry the all-ones
  sentinel key and row ids from a reserved range (``>= 2**31``, above any
  real row position, which the backend contract bounds by ``n < 2**31``).
  Under the (key, row) determinism contract the pads therefore compare
  strictly after every real pair — equal-key ties break on the row id —
  so the first ``n`` output rows are bit-for-bit the unpadded result and
  the pads are sliced off before anything downstream sees them.
* **build / refresh** — pads are inert garbage lanes: every consumer
  clips its gathers to the valid count (carried as a dynamic scalar
  operand) and the padded tail is sliced off host-side.

Counters: ``hits``/``misses`` count cache lookups; ``traces`` counts
actual program *tracings* (the Python body of a cached program runs only
while JAX traces it, so the counter increments exactly once per compile).
``assert cache.stats()["traces"]`` unchanged across a call is the strong
form of "zero recompilations" the regression tests use.

Long-lived servers can bound the cache: ``PlanCache(max_programs=N)``
evicts the least-recently-used program past the bound (``evictions``
counts them; an evicted program that is needed again simply rebuilds and
re-traces).  The default is unbounded — the PR-3 behavior.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "BUCKET_MIN",
    "ROW_PAD_A",
    "ROW_PAD_B",
    "bucket",
    "PlanCache",
    "get_cache",
    "reset_cache",
    "set_max_programs",
    "cache_stats",
    "pad_rows_2d",
    "pad_rows_1d",
    "pad_run",
    "sort_padded",
    "merge_padded",
    "fused_extract_sort_padded",
    "adjacent_dpos_padded",
]

#: bucket floor — tiny inputs share one program instead of one per size
BUCKET_MIN = 256

#: sentinel key word for pad rows (sorts last; ties break on the row id)
SENTINEL = np.uint32(0xFFFFFFFF)

#: pad row-id bases: above any real row position (the backend contract has
#: rows in [0, n) with n < 2**31) and distinct between the two merge runs
ROW_PAD_A = np.uint32(0x80000000)
ROW_PAD_B = np.uint32(0xC0000000)


def bucket(n: int, minimum: int = BUCKET_MIN) -> int:
    """Smallest power of two >= max(n, minimum)."""
    n = max(int(n), int(minimum))
    return 1 << (n - 1).bit_length()


@dataclass
class PlanCache:
    """Memoized compiled programs + hit/miss/trace/eviction counters.

    ``max_programs`` (optional) bounds the cache: past the bound the
    least-recently-used program is evicted (``programs`` is kept in
    recency order — a hit re-inserts its key at the end).
    """

    programs: dict = field(default_factory=dict)
    hits: int = 0
    misses: int = 0
    traces: int = 0
    evictions: int = 0
    max_programs: int | None = None

    def __post_init__(self) -> None:
        if self.max_programs is not None and int(self.max_programs) < 1:
            raise ValueError(
                f"max_programs must be >= 1 or None, got {self.max_programs}"
            )

    def program(self, key: tuple, builder: Callable[[], Callable]) -> Callable:
        """The compiled program for ``key``, building it on first use."""
        prog = self.programs.get(key)
        if prog is not None:
            self.hits += 1
            if self.max_programs is not None:
                # refresh recency: dicts iterate in insertion order, so
                # re-inserting makes the oldest entry the LRU victim
                del self.programs[key]
                self.programs[key] = prog
            return prog
        self.misses += 1
        prog = builder()
        self.programs[key] = prog
        if self.max_programs is not None:
            while len(self.programs) > int(self.max_programs):
                victim = next(iter(self.programs))
                del self.programs[victim]
                self.evictions += 1
        return prog

    def jit(self, fn: Callable, **jit_kwargs) -> Callable:
        """``jax.jit`` with trace counting: the wrapper body executes only
        while JAX traces, so ``traces`` counts compilations, not calls."""

        def traced(*args, **kwargs):
            self.traces += 1
            return fn(*args, **kwargs)

        return jax.jit(traced, **jit_kwargs)

    def stats(self) -> dict[str, Any]:
        """Counter snapshot: ``programs`` (cached), ``hits``/``misses``
        (cache lookups), ``traces`` (actual JAX tracings — the number that
        must stay flat across a warm same-bucket call), ``evictions``
        (LRU victims) and the configured ``max_programs`` bound."""
        return {
            "programs": len(self.programs),
            "hits": self.hits,
            "misses": self.misses,
            "traces": self.traces,
            "evictions": self.evictions,
            "max_programs": self.max_programs,
        }

    def reset(self) -> None:
        """Drop every cached program and zero the counters (tests); the
        ``max_programs`` bound is configuration and survives."""
        self.programs.clear()
        self.hits = self.misses = self.traces = self.evictions = 0


_GLOBAL = PlanCache()


def get_cache() -> PlanCache:
    """The process-global cache every backend shares by default."""
    return _GLOBAL


def reset_cache() -> None:
    """Reset the process-global cache (see :meth:`PlanCache.reset`)."""
    _GLOBAL.reset()


def set_max_programs(max_programs: int | None) -> None:
    """Bound (or unbound, with ``None``) the process-global cache.

    ``max_programs`` must be >= 1 (the hot program itself must stay
    cached) or ``None``.  Takes effect on the next
    :meth:`PlanCache.program` insert; already cached programs are
    evicted lazily as new ones land.
    """
    if max_programs is not None and int(max_programs) < 1:
        raise ValueError(
            f"max_programs must be >= 1 or None, got {max_programs}"
        )
    _GLOBAL.max_programs = (
        None if max_programs is None else int(max_programs)
    )


def cache_stats() -> dict[str, Any]:
    """Counter snapshot of the process-global cache (see
    :meth:`PlanCache.stats`); the zero-retrace assertions diff this."""
    return _GLOBAL.stats()


# ---------------------------------------------------------------------------
# padding helpers
# ---------------------------------------------------------------------------

def pad_rows_2d(x: jnp.ndarray, rows: int, fill) -> jnp.ndarray:
    """Pad the leading axis of (n, W) to ``rows`` with ``fill``."""
    pad = rows - int(x.shape[0])
    if pad <= 0:
        return x
    return jnp.concatenate(
        [x, jnp.full((pad,) + tuple(x.shape[1:]), fill, x.dtype)], axis=0
    )


def pad_rows_1d(x: jnp.ndarray, rows: int, fill) -> jnp.ndarray:
    """Pad a (n,) vector to ``rows`` with ``fill`` (1-D twin of
    :func:`pad_rows_2d`)."""
    pad = rows - int(x.shape[0])
    if pad <= 0:
        return x
    return jnp.concatenate([x, jnp.full((pad,), fill, x.dtype)])


def pad_run(
    keys: jnp.ndarray, rows: jnp.ndarray, b: int, row_base: np.uint32 = ROW_PAD_A
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Pad a (key, row) run to ``b`` rows with sentinel pairs that sort last."""
    n = int(keys.shape[0])
    pad = b - n
    if pad <= 0:
        return jnp.asarray(keys, jnp.uint32), jnp.asarray(rows, jnp.uint32)
    keys_p = pad_rows_2d(jnp.asarray(keys, jnp.uint32), b, SENTINEL)
    rows_p = jnp.concatenate(
        [
            jnp.asarray(rows, jnp.uint32),
            jnp.uint32(row_base) + jnp.arange(pad, dtype=jnp.uint32),
        ]
    )
    return keys_p, rows_p


# ---------------------------------------------------------------------------
# bucketed stage wrappers
# ---------------------------------------------------------------------------

def sort_padded(
    keys: jnp.ndarray,
    rows: jnp.ndarray,
    *,
    backend: str = "jnp",
    impl: Callable | None = None,
    extra_key: tuple = (),
    cache: PlanCache | None = None,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Bucketed keyed sort: one compiled program per (backend, bucket, W).

    ``impl(keys_pad, rows_pad) -> (keys_sorted, rows_sorted)`` is the
    backend's sort body (default: the jnp keyed sort); it runs inside one
    jitted, cached program over the padded shapes.
    """
    cache = cache or _GLOBAL
    n, w = int(keys.shape[0]), int(keys.shape[1])
    b = bucket(n)
    if impl is None:
        from .dbits import sort_words_keyed

        impl = sort_words_keyed
    prog = cache.program(
        ("sort", backend, b, w) + extra_key, lambda: cache.jit(impl)
    )
    kp, rp = pad_run(keys, rows, b)
    ks, rs = prog(kp, rp)
    return ks[:n], rs[:n]


def merge_padded(
    keys_a: jnp.ndarray,
    rows_a: jnp.ndarray,
    keys_b: jnp.ndarray,
    rows_b: jnp.ndarray,
    *,
    backend: str = "jnp",
    impl: Callable | None = None,
    extra_key: tuple = (),
    cache: PlanCache | None = None,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Bucketed two-run merge: one program per (backend, bucket_a, bucket_b, W).

    Fixes the per-``(na, nb)`` retrace of the jnp merge (ROADMAP): any
    (na, nb) inside the same bucket pair replays the cached program.  Pad
    pairs sort after every real pair (sentinel key, reserved row range,
    distinct between the runs), so the first ``na + nb`` merged rows are
    byte-identical to the unpadded merge.
    """
    cache = cache or _GLOBAL
    na, nb = int(keys_a.shape[0]), int(keys_b.shape[0])
    w = int(keys_a.shape[1])
    ba, bb = bucket(na), bucket(nb)
    if impl is None:
        from .dbits import merge_words_keyed

        impl = merge_words_keyed
    prog = cache.program(
        ("merge", backend, ba, bb, w) + extra_key, lambda: cache.jit(impl)
    )
    ka, ra = pad_run(keys_a, rows_a, ba, ROW_PAD_A)
    kb, rb = pad_run(keys_b, rows_b, bb, ROW_PAD_B)
    km, rm = prog(ka, ra, kb, rb)
    return km[: na + nb], rm[: na + nb]


def fused_extract_sort_padded(
    words: jnp.ndarray,
    plan,
    rows: jnp.ndarray,
    *,
    backend: str = "jnp",
    cache: PlanCache | None = None,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Bucketed fused extract+sort (one program per bucket *and* plan).

    All-ones pad keys extract to the all-ones compressed pattern — the
    maximum any real key can compress to, since the slack bits of the last
    compressed word are zero for every key — and the reserved row range
    breaks the tie, so pads still sort strictly last.
    """
    cache = cache or _GLOBAL
    n, w = int(words.shape[0]), int(words.shape[1])
    b = bucket(n)

    def builder():
        from .compress import extract_bits
        from .dbits import sort_words_keyed

        def prog(wp, rp):
            return sort_words_keyed(extract_bits(wp, plan), rp)

        return cache.jit(prog)

    prog = cache.program(("fused", backend, b, w, plan), builder)
    wp, rp = pad_run(words, rows, b)
    ks, rs = prog(wp, rp)
    return ks[:n], rs[:n]


def adjacent_dpos_padded(
    comp_sorted: jnp.ndarray,
    *,
    backend: str = "jnp",
    cache: PlanCache | None = None,
) -> np.ndarray:
    """Adjacent distinction-bit positions of a sorted run, bucketed.

    The refresh stage's device half: one cached program per (backend,
    bucket, Wc) computes all n-1 adjacent D-bit positions; the host half
    (the scatter-OR into the 32-bit bitmap words) lives in
    ``repro.core.metadata.meta_on_rebuild``.  Returns (n-1,) int32 with
    ``NO_DBIT`` at equal-key adjacencies.
    """
    cache = cache or _GLOBAL
    n, wc = int(comp_sorted.shape[0]), int(comp_sorted.shape[1])
    if n < 2:
        return np.zeros((0,), np.int32)
    b = bucket(n)

    def builder():
        from .dbits import adjacent_dbit_positions

        return cache.jit(adjacent_dbit_positions)

    prog = cache.program(("refresh_dpos", backend, b, wc), builder)
    comp_pad = pad_rows_2d(jnp.asarray(comp_sorted, jnp.uint32), b, SENTINEL)
    return np.asarray(prog(comp_pad)[: n - 1], np.int32)
