"""Bottom-up bulk build of the partial-key B+tree (paper §4.2, §5.3).

TPU adaptation (DESIGN.md §2): pointer-chasing nodes become
structure-of-arrays *levels* — each level is a dict of `(n_nodes, fanout)`
arrays — so bulk build is reshapes + gathers and batched search is a
vectorized descent.  Entry layout is the paper's: every entry carries a
``pk``-bit partial key, the distinction bit position against the previous
entry's (highest) key, the key length, and a record id (leaf) or child
pointer + highest-key pointer (non-leaf).

Node geometry follows §5.3 exactly: 256-byte nodes, 24-byte header (+8-byte
next pointer in leaves), 16-byte leaf entries and 24-byte non-leaf entries
=> max fanout 14 (leaf) / 9 (non-leaf), filled to ``max_fanout * fill``
(default fill 0.9).

Partial-key bits are obtained by paper option **C.b**: sliced from the
record's full key via the record id (the base table is memory-resident in
the target systems, so the deref is a gather).  Point lookups can use the
partial-key screening path (`search_batch_partial`) which derefs only
entries whose partial window matches the query — the vectorized analogue of
Bohannon et al.'s sequential leaf procedure.
"""

from __future__ import annotations

from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from .dbits import (
    NO_DBIT,
    adjacent_dbit_positions,
    dbit_position_pairwise,
    lex_compare_le,
)
from .metadata import DSMeta

__all__ = [
    "BTreeConfig",
    "BTree",
    "build_btree",
    "search_batch",
    "search_batch_partial",
    "lookup_batch_planned",
    "lookup_many_planned",
    "stack_trees",
    "tree_geometry",
    "NOT_FOUND_RID",
]

NODE_BYTES = 256
LEAF_HEADER = 24 + 8  # header + next-node pointer
NONLEAF_HEADER = 24
LEAF_ENTRY = 16
NONLEAF_ENTRY = 24
LEAF_MAX_FANOUT = (NODE_BYTES - LEAF_HEADER) // LEAF_ENTRY  # 14
NONLEAF_MAX_FANOUT = (NODE_BYTES - NONLEAF_HEADER) // NONLEAF_ENTRY  # 9


@dataclass(frozen=True)
class BTreeConfig:
    pk_bits: int = 16
    fill_factor: float = 0.9

    @property
    def leaf_cap(self) -> int:
        return max(2, int(LEAF_MAX_FANOUT * self.fill_factor))

    @property
    def nonleaf_cap(self) -> int:
        return max(2, int(NONLEAF_MAX_FANOUT * self.fill_factor))


@jax.tree_util.register_pytree_node_class
@dataclass
class BTree:
    """SoA partial-key B+tree.

    levels: root-first tuple of non-leaf levels, each a dict with
            child (m,c) int32 (-1 = empty), hi (m,c) int32 (index into the
            sorted key order), pk (m,c) uint32, dpos (m,c) int32,
            klen (m,c) int32.
    leaf:   dict with rid (L,c) uint32, pk (L,c) uint32, dpos (L,c) int32,
            klen (L,c) int32, valid (L,c) bool.
    sorted_full: (n, W) uint32 — full keys in sorted order (the "pointer to
            the highest index key" target; rows of the memory-resident table
            in key order).
    sorted_rids: (n,) uint32.
    """

    levels: tuple
    leaf: dict
    sorted_full: jnp.ndarray
    sorted_rids: jnp.ndarray
    n_keys: int
    config: BTreeConfig

    def tree_flatten(self):
        children = (self.levels, self.leaf, self.sorted_full, self.sorted_rids)
        aux = (self.n_keys, self.config)
        return children, aux

    @classmethod
    def tree_unflatten(cls, aux, children):
        levels, leaf, sorted_full, sorted_rids = children
        return cls(levels, leaf, sorted_full, sorted_rids, *aux)

    @property
    def height(self) -> int:
        return len(self.levels) + 1

    def nodes_per_level(self) -> list[int]:
        return [int(l["child"].shape[0]) for l in self.levels] + [
            int(self.leaf["rid"].shape[0])
        ]

    def memory_bytes(self) -> int:
        return sum(self.nodes_per_level()) * NODE_BYTES


def _slice_bits(words: jnp.ndarray, start: jnp.ndarray, pk_bits: int) -> jnp.ndarray:
    """pk_bits bits of (m, W) keys starting at bit position start (m,)."""
    W = words.shape[-1]
    start = jnp.clip(start, 0, W * 32 - 1)
    wi = start // 32
    sh = (start % 32).astype(jnp.uint32)
    w0 = jnp.take_along_axis(words, wi[..., None], axis=-1)[..., 0]
    wi1 = jnp.minimum(wi + 1, W - 1)
    w1 = jnp.take_along_axis(words, wi1[..., None], axis=-1)[..., 0]
    w1 = jnp.where(wi + 1 < W, w1, 0)
    hi = w0 << sh
    lo = jnp.where(sh == 0, jnp.uint32(0), w1 >> (jnp.uint32(32) - sh))
    window = hi | lo
    return window >> jnp.uint32(32 - pk_bits)


def _np_pad(x: np.ndarray, rows: int, fill) -> np.ndarray:
    pad = rows - x.shape[0]
    if pad <= 0:
        return x
    return np.concatenate([x, np.full((pad,) + x.shape[1:], fill, dtype=x.dtype)])


def _leaf_program(cache, slice_fn, pk: int, donate: bool = False):
    """Stage-3 entry computation for the leaf level, one jitted program.

    All heavy per-entry work — the row gathers (sorted full keys, lengths,
    rids), the adjacent compressed-key D-bit positions mapped through
    D-offset, and the partial-key windows — fuses into a single compiled
    body over the bucket-padded shapes.  ``n`` and ``n_off`` arrive as
    dynamic scalar operands so every size inside the bucket replays the
    same program; padded lanes are clipped garbage, sliced off by the
    caller before assembly.

    ``donate`` donates the sort-permutation operand (``row_pad``, argnum
    4) — its information is fully absorbed into the gathers, so it is
    scratch after this program.  ``comp_pad``/``words_pad`` and the
    possibly-cached constants (lengths, rids) are never donated: the
    level programs and the caller still read them.
    """

    def prog(comp_pad, words_pad, lengths_pad, rids_pad, row_pad, d_off_pad, n, n_off):
        rowc = jnp.clip(row_pad, 0, jnp.maximum(n - 1, 0)).astype(jnp.int32)
        sorted_full = words_pad[rowc]
        klen = lengths_pad[rowc]
        rid_sorted = rids_pad[rowc]
        # distinction bit positions per sorted entry (entry 0 -> position 0)
        dpos_comp = adjacent_dbit_positions(comp_pad)
        safe = jnp.clip(dpos_comp, 0, n_off - 1)
        tail = jnp.where(dpos_comp == NO_DBIT, jnp.int32(0), d_off_pad[safe])
        dpos_full = jnp.concatenate(
            [jnp.zeros((1,), jnp.int32), tail.astype(jnp.int32)]
        )
        # partial key: pk bits following the distinction bit position
        # (option C.b: sliced from the record's full key)
        pkeys = slice_fn(sorted_full, dpos_full + 1, pk).astype(jnp.uint32)
        return sorted_full, klen, rid_sorted, dpos_full, pkeys

    return cache.jit(prog, **({"donate_argnums": (4,)} if donate else {}))


def _level_program(cache, slice_fn, pk: int, donate: bool = False):
    """Stage-3 entry computation for one non-leaf level, one jitted program.

    The adjacent highest-key D-bits (via compressed keys + D-offset, §5.3),
    the entry partial-key windows, and the key-length gather for a whole
    level run as one compiled body over bucket-padded node rows.

    ``donate`` donates the per-level hi-index operand (``hi_pad``, argnum
    0) — it is rebuilt host-side for every level, so the program may
    reuse its buffer.  The shared leaf outputs (``full_pad``/``klen_pad``)
    and ``comp_pad`` are read by every level and never donated.
    """

    def prog(hi_pad, comp_pad, full_pad, klen_pad, d_off_pad, n, n_off):
        hi_prev = jnp.concatenate([hi_pad[:1], hi_pad[:-1]])
        ac = jnp.clip(hi_prev, 0, n - 1)
        bc = jnp.clip(hi_pad, 0, n - 1)
        a = comp_pad[ac]
        b = comp_pad[bc]
        dc = dbit_position_pairwise(a, b)
        dfull = jnp.where(
            dc == NO_DBIT, jnp.int32(0), d_off_pad[jnp.clip(dc, 0, n_off - 1)]
        ).astype(jnp.int32)
        dfull = dfull.at[0].set(0)
        epk = slice_fn(full_pad[bc], dfull + 1, pk).astype(jnp.uint32)
        klen_hi = jnp.take(klen_pad, bc)
        return dfull, epk, klen_hi

    return cache.jit(prog, **({"donate_argnums": (0,)} if donate else {}))


def build_btree(
    comp_sorted: jnp.ndarray,
    row_sorted: jnp.ndarray,
    meta: DSMeta,
    table_words: jnp.ndarray,
    table_lengths: jnp.ndarray | None = None,
    config: BTreeConfig = BTreeConfig(),
    rids: jnp.ndarray | None = None,
    *,
    slice_fn=None,
    backend_name: str = "jnp",
    program_key_extra: tuple = (),
    cache=None,
    n_valid: int | None = None,
    donate: bool = False,
) -> BTree:
    """Bulk-build the tree from sorted compressed keys + row positions (§5.3).

    ``table_words`` is the base table's full keys by *row*; ``row_sorted``
    is the sort permutation over rows; ``rids`` (optional) maps rows to
    record ids stored in leaf entries (defaults to the row index).
    Distinction bit positions of entries come from adjacent *compressed*
    keys mapped through D-offset — no full-key comparisons are needed
    anywhere in the build, which is the point of the paper.

    Compiled-plan execution: each level's entry computation is one jitted
    program, cached in the shared plan cache (``repro.core.plancache``)
    under static ``(backend, bucket, n_words, leaf/nonleaf caps, pk)``;
    only cheap host-side reshapes happen between program calls.
    ``slice_fn`` lets a backend substitute its own partial-key window
    gather (the Pallas tiled kernel in ``repro.kernels.build``) — it must
    be bit-identical to ``_slice_bits``, and any configuration baked into
    the closure (tile size, interpret mode) must travel in
    ``program_key_extra`` so differently-configured backends never share a
    cached program.

    ``n_valid`` marks ``comp_sorted``/``row_sorted`` as already
    bucket-shaped with ``n_valid`` real rows (the pipeline's zero-copy
    chaining out of the sort stage: the sort's padded outputs feed the
    build programs directly).  Pad lanes may carry arbitrary content —
    sort sentinels or zeros — because every program gather clips to the
    dynamic ``n``/``n_off`` operands and the padded tail is sliced off
    before assembly; the pad-contents property test pins this down.

    ``donate=True`` donates the build programs' scratch operands — the
    sort permutation (``row_pad``) into the leaf program and the
    per-level hi-index buffer into each level program.  The caller must
    not read the (possibly identity-padded) ``row_sorted`` buffer again
    after the build; everything else the programs touch (``comp_sorted``,
    ``table_words``, the cached iota/const operands) is read-only and
    never donated.  The flag is part of the program cache keys, so
    donated and non-donated variants coexist.
    """
    from . import plancache

    cache = cache or plancache.get_cache()
    if slice_fn is None:
        slice_fn = _slice_bits
    donate = bool(donate) and plancache.donation_supported()

    n = int(comp_sorted.shape[0]) if n_valid is None else int(n_valid)
    W = int(table_words.shape[1])
    Wc = int(comp_sorted.shape[1])
    lc, nc = config.leaf_cap, config.nonleaf_cap
    pk = config.pk_bits

    d_off_np = np.asarray(meta.d_offset(), np.int32)
    n_off = int(d_off_np.shape[0])
    DB = W * 32  # d_off is padded to the max possible D-bit count (static)
    d_off_pad = jnp.asarray(_np_pad(d_off_np, DB, 0))

    B = (
        int(comp_sorted.shape[0])
        if n_valid is not None
        else plancache.bucket_for("build", n)
    )
    # pad_tail is identity on already-bucket-shaped inputs (the warm path)
    # and one dynamic_update_slice against a cached constant otherwise —
    # no per-call jnp.concatenate / jnp.full anywhere in the build
    comp_pad = plancache.pad_tail(jnp.asarray(comp_sorted, jnp.uint32), B, 0)
    words_pad = plancache.pad_tail(jnp.asarray(table_words, jnp.uint32), B, 0)
    row_pad = plancache.pad_tail(jnp.asarray(row_sorted, jnp.uint32), B, 0)
    if table_lengths is None:
        lengths_pad = plancache.const_full((B,), W * 4, jnp.int32)
    else:
        lengths_pad = plancache.pad_tail(jnp.asarray(table_lengths, jnp.int32), B, 0)
    rids_pad = (
        plancache.iota_u32(B)
        if rids is None
        else plancache.pad_tail(jnp.asarray(rids, jnp.uint32), B, 0)
    )

    # ---------------- leaf level (one cached program + host reshape) -------
    leaf_prog = cache.program(
        ("build_leaf", backend_name, B, W, Wc, pk, donate) + program_key_extra,
        lambda: _leaf_program(cache, slice_fn, pk, donate),
    )
    full_pad, klen_pad, rid_dev, dpos_dev, pkeys_dev = leaf_prog(
        comp_pad, words_pad, lengths_pad, rids_pad, row_pad, d_off_pad,
        np.int32(n), np.int32(n_off),
    )
    sorted_full = full_pad[:n]
    rid_sorted = rid_dev[:n]
    rid_np = np.asarray(rid_sorted)
    dpos_np = np.asarray(dpos_dev[:n])
    pkeys_np = np.asarray(pkeys_dev[:n])
    klen_np = np.asarray(klen_pad[:n])

    n_leaves = -(-n // lc)
    rows = n_leaves * lc
    leaf = {
        "rid": jnp.asarray(_np_pad(rid_np, rows, 0xFFFFFFFF).reshape(n_leaves, lc)),
        "pk": jnp.asarray(_np_pad(pkeys_np, rows, 0).reshape(n_leaves, lc)),
        "dpos": jnp.asarray(_np_pad(dpos_np, rows, 0).reshape(n_leaves, lc)),
        "klen": jnp.asarray(_np_pad(klen_np, rows, 0).reshape(n_leaves, lc)),
        "valid": jnp.asarray(np.arange(rows).reshape(n_leaves, lc) < n),
    }
    # highest (sorted-order) key index of each leaf
    child_hi = np.minimum(np.arange(n_leaves) * lc + lc, n).astype(np.int32) - 1

    # ---------------- non-leaf levels, bottom-up ----------------
    levels: list[dict] = []
    child_idx = np.arange(n_leaves, dtype=np.int32)
    while child_idx.shape[0] > 1:
        m_children = int(child_idx.shape[0])
        n_nodes = -(-m_children // nc)
        rows = n_nodes * nc
        Bn = plancache.bucket(rows)
        hi_np = _np_pad(child_hi.astype(np.int32), rows, -1)
        level_prog = cache.program(
            ("build_level", backend_name, Bn, B, W, Wc, pk, donate)
            + program_key_extra,
            lambda: _level_program(cache, slice_fn, pk, donate),
        )
        dfull_dev, epk_dev, klen_dev = level_prog(
            jnp.asarray(_np_pad(hi_np, Bn, -1)), comp_pad, full_pad, klen_pad,
            d_off_pad, np.int32(n), np.int32(n_off),
        )
        dfull = np.asarray(dfull_dev[:rows])
        epk = np.asarray(epk_dev[:rows])
        klen_hi = np.asarray(klen_dev[:rows])
        child_np = _np_pad(child_idx, rows, -1).reshape(n_nodes, nc)
        hi_grid = hi_np.reshape(n_nodes, nc)
        level = {
            "child": jnp.asarray(child_np),
            "hi": jnp.asarray(hi_grid),
            "pk": jnp.asarray(epk.astype(np.uint32).reshape(n_nodes, nc)),
            "dpos": jnp.asarray(dfull.astype(np.int32).reshape(n_nodes, nc)),
            "klen": jnp.asarray(klen_hi.reshape(n_nodes, nc)),
        }
        levels.append(level)
        # parents become the children of the next level up
        last_valid = (child_np >= 0).sum(axis=1) - 1
        child_hi = hi_grid[np.arange(n_nodes), last_valid]
        child_idx = np.arange(n_nodes, dtype=np.int32)

    levels.reverse()  # root first
    return BTree(
        levels=tuple(levels),
        leaf=leaf,
        sorted_full=sorted_full,
        sorted_rids=jnp.asarray(rid_np),
        n_keys=n,
        config=config,
    )


# ---------------------------------------------------------------------------
# batched search
# ---------------------------------------------------------------------------

def _first_ge(entry_keys: jnp.ndarray, valid: jnp.ndarray, query: jnp.ndarray) -> jnp.ndarray:
    """Index of first valid entry whose key >= query; last valid if none."""
    ge = lex_compare_le(query[:, None, :], entry_keys) & valid
    any_ge = jnp.any(ge, axis=1)
    first = jnp.argmax(ge, axis=1)
    last_valid = jnp.sum(valid.astype(jnp.int32), axis=1) - 1
    return jnp.where(any_ge, first, last_valid)


def _descend(tree: BTree, queries: jnp.ndarray) -> jnp.ndarray:
    """Non-leaf descent shared by every search path: (q,) leaf node ids.

    Each level compares the query against the entries' *highest index
    keys* through the highest-key pointer, exactly as the paper's search
    (§4.3) does — a full-key binary comparison per entry, vectorized over
    the node fanout and the query batch.
    """
    q = queries.shape[0]
    node = jnp.zeros((q,), jnp.int32)
    for level in tree.levels:
        hi = level["hi"][node]  # (q, c)
        valid = level["child"][node] >= 0
        hi_keys = tree.sorted_full[jnp.clip(hi, 0, tree.n_keys - 1)]  # (q, c, W)
        e = _first_ge(hi_keys, valid, queries)
        node = jnp.take_along_axis(level["child"][node], e[:, None], axis=1)[:, 0]
        node = jnp.maximum(node, 0)
    return node


def _leaf_keys(tree: BTree, node: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Full keys of each descended leaf's entry lanes: (pos0, (q, lc, W))."""
    lc = tree.config.leaf_cap
    pos0 = node * lc
    keys = tree.sorted_full[
        jnp.clip(pos0[:, None] + jnp.arange(lc)[None, :], 0, tree.n_keys - 1)
    ]
    return pos0, keys


@jax.jit
def search_batch(tree: BTree, queries: jnp.ndarray):
    """Vectorized descent; returns (found (q,), rid (q,), position (q,))."""
    node = _descend(tree, queries)
    rids = tree.leaf["rid"][node]  # (q, c)
    valid = tree.leaf["valid"][node]
    pos0, keys = _leaf_keys(tree, node)
    e = _first_ge(keys, valid, queries)
    key_at = jnp.take_along_axis(keys, e[:, None, None], axis=1)[:, 0, :]
    found = jnp.all(key_at == queries, axis=-1)
    rid = jnp.take_along_axis(rids, e[:, None], axis=1)[:, 0]
    return found, rid, pos0 + e


@jax.jit
def search_batch_partial(tree: BTree, queries: jnp.ndarray):
    """Point lookup via partial-key screening (vectorized Bohannon §4.3).

    For each leaf entry, a true match requires the query's ``pk``-bit window
    at the entry's distinction bit position to equal the entry's partial
    key.  Only screened candidates are dereferenced (full-key compare),
    which is the partial-key B-tree's cache saving; we report the deref
    count so benchmarks can measure it.
    """
    node = _descend(tree, queries)
    lc = tree.config.leaf_cap
    pk = tree.config.pk_bits
    dpos = tree.leaf["dpos"][node]  # (q, c)
    entry_pk = tree.leaf["pk"][node]
    valid = tree.leaf["valid"][node]
    # query window at each entry's dpos
    qwin = _slice_bits(queries[:, None, :].repeat(lc, 1), dpos + 1, pk)
    candidate = (qwin == entry_pk) & valid
    n_deref = jnp.sum(candidate.astype(jnp.int32), axis=1)
    # deref candidates only: compare full keys where candidate
    _, keys = _leaf_keys(tree, node)
    eq = jnp.all(keys == queries[:, None, :], axis=-1) & candidate
    found = jnp.any(eq, axis=1)
    e = jnp.argmax(eq, axis=1)
    rid = jnp.take_along_axis(tree.leaf["rid"][node], e[:, None], axis=1)[:, 0]
    return found, jnp.where(found, rid, jnp.uint32(0xFFFFFFFF)), n_deref


# ---------------------------------------------------------------------------
# the lookup backend op: plan-cached batched point lookup
# ---------------------------------------------------------------------------

#: rid every backend returns for a missing query — lookup results must be
#: byte-identical across backends, so the miss lane cannot carry whatever
#: neighbor entry the descent happened to land on
NOT_FOUND_RID = np.uint32(0xFFFFFFFF)


def _leaf_match_full(tree, node, keys, queries):
    """Default leaf probe: full-key equality over every entry lane."""
    del tree, node
    return jnp.all(keys == queries[:, None, :], axis=-1)


def _lookup_program(cache, leaf_match_fn):
    """The batched point-lookup body, one jitted program.

    The descent is ``search_batch``'s (highest-key compares per non-leaf
    level), but the leaf stage runs a substitutable ``leaf_match_fn(tree,
    node, keys, queries) -> (q, lc) bool`` — full-key equality on the jnp
    oracle, the partial-key probe kernel on pallas — and the miss lanes are
    normalized to ``NOT_FOUND_RID`` so outputs are byte-identical across
    backends.  Tree geometry (level shapes, ``n_keys``, config) is part of
    the jit signature: a snapshot of the same-sized index replays the
    program, a resized one re-traces exactly once (counted by the plan
    cache's ``traces``).
    """

    def prog(tree, queries, n_valid):
        # normalize pad lanes in-program: the host pads with a cached
        # constant whose content is irrelevant — lanes >= n_valid become
        # all-ones queries (harmless descents, sliced off by the caller)
        lane = jnp.arange(queries.shape[0], dtype=jnp.uint32)
        queries = jnp.where(
            (lane < n_valid)[:, None], queries, jnp.uint32(0xFFFFFFFF)
        )
        node = _descend(tree, queries)
        valid = tree.leaf["valid"][node]
        _, keys = _leaf_keys(tree, node)
        eq = leaf_match_fn(tree, node, keys, queries) & valid
        found = jnp.any(eq, axis=1)
        e = jnp.argmax(eq, axis=1)
        rid = jnp.take_along_axis(tree.leaf["rid"][node], e[:, None], axis=1)[:, 0]
        return found, jnp.where(found, rid, jnp.uint32(NOT_FOUND_RID))

    return cache.jit(prog)


def lookup_batch_planned(
    tree: BTree,
    queries: jnp.ndarray,
    *,
    backend_name: str = "jnp",
    leaf_match_fn=None,
    program_key_extra: tuple = (),
    cache=None,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Batched point lookup through the shared plan cache (§4.3 search).

    Returns ``(found (q,) bool, rid (q,) uint32)`` with miss lanes
    normalized to :data:`NOT_FOUND_RID` — the backend ``lookup`` op's
    byte-identity contract.  The query batch pads to a plan-cache bucket
    (floor tunable via ``plancache.set_bucket_floor("lookup", ...)``)
    against a cached fill constant; the dynamic valid count travels as a
    program operand and the pad lanes are normalized to all-ones queries
    *inside* the program (their answers are garbage, sliced off before
    return), so a steady query stream at drifting batch sizes replays one
    compiled program per bucket with zero host-side pad allocation.
    ``leaf_match_fn`` substitutes
    the leaf probe (it must imply full-key equality bit-for-bit — see
    ``_lookup_program``); configuration baked into it travels in
    ``program_key_extra`` so differently-configured backends never share a
    cached program.
    """
    from . import plancache

    cache = cache or plancache.get_cache()
    if leaf_match_fn is None:
        leaf_match_fn = _leaf_match_full
    queries = jnp.asarray(queries, jnp.uint32)
    q, w = int(queries.shape[0]), int(queries.shape[1])
    b = plancache.bucket_for("lookup", q)
    prog = cache.program(
        ("lookup", backend_name, b, w) + program_key_extra,
        lambda: _lookup_program(cache, leaf_match_fn),
    )
    qp = plancache.pad_tail(queries, b, 0xFFFFFFFF)
    found, rid = prog(tree, qp, np.uint32(q))
    return found[:q], rid[:q]


# ---------------------------------------------------------------------------
# multi-tenant lookup: T same-geometry trees stacked, one program
# ---------------------------------------------------------------------------


def tree_geometry(tree: BTree) -> tuple:
    """Static shape signature of a tree — the arena bucketing key.

    Two trees with equal geometry can be stacked into one arena and
    replay one compiled ``lookup_many`` program; a rebuild that changes
    any array shape (or ``n_keys``, or the config) changes the geometry
    and must migrate to a different arena bucket.  The tuple is hashable
    and travels inside plan-cache keys.
    """
    levels = tuple(
        tuple(sorted((k, tuple(map(int, v.shape))) for k, v in level.items()))
        for level in tree.levels
    )
    leaf = tuple(sorted((k, tuple(map(int, v.shape))) for k, v in tree.leaf.items()))
    return (
        levels,
        leaf,
        tuple(map(int, tree.sorted_full.shape)),
        tuple(map(int, tree.sorted_rids.shape)),
        int(tree.n_keys),
        int(tree.config.pk_bits),
        float(tree.config.fill_factor),
    )


def stack_trees(trees, capacity: int | None = None) -> BTree:
    """Stack T same-geometry trees on a new leading tenant axis.

    Returns a :class:`BTree` whose every array leaf has shape
    ``(capacity,) + member_shape`` — a valid pytree over which
    ``jax.vmap`` runs the existing descent, which is how the jnp
    ``lookup_many`` oracle is built.  ``capacity`` defaults to the next
    power of two ``>= len(trees)`` so that tenants joining an arena
    within its capacity replay one compiled program; pad slots replicate
    the first member (their queries are masked out by ``n_valid``, so
    the content is irrelevant but must be shape-correct).
    """
    trees = list(trees)
    if not trees:
        raise ValueError("stack_trees needs at least one tree")
    geom = tree_geometry(trees[0])
    for i, t in enumerate(trees[1:], 1):
        if tree_geometry(t) != geom:
            raise ValueError(
                f"tree {i} geometry differs from tree 0; same-geometry "
                "trees only — bucket by tree_geometry() first"
            )
    t_live = len(trees)
    if capacity is None:
        capacity = 1 << max(0, (t_live - 1).bit_length())
    if capacity < t_live:
        raise ValueError(f"capacity {capacity} < {t_live} trees")
    padded = trees + [trees[0]] * (capacity - t_live)
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *padded)


def _leaf_match_many_full(tree, node, keys, queries):
    """Default stacked leaf probe: full-key equality, tenant-major.

    ``keys`` is (T, q, lc, W), ``queries`` (T, q, W) — the T-leading twin
    of :func:`_leaf_match_full`, same math per tenant slice.
    """
    del tree, node
    return jnp.all(keys == queries[:, :, None, :], axis=-1)


def _lookup_many_program(cache, leaf_match_many_fn):
    """The fused cross-tenant point-lookup body, one jitted program.

    The single-snapshot descent (`_descend`) is ``vmap``-ed over the
    stacked tree's tenant axis, so T tenants' query blocks answer in one
    dispatch of one compiled program — the multi-tenant fan-out the
    ROADMAP asks for.  Per-tenant valid counts arrive as a ``(T,)``
    operand; lanes at or past a tenant's count (including whole pad
    tenants in a partially filled arena) are normalized to all-ones
    queries in-program, exactly like the single path, so results are
    byte-identical per tenant to ``_lookup_program`` on that tenant's
    tree alone.  ``leaf_match_many_fn(tree, node, keys, queries) ->
    (T, q, lc) bool`` substitutes the leaf probe (tenant-major Pallas
    kernel on the pallas backend) and must imply full-key equality
    bit-for-bit.
    """

    return cache.jit(_lookup_many_body(leaf_match_many_fn))


def _lookup_many_body(leaf_match_many_fn):
    """The un-jitted fused lookup body — see :func:`_lookup_many_program`.

    Exposed separately so the distributed backend can wrap it in a
    ``shard_map`` over the tenant axis before handing it to the plan
    cache's jit.
    """

    def prog(tree, queries, n_valid):
        lane = jnp.arange(queries.shape[1], dtype=jnp.uint32)
        live = lane[None, :] < n_valid[:, None]  # (T, q)
        queries = jnp.where(live[..., None], queries, jnp.uint32(0xFFFFFFFF))
        node = jax.vmap(_descend)(tree, queries)  # (T, q)
        valid = jax.vmap(lambda t, n: t.leaf["valid"][n])(tree, node)
        keys = jax.vmap(lambda t, n: _leaf_keys(t, n)[1])(tree, node)
        eq = leaf_match_many_fn(tree, node, keys, queries) & valid
        found = jnp.any(eq, axis=2)
        e = jnp.argmax(eq, axis=2)
        rids = jax.vmap(lambda t, n: t.leaf["rid"][n])(tree, node)
        rid = jnp.take_along_axis(rids, e[..., None], axis=2)[..., 0]
        return found, jnp.where(found, rid, jnp.uint32(NOT_FOUND_RID))

    return prog


def lookup_many_planned(
    stacked: BTree,
    queries: jnp.ndarray,
    n_valid=None,
    *,
    backend_name: str = "jnp",
    leaf_match_many_fn=None,
    program_key_extra: tuple = (),
    cache=None,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Fused multi-tenant point lookup through the shared plan cache.

    ``stacked`` is a :func:`stack_trees` arena of T same-geometry
    snapshots; ``queries`` is ``(T_q, q, W)`` with ``T_q <= T`` — tenant
    ``t``'s block is answered against member tree ``t``.  ``n_valid``
    (optional, ``(T_q,)``) gives each tenant's live lane count; missing
    tenant rows up to the arena capacity are padded with zero-valid
    blocks, so a partially filled arena still replays the capacity-shaped
    program.  Returns ``(found (T_q, q) bool, rid (T_q, q) uint32)``,
    each tenant's slice byte-identical to :func:`lookup_batch_planned`
    on that tenant's tree alone (the lookup byte-identity contract,
    lifted over the tenant axis).

    The program cache key buckets on ``(T, query_bucket, tree
    geometry)`` per the zero-retrace discipline: tenants joining within
    capacity, query batches drifting within a bucket, and snapshot churn
    at fixed geometry all replay one compiled program (observable per op
    via ``PlanCache.stats()["per_op"]["lookup_many"]``).
    """
    from . import plancache

    cache = cache or plancache.get_cache()
    if leaf_match_many_fn is None:
        leaf_match_many_fn = _leaf_match_many_full
    queries = jnp.asarray(queries, jnp.uint32)
    if queries.ndim != 3:
        raise ValueError(f"queries must be (T, q, W), got {queries.shape}")
    t_q, q, w = (int(s) for s in queries.shape)
    t_cap = int(stacked.sorted_full.shape[0])
    if t_q > t_cap:
        raise ValueError(f"{t_q} tenant blocks > arena capacity {t_cap}")
    if n_valid is None:
        nv = np.full((t_q,), q, np.uint32)
    else:
        nv = np.asarray(n_valid, np.uint32).reshape(-1)
        if nv.shape[0] != t_q:
            raise ValueError(f"n_valid has {nv.shape[0]} rows, expected {t_q}")
    nv_full = np.zeros((t_cap,), np.uint32)
    nv_full[:t_q] = np.minimum(nv, q)
    b = plancache.bucket_for("lookup_many", q)
    prog = cache.program(
        ("lookup_many", backend_name, t_cap, b, w, tree_geometry(stacked))
        + program_key_extra,
        lambda: _lookup_many_program(cache, leaf_match_many_fn),
    )
    qp = plancache.pad_tail(queries, b, 0xFFFFFFFF, axis=1)
    qp = plancache.pad_tail(qp, t_cap, 0xFFFFFFFF, axis=0)
    found, rid = prog(stacked, qp, jnp.asarray(nv_full))
    return found[:t_q, :q], rid[:t_q, :q]
