"""Unified reconstruction pipeline (paper §5, Figure 7) over pluggable backends.

    table (memory-resident) --scan--> extract compressed keys + rids
        --parallel sort--> sorted (comp key, rid) pairs
        --bottom-up build--> partial-key B+tree
        (+ recompute DS-metadata for next time, §4.3)

One pipeline, four explicit stages — ``extract``, ``sort``, ``build``,
``refresh_meta`` — with per-stage wall timings (the paper's Figure 9
breakdown) and per-run stats.  The two data-parallel stages dispatch to an
``ExecutionBackend`` (``repro.backends``): ``jnp`` (oracle), ``pallas``
(PEXT + bitonic kernels), ``distributed`` (mesh sample sort — extraction
runs before the all_to_all, so the ICI byte volume shrinks by the sort-key
ratio).  Every reconstruction call site in the repo — core, serving pager,
checkpoint restore, examples, benchmarks — routes through this class;
backends compose with all of them by construction.

Extras over the plain flow:

* **fused fast path** — when the backend supports it, extract+sort run as
  one program and the compressed array is never materialized between the
  stages (``fused=True``).
* **batched multi-index reconstruction** — ``run_many`` rebuilds many
  independent indexes (the replication scenario of §6): same-shape key sets
  on a backend with ``supports_batched`` are stacked and their extract+sort
  is one batched program (vmapped dynamic-bitmap extraction on jnp, vmapped
  kernels on pallas); tree builds then loop (host-side assembly).
* **incremental delta-merge reconstruction** — ``run_incremental`` folds a
  small change set (deletions as a keep-mask, insertions as a delta keyset)
  into a previous reconstruction *without* re-sorting the base: filter the
  surviving base run, extract+sort only the delta, ``merge_sorted`` the two
  runs on the backend, and rebuild the tree bottom-up from the merged run.
  Output is byte-identical to a full ``run`` over the folded keyset with the
  same DS-metadata; when the D-bitmap changed since the previous extraction
  (the compressed projection moved), it falls back to the full path.
* **snapshot publication** — ``run``/``run_incremental`` *produce*; they
  never mutate a reader-visible index in place.  Passing
  ``publish_to=<repro.core.snapshot.SnapshotCell>`` freezes the finished
  result into an immutable, epoch-stamped ``IndexSnapshot`` and atomically
  swaps it in as the cell's next epoch — readers pinned on the previous
  epoch keep their answers until they release (double buffering); see
  ``repro.core.snapshot``.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field, replace as _dc_replace

import jax
import jax.numpy as jnp
import numpy as np

from repro.backends import ExecutionBackend, get_backend

from .btree import BTree, BTreeConfig
from .keyformat import KeySet
from .metadata import DSMeta, meta_from_keys
from .sortkeys import word_comparison_counts

__all__ = [
    "ReconstructionResult",
    "ReconstructionPipeline",
    "identity_meta",
    "fold_keyset",
]


@dataclass
class ReconstructionResult:
    """What a reconstruction returns: the tree, refreshed DS-metadata, the
    sorted compressed keys + rid permutation, and per-stage timings/stats.

    ``extract_bitmap`` is the D-bitmap the compressed keys were *actually*
    extracted under (the input metadata's bitmap — ``meta`` holds the
    refreshed bitmap, which may have shed bits).  ``run_incremental`` merges
    against ``comp_sorted`` only when the current bitmap still equals it.
    """

    tree: BTree
    meta: DSMeta
    comp_sorted: jnp.ndarray
    rid_sorted: jnp.ndarray
    timings: dict = field(default_factory=dict)
    stats: dict = field(default_factory=dict)
    row_sorted: jnp.ndarray | None = None
    extract_bitmap: np.ndarray | None = None
    #: LSN watermark this result is current through (replication consumers
    #: stamp it via ``run``/``run_incremental``; ``None`` = not log-driven)
    watermark: int | None = None


def identity_meta(keyset: KeySet) -> DSMeta:
    """All-ones metadata: every bit position is a distinction bit — the
    full-key baseline (Figure 1 top flow) expressed as a degenerate plan."""
    return DSMeta(
        dbitmap=np.full((keyset.n_words,), 0xFFFFFFFF, np.uint32),
        varbitmap=np.full((keyset.n_words,), 0xFFFFFFFF, np.uint32),
        refkey=np.asarray(keyset.words[0], np.uint32),
        n_words=keyset.n_words,
    )


def fold_keyset(
    base: KeySet,
    keep_rows: np.ndarray | None = None,
    delta: KeySet | None = None,
) -> KeySet:
    """The folded table: surviving base rows, then delta rows appended.

    One boolean mask + one concatenate per column — the vectorized fold
    every incremental call site shares (no per-row Python tuple loop).
    ``keep_rows`` is a (base.n,) bool mask over base *row positions*;
    ``delta`` rows keep their own rids.
    """
    words = np.asarray(base.words, np.uint32)
    lengths = np.asarray(base.lengths, np.int32)
    rids = np.asarray(base.rids, np.uint32)
    if keep_rows is not None:
        keep = np.asarray(keep_rows, bool)
        if keep.shape != (base.n,):
            raise ValueError(f"keep_rows must be ({base.n},), got {keep.shape}")
        words, lengths, rids = words[keep], lengths[keep], rids[keep]
    if delta is not None and delta.n:
        words = np.concatenate([words, np.asarray(delta.words, np.uint32)], axis=0)
        lengths = np.concatenate([lengths, np.asarray(delta.lengths, np.int32)])
        rids = np.concatenate([rids, np.asarray(delta.rids, np.uint32)])
    if words.shape[0] == 0:
        raise ValueError("folded keyset is empty (all rows deleted, no delta)")
    return KeySet(words=words, lengths=lengths, rids=rids)


def _timed(fn, *args):
    t0 = time.perf_counter()
    out = fn(*args)
    out = jax.tree_util.tree_map(
        lambda x: x.block_until_ready() if hasattr(x, "block_until_ready") else x,
        out,
    )
    return out, time.perf_counter() - t0


class ReconstructionPipeline:
    """The scan → extract → sort → build → refresh flow, backend-dispatched.

    Parameters
    ----------
    backend:       a registered backend name (``"jnp"``, ``"pallas"``,
                   ``"distributed"``) or an ``ExecutionBackend`` instance.
    config:        B-tree geometry.
    fused:         run extract+sort as one program when the backend supports
                   it (extract time then reports 0 and folds into sort).
    backend_opts:  forwarded to the backend constructor when ``backend`` is
                   a name (e.g. ``{"interpret": False}`` for pallas on TPU,
                   ``{"mesh": mesh, "capacity_factor": 2.0}`` for distributed).
    chunk_threshold: key counts above this take the chunked large-N sort
                   path: the keyset splits into ``chunk_size``-aligned
                   chunks, each sorted through the (small-bucket) cached
                   sort programs, folded with a binary-counter ladder of
                   cached merges.  Keeps million-key rebuilds on the same
                   handful of compiled programs the serving sizes already
                   trace.
    chunk_size:    chunk length for the large-N path (power of two).
    async_dispatch: skip the per-stage ``block_until_ready`` barriers and
                   sync once at the end of ``run``/``run_incremental``.
                   JAX async dispatch then overlaps host-side program
                   dispatch (chunk i+1's sort) with device compute
                   (chunk i's merge).  Per-stage timings become dispatch
                   walls; pass ``stage_timings=True`` to a run when the
                   Figure-9 breakdown is explicitly wanted (it restores
                   the barriers for that call).  Results are bit-identical
                   either way — only the sync points move.
    donate:        mark operands the stages consume as donated
                   (``donate_argnums``): chunk sorts donate their key
                   slice, the cascade's merges both input runs,
                   build/refresh their scratch.  XLA then reuses a
                   donated buffer in place wherever its shape matches an
                   output (the bucket-shaped sort is the big win — a
                   full zero-copy in-place sort per chunk); operands
                   that can't alias are freed when their Python
                   reference drops, which the ladder does as soon as
                   each run is merged.  No-op on platforms without
                   donation support.
    auto_tune_chunks: lazily calibrate ``chunk_size``/``chunk_threshold``
                   from measured per-bucket sort and merge program costs
                   (:func:`repro.core.plancache.tune_chunking`) the first
                   time a run crosses the current threshold; the measured
                   :class:`~repro.core.plancache.ChunkPlan` persists on
                   the pipeline and is surfaced in ``stats``.
    """

    def __init__(
        self,
        backend: str | ExecutionBackend = "jnp",
        config: BTreeConfig = BTreeConfig(),
        fused: bool = False,
        backend_opts: dict | None = None,
        chunk_threshold: int = 1 << 19,
        chunk_size: int = 1 << 17,
        async_dispatch: bool = False,
        donate: bool = False,
        auto_tune_chunks: bool = False,
    ) -> None:
        if isinstance(backend, ExecutionBackend):
            self.backend = backend
        else:
            self.backend = get_backend(backend, **(backend_opts or {}))
        self.config = config
        self.fused = bool(fused)
        self.chunk_threshold = int(chunk_threshold)
        self.chunk_size = int(chunk_size)
        self.async_dispatch = bool(async_dispatch)
        self.donate = bool(donate)
        self.auto_tune_chunks = bool(auto_tune_chunks)
        self.chunk_plan = None
        self._last_cascade: dict = {}
        if self.chunk_size & (self.chunk_size - 1):
            raise ValueError(f"chunk_size must be a power of two, got {chunk_size}")

    # ------------------------------------------------------------- stages
    def extract(self, words: jnp.ndarray, plan) -> jnp.ndarray:
        """Stage 1 (§5.1): full keys -> compressed keys via the D-bitmap."""
        return self.backend.extract(words, plan)

    def sort(self, comp: jnp.ndarray, rows: jnp.ndarray, *,
             n_valid: int | None = None, keep_padded: bool = False,
             donate: bool = False):
        """Stage 2 (§5.2): parallel sort of (comp key, row) pairs."""
        return self.backend.sort(
            comp, rows, n_valid=n_valid, keep_padded=keep_padded, donate=donate
        )

    def build(self, comp_sorted, row_sorted, meta, words, lengths, rids,
              n_valid: int | None = None, donate: bool = False) -> BTree:
        """Stage 3 (§5.3): bottom-up bulk build (backend-dispatched — the
        cached per-level build programs, with backend entry gathers)."""
        return self.backend.build(
            comp_sorted, row_sorted, meta, words, lengths, self.config,
            rids=rids, n_valid=n_valid, donate=donate,
        )

    def refresh_meta(self, comp_sorted, meta: DSMeta, ref_key,
                     n_valid: int | None = None, donate: bool = False) -> DSMeta:
        """Stage 4 (§4.3): recompute DS-metadata at the opportune time
        (backend-dispatched: cached device dpos program + host scatter-OR)."""
        return self.backend.refresh_meta(comp_sorted, meta, ref_key,
                                         n_valid=n_valid, donate=donate)

    def tune_chunking(self, **kwargs):
        """Measure this backend's per-bucket sort/merge program costs and
        adopt the resulting :class:`~repro.core.plancache.ChunkPlan`
        (``chunk_size`` + ``chunk_threshold``).  Probes compile into a
        throwaway scoped cache, so the serving cache's stats and programs
        are untouched.  Keyword args forward to
        :func:`repro.core.plancache.tune_chunking`."""
        from . import plancache

        plan = plancache.tune_chunking(self.backend, **kwargs)
        self.chunk_size = plan.chunk_size
        self.chunk_threshold = plan.chunk_threshold
        self.chunk_plan = plan
        return plan

    def _stage(self, sync: bool, fn, *args):
        """Run one stage; barrier on its outputs only when ``sync``.

        Async mode leaves the outputs as in-flight device arrays — the next
        stage's dispatch overlaps their compute — so the returned wall is
        dispatch time, not execution time."""
        t0 = time.perf_counter()
        out = fn(*args)
        if sync:
            out = jax.tree_util.tree_map(
                lambda x: x.block_until_ready()
                if hasattr(x, "block_until_ready") else x,
                out,
            )
        return out, time.perf_counter() - t0

    @staticmethod
    def _sync(*arrays) -> float:
        """Barrier on the run's result arrays; returns the blocked wall."""
        t0 = time.perf_counter()
        for a in arrays:
            if hasattr(a, "block_until_ready"):
                a.block_until_ready()
        return time.perf_counter() - t0

    def _sort_chunked(self, comp: jnp.ndarray, n: int, b: int,
                      donate_sorts: bool = False):
        """Large-N sort: bucket-aligned chunks + a binary-counter ladder of
        cached merges.

        Each chunk sorts with *local* rows (every chunk replays the same
        small-bucket cached program and satisfies the [0, m) row contract);
        the chunk offset is added afterwards, which preserves the sorted
        (key, row) order because the offset is monotone within the chunk.

        The fold is a binary counter, not a level-by-level pass: a run of
        2^k merged chunks merges with its equal-sized neighbor the moment
        that neighbor completes, so at most O(log n_chunks) runs are ever
        live at once (one per set bit of the chunks-so-far count) instead
        of one full level — the ``cascade_peak_live_runs`` stat records the
        observed peak, and popping merged runs off the stack drops their
        last references so the footprint tracks it.  With ``self.donate``
        the chunk sorts also run zero-copy in place (input and output
        buckets coincide).  Any association of cached ``merge_sorted``
        programs is
        byte-identical to one monolithic sort because a merge of sorted
        runs under the total (key, row) order has exactly one output.

        Runs stay bucket-padded end to end (``keep_padded`` + ``n_valid``
        chaining — no eager slice-and-re-pad between levels); one final
        ``pad_tail`` aligns the cascade total to the build bucket ``b``.
        Returns ``(b,)``-padded buffers.
        """
        from . import plancache

        c = self.chunk_size
        donate = self.donate
        # stack of live runs: (chunks_merged, n_valid, keys, rows); the
        # chunk counts are strictly decreasing, adjacent equals merge
        stack: list = []
        peak = 0
        merges = 0

        def _merge_top():
            nonlocal merges
            cb, nvb, kb, rb = stack.pop()
            ca, nva, ka, ra = stack.pop()
            mk, mr = self.backend.merge_sorted(
                ka, ra, kb, rb, n_valid_a=nva, n_valid_b=nvb,
                keep_padded=True, donate=donate,
            )
            stack.append((ca + cb, nva + nvb, mk, mr))
            merges += 1

        for s in range(0, n, c):
            m = min(c, n - s)
            chunk = comp[s : s + c]
            ck, cr = self.backend.sort(
                chunk, plancache.iota_u32(int(chunk.shape[0])),
                n_valid=m, keep_padded=True, donate=donate_sorts,
            )
            stack.append((1, m, ck, cr + jnp.uint32(s)))
            peak = max(peak, len(stack))
            while len(stack) >= 2 and stack[-1][0] == stack[-2][0]:
                _merge_top()
        while len(stack) > 1:  # fold the leftover ragged tail, smallest first
            _merge_top()
        _, nv, ks, rs = stack[0]
        self._last_cascade = {
            "cascade_peak_live_runs": peak,
            "cascade_merges": merges,
        }
        # align the cascade total (n_chunks * chunk bucket) to the build
        # bucket; identity when they already agree.  Pad *content* is
        # irrelevant — downstream programs renormalize from n_valid.
        if int(ks.shape[0]) != b:
            ks = plancache.pad_tail(ks, b, 0xFFFFFFFF)
            rs = plancache.pad_tail(rs, b, 0)
        return ks, rs

    # ---------------------------------------------------------------- run
    def run(
        self,
        keyset: KeySet,
        meta: DSMeta | None = None,
        full_keys: bool = False,
        watermark: int | None = None,
        publish_to=None,
        stage_timings: bool | None = None,
    ) -> ReconstructionResult:
        """Reconstruct one index.

        ``full_keys=True`` runs the uncompressed baseline (Figure 1 top
        flow): identity metadata, extraction skipped, the sort sees the full
        key width.  DS-metadata is then left as-is (the baseline has none to
        refresh).  ``watermark`` stamps the result with the LSN it is
        current through (replication consumers use it for lag accounting
        and to elide no-op rebuilds).  ``publish_to`` (a
        ``repro.core.snapshot.SnapshotCell``) atomically publishes the
        finished result as the cell's next snapshot epoch before returning.
        ``stage_timings`` overrides the pipeline's sync policy for this
        call: ``True`` restores the per-stage barriers (the Figure-9
        breakdown) even under ``async_dispatch``; ``False`` forces one
        end-of-run sync.  Either way the run returns fully materialized
        results and ``timings["sync"]`` reports the final barrier's wall.
        """
        from . import plancache

        t_run0 = time.perf_counter()
        sync = (stage_timings if stage_timings is not None
                else not self.async_dispatch)
        n = keyset.n
        rids = jnp.asarray(keyset.rids, jnp.uint32)
        lengths = jnp.asarray(keyset.lengths, jnp.int32)
        # enter the bucket world once: pad the full keys to the sort bucket
        # against cached constants (one dynamic_update_slice, no per-call
        # concatenate/fill) and take the cached iota as the row ids.  Pad
        # lane *content* is irrelevant from here on — every cached program
        # renormalizes its pads from the dynamic valid-count operand.
        b = plancache.bucket_for("sort", n)
        words_dev = plancache.pad_tail(
            jnp.asarray(keyset.words, jnp.uint32), b, 0xFFFFFFFF
        )
        rows_dev = plancache.iota_u32(b)

        t_meta = 0.0
        if full_keys:
            meta = identity_meta(keyset)
        elif meta is None:
            t0 = time.perf_counter()
            meta = meta_from_keys(keyset.words)
            t_meta = time.perf_counter() - t0
        plan = meta.plan()

        if (self.auto_tune_chunks and self.chunk_plan is None
                and n > self.chunk_threshold):
            self.tune_chunking()

        # Donation guards: ``words_dev`` is never donated (the build stage
        # reads it after the sort on the full-keys and fused paths, and the
        # caller's keyset aliases nothing else); when n == b the [:n]
        # result slices alias the padded buffers themselves (a full slice
        # is the identity), so build/refresh must not consume them either.
        donate = self.donate
        donate_results = donate and n < b

        # -- extract / sort (backend-dispatched, optionally fused) ---------
        fused_used = False
        chunks = 0
        if n > self.chunk_threshold:
            # large-N path: extraction stays one bucket-shaped program; the
            # sort splits into chunk-bucket programs + a merge ladder
            chunks = -(-n // self.chunk_size)
            if full_keys:
                comp, t_extract = words_dev, 0.0
            else:
                comp, t_extract = self._stage(sync, self.extract, words_dev, plan)
            # chunk sorts consume their key slices — strict sub-slices are
            # fresh buffers even when comp is words_dev, but a single
            # clamped full slice *is* comp, so full_keys then opts out
            donate_sorts = donate and (not full_keys or chunks > 1)
            (comp_sorted_p, row_sorted_p), t_sort = self._stage(
                sync, lambda: self._sort_chunked(comp, n, b, donate_sorts)
            )
        elif full_keys:
            t_extract = 0.0
            (comp_sorted_p, row_sorted_p), t_sort = self._stage(
                sync,
                lambda: self.sort(words_dev, rows_dev, n_valid=n,
                                  keep_padded=True),
            )
        elif self.fused and self.backend.supports_fused:
            fused_used = True
            t_extract = 0.0
            (comp_sorted_p, row_sorted_p), t_sort = self._stage(
                sync,
                lambda: self.backend.fused_extract_sort(
                    words_dev, plan, rows_dev, n_valid=n, keep_padded=True
                ),
            )
        else:
            comp, t_extract = self._stage(sync, self.extract, words_dev, plan)
            # comp is the extract output and dies with the sort
            (comp_sorted_p, row_sorted_p), t_sort = self._stage(
                sync,
                lambda: self.sort(comp, rows_dev, n_valid=n, keep_padded=True,
                                  donate=donate),
            )
        row_sorted_p = jnp.asarray(row_sorted_p, jnp.uint32)
        comp_sorted = comp_sorted_p[:n]
        row_sorted = row_sorted_p[:n]
        rid_sorted = rids[row_sorted]

        # -- build (padded buffers chain straight in; n_valid carries the
        # -- real count, so no slice-and-re-pad between the stages).  The
        # -- build may consume row_sorted_p (its scratch) once the result
        # -- slices above are dispatched ------------------------------------
        tree, t_build = self._stage(
            sync,
            lambda: self.build(
                comp_sorted_p, row_sorted_p, meta, words_dev, lengths, rids,
                n_valid=n, donate=donate_results,
            ),
        )

        # -- refresh DS-metadata (opportune time, §4.3); last consumer of
        # -- comp_sorted_p, so it may take the buffer --------------------------
        t_refresh = 0.0
        new_meta = meta
        if not full_keys:
            t0 = time.perf_counter()
            new_meta = self.refresh_meta(
                comp_sorted_p, meta, keyset.words[0], n_valid=n,
                donate=donate_results,
            )
            t_refresh = time.perf_counter() - t0

        t_sync = 0.0 if sync else self._sync(comp_sorted, row_sorted, rid_sorted)
        timings = {
            "meta": t_meta,
            "extract": t_extract,
            "sort": t_sort,
            "build": t_build,
            "refresh_meta": t_refresh,
            "sync": t_sync,
            "total": (t_extract + t_sort + t_build) if sync
            else time.perf_counter() - t_run0,
        }
        stats = self._stats(keyset, meta, comp_sorted, row_sorted, tree, fused_used)
        stats["chunked"] = chunks
        stats["async_dispatch"] = not sync
        stats["donate"] = donate
        stats["chunk_size"] = self.chunk_size
        stats["chunk_threshold"] = self.chunk_threshold
        stats["chunk_tuned"] = self.chunk_plan is not None
        if chunks:
            stats.update(self._last_cascade)
        res = ReconstructionResult(
            tree=tree,
            meta=new_meta,
            comp_sorted=comp_sorted,
            rid_sorted=rid_sorted,
            timings=timings,
            stats=stats,
            row_sorted=row_sorted,
            extract_bitmap=np.array(meta.dbitmap, np.uint32, copy=True),
            watermark=watermark,
        )
        if publish_to is not None:
            publish_to.publish(res)
        return res

    # -------------------------------------------------- incremental (delta)
    def run_incremental(
        self,
        prev: ReconstructionResult,
        base_keyset: KeySet,
        delta_keyset: KeySet | None = None,
        *,
        keep_rows: np.ndarray | None = None,
        meta: DSMeta | None = None,
        watermark: int | None = None,
        publish_to=None,
        stage_timings: bool | None = None,
    ) -> tuple[ReconstructionResult, KeySet]:
        """Fold a change set into ``prev`` without re-sorting the base.

        ``base_keyset`` must be the keyset ``prev`` was reconstructed from;
        ``keep_rows`` masks deleted base row positions; ``delta_keyset``
        holds inserted rows (appended after the surviving base rows, which
        is exactly the row numbering a full ``run`` over the folded keyset
        sees).  ``meta`` is the *current* DS-metadata — the caller maintains
        it across mutations via the §4.3 insert rule (defaults to
        ``prev.meta``).

        Returns ``(result, folded_keyset)``.  The result is byte-identical —
        sorted compressed keys, rid permutation, tree levels — to
        ``self.run(folded_keyset, meta=meta)``:

        * surviving base rows keep their relative (key, row) order because
          deletion renumbers rows monotonically;
        * the delta is extracted and sorted through the normal backend
          stages, with row ids offset past the surviving base rows;
        * ``backend.merge_sorted`` interleaves the two runs under the same
          (key, row) contract the sort stage obeys.

        Falls back to the full path (with ``stats["incremental"] = False``
        and the reason in ``stats["incremental_fallback"]``) when the
        D-bitmap changed since ``prev``'s extraction — the compressed
        projection moved, so ``prev.comp_sorted`` can no longer be merged
        against (e.g. an online insert set a new distinction bit and the
        compressed width or bit set grew).

        ``watermark`` stamps the result with the LSN it is current through.
        A change set that is *empty* (no deletes, no delta) under unchanged
        metadata short-circuits entirely: the previous result is returned
        re-stamped at the new watermark (``stats["noop"] = True``) without
        touching the device — the heartbeat-batch fast path of the stream
        layer.  The short-circuit preserves byte-identity because ``prev``
        already equals a full ``run`` over the (unchanged) folded keyset.

        ``publish_to`` publishes the result — whichever path produced it,
        the no-op re-stamp included — as the cell's next snapshot epoch,
        so a reader pinned on the pre-rebuild epoch keeps serving it while
        this method runs and epochs stay aligned with watermarks.
        """
        if meta is None:
            meta = prev.meta
        folded = fold_keyset(base_keyset, keep_rows, delta_keyset)
        n_delta = 0 if delta_keyset is None else delta_keyset.n

        fallback = None
        if prev.extract_bitmap is None:
            fallback = "no_extract_bitmap"
        elif not np.array_equal(
            np.asarray(meta.dbitmap, np.uint32), prev.extract_bitmap
        ):
            fallback = "dbitmap_changed"
        t_run0 = time.perf_counter()
        sync = (stage_timings if stage_timings is not None
                else not self.async_dispatch)
        if fallback is not None:
            res = self.run(folded, meta=meta, watermark=watermark,
                           stage_timings=stage_timings)
            res.stats["incremental"] = False
            res.stats["incremental_fallback"] = fallback
            if publish_to is not None:
                publish_to.publish(res)
            return res, folded

        # -- empty change set: advance the watermark, skip the rebuild -----
        if (
            n_delta == 0
            and (keep_rows is None or bool(np.asarray(keep_rows, bool).all()))
            and (
                meta is prev.meta
                or np.array_equal(meta.varbitmap, prev.meta.varbitmap)
            )
        ):
            stats = dict(prev.stats)
            stats.update(incremental=True, noop=True, n_delta=0, n_deleted=0)
            stats.pop("incremental_fallback", None)
            timings = {
                k: 0.0
                for k in ("meta", "filter", "extract", "sort", "merge",
                          "build", "refresh_meta", "sync", "total")
            }
            res = _dc_replace(
                prev, timings=timings, stats=stats, watermark=watermark
            )
            if publish_to is not None:
                publish_to.publish(res)
            return res, folded

        plan = meta.plan()

        # -- filter the surviving base run (device-side mask, no re-sort) --
        def _filter():
            if keep_rows is None:
                return prev.comp_sorted, jnp.asarray(prev.row_sorted, jnp.uint32)
            keep = jnp.asarray(np.asarray(keep_rows, bool))
            keep_sorted = keep[prev.row_sorted]
            # deletion renumbers surviving rows monotonically, so the kept
            # run stays ascending in (key, new row)
            new_row = jnp.cumsum(keep.astype(jnp.int32)) - 1
            base_comp = prev.comp_sorted[keep_sorted]
            base_rows = new_row[prev.row_sorted][keep_sorted].astype(jnp.uint32)
            return base_comp, base_rows

        (base_comp, base_rows), t_filter = self._stage(sync, _filter)
        n_kept = int(base_comp.shape[0])

        # -- extract + sort only the delta.  The delta's compressed keys
        # -- die with the sort, so they may be donated; the *base* run is
        # -- prev.comp_sorted (or a view of it) and is never donated — the
        # -- caller's previous result must survive this call ---------------
        t_extract = t_sort = 0.0
        if n_delta:
            delta_words = jnp.asarray(delta_keyset.words, jnp.uint32)
            comp_delta, t_extract = self._stage(
                sync, self.extract, delta_words, plan
            )
            (comp_delta_sorted, rows_delta), t_sort = self._stage(
                sync,
                lambda: self.sort(
                    comp_delta, jnp.arange(n_delta, dtype=jnp.uint32),
                    donate=self.donate,
                ),
            )
            # delta rows live after every surviving base row in the folded
            # numbering; the offset preserves the sorted (key, row) order
            rows_delta = jnp.asarray(rows_delta, jnp.uint32) + jnp.uint32(n_kept)
        else:
            comp_delta_sorted = jnp.zeros((0, base_comp.shape[1]), jnp.uint32)
            rows_delta = jnp.zeros((0,), jnp.uint32)

        # -- merge the runs (the backend op) -------------------------------
        (comp_sorted, row_sorted), t_merge = self._stage(
            sync, self.backend.merge_sorted,
            base_comp, base_rows, comp_delta_sorted, rows_delta,
        )
        row_sorted = jnp.asarray(row_sorted, jnp.uint32)
        rid_sorted = jnp.asarray(folded.rids, jnp.uint32)[row_sorted]

        # -- build + refresh (identical to the full path; no donation —
        # -- comp_sorted/row_sorted ARE the result arrays here) ------------
        words = jnp.asarray(folded.words, jnp.uint32)
        lengths = jnp.asarray(folded.lengths, jnp.int32)
        rids = jnp.asarray(folded.rids, jnp.uint32)
        tree, t_build = self._stage(
            sync, self.build, comp_sorted, row_sorted, meta, words, lengths,
            rids,
        )
        t0 = time.perf_counter()
        new_meta = self.refresh_meta(comp_sorted, meta, folded.words[0])
        t_refresh = time.perf_counter() - t0

        t_sync = 0.0 if sync else self._sync(comp_sorted, row_sorted, rid_sorted)
        timings = {
            "meta": 0.0,
            "filter": t_filter,
            "extract": t_extract,
            "sort": t_sort,
            "merge": t_merge,
            "build": t_build,
            "refresh_meta": t_refresh,
            "sync": t_sync,
            "total": (t_filter + t_extract + t_sort + t_merge + t_build)
            if sync else time.perf_counter() - t_run0,
        }
        stats = self._stats(folded, meta, comp_sorted, row_sorted, tree, False)
        stats["incremental"] = True
        stats["n_delta"] = n_delta
        stats["n_deleted"] = base_keyset.n - n_kept
        stats["async_dispatch"] = not sync
        stats["donate"] = self.donate
        res = ReconstructionResult(
            tree=tree,
            meta=new_meta,
            comp_sorted=comp_sorted,
            rid_sorted=rid_sorted,
            timings=timings,
            stats=stats,
            row_sorted=row_sorted,
            extract_bitmap=np.array(meta.dbitmap, np.uint32, copy=True),
            watermark=watermark,
        )
        if publish_to is not None:
            publish_to.publish(res)
        return res, folded

    def _stats(self, keyset, meta, comp_sorted, row_sorted, tree, fused_used):
        full_bits = keyset.n_bits
        # wcc over the *row*-permuted full keys: row_sorted indexes rows of
        # the table; rids are labels, not positions.
        full_sorted = jnp.asarray(keyset.words, jnp.uint32)[row_sorted]
        stats = {
            "backend": self.backend.name,
            "fused": fused_used,
            "n_keys": keyset.n,
            "full_key_bits": full_bits,
            "distinction_bits": meta.n_dbits,
            "compression_ratio": full_bits / max(meta.n_dbits, 1),
            "full_sort_key_words": keyset.n_words + 1,  # + rid word
            "comp_sort_key_words": int(comp_sorted.shape[1]) + 1,
            "sort_key_ratio": (keyset.n_words + 1) / (int(comp_sorted.shape[1]) + 1),
            "wcc_full": float(word_comparison_counts(full_sorted)),
            "wcc_comp": float(word_comparison_counts(comp_sorted)),
            "tree_height": tree.height,
            "tree_bytes": tree.memory_bytes(),
        }
        stats["word_comparison_ratio"] = stats["wcc_full"] / max(stats["wcc_comp"], 1e-9)
        stats.update(self.backend.last_info)
        return stats

    # ----------------------------------------------------- batched (many)
    def run_many(
        self,
        keysets: list[KeySet],
        metas: list[DSMeta | None] | None = None,
    ) -> list[ReconstructionResult]:
        """Reconstruct many independent indexes (the replication scenario).

        Same-shape key sets on a backend with ``supports_batched`` are
        batched: the stacked extract+sort dispatches to the backend's
        ``batched_extract_sort`` (one vmapped dynamic-bitmap program on jnp;
        per-plan pext kernels + one vmapped bitonic sort program on pallas),
        then a per-index build loop.  Heterogeneous shapes — and backends
        without the capability, e.g. distributed, whose exchange owns the
        whole mesh — fall back to sequential ``run``.
        """
        if metas is None:
            metas = [None] * len(keysets)
        if len(metas) != len(keysets):
            raise ValueError("metas must align with keysets")

        results: list[ReconstructionResult | None] = [None] * len(keysets)

        if not self.backend.supports_batched:
            return [self.run(ks, meta=m) for ks, m in zip(keysets, metas)]

        # metadata first (it determines the compressed width), then group by
        # (shape bucket, n_words, compressed width): members of a bucket pad
        # to the bucket boundary with sentinel rows, so the stacked program
        # is shared across drifting sizes AND every member still gets
        # exactly the comp_sorted its own single run would produce
        t0 = time.perf_counter()
        metas = [
            m if m is not None else meta_from_keys(ks.words)
            for ks, m in zip(keysets, metas)
        ]
        t_meta_total = time.perf_counter() - t0

        from . import plancache

        groups: dict[tuple[int, int, int], list[int]] = {}
        for i, (ks, m) in enumerate(zip(keysets, metas)):
            groups.setdefault(
                (
                    plancache.bucket_for("run_many", ks.n),
                    ks.n_words,
                    m.plan().n_words_out,
                ),
                [],
            ).append(i)

        t_meta = t_meta_total / max(len(keysets), 1)
        for _, idxs in groups.items():
            if len(idxs) < 2:
                for i in idxs:
                    results[i] = self.run(keysets[i], meta=metas[i])
                continue
            for i, res in zip(idxs, self._run_batched(
                [keysets[i] for i in idxs], [metas[i] for i in idxs], t_meta
            )):
                results[i] = res
        return results  # type: ignore[return-value]

    def _run_batched(self, keysets, metas, t_meta) -> list[ReconstructionResult]:
        from . import plancache

        k = len(keysets)
        plans = [m.plan() for m in metas]
        b = plancache.bucket_for("run_many", max(ks.n for ks in keysets))
        # members pad to the shared bucket boundary: all-ones sentinel keys
        # extract to the maximal compressed pattern and the reserved row-id
        # range breaks ties, so each member's pads sort strictly last and
        # slicing [:n] recovers its exact single-run output
        words = jnp.asarray(
            np.stack([
                np.concatenate([
                    np.asarray(ks.words, np.uint32),
                    np.full((b - ks.n, ks.n_words), 0xFFFFFFFF, np.uint32),
                ])
                for ks in keysets
            ]),
            jnp.uint32,
        )
        bitmaps = jnp.asarray(np.stack([m.dbitmap for m in metas]), jnp.uint32)
        rows = jnp.asarray(
            np.stack([
                np.concatenate([
                    np.arange(ks.n, dtype=np.uint32),
                    np.uint32(plancache.ROW_PAD_A)
                    + np.arange(b - ks.n, dtype=np.uint32),
                ])
                for ks in keysets
            ]),
            jnp.uint32,
        )

        # the stacked extract+sort is the backend's batched program (keyed
        # sort — the determinism contract — on whatever substrate it runs)
        (comp_sorted, row_sorted), t_xs = _timed(
            self.backend.batched_extract_sort, words, bitmaps, rows, plans
        )

        out = []
        for i, (ks, meta) in enumerate(zip(keysets, metas)):
            cs, rs = comp_sorted[i, : ks.n], row_sorted[i, : ks.n]
            rids = jnp.asarray(ks.rids, jnp.uint32)
            lengths = jnp.asarray(ks.lengths, jnp.int32)
            tree, t_build = _timed(
                self.build, cs, rs, meta, jnp.asarray(ks.words, jnp.uint32),
                lengths, rids,
            )
            t0 = time.perf_counter()
            new_meta = self.refresh_meta(cs, meta, ks.words[0])
            t_refresh = time.perf_counter() - t0
            timings = {
                "meta": t_meta,
                "extract": 0.0,
                "sort": t_xs / k,
                "build": t_build,
                "refresh_meta": t_refresh,
                "total": t_xs / k + t_build,
            }
            # "batched" carries the batching fact; "fused" stays reserved
            # for the backend's fused_extract_sort path
            stats = self._stats(ks, meta, cs, rs, tree, fused_used=False)
            stats["batched"] = k
            out.append(
                ReconstructionResult(
                    tree=tree,
                    meta=new_meta,
                    comp_sorted=cs,
                    rid_sorted=rids[rs],
                    timings=timings,
                    stats=stats,
                    row_sorted=rs,
                    extract_bitmap=np.array(meta.dbitmap, np.uint32, copy=True),
                )
            )
        return out
