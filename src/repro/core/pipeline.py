"""Unified reconstruction pipeline (paper §5, Figure 7) over pluggable backends.

    table (memory-resident) --scan--> extract compressed keys + rids
        --parallel sort--> sorted (comp key, rid) pairs
        --bottom-up build--> partial-key B+tree
        (+ recompute DS-metadata for next time, §4.3)

One pipeline, four explicit stages — ``extract``, ``sort``, ``build``,
``refresh_meta`` — with per-stage wall timings (the paper's Figure 9
breakdown) and per-run stats.  The two data-parallel stages dispatch to an
``ExecutionBackend`` (``repro.backends``): ``jnp`` (oracle), ``pallas``
(PEXT + bitonic kernels), ``distributed`` (mesh sample sort — extraction
runs before the all_to_all, so the ICI byte volume shrinks by the sort-key
ratio).  Every reconstruction call site in the repo — core, serving pager,
checkpoint restore, examples, benchmarks — routes through this class;
backends compose with all of them by construction.

Extras over the plain flow:

* **fused fast path** — when the backend supports it, extract+sort run as
  one program and the compressed array is never materialized between the
  stages (``fused=True``).
* **batched multi-index reconstruction** — ``run_many`` rebuilds many
  independent indexes (the replication scenario of §6): same-shape key sets
  on the jnp backend are stacked and their extract+sort is one ``vmap``-ed
  program using the dynamic-bitmap extractor; tree builds then loop
  (host-side assembly).
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.backends import ExecutionBackend, get_backend

from .btree import BTree, BTreeConfig, build_btree
from .compress import extract_bits_dynamic
from .dbits import sort_words_keyed
from .keyformat import KeySet
from .metadata import DSMeta, meta_from_keys, meta_on_rebuild
from .sortkeys import word_comparison_counts

__all__ = ["ReconstructionResult", "ReconstructionPipeline", "identity_meta"]


@dataclass
class ReconstructionResult:
    """What a reconstruction returns: the tree, refreshed DS-metadata, the
    sorted compressed keys + rid permutation, and per-stage timings/stats."""

    tree: BTree
    meta: DSMeta
    comp_sorted: jnp.ndarray
    rid_sorted: jnp.ndarray
    timings: dict = field(default_factory=dict)
    stats: dict = field(default_factory=dict)
    row_sorted: jnp.ndarray | None = None


def identity_meta(keyset: KeySet) -> DSMeta:
    """All-ones metadata: every bit position is a distinction bit — the
    full-key baseline (Figure 1 top flow) expressed as a degenerate plan."""
    return DSMeta(
        dbitmap=np.full((keyset.n_words,), 0xFFFFFFFF, np.uint32),
        varbitmap=np.full((keyset.n_words,), 0xFFFFFFFF, np.uint32),
        refkey=np.asarray(keyset.words[0], np.uint32),
        n_words=keyset.n_words,
    )


def _timed(fn, *args):
    t0 = time.perf_counter()
    out = fn(*args)
    out = jax.tree_util.tree_map(
        lambda x: x.block_until_ready() if hasattr(x, "block_until_ready") else x,
        out,
    )
    return out, time.perf_counter() - t0


class ReconstructionPipeline:
    """The scan → extract → sort → build → refresh flow, backend-dispatched.

    Parameters
    ----------
    backend:       a registered backend name (``"jnp"``, ``"pallas"``,
                   ``"distributed"``) or an ``ExecutionBackend`` instance.
    config:        B-tree geometry.
    fused:         run extract+sort as one program when the backend supports
                   it (extract time then reports 0 and folds into sort).
    backend_opts:  forwarded to the backend constructor when ``backend`` is
                   a name (e.g. ``{"interpret": False}`` for pallas on TPU,
                   ``{"mesh": mesh, "capacity_factor": 2.0}`` for distributed).
    """

    def __init__(
        self,
        backend: str | ExecutionBackend = "jnp",
        config: BTreeConfig = BTreeConfig(),
        fused: bool = False,
        backend_opts: dict | None = None,
    ) -> None:
        if isinstance(backend, ExecutionBackend):
            self.backend = backend
        else:
            self.backend = get_backend(backend, **(backend_opts or {}))
        self.config = config
        self.fused = bool(fused)

    # ------------------------------------------------------------- stages
    def extract(self, words: jnp.ndarray, plan) -> jnp.ndarray:
        """Stage 1 (§5.1): full keys -> compressed keys via the D-bitmap."""
        return self.backend.extract(words, plan)

    def sort(self, comp: jnp.ndarray, rows: jnp.ndarray):
        """Stage 2 (§5.2): parallel sort of (comp key, row) pairs."""
        return self.backend.sort(comp, rows)

    def build(self, comp_sorted, row_sorted, meta, words, lengths, rids) -> BTree:
        """Stage 3 (§5.3): bottom-up bulk build of the partial-key B+tree."""
        return build_btree(
            comp_sorted, row_sorted, meta, words, lengths, self.config, rids=rids
        )

    def refresh_meta(self, comp_sorted, meta: DSMeta, ref_key) -> DSMeta:
        """Stage 4 (§4.3): recompute DS-metadata at the opportune time."""
        return meta_on_rebuild(np.asarray(comp_sorted), meta, np.asarray(ref_key))

    # ---------------------------------------------------------------- run
    def run(
        self,
        keyset: KeySet,
        meta: DSMeta | None = None,
        full_keys: bool = False,
    ) -> ReconstructionResult:
        """Reconstruct one index.

        ``full_keys=True`` runs the uncompressed baseline (Figure 1 top
        flow): identity metadata, extraction skipped, the sort sees the full
        key width.  DS-metadata is then left as-is (the baseline has none to
        refresh).
        """
        words = jnp.asarray(keyset.words, jnp.uint32)
        rids = jnp.asarray(keyset.rids, jnp.uint32)
        lengths = jnp.asarray(keyset.lengths, jnp.int32)
        rows = jnp.arange(keyset.n, dtype=jnp.uint32)

        t_meta = 0.0
        if full_keys:
            meta = identity_meta(keyset)
        elif meta is None:
            t0 = time.perf_counter()
            meta = meta_from_keys(keyset.words)
            t_meta = time.perf_counter() - t0
        plan = meta.plan()

        # -- extract / sort (backend-dispatched, optionally fused) ---------
        fused_used = False
        if full_keys:
            comp, t_extract = words, 0.0
            (comp_sorted, row_sorted), t_sort = _timed(self.sort, comp, rows)
        elif self.fused and self.backend.supports_fused:
            fused_used = True
            t_extract = 0.0
            (comp_sorted, row_sorted), t_sort = _timed(
                self.backend.fused_extract_sort, words, plan, rows
            )
        else:
            comp, t_extract = _timed(self.extract, words, plan)
            (comp_sorted, row_sorted), t_sort = _timed(self.sort, comp, rows)
        row_sorted = jnp.asarray(row_sorted, jnp.uint32)
        rid_sorted = rids[row_sorted]

        # -- build ---------------------------------------------------------
        tree, t_build = _timed(
            self.build, comp_sorted, row_sorted, meta, words, lengths, rids
        )

        # -- refresh DS-metadata (opportune time, §4.3) ----------------------
        t_refresh = 0.0
        new_meta = meta
        if not full_keys:
            t0 = time.perf_counter()
            new_meta = self.refresh_meta(comp_sorted, meta, keyset.words[0])
            t_refresh = time.perf_counter() - t0

        timings = {
            "meta": t_meta,
            "extract": t_extract,
            "sort": t_sort,
            "build": t_build,
            "refresh_meta": t_refresh,
            "total": t_extract + t_sort + t_build,
        }
        stats = self._stats(keyset, meta, comp_sorted, row_sorted, tree, fused_used)
        return ReconstructionResult(
            tree=tree,
            meta=new_meta,
            comp_sorted=comp_sorted,
            rid_sorted=rid_sorted,
            timings=timings,
            stats=stats,
            row_sorted=row_sorted,
        )

    def _stats(self, keyset, meta, comp_sorted, row_sorted, tree, fused_used):
        full_bits = keyset.n_bits
        # wcc over the *row*-permuted full keys: row_sorted indexes rows of
        # the table; rids are labels, not positions.
        full_sorted = jnp.asarray(keyset.words, jnp.uint32)[row_sorted]
        stats = {
            "backend": self.backend.name,
            "fused": fused_used,
            "n_keys": keyset.n,
            "full_key_bits": full_bits,
            "distinction_bits": meta.n_dbits,
            "compression_ratio": full_bits / max(meta.n_dbits, 1),
            "full_sort_key_words": keyset.n_words + 1,  # + rid word
            "comp_sort_key_words": int(comp_sorted.shape[1]) + 1,
            "sort_key_ratio": (keyset.n_words + 1) / (int(comp_sorted.shape[1]) + 1),
            "wcc_full": float(word_comparison_counts(full_sorted)),
            "wcc_comp": float(word_comparison_counts(comp_sorted)),
            "tree_height": tree.height,
            "tree_bytes": tree.memory_bytes(),
        }
        stats["word_comparison_ratio"] = stats["wcc_full"] / max(stats["wcc_comp"], 1e-9)
        stats.update(self.backend.last_info)
        return stats

    # ----------------------------------------------------- batched (many)
    def run_many(
        self,
        keysets: list[KeySet],
        metas: list[DSMeta | None] | None = None,
    ) -> list[ReconstructionResult]:
        """Reconstruct many independent indexes (the replication scenario).

        Same-shape key sets on a backend with ``supports_batched`` are
        batched: one vmap-ed extract+sort over the stack (dynamic-bitmap
        extraction, so one trace serves every index), then a per-index build
        loop.  Heterogeneous shapes — and backends without the capability,
        e.g. distributed, whose exchange owns the whole mesh — fall back to
        sequential ``run``.
        """
        if metas is None:
            metas = [None] * len(keysets)
        if len(metas) != len(keysets):
            raise ValueError("metas must align with keysets")

        results: list[ReconstructionResult | None] = [None] * len(keysets)

        if not self.backend.supports_batched:
            return [self.run(ks, meta=m) for ks, m in zip(keysets, metas)]

        # metadata first (it determines the compressed width), then group by
        # (n, n_words, compressed width) so every member of a batch gets
        # exactly the comp_sorted width its own single run would produce
        t0 = time.perf_counter()
        metas = [
            m if m is not None else meta_from_keys(ks.words)
            for ks, m in zip(keysets, metas)
        ]
        t_meta_total = time.perf_counter() - t0

        groups: dict[tuple[int, int, int], list[int]] = {}
        for i, (ks, m) in enumerate(zip(keysets, metas)):
            groups.setdefault((ks.n, ks.n_words, m.plan().n_words_out), []).append(i)

        t_meta = t_meta_total / max(len(keysets), 1)
        for _, idxs in groups.items():
            if len(idxs) < 2:
                for i in idxs:
                    results[i] = self.run(keysets[i], meta=metas[i])
                continue
            for i, res in zip(idxs, self._run_batched(
                [keysets[i] for i in idxs], [metas[i] for i in idxs], t_meta
            )):
                results[i] = res
        return results  # type: ignore[return-value]

    def _run_batched(self, keysets, metas, t_meta) -> list[ReconstructionResult]:
        k = len(keysets)
        plans = [m.plan() for m in metas]
        wc_out = plans[0].n_words_out  # equal within a group by construction
        words = jnp.asarray(np.stack([ks.words for ks in keysets]), jnp.uint32)
        bitmaps = jnp.asarray(np.stack([m.dbitmap for m in metas]), jnp.uint32)
        n = keysets[0].n
        rows = jnp.broadcast_to(jnp.arange(n, dtype=jnp.uint32), (k, n))

        # one program for the whole batch: dynamic-bitmap extract + keyed
        # sort (the backend determinism contract), vmapped over the index
        # axis
        def one(w, bm, r):
            comp = extract_bits_dynamic(w, bm, wc_out)
            return sort_words_keyed(comp, r)

        (comp_sorted, row_sorted), t_xs = _timed(
            jax.jit(jax.vmap(one)), words, bitmaps, rows
        )

        out = []
        for i, (ks, meta) in enumerate(zip(keysets, metas)):
            cs, rs = comp_sorted[i], row_sorted[i]
            rids = jnp.asarray(ks.rids, jnp.uint32)
            lengths = jnp.asarray(ks.lengths, jnp.int32)
            tree, t_build = _timed(
                self.build, cs, rs, meta, jnp.asarray(ks.words, jnp.uint32),
                lengths, rids,
            )
            t0 = time.perf_counter()
            new_meta = self.refresh_meta(cs, meta, ks.words[0])
            t_refresh = time.perf_counter() - t0
            timings = {
                "meta": t_meta,
                "extract": 0.0,
                "sort": t_xs / k,
                "build": t_build,
                "refresh_meta": t_refresh,
                "total": t_xs / k + t_build,
            }
            # "batched" carries the batching fact; "fused" stays reserved
            # for the backend's fused_extract_sort path
            stats = self._stats(ks, meta, cs, rs, tree, fused_used=False)
            stats["batched"] = k
            out.append(
                ReconstructionResult(
                    tree=tree,
                    meta=new_meta,
                    comp_sorted=cs,
                    rid_sorted=rids[rs],
                    timings=timings,
                    stats=stats,
                    row_sorted=rs,
                )
            )
        return out
