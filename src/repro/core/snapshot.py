"""Versioned, immutable index snapshots with epoch-based publish (reads).

The write path produces: every ``ReconstructionPipeline.run`` /
``run_incremental`` yields a fresh set of device arrays (tree levels,
sorted compressed keys, rid permutation) plus host metadata.  The *read*
path must never observe a half-swapped mixture of two reconstructions —
a replica answering queries while ``poll`` folds the next log span, a
serving engine routing page gets across a restart rebuild.  This module
is the seam between the two:

* :class:`IndexSnapshot` freezes one reconstruction into an immutable,
  epoch-stamped artifact: the tree, the DS-metadata, the sorted run, the
  extraction bitmap, and the LSN watermark the state is current through.
  The arrays are the (already immutable) device buffers the pipeline
  produced; the host-side metadata is copied at freeze time so later
  in-place mutation by the producer cannot leak in.
* :class:`SnapshotCell` is the publish/acquire protocol — a one-slot
  double buffer.  ``publish`` atomically swaps the current snapshot to
  the next epoch; readers ``acquire`` (pin) the current epoch and
  ``release`` it when done.  A publish never invalidates a pinned
  snapshot: the previous epoch is *retired* and kept alive until its
  last pin drops, so a reader that pinned epoch ``e`` keeps getting
  epoch-``e`` answers even if rebuilds publish ``e+1, e+2, …``
  underneath it — the double-buffering the replica read scale-out needs.

Epochs are dense and monotonically increasing.  Consumers that persist
state (the checkpoint layer) record the epoch next to the watermark and
resume the cell at it, so a bootstrapped replica's snapshot history
continues the primary's numbering rather than restarting at zero.
"""

from __future__ import annotations

from contextlib import contextmanager
from dataclasses import dataclass
from typing import TYPE_CHECKING, Iterator

import numpy as np

if TYPE_CHECKING:  # the pipeline imports this module; keep the cycle lazy
    from .btree import BTree
    from .metadata import DSMeta
    from .pipeline import ReconstructionResult

__all__ = ["IndexSnapshot", "SnapshotCell"]


@dataclass(frozen=True)
class IndexSnapshot:
    """One reconstruction, frozen: epoch-stamped, device-resident, immutable.

    ``tree``/``comp_sorted``/``rid_sorted``/``row_sorted`` are the
    pipeline's device arrays; ``meta`` is the refreshed DS-metadata and
    ``extract_bitmap`` the D-bitmap the compressed run was extracted
    under (both copied at freeze time); ``watermark`` is the LSN the
    state is current through (``None`` when not log-driven).
    """

    epoch: int
    tree: "BTree"
    meta: "DSMeta"
    comp_sorted: object
    rid_sorted: object
    row_sorted: object | None
    extract_bitmap: np.ndarray | None
    watermark: int | None

    @property
    def n_keys(self) -> int:
        """Number of live keys in the snapshot's tree."""
        return int(self.tree.n_keys)

    @staticmethod
    def from_result(result: "ReconstructionResult", epoch: int) -> "IndexSnapshot":
        """Freeze a pipeline result at ``epoch``.

        The device arrays are shared (jax arrays are immutable); the
        host-side metadata is deep-copied so producers that keep mutating
        their working ``DSMeta``/``extract_bitmap`` (the §4.3 insert rule
        runs in place on some consumers) cannot reach into a published
        snapshot.
        """
        from dataclasses import replace as _replace

        meta = result.meta
        frozen_meta = _replace(
            meta,
            dbitmap=np.array(meta.dbitmap, np.uint32, copy=True),
            varbitmap=np.array(meta.varbitmap, np.uint32, copy=True),
            refkey=np.array(meta.refkey, np.uint32, copy=True),
        )
        eb = result.extract_bitmap
        return IndexSnapshot(
            epoch=int(epoch),
            tree=result.tree,
            meta=frozen_meta,
            comp_sorted=result.comp_sorted,
            rid_sorted=result.rid_sorted,
            row_sorted=result.row_sorted,
            extract_bitmap=None if eb is None else np.array(eb, np.uint32, copy=True),
            watermark=result.watermark,
        )

    def lookup(self, backend, queries):
        """Batched point lookup through a backend's ``lookup`` op.

        Convenience for read-path consumers: ``backend`` is any
        ``ExecutionBackend``; returns the op's ``(found, rid)`` pair.
        """
        return backend.lookup(self.tree, queries)


class SnapshotCell:
    """The epoch-based publish/acquire protocol (a one-slot double buffer).

    Writers call :meth:`publish` with each finished reconstruction;
    readers wrap their lookups in :meth:`pin` (or the explicit
    ``acquire``/``release`` pair).  The cell retires — but does not drop —
    the previous snapshot while any reader still pins it, which is what
    lets a rebuild proceed concurrently with reads: queries pinned before
    the swap keep answering from the pre-rebuild epoch, queries pinned
    after it see the new one, and no query ever sees a mixture.

    ``start_epoch`` seeds the numbering: the first publish lands at
    ``start_epoch + 1`` (the default ``-1`` makes it epoch 0).  A
    checkpoint-restored consumer resumes the cell at the persisted epoch
    so its history continues the producer's.
    """

    def __init__(self, start_epoch: int = -1) -> None:
        self._current: IndexSnapshot | None = None
        self._epoch = int(start_epoch)
        self._pins: dict[int, int] = {}
        self._retired: dict[int, IndexSnapshot] = {}
        self.n_published = 0
        self.n_acquired = 0

    # --------------------------------------------------------------- state
    @property
    def current(self) -> IndexSnapshot | None:
        """The currently published snapshot (``None`` before the first)."""
        return self._current

    @property
    def epoch(self) -> int:
        """Epoch of the current snapshot (``start_epoch`` before any)."""
        return self._epoch

    def pinned_epochs(self) -> list[int]:
        """Epochs with at least one outstanding pin, ascending."""
        return sorted(e for e, c in self._pins.items() if c > 0)

    # ------------------------------------------------------------- publish
    def publish(
        self, result: "ReconstructionResult", epoch: int | None = None
    ) -> IndexSnapshot:
        """Freeze ``result`` and atomically swap it in as the next epoch.

        ``epoch`` defaults to ``current + 1`` and must be strictly
        increasing when given explicitly (the checkpoint-resume path).
        The previous snapshot is retired while pinned and dropped once its
        last pin releases; an unpinned previous snapshot is dropped
        immediately (double buffering, not an unbounded history).
        """
        epoch = self._epoch + 1 if epoch is None else int(epoch)
        if epoch <= self._epoch and self._current is not None:
            raise ValueError(
                f"epoch must increase: publishing {epoch} over {self._epoch}"
            )
        snap = IndexSnapshot.from_result(result, epoch)
        prev = self._current
        self._current = snap
        self._epoch = epoch
        self.n_published += 1
        if prev is not None and self._pins.get(prev.epoch, 0) > 0:
            self._retired[prev.epoch] = prev
        return snap

    # ------------------------------------------------------------- readers
    def acquire(self) -> IndexSnapshot:
        """Pin and return the current snapshot (raises before any publish).

        Every ``acquire`` must be paired with a :meth:`release` of the
        returned snapshot; prefer the :meth:`pin` context manager.
        """
        if self._current is None:
            raise RuntimeError("no snapshot published yet")
        snap = self._current
        self._pins[snap.epoch] = self._pins.get(snap.epoch, 0) + 1
        self.n_acquired += 1
        return snap

    def release(self, snap: IndexSnapshot) -> None:
        """Drop one pin on ``snap``; a fully-unpinned retired epoch is freed."""
        n = self._pins.get(snap.epoch, 0)
        if n <= 0:
            raise RuntimeError(f"release of unpinned epoch {snap.epoch}")
        if n == 1:
            del self._pins[snap.epoch]
            self._retired.pop(snap.epoch, None)
        else:
            self._pins[snap.epoch] = n - 1

    @contextmanager
    def pin(self) -> Iterator[IndexSnapshot]:
        """``with cell.pin() as snap:`` — acquire/release, exception-safe."""
        snap = self.acquire()
        try:
            yield snap
        finally:
            self.release(snap)

    # --------------------------------------------------------------- stats
    def stats(self) -> dict:
        """Cell counters: current epoch, publishes, pins, retired epochs."""
        return {
            "epoch": self._epoch,
            "n_published": self.n_published,
            "n_acquired": self.n_acquired,
            "pinned": sum(self._pins.values()),
            "retired": len(self._retired),
        }
