"""Versioned, immutable index snapshots with epoch-based publish (reads).

The write path produces: every ``ReconstructionPipeline.run`` /
``run_incremental`` yields a fresh set of device arrays (tree levels,
sorted compressed keys, rid permutation) plus host metadata.  The *read*
path must never observe a half-swapped mixture of two reconstructions —
a replica answering queries while ``poll`` folds the next log span, a
serving engine routing page gets across a restart rebuild.  This module
is the seam between the two:

* :class:`IndexSnapshot` freezes one reconstruction into an immutable,
  epoch-stamped artifact: the tree, the DS-metadata, the sorted run, the
  extraction bitmap, and the LSN watermark the state is current through.
  The arrays are the (already immutable) device buffers the pipeline
  produced; the host-side metadata is copied at freeze time so later
  in-place mutation by the producer cannot leak in.
* :class:`SnapshotCell` is the publish/acquire protocol — a one-slot
  double buffer.  ``publish`` atomically swaps the current snapshot to
  the next epoch; readers ``acquire`` (pin) the current epoch and
  ``release`` it when done.  A publish never invalidates a pinned
  snapshot: the previous epoch is *retired* and kept alive until its
  last pin drops, so a reader that pinned epoch ``e`` keeps getting
  epoch-``e`` answers even if rebuilds publish ``e+1, e+2, …``
  underneath it — the double-buffering the replica read scale-out needs.

Epochs are dense and monotonically increasing.  Consumers that persist
state (the checkpoint layer) record the epoch next to the watermark and
resume the cell at it, so a bootstrapped replica's snapshot history
continues the primary's numbering rather than restarting at zero.

Concurrency model (the serving contract)
----------------------------------------

The cell is **single-writer, multi-reader**: one thread publishes,
any number of threads pin.  All refcount bookkeeping — the pin table,
the retired-epoch map, every counter — is guarded by one mutex whose
critical sections are a handful of dict operations; nothing heavyweight
ever runs under it.  In particular:

* ``publish`` freezes the result (the metadata deep copies) *outside*
  the lock and only swaps the pointer inside it, so a reader's
  :meth:`~SnapshotCell.acquire` never waits on a rebuild — the read hot
  path is wait-free in the practical sense: it can only contend with
  other few-instruction critical sections, never with reconstruction
  work.
* The backend ``lookup`` a reader runs against its pinned snapshot
  executes entirely outside the lock.
* An epoch is retired at most once and freed exactly once: the publish
  that supersedes it either drops it immediately (no pins) or parks it
  in the retired map, and the *last* release frees it.  Double release
  is detected per-lease (every ``acquire`` returns a one-shot
  :class:`SnapshotPin`) and raises instead of corrupting a concurrent
  reader's refcount.
* :meth:`~SnapshotCell.stats` counters (``acquires``, ``releases``,
  ``retired_epochs``, ``max_concurrent_pins``) are updated inside the
  same critical sections, so they are exact under contention — the
  concurrency tests assert their closed-form values after adversarial
  thread schedules.

Admission control: ``max_lag_epochs`` bounds how far the writer may
fall behind its mutation feed before the cell stops admitting new
reads.  The writer reports its backlog with
:meth:`~SnapshotCell.report_lag` (in epochs, i.e. pending un-rebuilt
batches); while the reported lag exceeds the bound, ``acquire`` either
**sheds** the read (raises :class:`AdmissionShed`, the default) or
**parks** it (blocks until the writer catches up, with an optional
timeout after which it sheds).  Shedding reads under lag is what keeps
a rebuild-starved writer from being starved further by the read side.
"""

from __future__ import annotations

import threading
import time
from contextlib import contextmanager
from dataclasses import dataclass
from typing import TYPE_CHECKING, Iterator

import numpy as np

if TYPE_CHECKING:  # the pipeline imports this module; keep the cycle lazy
    from .btree import BTree
    from .metadata import DSMeta
    from .pipeline import ReconstructionResult

__all__ = ["AdmissionShed", "IndexSnapshot", "SnapshotPin", "SnapshotCell"]


class AdmissionShed(RuntimeError):
    """A read was shed by admission control (rebuild lag over the bound).

    Raised by :meth:`SnapshotCell.acquire` when the writer-reported lag
    exceeds ``max_lag_epochs`` under the ``"shed"`` policy, or when a
    parked read times out under the ``"park"`` policy.  Callers are
    expected to drop or retry the request — the whole point is that the
    read does *not* run while the writer is drowning.
    """


@dataclass(frozen=True)
class IndexSnapshot:
    """One reconstruction, frozen: epoch-stamped, device-resident, immutable.

    ``tree``/``comp_sorted``/``rid_sorted``/``row_sorted`` are the
    pipeline's device arrays; ``meta`` is the refreshed DS-metadata and
    ``extract_bitmap`` the D-bitmap the compressed run was extracted
    under (both copied at freeze time); ``watermark`` is the LSN the
    state is current through (``None`` when not log-driven).
    """

    epoch: int
    tree: "BTree"
    meta: "DSMeta"
    comp_sorted: object
    rid_sorted: object
    row_sorted: object | None
    extract_bitmap: np.ndarray | None
    watermark: int | None

    @property
    def n_keys(self) -> int:
        """Number of live keys in the snapshot's tree."""
        return int(self.tree.n_keys)

    @staticmethod
    def from_result(result: "ReconstructionResult", epoch: int) -> "IndexSnapshot":
        """Freeze a pipeline result at ``epoch``.

        The device arrays are shared (jax arrays are immutable); the
        host-side metadata is deep-copied so producers that keep mutating
        their working ``DSMeta``/``extract_bitmap`` (the §4.3 insert rule
        runs in place on some consumers) cannot reach into a published
        snapshot.
        """
        from dataclasses import replace as _replace

        meta = result.meta
        frozen_meta = _replace(
            meta,
            dbitmap=np.array(meta.dbitmap, np.uint32, copy=True),
            varbitmap=np.array(meta.varbitmap, np.uint32, copy=True),
            refkey=np.array(meta.refkey, np.uint32, copy=True),
        )
        eb = result.extract_bitmap
        return IndexSnapshot(
            epoch=int(epoch),
            tree=result.tree,
            meta=frozen_meta,
            comp_sorted=result.comp_sorted,
            rid_sorted=result.rid_sorted,
            row_sorted=result.row_sorted,
            extract_bitmap=None if eb is None else np.array(eb, np.uint32, copy=True),
            watermark=result.watermark,
        )

    def lookup(self, backend, queries):
        """Batched point lookup through a backend's ``lookup`` op.

        Convenience for read-path consumers: ``backend`` is any
        ``ExecutionBackend``; returns the op's ``(found, rid)`` pair.
        """
        return backend.lookup(self.tree, queries)


class SnapshotPin:
    """One acquire: a lease on a pinned epoch, released exactly once.

    Every :meth:`SnapshotCell.acquire` mints a fresh lease; the lease —
    not the (shared, epoch-wide) snapshot object — is what ``release``
    consumes, which is how a double release is *detected* instead of
    silently decrementing some other reader's refcount.  Attribute
    access delegates to the pinned :class:`IndexSnapshot` (``.tree``,
    ``.epoch``, ``.lookup(...)`` all work directly), and the lease is a
    context manager for scoped use.
    """

    __slots__ = ("_cell", "_snapshot", "_released")

    def __init__(self, cell: "SnapshotCell", snapshot: IndexSnapshot) -> None:
        self._cell = cell
        self._snapshot = snapshot
        self._released = False

    @property
    def snapshot(self) -> IndexSnapshot:
        """The pinned snapshot this lease holds alive."""
        return self._snapshot

    @property
    def released(self) -> bool:
        """Whether this lease was already released."""
        return self._released

    def release(self) -> None:
        """Drop this lease (exactly once; a second call raises)."""
        self._cell.release(self)

    def __getattr__(self, name):
        # only reached for names not on the lease itself: delegate to the
        # snapshot so pin-holding readers can use it as one
        return getattr(object.__getattribute__(self, "_snapshot"), name)

    def __enter__(self) -> "SnapshotPin":
        """Scoped use: ``with cell.acquire() as snap: ...``."""
        return self

    def __exit__(self, *exc) -> None:
        """Release the lease on scope exit."""
        self.release()

    def __repr__(self) -> str:
        state = "released" if self._released else "held"
        return f"SnapshotPin(epoch={self._snapshot.epoch}, {state})"


class SnapshotCell:
    """The epoch-based publish/acquire protocol (a one-slot double buffer).

    Writers call :meth:`publish` with each finished reconstruction;
    readers wrap their lookups in :meth:`pin` (or hold the
    :class:`SnapshotPin` an explicit :meth:`acquire` returns).  The cell
    retires — but does not drop — the previous snapshot while any reader
    still pins it, which is what lets a rebuild proceed concurrently
    with reads: queries pinned before the swap keep answering from the
    pre-rebuild epoch, queries pinned after it see the new one, and no
    query ever sees a mixture.  The protocol is single-writer,
    multi-reader thread-safe (see the module docstring for the exact
    guarantees and the admission-control knobs).

    ``start_epoch`` seeds the numbering: the first publish lands at
    ``start_epoch + 1`` (the default ``-1`` makes it epoch 0).  A
    checkpoint-restored consumer resumes the cell at the persisted epoch
    so its history continues the producer's.

    ``max_lag_epochs`` (optional) turns on admission control: while the
    writer-reported lag (:meth:`report_lag`) exceeds it, ``acquire``
    sheds (``admission="shed"``, raising :class:`AdmissionShed`) or
    parks (``admission="park"``, blocking until the lag drops;
    ``park_timeout`` seconds at most, then it sheds).
    """

    def __init__(
        self,
        start_epoch: int = -1,
        *,
        max_lag_epochs: int | None = None,
        admission: str = "shed",
        park_timeout: float | None = None,
    ) -> None:
        if admission not in ("shed", "park"):
            raise ValueError(f"admission must be 'shed' or 'park', got {admission!r}")
        if max_lag_epochs is not None and int(max_lag_epochs) < 0:
            raise ValueError(f"max_lag_epochs must be >= 0, got {max_lag_epochs}")
        self._lock = threading.Lock()
        self._lag_ok = threading.Condition(self._lock)
        self._current: IndexSnapshot | None = None
        self._epoch = int(start_epoch)
        self._pins: dict[int, int] = {}
        self._retired: dict[int, IndexSnapshot] = {}
        # admission control
        self.max_lag_epochs = None if max_lag_epochs is None else int(max_lag_epochs)
        self.admission = admission
        self.park_timeout = park_timeout
        self._lag = 0
        # counters — mutated only inside the lock's critical sections, so
        # they are exact under contention (asserted by the concurrency tests)
        self.n_published = 0
        self.n_acquired = 0
        self.n_released = 0
        self.n_shed = 0
        self.n_parked = 0
        self.park_wait_s = 0.0
        self._retired_epochs = 0
        self._outstanding = 0
        self._max_concurrent_pins = 0

    # --------------------------------------------------------------- state
    @property
    def current(self) -> IndexSnapshot | None:
        """The currently published snapshot (``None`` before the first)."""
        return self._current

    @property
    def epoch(self) -> int:
        """Epoch of the current snapshot (``start_epoch`` before any)."""
        return self._epoch

    @property
    def lag_epochs(self) -> int:
        """The writer-reported rebuild lag (see :meth:`report_lag`)."""
        return self._lag

    def pinned_epochs(self) -> list[int]:
        """Epochs with at least one outstanding pin, ascending."""
        with self._lock:
            return sorted(e for e, c in self._pins.items() if c > 0)

    # ------------------------------------------------------------- publish
    def publish(
        self, result: "ReconstructionResult", epoch: int | None = None
    ) -> IndexSnapshot:
        """Freeze ``result`` and atomically swap it in as the next epoch.

        ``epoch`` defaults to ``current + 1`` and must be strictly
        increasing when given explicitly (the checkpoint-resume path).
        The previous snapshot is retired while pinned and dropped once its
        last pin releases; an unpinned previous snapshot is dropped
        immediately (double buffering, not an unbounded history).

        The freeze — the metadata deep copies — runs *outside* the cell's
        mutex; only the pointer swap and the retire bookkeeping run under
        it, so concurrent readers never wait on reconstruction work.
        The cell is single-writer: concurrent publishers are not torn
        (the swap is locked) but the loser of an epoch race gets the
        monotonicity ``ValueError``.
        """
        epoch = self._epoch + 1 if epoch is None else int(epoch)
        snap = IndexSnapshot.from_result(result, epoch)
        with self._lag_ok:
            if epoch <= self._epoch and self._current is not None:
                raise ValueError(
                    f"epoch must increase: publishing {epoch} over {self._epoch}"
                )
            prev = self._current
            self._current = snap
            self._epoch = epoch
            self.n_published += 1
            if prev is not None:
                if self._pins.get(prev.epoch, 0) > 0:
                    self._retired[prev.epoch] = prev
                else:
                    # no reader ever pins it again: freed right here
                    self._retired_epochs += 1
            # a publish can only shrink the backlog — wake parked readers
            # so they re-check the lag bound
            self._lag_ok.notify_all()
        return snap

    # --------------------------------------------------- admission control
    def report_lag(self, lag_epochs: int) -> None:
        """Writer-side backlog report: ``lag_epochs`` pending rebuilds.

        The serving writer calls this as its mutation feed outruns (or
        catches up with) its rebuild loop; ``acquire`` compares the last
        reported value against ``max_lag_epochs``.  Lowering the lag
        wakes parked readers.
        """
        with self._lag_ok:
            self._lag = max(0, int(lag_epochs))
            if self.max_lag_epochs is None or self._lag <= self.max_lag_epochs:
                self._lag_ok.notify_all()

    def _admit_locked(self) -> None:
        """Shed or park the calling reader while the lag is over bound.

        Runs under the lock; ``park`` waits on the condition the writer
        notifies (re-checking, so spurious wakeups are harmless) and
        sheds on timeout.
        """
        if self.max_lag_epochs is None or self._lag <= self.max_lag_epochs:
            return
        if self.admission == "shed":
            self.n_shed += 1
            raise AdmissionShed(
                f"read shed: rebuild lag {self._lag} epochs > "
                f"max_lag_epochs {self.max_lag_epochs}"
            )
        self.n_parked += 1
        t0 = time.perf_counter()
        deadline = None if self.park_timeout is None else t0 + self.park_timeout
        while self._lag > self.max_lag_epochs:
            remaining = None if deadline is None else deadline - time.perf_counter()
            if remaining is not None and remaining <= 0:
                self.park_wait_s += time.perf_counter() - t0
                self.n_shed += 1
                raise AdmissionShed(
                    f"parked read timed out after {self.park_timeout}s: "
                    f"rebuild lag {self._lag} epochs > "
                    f"max_lag_epochs {self.max_lag_epochs}"
                )
            self._lag_ok.wait(timeout=remaining)
        self.park_wait_s += time.perf_counter() - t0

    # ------------------------------------------------------------- readers
    def acquire(self) -> SnapshotPin:
        """Pin the current snapshot; returns a one-shot :class:`SnapshotPin`.

        Raises ``RuntimeError`` before the first publish and
        :class:`AdmissionShed` when admission control sheds the read.
        Every lease must be released exactly once (``pin.release()`` or
        the lease's context manager); prefer the :meth:`pin` context
        manager for scoped reads.  The critical section is a few dict
        operations — a reader never waits on a concurrent rebuild.
        """
        with self._lock:
            self._admit_locked()
            snap = self._current
            if snap is None:
                raise RuntimeError("no snapshot published yet")
            self._pins[snap.epoch] = self._pins.get(snap.epoch, 0) + 1
            self.n_acquired += 1
            self._outstanding += 1
            if self._outstanding > self._max_concurrent_pins:
                self._max_concurrent_pins = self._outstanding
            return SnapshotPin(self, snap)

    def release(self, pin: "SnapshotPin | IndexSnapshot") -> None:
        """Drop one pin; the last release of a retired epoch frees it.

        ``pin`` is normally the :class:`SnapshotPin` lease ``acquire``
        returned: releasing it twice raises, even while other readers
        still pin the same epoch (the double release consumed *this*
        lease, not their refcount).  A raw :class:`IndexSnapshot` is
        also accepted for epoch-level bookkeeping, but it must be a
        snapshot this cell actually published *and* its epoch must have
        outstanding pins — anything else raises instead of silently
        corrupting the refcounts.
        """
        with self._lock:
            if isinstance(pin, SnapshotPin):
                if pin._released:
                    raise RuntimeError(
                        f"double release of pin on epoch {pin._snapshot.epoch}"
                    )
                if pin._cell is not self:
                    raise RuntimeError("pin belongs to a different SnapshotCell")
                pin._released = True
                snap = pin._snapshot
            else:
                snap = pin
                live = (
                    self._current
                    if self._current is not None and snap.epoch == self._current.epoch
                    else self._retired.get(snap.epoch)
                )
                if live is not snap:
                    raise RuntimeError(
                        f"release of epoch {snap.epoch}: not a snapshot this "
                        f"cell currently tracks (double release or foreign "
                        f"snapshot)"
                    )
            n = self._pins.get(snap.epoch, 0)
            if n <= 0:
                raise RuntimeError(f"release of unpinned epoch {snap.epoch}")
            self.n_released += 1
            self._outstanding -= 1
            if n == 1:
                del self._pins[snap.epoch]
                if self._retired.pop(snap.epoch, None) is not None:
                    # the last release of a retired epoch frees it — once
                    self._retired_epochs += 1
            else:
                self._pins[snap.epoch] = n - 1

    @contextmanager
    def pin(self) -> Iterator[SnapshotPin]:
        """``with cell.pin() as snap:`` — acquire/release, exception-safe.

        Yields the :class:`SnapshotPin` lease, which delegates attribute
        access to the pinned snapshot (``snap.tree``, ``snap.epoch``,
        ``snap.lookup(...)``).
        """
        p = self.acquire()
        try:
            yield p
        finally:
            p.release()

    # --------------------------------------------------------------- stats
    def stats(self) -> dict:
        """Exact cell counters (taken under the bookkeeping mutex).

        ``acquires``/``releases`` count leases; ``pinned`` is the
        outstanding total and ``max_concurrent_pins`` its high-water
        mark; ``retired`` is the number of superseded epochs still held
        alive by pins, ``retired_epochs`` the cumulative count of
        superseded epochs the cell has freed (each exactly once);
        ``shed``/``parked``/``park_wait_s``/``lag_epochs`` are the
        admission-control counters.  ``n_published``/``n_acquired`` are
        kept as aliases of ``publishes``/``acquires``.
        """
        with self._lock:
            return {
                "epoch": self._epoch,
                "n_published": self.n_published,
                "n_acquired": self.n_acquired,
                "acquires": self.n_acquired,
                "releases": self.n_released,
                "pinned": self._outstanding,
                "max_concurrent_pins": self._max_concurrent_pins,
                "retired": len(self._retired),
                "retired_epochs": self._retired_epochs,
                "shed": self.n_shed,
                "parked": self.n_parked,
                "park_wait_s": self.park_wait_s,
                "lag_epochs": self._lag,
                "max_lag_epochs": self.max_lag_epochs,
            }
