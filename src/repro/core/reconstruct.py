"""End-to-end index reconstruction (paper §5, Figure 7).

    table (memory-resident) --scan--> extract compressed keys + rids
        --parallel sort--> sorted (comp key, rid) pairs
        --bottom-up build--> partial-key B+tree
        (+ recompute DS-metadata for next time, §4.3)

Single-device and mesh-distributed (shard_map sample sort) paths.  Timings
of the three phases (extract / sort / build) are reported to mirror the
paper's Figure 9 breakdown.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from .btree import BTree, BTreeConfig, build_btree
from .compress import extract_bits
from .dbits import sort_words
from .keyformat import KeySet
from .metadata import DSMeta, meta_from_keys, meta_on_rebuild
from .sortkeys import word_comparison_counts

__all__ = ["ReconstructionResult", "reconstruct_index", "full_key_reconstruct"]


@dataclass
class ReconstructionResult:
    tree: BTree
    meta: DSMeta
    comp_sorted: jnp.ndarray
    rid_sorted: jnp.ndarray
    timings: dict = field(default_factory=dict)
    stats: dict = field(default_factory=dict)


def _timed(fn, *args):
    t0 = time.perf_counter()
    out = fn(*args)
    out_c = jax.tree_util.tree_map(
        lambda x: x.block_until_ready() if hasattr(x, "block_until_ready") else x, out
    )
    return out_c, time.perf_counter() - t0


def reconstruct_index(
    keyset: KeySet,
    meta: DSMeta | None = None,
    config: BTreeConfig = BTreeConfig(),
    use_kernel: bool = False,
    time_phases: bool = True,
) -> ReconstructionResult:
    """The compressed key sort pipeline of Figure 1 (bottom flow)."""
    words = jnp.asarray(keyset.words, jnp.uint32)
    rids = jnp.asarray(keyset.rids, jnp.uint32)
    lengths = jnp.asarray(keyset.lengths, jnp.int32)

    t_meta = 0.0
    if meta is None:
        t0 = time.perf_counter()
        meta = meta_from_keys(keyset.words)
        t_meta = time.perf_counter() - t0
    plan = meta.plan()

    # -- extract ------------------------------------------------------------
    if use_kernel:
        from repro.kernels.pext import ops as pext_ops

        extract = lambda w: pext_ops.pext(w, plan)
    else:
        extract = lambda w: extract_bits(w, plan)
    comp, t_extract = _timed(extract, words)

    # -- sort ---------------------------------------------------------------
    rows = jnp.arange(keyset.n, dtype=jnp.uint32)

    def _sort(c, r):
        sw, srow = sort_words(c, r)
        return sw, srow

    (comp_sorted, row_sorted), t_sort = _timed(_sort, comp, rows)
    rid_sorted = rids[row_sorted]

    # -- build --------------------------------------------------------------
    def _build():
        return build_btree(
            comp_sorted, row_sorted, meta, words, lengths, config, rids=rids
        )

    tree, t_build = _timed(_build)

    # -- refresh DS-metadata (opportune time, §4.3) ---------------------------
    new_meta = meta_on_rebuild(
        np.asarray(comp_sorted), meta, np.asarray(keyset.words[0])
    )

    full_bits = keyset.n_bits
    stats = {
        "n_keys": keyset.n,
        "full_key_bits": full_bits,
        "distinction_bits": meta.n_dbits,
        "compression_ratio": full_bits / max(meta.n_dbits, 1),
        "full_sort_key_words": keyset.n_words + 1,  # + rid word
        "comp_sort_key_words": comp.shape[1] + 1,
        "sort_key_ratio": (keyset.n_words + 1) / (comp.shape[1] + 1),
        "wcc_full": float(word_comparison_counts(jnp.asarray(keyset.words)[rid_sorted])),
        "wcc_comp": float(word_comparison_counts(comp_sorted)),
        "tree_height": tree.height,
        "tree_bytes": tree.memory_bytes(),
    }
    stats["word_comparison_ratio"] = stats["wcc_full"] / max(stats["wcc_comp"], 1e-9)
    timings = {
        "meta": t_meta,
        "extract": t_extract,
        "sort": t_sort,
        "build": t_build,
        "total": t_extract + t_sort + t_build,
    }
    return ReconstructionResult(tree, new_meta, comp_sorted, rid_sorted, timings, stats)


def full_key_reconstruct(
    keyset: KeySet, config: BTreeConfig = BTreeConfig()
) -> ReconstructionResult:
    """Baseline (Figure 1 top flow): full key sort, then build.

    Uses the identity extraction plan — every bit position is treated as a
    distinction bit — so the same build path runs uncompressed.
    """
    words = jnp.asarray(keyset.words, jnp.uint32)
    rids = jnp.asarray(keyset.rids, jnp.uint32)
    lengths = jnp.asarray(keyset.lengths, jnp.int32)

    rows = jnp.arange(keyset.n, dtype=jnp.uint32)

    def _sort(w, r):
        return sort_words(w, r)

    (full_sorted, row_sorted), t_sort = _timed(_sort, words, rows)
    rid_sorted = rids[row_sorted]

    # identity metadata: all-ones bitmap over the full width
    ident = DSMeta(
        dbitmap=np.full((keyset.n_words,), 0xFFFFFFFF, np.uint32),
        varbitmap=np.full((keyset.n_words,), 0xFFFFFFFF, np.uint32),
        refkey=np.asarray(keyset.words[0], np.uint32),
        n_words=keyset.n_words,
    )

    def _build():
        return build_btree(
            full_sorted, row_sorted, ident, words, lengths, config, rids=rids
        )

    tree, t_build = _timed(_build)
    timings = {"extract": 0.0, "sort": t_sort, "build": t_build, "total": t_sort + t_build}
    stats = {
        "n_keys": keyset.n,
        "wcc_full": float(word_comparison_counts(full_sorted)),
        "tree_height": tree.height,
        "tree_bytes": tree.memory_bytes(),
    }
    return ReconstructionResult(tree, ident, full_sorted, rid_sorted, timings, stats)
