"""End-to-end index reconstruction (paper §5, Figure 7) — thin wrappers.

The actual pipeline — scan → compressed-key extract → parallel sort →
bottom-up build → DS-metadata refresh, with per-stage timings (Figure 9) —
lives in ``repro.core.pipeline.ReconstructionPipeline`` and dispatches its
data-parallel stages to a registered execution backend (``repro.backends``:
``jnp`` / ``pallas`` / ``distributed``).  These functions are the stable
convenience entry points the rest of the repo and the paper-table
benchmarks call.
"""

from __future__ import annotations

from .btree import BTreeConfig
from .keyformat import KeySet
from .metadata import DSMeta
from .pipeline import ReconstructionPipeline, ReconstructionResult

__all__ = ["ReconstructionResult", "reconstruct_index", "full_key_reconstruct"]


def reconstruct_index(
    keyset: KeySet,
    meta: DSMeta | None = None,
    config: BTreeConfig = BTreeConfig(),
    use_kernel: bool = False,
    time_phases: bool = True,
    backend: str | None = None,
    backend_opts: dict | None = None,
    fused: bool = False,
) -> ReconstructionResult:
    """The compressed key sort pipeline of Figure 1 (bottom flow).

    ``backend`` selects the execution substrate by name; ``use_kernel=True``
    is the legacy spelling of ``backend="pallas"``.  ``fused=True`` takes
    the fused extract+sort fast path on backends that support it.
    """
    del time_phases  # timings are always recorded by the pipeline
    name = backend or ("pallas" if use_kernel else "jnp")
    pipe = ReconstructionPipeline(
        backend=name, config=config, fused=fused, backend_opts=backend_opts
    )
    return pipe.run(keyset, meta=meta)


def full_key_reconstruct(
    keyset: KeySet,
    config: BTreeConfig = BTreeConfig(),
    backend: str = "jnp",
    backend_opts: dict | None = None,
) -> ReconstructionResult:
    """Baseline (Figure 1 top flow): full key sort, then build.

    Identity metadata — every bit position is a distinction bit — so the
    same build path runs uncompressed, on any backend.
    """
    pipe = ReconstructionPipeline(
        backend=backend, config=config, backend_opts=backend_opts
    )
    return pipe.run(keyset, full_keys=True)
