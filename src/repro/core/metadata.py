"""DS-metadata (paper §4.2–4.3): the only persistent state for an index.

``{D-bitmap, variant bitmap, reference key}`` — everything else (the sorted
order, the tree) is reconstructed from the base table.  The update rules and
their correctness arguments are implemented exactly:

* **insert** K between A and B: by Lemma 1, D-bit(A,B) = min(D(A,K), D(K,B))
  and is already set, so only ``max(D(A,K), D(K,B))`` needs setting; the
  variant bitmap ORs in ``K XOR reference``.
* **delete**: *no change* — by Lemma 1 the surviving pair's distinction bit
  is the min of the two removed pairs' bits, both already set.  Stale 1-bits
  are harmless by Theorem 2 (extended distinction bit positions).
* **rebuild**: compute the bitmap anew from adjacent compressed keys; bits
  that were 0 stay 0, stale bits are shed.

The *update rules* (``meta_on_insert`` etc.) are host-side scalar work
(numpy) — they sit on the DB transaction path.  The *rebuild-time refresh*
is not host-side-only: since the compiled-plan work landed, the adjacent
D-bit positions run as a cached, shape-bucketed device program (the
backends' ``refresh_meta`` op feeds them in via ``dpos_comp``), and only
the final scatter-OR into the bitmap words happens here on the host.
"""

from __future__ import annotations

from dataclasses import dataclass, replace

import numpy as np

from .compress import ExtractionPlan, make_plan

__all__ = [
    "DSMeta",
    "meta_from_keys",
    "meta_on_insert",
    "meta_on_delete",
    "meta_on_rebuild",
    "shed_or_pin",
]


def _np_dbit(a: np.ndarray, b: np.ndarray) -> int:
    """Distinction bit position of two (W,) uint32 keys; -1 if equal."""
    x = (np.asarray(a, np.uint32) ^ np.asarray(b, np.uint32)).astype(np.uint32)
    nz = np.nonzero(x)[0]
    if nz.size == 0:
        return -1
    w = int(nz[0])
    v = int(x[w])
    return w * 32 + (31 - v.bit_length() + 1)


def _set_bit(bitmap: np.ndarray, pos: int) -> np.ndarray:
    out = bitmap.copy()
    out[pos // 32] |= np.uint32(1) << np.uint32(31 - pos % 32)
    return out


@dataclass(frozen=True)
class DSMeta:
    """Persistent DS-metadata for one index (host-side numpy)."""

    dbitmap: np.ndarray  # (W,) uint32 — extended distinction bit positions
    varbitmap: np.ndarray  # (W,) uint32 — extended variant bit positions
    refkey: np.ndarray  # (W,) uint32 — any member key (invariant-bit source)
    n_words: int

    def plan(self) -> ExtractionPlan:
        return make_plan(self.dbitmap, self.n_words)

    @property
    def n_dbits(self) -> int:
        return int(sum(bin(int(w)).count("1") for w in self.dbitmap))

    @property
    def compression_ratio(self) -> float:
        return (self.n_words * 32) / max(self.n_dbits, 1)

    def d_offset(self) -> np.ndarray:
        """D-offset[i] = full-key position of the (i+1)-st 1 in the D-bitmap
        (paper §5.3) — maps compressed-key bit positions back to full-key
        positions for distinction-bit fields in tree entries."""
        from .dbits import dbit_positions_nonempty

        return dbit_positions_nonempty(self.dbitmap)

    # -- serialization (checkpoint manifest / replication payload) ----------
    def to_npz_dict(self) -> dict[str, np.ndarray]:
        return {
            "dbitmap": self.dbitmap,
            "varbitmap": self.varbitmap,
            "refkey": self.refkey,
            "n_words": np.asarray(self.n_words, np.int32),
        }

    @staticmethod
    def from_npz_dict(d: dict[str, np.ndarray]) -> "DSMeta":
        return DSMeta(
            dbitmap=np.asarray(d["dbitmap"], np.uint32),
            varbitmap=np.asarray(d["varbitmap"], np.uint32),
            refkey=np.asarray(d["refkey"], np.uint32),
            n_words=int(d["n_words"]),
        )


def meta_from_keys(words: np.ndarray) -> DSMeta:
    """Initial DS-metadata from full index keys (first-time build, §4.3)."""
    import jax.numpy as jnp

    from .dbits import compute_dbitmap, compute_variant_bitmap

    w = np.asarray(words, np.uint32)
    dbm = np.asarray(compute_dbitmap(jnp.asarray(w)), np.uint32)
    var, ref = compute_variant_bitmap(jnp.asarray(w))
    return DSMeta(
        dbitmap=dbm,
        varbitmap=np.asarray(var, np.uint32),
        refkey=np.asarray(ref, np.uint32),
        n_words=int(w.shape[1]),
    )


def meta_on_insert(meta: DSMeta, prev_key: np.ndarray | None, new_key: np.ndarray,
                   next_key: np.ndarray | None) -> DSMeta:
    """Insert K between neighbors A (prev) and B (next); either may be absent
    at the extremes of the key range."""
    candidates = []
    for nb in (prev_key, next_key):
        if nb is not None:
            d = _np_dbit(nb, new_key)
            if d >= 0:
                candidates.append(d)
    dbm = meta.dbitmap
    if candidates:
        # Lemma 1: min(D(A,K), D(K,B)) == D(A,B), already set; set the max.
        dbm = _set_bit(dbm, max(candidates))
    var = meta.varbitmap | (np.asarray(new_key, np.uint32) ^ meta.refkey)
    return replace(meta, dbitmap=dbm, varbitmap=var)


def meta_on_delete(meta: DSMeta) -> DSMeta:
    """Deletes leave the bitmaps untouched (lazy; valid by Theorem 2)."""
    return meta


def meta_on_rebuild(
    comp_sorted: np.ndarray,
    old_meta: DSMeta,
    ref_full_key: np.ndarray,
    dpos_comp: np.ndarray | None = None,
) -> DSMeta:
    """Recompute DS-metadata during index reconstruction (§4.3).

    The new D-bitmap comes from adjacent *compressed* keys mapped through
    D-offset: stale bits (0 adjacency in the compressed space) are shed and
    bits that were 0 stay 0.  The variant bitmap is rebuilt from the same
    pass over the table (done by the caller who still holds full keys;
    here we accept the compressed adjacency only).

    The bit set is one vectorized scatter-OR into the 32-bit bitmap words
    (``np.bitwise_or.at`` is duplicate-safe), not a per-position Python
    loop.  ``dpos_comp`` optionally carries precomputed adjacent D-bit
    positions — the pipeline's cached refresh program
    (``repro.core.plancache.adjacent_dpos_padded``) passes them so the
    device half of the refresh compiles once per shape bucket.
    """
    from .dbits import NO_DBIT

    if dpos_comp is None:
        import jax.numpy as jnp

        from .dbits import adjacent_dbit_positions

        dpos_comp = np.asarray(
            adjacent_dbit_positions(jnp.asarray(comp_sorted, jnp.uint32))
        )
    dpos_comp = np.asarray(dpos_comp)
    d_off = old_meta.d_offset()
    valid = dpos_comp != NO_DBIT
    full_pos = d_off[dpos_comp[valid]]
    dbm = np.zeros_like(old_meta.dbitmap)
    if full_pos.size:
        np.bitwise_or.at(
            dbm,
            full_pos // 32,
            np.uint32(1) << (31 - (full_pos % 32)).astype(np.uint32),
        )
    return replace(old_meta, dbitmap=dbm, refkey=np.asarray(ref_full_key, np.uint32))


def shed_or_pin(
    refreshed_meta: DSMeta,
    extract_bitmap: np.ndarray,
    deletes_since_shed: int,
    shed_delete_frac: float | None,
    n_live: int,
) -> tuple[DSMeta, bool, int]:
    """The post-rebuild bitmap policy shared by Replica and the serve pager.

    Pinning the working D-bitmap to the *extraction* bitmap keeps
    consecutive rebuilds incremental (the standing sorted run can still be
    merged against), but lets delete-stale widened bits accumulate.  When
    the delete volume since the bits were last re-derived crosses
    ``shed_delete_frac`` of the live index, adopt the refreshed (shed)
    bitmap instead — the next rebuild pays one full resort under the
    narrower projection, then pinning resumes.  ``None`` never sheds.

    Returns ``(working_meta, shed, deletes_since_shed)``.
    """
    shed = (
        shed_delete_frac is not None
        and deletes_since_shed > shed_delete_frac * n_live
    )
    if shed:
        return refreshed_meta, True, 0
    pinned = replace(
        refreshed_meta, dbitmap=np.array(extract_bitmap, np.uint32, copy=True)
    )
    return pinned, False, deletes_since_shed
