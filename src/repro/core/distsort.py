"""Distributed compressed-key sort — the row-column sort on a TPU mesh.

The paper's row-column sort (Appendix A) structures a parallel sort as:
per-core cache-sized block sorts -> per-core multiway merge -> *perfect
p-partition* across cores -> per-core multiway merge.  On a TPU mesh the
same roles are played by:

  CPU core          -> mesh device (shard_map over one mesh axis)
  L3-sized block    -> VMEM tile   (``repro.kernels.bitonic`` block sort)
  per-core merge    -> on-device ``lax.sort`` of block-sorted runs
  perfect partition -> regular-sampling splitters + bucketed ``all_to_all``
  shared memory     -> ICI collective (this is the step whose byte volume
                       key compression divides by the sort-key ratio)

**Adaptation note** (recorded per DESIGN.md §2): the perfect partition of
Francis–Mathieson–Pannan yields *exactly* n/p elements per core, which
requires data-dependent shard sizes.  XLA SPMD programs have static shapes,
so we use sampled splitters with a *capacity factor* — each device accepts
up to ``ceil(n/p * capacity_factor)`` elements and the kernel reports
overflow (exactly the compromise MoE dispatch makes).  With regular
sampling of locally sorted runs, the imbalance bound is the classic sample
sort bound (< 2x for p samples/shard); capacity 1.5 has zero overflow in
all our benchmarks, and overflow is detected and surfaced, never silent.
"""

from __future__ import annotations

from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map

from .dbits import sort_words, sort_words_keyed

__all__ = ["DistSortResult", "sample_sort", "make_sample_sort"]

# Padding sentinel: all-ones words sort after every real key under uint32
# lexicographic order.  Real keys that are all-ones in every word would tie
# with the sentinel; the validity mask (not the sentinel value) is
# authoritative, so correctness does not depend on sentinel uniqueness.
_SENTINEL = np.uint32(0xFFFFFFFF)


@dataclass
class DistSortResult:
    """Globally sorted keys, shard-padded.

    keys:     (p * cap, W) — device i holds rows [i*cap, (i+1)*cap); within a
              device rows are sorted and padded at the tail with sentinels.
              Concatenating the valid prefixes of shards 0..p-1 yields the
              globally sorted order.
    rids:     (p * cap,) permuted record ids (sentinel rows: 0xFFFFFFFF).
    valid:    (p * cap,) bool — True for real rows.
    overflow: () int32 — number of dropped elements (0 in healthy runs;
              callers must check and re-run with higher capacity if not).
    """

    keys: jnp.ndarray
    rids: jnp.ndarray
    valid: jnp.ndarray
    overflow: jnp.ndarray


def _local_shard_sort(words, rids):
    # Keyed sort: ties between equal keys break on the rid everywhere in
    # this module, so the global output order is the deterministic
    # (key, rid) order regardless of how the exchange interleaves equal
    # keys across shards.
    return sort_words_keyed(words, rids)


def make_sample_sort(mesh: Mesh, axis_name: str, n_per_shard: int, n_words: int,
                     capacity_factor: float = 1.5):
    """Build a jit-able distributed sample sort over one mesh axis.

    Returns fn(words (n,W) uint32, rids (n,)) -> DistSortResult with
    n = p * n_per_shard, sharded on axis 0.
    """
    p = mesh.shape[axis_name]
    cap = int(np.ceil(n_per_shard * capacity_factor / max(p, 1)))  # per-bucket
    recv = p * cap  # rows per device after exchange

    def shard_fn(words, rids):
        ln = words.shape[0]

        # ---- phase 0: spread exchange -----------------------------------
        # The paper scans an *unsorted* table; if the caller's shards are
        # range-partitioned (e.g. already sorted), every row of a shard
        # lands in one bucket and per-pair capacity blows up.  A fixed
        # block exchange gives every device a cross-section of the global
        # range first (one extra all_to_all of the payload).
        if p > 1 and ln % p == 0:
            def spread(x):
                parts = x.reshape((p, ln // p) + x.shape[1:])
                return jax.lax.all_to_all(parts, axis_name, 0, 0).reshape(x.shape)

            words = spread(words)
            rids = spread(rids)

        # ---- phase 1: local sort (block bitonic + merge in kernel path;
        # lax.sort here — same comparator structure) -------------------------
        sw, srid = _local_shard_sort(words, rids)

        if p == 1:
            pad = recv - ln
            keys = jnp.concatenate([sw, jnp.full((pad, n_words), _SENTINEL)], axis=0) if pad else sw
            out_r = jnp.concatenate([srid, jnp.full((pad,), _SENTINEL)]) if pad else srid
            valid = jnp.arange(recv) < ln
            return keys[:recv], out_r[:recv], valid, jnp.int32(0)

        # ---- phase 2: regular sampling -> global splitters ------------------
        # Splitters extend the key with the rid: the perfect partition of
        # Francis-Mathieson-Pannan splits runs of EQUAL keys across
        # processors; a (key ++ rid) splitter reproduces that tie handling,
        # so duplicate-heavy inputs (Zipf keys) still balance.
        step = max(ln // p, 1)
        samp_idx = jnp.minimum(jnp.arange(p) * step + step // 2, ln - 1)
        keyed = jnp.concatenate([sw, srid[:, None]], axis=1)  # (ln, W+1)
        samples = keyed[samp_idx]  # (p, W+1)
        all_samples = jax.lax.all_gather(samples, axis_name)  # (p, p, W+1)
        flat = all_samples.reshape(p * p, n_words + 1)
        (sorted_samples,) = sort_words(flat)
        splitters = sorted_samples[jnp.arange(1, p) * p]  # (p-1, W+1)

        # ---- phase 3: bucket assignment (locally sorted => buckets are
        # contiguous runs) ----------------------------------------------------
        # bucket(key) = #splitters <= key, via multiword lexicographic compare
        def le(a, b):  # a (m,W) splitters vs b (ln,W) keys -> (ln, m)
            lt = a[None, :, :] < b[:, None, :]
            eq = a[None, :, :] == b[:, None, :]
            eq_prefix = jnp.cumprod(
                jnp.concatenate(
                    [jnp.ones_like(eq[..., :1], jnp.int32), eq[..., :-1].astype(jnp.int32)],
                    axis=-1,
                ),
                axis=-1,
            ).astype(bool)
            less = jnp.any(lt & eq_prefix, axis=-1)
            equal = jnp.all(eq, axis=-1)
            return less | equal

        bucket = jnp.sum(le(splitters, keyed), axis=1).astype(jnp.int32)  # (ln,)
        start = jnp.searchsorted(bucket, jnp.arange(p), side="left")
        within = jnp.arange(ln, dtype=jnp.int32) - start[bucket]
        overflow = jnp.sum((within >= cap).astype(jnp.int32))

        # ---- phase 4: scatter into per-destination capacity buckets ---------
        send_keys = jnp.full((p, cap, n_words), _SENTINEL, dtype=jnp.uint32)
        send_rids = jnp.full((p, cap), _SENTINEL, dtype=jnp.uint32)
        send_valid = jnp.zeros((p, cap), dtype=jnp.uint32)
        ok = within < cap
        w_idx = jnp.where(ok, within, cap)  # cap is out of bounds -> dropped
        send_keys = send_keys.at[bucket, w_idx].set(sw, mode="drop")
        send_rids = send_rids.at[bucket, w_idx].set(srid, mode="drop")
        send_valid = send_valid.at[bucket, w_idx].set(jnp.uint32(1), mode="drop")

        # ---- phase 5: the "shared memory" step -> ICI all_to_all -------------
        recv_keys = jax.lax.all_to_all(send_keys, axis_name, 0, 0, tiled=False)
        recv_rids = jax.lax.all_to_all(send_rids, axis_name, 0, 0, tiled=False)
        recv_valid = jax.lax.all_to_all(send_valid, axis_name, 0, 0, tiled=False)

        # ---- phase 6: final local merge --------------------------------------
        rk = recv_keys.reshape(recv, n_words)
        rr = recv_rids.reshape(recv)
        rv = recv_valid.reshape(recv)
        # invalid rows carry sentinels already; sort once more (merge of p
        # runs), rid again a key word so equal keys land in (key, rid) order
        mk, mr, mv = sort_words_keyed(rk, rr, rv.astype(jnp.uint32))
        total_overflow = jax.lax.psum(overflow, axis_name)
        return mk, mr, mv.astype(jnp.bool_), total_overflow

    mapped = shard_map(
        shard_fn,
        mesh=mesh,
        in_specs=(P(axis_name, None), P(axis_name)),
        out_specs=(P(axis_name, None), P(axis_name), P(axis_name), P()),
    )

    @jax.jit
    def run_arrays(words, rids):
        return mapped(jnp.asarray(words, jnp.uint32), jnp.asarray(rids, jnp.uint32))

    def run(words, rids):
        k, r, v, ov = run_arrays(words, rids)
        return DistSortResult(keys=k, rids=r, valid=v, overflow=ov)

    return run


def sample_sort(
    words: jnp.ndarray,
    rids: jnp.ndarray,
    mesh: Mesh,
    axis_name: str = "data",
    capacity_factor: float = 1.5,
) -> DistSortResult:
    """Convenience wrapper: build + run the distributed sort."""
    n, w = words.shape
    p = mesh.shape[axis_name]
    if n % p:
        raise ValueError(f"n={n} must divide evenly over axis {axis_name}={p}")
    fn = make_sample_sort(mesh, axis_name, n // p, w, capacity_factor)
    return fn(words, rids)
