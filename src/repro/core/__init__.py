# The paper's primary contribution — compressed key sort + fast index
# reconstruction — as composable JAX modules. Sibling subpackages hold the
# substrates (models/train/serve/ckpt/data/distributed/launch).

from . import (
    btree,
    compress,
    dbits,
    distsort,
    index,
    keyformat,
    metadata,
    pipeline,
    reconstruct,
    snapshot,
    sortkeys,
)

__all__ = [
    "btree",
    "compress",
    "dbits",
    "distsort",
    "index",
    "keyformat",
    "metadata",
    "pipeline",
    "reconstruct",
    "snapshot",
    "sortkeys",
]
