"""Online index wrapper: search / insert / delete + DS-metadata upkeep (§4.3).

The bulk-built tree is immutable (SoA arrays); online mutations follow the
main-memory-DBMS recipe the paper assumes: inserts land in a small sorted
delta buffer, deletes set tombstones, DS-metadata is updated incrementally
(insert rule) or not at all (delete rule — lazy, valid by Theorem 2), and a
rebuild folds everything down via the compressed key sort.  This mirrors
the paper's premise that indexes are cheap to *reconstruct* and therefore
need neither eager maintenance of exact metadata nor a durable index image.

Reads go through the versioned snapshot protocol: the standing
reconstruction is published into a ``repro.core.snapshot.SnapshotCell``
and every lookup probes *this instance's* epoch with the backend's
plan-cached ``lookup`` op, then overlays the delta/tombstone view (the
overlay is only meaningful against the reconstruction it accumulated
on).  ``rebuild`` publishes the *next* epoch into the shared cell — the
successor answers from it while the pre-rebuild instance, and any
reader that acquired the old epoch from the cell, keep their
pre-rebuild answers (double buffering) — and the scalar ``search`` is a
thin wrapper over ``search_batch`` so single-query and batched results
can never diverge.

Mutations are double-entried: the sorted host-side delta/tombstone view
serves point lookups and neighbor queries (the transaction path), while a
``repro.replication.ChangeLog`` keeps the same mutations as LSN-stamped
columnar arrays — the *rebuild* path never touches a per-row Python tuple.
``rebuild`` folds the log with one vectorized mask + concatenate and goes
through ``ReconstructionPipeline.run_incremental``: when the D-bitmap is
unchanged since the last reconstruction only the delta is extracted and
sorted and the backend merges it into the standing run; when an insert set
a new distinction bit the pipeline falls back to the full resort.  Either
way the output is byte-identical, and rebuilds honour the index's
configured execution backend.
"""

from __future__ import annotations

import bisect
from dataclasses import dataclass, field, replace

import jax.numpy as jnp
import numpy as np

from .btree import BTreeConfig
from .keyformat import KeySet
from .metadata import DSMeta, meta_on_delete, meta_on_insert
from .pipeline import ReconstructionPipeline
from .reconstruct import ReconstructionResult, reconstruct_index
from .snapshot import SnapshotCell

__all__ = ["OnlineIndex"]


@dataclass
class OnlineIndex:
    """A reconstructable index with an insert delta and delete tombstones."""

    keyset: KeySet
    result: ReconstructionResult
    config: BTreeConfig = field(default_factory=BTreeConfig)
    backend: str = "jnp"
    #: the versioned read path: the standing reconstruction is published
    #: here and every lookup pins an epoch; ``rebuild`` hands the same
    #: cell to its successor so epochs keep increasing across rebuilds
    snapshots: SnapshotCell = field(default_factory=SnapshotCell, repr=False)
    _delta: list = field(default_factory=list)  # sorted [(key_tuple, rid)]
    _tombstones: set = field(default_factory=set)  # rids
    # sorted key-tuple cache for neighbor lookups: built lazily from the
    # tree's sorted order, then maintained incrementally per insert/delete
    # (the rebuild-per-insert it replaces was O(n log n) per mutation)
    _sorted_keys: list | None = field(default=None, repr=False)
    # the same mutations as columnar LSN-stamped arrays — the rebuild path
    # (fold + incremental merge) consumes this, never the tuple list
    _log: object | None = field(default=None, repr=False)
    _lookup_backend: object | None = field(default=None, repr=False)
    # THIS instance's epoch: searches probe it, not the cell head — the
    # delta/tombstone overlay only makes sense against the reconstruction
    # this instance was built from, so a pre-rebuild instance must not
    # mix its overlay with a successor's tree
    _snapshot: object | None = field(default=None, repr=False)

    def __post_init__(self):
        # publish the standing result unless the cell already carries it
        # (the rebuild path publishes before constructing the successor),
        # then bind this instance to its own epoch's snapshot
        cur = self.snapshots.current
        if cur is None or cur.tree is not self.result.tree:
            cur = self.snapshots.publish(self.result)
        self._snapshot = cur

    @property
    def log(self):
        from repro.replication import ChangeLog

        if self._log is None:
            self._log = ChangeLog(self.keyset.n_words)
        return self._log

    # ------------------------------------------------------------------ build
    @staticmethod
    def build(keyset: KeySet, meta: DSMeta | None = None,
              config: BTreeConfig = BTreeConfig(),
              backend: str = "jnp") -> "OnlineIndex":
        res = reconstruct_index(keyset, meta=meta, config=config, backend=backend)
        return OnlineIndex(keyset=keyset, result=res, config=config, backend=backend)

    @property
    def meta(self) -> DSMeta:
        return self.result.meta

    # ----------------------------------------------------------------- search
    def _backend_obj(self):
        """The lookup backend instance (lazy; matches ``self.backend``)."""
        if self._lookup_backend is None:
            from repro.backends import get_backend

            self._lookup_backend = get_backend(self.backend)
        return self._lookup_backend

    def search_batch(
        self, query_words: np.ndarray
    ) -> tuple[np.ndarray, np.ndarray]:
        """Batched point lookup: (q, W) keys -> ((q,) found, (q,) rid).

        The tree probe runs the backend's plan-cached ``lookup`` op
        against *this instance's* snapshot epoch (the reconstruction the
        delta/tombstone overlay is relative to — a pre-rebuild instance
        keeps answering from its own epoch even after a successor
        publishes); the overlay is applied per query.  Miss lanes carry
        ``NOT_FOUND_RID`` unless the delta answers them.
        """
        q = np.asarray(query_words, np.uint32).reshape(-1, self.keyset.n_words)
        found, rid = self._backend_obj().lookup(
            self._snapshot.tree, jnp.asarray(q, jnp.uint32)
        )
        found = np.asarray(found, bool).copy()
        rid = np.array(rid, np.uint32, copy=True)
        if self._tombstones or self._delta:
            # only a mutated instance pays the host-side overlay; right
            # after a rebuild the batched probe is pure device work
            for i in range(q.shape[0]):
                if found[i] and int(rid[i]) in self._tombstones:
                    found[i] = False
                if not found[i]:
                    key_t = tuple(int(x) for x in q[i])
                    j = bisect.bisect_left(self._delta, (key_t, -1))
                    if j < len(self._delta) and self._delta[j][0] == key_t:
                        found[i], rid[i] = True, np.uint32(self._delta[j][1])
        return found, rid

    def search(self, query_words: np.ndarray) -> tuple[bool, int]:
        """Point lookup for a single (W,) key; consults tree + delta - tombstones.

        A thin wrapper over :meth:`search_batch` — the scalar and batched
        paths share one implementation, so they can never diverge.
        """
        found, rid = self.search_batch(np.asarray(query_words, np.uint32)[None, :])
        return bool(found[0]), int(rid[0])

    # ----------------------------------------------------------------- insert
    def insert(self, key_words: np.ndarray, rid: int) -> None:
        """Insert K; update DS-metadata per §4.3 (set max(D(A,K), D(K,B)))."""
        key = np.asarray(key_words, np.uint32)
        key_t = tuple(int(x) for x in key)
        # neighbors A, B in the *current* sorted order (tree + delta view)
        a, b = self._neighbors(key_t)
        new_meta = meta_on_insert(self.meta, a, key, b)
        self.result.meta = new_meta
        bisect.insort(self._delta, (key_t, int(rid)))
        if self._sorted_keys is not None:
            bisect.insort(self._sorted_keys, key_t)
        self.log.append_inserts(key[None, :], [int(rid)])

    def delete(self, key_words: np.ndarray) -> bool:
        """Delete K; DS-metadata untouched (lazy rule, valid by Theorem 2)."""
        found, rid = self.search(np.asarray(key_words, np.uint32))
        if not found:
            return False
        key_t = tuple(int(x) for x in np.asarray(key_words, np.uint32))
        i = bisect.bisect_left(self._delta, (key_t, -1))
        if i < len(self._delta) and self._delta[i][0] == key_t:
            rid = self._delta[i][1]
            self._delta.pop(i)
            if self._sorted_keys is not None:
                j = bisect.bisect_left(self._sorted_keys, key_t)
                if j < len(self._sorted_keys) and self._sorted_keys[j] == key_t:
                    self._sorted_keys.pop(j)
        else:
            # tombstoned base rows stay in the neighbor view (as before):
            # stale neighbors only ever *extend* the distinction bit set,
            # which Theorem 2 permits
            self._tombstones.add(rid)
        self.log.append_deletes([int(rid)])
        self.result.meta = meta_on_delete(self.meta)
        return True

    def _neighbors(self, key_t: tuple) -> tuple[np.ndarray | None, np.ndarray | None]:
        keys = self._sorted_view()
        i = bisect.bisect_left(keys, key_t)
        a = np.asarray(keys[i - 1], np.uint32) if i > 0 else None
        b = np.asarray(keys[i], np.uint32) if i < len(keys) else None
        return a, b

    def _sorted_view(self) -> list:
        """The sorted (base + delta) key tuples, built once then maintained
        incrementally by insert/delete."""
        if self._sorted_keys is None:
            sf = np.asarray(self.result.tree.sorted_full)
            keys = [tuple(int(x) for x in r) for r in sf]
            for k, _ in self._delta:
                bisect.insort(keys, k)
            self._sorted_keys = keys
        return self._sorted_keys

    # ---------------------------------------------------------------- rebuild
    def rebuild(self, backend: str | None = None) -> "OnlineIndex":
        """Fold the change log into the base table and reconstruct with the
        *current* (possibly stale-bit) DS-metadata — the paper's recovery path.

        The fold is one vectorized mask + concatenate over the log's
        columnar arrays, and reconstruction goes through
        ``run_incremental``: unchanged D-bitmap ⇒ only the delta is
        extracted/sorted and merged into the standing run; otherwise the
        pipeline falls back to the byte-identical full resort (key
        compression with the current bitmap — extended positions OK).
        """
        keep_rows, delta = self.log.fold_keyset(self.keyset)
        name = backend or self.backend
        pipe = ReconstructionPipeline(backend=name, config=self.config)
        res, folded = pipe.run_incremental(
            self.result, self.keyset, delta, keep_rows=keep_rows, meta=self.meta,
            publish_to=self.snapshots,
        )
        # pin the carried bitmap to what the standing run was extracted
        # under (a superset of the refreshed bitmap — valid by Theorem 2) so
        # a quiet follow-up rebuild can merge instead of resort; see ROADMAP
        # on shedding policy
        res.meta = replace(
            res.meta, dbitmap=np.array(res.extract_bitmap, np.uint32, copy=True)
        )
        # the successor shares the cell (external readers acquire epochs
        # from it); each instance stays bound to its own epoch's snapshot,
        # so the pre-rebuild instance keeps answering from the pre-rebuild
        # tree + its own overlay
        return OnlineIndex(
            keyset=folded, result=res, config=self.config, backend=name,
            snapshots=self.snapshots,
        )
