"""Online index wrapper: search / insert / delete + DS-metadata upkeep (§4.3).

The bulk-built tree is immutable (SoA arrays); online mutations follow the
main-memory-DBMS recipe the paper assumes: inserts land in a small sorted
delta buffer, deletes set tombstones, DS-metadata is updated incrementally
(insert rule) or not at all (delete rule — lazy, valid by Theorem 2), and a
rebuild folds everything down via the compressed key sort.  This mirrors
the paper's premise that indexes are cheap to *reconstruct* and therefore
need neither logging nor eager maintenance of exact metadata.

Rebuilds route through ``ReconstructionPipeline`` and honour the index's
configured execution backend, so an online index on a mesh rebuilds with
the distributed sample sort while its mutation path stays host-side.
"""

from __future__ import annotations

import bisect
from dataclasses import dataclass, field

import jax.numpy as jnp
import numpy as np

from .btree import BTreeConfig, search_batch
from .keyformat import KeySet
from .metadata import DSMeta, meta_on_delete, meta_on_insert
from .pipeline import ReconstructionPipeline
from .reconstruct import ReconstructionResult, reconstruct_index

__all__ = ["OnlineIndex"]


@dataclass
class OnlineIndex:
    """A reconstructable index with an insert delta and delete tombstones."""

    keyset: KeySet
    result: ReconstructionResult
    config: BTreeConfig = field(default_factory=BTreeConfig)
    backend: str = "jnp"
    _delta: list = field(default_factory=list)  # sorted [(key_tuple, rid)]
    _tombstones: set = field(default_factory=set)  # rids
    # sorted key-tuple cache for neighbor lookups: built lazily from the
    # tree's sorted order, then maintained incrementally per insert/delete
    # (the rebuild-per-insert it replaces was O(n log n) per mutation)
    _sorted_keys: list | None = field(default=None, repr=False)

    # ------------------------------------------------------------------ build
    @staticmethod
    def build(keyset: KeySet, meta: DSMeta | None = None,
              config: BTreeConfig = BTreeConfig(),
              backend: str = "jnp") -> "OnlineIndex":
        res = reconstruct_index(keyset, meta=meta, config=config, backend=backend)
        return OnlineIndex(keyset=keyset, result=res, config=config, backend=backend)

    @property
    def meta(self) -> DSMeta:
        return self.result.meta

    # ----------------------------------------------------------------- search
    def search(self, query_words: np.ndarray) -> tuple[bool, int]:
        """Point lookup for a single (W,) key; consults tree + delta - tombstones."""
        q = jnp.asarray(query_words, jnp.uint32)[None, :]
        found, rid, _ = search_batch(self.result.tree, q)
        found, rid = bool(found[0]), int(rid[0])
        if found and rid in self._tombstones:
            found = False
        if not found:
            key_t = tuple(int(x) for x in np.asarray(query_words))
            i = bisect.bisect_left(self._delta, (key_t, -1))
            if i < len(self._delta) and self._delta[i][0] == key_t:
                return True, self._delta[i][1]
        return found, rid

    # ----------------------------------------------------------------- insert
    def insert(self, key_words: np.ndarray, rid: int) -> None:
        """Insert K; update DS-metadata per §4.3 (set max(D(A,K), D(K,B)))."""
        key = np.asarray(key_words, np.uint32)
        key_t = tuple(int(x) for x in key)
        # neighbors A, B in the *current* sorted order (tree + delta view)
        a, b = self._neighbors(key_t)
        new_meta = meta_on_insert(self.meta, a, key, b)
        self.result.meta = new_meta
        bisect.insort(self._delta, (key_t, int(rid)))
        if self._sorted_keys is not None:
            bisect.insort(self._sorted_keys, key_t)

    def delete(self, key_words: np.ndarray) -> bool:
        """Delete K; DS-metadata untouched (lazy rule, valid by Theorem 2)."""
        found, rid = self.search(np.asarray(key_words, np.uint32))
        if not found:
            return False
        key_t = tuple(int(x) for x in np.asarray(key_words, np.uint32))
        i = bisect.bisect_left(self._delta, (key_t, -1))
        if i < len(self._delta) and self._delta[i][0] == key_t:
            self._delta.pop(i)
            if self._sorted_keys is not None:
                j = bisect.bisect_left(self._sorted_keys, key_t)
                if j < len(self._sorted_keys) and self._sorted_keys[j] == key_t:
                    self._sorted_keys.pop(j)
        else:
            # tombstoned base rows stay in the neighbor view (as before):
            # stale neighbors only ever *extend* the distinction bit set,
            # which Theorem 2 permits
            self._tombstones.add(rid)
        self.result.meta = meta_on_delete(self.meta)
        return True

    def _neighbors(self, key_t: tuple) -> tuple[np.ndarray | None, np.ndarray | None]:
        keys = self._sorted_view()
        i = bisect.bisect_left(keys, key_t)
        a = np.asarray(keys[i - 1], np.uint32) if i > 0 else None
        b = np.asarray(keys[i], np.uint32) if i < len(keys) else None
        return a, b

    def _sorted_view(self) -> list:
        """The sorted (base + delta) key tuples, built once then maintained
        incrementally by insert/delete."""
        if self._sorted_keys is None:
            sf = np.asarray(self.result.tree.sorted_full)
            keys = [tuple(int(x) for x in r) for r in sf]
            for k, _ in self._delta:
                bisect.insort(keys, k)
            self._sorted_keys = keys
        return self._sorted_keys

    # ---------------------------------------------------------------- rebuild
    def rebuild(self, backend: str | None = None) -> "OnlineIndex":
        """Fold delta/tombstones into the base table and reconstruct with the
        *current* (possibly stale-bit) DS-metadata — the paper's recovery path."""
        sf = np.asarray(self.keyset.words)
        lengths = list(np.asarray(self.keyset.lengths))
        rids = list(np.asarray(self.keyset.rids))
        rows = [r for r in zip(sf, lengths, rids) if int(r[2]) not in self._tombstones]
        for key_t, rid in self._delta:
            rows.append((np.asarray(key_t, np.uint32), len(key_t) * 4, rid))
        words = np.stack([r[0] for r in rows])
        ks = KeySet(
            words=words,
            lengths=np.asarray([r[1] for r in rows], np.int32),
            rids=np.asarray([r[2] for r in rows], np.uint32),
        )
        # key compression with the current bitmap (extended positions OK)
        name = backend or self.backend
        pipe = ReconstructionPipeline(backend=name, config=self.config)
        res = pipe.run(ks, meta=self.meta)
        return OnlineIndex(keyset=ks, result=res, config=self.config, backend=name)
