"""Online index wrapper: search / insert / delete + DS-metadata upkeep (§4.3).

The bulk-built tree is immutable (SoA arrays); online mutations follow the
main-memory-DBMS recipe the paper assumes: inserts land in a small sorted
delta buffer, deletes set tombstones, DS-metadata is updated incrementally
(insert rule) or not at all (delete rule — lazy, valid by Theorem 2), and a
rebuild folds everything down via the compressed key sort.  This mirrors
the paper's premise that indexes are cheap to *reconstruct* and therefore
need neither eager maintenance of exact metadata nor a durable index image.

Mutations are double-entried: the sorted host-side delta/tombstone view
serves point lookups and neighbor queries (the transaction path), while a
``repro.replication.ChangeLog`` keeps the same mutations as LSN-stamped
columnar arrays — the *rebuild* path never touches a per-row Python tuple.
``rebuild`` folds the log with one vectorized mask + concatenate and goes
through ``ReconstructionPipeline.run_incremental``: when the D-bitmap is
unchanged since the last reconstruction only the delta is extracted and
sorted and the backend merges it into the standing run; when an insert set
a new distinction bit the pipeline falls back to the full resort.  Either
way the output is byte-identical, and rebuilds honour the index's
configured execution backend.
"""

from __future__ import annotations

import bisect
from dataclasses import dataclass, field, replace

import jax.numpy as jnp
import numpy as np

from .btree import BTreeConfig, search_batch
from .keyformat import KeySet
from .metadata import DSMeta, meta_on_delete, meta_on_insert
from .pipeline import ReconstructionPipeline
from .reconstruct import ReconstructionResult, reconstruct_index

__all__ = ["OnlineIndex"]


@dataclass
class OnlineIndex:
    """A reconstructable index with an insert delta and delete tombstones."""

    keyset: KeySet
    result: ReconstructionResult
    config: BTreeConfig = field(default_factory=BTreeConfig)
    backend: str = "jnp"
    _delta: list = field(default_factory=list)  # sorted [(key_tuple, rid)]
    _tombstones: set = field(default_factory=set)  # rids
    # sorted key-tuple cache for neighbor lookups: built lazily from the
    # tree's sorted order, then maintained incrementally per insert/delete
    # (the rebuild-per-insert it replaces was O(n log n) per mutation)
    _sorted_keys: list | None = field(default=None, repr=False)
    # the same mutations as columnar LSN-stamped arrays — the rebuild path
    # (fold + incremental merge) consumes this, never the tuple list
    _log: object | None = field(default=None, repr=False)

    @property
    def log(self):
        from repro.replication import ChangeLog

        if self._log is None:
            self._log = ChangeLog(self.keyset.n_words)
        return self._log

    # ------------------------------------------------------------------ build
    @staticmethod
    def build(keyset: KeySet, meta: DSMeta | None = None,
              config: BTreeConfig = BTreeConfig(),
              backend: str = "jnp") -> "OnlineIndex":
        res = reconstruct_index(keyset, meta=meta, config=config, backend=backend)
        return OnlineIndex(keyset=keyset, result=res, config=config, backend=backend)

    @property
    def meta(self) -> DSMeta:
        return self.result.meta

    # ----------------------------------------------------------------- search
    def search(self, query_words: np.ndarray) -> tuple[bool, int]:
        """Point lookup for a single (W,) key; consults tree + delta - tombstones."""
        q = jnp.asarray(query_words, jnp.uint32)[None, :]
        found, rid, _ = search_batch(self.result.tree, q)
        found, rid = bool(found[0]), int(rid[0])
        if found and rid in self._tombstones:
            found = False
        if not found:
            key_t = tuple(int(x) for x in np.asarray(query_words))
            i = bisect.bisect_left(self._delta, (key_t, -1))
            if i < len(self._delta) and self._delta[i][0] == key_t:
                return True, self._delta[i][1]
        return found, rid

    # ----------------------------------------------------------------- insert
    def insert(self, key_words: np.ndarray, rid: int) -> None:
        """Insert K; update DS-metadata per §4.3 (set max(D(A,K), D(K,B)))."""
        key = np.asarray(key_words, np.uint32)
        key_t = tuple(int(x) for x in key)
        # neighbors A, B in the *current* sorted order (tree + delta view)
        a, b = self._neighbors(key_t)
        new_meta = meta_on_insert(self.meta, a, key, b)
        self.result.meta = new_meta
        bisect.insort(self._delta, (key_t, int(rid)))
        if self._sorted_keys is not None:
            bisect.insort(self._sorted_keys, key_t)
        self.log.append_inserts(key[None, :], [int(rid)])

    def delete(self, key_words: np.ndarray) -> bool:
        """Delete K; DS-metadata untouched (lazy rule, valid by Theorem 2)."""
        found, rid = self.search(np.asarray(key_words, np.uint32))
        if not found:
            return False
        key_t = tuple(int(x) for x in np.asarray(key_words, np.uint32))
        i = bisect.bisect_left(self._delta, (key_t, -1))
        if i < len(self._delta) and self._delta[i][0] == key_t:
            rid = self._delta[i][1]
            self._delta.pop(i)
            if self._sorted_keys is not None:
                j = bisect.bisect_left(self._sorted_keys, key_t)
                if j < len(self._sorted_keys) and self._sorted_keys[j] == key_t:
                    self._sorted_keys.pop(j)
        else:
            # tombstoned base rows stay in the neighbor view (as before):
            # stale neighbors only ever *extend* the distinction bit set,
            # which Theorem 2 permits
            self._tombstones.add(rid)
        self.log.append_deletes([int(rid)])
        self.result.meta = meta_on_delete(self.meta)
        return True

    def _neighbors(self, key_t: tuple) -> tuple[np.ndarray | None, np.ndarray | None]:
        keys = self._sorted_view()
        i = bisect.bisect_left(keys, key_t)
        a = np.asarray(keys[i - 1], np.uint32) if i > 0 else None
        b = np.asarray(keys[i], np.uint32) if i < len(keys) else None
        return a, b

    def _sorted_view(self) -> list:
        """The sorted (base + delta) key tuples, built once then maintained
        incrementally by insert/delete."""
        if self._sorted_keys is None:
            sf = np.asarray(self.result.tree.sorted_full)
            keys = [tuple(int(x) for x in r) for r in sf]
            for k, _ in self._delta:
                bisect.insort(keys, k)
            self._sorted_keys = keys
        return self._sorted_keys

    # ---------------------------------------------------------------- rebuild
    def rebuild(self, backend: str | None = None) -> "OnlineIndex":
        """Fold the change log into the base table and reconstruct with the
        *current* (possibly stale-bit) DS-metadata — the paper's recovery path.

        The fold is one vectorized mask + concatenate over the log's
        columnar arrays, and reconstruction goes through
        ``run_incremental``: unchanged D-bitmap ⇒ only the delta is
        extracted/sorted and merged into the standing run; otherwise the
        pipeline falls back to the byte-identical full resort (key
        compression with the current bitmap — extended positions OK).
        """
        keep_rows, delta = self.log.fold_keyset(self.keyset)
        name = backend or self.backend
        pipe = ReconstructionPipeline(backend=name, config=self.config)
        res, folded = pipe.run_incremental(
            self.result, self.keyset, delta, keep_rows=keep_rows, meta=self.meta
        )
        # pin the carried bitmap to what the standing run was extracted
        # under (a superset of the refreshed bitmap — valid by Theorem 2) so
        # a quiet follow-up rebuild can merge instead of resort; see ROADMAP
        # on shedding policy
        res.meta = replace(
            res.meta, dbitmap=np.array(res.extract_bitmap, np.uint32, copy=True)
        )
        return OnlineIndex(keyset=folded, result=res, config=self.config, backend=name)
