"""Compressed key sort (paper §3.2, §5.2) — single-device orchestration.

The sort key is the pair (compressed key, record id).  We keep the record id
as a payload operand of ``lax.sort`` rather than splicing its variant bits
into the key (the paper's Table 2 does both; payload form is equivalent
because ``lax.sort`` is stable and the rid uniquifies entries, and it keeps
the comparator width at exactly the compressed width).

The measurable effect of compression under XLA mirrors the paper's two
mechanisms:
  1. fewer sort-key words  -> fewer ``lax.sort`` key operands (smaller
     comparator, less data movement) — the paper's *sort key ratio*;
  2. distinction bits compacted into the leading word  -> comparator
     resolves in the first operand — the paper's *word comparison ratio*.
"""

from __future__ import annotations

from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from .compress import ExtractionPlan, extract_bits
from .dbits import sort_words

__all__ = ["SortResult", "full_key_sort", "compressed_key_sort", "word_comparison_counts"]


@dataclass
class SortResult:
    """Sorted sort-keys plus the permutation that produced them."""

    keys: jnp.ndarray  # (n, W) sorted (full or compressed) keys
    rids: jnp.ndarray  # (n,) record ids, permuted
    perm: jnp.ndarray  # (n,) original row index of each sorted row


@partial(jax.jit)
def _sort_with_payload(words, rids):
    n = words.shape[0]
    iota = jnp.arange(n, dtype=jnp.uint32)
    sw, srid, sperm = sort_words(words, rids, iota)
    return sw, srid, sperm


def full_key_sort(words: jnp.ndarray, rids: jnp.ndarray) -> SortResult:
    """Baseline: sort by the full (uncompressed) keys."""
    sw, srid, sperm = _sort_with_payload(jnp.asarray(words, jnp.uint32), rids)
    return SortResult(keys=sw, rids=srid, perm=sperm)


def compressed_key_sort(
    words: jnp.ndarray, rids: jnp.ndarray, plan: ExtractionPlan
) -> SortResult:
    """The paper's compressed key sort: extract distinction bits, then sort.

    Returns the *compressed* keys in sorted order; by Theorem 2 the induced
    permutation sorts the full keys as well.
    """
    comp = extract_bits(jnp.asarray(words, jnp.uint32), plan)
    sw, srid, sperm = _sort_with_payload(comp, rids)
    return SortResult(keys=sw, rids=srid, perm=sperm)


def word_comparison_counts(sorted_words: jnp.ndarray, sample_pairs: int = 4096,
                           seed: int = 0) -> jnp.ndarray:
    """Estimate wcc — average word comparisons per key comparison (§6.3).

    A comparator examines words until the first difference; for a random
    pair that is (index of first differing word + 1).  Sampled over random
    pairs of the key set.
    """
    n, w = sorted_words.shape
    k = jax.random.PRNGKey(seed)
    idx = jax.random.randint(k, (sample_pairs, 2), 0, n)
    a = sorted_words[idx[:, 0]]
    b = sorted_words[idx[:, 1]]
    diff = a != b
    any_diff = jnp.any(diff, axis=-1)
    first = jnp.argmax(diff, axis=-1)
    words_examined = jnp.where(any_diff, first + 1, w)
    return jnp.mean(words_examined.astype(jnp.float32))
