"""Compressed-key extraction (paper §5.1).

The CPU implementation uses the BMI ``PEXT`` instruction per 8-byte mask plus
shift/OR concatenation.  TPUs have no PEXT; we adapt the idea to the
TPU memory/compute hierarchy:

* The D-bitmap is metadata that changes only on reconstruction (it is
  persisted in the DS-metadata, §4.2), so we precompute an **extraction
  plan** host-side: for each output bit ``b`` of the compressed key, the
  source word and source shift in the full key.  The plan is a trace-time
  constant, turning bit gathering into a static shift/mask schedule — the
  TPU-idiomatic equivalent of PEXT where each scheduled op is amortized over
  the full 8×128 vector tile of keys.
* Two execution paths: a fully vectorized jnp path (`extract_bits` — also
  the oracle for the Pallas kernel) and the Pallas kernel in
  ``repro.kernels.pext`` that performs the same schedule per VMEM tile.

Output compressed keys are ``(n, Wc)`` uint32, word 0 most significant,
bit order preserved (ascending source position -> ascending output
position), which is exactly what Theorem 2 requires for order equivalence.
"""

from __future__ import annotations

from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from .dbits import dbit_positions_nonempty

__all__ = ["ExtractionPlan", "make_plan", "extract_bits", "extract_bits_dynamic"]


@dataclass(frozen=True)
class ExtractionPlan:
    """Static schedule mapping full-key bit positions to compressed-key bits.

    positions:   (B_c,) int32 ascending source bit positions (host numpy).
    src_word:    (B_c,) source word index   = positions // 32
    src_shift:   (B_c,) right-shift amount  = 31 - positions % 32
    n_words_in:  full key width in words.
    n_words_out: compressed key width in words = ceil(B_c / 32).
    """

    positions: tuple[int, ...]
    src_word: tuple[int, ...]
    src_shift: tuple[int, ...]
    n_words_in: int
    n_words_out: int

    @property
    def n_bits(self) -> int:
        return len(self.positions)

    def dst(self, b: int) -> tuple[int, int]:
        """(dst_word, dst_shift) of output bit b (b=0 is global MSB)."""
        return b // 32, 31 - (b % 32)

    def as_arrays(self) -> dict[str, np.ndarray]:
        """Plan as dense arrays (for the scalar-prefetch kernel variant)."""
        b = np.arange(self.n_bits, dtype=np.int32)
        return {
            "src_word": np.asarray(self.src_word, np.int32),
            "src_shift": np.asarray(self.src_shift, np.int32),
            "dst_word": b // 32,
            "dst_shift": 31 - (b % 32),
        }


def make_plan(bitmap: np.ndarray, n_words_in: int | None = None) -> ExtractionPlan:
    """Build the extraction plan from a D-bitmap (host-side)."""
    bm = np.asarray(bitmap, dtype=np.uint32)
    if n_words_in is None:
        n_words_in = bm.shape[0]
    pos = dbit_positions_nonempty(bm)
    return ExtractionPlan(
        positions=tuple(int(p) for p in pos),
        src_word=tuple(int(p) // 32 for p in pos),
        src_shift=tuple(31 - int(p) % 32 for p in pos),
        n_words_in=int(n_words_in),
        n_words_out=(len(pos) + 31) // 32,
    )


@partial(jax.jit, static_argnames=("plan",))
def extract_bits(words: jnp.ndarray, plan: ExtractionPlan) -> jnp.ndarray:
    """Vectorized compressed-key extraction, (n, W) uint32 -> (n, Wc) uint32.

    One shift+mask+shift+or per planned bit, fully parallel over keys.  This
    is the pure-jnp oracle for ``repro.kernels.pext``.
    """
    w = jnp.asarray(words, jnp.uint32)
    n = w.shape[0]
    out = [jnp.zeros((n,), jnp.uint32) for _ in range(plan.n_words_out)]
    for b in range(plan.n_bits):
        sw, ss = plan.src_word[b], plan.src_shift[b]
        dw, ds = plan.dst(b)
        bit = (w[:, sw] >> np.uint32(ss)) & jnp.uint32(1)
        out[dw] = out[dw] | (bit << np.uint32(ds))
    return jnp.stack(out, axis=1)


@partial(jax.jit, static_argnames=("n_words_out",))
def extract_bits_dynamic(
    words: jnp.ndarray, bitmap: jnp.ndarray, n_words_out: int
) -> jnp.ndarray:
    """Dynamic-bitmap extraction (no host round-trip).

    For runtime-updated D-bitmaps (e.g. after online inserts, §4.3) where
    re-tracing per bitmap is undesirable.  Unpacks the key tile to a bit
    matrix, ranks the selected columns with a cumulative popcount of the
    bitmap, and packs via one-hot matmul — MXU-friendly, at the price of
    materializing the (n, 32·W) bit matrix per block.
    """
    w = jnp.asarray(words, jnp.uint32)
    n, W = w.shape
    shifts = jnp.arange(31, -1, -1, dtype=jnp.uint32)
    bits = ((w[:, :, None] >> shifts[None, None, :]) & jnp.uint32(1)).reshape(n, W * 32)
    bmbits = ((bitmap[:, None] >> shifts[None, :]) & jnp.uint32(1)).reshape(W * 32)
    # output slot of each source bit (ascending position order preserved)
    slot = jnp.cumsum(bmbits) - 1
    sel = bmbits.astype(bool)
    B_out = n_words_out * 32
    slot = jnp.where(sel, slot, B_out)  # parked: one past the packed range
    packed = jnp.zeros((n, B_out + 1), jnp.uint32).at[:, slot].max(bits)
    packed = packed[:, :B_out].reshape(n, n_words_out, 32)
    weights = (jnp.uint32(1) << jnp.arange(31, -1, -1, dtype=jnp.uint32))
    return jnp.sum(packed * weights[None, None, :], axis=-1, dtype=jnp.uint32)
