"""Distinction bits (paper §3).

Keys are ``(n, W)`` ``uint32`` arrays, word 0 most significant, bit position
``p`` at word ``p // 32``, shift ``31 - (p % 32)`` (position 0 = global MSB,
matching the paper's numbering).

The central facts implemented here:

* Lemma 1:    D-bit(key_i, key_j) = min_{i<k<=j} D_k   (adjacent D-bits).
* Theorem 1:  the set of distinction bit positions over *all* pairs equals
              the set over *adjacent* pairs in sorted order, hence at most
              ``n`` positions for ``n+1`` keys.
* Theorem 2:  the bit slice at (a superset of) the distinction bit positions
              sorts the keys correctly.

``compute_dbitmap`` therefore only ever looks at adjacent keys of the sorted
input — O(n) work on top of the sort, exactly the paper's Remark 1.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "lex_less",
    "lex_compare_le",
    "sort_words",
    "sort_words_keyed",
    "rank_in_sorted_keyed",
    "merge_from_ranks",
    "merge_words_keyed",
    "adjacent_dbit_positions",
    "dbit_position_pairwise",
    "positions_to_bitmap",
    "bitmap_to_positions",
    "dbit_positions_nonempty",
    "bitmap_popcount",
    "compute_dbitmap",
    "compute_variant_bitmap",
    "NO_DBIT",
]

# Sentinel distinction-bit position for equal keys: one past the last bit.
NO_DBIT = np.int32(2**31 - 1)


# ---------------------------------------------------------------------------
# multiword lexicographic comparison
# ---------------------------------------------------------------------------

def lex_less(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Vectorized ``a < b`` for (..., W) uint32 keys, word 0 most significant."""
    lt = a < b
    eq = a == b
    # prefix of equal words before each position
    eq_prefix = jnp.cumprod(
        jnp.concatenate(
            [jnp.ones_like(eq[..., :1], dtype=jnp.int32), eq[..., :-1].astype(jnp.int32)],
            axis=-1,
        ),
        axis=-1,
    ).astype(bool)
    return jnp.any(lt & eq_prefix, axis=-1)


def lex_compare_le(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    eq = jnp.all(a == b, axis=-1)
    return lex_less(a, b) | eq


def sort_words_keyed(
    keys: jnp.ndarray, rows: jnp.ndarray, *payloads: jnp.ndarray
) -> tuple[jnp.ndarray, ...]:
    """Sort (n, W) keys with (n,) rows as the least-significant key word.

    The paper's sort key is literally the (compressed key, rid) pair; making
    the row a key word (not a stable-sort payload) is THE definition of the
    backend determinism contract — ascending (key, row) order regardless of
    input order — shared by every backend and the distributed merges.
    Returns (keys_sorted, rows_sorted, *payloads_sorted).
    """
    w = keys.shape[1]
    keyed = jnp.concatenate(
        [keys, jnp.asarray(rows, jnp.uint32)[:, None]], axis=1
    )
    out = sort_words(keyed, *payloads)
    return (out[0][:, :w], out[0][:, w]) + tuple(out[1:])


def sort_words(
    words: jnp.ndarray, *payloads: jnp.ndarray, num_key_words: int | None = None
) -> tuple[jnp.ndarray, ...]:
    """Lexicographic sort of (n, W) keys with payload arrays.

    Maps each of the first ``num_key_words`` word columns to a ``lax.sort``
    key operand — the multiword comparator of the paper, where the word count
    of the sort key directly sets the comparator cost.  Compression lowers
    ``num_key_words``; this is the mechanism by which the paper's word
    comparison ratio becomes a real speedup under XLA.
    """
    n, w = words.shape
    if num_key_words is None:
        num_key_words = w
    operands = tuple(words[:, i] for i in range(w)) + tuple(payloads)
    out = jax.lax.sort(operands, num_keys=num_key_words)
    sorted_words = jnp.stack(out[:w], axis=1)
    return (sorted_words,) + tuple(out[w:])


# ---------------------------------------------------------------------------
# merge of sorted (key, row) runs
# ---------------------------------------------------------------------------

def rank_in_sorted_keyed(
    keys_s: jnp.ndarray,
    rows_s: jnp.ndarray,
    keys_q: jnp.ndarray,
    rows_q: jnp.ndarray,
) -> jnp.ndarray:
    """Rank of each query pair in a sorted run: #{i : (key_s, row_s)_i < q}.

    ``(keys_s, rows_s)`` must be ascending in the (key, row) order of the
    backend determinism contract.  The query pairs need not be sorted.  This
    is the merge-path primitive: the output position of a run element in the
    two-run merge is its own index plus its rank in the *other* run.
    Vectorized binary search — log2(n_s) steps of whole-array lexicographic
    compares, no host loop.
    """
    ns = int(keys_s.shape[0])
    nq = int(keys_q.shape[0])
    if ns == 0 or nq == 0:
        return jnp.zeros((nq,), jnp.int32)
    lo = jnp.zeros((nq,), jnp.int32)
    hi = jnp.full((nq,), ns, jnp.int32)
    for _ in range(max(1, ns.bit_length())):
        mid = (lo + hi) // 2
        midc = jnp.minimum(mid, ns - 1)
        sk = keys_s[midc]
        sr = rows_s[midc]
        eq = jnp.all(sk == keys_q, axis=-1)
        lt = lex_less(sk, keys_q) | (eq & (sr < rows_q))
        lt = lt & (mid < ns)
        lo = jnp.where(lt, mid + 1, lo)
        hi = jnp.where(lt, hi, mid)
    return lo


def merge_from_ranks(
    keys_a: jnp.ndarray,
    rows_a: jnp.ndarray,
    keys_b: jnp.ndarray,
    rows_b: jnp.ndarray,
    rank_fn=None,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Merge two ascending (key, row) runs given a rank primitive.

    ``rank_fn(keys_s, rows_s, keys_q, rows_q)`` must return the rank of
    each query pair in the sorted run (#{s < q}); the merge is then a
    permutation scatter: each element's output position is its own index
    plus its rank in the other run.  Rows must be distinct across the two
    runs so the (key, row) order is total and the scatter collision-free.
    The default primitive is ``rank_in_sorted_keyed``; the Pallas backend
    passes its tiled rank kernel instead.
    """
    if rank_fn is None:
        rank_fn = rank_in_sorted_keyed
    keys_a = jnp.asarray(keys_a, jnp.uint32)
    keys_b = jnp.asarray(keys_b, jnp.uint32)
    rows_a = jnp.asarray(rows_a, jnp.uint32)
    rows_b = jnp.asarray(rows_b, jnp.uint32)
    na, nb = int(keys_a.shape[0]), int(keys_b.shape[0])
    if na == 0:
        return keys_b, rows_b
    if nb == 0:
        return keys_a, rows_a
    # One rank pass, not two: rank the smaller run in the larger one, then
    # derive the larger run's positions from the complement.  The scatter
    # positions of the ranked run are exact; the other run fills the
    # remaining output slots in its own (ascending) order, so position p
    # holds element ``p - #{ranked elements before p}`` of the unranked
    # run.  That complement is one cumsum + one gather — O(n) — replacing
    # the second O(n log n) whole-array binary-search pass.  The resulting
    # permutation is identical to the two-pass construction, so the output
    # stays byte-identical to ``sort_words_keyed`` over the concatenation.
    if nb <= na:
        small_k, small_r, big_k, big_r = keys_b, rows_b, keys_a, rows_a
    else:
        small_k, small_r, big_k, big_r = keys_a, rows_a, keys_b, rows_b
    n_small, n_big = int(small_k.shape[0]), int(big_k.shape[0])
    n, w = na + nb, int(keys_a.shape[1])
    pos_s = (
        jnp.arange(n_small, dtype=jnp.int32)
        + rank_fn(big_k, big_r, small_k, small_r)
    )
    occ = jnp.zeros((n,), jnp.int32).at[pos_s].set(1)
    # number of ranked (small-run) elements strictly before each position
    before = jnp.cumsum(occ) - occ
    big_idx = jnp.clip(
        jnp.arange(n, dtype=jnp.int32) - before, 0, n_big - 1
    )
    keys = jnp.where(
        (occ == 1)[:, None],
        jnp.zeros((n, w), jnp.uint32).at[pos_s].set(small_k),
        big_k[big_idx],
    )
    rows = jnp.where(
        occ == 1,
        jnp.zeros((n,), jnp.uint32).at[pos_s].set(small_r),
        big_r[big_idx],
    )
    return keys, rows


def merge_words_keyed(
    keys_a: jnp.ndarray,
    rows_a: jnp.ndarray,
    keys_b: jnp.ndarray,
    rows_b: jnp.ndarray,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Merge two runs that are each ascending in (key, row) order.

    Byte-identical to ``sort_words_keyed`` over the concatenated pairs —
    rows must be distinct across both runs, so the (key, row) order is total
    and the merge is a permutation scatter (O(n log n) comparisons for the
    ranks vs the full sort's network; O(n) data movement).  This is the jnp
    reference semantics of the backend ``merge_sorted`` op.
    """
    return merge_from_ranks(keys_a, rows_a, keys_b, rows_b)


# ---------------------------------------------------------------------------
# distinction bit positions
# ---------------------------------------------------------------------------

def dbit_position_pairwise(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """D-bit(a, b) for (..., W) keys: MSB position where they differ.

    Returns NO_DBIT where the keys are equal.
    """
    x = a ^ b
    nz = x != 0
    any_nz = jnp.any(nz, axis=-1)
    first_word = jnp.argmax(nz, axis=-1)  # first differing word
    xw = jnp.take_along_axis(x, first_word[..., None], axis=-1)[..., 0]
    # clz of a uint32: number of leading zeros == bit offset of MSB set bit
    clz = jax.lax.clz(xw.astype(jnp.uint32)).astype(jnp.int32)
    pos = first_word.astype(jnp.int32) * 32 + clz
    return jnp.where(any_nz, pos, NO_DBIT)


def adjacent_dbit_positions(sorted_words: jnp.ndarray) -> jnp.ndarray:
    """D_i = D-bit(key_{i-1}, key_i) for i in 1..n-1 of sorted keys.

    Shape (n-1,).  Equal adjacent keys (duplicates) yield NO_DBIT which
    callers must mask before scattering into a bitmap.
    """
    return dbit_position_pairwise(sorted_words[:-1], sorted_words[1:])


def positions_to_bitmap(positions: jnp.ndarray, n_words: int) -> jnp.ndarray:
    """Scatter bit positions into a (n_words,) uint32 bitmap (MSB-first)."""
    valid = positions != NO_DBIT
    pos = jnp.where(valid, positions, 0)
    word = pos // 32
    bit = jnp.where(valid, jnp.uint32(1) << (31 - (pos % 32)).astype(jnp.uint32), 0)
    zeros = jnp.zeros((n_words,), dtype=jnp.uint32)
    return _scatter_or(zeros, word, bit)


def _scatter_or(zeros: jnp.ndarray, word_idx: jnp.ndarray, bitmask: jnp.ndarray) -> jnp.ndarray:
    """OR-scatter bitmask values into words. Duplicate-safe."""
    n_words = zeros.shape[0]
    out = zeros
    # one plane per bit keeps the scatter duplicate-safe: a plane's scatter
    # writes the same value for every duplicate, so `.max` is an OR.
    for b in range(32):
        mask = jnp.uint32(1) << b
        plane = (bitmask & mask) != 0
        hits = jnp.zeros((n_words,), jnp.uint32).at[word_idx].max(plane.astype(jnp.uint32))
        out = out | (hits << b)
    return out


def bitmap_to_positions(bitmap: np.ndarray) -> np.ndarray:
    """Positions of set bits, ascending (host-side; bitmap is metadata)."""
    bm = np.asarray(bitmap, dtype=np.uint32)
    out = []
    for wi, w in enumerate(bm):
        w = int(w)
        for b in range(32):
            if w & (1 << (31 - b)):
                out.append(wi * 32 + b)
    return np.asarray(out, dtype=np.int32)


def dbit_positions_nonempty(bitmap: np.ndarray) -> np.ndarray:
    """``bitmap_to_positions`` with the degenerate-bitmap convention.

    An empty D-bitmap (all keys identical) yields the single position 0 so
    extraction plans, D-offset tables and tree builds all keep one-bit
    shapes — the ONE place this convention is defined.
    """
    pos = bitmap_to_positions(bitmap)
    if len(pos) == 0:
        pos = np.asarray([0], dtype=np.int32)
    return pos


def bitmap_popcount(bitmap: jnp.ndarray) -> jnp.ndarray:
    return jnp.sum(jax.lax.population_count(bitmap.astype(jnp.uint32)).astype(jnp.int32))


@partial(jax.jit, static_argnames=("n_words",))
def _dbitmap_from_sorted(sorted_words: jnp.ndarray, n_words: int) -> jnp.ndarray:
    dpos = adjacent_dbit_positions(sorted_words)
    return positions_to_bitmap(dpos, n_words)


def compute_dbitmap(words: jnp.ndarray, *, presorted: bool = False) -> jnp.ndarray:
    """D-bitmap of a key set: sort, then adjacent-pair distinction bits.

    By Theorem 1 this bitmap covers the distinction bit positions of *every*
    key pair.
    """
    w = jnp.asarray(words, dtype=jnp.uint32)
    if not presorted:
        (w,) = sort_words(w)
    return _dbitmap_from_sorted(w, int(words.shape[1]))


def compute_variant_bitmap(
    words: jnp.ndarray, reference: jnp.ndarray | None = None
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Variant bitmap + reference key (paper §4.2): OR of (key XOR reference).

    The reference key is an arbitrary member — we take row 0.
    """
    w = jnp.asarray(words, dtype=jnp.uint32)
    ref = w[0] if reference is None else jnp.asarray(reference, jnp.uint32)
    var = jax.lax.reduce(
        w ^ ref[None, :],
        jnp.uint32(0),
        jax.lax.bitwise_or,
        dimensions=(0,),
    )
    return var, ref
