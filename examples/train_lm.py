"""End-to-end driver (deliverable (b)): train the ~100M-param repro-100m
model for a few hundred steps with the full substrate — compressed-key-sort
data shuffle, microbatched AdamW, atomic checkpoints, crash-restart.

  PYTHONPATH=src python examples/train_lm.py            # ~300 steps
  PYTHONPATH=src python examples/train_lm.py --quick    # smoke (2 min)
"""

import argparse
import sys

from repro.launch.train import main as train_main


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_100m_ckpt")
    args = ap.parse_args()
    if args.quick:
        train_main([
            "--arch", "repro-100m", "--steps", "30", "--batch", "4",
            "--seq", "128", "--ckpt-dir", args.ckpt_dir, "--ckpt-every", "25",
        ])
    else:
        train_main([
            "--arch", "repro-100m", "--steps", "300", "--batch", "8",
            "--seq", "256", "--ckpt-dir", args.ckpt_dir, "--ckpt-every", "100",
        ])


if __name__ == "__main__":
    main()
