"""Serve a (reduced) Qwen3-MoE model with the compressed-key-sort dispatch
and the paged KV cache whose page index is a reconstructable B-tree.

  PYTHONPATH=src python examples/serve_moe.py
"""

from dataclasses import replace

import jax
import numpy as np

from repro.configs import ARCHS
from repro.models.lm import LM
from repro.serve.engine import ServeEngine


def main():
    cfg = replace(ARCHS["qwen3-moe-235b-a22b"].reduced(), dispatch_mode="sort")
    model = LM(cfg, remat=False)
    params = model.init(jax.random.PRNGKey(0))
    print(f"== serving {cfg.name} (reduced; {cfg.n_experts} experts top-{cfg.top_k}, "
          f"sort-based dispatch) ==")

    eng = ServeEngine(model, params, max_seq=96, batch_size=4, page_tokens=16)
    prompts = np.random.default_rng(0).integers(0, cfg.vocab_size, (4, 32))
    out = eng.generate(prompts, n_new=16, temperature=0.8)
    print(f"   generated {out.shape[1]} tokens x {out.shape[0]} seqs")
    print(f"   pager: {eng.pager.stats}")

    print("== engine restart: page index reconstruction ==")
    st = eng.restart()
    print(f"   rebuilt in {st['rebuild_s']*1e3:.1f}ms, "
          f"compression {st['compression_ratio']:.2f}:1, "
          f"height {st['index_height']}")
    phys = eng.pager.lookup(seq_id=2, page_no=1)
    print(f"   lookup (seq 2, page 1) -> physical page {phys}")


if __name__ == "__main__":
    main()
