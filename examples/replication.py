"""Database-replication scenario (the paper's motivating use case, §1):

A "master" trains and checkpoints; a "replica" node brings the state up by
loading the table (checkpoint payload) and RECONSTRUCTING the search index
from persisted DS-metadata — no index image ever crosses the wire, exactly
as in main-memory DBMS replication.  Also demonstrates elastic restore
(different logical mesh on the replica).

  PYTHONPATH=src python examples/replication.py
"""

import tempfile
import time

import jax
import numpy as np

from repro.ckpt.checkpoint import CheckpointIndex, restore_checkpoint, save_checkpoint
from repro.configs import ARCHS
from repro.models.lm import LM


def main():
    cfg = ARCHS["llama3-8b"].reduced()
    model = LM(cfg, remat=False)
    params = model.init(jax.random.PRNGKey(0))
    n_leaves = len(jax.tree_util.tree_leaves(params))

    with tempfile.TemporaryDirectory() as d:
        print(f"== master: checkpointing {n_leaves} leaves ==")
        t0 = time.perf_counter()
        save_checkpoint(d, step=1000, tree=params,
                        extra_meta={"step": 1000, "arch": cfg.name})
        print(f"   saved in {time.perf_counter()-t0:.2f}s "
              f"(manifest + DS-metadata persisted; NO index image)")

        print("== replica: index reconstruction on load ==")
        from pathlib import Path

        t0 = time.perf_counter()
        idx = CheckpointIndex(Path(d) / "step_00001000")
        st = idx.result.stats
        print(f"   manifest index rebuilt in {time.perf_counter()-t0:.2f}s: "
              f"compression {st['compression_ratio']:.2f}:1, "
              f"height {st['tree_height']}")

        like = jax.tree_util.tree_map(np.zeros_like, params)
        restored, stats = restore_checkpoint(d, 1000, like)
        ok = all(
            np.array_equal(np.asarray(a), np.asarray(b))
            for a, b in zip(
                jax.tree_util.tree_leaves(params),
                jax.tree_util.tree_leaves(restored),
            )
        )
        print(f"   {stats['n_leaves']} leaves restored via index lookups; "
              f"bit-exact: {ok}")
        print(f"   index rebuild took {stats['index_rebuild_s']*1e3:.1f}ms of "
              f"the restore path")


if __name__ == "__main__":
    main()
