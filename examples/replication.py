"""Async streaming replication demo: one primary, two lagging replicas.

The paper's motivating scenario (§1, §6) end to end: the wire carries the
table's change log and checkpoint *manifests* — never an index image —
and every consumer keeps its index current by reconstructing with the
compressed key sort:

* the **primary** owns the table, ships LSN-ordered ``ChangeLog`` batches
  over a ``DirectoryTransport`` spool, and checkpoints its state through
  ``save_checkpoint`` / ``save_checkpoint_delta`` chains;
* **replica A** tails the stream: every poll folds the pending batches
  through ONE incremental delta-merge rebuild (sort the delta, merge into
  the standing run);
* **replica B** sleeps through most of the stream; bounded-lag
  backpressure makes the primary checkpoint + truncate the spool, so B is
  forced onto the catch-up path — restore the checkpoint chain, then tail
  — and still lands **byte-identical** to A and to the primary.

  PYTHONPATH=src python examples/replication.py [--fast]
"""

import argparse
import tempfile
import time

import numpy as np

from repro.configs.paper_index import ZipfConfig
from repro.data.synthetic import zipf_keys
from repro.replication import (
    ChangeLog,
    DirectoryTransport,
    StreamPrimary,
    StreamReplica,
)


def identical(a, b) -> bool:
    """Byte-identity of two replicas' standing state."""
    return (
        np.array_equal(np.asarray(a.result.comp_sorted), np.asarray(b.result.comp_sorted))
        and np.array_equal(np.asarray(a.result.rid_sorted), np.asarray(b.result.rid_sorted))
        and np.array_equal(a.meta.dbitmap, b.meta.dbitmap)
        and a.applied_lsn == b.applied_lsn
    )


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true", help="smaller sizes (CI smoke)")
    ap.add_argument("--backend", default="jnp", help="replica backend (jnp/pallas)")
    args = ap.parse_args()
    n_keys = 4096 if args.fast else 32768
    n_batches = 10 if args.fast else 14
    batch = 128 if args.fast else 512

    rng = np.random.default_rng(0)
    base = zipf_keys(ZipfConfig(1.5, 40, 0, n_keys=n_keys), seed=0)

    with tempfile.TemporaryDirectory() as d:
        transport = DirectoryTransport(d + "/spool")
        primary = StreamPrimary(
            transport, base,
            ckpt_dir=d + "/ckpt",
            max_lag_batches=2,       # bounded lag: checkpoint + truncate past 2
            coalesce_min=batch,      # ship bucket-aligned batches
        )
        rep_a = StreamReplica(transport, backend=args.backend)
        rep_b = StreamReplica(transport, backend=args.backend)

        st = rep_a.poll()
        print(f"== replica A bring-up from the genesis batch: "
              f"{st['apply']['n_keys']} keys ==")

        next_rid = n_keys
        for b in range(n_batches):
            log = ChangeLog(base.n_words, start_lsn=primary.next_lsn)
            pick = rng.integers(0, primary.replica.keyset.n, size=batch)
            log.append_inserts(
                np.asarray(primary.replica.keyset.words)[pick],
                np.arange(next_rid, next_rid + batch, dtype=np.uint32),
            )
            next_rid += batch
            dead = rng.choice(np.asarray(primary.replica.keyset.rids),
                              size=batch // 4, replace=False)
            log.append_deletes(dead)
            primary.publish(log)

            t0 = time.perf_counter()
            st = rep_a.poll()     # A stays current; B sleeps
            if st["apply"]:
                a = st["apply"]
                path = "noop" if a.get("noop") else (
                    "incremental" if a["incremental"] else f"full ({a['fallback']})")
                print(f"   batch {b}: A applied {st['applied_batches']} frame(s) "
                      f"[{path}] +{a['n_delta']} -{a['n_deleted']} "
                      f"in {(time.perf_counter()-t0)*1e3:.1f}ms "
                      f"(lsn {st['applied_lsn']}, B lags {rep_b.lag_frames()} frames)")

        print(f"== primary: {primary.stats['n_batches_published']} batches, "
              f"{primary.stats['ckpt_step']} checkpoint step(s), "
              f"{primary.stats['transport_retained']} frames retained ==")

        t0 = time.perf_counter()
        st = rep_b.poll()
        print(f"== replica B wakes up: catch-up from the checkpoint chain ==")
        print(f"   catchup={st['catchup']} "
              f"(truncation jumped: {st['truncated_jump']}), then applied "
              f"{st['applied_batches']} batch frame(s) in "
              f"{time.perf_counter()-t0:.2f}s -> lsn {st['applied_lsn']}")

        ok_ab = identical(rep_a.replica, rep_b.replica)
        ok_ap = identical(rep_a.replica, primary.replica)
        print(f"   byte-identical: A==B {ok_ab}, A==primary {ok_ap}")
        if not (ok_ab and ok_ap):
            raise SystemExit("replicas diverged")

        # a point lookup answers the same everywhere
        probe = np.asarray(primary.replica.keyset.words)[17]
        print(f"   probe lookup: primary={primary.replica.search(probe)} "
              f"A={rep_a.search(probe)} B={rep_b.search(probe)}")


if __name__ == "__main__":
    main()
